//! Runtime server: the `xla` crate's PJRT client is `Rc`-based and thus
//! pinned to one thread, while coordinator jobs run on many. The server
//! owns the [`Runtime`] on a dedicated thread and job threads talk to it
//! through an mpsc request/reply protocol — the same "one executor
//! process, many logical workers" shape a real single-node deployment has.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::err;
use crate::runtime::{Meta, Runtime};
use crate::util::error::Result;

type Reply<T> = Sender<Result<T>>;

enum Req {
    TrainStep { params: Vec<f32>, tokens: Vec<i32>, pallas: bool, reply: Reply<(Vec<f32>, f32)> },
    GradStep { params: Vec<f32>, tokens: Vec<i32>, reply: Reply<(Vec<f32>, f32)> },
    AllReduceSum { x: Vec<f32>, y: Vec<f32>, reply: Reply<Vec<f32>> },
    ApplyGrads { params: Vec<f32>, grads: Vec<f32>, scale: f32, reply: Reply<Vec<f32>> },
    InitParams { reply: Reply<Vec<f32>> },
    Shutdown,
}

/// Clonable, `Send` handle to the runtime server.
#[derive(Clone)]
pub struct RtHandle {
    tx: Sender<Req>,
}

macro_rules! call {
    ($self:ident, $variant:ident { $($field:ident : $value:expr),* }) => {{
        let (reply, rx) = channel();
        $self
            .tx
            .send(Req::$variant { $($field: $value,)* reply })
            .map_err(|_| err!("runtime server is gone"))?;
        rx.recv().map_err(|_| err!("runtime server dropped the reply"))?
    }};
}

impl RtHandle {
    pub fn train_step(&self, params: Vec<f32>, tokens: Vec<i32>, pallas: bool) -> Result<(Vec<f32>, f32)> {
        call!(self, TrainStep { params: params, tokens: tokens, pallas: pallas })
    }

    pub fn grad_step(&self, params: Vec<f32>, tokens: Vec<i32>) -> Result<(Vec<f32>, f32)> {
        call!(self, GradStep { params: params, tokens: tokens })
    }

    pub fn allreduce_sum(&self, x: Vec<f32>, y: Vec<f32>) -> Result<Vec<f32>> {
        call!(self, AllReduceSum { x: x, y: y })
    }

    pub fn apply_grads(&self, params: Vec<f32>, grads: Vec<f32>, scale: f32) -> Result<Vec<f32>> {
        call!(self, ApplyGrads { params: params, grads: grads, scale: scale })
    }

    pub fn init_params(&self) -> Result<Vec<f32>> {
        call!(self, InitParams {})
    }
}

/// The running server: keeps the join handle + parsed meta.
pub struct RtServer {
    tx: Sender<Req>,
    join: Option<JoinHandle<()>>,
    pub meta: Meta,
}

impl RtServer {
    /// Load artifacts from `dir` on a fresh thread and start serving.
    pub fn start(dir: impl Into<PathBuf>) -> Result<RtServer> {
        let dir = dir.into();
        let (tx, rx) = channel::<Req>();
        let (meta_tx, meta_rx) = channel::<Result<Meta>>();
        let join = std::thread::Builder::new()
            .name("rt-server".into())
            .spawn(move || serve(dir, rx, meta_tx))
            .expect("spawn rt-server");
        let meta = meta_rx
            .recv()
            .map_err(|_| err!("runtime server died during load"))??;
        Ok(RtServer { tx, join: Some(join), meta })
    }

    pub fn handle(&self) -> RtHandle {
        RtHandle { tx: self.tx.clone() }
    }
}

impl Drop for RtServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Req::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn serve(dir: PathBuf, rx: Receiver<Req>, meta_tx: Sender<Result<Meta>>) {
    let rt = match Runtime::load(&dir) {
        Ok(rt) => {
            let _ = meta_tx.send(Ok(rt.meta.clone()));
            rt
        }
        Err(e) => {
            let _ = meta_tx.send(Err(e));
            return;
        }
    };
    while let Ok(req) = rx.recv() {
        match req {
            Req::TrainStep { params, tokens, pallas, reply } => {
                let _ = reply.send(rt.train_step(&params, &tokens, pallas));
            }
            Req::GradStep { params, tokens, reply } => {
                let _ = reply.send(rt.grad_step(&params, &tokens));
            }
            Req::AllReduceSum { x, y, reply } => {
                let _ = reply.send(rt.allreduce_sum(&x, &y));
            }
            Req::ApplyGrads { params, grads, scale, reply } => {
                let _ = reply.send(rt.apply_grads(&params, &grads, scale));
            }
            Req::InitParams { reply } => {
                let _ = reply.send(rt.init_params());
            }
            Req::Shutdown => break,
        }
    }
}
