//! Synthetic token streams for the live training jobs: a noisy
//! deterministic "language" (affine next-token rule + noise) that a small
//! transformer can actually learn, so e2e loss curves show real progress
//! instead of hovering at log(vocab).

use crate::util::rng::Pcg;

/// Deterministic noisy-affine token source.
pub struct TokenStream {
    rng: Pcg,
    vocab: usize,
    state: u32,
}

impl TokenStream {
    pub fn new(seed: u64, vocab: usize) -> TokenStream {
        assert!(vocab >= 4);
        TokenStream { rng: Pcg::new(seed, 0xda7a), vocab, state: (seed % vocab as u64) as u32 }
    }

    /// Next token: x ← 3x + 7 (mod vocab), with 10% uniform noise.
    pub fn next_token(&mut self) -> i32 {
        if self.rng.chance(0.10) {
            self.state = self.rng.next_below(self.vocab as u64) as u32;
        } else {
            self.state = ((self.state as u64 * 3 + 7) % self.vocab as u64) as u32;
        }
        self.state as i32
    }

    /// A (batch × len) token matrix, flattened row-major.
    pub fn batch(&mut self, batch: usize, len: usize) -> Vec<i32> {
        (0..batch * len).map(|_| self.next_token()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab() {
        let mut s = TokenStream::new(1, 256);
        for _ in 0..1000 {
            let t = s.next_token();
            assert!((0..256).contains(&t));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = TokenStream::new(5, 64).batch(4, 16);
        let b = TokenStream::new(5, 64).batch(4, 16);
        let c = TokenStream::new(6, 64).batch(4, 16);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn mostly_predictable() {
        // ~90% of transitions follow the affine rule — the learnable signal.
        let mut s = TokenStream::new(2, 128);
        let toks = s.batch(1, 5000);
        let mut predictable = 0;
        for w in toks.windows(2) {
            if (w[0] as u64 * 3 + 7) % 128 == w[1] as u64 {
                predictable += 1;
            }
        }
        let frac = predictable as f64 / (toks.len() - 1) as f64;
        assert!((0.8..0.99).contains(&frac), "{frac}");
    }

    #[test]
    fn batch_shape() {
        let mut s = TokenStream::new(3, 32);
        assert_eq!(s.batch(8, 65).len(), 8 * 65);
    }
}
