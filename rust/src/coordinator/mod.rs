//! Online multi-job training coordinator — the live counterpart of the
//! simulator. Real DDL jobs (AOT-compiled JAX/Pallas train steps executed
//! through [`crate::runtime`]) are placed on the modelled cluster with
//! LWF-κ and their gradient all-reduce phases pass through a *live*
//! AdaDUAL admission gate: a job may only start its reduction when the
//! policy admits it against the transfers currently in flight, exactly as
//! Algorithm 3 does in simulation.
//!
//! Network transfers are paced by the Eq (5) contention model (the testbed
//! has no 10 GbE fabric to contend on — DESIGN.md §Substitutions): the
//! transfer duration `a + k·b·M + (k−1)·η·M` is slept, scaled by
//! `time_scale`, while the arithmetic of the reduction (the `allreduce_sum`
//! artifact) runs for real. Compute (grad steps) is always real.

pub mod data;
mod gate;
mod rtserver;

pub use gate::{GateStats, NetGate};
pub use rtserver::{RtHandle, RtServer};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::err;
use crate::util::error::Result;

use crate::cluster::{ClusterSpec, ClusterState};
use crate::model::CommModel;
use crate::placement::{LwfPlacer, Placer};
use crate::trace::JobSpec;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub cluster: ClusterSpec,
    pub comm: CommModel,
    /// LWF-κ consolidation threshold.
    pub kappa: usize,
    /// Scale factor for slept network time (1.0 = real-time Eq 5 pacing;
    /// 0.0 = no pacing, admission logic still exercised).
    pub time_scale: f64,
    /// Use the Pallas train-step artifact (vs the pure-jnp reference).
    pub use_pallas: bool,
    /// Admission policy name: "ada", "srsf1", "srsf2", "srsf3".
    pub policy: String,
}

impl CoordinatorConfig {
    pub fn default_ada(cluster: ClusterSpec) -> CoordinatorConfig {
        CoordinatorConfig {
            cluster,
            comm: CommModel::paper_10gbe(),
            kappa: 1,
            time_scale: 1.0,
            use_pallas: true,
            policy: "ada".into(),
        }
    }
}

/// One training job request for the live coordinator.
#[derive(Clone, Debug)]
pub struct JobRequest {
    pub id: usize,
    /// Data-parallel worker count (= GPUs requested from placement).
    pub n_workers: usize,
    /// Optimisation steps to run.
    pub steps: usize,
    /// Data-stream seed.
    pub seed: u64,
}

/// Outcome of one job.
#[derive(Clone, Debug)]
pub struct JobReport {
    pub id: usize,
    pub losses: Vec<f32>,
    pub jct: f64,
    pub gpus: Vec<usize>,
    pub multi_server: bool,
    pub comm_rounds: usize,
    pub contended_rounds: usize,
}

/// Run `jobs` concurrently through placement + the admission gate,
/// executing real train/grad steps via the runtime server. Returns
/// per-job reports (indexed like `jobs`).
pub fn run_jobs(
    cfg: &CoordinatorConfig,
    server: &RtServer,
    jobs: &[JobRequest],
) -> Result<Vec<JobReport>> {
    // ---- placement (leader, sequential) -----------------------------------
    let mut cluster = ClusterState::new(cfg.cluster);
    let mut placer = LwfPlacer::new(cfg.kappa);
    let mut placements: Vec<(Vec<usize>, bool)> = Vec::with_capacity(jobs.len());
    for job in jobs {
        // Synthesize a JobSpec for the placer: memory/bookkeeping use the
        // smallest zoo entry scaled — the live jobs are all the same small
        // transformer, so placement differentiates on load only.
        let spec = JobSpec {
            id: job.id,
            arrival: 0.0,
            model: crate::model::DnnModel::ResNet50,
            n_gpus: job.n_workers,
            iterations: job.steps as u64,
        };
        let gpus = placer
            .place(&spec, &cluster)
            .ok_or_else(|| err!("placement failed for job {}", job.id))?;
        let load = spec.compute_total(cfg.cluster.gpu_peak_gflops) * gpus.len() as f64;
        cluster.allocate(&gpus, spec.mem_bytes(), load);
        let multi = cfg.cluster.servers_of(&gpus).len() > 1;
        placements.push((gpus, multi));
    }

    // ---- execution (one thread per job) ------------------------------------
    let gate = Arc::new(NetGate::new(
        cfg.cluster.n_servers,
        cfg.comm,
        &cfg.policy,
        cfg.time_scale,
    )?);
    let msg_bytes = server.meta.n_params as f64 * 4.0;
    let started = Instant::now();
    let next_seq = Arc::new(AtomicUsize::new(0));

    let reports: Vec<Result<JobReport>> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (job, (gpus, multi)) in jobs.iter().zip(&placements) {
            let rt = server.handle();
            let meta = server.meta.clone();
            let gate = Arc::clone(&gate);
            let servers = cfg.cluster.servers_of(gpus);
            let gpus = gpus.clone();
            let multi = *multi;
            let job = job.clone();
            let cfg = cfg.clone();
            let next_seq = Arc::clone(&next_seq);
            handles.push(scope.spawn(move || {
                run_one_job(&cfg, &rt, &meta, &gate, &job, gpus, servers, multi, msg_bytes, &next_seq)
            }));
        }
        handles.into_iter().map(|h| h.join().expect("job thread panicked")).collect()
    });
    let mut out = Vec::with_capacity(jobs.len());
    for r in reports {
        out.push(r?);
    }
    let _ = started;
    Ok(out)
}

#[allow(clippy::too_many_arguments)]
fn run_one_job(
    cfg: &CoordinatorConfig,
    rt: &RtHandle,
    meta: &crate::runtime::Meta,
    gate: &NetGate,
    job: &JobRequest,
    gpus: Vec<usize>,
    servers: Vec<usize>,
    multi_server: bool,
    msg_bytes: f64,
    next_seq: &AtomicUsize,
) -> Result<JobReport> {
    let t0 = Instant::now();
    let mut params = rt.init_params()?;
    let (b, t) = meta.tokens_shape;
    let mut stream = data::TokenStream::new(job.seed, meta.vocab);
    let mut losses = Vec::with_capacity(job.steps);
    let mut comm_rounds = 0usize;
    let mut contended_rounds = 0usize;
    let lr = meta.lr as f32;

    for _step in 0..job.steps {
        if job.n_workers <= 1 || !multi_server {
            // Single worker (or single-server job): fused train step. For
            // multi-worker single-server jobs the all-reduce is intra-node
            // (free in the paper's model) so the fused step is equivalent.
            let tokens = stream.batch(b, t);
            let (p, loss) = rt.train_step(params, tokens, cfg.use_pallas)?;
            params = p;
            losses.push(loss);
        } else {
            // Data-parallel: per-worker gradients, then a gated all-reduce.
            let mut grads: Option<Vec<f32>> = None;
            let mut loss_acc = 0.0f32;
            for _w in 0..job.n_workers {
                let tokens = stream.batch(b, t);
                let (g, loss) = rt.grad_step(params.clone(), tokens)?;
                loss_acc += loss;
                grads = Some(match grads {
                    None => g,
                    Some(acc) => rt.allreduce_sum(acc, g)?, // local (intra-node) partial
                });
            }
            // Inter-node phase: acquire admission, pace by Eq (5), reduce.
            let seq = next_seq.fetch_add(1, Ordering::Relaxed);
            let token = gate.acquire(seq, job.id, &servers, msg_bytes);
            if token.contended {
                contended_rounds += 1;
            }
            comm_rounds += 1;
            let summed = grads.expect("at least one worker");
            params = rt.apply_grads(params, summed, lr / job.n_workers as f32)?;
            gate.release(token);
            losses.push(loss_acc / job.n_workers as f32);
        }
    }
    Ok(JobReport {
        id: job.id,
        losses,
        jct: t0.elapsed().as_secs_f64(),
        gpus,
        multi_server,
        comm_rounds,
        contended_rounds,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_builds() {
        let cfg = CoordinatorConfig::default_ada(ClusterSpec::tiny(2, 2));
        assert_eq!(cfg.kappa, 1);
        assert_eq!(cfg.policy, "ada");
    }
}
