//! The live admission gate: the same `CommPolicy` decisions the simulator
//! makes, applied to real in-flight gradient reductions, with Eq (5)
//! pacing of the transfer duration.

use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::model::CommModel;
use crate::net::LinkLists;
use crate::scenario::registry;
use crate::sched::{Admission, CommPolicy, NetView};
use crate::util::error::Result;

/// An admitted transfer: hold it for the duration of the reduction, then
/// `release` it.
pub struct GateToken {
    pub seq: usize,
    pub contended: bool,
    servers: Vec<usize>,
}

struct Flight {
    seq: usize,
    msg_bytes: f64,
    started: Instant,
    k_at_admit: usize,
}

struct GateState {
    /// Active flight seqs per fabric link, in the same flat [`LinkLists`]
    /// slab the simulator's hot path uses. The live testbed is a single
    /// non-blocking switch (`net::TopologySpec::Flat`), where link id ==
    /// server id — so the gate tracks one NIC link per server, exactly
    /// like the simulator's flat fabric.
    per_link: LinkLists,
    flights: Vec<Flight>,
    admitted_total: usize,
    contended_total: usize,
    max_k: usize,
}

/// Cumulative gate statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct GateStats {
    pub admitted: usize,
    pub contended: usize,
    pub max_contention: usize,
}

/// Contention-aware network admission gate shared by all job threads.
pub struct NetGate {
    state: Mutex<GateState>,
    cv: Condvar,
    policy: Box<dyn CommPolicy + Send + Sync>,
    comm: CommModel,
    time_scale: f64,
}

impl NetGate {
    pub fn new(n_servers: usize, comm: CommModel, policy: &str, time_scale: f64) -> Result<NetGate> {
        // Same registry as the simulator/scenario API: the live gate and
        // the simulated admission logic can never drift apart on naming.
        let policy = registry::make_policy(policy, comm)?;
        Ok(NetGate {
            state: Mutex::new(GateState {
                per_link: LinkLists::new(n_servers),
                flights: Vec::new(),
                admitted_total: 0,
                contended_total: 0,
                max_k: 0,
            }),
            cv: Condvar::new(),
            policy,
            comm,
            time_scale,
        })
    }

    /// Remaining-bytes estimate for a flight (drains at the rate fixed at
    /// admission; a conservative approximation of the simulator's exact
    /// repricing, documented in DESIGN.md).
    fn remaining(&self, f: &Flight) -> f64 {
        let scale = if self.time_scale > 0.0 { self.time_scale } else { 1.0 };
        let elapsed = f.started.elapsed().as_secs_f64() / scale;
        (f.msg_bytes - elapsed * self.comm.rate(f.k_at_admit)).max(0.0)
    }

    /// Block until the policy admits a transfer of `msg_bytes` over
    /// `servers`, then register it and sleep the Eq (5) transfer time.
    pub fn acquire(&self, seq: usize, _job: usize, servers: &[usize], msg_bytes: f64) -> GateToken {
        let mut st = self.state.lock().unwrap();
        loop {
            // Lazy view over the live per-link lists: a flight's
            // remaining bytes are estimated only when the policy inspects
            // a link carrying it (the previous full per-loop snapshot
            // materialized every flight on every link per wakeup).
            let admit = {
                let remaining = |seq: usize| {
                    let f = st.flights.iter().find(|f| f.seq == seq).unwrap();
                    self.remaining(f)
                };
                let net = NetView::new(&st.per_link, &remaining);
                self.policy.admit(msg_bytes, servers, &net)
            };
            if admit == Admission::Start {
                let k = servers
                    .iter()
                    .map(|&s| st.per_link.len(s))
                    .max()
                    .unwrap_or(0)
                    + 1;
                st.flights.push(Flight {
                    seq,
                    msg_bytes,
                    started: Instant::now(),
                    k_at_admit: k,
                });
                for &s in servers {
                    st.per_link.push(s, seq);
                }
                st.admitted_total += 1;
                if k > 1 {
                    st.contended_total += 1;
                }
                st.max_k = st.max_k.max(k);
                let contended = k > 1;
                drop(st);
                // Pace the transfer per Eq (5) at the admission-time k.
                if self.time_scale > 0.0 {
                    let dur = self.comm.time_contended(msg_bytes, k) * self.time_scale;
                    std::thread::sleep(Duration::from_secs_f64(dur));
                }
                return GateToken { seq, contended, servers: servers.to_vec() };
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Unregister a finished transfer and wake waiters.
    pub fn release(&self, token: GateToken) {
        let mut st = self.state.lock().unwrap();
        for &s in &token.servers {
            // Find-then-swap-remove replaces the old `retain` scan; a
            // link carries a handful of flights, so position lookup is
            // the same O(occupancy) but without rewriting the whole row.
            let pos = st.per_link.tasks(s).iter().position(|&x| x == token.seq);
            if let Some(pos) = pos {
                st.per_link.swap_remove(s, pos);
            }
        }
        st.flights.retain(|f| f.seq != token.seq);
        drop(st);
        self.cv.notify_all();
    }

    pub fn stats(&self) -> GateStats {
        let st = self.state.lock().unwrap();
        GateStats {
            admitted: st.admitted_total,
            contended: st.contended_total,
            max_contention: st.max_k,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn gate(policy: &str) -> Arc<NetGate> {
        Arc::new(NetGate::new(2, CommModel::paper_10gbe(), policy, 0.0).unwrap())
    }

    #[test]
    fn sequential_acquire_release() {
        let g = gate("ada");
        let t1 = g.acquire(1, 0, &[0, 1], 1e6);
        assert!(!t1.contended);
        g.release(t1);
        let t2 = g.acquire(2, 1, &[0, 1], 1e6);
        assert!(!t2.contended);
        g.release(t2);
        let s = g.stats();
        assert_eq!(s.admitted, 2);
        assert_eq!(s.contended, 0);
    }

    #[test]
    fn srsf1_serialises_overlap() {
        let g = gate("srsf1");
        let t1 = g.acquire(1, 0, &[0], 1e8);
        // Second acquire on the same server must block until release.
        let g2 = Arc::clone(&g);
        let handle = std::thread::spawn(move || {
            let t = g2.acquire(2, 1, &[0], 1e8);
            let contended = t.contended;
            g2.release(t);
            contended
        });
        std::thread::sleep(Duration::from_millis(30));
        g.release(t1);
        let contended = handle.join().unwrap();
        assert!(!contended, "SRSF(1) admission must wait for an idle link");
        assert_eq!(g.stats().max_contention, 1);
    }

    #[test]
    fn ada_admits_small_against_large() {
        let g = gate("ada");
        let big = g.acquire(1, 0, &[0], 1e9);
        // A much smaller transfer passes the ratio test immediately.
        let small = g.acquire(2, 1, &[0], 1e6);
        assert!(small.contended);
        g.release(small);
        g.release(big);
        assert_eq!(g.stats().max_contention, 2);
    }

    #[test]
    fn ada_blocks_similar_sizes() {
        let g = gate("ada");
        let first = g.acquire(1, 0, &[0], 1e8);
        let g2 = Arc::clone(&g);
        let handle = std::thread::spawn(move || {
            let t = g2.acquire(2, 1, &[0], 1e8); // ratio 1.0 > threshold
            g2.release(t);
        });
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(g.stats().admitted, 1, "equal-size overlap must wait");
        g.release(first);
        handle.join().unwrap();
        assert_eq!(g.stats().admitted, 2);
    }

    #[test]
    fn unknown_policy_rejected() {
        assert!(NetGate::new(1, CommModel::paper_10gbe(), "nope", 0.0).is_err());
    }
}
