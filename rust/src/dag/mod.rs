//! The DAG formulation of a DDL job (Fig 3): per iteration, one feed-forward
//! and one backpropagation task per worker plus one All-Reduce task with a
//! synchronisation barrier; the All-Reduce of iteration i precedes the
//! feed-forwards of iteration i+1. A virtual global entry/exit stitches
//! multiple jobs into one global DAG.
//!
//! The event-driven simulator (sim/) walks an equivalent per-job state
//! machine rather than materialising R_k child DAGs; this module is the
//! explicit graph used for structural tests, critical-path lower bounds and
//! the coordinator's task bookkeeping.

use crate::model::CommModel;
use crate::trace::JobSpec;

/// Task kinds of the child DAG.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// Virtual source/sink (zero cost).
    Virtual,
    /// Feed-forward on one worker.
    Forward { worker: usize },
    /// Backpropagation on one worker.
    Backward { worker: usize },
    /// Gradient All-Reduce (one per iteration, spans all workers).
    AllReduce,
}

/// One node of the job DAG.
#[derive(Clone, Debug)]
pub struct TaskNode {
    pub kind: TaskKind,
    pub iteration: u64,
    /// Contention-free duration (seconds).
    pub cost: f64,
    /// Indices of successor tasks.
    pub succ: Vec<usize>,
    /// Number of predecessors (for topological/readiness accounting).
    pub n_pred: usize,
}

/// The DAG of one job, unrolled for `iterations` (use a small count for
/// structural tests; the simulator never materialises this).
#[derive(Clone, Debug)]
pub struct JobDag {
    pub job_id: usize,
    pub tasks: Vec<TaskNode>,
    pub entry: usize,
    pub exit: usize,
}

impl JobDag {
    /// Build the DAG per Fig 3(a): entry -> F_w -> B_w -> AllReduce ->
    /// (next iteration F_w ...) -> exit. `multi_server` decides whether the
    /// All-Reduce carries a real cost or is free (single-server jobs).
    pub fn build(
        job: &JobSpec,
        iterations: u64,
        peak_gflops: f64,
        multi_server: bool,
        cm: &CommModel,
    ) -> JobDag {
        let spec = job.model.spec();
        let perf = crate::model::PerfModel::for_model(job.model);
        let t_f = perf.t_fwd(spec.batch_size, peak_gflops);
        let t_b = perf.t_bwd(spec.batch_size, peak_gflops);
        let t_c = if multi_server { cm.time_free(spec.model_bytes) } else { 0.0 };
        let w = job.n_gpus;

        let mut tasks: Vec<TaskNode> = Vec::with_capacity(2 + iterations as usize * (2 * w + 1));
        let entry = 0;
        tasks.push(TaskNode { kind: TaskKind::Virtual, iteration: 0, cost: 0.0, succ: vec![], n_pred: 0 });

        let mut prev_barrier = entry; // entry, then each iteration's AllReduce
        for it in 0..iterations {
            let fwd_base = tasks.len();
            for worker in 0..w {
                tasks.push(TaskNode {
                    kind: TaskKind::Forward { worker },
                    iteration: it,
                    cost: t_f,
                    succ: vec![],
                    n_pred: 0,
                });
            }
            let bwd_base = tasks.len();
            for worker in 0..w {
                tasks.push(TaskNode {
                    kind: TaskKind::Backward { worker },
                    iteration: it,
                    cost: t_b,
                    succ: vec![],
                    n_pred: 0,
                });
            }
            let ar = tasks.len();
            tasks.push(TaskNode { kind: TaskKind::AllReduce, iteration: it, cost: t_c, succ: vec![], n_pred: 0 });
            // edges: barrier -> each F; F_w -> B_w; each B -> AllReduce
            for worker in 0..w {
                link(&mut tasks, prev_barrier, fwd_base + worker);
                link(&mut tasks, fwd_base + worker, bwd_base + worker);
                link(&mut tasks, bwd_base + worker, ar);
            }
            prev_barrier = ar;
        }
        let exit = tasks.len();
        tasks.push(TaskNode { kind: TaskKind::Virtual, iteration: iterations, cost: 0.0, succ: vec![], n_pred: 0 });
        link(&mut tasks, prev_barrier, exit);
        JobDag { job_id: job.id, tasks, entry, exit }
    }

    /// Longest path through the DAG by task cost — the contention-free
    /// lower bound on the job's runtime (used as a simulator invariant).
    pub fn critical_path(&self) -> f64 {
        // Tasks are pushed in topological order by construction.
        let mut dist = vec![0.0f64; self.tasks.len()];
        for (i, t) in self.tasks.iter().enumerate() {
            let reach = dist[i] + t.cost;
            for &s in &t.succ {
                if reach > dist[s] {
                    dist[s] = reach;
                }
            }
        }
        dist[self.exit]
    }

    /// Verify DAG structural invariants; returns an error description.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.tasks.len();
        let mut indeg = vec![0usize; n];
        for t in &self.tasks {
            for &s in &t.succ {
                if s >= n {
                    return Err(format!("edge to out-of-range task {s}"));
                }
                indeg[s] += 1;
            }
        }
        for (i, t) in self.tasks.iter().enumerate() {
            if indeg[i] != t.n_pred {
                return Err(format!("task {i} n_pred {} != indegree {}", t.n_pred, indeg[i]));
            }
        }
        if indeg[self.entry] != 0 {
            return Err("entry has predecessors".into());
        }
        if !self.tasks[self.exit].succ.is_empty() {
            return Err("exit has successors".into());
        }
        // Kahn's algorithm: all tasks reachable & acyclic.
        let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut seen = 0;
        while let Some(i) = ready.pop() {
            seen += 1;
            for &s in &self.tasks[i].succ {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    ready.push(s);
                }
            }
        }
        if seen != n {
            return Err(format!("cycle detected: visited {seen} of {n}"));
        }
        Ok(())
    }

    /// Number of non-virtual tasks.
    pub fn n_real_tasks(&self) -> usize {
        self.tasks.iter().filter(|t| t.kind != TaskKind::Virtual).count()
    }
}

fn link(tasks: &mut [TaskNode], from: usize, to: usize) {
    tasks[from].succ.push(to);
    tasks[to].n_pred += 1;
}

/// Analytic critical path without materialising the DAG — must agree with
/// `JobDag::critical_path` (cross-checked in tests). Iterations chain
/// serially: I · (t_f + t_b + t_c).
pub fn critical_path_analytic(
    job: &JobSpec,
    peak_gflops: f64,
    multi_server: bool,
    cm: &CommModel,
) -> f64 {
    let t_c = if multi_server { cm.time_free(job.message_bytes()) } else { 0.0 };
    (job.t_iter(peak_gflops) + t_c) * job.iterations as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::DnnModel;
    use crate::model::V100_PEAK_GFLOPS as P;

    fn job(n_gpus: usize, iters: u64) -> JobSpec {
        JobSpec { id: 3, arrival: 0.0, model: DnnModel::ResNet50, n_gpus, iterations: iters }
    }

    #[test]
    fn shape_matches_fig3() {
        let cm = CommModel::paper_10gbe();
        let dag = JobDag::build(&job(4, 3), 3, P, true, &cm);
        // 2 virtual + 3 iterations × (4 F + 4 B + 1 AR)
        assert_eq!(dag.tasks.len(), 2 + 3 * 9);
        assert_eq!(dag.n_real_tasks(), 27);
        dag.validate().unwrap();
    }

    #[test]
    fn allreduce_is_barrier() {
        let cm = CommModel::paper_10gbe();
        let dag = JobDag::build(&job(4, 2), 2, P, true, &cm);
        for (i, t) in dag.tasks.iter().enumerate() {
            if t.kind == TaskKind::AllReduce {
                assert_eq!(t.n_pred, 4, "AR task {i} must wait for all 4 backward tasks");
            }
        }
    }

    #[test]
    fn critical_path_matches_analytic() {
        let cm = CommModel::paper_10gbe();
        for (gpus, multi) in [(1, false), (4, false), (8, true)] {
            let j = job(gpus, 5);
            let dag = JobDag::build(&j, 5, P, multi, &cm);
            let want = critical_path_analytic(&j, P, multi, &cm);
            let got = dag.critical_path();
            assert!((got - want).abs() < 1e-9, "gpus={gpus} multi={multi}: {got} vs {want}");
        }
    }

    #[test]
    fn single_server_allreduce_free() {
        let cm = CommModel::paper_10gbe();
        let dag = JobDag::build(&job(4, 1), 1, P, false, &cm);
        let ar_cost: f64 = dag
            .tasks
            .iter()
            .filter(|t| t.kind == TaskKind::AllReduce)
            .map(|t| t.cost)
            .sum();
        assert_eq!(ar_cost, 0.0);
    }

    #[test]
    fn validate_catches_corruption() {
        let cm = CommModel::paper_10gbe();
        let mut dag = JobDag::build(&job(2, 1), 1, P, true, &cm);
        dag.tasks[1].n_pred += 1; // corrupt
        assert!(dag.validate().is_err());
    }
}
