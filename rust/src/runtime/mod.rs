//! PJRT runtime: load the AOT-compiled HLO text artifacts produced by
//! `python/compile/aot.py` and execute them from rust. Python never runs
//! here — this is the request-path half of the three-layer architecture.
//!
//! The PJRT/XLA backend needs the `xla` bindings crate, which the offline
//! registry does not carry, so it is gated behind the `pjrt` cargo feature
//! *and* the `ddl_pjrt_vendored` cfg (set via
//! `RUSTFLAGS="--cfg ddl_pjrt_vendored"` once the bindings are vendored —
//! a bare `--all-features` build must stay resolvable for CI). Without
//! both, the [`Runtime`] keeps its full API surface (the coordinator and
//! tests compile unchanged) but reports itself unavailable at load time;
//! integration tests skip when artifacts are absent anyway.
//!
//! Artifacts (see aot.py):
//! * `train_step`      (params f32[P], tokens s32[B,T+1]) -> (params', loss)
//! * `train_step_ref`  same computation with pure-jnp kernels (L1 ablation)
//! * `grad_step`       (params, tokens) -> (grads, loss)
//! * `allreduce_sum`   (x, y) -> x + y — one stage of a reduction tree
//! * `apply_grads`     (params, grads, scale) -> params'
//! * `init_params.bin` raw LE f32 initial parameter vector
//! * `meta.json`       config + shape index (parsed with util::json)

use std::path::PathBuf;

use crate::err;
use crate::util::error::{Context, Error, Result};
use crate::util::json::Json;

/// Parsed `meta.json`.
#[derive(Clone, Debug)]
pub struct Meta {
    pub preset: String,
    pub n_params: usize,
    pub batch: usize,
    /// tokens shape [batch, seq_len + 1]
    pub tokens_shape: (usize, usize),
    pub lr: f64,
    pub vocab: usize,
    pub artifact_names: Vec<String>,
}

impl Meta {
    pub fn parse(text: &str) -> Result<Meta> {
        let v = Json::parse(text).context("meta.json parse")?;
        let shape = v
            .get("tokens_shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| err!("meta.json missing tokens_shape"))?;
        if shape.len() < 2 {
            return Err(err!("bad tokens_shape"));
        }
        let cfg = v.get("config").ok_or_else(|| err!("meta.json missing config"))?;
        let arts = v.get("artifacts").ok_or_else(|| err!("meta.json missing artifacts"))?;
        let artifact_names = match arts {
            Json::Obj(entries) => entries.iter().map(|(k, _)| k.clone()).collect(),
            _ => return Err(err!("artifacts must be an object")),
        };
        Ok(Meta {
            preset: v.req_str("preset").map_err(Error::msg)?.to_string(),
            n_params: v.req_usize("n_params").map_err(Error::msg)?,
            batch: v.req_usize("batch").map_err(Error::msg)?,
            tokens_shape: (
                shape[0].as_usize().ok_or_else(|| err!("bad tokens_shape"))?,
                shape[1].as_usize().ok_or_else(|| err!("bad tokens_shape"))?,
            ),
            lr: v.req_f64("lr").map_err(Error::msg)?,
            vocab: cfg.req_usize("vocab").map_err(Error::msg)?,
            artifact_names,
        })
    }
}

/// Read `dir/meta.json` (shared by both backends).
fn load_meta(dir: &std::path::Path) -> Result<Meta> {
    let meta_text = std::fs::read_to_string(dir.join("meta.json"))
        .with_context(|| format!("reading {}/meta.json — run `make artifacts`", dir.display()))?;
    Meta::parse(&meta_text)
}

#[cfg(all(feature = "pjrt", ddl_pjrt_vendored))]
mod pjrt_backend {
    //! The real PJRT CPU backend. Compiling this module requires the `xla`
    //! bindings crate to be vendored into the workspace.

    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    use super::{load_meta, Meta};
    use crate::err;
    use crate::util::error::Result;

    /// A compiled model runtime: one PJRT CPU client plus the compiled
    /// executables for each artifact.
    pub struct Runtime {
        client: xla::PjRtClient,
        exes: HashMap<String, xla::PjRtLoadedExecutable>,
        pub meta: Meta,
        pub dir: PathBuf,
    }

    impl Runtime {
        /// Load `meta.json` + every listed HLO artifact from `dir` and
        /// compile them on a fresh PJRT CPU client.
        pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
            let dir = dir.as_ref().to_path_buf();
            let meta = load_meta(&dir)?;
            let client = xla::PjRtClient::cpu().map_err(to_err)?;
            let mut exes = HashMap::new();
            for name in &meta.artifact_names {
                let path = dir.join(format!("{name}.hlo.txt"));
                let proto = xla::HloModuleProto::from_text_file(&path).map_err(to_err)?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client.compile(&comp).map_err(to_err)?;
                exes.insert(name.clone(), exe);
            }
            Ok(Runtime { client, exes, meta, dir })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Read `init_params.bin` into an f32 parameter vector.
        pub fn init_params(&self) -> Result<Vec<f32>> {
            let bytes = std::fs::read(self.dir.join("init_params.bin"))?;
            if bytes.len() != self.meta.n_params * 4 {
                return Err(err!(
                    "init_params.bin is {} bytes, expected {}",
                    bytes.len(),
                    self.meta.n_params * 4
                ));
            }
            Ok(bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        }

        fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
            self.exes.get(name).ok_or_else(|| err!("artifact '{name}' not loaded"))
        }

        fn params_literal(&self, params: &[f32]) -> Result<xla::Literal> {
            if params.len() != self.meta.n_params {
                return Err(err!("params length {} != {}", params.len(), self.meta.n_params));
            }
            Ok(xla::Literal::vec1(params))
        }

        fn tokens_literal(&self, tokens: &[i32]) -> Result<xla::Literal> {
            let (b, t) = self.meta.tokens_shape;
            if tokens.len() != b * t {
                return Err(err!("tokens length {} != {}x{}", tokens.len(), b, t));
            }
            xla::Literal::vec1(tokens).reshape(&[b as i64, t as i64]).map_err(to_err)
        }

        fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<xla::Literal> {
            let exe = self.exe(name)?;
            let result = exe.execute::<xla::Literal>(inputs).map_err(to_err)?;
            result[0][0].to_literal_sync().map_err(to_err)
        }

        /// One SGD step: returns (new params, loss). `pallas` picks the
        /// Pallas or the pure-jnp (`train_step_ref`) variant.
        pub fn train_step(
            &self,
            params: &[f32],
            tokens: &[i32],
            pallas: bool,
        ) -> Result<(Vec<f32>, f32)> {
            let name = if pallas { "train_step" } else { "train_step_ref" };
            let out =
                self.run(name, &[self.params_literal(params)?, self.tokens_literal(tokens)?])?;
            let (p, l) = out.to_tuple2().map_err(to_err)?;
            Ok((
                p.to_vec::<f32>().map_err(to_err)?,
                l.get_first_element::<f32>().map_err(to_err)?,
            ))
        }

        /// One data-parallel worker's gradient computation: (grads, loss).
        pub fn grad_step(&self, params: &[f32], tokens: &[i32]) -> Result<(Vec<f32>, f32)> {
            let out = self
                .run("grad_step", &[self.params_literal(params)?, self.tokens_literal(tokens)?])?;
            let (g, l) = out.to_tuple2().map_err(to_err)?;
            Ok((
                g.to_vec::<f32>().map_err(to_err)?,
                l.get_first_element::<f32>().map_err(to_err)?,
            ))
        }

        /// One reduction stage: x + y element-wise over the parameter vector.
        pub fn allreduce_sum(&self, x: &[f32], y: &[f32]) -> Result<Vec<f32>> {
            let out =
                self.run("allreduce_sum", &[self.params_literal(x)?, self.params_literal(y)?])?;
            out.to_tuple1().map_err(to_err)?.to_vec::<f32>().map_err(to_err)
        }

        /// Leader update: params - scale · grads.
        pub fn apply_grads(&self, params: &[f32], grads: &[f32], scale: f32) -> Result<Vec<f32>> {
            let out = self.run(
                "apply_grads",
                &[
                    self.params_literal(params)?,
                    self.params_literal(grads)?,
                    xla::Literal::scalar(scale),
                ],
            )?;
            out.to_tuple1().map_err(to_err)?.to_vec::<f32>().map_err(to_err)
        }
    }

    fn to_err(e: xla::Error) -> crate::util::error::Error {
        err!("{e}")
    }
}

#[cfg(all(feature = "pjrt", ddl_pjrt_vendored))]
pub use pjrt_backend::Runtime;

#[cfg(not(all(feature = "pjrt", ddl_pjrt_vendored)))]
mod stub_backend {
    //! API-compatible stand-in used when the crate is built without the
    //! `pjrt` feature: loading parses `meta.json` (so misconfiguration is
    //! still reported precisely) and then declines to execute.

    use std::path::{Path, PathBuf};

    use super::{load_meta, Meta};
    use crate::err;
    use crate::util::error::{Error, Result};

    /// Stub runtime: same surface as the PJRT-backed one, always errors.
    pub struct Runtime {
        pub meta: Meta,
        pub dir: PathBuf,
    }

    fn unavailable() -> Error {
        err!(
            "PJRT runtime unavailable: this binary was built without the `pjrt` \
             cargo feature (which requires the vendored `xla` bindings crate); \
             the simulator/scenario API is fully functional without it"
        )
    }

    impl Runtime {
        pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
            let dir = dir.as_ref().to_path_buf();
            let _meta = load_meta(&dir)?;
            Err(unavailable())
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn init_params(&self) -> Result<Vec<f32>> {
            Err(unavailable())
        }

        pub fn train_step(
            &self,
            _params: &[f32],
            _tokens: &[i32],
            _pallas: bool,
        ) -> Result<(Vec<f32>, f32)> {
            Err(unavailable())
        }

        pub fn grad_step(&self, _params: &[f32], _tokens: &[i32]) -> Result<(Vec<f32>, f32)> {
            Err(unavailable())
        }

        pub fn allreduce_sum(&self, _x: &[f32], _y: &[f32]) -> Result<Vec<f32>> {
            Err(unavailable())
        }

        pub fn apply_grads(&self, _params: &[f32], _grads: &[f32], _scale: f32) -> Result<Vec<f32>> {
            Err(unavailable())
        }
    }
}

#[cfg(not(all(feature = "pjrt", ddl_pjrt_vendored)))]
pub use stub_backend::Runtime;

/// Default artifacts directory: `$DDL_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("DDL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parses_minimal() {
        let text = r#"{
            "preset": "small", "config": {"vocab": 256},
            "batch": 8, "lr": 0.05, "seed": 0, "n_params": 1000,
            "tokens_shape": [8, 65],
            "artifacts": {"train_step": {"file": "x"}, "grad_step": {"file": "y"}}
        }"#;
        let m = Meta::parse(text).unwrap();
        assert_eq!(m.n_params, 1000);
        assert_eq!(m.tokens_shape, (8, 65));
        assert_eq!(m.vocab, 256);
        assert_eq!(m.artifact_names.len(), 2);
    }

    #[test]
    fn meta_rejects_missing_fields() {
        assert!(Meta::parse("{}").is_err());
        assert!(Meta::parse(r#"{"preset": "x"}"#).is_err());
    }

    #[cfg(not(all(feature = "pjrt", ddl_pjrt_vendored)))]
    #[test]
    fn stub_load_reports_missing_artifacts_or_feature() {
        // Missing meta.json dominates; a present one reports the feature.
        let e = Runtime::load("/definitely/not/a/dir").unwrap_err().to_string();
        assert!(e.contains("meta.json"), "{e}");
    }
}
