//! In-repo substrates replacing crates the offline registry does not carry
//! (`rand`, `serde_json`, `clap`, `criterion`, `proptest`, `anyhow`,
//! `thiserror`) — see DESIGN.md §Substitutions.

pub mod bench;
pub mod cli;
pub mod error;
pub mod heap;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
