//! A small shrinking property-test driver — in-repo substitute for
//! `proptest` (offline registry; DESIGN.md §Substitutions).
//!
//! Usage:
//! ```ignore
//! prop_check(256, |g| {
//!     let n = g.usize(1, 64);
//!     let xs = g.vec_f64(n, 0.0, 1.0);
//!     // ... assert invariant, or return Err(msg) ...
//!     Ok(())
//! });
//! ```
//! On failure the driver re-runs the case with a reported seed so it can be
//! reproduced exactly (`prop_replay`). Inputs are generated, not shrunk
//! structurally; for this codebase's invariants, the failing seed plus the
//! case description has proven sufficient to debug.

use super::rng::Pcg;

/// Value generator handed to each property case.
pub struct Gen {
    rng: Pcg,
    pub case: u64,
    log: Vec<String>,
}

impl Gen {
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        let v = self.rng.range_usize(lo, hi);
        self.log.push(format!("usize({lo},{hi})={v}"));
        v
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        let v = self.rng.range_u64(lo, hi);
        self.log.push(format!("u64({lo},{hi})={v}"));
        v
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.range_f64(lo, hi);
        self.log.push(format!("f64({lo},{hi})={v}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.chance(0.5);
        self.log.push(format!("bool={v}"));
        v
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        let idx = self.rng.range_usize(0, items.len() - 1);
        self.log.push(format!("pick[{idx}/{}]", items.len()));
        &items[idx]
    }

    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.rng.range_f64(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, n: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..n).map(|_| self.rng.range_usize(lo, hi)).collect()
    }

    /// Raw access for custom generators.
    pub fn rng(&mut self) -> &mut Pcg {
        &mut self.rng
    }
}

/// Run `cases` random cases of `prop`. Panics with the failing seed and the
/// generator log on the first failure.
pub fn prop_check<F>(cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    prop_check_seeded(0xdd15eed, cases, prop)
}

/// Like [`prop_check`] with an explicit base seed (use to replay failures).
pub fn prop_check_seeded<F>(base_seed: u64, cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9e3779b97f4a7c15));
        let mut g = Gen { rng: Pcg::seed(seed), case, log: Vec::new() };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed at case {case} (replay: prop_replay({base_seed}, {case}, ...))\n  \
                 error: {msg}\n  inputs: {}",
                g.log.join(", ")
            );
        }
    }
}

/// Re-run a single failing case found by [`prop_check_seeded`].
pub fn prop_replay<F>(base_seed: u64, case: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let seed = base_seed.wrapping_add(case.wrapping_mul(0x9e3779b97f4a7c15));
    let mut g = Gen { rng: Pcg::seed(seed), case, log: Vec::new() };
    prop(&mut g).expect("replayed case should reproduce the failure");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        prop_check(64, |g| {
            let x = g.f64(0.0, 10.0);
            if x >= 0.0 { Ok(()) } else { Err("negative".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_false_property() {
        prop_check(64, |g| {
            let x = g.usize(0, 100);
            if x < 95 { Ok(()) } else { Err(format!("x={x}")) }
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let mut first = Vec::new();
        prop_check_seeded(7, 10, |g| {
            first.push(g.u64(0, 1000));
            Ok(())
        });
        let mut second = Vec::new();
        prop_check_seeded(7, 10, |g| {
            second.push(g.u64(0, 1000));
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn gen_ranges_respected() {
        prop_check(128, |g| {
            let n = g.usize(1, 16);
            let v = g.vec_f64(n, -2.0, 3.0);
            if v.len() != n {
                return Err("len".into());
            }
            if v.iter().any(|x| !(-2.0..3.0).contains(x)) {
                return Err("range".into());
            }
            Ok(())
        });
    }
}
