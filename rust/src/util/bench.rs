//! Tiny benchmarking harness — in-repo substitute for `criterion` (offline
//! registry; DESIGN.md §Substitutions). All `benches/*.rs` use
//! `harness = false` and drive this directly, because the paper benches are
//! *result-regeneration* harnesses (tables/series) first and timers second.

use std::time::Instant;

use crate::util::json::Json;

/// Timing of one benchmark: wall-clock stats over `iters` runs.
#[derive(Clone, Debug)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Timing {
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>10.3} ms/iter (min {:.3}, max {:.3}, n={})",
            self.name,
            self.mean_s * 1e3,
            self.min_s * 1e3,
            self.max_s * 1e3,
            self.iters
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Timing {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean_s = samples.iter().sum::<f64>() / samples.len() as f64;
    let min_s = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max_s = samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    Timing { name: name.to_string(), iters, mean_s, min_s, max_s }
}

/// Pretty table printer used by the table/figure regeneration benches.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{:>width$}", c, width = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Machine-readable bench emission: one `BENCH_<name>.json` file of
/// `{workload, events, wall_ms, events_per_s}` rows next to the printed
/// table, so the perf trajectory is diffable across PRs (CI uploads the
/// sim-hotpath one as an artifact).
pub struct BenchReport {
    name: String,
    rows: Vec<Json>,
}

impl BenchReport {
    pub fn new(name: &str) -> BenchReport {
        BenchReport { name: name.to_string(), rows: Vec::new() }
    }

    /// Full row: a timed workload.
    pub fn record(&mut self, workload: &str, events: u64, wall_s: f64) {
        self.rows.push(
            Json::obj()
                .set("workload", workload)
                .set("events", events)
                .set("wall_ms", wall_s * 1e3)
                .set("events_per_s", events as f64 / wall_s),
        );
    }

    /// Row without its own timing (e.g. one cell of a sweep timed as a
    /// whole — the caller records the aggregate separately).
    pub fn record_events(&mut self, workload: &str, events: u64) {
        self.rows.push(Json::obj().set("workload", workload).set("events", events));
    }

    /// Full row plus the process peak RSS sampled at record time — for
    /// bounded-memory gates (the streaming scale smoke). The extra key is
    /// ignored by [`BenchReport::delta_vs_committed`], so RSS rows diff
    /// cleanly against pre-RSS baselines.
    pub fn record_with_rss(&mut self, workload: &str, events: u64, wall_s: f64) {
        let mut row = Json::obj()
            .set("workload", workload)
            .set("events", events)
            .set("wall_ms", wall_s * 1e3)
            .set("events_per_s", events as f64 / wall_s);
        if let Some(rss) = peak_rss_bytes() {
            row = row.set("peak_rss_mb", rss as f64 / (1024.0 * 1024.0));
        }
        self.rows.push(row);
    }

    /// Full row plus a per-operation allocation count (from
    /// [`crate::util::heap`] snapshot deltas around the workload). Only
    /// meaningful under `--features dhat-heap` — callers pass the
    /// measured delta and `ops`; without the feature the delta is zero
    /// and the key is omitted so rows stay identical to default builds.
    /// Like `peak_rss_mb`, the extra key is ignored by
    /// [`BenchReport::delta_vs_committed`].
    pub fn record_with_allocs(
        &mut self,
        workload: &str,
        events: u64,
        wall_s: f64,
        allocs: u64,
        ops: u64,
    ) {
        let mut row = Json::obj()
            .set("workload", workload)
            .set("events", events)
            .set("wall_ms", wall_s * 1e3)
            .set("events_per_s", events as f64 / wall_s);
        if crate::util::heap::ENABLED && ops > 0 {
            row = row.set("allocs_per_op", allocs as f64 / ops as f64);
        }
        self.rows.push(row);
    }

    /// Write `results/BENCH_<name>.json` (creating the dir — the same
    /// convention as `write_csv`); returns the path written.
    pub fn write(&self) -> std::io::Result<String> {
        std::fs::create_dir_all("results")?;
        let path = format!("results/BENCH_{}.json", self.name);
        std::fs::write(&path, Json::Arr(self.rows.clone()).to_string_pretty())?;
        Ok(path)
    }

    /// Render an events/s delta of this run against the committed
    /// baseline `results/BENCH_<name>.json`, matching rows by workload
    /// label. Purely informational and deliberately non-fatal: a missing
    /// or unparseable baseline, or a label with no counterpart, just
    /// says so — CI prints this into the workflow log so throughput
    /// regressions are visible in PR checks without flaking the build.
    /// Call *before* [`BenchReport::write`] (which overwrites the file).
    pub fn delta_vs_committed(&self) -> String {
        let path = format!("results/BENCH_{}.json", self.name);
        let baseline = match std::fs::read_to_string(&path) {
            Ok(text) => text,
            Err(_) => return format!("no committed baseline at {path} — skipping delta\n"),
        };
        let rows = match Json::parse(&baseline) {
            Ok(v) => match v.as_arr() {
                Some(rows) => rows.to_vec(),
                None => return format!("baseline {path} is not a row array — skipping delta\n"),
            },
            Err(e) => return format!("baseline {path} unparseable ({e:?}) — skipping delta\n"),
        };
        let mut out = format!("== events/s delta vs committed {path} ==\n");
        for row in &self.rows {
            let Ok(label) = row.req_str("workload") else { continue };
            let Some(new) = row.get("events_per_s").and_then(Json::as_f64) else {
                continue;
            };
            let old = rows.iter().find_map(|r| {
                (r.req_str("workload") == Ok(label))
                    .then(|| r.get("events_per_s").and_then(Json::as_f64))
                    .flatten()
            });
            match old {
                Some(old) if old > 0.0 => {
                    let pct = (new / old - 1.0) * 100.0;
                    out.push_str(&format!(
                        "{label:<44} {:>10.2} -> {:>10.2} Mev/s  ({pct:+.1}%)\n",
                        old / 1e6,
                        new / 1e6
                    ));
                }
                _ => out.push_str(&format!("{label:<44} no baseline row\n")),
            }
        }
        out
    }
}

/// Peak resident set size of this process in bytes — Linux `VmHWM` from
/// `/proc/self/status`. `None` where procfs is unavailable (non-Linux);
/// callers print "n/a" instead of failing the bench.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

/// Write a CSV series to `results/<name>.csv` (creating the dir) so figures
/// can be re-plotted; returns the path written.
pub fn write_csv(name: &str, header: &[&str], rows: &[Vec<f64>]) -> std::io::Result<String> {
    std::fs::create_dir_all("results")?;
    let path = format!("results/{name}.csv");
    let mut text = header.join(",");
    text.push('\n');
    for row in rows {
        text.push_str(
            &row.iter().map(|x| format!("{x}")).collect::<Vec<_>>().join(","),
        );
        text.push('\n');
    }
    std::fs::write(&path, text)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let t = bench("noop-ish", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(t.iters, 5);
        assert!(t.min_s <= t.mean_s && t.mean_s <= t.max_s);
        assert!(t.mean_s >= 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn delta_without_baseline_is_nonfatal() {
        let mut r = BenchReport::new("definitely_not_committed_baseline");
        r.record("w", 10, 1.0);
        let s = r.delta_vs_committed();
        assert!(s.contains("skipping delta"), "{s}");
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn peak_rss_reads_procfs() {
        let rss = peak_rss_bytes().expect("VmHWM missing from /proc/self/status");
        // Any running test binary has at least a megabyte resident.
        assert!(rss > 1024 * 1024, "implausible peak RSS {rss}");
    }

    #[test]
    fn rss_row_keeps_delta_schema() {
        let mut r = BenchReport::new("unit_test_rss_report");
        r.record_with_rss("w", 1000, 0.5);
        let row = &r.rows[0];
        assert_eq!(row.req_f64("events_per_s").unwrap(), 2000.0);
        // On Linux the RSS key rides along; either way the delta keys stay.
        assert_eq!(row.req_str("workload").unwrap(), "w");
    }

    #[test]
    fn allocs_row_keeps_delta_schema() {
        let mut r = BenchReport::new("unit_test_allocs_report");
        r.record_with_allocs("w", 1000, 0.5, 4200, 1000);
        let row = &r.rows[0];
        assert_eq!(row.req_f64("events_per_s").unwrap(), 2000.0);
        assert_eq!(row.req_str("workload").unwrap(), "w");
        // The allocation key appears only in dhat-heap builds.
        assert_eq!(row.get("allocs_per_op").is_some(), crate::util::heap::ENABLED);
    }

    #[test]
    fn bench_report_rows_parse_back() {
        let mut r = BenchReport::new("unit_test_report");
        r.record("w1", 1000, 0.5);
        r.record_events("w2", 42);
        let text = Json::Arr(r.rows.clone()).to_string_pretty();
        let v = Json::parse(&text).unwrap();
        let rows = v.as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].req_str("workload").unwrap(), "w1");
        assert_eq!(rows[0].req_f64("events_per_s").unwrap(), 2000.0);
        assert_eq!(rows[1].req_u64("events").unwrap(), 42);
        assert!(rows[1].get("wall_ms").is_none());
    }
}
