//! Deterministic PRNG (PCG64-DXSM-style) — in-repo substitute for the `rand`
//! crate, which the offline registry does not carry (DESIGN.md §Substitutions).
//!
//! The generator is a 128-bit-state permuted congruential generator. It is
//! deterministic across platforms, which the trace generator and property
//! tests rely on for reproducibility.

/// A 128-bit-state PCG with DXSM output permutation.
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u128,
    inc: u128,
}

/// A serializable [`Pcg`] snapshot: the full 256 bits of generator state
/// split into `u64` halves (no `u128` in serialized surfaces — JSON
/// readers and the hand-rolled writers in this crate handle 64-bit
/// integers only). [`Pcg::save`] / [`Pcg::restore`] round-trip exactly:
/// a restored generator continues the stream bit-for-bit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PcgState {
    pub state_lo: u64,
    pub state_hi: u64,
    pub inc_lo: u64,
    pub inc_hi: u64,
}

const MUL: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg {
    /// Seed with a stream id; (seed, stream) pairs give independent streams.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg { state: 0, inc };
        rng.state = rng.state.wrapping_mul(MUL).wrapping_add(inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(MUL).wrapping_add(inc);
        rng.next_u64();
        rng
    }

    /// Convenience single-stream constructor.
    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
        let mut hi = (self.state >> 64) as u64;
        let lo = (self.state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xda942042e4dd58b5);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Uniform in `[0, bound)` via Lemire's unbiased multiply-shift rejection.
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let l = m as u64;
            if l >= bound || l >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.next_below(hi - lo + 1)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty());
        &items[self.next_below(items.len() as u64) as usize]
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            items.swap(i, j);
        }
    }

    /// Snapshot the generator (see [`PcgState`]).
    pub fn save(&self) -> PcgState {
        PcgState {
            state_lo: self.state as u64,
            state_hi: (self.state >> 64) as u64,
            inc_lo: self.inc as u64,
            inc_hi: (self.inc >> 64) as u64,
        }
    }

    /// Rebuild a generator from a snapshot taken by [`Pcg::save`].
    pub fn restore(snap: &PcgState) -> Pcg {
        Pcg {
            state: (snap.state_lo as u128) | ((snap.state_hi as u128) << 64),
            inc: (snap.inc_lo as u128) | ((snap.inc_hi as u128) << 64),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let a: Vec<u64> = (0..8).map({ let mut r = Pcg::seed(42); move |_| r.next_u64() }).collect();
        let b: Vec<u64> = (0..8).map({ let mut r = Pcg::seed(42); move |_| r.next_u64() }).collect();
        assert_eq!(a, b);
        let c: Vec<u64> = (0..8).map({ let mut r = Pcg::seed(43); move |_| r.next_u64() }).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Pcg::seed(1);
        for bound in [1u64, 2, 3, 7, 100, 1 << 33] {
            for _ in 0..200 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg::seed(2);
        let mut acc = 0.0;
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            acc += x;
        }
        let mean = acc / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn range_inclusive() {
        let mut r = Pcg::seed(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2_000 {
            let x = r.range_u64(5, 8);
            assert!((5..=8).contains(&x));
            seen_lo |= x == 5;
            seen_hi |= x == 8;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::seed(4);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn save_restore_round_trips_mid_stream() {
        let mut r = Pcg::new(99, 7);
        for _ in 0..13 {
            r.next_u64(); // advance off the seed point
        }
        let snap = r.save();
        let ahead: Vec<u64> = (0..32).map(|_| r.next_u64()).collect();
        let mut resumed = Pcg::restore(&snap);
        let replay: Vec<u64> = (0..32).map(|_| resumed.next_u64()).collect();
        assert_eq!(ahead, replay);
        // The snapshot itself round-trips exactly.
        assert_eq!(Pcg::restore(&snap).save(), snap);
    }

    #[test]
    fn uniformity_chi2_rough() {
        // 10 buckets, 10k draws: chi2 should be far below a catastrophic value.
        let mut r = Pcg::seed(5);
        let mut buckets = [0usize; 10];
        for _ in 0..10_000 {
            buckets[r.next_below(10) as usize] += 1;
        }
        let chi2: f64 = buckets
            .iter()
            .map(|&o| {
                let d = o as f64 - 1000.0;
                d * d / 1000.0
            })
            .sum();
        assert!(chi2 < 40.0, "chi2 {chi2}");
    }
}
