//! Minimal message-chain error type — in-repo substitute for `anyhow` and
//! `thiserror` (offline registry; DESIGN.md §Substitutions).
//!
//! Any `std::error::Error` converts into [`Error`] through `?`; `err!` /
//! `bail!` build ad-hoc errors from format strings; [`Context`] mirrors
//! anyhow's `.context()` / `.with_context()` by prefixing the message chain.

use std::fmt;

/// A message-based error. Deliberately does NOT implement
/// `std::error::Error`: that keeps the blanket `From<E: std::error::Error>`
/// impl below coherent with the reflexive `From<Error> for Error`.
pub struct Error {
    msg: String,
}

/// Crate-wide result alias (defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from anything printable.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string() }
    }

    /// Prefix the message chain with higher-level context.
    pub fn wrap<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Attach context to a failing result, anyhow-style.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, msg: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, msg: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(msg))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

/// Build an [`Error`](crate::util::error::Error) from a format string.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`](crate::util::error::Error).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(())
    }

    #[test]
    fn std_errors_convert() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_prefixes() {
        let e = io_fail().context("loading config").unwrap_err();
        assert!(e.to_string().starts_with("loading config: "), "{e}");
        let e = io_fail().with_context(|| format!("attempt {}", 2)).unwrap_err();
        assert!(e.to_string().starts_with("attempt 2: "), "{e}");
    }

    #[test]
    fn macros_format() {
        let e = err!("bad value {} for {}", 7, "kappa");
        assert_eq!(e.to_string(), "bad value 7 for kappa");
        fn f() -> Result<()> {
            bail!("nope: {}", 1);
        }
        assert_eq!(f().unwrap_err().to_string(), "nope: 1");
    }

    #[test]
    fn debug_matches_display() {
        let e = err!("x");
        assert_eq!(format!("{e:?}"), format!("{e}"));
    }
}
