//! Minimal JSON value model, parser and serializer — in-repo substitute for
//! `serde_json` (offline registry; DESIGN.md §Substitutions).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Object key order is preserved, which keeps
//! emitted traces/metrics diffable.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys keep insertion order via a parallel index.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ----- constructors ---------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut entries) = self {
            entries.push((key.to_string(), val.into()));
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    // ----- accessors ------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(entries) => entries.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integer view. Numbers are stored as f64, so only values up to 2^53
    /// are exactly representable; larger ones are rejected rather than
    /// silently rounded (a mangled seed would break run reproducibility).
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64()
            .filter(|x| *x >= 0.0 && x.fract() == 0.0 && *x <= 9_007_199_254_740_992.0)
            .map(|x| x as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Convenience: `get` chained with typed access, for required fields.
    pub fn req_f64(&self, key: &str) -> Result<f64, String> {
        self.get(key).and_then(Json::as_f64).ok_or_else(|| format!("missing number field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, String> {
        self.get(key).and_then(Json::as_usize).ok_or_else(|| format!("missing integer field '{key}'"))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64, String> {
        self.get(key).and_then(Json::as_u64).ok_or_else(|| format!("missing integer field '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> Result<&str, String> {
        self.get(key).and_then(Json::as_str).ok_or_else(|| format!("missing string field '{key}'"))
    }

    // ----- parsing ----------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----- serialization ----------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(0));
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(level) = indent {
                        newline(out, level + 1);
                        item.write(out, Some(level + 1));
                    } else {
                        item.write(out, None);
                    }
                }
                if let Some(level) = indent {
                    newline(out, level);
                }
                out.push(']');
            }
            Json::Obj(entries) => {
                if entries.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in entries.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(level) = indent {
                        newline(out, level + 1);
                    }
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent.map(|l| l + 1));
                }
                if let Some(level) = indent {
                    newline(out, level);
                }
                out.push('}');
            }
        }
    }

    /// Build from a map (sorted keys), handy in tests.
    pub fn from_map(map: BTreeMap<String, Json>) -> Json {
        Json::Obj(map.into_iter().collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn newline(out: &mut String, level: usize) {
    out.push('\n');
    for _ in 0..level {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, x: f64) {
    if !x.is_finite() {
        out.push_str("null"); // JSON has no NaN/inf
    } else if x.fract() == 0.0 && x.abs() < 9.0e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(x: Vec<T>) -> Json {
        Json::Arr(x.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut entries = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(entries));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            entries.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(entries));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.25", "1e-9", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "{text}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": -1.5e3, "e": true}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("d").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::obj().set("z", 1.0).set("a", 2.0).set("m", 3.0);
        assert_eq!(v.to_string(), r#"{"z":1,"a":2,"m":3}"#);
    }

    #[test]
    fn string_escapes() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let parsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(parsed, v);
    }

    #[test]
    fn unicode_escape_parse() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        for text in ["", "{", "[1,", "tru", "{\"a\" 1}", "1 2", "{\"a\":}"] {
            assert!(Json::parse(text).is_err(), "{text}");
        }
    }

    #[test]
    fn pretty_is_parseable() {
        let v = Json::obj()
            .set("xs", vec![1.0, 2.0])
            .set("o", Json::obj().set("k", "v"));
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_dot() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn u64_rejects_values_beyond_f64_exactness() {
        assert_eq!(Json::Num(9_007_199_254_740_992.0).as_u64(), Some(1 << 53));
        assert_eq!(Json::Num(9.1e15).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
    }
}
