//! Flag-style argument parsing — in-repo substitute for `clap` (offline
//! registry; DESIGN.md §Substitutions).
//!
//! Grammar: `prog <subcommand> [--key value]... [--flag]...`
//! Every option is named; values parse on demand with typed getters.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    MissingValue(String),
    BadValue { key: String, value: String, ty: &'static str },
    MissingRequired(String),
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CliError::MissingValue(k) => write!(f, "option --{k} expects a value"),
            CliError::BadValue { key, value, ty } => {
                write!(f, "cannot parse --{key} value '{value}' as {ty}")
            }
            CliError::MissingRequired(k) => write!(f, "missing required option --{k}"),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, CliError> {
        let mut it = raw.into_iter().peekable();
        let mut args = Args {
            subcommand: None,
            opts: BTreeMap::new(),
            flags: Vec::new(),
            positional: Vec::new(),
        };
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                // `--key=value` or `--key value` or bare flag
                if let Some((k, v)) = key.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if let Some(val) = it.next_if(|n| !n.starts_with("--")) {
                    args.opts.insert(key.to_string(), val);
                } else {
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args, CliError> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: name.into(),
                value: v.into(),
                ty: "usize",
            }),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: name.into(),
                value: v.into(),
                ty: "u64",
            }),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: name.into(),
                value: v.into(),
                ty: "f64",
            }),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str, CliError> {
        self.get(name).ok_or_else(|| CliError::MissingRequired(name.into()))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["simulate", "--jobs", "160", "--seed=7", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.usize_or("jobs", 0).unwrap(), 160);
        assert_eq!(a.u64_or("seed", 0).unwrap(), 7);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_and_typed_errors() {
        let a = parse(&["x", "--rate", "abc"]);
        assert_eq!(a.f64_or("missing", 1.5).unwrap(), 1.5);
        assert!(a.f64_or("rate", 0.0).is_err());
    }

    #[test]
    fn no_subcommand_when_first_is_flag() {
        let a = parse(&["--help"]);
        assert_eq!(a.subcommand, None);
        assert!(a.flag("help"));
    }

    #[test]
    fn positional_args_collected() {
        let a = parse(&["run", "file1", "--k", "v", "file2"]);
        assert_eq!(a.positional(), &["file1".to_string(), "file2".to_string()]);
    }

    #[test]
    fn require_errors_when_absent() {
        let a = parse(&["run"]);
        assert!(a.require("out").is_err());
    }
}
