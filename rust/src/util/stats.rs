//! Summary statistics, percentiles, CDFs and least-squares fitting — the
//! numeric toolbox behind the metrics module and the Fig 2 model fit.

/// Mean of a slice; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) with linear interpolation, matching numpy's
/// default "linear" method. `None` on empty input; NaNs sort last
/// (`total_cmp`) instead of poisoning the sort, so a slice with stray
/// NaNs still yields a deterministic answer.
pub fn try_percentile(xs: &[f64], p: f64) -> Option<f64> {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return None;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    Some(if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    })
}

/// p-th percentile (0..=100). Panics on empty input — callers that can
/// see a zero-job workload use [`try_percentile`].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    try_percentile(xs, p).expect("percentile of empty slice")
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Empirical CDF sampled at each data point: returns (x, P(X <= x)) pairs
/// sorted by x — the exact series used for the paper's JCT CDF figures.
pub fn ecdf(xs: &[f64]) -> Vec<(f64, f64)> {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len() as f64;
    v.into_iter()
        .enumerate()
        .map(|(i, x)| (x, (i + 1) as f64 / n))
        .collect()
}

/// Ordinary least squares fit y = a + b x; returns (a, b, r_squared).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need at least two points");
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

/// Fixed-width histogram over [lo, hi) with `bins` buckets; values outside
/// are clamped into the edge buckets. Returns per-bucket counts.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    assert!(bins > 0 && hi > lo);
    let mut counts = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        let idx = ((x - lo) / w).floor();
        let idx = (idx.max(0.0) as usize).min(bins - 1);
        counts[idx] += 1;
    }
    counts
}

/// Summary bundle used throughout metrics reporting.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "summary of empty slice");
        Summary {
            n: xs.len(),
            mean: mean(xs),
            median: median(xs),
            p95: percentile(xs, 95.0),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }

    /// The zero-job summary: `n == 0`, every statistic 0.0. Lets callers
    /// that may legitimately finish no jobs (empty streamed traces) report
    /// cleanly instead of panicking in [`Summary::of`].
    pub fn empty() -> Summary {
        Summary { n: 0, mean: 0.0, median: 0.0, p95: 0.0, min: 0.0, max: 0.0 }
    }
}

/// Streaming quantile estimator — the P² algorithm of Jain & Chlamtac
/// (CACM 1985). Tracks one quantile in O(1) memory: five marker heights
/// whose positions chase the desired rank via parabolic interpolation.
/// Exact for the first five observations (they are buffered verbatim);
/// afterwards the estimate converges to the true quantile for stationary
/// inputs. This is what lets million-job streamed runs report tail
/// latencies without retaining per-job samples.
#[derive(Clone, Debug)]
pub struct P2Quantile {
    /// Target quantile in [0, 1], e.g. 0.95.
    p: f64,
    count: u64,
    /// Marker heights q0..q4 (min, lower mid, target, upper mid, max).
    q: [f64; 5],
    /// Actual marker positions (1-indexed ranks).
    n: [f64; 5],
    /// Desired marker positions.
    np: [f64; 5],
    /// Per-observation increments of the desired positions.
    dn: [f64; 5],
}

impl P2Quantile {
    pub fn new(p: f64) -> P2Quantile {
        assert!((0.0..=1.0).contains(&p), "quantile must be in [0, 1]");
        P2Quantile {
            p,
            count: 0,
            q: [0.0; 5],
            n: [1.0, 2.0, 3.0, 4.0, 5.0],
            np: [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0],
            dn: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn observe(&mut self, x: f64) {
        if self.count < 5 {
            self.q[self.count as usize] = x;
            self.count += 1;
            if self.count == 5 {
                self.q.sort_by(f64::total_cmp);
            }
            return;
        }
        self.count += 1;
        // Locate the cell k with q[k] <= x < q[k+1], extending the extremes.
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x >= self.q[4] {
            self.q[4] = x;
            3
        } else {
            let mut k = 0;
            while k < 3 && x >= self.q[k + 1] {
                k += 1;
            }
            k
        };
        for pos in self.n[k + 1..].iter_mut() {
            *pos += 1.0;
        }
        for (want, step) in self.np.iter_mut().zip(&self.dn) {
            *want += *step;
        }
        // Nudge interior markers toward their desired positions.
        for i in 1..4 {
            let d = self.np[i] - self.n[i];
            if (d >= 1.0 && self.n[i + 1] - self.n[i] > 1.0)
                || (d <= -1.0 && self.n[i - 1] - self.n[i] < -1.0)
            {
                let d = d.signum();
                let qp = self.parabolic(i, d);
                self.q[i] = if self.q[i - 1] < qp && qp < self.q[i + 1] {
                    qp
                } else {
                    self.linear(i, d)
                };
                self.n[i] += d;
            }
        }
    }

    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let (q, n) = (&self.q, &self.n);
        q[i] + d / (n[i + 1] - n[i - 1])
            * ((n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))
    }

    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = if d > 0.0 { i + 1 } else { i - 1 };
        self.q[i] + d * (self.q[j] - self.q[i]) / (self.n[j] - self.n[i])
    }

    /// Current estimate: `None` before any observation, exact while at most
    /// five have been seen, the P² marker height afterwards.
    pub fn value(&self) -> Option<f64> {
        let c = self.count.min(5) as usize;
        if c == 0 {
            None
        } else if c < 5 {
            try_percentile(&self.q[..c], self.p * 100.0)
        } else {
            Some(self.q[2])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feq(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-9, "{a} != {b}");
    }

    #[test]
    fn mean_median() {
        feq(mean(&[1.0, 2.0, 3.0, 4.0]), 2.5);
        feq(median(&[3.0, 1.0, 2.0]), 2.0);
        feq(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentile_interp() {
        let xs = [10.0, 20.0, 30.0, 40.0, 50.0];
        feq(percentile(&xs, 0.0), 10.0);
        feq(percentile(&xs, 100.0), 50.0);
        feq(percentile(&xs, 50.0), 30.0);
        feq(percentile(&xs, 25.0), 20.0);
        feq(percentile(&xs, 95.0), 48.0);
    }

    #[test]
    fn ecdf_monotone_and_complete() {
        let cdf = ecdf(&[3.0, 1.0, 2.0, 2.0]);
        assert_eq!(cdf.len(), 4);
        feq(cdf.last().unwrap().1, 1.0);
        for w in cdf.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn fit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.5 + 0.75 * x).collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        feq(a, 2.5);
        feq(b, 0.75);
        feq(r2, 1.0);
    }

    #[test]
    fn fit_noisy_r2_below_one() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().enumerate()
            .map(|(i, x)| 1.0 + 2.0 * x + if i % 2 == 0 { 5.0 } else { -5.0 })
            .collect();
        let (_, b, r2) = linear_fit(&xs, &ys);
        assert!((b - 2.0).abs() < 0.05);
        assert!(r2 < 1.0 && r2 > 0.9);
    }

    #[test]
    fn histogram_clamps() {
        let h = histogram(&[-1.0, 0.0, 0.5, 0.99, 5.0], 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 3]); // -1 clamps into [0,.5); 5 clamps into [.5,1)
        assert_eq!(h.iter().sum::<usize>(), 5);
    }

    #[test]
    fn try_percentile_handles_empty_and_nan() {
        assert_eq!(try_percentile(&[], 50.0), None);
        // NaNs sort last under total_cmp; the call must not panic and the
        // low percentiles still see the finite values.
        let xs = [2.0, f64::NAN, 1.0];
        feq(try_percentile(&xs, 0.0).unwrap(), 1.0);
        feq(try_percentile(&xs, 50.0).unwrap(), 2.0);
    }

    #[test]
    fn summary_empty_is_zeroed() {
        let s = Summary::empty();
        assert_eq!(s.n, 0);
        feq(s.mean, 0.0);
        feq(s.p95, 0.0);
    }

    #[test]
    fn p2_exact_below_five_samples() {
        let mut est = P2Quantile::new(0.5);
        assert_eq!(est.value(), None);
        est.observe(3.0);
        feq(est.value().unwrap(), 3.0);
        est.observe(1.0);
        feq(est.value().unwrap(), 2.0);
        est.observe(2.0);
        feq(est.value().unwrap(), 2.0);
        assert_eq!(est.count(), 3);
    }

    #[test]
    fn p2_tracks_uniform_median() {
        // Deterministic low-discrepancy stream over (0, 1): golden-ratio
        // rotation. The P² median must land near 0.5.
        let mut est = P2Quantile::new(0.5);
        let mut x = 0.0f64;
        for _ in 0..10_000 {
            x = (x + 0.618_033_988_749_894_9) % 1.0;
            est.observe(x);
        }
        let v = est.value().unwrap();
        assert!((v - 0.5).abs() < 0.02, "p50 estimate {v}");
    }

    #[test]
    fn p2_tail_quantile_close_to_exact() {
        let mut est = P2Quantile::new(0.95);
        let mut all = Vec::new();
        let mut x = 0.0f64;
        for _ in 0..20_000 {
            x = (x + 0.618_033_988_749_894_9) % 1.0;
            // Skewed tail: cube keeps most mass low, stretches the top.
            let y = x * x * x * 100.0;
            est.observe(y);
            all.push(y);
        }
        let exact = percentile(&all, 95.0);
        let got = est.value().unwrap();
        assert!(
            (got - exact).abs() / exact < 0.05,
            "p95 estimate {got} vs exact {exact}"
        );
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.n, 5);
        feq(s.min, 1.0);
        feq(s.max, 100.0);
        feq(s.median, 3.0);
        assert!(s.p95 > 4.0);
    }
}
