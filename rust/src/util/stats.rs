//! Summary statistics, percentiles, CDFs and least-squares fitting — the
//! numeric toolbox behind the metrics module and the Fig 2 model fit.

/// Mean of a slice; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) with linear interpolation, matching numpy's
/// default "linear" method. Panics on empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&p));
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Empirical CDF sampled at each data point: returns (x, P(X <= x)) pairs
/// sorted by x — the exact series used for the paper's JCT CDF figures.
pub fn ecdf(xs: &[f64]) -> Vec<(f64, f64)> {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len() as f64;
    v.into_iter()
        .enumerate()
        .map(|(i, x)| (x, (i + 1) as f64 / n))
        .collect()
}

/// Ordinary least squares fit y = a + b x; returns (a, b, r_squared).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need at least two points");
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

/// Fixed-width histogram over [lo, hi) with `bins` buckets; values outside
/// are clamped into the edge buckets. Returns per-bucket counts.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    assert!(bins > 0 && hi > lo);
    let mut counts = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        let idx = ((x - lo) / w).floor();
        let idx = (idx.max(0.0) as usize).min(bins - 1);
        counts[idx] += 1;
    }
    counts
}

/// Summary bundle used throughout metrics reporting.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "summary of empty slice");
        Summary {
            n: xs.len(),
            mean: mean(xs),
            median: median(xs),
            p95: percentile(xs, 95.0),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feq(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-9, "{a} != {b}");
    }

    #[test]
    fn mean_median() {
        feq(mean(&[1.0, 2.0, 3.0, 4.0]), 2.5);
        feq(median(&[3.0, 1.0, 2.0]), 2.0);
        feq(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentile_interp() {
        let xs = [10.0, 20.0, 30.0, 40.0, 50.0];
        feq(percentile(&xs, 0.0), 10.0);
        feq(percentile(&xs, 100.0), 50.0);
        feq(percentile(&xs, 50.0), 30.0);
        feq(percentile(&xs, 25.0), 20.0);
        feq(percentile(&xs, 95.0), 48.0);
    }

    #[test]
    fn ecdf_monotone_and_complete() {
        let cdf = ecdf(&[3.0, 1.0, 2.0, 2.0]);
        assert_eq!(cdf.len(), 4);
        feq(cdf.last().unwrap().1, 1.0);
        for w in cdf.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn fit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.5 + 0.75 * x).collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        feq(a, 2.5);
        feq(b, 0.75);
        feq(r2, 1.0);
    }

    #[test]
    fn fit_noisy_r2_below_one() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().enumerate()
            .map(|(i, x)| 1.0 + 2.0 * x + if i % 2 == 0 { 5.0 } else { -5.0 })
            .collect();
        let (_, b, r2) = linear_fit(&xs, &ys);
        assert!((b - 2.0).abs() < 0.05);
        assert!(r2 < 1.0 && r2 > 0.9);
    }

    #[test]
    fn histogram_clamps() {
        let h = histogram(&[-1.0, 0.0, 0.5, 0.99, 5.0], 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 3]); // -1 clamps into [0,.5); 5 clamps into [.5,1)
        assert_eq!(h.iter().sum::<usize>(), 5);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.n, 5);
        feq(s.min, 1.0);
        feq(s.max, 100.0);
        feq(s.median, 3.0);
        assert!(s.p95 > 4.0);
    }
}
