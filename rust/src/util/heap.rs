//! Feature-gated heap profiling — in-repo substitute for `dhat` (offline
//! registry; DESIGN.md §Substitutions). With `--features dhat-heap` a
//! counting [`GlobalAlloc`] wraps the system allocator and every
//! allocation/deallocation bumps process-wide atomic counters; benches
//! read [`snapshot`] deltas around a workload to report allocations/op
//! and allocations/event (the §Perf allocation-profile table in
//! docs/EXPERIMENTS.md). Without the feature the counters compile away:
//! [`snapshot`] returns zeros, [`ENABLED`] is `false`, and the default
//! build pays nothing.
//!
//! The counters are *counts and bytes*, not call-site attribution — the
//! real dhat's flamegraphs need a backtrace dependency the registry does
//! not carry. Attribution here is by construction instead: the micro
//! bench suite (`benches/micro/`) saturates one subsystem per workload,
//! so a nonzero allocs/op localizes to that subsystem directly.

/// True iff the crate was built with `--features dhat-heap` (the
/// counting allocator is installed and [`snapshot`] is live).
pub const ENABLED: bool = cfg!(feature = "dhat-heap");

/// Point-in-time allocation counters. All zeros when the `dhat-heap`
/// feature is off.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AllocSnapshot {
    /// Allocations since process start (reallocs count as one).
    pub allocs: u64,
    /// Deallocations since process start.
    pub frees: u64,
    /// Bytes requested by those allocations, cumulatively.
    pub bytes_allocated: u64,
}

impl AllocSnapshot {
    /// Counter deltas since an `earlier` snapshot (saturating, so a
    /// zeroed feature-off snapshot pair stays zero).
    pub fn since(&self, earlier: &AllocSnapshot) -> AllocSnapshot {
        AllocSnapshot {
            allocs: self.allocs.saturating_sub(earlier.allocs),
            frees: self.frees.saturating_sub(earlier.frees),
            bytes_allocated: self.bytes_allocated.saturating_sub(earlier.bytes_allocated),
        }
    }
}

#[cfg(feature = "dhat-heap")]
mod imp {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicU64, Ordering};

    pub static ALLOCS: AtomicU64 = AtomicU64::new(0);
    pub static FREES: AtomicU64 = AtomicU64::new(0);
    pub static BYTES: AtomicU64 = AtomicU64::new(0);

    /// System-allocator wrapper bumping the counters. Relaxed ordering:
    /// the counters are statistics, not synchronization — bench readers
    /// only ever look at quiescent deltas.
    pub struct CountingAlloc;

    // SAFETY: pure delegation to `System`; the counter updates are
    // atomic and allocation-free.
    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
            System.alloc(layout)
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            FREES.fetch_add(1, Ordering::Relaxed);
            System.dealloc(ptr, layout)
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
            System.realloc(ptr, layout, new_size)
        }
    }

    #[global_allocator]
    static GLOBAL: CountingAlloc = CountingAlloc;
}

/// Current process-wide allocation counters (zeros when the `dhat-heap`
/// feature is off). Diff two snapshots with [`AllocSnapshot::since`].
pub fn snapshot() -> AllocSnapshot {
    #[cfg(feature = "dhat-heap")]
    {
        use std::sync::atomic::Ordering;
        AllocSnapshot {
            allocs: imp::ALLOCS.load(Ordering::Relaxed),
            frees: imp::FREES.load(Ordering::Relaxed),
            bytes_allocated: imp::BYTES.load(Ordering::Relaxed),
        }
    }
    #[cfg(not(feature = "dhat-heap"))]
    {
        AllocSnapshot::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_counts_iff_enabled() {
        let before = snapshot();
        let v: Vec<u64> = std::hint::black_box((0..1024).collect());
        std::hint::black_box(&v);
        let after = snapshot();
        let d = after.since(&before);
        if ENABLED {
            assert!(d.allocs >= 1, "counting allocator missed a Vec allocation");
            assert!(d.bytes_allocated >= 1024 * 8, "byte counter undercounted: {d:?}");
        } else {
            assert_eq!(before, AllocSnapshot::default());
            assert_eq!(d, AllocSnapshot::default());
        }
    }

    #[test]
    fn since_saturates() {
        let a = AllocSnapshot { allocs: 5, frees: 5, bytes_allocated: 100 };
        let b = AllocSnapshot { allocs: 3, frees: 9, bytes_allocated: 40 };
        let d = b.since(&a);
        assert_eq!(d.allocs, 0);
        assert_eq!(d.frees, 4);
        assert_eq!(d.bytes_allocated, 0);
    }
}
