//! Deterministic fault injection (docs/EXPERIMENTS.md §Faults).
//!
//! A fault timeline is data, not chance: scenarios either list explicit
//! [`FaultEvent`]s or ask for an MTBF/MTTR-generated schedule, and both
//! compile — via [`FaultsSpec::compile`] — into the same flat, time-sorted
//! [`FaultPlan`] of GPU/link primitives the engine consumes as first-class
//! heap events. The generator draws from [`util::rng::Pcg`] on its own
//! stream, so a (seed, spec) pair is byte-reproducible across runs,
//! platforms and worker counts, exactly like trace generation.
//!
//! Server faults are sugar: a server failing takes down each of its GPUs
//! plus its NIC link (NIC `LinkId` == `ServerId` in every fabric preset;
//! rack uplinks survive a member server's death). Recovery reverses the
//! same expansion.
//!
//! [`HealthView`] is the engine's live health map; placement reaches
//! it indirectly (a down GPU's free memory is held at zero so every
//! placer's `fits` test fails) and admission consults it directly, so no
//! work lands on dead capacity. The checkpoint model is coarse-grained:
//! a preempted job rewinds to its last multiple of `checkpoint_iters`
//! (0 = no checkpointing, restart from scratch) and a restart pays
//! `warmup_s` seconds of dead time on its new GPUs before iterating.
//!
//! Beyond fail-stop, the model covers *gray* failures: a link can degrade
//! to a fraction of its nominal bandwidth ([`FaultKind::LinkDegrade`])
//! and a GPU can slow down ([`FaultKind::GpuSlow`]), each with a health
//! factor in (0, 1] and a paired restore back to 1.0. [`HealthView`]
//! therefore stores per-device f64 factors (1.0 = healthy, 0.0 = down);
//! the binary up/down API is preserved as `factor > 0`. Degradations come
//! from explicit timeline events or from the seeded [`DegradeSpec`]
//! generator (Exp-distributed onset, uniform factor in a configured
//! range, Exp recovery) on its own RNG stream — adding a degradation
//! section never perturbs an existing (seed, spec) failure schedule.

use crate::cluster::ClusterSpec;
use crate::net::LinkId;
use crate::util::error::{Error, Result};
use crate::util::json::Json;
use crate::util::rng::Pcg;

/// Dedicated RNG stream for the MTBF/MTTR generator (trace generation
/// uses 0x7ace / 0x57ea, RandomPlacer 0x91ac — distinct streams keep the
/// draws independent under a shared scenario seed).
pub const FAULT_STREAM: u64 = 0xfa17;

/// Dedicated RNG stream for the degradation generator. Distinct from
/// [`FAULT_STREAM`] so adding a `degraded` section to a scenario leaves
/// the fail-stop schedule of the same (seed, spec) byte-identical.
pub const DEGRADE_STREAM: u64 = 0xdeca;

/// Default checkpoint interval (iterations) when a scenario enables
/// faults without choosing one.
pub const DEFAULT_CHECKPOINT_ITERS: u64 = 100;

/// A spec-level fault: what fails (or recovers) and which one.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    GpuFail(usize),
    GpuRecover(usize),
    ServerFail(usize),
    ServerRecover(usize),
    LinkFail(LinkId),
    LinkRecover(LinkId),
    /// Gray failure: the GPU keeps running but every compute phase takes
    /// `1/factor` as long (factor in (0, 1]).
    GpuSlow(usize, f64),
    /// Recovery from [`FaultKind::GpuSlow`]: health factor back to 1.0.
    GpuRestore(usize),
    /// Gray failure: the link carries traffic at `factor` of nominal
    /// bandwidth, i.e. per-byte cost scales by `1/factor`.
    LinkDegrade(LinkId, f64),
    /// Recovery from [`FaultKind::LinkDegrade`]: factor back to 1.0.
    LinkRestore(LinkId),
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::GpuFail(_) => "gpu-fail",
            FaultKind::GpuRecover(_) => "gpu-recover",
            FaultKind::ServerFail(_) => "server-fail",
            FaultKind::ServerRecover(_) => "server-recover",
            FaultKind::LinkFail(_) => "link-fail",
            FaultKind::LinkRecover(_) => "link-recover",
            FaultKind::GpuSlow(..) => "gpu-slow",
            FaultKind::GpuRestore(_) => "gpu-restore",
            FaultKind::LinkDegrade(..) => "link-degrade",
            FaultKind::LinkRestore(_) => "link-restore",
        }
    }

    pub fn id(&self) -> usize {
        match *self {
            FaultKind::GpuFail(x)
            | FaultKind::GpuRecover(x)
            | FaultKind::ServerFail(x)
            | FaultKind::ServerRecover(x)
            | FaultKind::LinkFail(x)
            | FaultKind::LinkRecover(x)
            | FaultKind::GpuSlow(x, _)
            | FaultKind::GpuRestore(x)
            | FaultKind::LinkDegrade(x, _)
            | FaultKind::LinkRestore(x) => x,
        }
    }

    /// The health factor carried by degradation kinds; `None` otherwise.
    pub fn factor(&self) -> Option<f64> {
        match *self {
            FaultKind::GpuSlow(_, f) | FaultKind::LinkDegrade(_, f) => Some(f),
            _ => None,
        }
    }

    /// `factor` is required for (and only allowed on) the degradation
    /// kinds `gpu-slow` / `link-degrade`.
    pub fn parse(kind: &str, id: usize, factor: Option<f64>) -> Option<FaultKind> {
        let k = match (kind, factor) {
            ("gpu-fail", None) => FaultKind::GpuFail(id),
            ("gpu-recover", None) => FaultKind::GpuRecover(id),
            ("server-fail", None) => FaultKind::ServerFail(id),
            ("server-recover", None) => FaultKind::ServerRecover(id),
            ("link-fail", None) => FaultKind::LinkFail(id),
            ("link-recover", None) => FaultKind::LinkRecover(id),
            ("gpu-slow", Some(f)) => FaultKind::GpuSlow(id, f),
            ("link-degrade", Some(f)) => FaultKind::LinkDegrade(id, f),
            ("gpu-restore", None) => FaultKind::GpuRestore(id),
            ("link-restore", None) => FaultKind::LinkRestore(id),
            _ => return None,
        };
        Some(k)
    }
}

/// One timeline entry: `kind` happens at simulated time `t`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub t: f64,
    pub kind: FaultKind,
}

impl FaultEvent {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj()
            .set("t", self.t)
            .set("kind", self.kind.name())
            .set("id", self.kind.id());
        if let Some(f) = self.kind.factor() {
            o = o.set("factor", f);
        }
        o
    }

    pub fn from_json(v: &Json) -> Result<FaultEvent> {
        if let Json::Obj(entries) = v {
            for (key, _) in entries {
                if !matches!(key.as_str(), "t" | "kind" | "id" | "factor") {
                    return Err(Error::msg(format!(
                        "unknown fault event key '{key}' (t|kind|id|factor)"
                    )));
                }
            }
        } else {
            return Err(Error::msg("fault event must be an object"));
        }
        let t = v.req_f64("t").map_err(Error::msg)?;
        let kind = v.req_str("kind").map_err(Error::msg)?;
        let id = v.req_usize("id").map_err(Error::msg)?;
        let factor = match v.get("factor") {
            Some(x) => {
                Some(x.as_f64().ok_or_else(|| Error::msg("fault 'factor' must be a number"))?)
            }
            None => None,
        };
        let kind = FaultKind::parse(kind, id, factor).ok_or_else(|| {
            if matches!(kind, "gpu-slow" | "link-degrade") && factor.is_none() {
                Error::msg(format!("fault kind '{kind}' requires a 'factor' in (0, 1]"))
            } else if factor.is_some() && FaultKind::parse(kind, id, None).is_some() {
                Error::msg(format!(
                    "fault kind '{kind}' does not take a 'factor' \
                     (only gpu-slow|link-degrade do)"
                ))
            } else {
                Error::msg(format!(
                    "unknown fault kind '{kind}' \
                     (gpu-fail|gpu-recover|server-fail|server-recover|link-fail|link-recover\
                     |gpu-slow|gpu-restore|link-degrade|link-restore)"
                ))
            }
        })?;
        Ok(FaultEvent { t, kind })
    }
}

/// What the MTBF/MTTR generator aims failures at.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultTargets {
    Gpus,
    Links,
    Both,
}

impl FaultTargets {
    pub fn name(&self) -> &'static str {
        match self {
            FaultTargets::Gpus => "gpus",
            FaultTargets::Links => "links",
            FaultTargets::Both => "both",
        }
    }

    pub fn parse(s: &str) -> Option<FaultTargets> {
        Some(match s {
            "gpus" => FaultTargets::Gpus,
            "links" => FaultTargets::Links,
            "both" => FaultTargets::Both,
            _ => return None,
        })
    }
}

/// MTBF/MTTR schedule generator parameters. The failure process is
/// global: inter-failure gaps are Exp(mtbf_s) across the whole fleet,
/// each failure picks a uniform target, and each failed target recovers
/// after an independent Exp(mttr_s) — always, even past the horizon, so
/// every generated schedule ends with full capacity restored.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GenSpec {
    pub mtbf_s: f64,
    pub mttr_s: f64,
    /// No new failures are generated at or past this time.
    pub horizon_s: f64,
    pub targets: FaultTargets,
    /// `None` = derive from the scenario seed.
    pub seed: Option<u64>,
}

impl GenSpec {
    pub const DEFAULT_MTTR_S: f64 = 60.0;
    pub const DEFAULT_HORIZON_S: f64 = 1200.0;

    /// A generator spec with everything but the MTBF defaulted — what the
    /// experiment `mtbf` axis materializes on a fault-less base scenario.
    pub fn with_mtbf(mtbf_s: f64) -> GenSpec {
        GenSpec {
            mtbf_s,
            mttr_s: Self::DEFAULT_MTTR_S,
            horizon_s: Self::DEFAULT_HORIZON_S,
            targets: FaultTargets::Gpus,
            seed: None,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj()
            .set("mtbf_s", self.mtbf_s)
            .set("mttr_s", self.mttr_s)
            .set("horizon_s", self.horizon_s)
            .set("targets", self.targets.name());
        if let Some(seed) = self.seed {
            o = o.set("seed", seed);
        }
        o
    }

    pub fn from_json(v: &Json) -> Result<GenSpec> {
        if let Json::Obj(entries) = v {
            for (key, _) in entries {
                if !matches!(key.as_str(), "mtbf_s" | "mttr_s" | "horizon_s" | "targets" | "seed")
                {
                    return Err(Error::msg(format!(
                        "unknown fault generator key '{key}' \
                         (mtbf_s|mttr_s|horizon_s|targets|seed)"
                    )));
                }
            }
        } else {
            return Err(Error::msg("fault generator ('mtbf') must be an object"));
        }
        let mut g = GenSpec::with_mtbf(v.req_f64("mtbf_s").map_err(Error::msg)?);
        if let Some(x) = v.get("mttr_s") {
            g.mttr_s = x.as_f64().ok_or_else(|| Error::msg("mttr_s must be a number"))?;
        }
        if let Some(x) = v.get("horizon_s") {
            g.horizon_s = x.as_f64().ok_or_else(|| Error::msg("horizon_s must be a number"))?;
        }
        if let Some(x) = v.get("targets") {
            let s = x.as_str().ok_or_else(|| Error::msg("targets must be a string"))?;
            g.targets = FaultTargets::parse(s)
                .ok_or_else(|| Error::msg(format!("unknown targets '{s}' (gpus|links|both)")))?;
        }
        if let Some(x) = v.get("seed") {
            g.seed =
                Some(x.as_u64().ok_or_else(|| Error::msg("fault seed must be an integer"))?);
        }
        Ok(g)
    }
}

/// Degradation (gray-failure) schedule generator parameters, mirroring
/// [`GenSpec`]: onset gaps are Exp(mtbd_s) across the fleet, each onset
/// picks a uniform target and a uniform health factor in
/// `[factor_min, factor_max]`, and each degraded target restores to full
/// health after an independent Exp(mttr_s). Draws come from
/// [`DEGRADE_STREAM`], so the schedule is a pure function of (spec, seed)
/// and independent of any fail-stop generator sharing the scenario seed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DegradeSpec {
    /// Mean time between degradation onsets (fleet-global).
    pub mtbd_s: f64,
    /// Mean time to restore a degraded target to factor 1.0.
    pub mttr_s: f64,
    /// No new degradations are generated at or past this time.
    pub horizon_s: f64,
    /// Drawn health factors are uniform in `[factor_min, factor_max]`;
    /// both must lie in (0, 1] (smaller = more severe).
    pub factor_min: f64,
    pub factor_max: f64,
    pub targets: FaultTargets,
    /// `None` = derive from the scenario seed.
    pub seed: Option<u64>,
}

impl DegradeSpec {
    pub const DEFAULT_MTTR_S: f64 = 120.0;
    pub const DEFAULT_HORIZON_S: f64 = 1200.0;
    pub const DEFAULT_MTBD_S: f64 = 180.0;
    pub const DEFAULT_FACTOR_MIN: f64 = 0.25;
    pub const DEFAULT_FACTOR_MAX: f64 = 0.75;

    /// A generator spec with everything but the onset rate defaulted.
    pub fn with_mtbd(mtbd_s: f64) -> DegradeSpec {
        DegradeSpec {
            mtbd_s,
            mttr_s: Self::DEFAULT_MTTR_S,
            horizon_s: Self::DEFAULT_HORIZON_S,
            factor_min: Self::DEFAULT_FACTOR_MIN,
            factor_max: Self::DEFAULT_FACTOR_MAX,
            targets: FaultTargets::Both,
            seed: None,
        }
    }

    /// What the experiment `degrade` axis materializes: every drawn
    /// degradation pins the health factor to exactly `factor` (severity),
    /// everything else defaulted.
    pub fn with_severity(factor: f64) -> DegradeSpec {
        DegradeSpec {
            factor_min: factor,
            factor_max: factor,
            ..Self::with_mtbd(Self::DEFAULT_MTBD_S)
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj()
            .set("mtbd_s", self.mtbd_s)
            .set("mttr_s", self.mttr_s)
            .set("horizon_s", self.horizon_s)
            .set("factor_min", self.factor_min)
            .set("factor_max", self.factor_max)
            .set("targets", self.targets.name());
        if let Some(seed) = self.seed {
            o = o.set("seed", seed);
        }
        o
    }

    pub fn from_json(v: &Json) -> Result<DegradeSpec> {
        if let Json::Obj(entries) = v {
            for (key, _) in entries {
                if !matches!(
                    key.as_str(),
                    "mtbd_s" | "mttr_s" | "horizon_s" | "factor_min" | "factor_max" | "targets"
                        | "seed"
                ) {
                    return Err(Error::msg(format!(
                        "unknown degradation generator key '{key}' \
                         (mtbd_s|mttr_s|horizon_s|factor_min|factor_max|targets|seed)"
                    )));
                }
            }
        } else {
            return Err(Error::msg("fault degradation ('degraded') must be an object"));
        }
        let mut d = DegradeSpec::with_mtbd(v.req_f64("mtbd_s").map_err(Error::msg)?);
        if let Some(x) = v.get("mttr_s") {
            d.mttr_s = x.as_f64().ok_or_else(|| Error::msg("mttr_s must be a number"))?;
        }
        if let Some(x) = v.get("horizon_s") {
            d.horizon_s = x.as_f64().ok_or_else(|| Error::msg("horizon_s must be a number"))?;
        }
        if let Some(x) = v.get("factor_min") {
            d.factor_min = x.as_f64().ok_or_else(|| Error::msg("factor_min must be a number"))?;
        }
        if let Some(x) = v.get("factor_max") {
            d.factor_max = x.as_f64().ok_or_else(|| Error::msg("factor_max must be a number"))?;
        }
        if let Some(x) = v.get("targets") {
            let s = x.as_str().ok_or_else(|| Error::msg("targets must be a string"))?;
            d.targets = FaultTargets::parse(s)
                .ok_or_else(|| Error::msg(format!("unknown targets '{s}' (gpus|links|both)")))?;
        }
        if let Some(x) = v.get("seed") {
            d.seed =
                Some(x.as_u64().ok_or_else(|| Error::msg("degrade seed must be an integer"))?);
        }
        Ok(d)
    }
}

/// The scenario-level `faults` section (docs/SCENARIOS.md §Faults):
/// checkpoint/restart knobs plus an explicit timeline and/or a generator.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultsSpec {
    /// A preempted job rewinds to its last multiple of this many
    /// iterations; 0 = no checkpointing (restart from iteration 0).
    pub checkpoint_iters: u64,
    /// Dead time a restarted job pays on its new GPUs before iterating.
    pub warmup_s: f64,
    pub events: Vec<FaultEvent>,
    pub gen: Option<GenSpec>,
    pub degraded: Option<DegradeSpec>,
    /// Exponential restart backoff base: a job's n-th preemption keeps it
    /// out of the queue for `min(cap, base * 2^(n-1))` seconds. 0 = off
    /// (preempted jobs requeue immediately, the pre-gray-failure path).
    pub backoff_base_s: f64,
    pub backoff_cap_s: f64,
    /// A GPU that fails `blacklist_k` times within `blacklist_window_s`
    /// stays excluded from placement after recovery until the window
    /// drains. 0 = off.
    pub blacklist_k: u64,
    pub blacklist_window_s: f64,
}

/// Default cap on the exponential restart backoff delay.
pub const DEFAULT_BACKOFF_CAP_S: f64 = 300.0;

/// Default sliding window for the failure-count blacklist.
pub const DEFAULT_BLACKLIST_WINDOW_S: f64 = 600.0;

impl Default for FaultsSpec {
    fn default() -> FaultsSpec {
        FaultsSpec {
            checkpoint_iters: DEFAULT_CHECKPOINT_ITERS,
            warmup_s: 0.0,
            events: Vec::new(),
            gen: None,
            degraded: None,
            backoff_base_s: 0.0,
            backoff_cap_s: DEFAULT_BACKOFF_CAP_S,
            blacklist_k: 0,
            blacklist_window_s: DEFAULT_BLACKLIST_WINDOW_S,
        }
    }
}

impl FaultsSpec {
    /// Typed numeric-sanity + range validation, given the cluster shape
    /// and the fabric's link count ([`TopologySpec::n_links`]).
    pub fn validate(&self, cluster: &ClusterSpec, n_links: usize) -> Result<()> {
        if !self.warmup_s.is_finite() || self.warmup_s < 0.0 {
            return Err(Error::msg(format!(
                "faults.warmup_s must be finite and non-negative, got {}",
                self.warmup_s
            )));
        }
        for e in &self.events {
            if !e.t.is_finite() || e.t < 0.0 {
                return Err(Error::msg(format!(
                    "fault event time {} must be finite and non-negative",
                    e.t
                )));
            }
            let (id, max, what) = match e.kind {
                FaultKind::GpuFail(g)
                | FaultKind::GpuRecover(g)
                | FaultKind::GpuSlow(g, _)
                | FaultKind::GpuRestore(g) => (g, cluster.n_gpus(), "gpu"),
                FaultKind::ServerFail(s) | FaultKind::ServerRecover(s) => {
                    (s, cluster.n_servers, "server")
                }
                FaultKind::LinkFail(l)
                | FaultKind::LinkRecover(l)
                | FaultKind::LinkDegrade(l, _)
                | FaultKind::LinkRestore(l) => (l, n_links, "link"),
            };
            if id >= max {
                return Err(Error::msg(format!(
                    "fault event targets {what} {id} but the scenario has only {max}"
                )));
            }
            if let Some(f) = e.kind.factor() {
                if !f.is_finite() || f <= 0.0 || f > 1.0 {
                    return Err(Error::msg(format!(
                        "fault event '{}' factor must be in (0, 1], got {f}",
                        e.kind.name()
                    )));
                }
            }
        }
        if let Some(g) = &self.gen {
            for (name, v) in [("mtbf_s", g.mtbf_s), ("mttr_s", g.mttr_s)] {
                if !v.is_finite() || v <= 0.0 {
                    return Err(Error::msg(format!(
                        "faults.mtbf.{name} must be finite and positive, got {v}"
                    )));
                }
            }
            if !g.horizon_s.is_finite() || g.horizon_s < 0.0 {
                return Err(Error::msg(format!(
                    "faults.mtbf.horizon_s must be finite and non-negative, got {}",
                    g.horizon_s
                )));
            }
            if g.targets != FaultTargets::Gpus && n_links == 0 {
                return Err(Error::msg(
                    "faults.mtbf targets links but the topology has no links",
                ));
            }
        }
        if let Some(d) = &self.degraded {
            for (name, v) in [("mtbd_s", d.mtbd_s), ("mttr_s", d.mttr_s)] {
                if !v.is_finite() || v <= 0.0 {
                    return Err(Error::msg(format!(
                        "faults.degraded.{name} must be finite and positive, got {v}"
                    )));
                }
            }
            if !d.horizon_s.is_finite() || d.horizon_s < 0.0 {
                return Err(Error::msg(format!(
                    "faults.degraded.horizon_s must be finite and non-negative, got {}",
                    d.horizon_s
                )));
            }
            for (name, v) in [("factor_min", d.factor_min), ("factor_max", d.factor_max)] {
                if !v.is_finite() || v <= 0.0 || v > 1.0 {
                    return Err(Error::msg(format!(
                        "faults.degraded.{name} must be in (0, 1], got {v}"
                    )));
                }
            }
            if d.factor_min > d.factor_max {
                return Err(Error::msg(format!(
                    "faults.degraded.factor_min ({}) exceeds factor_max ({})",
                    d.factor_min, d.factor_max
                )));
            }
            if d.targets != FaultTargets::Gpus && n_links == 0 {
                return Err(Error::msg(
                    "faults.degraded targets links but the topology has no links",
                ));
            }
        }
        if !self.backoff_base_s.is_finite() || self.backoff_base_s < 0.0 {
            return Err(Error::msg(format!(
                "faults.backoff_base_s must be finite and non-negative, got {}",
                self.backoff_base_s
            )));
        }
        if !self.backoff_cap_s.is_finite() || self.backoff_cap_s < 0.0 {
            return Err(Error::msg(format!(
                "faults.backoff_cap_s must be finite and non-negative, got {}",
                self.backoff_cap_s
            )));
        }
        if !self.blacklist_window_s.is_finite() || self.blacklist_window_s <= 0.0 {
            return Err(Error::msg(format!(
                "faults.blacklist_window_s must be finite and positive, got {}",
                self.blacklist_window_s
            )));
        }
        Ok(())
    }

    /// Expand server sugar, run the generator, and merge everything into
    /// one time-sorted primitive plan. `default_seed` (the scenario seed)
    /// feeds the generator unless the spec pins its own.
    pub fn compile(
        &self,
        cluster: &ClusterSpec,
        n_links: usize,
        default_seed: u64,
    ) -> Result<FaultPlan> {
        self.validate(cluster, n_links)?;
        let mut events: Vec<(f64, PrimFault)> = Vec::new();
        for e in &self.events {
            match e.kind {
                FaultKind::GpuFail(g) => events.push((e.t, PrimFault::GpuFail(g))),
                FaultKind::GpuRecover(g) => events.push((e.t, PrimFault::GpuRecover(g))),
                FaultKind::LinkFail(l) => events.push((e.t, PrimFault::LinkFail(l))),
                FaultKind::LinkRecover(l) => events.push((e.t, PrimFault::LinkRecover(l))),
                FaultKind::GpuSlow(g, f) => events.push((e.t, PrimFault::GpuSlow(g, f))),
                FaultKind::GpuRestore(g) => events.push((e.t, PrimFault::GpuRestore(g))),
                FaultKind::LinkDegrade(l, f) => events.push((e.t, PrimFault::LinkDegrade(l, f))),
                FaultKind::LinkRestore(l) => events.push((e.t, PrimFault::LinkRestore(l))),
                FaultKind::ServerFail(s) => {
                    for g in cluster.gpus_of(s) {
                        events.push((e.t, PrimFault::GpuFail(g)));
                    }
                    // NIC LinkId == ServerId in every preset; the rack
                    // uplink (two-tier) is shared and survives.
                    if s < n_links {
                        events.push((e.t, PrimFault::LinkFail(s)));
                    }
                }
                FaultKind::ServerRecover(s) => {
                    for g in cluster.gpus_of(s) {
                        events.push((e.t, PrimFault::GpuRecover(g)));
                    }
                    if s < n_links {
                        events.push((e.t, PrimFault::LinkRecover(s)));
                    }
                }
            }
        }
        if let Some(g) = &self.gen {
            generate(g, cluster.n_gpus(), n_links, default_seed, &mut events);
        }
        if let Some(d) = &self.degraded {
            generate_degrade(d, cluster.n_gpus(), n_links, default_seed, &mut events);
        }
        // Stable sort: simultaneous primitives keep spec/generator order
        // (in particular a server's GPU fails stay grouped before its NIC).
        events.sort_by(|a, b| a.0.total_cmp(&b.0));
        Ok(FaultPlan {
            events,
            checkpoint_iters: self.checkpoint_iters,
            warmup_s: self.warmup_s,
            backoff_base_s: self.backoff_base_s,
            backoff_cap_s: self.backoff_cap_s,
            blacklist_k: self.blacklist_k as usize,
            blacklist_window_s: self.blacklist_window_s,
        })
    }

    // ---- serialization (defaults elided; docs/SCENARIOS.md) ----------------

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        if self.checkpoint_iters != DEFAULT_CHECKPOINT_ITERS {
            o = o.set("checkpoint_iters", self.checkpoint_iters);
        }
        if self.warmup_s != 0.0 {
            o = o.set("warmup_s", self.warmup_s);
        }
        if !self.events.is_empty() {
            o = o.set(
                "events",
                Json::Arr(self.events.iter().map(FaultEvent::to_json).collect()),
            );
        }
        if let Some(g) = &self.gen {
            o = o.set("mtbf", g.to_json());
        }
        if let Some(d) = &self.degraded {
            o = o.set("degraded", d.to_json());
        }
        if self.backoff_base_s != 0.0 {
            o = o.set("backoff_base_s", self.backoff_base_s);
        }
        if self.backoff_cap_s != DEFAULT_BACKOFF_CAP_S {
            o = o.set("backoff_cap_s", self.backoff_cap_s);
        }
        if self.blacklist_k != 0 {
            o = o.set("blacklist_k", self.blacklist_k);
        }
        if self.blacklist_window_s != DEFAULT_BLACKLIST_WINDOW_S {
            o = o.set("blacklist_window_s", self.blacklist_window_s);
        }
        o
    }

    pub fn from_json(v: &Json) -> Result<FaultsSpec> {
        if let Json::Obj(entries) = v {
            for (key, _) in entries {
                if !matches!(
                    key.as_str(),
                    "checkpoint_iters" | "warmup_s" | "events" | "mtbf" | "degraded"
                        | "backoff_base_s" | "backoff_cap_s" | "blacklist_k"
                        | "blacklist_window_s"
                ) {
                    return Err(Error::msg(format!(
                        "unknown faults key '{key}' \
                         (checkpoint_iters|warmup_s|events|mtbf|degraded|backoff_base_s\
                         |backoff_cap_s|blacklist_k|blacklist_window_s)"
                    )));
                }
            }
        } else {
            return Err(Error::msg("'faults' must be an object"));
        }
        let mut spec = FaultsSpec::default();
        if let Some(x) = v.get("checkpoint_iters") {
            spec.checkpoint_iters = x
                .as_u64()
                .ok_or_else(|| Error::msg("checkpoint_iters must be a non-negative integer"))?;
        }
        if let Some(x) = v.get("warmup_s") {
            spec.warmup_s = x.as_f64().ok_or_else(|| Error::msg("warmup_s must be a number"))?;
        }
        if let Some(x) = v.get("events") {
            let arr = x.as_arr().ok_or_else(|| Error::msg("faults.events must be an array"))?;
            spec.events = arr.iter().map(FaultEvent::from_json).collect::<Result<_>>()?;
        }
        if let Some(x) = v.get("mtbf") {
            spec.gen = Some(GenSpec::from_json(x)?);
        }
        if let Some(x) = v.get("degraded") {
            spec.degraded = Some(DegradeSpec::from_json(x)?);
        }
        if let Some(x) = v.get("backoff_base_s") {
            spec.backoff_base_s =
                x.as_f64().ok_or_else(|| Error::msg("backoff_base_s must be a number"))?;
        }
        if let Some(x) = v.get("backoff_cap_s") {
            spec.backoff_cap_s =
                x.as_f64().ok_or_else(|| Error::msg("backoff_cap_s must be a number"))?;
        }
        if let Some(x) = v.get("blacklist_k") {
            spec.blacklist_k = x
                .as_u64()
                .ok_or_else(|| Error::msg("blacklist_k must be a non-negative integer"))?;
        }
        if let Some(x) = v.get("blacklist_window_s") {
            spec.blacklist_window_s =
                x.as_f64().ok_or_else(|| Error::msg("blacklist_window_s must be a number"))?;
        }
        Ok(spec)
    }
}

/// Exp(mean) draw. `next_f64` is in [0, 1), so `1 - u` is in (0, 1] and
/// the result is finite and non-negative.
fn exp_draw(rng: &mut Pcg, mean: f64) -> f64 {
    -mean * (1.0 - rng.next_f64()).ln()
}

/// The MTBF/MTTR process (see [`GenSpec`]): appends (time, primitive)
/// pairs. A failure aimed at a target that is still down is skipped —
/// the global clock still advanced, matching a fleet whose failed unit
/// cannot fail again until repaired.
fn generate(
    spec: &GenSpec,
    n_gpus: usize,
    n_links: usize,
    default_seed: u64,
    out: &mut Vec<(f64, PrimFault)>,
) {
    let n_targets = match spec.targets {
        FaultTargets::Gpus => n_gpus,
        FaultTargets::Links => n_links,
        FaultTargets::Both => n_gpus + n_links,
    };
    if n_targets == 0 {
        return;
    }
    let mut rng = Pcg::new(spec.seed.unwrap_or(default_seed), FAULT_STREAM);
    let mut down_until = vec![0.0f64; n_targets];
    let mut t = 0.0f64;
    loop {
        t += exp_draw(&mut rng, spec.mtbf_s);
        if t >= spec.horizon_s {
            break;
        }
        let target = rng.next_below(n_targets as u64) as usize;
        if t < down_until[target] {
            continue; // still being repaired; cannot fail again
        }
        let recover_at = t + exp_draw(&mut rng, spec.mttr_s);
        down_until[target] = recover_at;
        let gpu_target = match spec.targets {
            FaultTargets::Gpus => true,
            FaultTargets::Links => false,
            FaultTargets::Both => target < n_gpus,
        };
        if gpu_target {
            out.push((t, PrimFault::GpuFail(target)));
            out.push((recover_at, PrimFault::GpuRecover(target)));
        } else {
            let link = if spec.targets == FaultTargets::Both { target - n_gpus } else { target };
            out.push((t, PrimFault::LinkFail(link)));
            out.push((recover_at, PrimFault::LinkRecover(link)));
        }
    }
}

/// The degradation process (see [`DegradeSpec`]): appends (time,
/// primitive) pairs. Structure mirrors [`generate`], with a per-onset
/// uniform factor draw, on [`DEGRADE_STREAM`]. A degradation aimed at a
/// still-degraded target is skipped (the global clock still advanced).
fn generate_degrade(
    spec: &DegradeSpec,
    n_gpus: usize,
    n_links: usize,
    default_seed: u64,
    out: &mut Vec<(f64, PrimFault)>,
) {
    let n_targets = match spec.targets {
        FaultTargets::Gpus => n_gpus,
        FaultTargets::Links => n_links,
        FaultTargets::Both => n_gpus + n_links,
    };
    if n_targets == 0 {
        return;
    }
    let mut rng = Pcg::new(spec.seed.unwrap_or(default_seed), DEGRADE_STREAM);
    let mut degraded_until = vec![0.0f64; n_targets];
    let mut t = 0.0f64;
    loop {
        t += exp_draw(&mut rng, spec.mtbd_s);
        if t >= spec.horizon_s {
            break;
        }
        let target = rng.next_below(n_targets as u64) as usize;
        if t < degraded_until[target] {
            continue; // still degraded; no compounding
        }
        let factor = spec.factor_min + (spec.factor_max - spec.factor_min) * rng.next_f64();
        let restore_at = t + exp_draw(&mut rng, spec.mttr_s);
        degraded_until[target] = restore_at;
        let gpu_target = match spec.targets {
            FaultTargets::Gpus => true,
            FaultTargets::Links => false,
            FaultTargets::Both => target < n_gpus,
        };
        if gpu_target {
            out.push((t, PrimFault::GpuSlow(target, factor)));
            out.push((restore_at, PrimFault::GpuRestore(target)));
        } else {
            let link = if spec.targets == FaultTargets::Both { target - n_gpus } else { target };
            out.push((t, PrimFault::LinkDegrade(link, factor)));
            out.push((restore_at, PrimFault::LinkRestore(link)));
        }
    }
}

/// A compiled, engine-level fault primitive: GPUs and links only (server
/// sugar already expanded).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PrimFault {
    GpuFail(usize),
    GpuRecover(usize),
    LinkFail(LinkId),
    LinkRecover(LinkId),
    GpuSlow(usize, f64),
    GpuRestore(usize),
    LinkDegrade(LinkId, f64),
    LinkRestore(LinkId),
}

/// The engine's fault input: a time-sorted primitive timeline plus the
/// checkpoint/restart knobs. `Default` is the empty plan, under which the
/// engine is bit-identical to a fault-less build (no heap pushes, no
/// extra float ops, no RNG draws — see sim/engine.rs).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<(f64, PrimFault)>,
    pub checkpoint_iters: u64,
    pub warmup_s: f64,
    /// See [`FaultsSpec::backoff_base_s`]; 0 = requeue immediately.
    pub backoff_base_s: f64,
    pub backoff_cap_s: f64,
    /// See [`FaultsSpec::blacklist_k`]; 0 = blacklisting off.
    pub blacklist_k: usize,
    pub blacklist_window_s: f64,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan {
            events: Vec::new(),
            checkpoint_iters: DEFAULT_CHECKPOINT_ITERS,
            warmup_s: 0.0,
            backoff_base_s: 0.0,
            backoff_cap_s: DEFAULT_BACKOFF_CAP_S,
            blacklist_k: 0,
            blacklist_window_s: DEFAULT_BLACKLIST_WINDOW_S,
        }
    }
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Live per-device health factors, driven by the engine as it processes
/// the fault timeline: 1.0 = healthy, 0.0 = down, anything between is a
/// gray failure (a link's factor scales its effective bandwidth, a GPU's
/// factor scales its compute speed). The binary API (`gpu_up` etc.) is
/// `factor > 0`. Admission reads it directly; placement reads it
/// indirectly through the zero-free-memory hold on down GPUs.
#[derive(Clone, Debug)]
pub struct HealthView {
    gpu: Vec<f64>,
    link: Vec<f64>,
}

impl HealthView {
    pub fn new(n_gpus: usize, n_links: usize) -> HealthView {
        HealthView { gpu: vec![1.0; n_gpus], link: vec![1.0; n_links] }
    }

    pub fn gpu_up(&self, g: usize) -> bool {
        self.gpu[g] > 0.0
    }

    pub fn link_up(&self, l: LinkId) -> bool {
        self.link[l] > 0.0
    }

    pub fn links_up(&self, links: &[LinkId]) -> bool {
        links.iter().all(|&l| self.link[l] > 0.0)
    }

    pub fn gpu_factor(&self, g: usize) -> f64 {
        self.gpu[g]
    }

    pub fn link_factor(&self, l: LinkId) -> f64 {
        self.link[l]
    }

    /// Up/down transitions snap the factor to 1.0 / 0.0: a recovered
    /// device comes back at full health.
    pub fn set_gpu(&mut self, g: usize, up: bool) {
        self.gpu[g] = if up { 1.0 } else { 0.0 };
    }

    pub fn set_link(&mut self, l: LinkId, up: bool) {
        self.link[l] = if up { 1.0 } else { 0.0 };
    }

    pub fn set_gpu_factor(&mut self, g: usize, factor: f64) {
        self.gpu[g] = factor;
    }

    pub fn set_link_factor(&mut self, l: LinkId, factor: f64) {
        self.link[l] = factor;
    }

    /// The raw per-GPU factor slice (index = GpuId) — what the
    /// health-aware placer folds into its EWMA each decision.
    pub fn gpu_factors(&self) -> &[f64] {
        &self.gpu
    }

    /// The raw per-link factor slice (index = LinkId).
    pub fn link_factors(&self) -> &[f64] {
        &self.link
    }

    pub fn n_gpus(&self) -> usize {
        self.gpu.len()
    }

    pub fn n_links(&self) -> usize {
        self.link.len()
    }

    pub fn n_gpus_up(&self) -> usize {
        self.gpu.iter().filter(|&&f| f > 0.0).count()
    }

    pub fn n_links_up(&self) -> usize {
        self.link.iter().filter(|&&f| f > 0.0).count()
    }

    /// Mean health factor over every GPU and link — the `Obs` feature a
    /// learned scheduler watches to sense gray failures. 1.0 when the
    /// fleet is fully healthy (or empty).
    pub fn mean_health(&self) -> f64 {
        let n = self.gpu.len() + self.link.len();
        if n == 0 {
            return 1.0;
        }
        let sum: f64 = self.gpu.iter().chain(self.link.iter()).sum();
        sum / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster() -> ClusterSpec {
        ClusterSpec::tiny(4, 2)
    }

    #[test]
    fn generator_is_deterministic_and_paired() {
        let spec = GenSpec { seed: Some(7), ..GenSpec::with_mtbf(100.0) };
        let faults = FaultsSpec { gen: Some(spec), ..FaultsSpec::default() };
        let a = faults.compile(&cluster(), 4, 42).unwrap();
        let b = faults.compile(&cluster(), 4, 42).unwrap();
        assert_eq!(a, b, "same (seed, spec) must be byte-reproducible");
        assert!(!a.is_empty(), "mtbf 100s over a 1200s horizon produced nothing");
        // Every failure has exactly one recovery, even past the horizon.
        let mut balance = std::collections::BTreeMap::new();
        for &(t, p) in &a.events {
            assert!(t.is_finite() && t >= 0.0);
            match p {
                PrimFault::GpuFail(g) => *balance.entry(g).or_insert(0i64) += 1,
                PrimFault::GpuRecover(g) => *balance.entry(g).or_insert(0i64) -= 1,
                other => panic!("gpus-only generator emitted {other:?}"),
            }
        }
        assert!(balance.values().all(|&v| v == 0), "unbalanced fail/recover: {balance:?}");
        // Sorted by time.
        assert!(a.events.windows(2).all(|w| w[0].0 <= w[1].0));
    }

    #[test]
    fn generator_seed_changes_schedule() {
        let mk = |seed| {
            let spec = GenSpec { seed: Some(seed), ..GenSpec::with_mtbf(100.0) };
            FaultsSpec { gen: Some(spec), ..FaultsSpec::default() }
                .compile(&cluster(), 4, 42)
                .unwrap()
        };
        assert_ne!(mk(1), mk(2));
        // And with seed: None, the scenario seed feeds the stream.
        let spec = GenSpec { seed: None, ..GenSpec::with_mtbf(100.0) };
        let faults = FaultsSpec { gen: Some(spec), ..FaultsSpec::default() };
        assert_ne!(
            faults.compile(&cluster(), 4, 1).unwrap(),
            faults.compile(&cluster(), 4, 2).unwrap()
        );
    }

    #[test]
    fn server_fault_expands_to_gpus_and_nic() {
        let faults = FaultsSpec {
            events: vec![
                FaultEvent { t: 10.0, kind: FaultKind::ServerFail(1) },
                FaultEvent { t: 20.0, kind: FaultKind::ServerRecover(1) },
            ],
            ..FaultsSpec::default()
        };
        let plan = faults.compile(&cluster(), 4, 42).unwrap();
        // Server 1 of a 4x2 cluster = GPUs {2, 3} + NIC link 1.
        assert_eq!(
            plan.events,
            vec![
                (10.0, PrimFault::GpuFail(2)),
                (10.0, PrimFault::GpuFail(3)),
                (10.0, PrimFault::LinkFail(1)),
                (20.0, PrimFault::GpuRecover(2)),
                (20.0, PrimFault::GpuRecover(3)),
                (20.0, PrimFault::LinkRecover(1)),
            ]
        );
    }

    #[test]
    fn json_roundtrip_and_elision() {
        let spec = FaultsSpec {
            checkpoint_iters: 25,
            warmup_s: 5.0,
            events: vec![
                FaultEvent { t: 100.0, kind: FaultKind::GpuFail(3) },
                FaultEvent { t: 160.0, kind: FaultKind::GpuRecover(3) },
            ],
            gen: Some(GenSpec {
                mtbf_s: 600.0,
                mttr_s: 90.0,
                horizon_s: 2000.0,
                targets: FaultTargets::Both,
                seed: Some(9),
            }),
            ..FaultsSpec::default()
        };
        let back = FaultsSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, back);
        // Defaults serialize to an empty object and parse back.
        let dflt = FaultsSpec::default();
        let text = dflt.to_json().to_string();
        assert_eq!(text, "{}", "defaults must be elided, got {text}");
        assert_eq!(FaultsSpec::from_json(&dflt.to_json()).unwrap(), dflt);
    }

    #[test]
    fn validation_rejects_bad_input() {
        let c = cluster();
        let bad_id = FaultsSpec {
            events: vec![FaultEvent { t: 1.0, kind: FaultKind::GpuFail(99) }],
            ..FaultsSpec::default()
        };
        assert!(bad_id.validate(&c, 4).unwrap_err().to_string().contains("gpu 99"));
        let bad_t = FaultsSpec {
            events: vec![FaultEvent { t: f64::NAN, kind: FaultKind::GpuFail(0) }],
            ..FaultsSpec::default()
        };
        assert!(bad_t.validate(&c, 4).is_err());
        let bad_mtbf = FaultsSpec {
            gen: Some(GenSpec::with_mtbf(-1.0)),
            ..FaultsSpec::default()
        };
        assert!(bad_mtbf.validate(&c, 4).unwrap_err().to_string().contains("mtbf_s"));
        let bad_warm = FaultsSpec { warmup_s: f64::INFINITY, ..FaultsSpec::default() };
        assert!(bad_warm.validate(&c, 4).unwrap_err().to_string().contains("warmup_s"));
        let bad_kind = Json::parse(r#"{"events": [{"t": 1.0, "kind": "meteor", "id": 0}]}"#)
            .unwrap();
        assert!(FaultsSpec::from_json(&bad_kind)
            .unwrap_err()
            .to_string()
            .contains("unknown fault kind"));
        let bad_key = Json::parse(r#"{"mtbf_hours": 1}"#).unwrap();
        assert!(FaultsSpec::from_json(&bad_key)
            .unwrap_err()
            .to_string()
            .contains("unknown faults key"));
    }

    #[test]
    fn degrade_generator_is_deterministic_paired_and_in_range() {
        let spec = DegradeSpec { seed: Some(11), ..DegradeSpec::with_mtbd(60.0) };
        let faults = FaultsSpec { degraded: Some(spec), ..FaultsSpec::default() };
        let a = faults.compile(&cluster(), 4, 42).unwrap();
        let b = faults.compile(&cluster(), 4, 42).unwrap();
        assert_eq!(a, b, "same (seed, spec) must be byte-reproducible");
        assert!(!a.is_empty(), "mtbd 60s over a 1200s horizon produced nothing");
        let mut balance = std::collections::BTreeMap::new();
        for &(t, p) in &a.events {
            assert!(t.is_finite() && t >= 0.0);
            match p {
                PrimFault::GpuSlow(g, f) => {
                    assert!(
                        (DegradeSpec::DEFAULT_FACTOR_MIN..=DegradeSpec::DEFAULT_FACTOR_MAX)
                            .contains(&f),
                        "factor {f} outside configured range"
                    );
                    *balance.entry(("g", g)).or_insert(0i64) += 1;
                }
                PrimFault::GpuRestore(g) => *balance.entry(("g", g)).or_insert(0i64) -= 1,
                PrimFault::LinkDegrade(l, f) => {
                    assert!(f > 0.0 && f <= 1.0);
                    *balance.entry(("l", l)).or_insert(0i64) += 1;
                }
                PrimFault::LinkRestore(l) => *balance.entry(("l", l)).or_insert(0i64) -= 1,
                other => panic!("degradation generator emitted {other:?}"),
            }
        }
        assert!(balance.values().all(|&v| v == 0), "unpaired slow/restore: {balance:?}");
        assert!(a.events.windows(2).all(|w| w[0].0 <= w[1].0));
    }

    #[test]
    fn degrade_stream_is_independent_of_failure_stream() {
        // Adding a degraded section must not perturb the fail-stop
        // schedule generated from the same scenario seed.
        let gen = GenSpec::with_mtbf(100.0);
        let plain = FaultsSpec { gen: Some(gen), ..FaultsSpec::default() };
        let mixed = FaultsSpec {
            gen: Some(gen),
            degraded: Some(DegradeSpec::with_mtbd(90.0)),
            ..FaultsSpec::default()
        };
        let failstop_of = |p: &FaultPlan| {
            p.events
                .iter()
                .filter(|(_, f)| {
                    matches!(
                        f,
                        PrimFault::GpuFail(_)
                            | PrimFault::GpuRecover(_)
                            | PrimFault::LinkFail(_)
                            | PrimFault::LinkRecover(_)
                    )
                })
                .copied()
                .collect::<Vec<_>>()
        };
        let a = plain.compile(&cluster(), 4, 42).unwrap();
        let b = mixed.compile(&cluster(), 4, 42).unwrap();
        assert_eq!(failstop_of(&a), failstop_of(&b));
        assert!(b.events.len() > a.events.len(), "degradations were generated");
    }

    #[test]
    fn degrade_json_roundtrip_and_knobs() {
        let spec = FaultsSpec {
            events: vec![
                FaultEvent { t: 5.0, kind: FaultKind::LinkDegrade(1, 0.5) },
                FaultEvent { t: 9.0, kind: FaultKind::LinkRestore(1) },
                FaultEvent { t: 6.0, kind: FaultKind::GpuSlow(2, 0.25) },
                FaultEvent { t: 8.0, kind: FaultKind::GpuRestore(2) },
            ],
            degraded: Some(DegradeSpec {
                mtbd_s: 300.0,
                mttr_s: 45.0,
                horizon_s: 900.0,
                factor_min: 0.1,
                factor_max: 0.9,
                targets: FaultTargets::Links,
                seed: Some(3),
            }),
            backoff_base_s: 2.0,
            backoff_cap_s: 64.0,
            blacklist_k: 3,
            blacklist_window_s: 120.0,
            ..FaultsSpec::default()
        };
        let back = FaultsSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, back);
        // Defaults (including the new knobs) still elide to "{}".
        assert_eq!(FaultsSpec::default().to_json().to_string(), "{}");
    }

    #[test]
    fn degrade_validation_rejects_bad_input() {
        let c = cluster();
        for f in [0.0, -0.5, 1.5, f64::NAN] {
            let bad = FaultsSpec {
                events: vec![FaultEvent { t: 1.0, kind: FaultKind::GpuSlow(0, f) }],
                ..FaultsSpec::default()
            };
            assert!(
                bad.validate(&c, 4).unwrap_err().to_string().contains("factor"),
                "factor {f} must be rejected"
            );
        }
        let bad_range = FaultsSpec {
            degraded: Some(DegradeSpec {
                factor_min: 0.8,
                factor_max: 0.2,
                ..DegradeSpec::with_mtbd(100.0)
            }),
            ..FaultsSpec::default()
        };
        assert!(bad_range.validate(&c, 4).unwrap_err().to_string().contains("factor_min"));
        let bad_backoff = FaultsSpec { backoff_base_s: -1.0, ..FaultsSpec::default() };
        assert!(bad_backoff.validate(&c, 4).unwrap_err().to_string().contains("backoff_base_s"));
        let bad_window = FaultsSpec {
            blacklist_window_s: 0.0,
            ..FaultsSpec::default()
        };
        assert!(bad_window.validate(&c, 4).unwrap_err().to_string().contains("blacklist_window"));
        // JSON-level factor rules.
        let missing = Json::parse(r#"{"events": [{"t": 1.0, "kind": "gpu-slow", "id": 0}]}"#)
            .unwrap();
        assert!(FaultsSpec::from_json(&missing)
            .unwrap_err()
            .to_string()
            .contains("requires a 'factor'"));
        let extra =
            Json::parse(r#"{"events": [{"t": 1.0, "kind": "gpu-fail", "id": 0, "factor": 0.5}]}"#)
                .unwrap();
        assert!(FaultsSpec::from_json(&extra)
            .unwrap_err()
            .to_string()
            .contains("does not take a 'factor'"));
    }

    #[test]
    fn health_view_tracks_factors() {
        let mut h = HealthView::new(4, 2);
        assert_eq!(h.gpu_factor(0), 1.0);
        assert_eq!(h.mean_health(), 1.0);
        h.set_gpu_factor(0, 0.5);
        h.set_link_factor(1, 0.25);
        assert!(h.gpu_up(0), "a slowed GPU is still up");
        assert!(h.link_up(1), "a degraded link is still up");
        assert_eq!(h.n_gpus_up(), 4);
        assert_eq!(h.mean_health(), (0.5 + 3.0 + 1.0 + 0.25) / 6.0);
        h.set_gpu(0, false);
        assert_eq!(h.gpu_factor(0), 0.0);
        h.set_gpu(0, true);
        assert_eq!(h.gpu_factor(0), 1.0, "recovery restores full health");
    }

    #[test]
    fn health_view_tracks_state() {
        let mut h = HealthView::new(4, 2);
        assert!(h.gpu_up(3) && h.link_up(1));
        assert_eq!(h.n_gpus_up(), 4);
        h.set_gpu(3, false);
        h.set_link(1, false);
        assert!(!h.gpu_up(3));
        assert!(!h.links_up(&[0, 1]));
        assert!(h.links_up(&[0]));
        assert_eq!(h.n_gpus_up(), 3);
        h.set_gpu(3, true);
        assert_eq!(h.n_gpus_up(), 4);
    }
}
