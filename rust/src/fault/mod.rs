//! Deterministic fault injection (docs/EXPERIMENTS.md §Faults).
//!
//! A fault timeline is data, not chance: scenarios either list explicit
//! [`FaultEvent`]s or ask for an MTBF/MTTR-generated schedule, and both
//! compile — via [`FaultsSpec::compile`] — into the same flat, time-sorted
//! [`FaultPlan`] of GPU/link primitives the engine consumes as first-class
//! heap events. The generator draws from [`util::rng::Pcg`] on its own
//! stream, so a (seed, spec) pair is byte-reproducible across runs,
//! platforms and worker counts, exactly like trace generation.
//!
//! Server faults are sugar: a server failing takes down each of its GPUs
//! plus its NIC link (NIC `LinkId` == `ServerId` in every fabric preset;
//! rack uplinks survive a member server's death). Recovery reverses the
//! same expansion.
//!
//! [`HealthView`] is the engine's live up/down bitmap; placement reaches
//! it indirectly (a down GPU's free memory is held at zero so every
//! placer's `fits` test fails) and admission consults it directly, so no
//! work lands on dead capacity. The checkpoint model is coarse-grained:
//! a preempted job rewinds to its last multiple of `checkpoint_iters`
//! (0 = no checkpointing, restart from scratch) and a restart pays
//! `warmup_s` seconds of dead time on its new GPUs before iterating.

use crate::cluster::ClusterSpec;
use crate::net::LinkId;
use crate::util::error::{Error, Result};
use crate::util::json::Json;
use crate::util::rng::Pcg;

/// Dedicated RNG stream for the MTBF/MTTR generator (trace generation
/// uses 0x7ace / 0x57ea, RandomPlacer 0x91ac — distinct streams keep the
/// draws independent under a shared scenario seed).
pub const FAULT_STREAM: u64 = 0xfa17;

/// Default checkpoint interval (iterations) when a scenario enables
/// faults without choosing one.
pub const DEFAULT_CHECKPOINT_ITERS: u64 = 100;

/// A spec-level fault: what fails (or recovers) and which one.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    GpuFail(usize),
    GpuRecover(usize),
    ServerFail(usize),
    ServerRecover(usize),
    LinkFail(LinkId),
    LinkRecover(LinkId),
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::GpuFail(_) => "gpu-fail",
            FaultKind::GpuRecover(_) => "gpu-recover",
            FaultKind::ServerFail(_) => "server-fail",
            FaultKind::ServerRecover(_) => "server-recover",
            FaultKind::LinkFail(_) => "link-fail",
            FaultKind::LinkRecover(_) => "link-recover",
        }
    }

    pub fn id(&self) -> usize {
        match *self {
            FaultKind::GpuFail(x)
            | FaultKind::GpuRecover(x)
            | FaultKind::ServerFail(x)
            | FaultKind::ServerRecover(x)
            | FaultKind::LinkFail(x)
            | FaultKind::LinkRecover(x) => x,
        }
    }

    pub fn parse(kind: &str, id: usize) -> Option<FaultKind> {
        Some(match kind {
            "gpu-fail" => FaultKind::GpuFail(id),
            "gpu-recover" => FaultKind::GpuRecover(id),
            "server-fail" => FaultKind::ServerFail(id),
            "server-recover" => FaultKind::ServerRecover(id),
            "link-fail" => FaultKind::LinkFail(id),
            "link-recover" => FaultKind::LinkRecover(id),
            _ => return None,
        })
    }
}

/// One timeline entry: `kind` happens at simulated time `t`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub t: f64,
    pub kind: FaultKind,
}

impl FaultEvent {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("t", self.t)
            .set("kind", self.kind.name())
            .set("id", self.kind.id())
    }

    pub fn from_json(v: &Json) -> Result<FaultEvent> {
        if let Json::Obj(entries) = v {
            for (key, _) in entries {
                if !matches!(key.as_str(), "t" | "kind" | "id") {
                    return Err(Error::msg(format!(
                        "unknown fault event key '{key}' (t|kind|id)"
                    )));
                }
            }
        } else {
            return Err(Error::msg("fault event must be an object"));
        }
        let t = v.req_f64("t").map_err(Error::msg)?;
        let kind = v.req_str("kind").map_err(Error::msg)?;
        let id = v.req_usize("id").map_err(Error::msg)?;
        let kind = FaultKind::parse(kind, id).ok_or_else(|| {
            Error::msg(format!(
                "unknown fault kind '{kind}' \
                 (gpu-fail|gpu-recover|server-fail|server-recover|link-fail|link-recover)"
            ))
        })?;
        Ok(FaultEvent { t, kind })
    }
}

/// What the MTBF/MTTR generator aims failures at.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultTargets {
    Gpus,
    Links,
    Both,
}

impl FaultTargets {
    pub fn name(&self) -> &'static str {
        match self {
            FaultTargets::Gpus => "gpus",
            FaultTargets::Links => "links",
            FaultTargets::Both => "both",
        }
    }

    pub fn parse(s: &str) -> Option<FaultTargets> {
        Some(match s {
            "gpus" => FaultTargets::Gpus,
            "links" => FaultTargets::Links,
            "both" => FaultTargets::Both,
            _ => return None,
        })
    }
}

/// MTBF/MTTR schedule generator parameters. The failure process is
/// global: inter-failure gaps are Exp(mtbf_s) across the whole fleet,
/// each failure picks a uniform target, and each failed target recovers
/// after an independent Exp(mttr_s) — always, even past the horizon, so
/// every generated schedule ends with full capacity restored.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GenSpec {
    pub mtbf_s: f64,
    pub mttr_s: f64,
    /// No new failures are generated at or past this time.
    pub horizon_s: f64,
    pub targets: FaultTargets,
    /// `None` = derive from the scenario seed.
    pub seed: Option<u64>,
}

impl GenSpec {
    pub const DEFAULT_MTTR_S: f64 = 60.0;
    pub const DEFAULT_HORIZON_S: f64 = 1200.0;

    /// A generator spec with everything but the MTBF defaulted — what the
    /// experiment `mtbf` axis materializes on a fault-less base scenario.
    pub fn with_mtbf(mtbf_s: f64) -> GenSpec {
        GenSpec {
            mtbf_s,
            mttr_s: Self::DEFAULT_MTTR_S,
            horizon_s: Self::DEFAULT_HORIZON_S,
            targets: FaultTargets::Gpus,
            seed: None,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj()
            .set("mtbf_s", self.mtbf_s)
            .set("mttr_s", self.mttr_s)
            .set("horizon_s", self.horizon_s)
            .set("targets", self.targets.name());
        if let Some(seed) = self.seed {
            o = o.set("seed", seed);
        }
        o
    }

    pub fn from_json(v: &Json) -> Result<GenSpec> {
        if let Json::Obj(entries) = v {
            for (key, _) in entries {
                if !matches!(key.as_str(), "mtbf_s" | "mttr_s" | "horizon_s" | "targets" | "seed")
                {
                    return Err(Error::msg(format!(
                        "unknown fault generator key '{key}' \
                         (mtbf_s|mttr_s|horizon_s|targets|seed)"
                    )));
                }
            }
        } else {
            return Err(Error::msg("fault generator ('mtbf') must be an object"));
        }
        let mut g = GenSpec::with_mtbf(v.req_f64("mtbf_s").map_err(Error::msg)?);
        if let Some(x) = v.get("mttr_s") {
            g.mttr_s = x.as_f64().ok_or_else(|| Error::msg("mttr_s must be a number"))?;
        }
        if let Some(x) = v.get("horizon_s") {
            g.horizon_s = x.as_f64().ok_or_else(|| Error::msg("horizon_s must be a number"))?;
        }
        if let Some(x) = v.get("targets") {
            let s = x.as_str().ok_or_else(|| Error::msg("targets must be a string"))?;
            g.targets = FaultTargets::parse(s)
                .ok_or_else(|| Error::msg(format!("unknown targets '{s}' (gpus|links|both)")))?;
        }
        if let Some(x) = v.get("seed") {
            g.seed =
                Some(x.as_u64().ok_or_else(|| Error::msg("fault seed must be an integer"))?);
        }
        Ok(g)
    }
}

/// The scenario-level `faults` section (docs/SCENARIOS.md §Faults):
/// checkpoint/restart knobs plus an explicit timeline and/or a generator.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultsSpec {
    /// A preempted job rewinds to its last multiple of this many
    /// iterations; 0 = no checkpointing (restart from iteration 0).
    pub checkpoint_iters: u64,
    /// Dead time a restarted job pays on its new GPUs before iterating.
    pub warmup_s: f64,
    pub events: Vec<FaultEvent>,
    pub gen: Option<GenSpec>,
}

impl Default for FaultsSpec {
    fn default() -> FaultsSpec {
        FaultsSpec {
            checkpoint_iters: DEFAULT_CHECKPOINT_ITERS,
            warmup_s: 0.0,
            events: Vec::new(),
            gen: None,
        }
    }
}

impl FaultsSpec {
    /// Typed numeric-sanity + range validation, given the cluster shape
    /// and the fabric's link count ([`TopologySpec::n_links`]).
    pub fn validate(&self, cluster: &ClusterSpec, n_links: usize) -> Result<()> {
        if !self.warmup_s.is_finite() || self.warmup_s < 0.0 {
            return Err(Error::msg(format!(
                "faults.warmup_s must be finite and non-negative, got {}",
                self.warmup_s
            )));
        }
        for e in &self.events {
            if !e.t.is_finite() || e.t < 0.0 {
                return Err(Error::msg(format!(
                    "fault event time {} must be finite and non-negative",
                    e.t
                )));
            }
            let (id, max, what) = match e.kind {
                FaultKind::GpuFail(g) | FaultKind::GpuRecover(g) => (g, cluster.n_gpus(), "gpu"),
                FaultKind::ServerFail(s) | FaultKind::ServerRecover(s) => {
                    (s, cluster.n_servers, "server")
                }
                FaultKind::LinkFail(l) | FaultKind::LinkRecover(l) => (l, n_links, "link"),
            };
            if id >= max {
                return Err(Error::msg(format!(
                    "fault event targets {what} {id} but the scenario has only {max}"
                )));
            }
        }
        if let Some(g) = &self.gen {
            for (name, v) in [("mtbf_s", g.mtbf_s), ("mttr_s", g.mttr_s)] {
                if !v.is_finite() || v <= 0.0 {
                    return Err(Error::msg(format!(
                        "faults.mtbf.{name} must be finite and positive, got {v}"
                    )));
                }
            }
            if !g.horizon_s.is_finite() || g.horizon_s < 0.0 {
                return Err(Error::msg(format!(
                    "faults.mtbf.horizon_s must be finite and non-negative, got {}",
                    g.horizon_s
                )));
            }
            if g.targets != FaultTargets::Gpus && n_links == 0 {
                return Err(Error::msg(
                    "faults.mtbf targets links but the topology has no links",
                ));
            }
        }
        Ok(())
    }

    /// Expand server sugar, run the generator, and merge everything into
    /// one time-sorted primitive plan. `default_seed` (the scenario seed)
    /// feeds the generator unless the spec pins its own.
    pub fn compile(
        &self,
        cluster: &ClusterSpec,
        n_links: usize,
        default_seed: u64,
    ) -> Result<FaultPlan> {
        self.validate(cluster, n_links)?;
        let mut events: Vec<(f64, PrimFault)> = Vec::new();
        for e in &self.events {
            match e.kind {
                FaultKind::GpuFail(g) => events.push((e.t, PrimFault::GpuFail(g))),
                FaultKind::GpuRecover(g) => events.push((e.t, PrimFault::GpuRecover(g))),
                FaultKind::LinkFail(l) => events.push((e.t, PrimFault::LinkFail(l))),
                FaultKind::LinkRecover(l) => events.push((e.t, PrimFault::LinkRecover(l))),
                FaultKind::ServerFail(s) => {
                    for g in cluster.gpus_of(s) {
                        events.push((e.t, PrimFault::GpuFail(g)));
                    }
                    // NIC LinkId == ServerId in every preset; the rack
                    // uplink (two-tier) is shared and survives.
                    if s < n_links {
                        events.push((e.t, PrimFault::LinkFail(s)));
                    }
                }
                FaultKind::ServerRecover(s) => {
                    for g in cluster.gpus_of(s) {
                        events.push((e.t, PrimFault::GpuRecover(g)));
                    }
                    if s < n_links {
                        events.push((e.t, PrimFault::LinkRecover(s)));
                    }
                }
            }
        }
        if let Some(g) = &self.gen {
            generate(g, cluster.n_gpus(), n_links, default_seed, &mut events);
        }
        // Stable sort: simultaneous primitives keep spec/generator order
        // (in particular a server's GPU fails stay grouped before its NIC).
        events.sort_by(|a, b| a.0.total_cmp(&b.0));
        Ok(FaultPlan {
            events,
            checkpoint_iters: self.checkpoint_iters,
            warmup_s: self.warmup_s,
        })
    }

    // ---- serialization (defaults elided; docs/SCENARIOS.md) ----------------

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        if self.checkpoint_iters != DEFAULT_CHECKPOINT_ITERS {
            o = o.set("checkpoint_iters", self.checkpoint_iters);
        }
        if self.warmup_s != 0.0 {
            o = o.set("warmup_s", self.warmup_s);
        }
        if !self.events.is_empty() {
            o = o.set(
                "events",
                Json::Arr(self.events.iter().map(FaultEvent::to_json).collect()),
            );
        }
        if let Some(g) = &self.gen {
            o = o.set("mtbf", g.to_json());
        }
        o
    }

    pub fn from_json(v: &Json) -> Result<FaultsSpec> {
        if let Json::Obj(entries) = v {
            for (key, _) in entries {
                if !matches!(key.as_str(), "checkpoint_iters" | "warmup_s" | "events" | "mtbf") {
                    return Err(Error::msg(format!(
                        "unknown faults key '{key}' (checkpoint_iters|warmup_s|events|mtbf)"
                    )));
                }
            }
        } else {
            return Err(Error::msg("'faults' must be an object"));
        }
        let mut spec = FaultsSpec::default();
        if let Some(x) = v.get("checkpoint_iters") {
            spec.checkpoint_iters = x
                .as_u64()
                .ok_or_else(|| Error::msg("checkpoint_iters must be a non-negative integer"))?;
        }
        if let Some(x) = v.get("warmup_s") {
            spec.warmup_s = x.as_f64().ok_or_else(|| Error::msg("warmup_s must be a number"))?;
        }
        if let Some(x) = v.get("events") {
            let arr = x.as_arr().ok_or_else(|| Error::msg("faults.events must be an array"))?;
            spec.events = arr.iter().map(FaultEvent::from_json).collect::<Result<_>>()?;
        }
        if let Some(x) = v.get("mtbf") {
            spec.gen = Some(GenSpec::from_json(x)?);
        }
        Ok(spec)
    }
}

/// Exp(mean) draw. `next_f64` is in [0, 1), so `1 - u` is in (0, 1] and
/// the result is finite and non-negative.
fn exp_draw(rng: &mut Pcg, mean: f64) -> f64 {
    -mean * (1.0 - rng.next_f64()).ln()
}

/// The MTBF/MTTR process (see [`GenSpec`]): appends (time, primitive)
/// pairs. A failure aimed at a target that is still down is skipped —
/// the global clock still advanced, matching a fleet whose failed unit
/// cannot fail again until repaired.
fn generate(
    spec: &GenSpec,
    n_gpus: usize,
    n_links: usize,
    default_seed: u64,
    out: &mut Vec<(f64, PrimFault)>,
) {
    let n_targets = match spec.targets {
        FaultTargets::Gpus => n_gpus,
        FaultTargets::Links => n_links,
        FaultTargets::Both => n_gpus + n_links,
    };
    if n_targets == 0 {
        return;
    }
    let mut rng = Pcg::new(spec.seed.unwrap_or(default_seed), FAULT_STREAM);
    let mut down_until = vec![0.0f64; n_targets];
    let mut t = 0.0f64;
    loop {
        t += exp_draw(&mut rng, spec.mtbf_s);
        if t >= spec.horizon_s {
            break;
        }
        let target = rng.next_below(n_targets as u64) as usize;
        if t < down_until[target] {
            continue; // still being repaired; cannot fail again
        }
        let recover_at = t + exp_draw(&mut rng, spec.mttr_s);
        down_until[target] = recover_at;
        let gpu_target = match spec.targets {
            FaultTargets::Gpus => true,
            FaultTargets::Links => false,
            FaultTargets::Both => target < n_gpus,
        };
        if gpu_target {
            out.push((t, PrimFault::GpuFail(target)));
            out.push((recover_at, PrimFault::GpuRecover(target)));
        } else {
            let link = if spec.targets == FaultTargets::Both { target - n_gpus } else { target };
            out.push((t, PrimFault::LinkFail(link)));
            out.push((recover_at, PrimFault::LinkRecover(link)));
        }
    }
}

/// A compiled, engine-level fault primitive: GPUs and links only (server
/// sugar already expanded).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PrimFault {
    GpuFail(usize),
    GpuRecover(usize),
    LinkFail(LinkId),
    LinkRecover(LinkId),
}

/// The engine's fault input: a time-sorted primitive timeline plus the
/// checkpoint/restart knobs. `Default` is the empty plan, under which the
/// engine is bit-identical to a fault-less build (no heap pushes, no
/// extra float ops, no RNG draws — see sim/engine.rs).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<(f64, PrimFault)>,
    pub checkpoint_iters: u64,
    pub warmup_s: f64,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan {
            events: Vec::new(),
            checkpoint_iters: DEFAULT_CHECKPOINT_ITERS,
            warmup_s: 0.0,
        }
    }
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Live hardware up/down bitmap, driven by the engine as it processes the
/// fault timeline. Admission reads it directly; placement reads it
/// indirectly through the zero-free-memory hold on down GPUs.
#[derive(Clone, Debug)]
pub struct HealthView {
    gpu: Vec<bool>,
    link: Vec<bool>,
}

impl HealthView {
    pub fn new(n_gpus: usize, n_links: usize) -> HealthView {
        HealthView { gpu: vec![true; n_gpus], link: vec![true; n_links] }
    }

    pub fn gpu_up(&self, g: usize) -> bool {
        self.gpu[g]
    }

    pub fn link_up(&self, l: LinkId) -> bool {
        self.link[l]
    }

    pub fn links_up(&self, links: &[LinkId]) -> bool {
        links.iter().all(|&l| self.link[l])
    }

    pub fn set_gpu(&mut self, g: usize, up: bool) {
        self.gpu[g] = up;
    }

    pub fn set_link(&mut self, l: LinkId, up: bool) {
        self.link[l] = up;
    }

    pub fn n_gpus_up(&self) -> usize {
        self.gpu.iter().filter(|&&u| u).count()
    }

    pub fn n_links_up(&self) -> usize {
        self.link.iter().filter(|&&u| u).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster() -> ClusterSpec {
        ClusterSpec::tiny(4, 2)
    }

    #[test]
    fn generator_is_deterministic_and_paired() {
        let spec = GenSpec { seed: Some(7), ..GenSpec::with_mtbf(100.0) };
        let faults = FaultsSpec { gen: Some(spec), ..FaultsSpec::default() };
        let a = faults.compile(&cluster(), 4, 42).unwrap();
        let b = faults.compile(&cluster(), 4, 42).unwrap();
        assert_eq!(a, b, "same (seed, spec) must be byte-reproducible");
        assert!(!a.is_empty(), "mtbf 100s over a 1200s horizon produced nothing");
        // Every failure has exactly one recovery, even past the horizon.
        let mut balance = std::collections::BTreeMap::new();
        for &(t, p) in &a.events {
            assert!(t.is_finite() && t >= 0.0);
            match p {
                PrimFault::GpuFail(g) => *balance.entry(g).or_insert(0i64) += 1,
                PrimFault::GpuRecover(g) => *balance.entry(g).or_insert(0i64) -= 1,
                other => panic!("gpus-only generator emitted {other:?}"),
            }
        }
        assert!(balance.values().all(|&v| v == 0), "unbalanced fail/recover: {balance:?}");
        // Sorted by time.
        assert!(a.events.windows(2).all(|w| w[0].0 <= w[1].0));
    }

    #[test]
    fn generator_seed_changes_schedule() {
        let mk = |seed| {
            let spec = GenSpec { seed: Some(seed), ..GenSpec::with_mtbf(100.0) };
            FaultsSpec { gen: Some(spec), ..FaultsSpec::default() }
                .compile(&cluster(), 4, 42)
                .unwrap()
        };
        assert_ne!(mk(1), mk(2));
        // And with seed: None, the scenario seed feeds the stream.
        let spec = GenSpec { seed: None, ..GenSpec::with_mtbf(100.0) };
        let faults = FaultsSpec { gen: Some(spec), ..FaultsSpec::default() };
        assert_ne!(
            faults.compile(&cluster(), 4, 1).unwrap(),
            faults.compile(&cluster(), 4, 2).unwrap()
        );
    }

    #[test]
    fn server_fault_expands_to_gpus_and_nic() {
        let faults = FaultsSpec {
            events: vec![
                FaultEvent { t: 10.0, kind: FaultKind::ServerFail(1) },
                FaultEvent { t: 20.0, kind: FaultKind::ServerRecover(1) },
            ],
            ..FaultsSpec::default()
        };
        let plan = faults.compile(&cluster(), 4, 42).unwrap();
        // Server 1 of a 4x2 cluster = GPUs {2, 3} + NIC link 1.
        assert_eq!(
            plan.events,
            vec![
                (10.0, PrimFault::GpuFail(2)),
                (10.0, PrimFault::GpuFail(3)),
                (10.0, PrimFault::LinkFail(1)),
                (20.0, PrimFault::GpuRecover(2)),
                (20.0, PrimFault::GpuRecover(3)),
                (20.0, PrimFault::LinkRecover(1)),
            ]
        );
    }

    #[test]
    fn json_roundtrip_and_elision() {
        let spec = FaultsSpec {
            checkpoint_iters: 25,
            warmup_s: 5.0,
            events: vec![
                FaultEvent { t: 100.0, kind: FaultKind::GpuFail(3) },
                FaultEvent { t: 160.0, kind: FaultKind::GpuRecover(3) },
            ],
            gen: Some(GenSpec {
                mtbf_s: 600.0,
                mttr_s: 90.0,
                horizon_s: 2000.0,
                targets: FaultTargets::Both,
                seed: Some(9),
            }),
        };
        let back = FaultsSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, back);
        // Defaults serialize to an empty object and parse back.
        let dflt = FaultsSpec::default();
        let text = dflt.to_json().to_string();
        assert_eq!(text, "{}", "defaults must be elided, got {text}");
        assert_eq!(FaultsSpec::from_json(&dflt.to_json()).unwrap(), dflt);
    }

    #[test]
    fn validation_rejects_bad_input() {
        let c = cluster();
        let bad_id = FaultsSpec {
            events: vec![FaultEvent { t: 1.0, kind: FaultKind::GpuFail(99) }],
            ..FaultsSpec::default()
        };
        assert!(bad_id.validate(&c, 4).unwrap_err().to_string().contains("gpu 99"));
        let bad_t = FaultsSpec {
            events: vec![FaultEvent { t: f64::NAN, kind: FaultKind::GpuFail(0) }],
            ..FaultsSpec::default()
        };
        assert!(bad_t.validate(&c, 4).is_err());
        let bad_mtbf = FaultsSpec {
            gen: Some(GenSpec::with_mtbf(-1.0)),
            ..FaultsSpec::default()
        };
        assert!(bad_mtbf.validate(&c, 4).unwrap_err().to_string().contains("mtbf_s"));
        let bad_warm = FaultsSpec { warmup_s: f64::INFINITY, ..FaultsSpec::default() };
        assert!(bad_warm.validate(&c, 4).unwrap_err().to_string().contains("warmup_s"));
        let bad_kind = Json::parse(r#"{"events": [{"t": 1.0, "kind": "meteor", "id": 0}]}"#)
            .unwrap();
        assert!(FaultsSpec::from_json(&bad_kind)
            .unwrap_err()
            .to_string()
            .contains("unknown fault kind"));
        let bad_key = Json::parse(r#"{"mtbf_hours": 1}"#).unwrap();
        assert!(FaultsSpec::from_json(&bad_key)
            .unwrap_err()
            .to_string()
            .contains("unknown faults key"));
    }

    #[test]
    fn health_view_tracks_state() {
        let mut h = HealthView::new(4, 2);
        assert!(h.gpu_up(3) && h.link_up(1));
        assert_eq!(h.n_gpus_up(), 4);
        h.set_gpu(3, false);
        h.set_link(1, false);
        assert!(!h.gpu_up(3));
        assert!(!h.links_up(&[0, 1]));
        assert!(h.links_up(&[0]));
        assert_eq!(h.n_gpus_up(), 3);
        h.set_gpu(3, true);
        assert_eq!(h.n_gpus_up(), 4);
    }
}
