//! # ddl-sched — communication-contention-aware DDL job scheduling
//!
//! Full reproduction of *"Communication Contention Aware Scheduling of
//! Multiple Deep Learning Training Jobs"* (Wang, Shi, Wang, Chu — CS.DC
//! 2020) as a three-layer rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: the DAG job
//!   model ([`dag`]), the Eq (5) contention network model ([`model`]),
//!   the link-level fabric topology ([`net`]: flat / two-tier
//!   oversubscribed / heterogeneous presets), LWF-κ and rack-locality
//!   placement ([`placement`]), AdaDUAL/Ada-SRSF communication
//!   scheduling ([`sched`]), the streaming job-source layer ([`source`]:
//!   materialized, synthetic and CSV trace streams with unknown horizon),
//!   the event-driven cluster simulator ([`sim`]),
//!   the evaluation metrics ([`metrics`]) and the declarative
//!   scenario/experiment API ([`scenario`]). A live multi-job training
//!   coordinator ([`coordinator`]) drives real AOT-compiled training jobs
//!   through the same placement + admission logic.
//! * **Layer 2/1 (python/, build-time only)** — a transformer training
//!   workload in JAX whose hot-spots are Pallas kernels, AOT-lowered to
//!   HLO text artifacts executed by [`runtime`] via the PJRT CPU client
//!   (gated behind the `pjrt` cargo feature).
//!
//! Quickstart — a [`scenario::Scenario`] names everything one run needs
//! and serializes to JSON, so evaluation setups are shareable data files:
//! ```no_run
//! use ddl_sched::prelude::*;
//!
//! // One run: the paper's LWF-1 + Ada-SRSF setup on the 160-job workload.
//! let record = Scenario::paper().run().unwrap();
//! println!("avg JCT: {:.1}s", record.eval.jct.mean);
//!
//! // A grid: placers x policies (Tables IV-V), executed on 8 threads.
//! let records = Experiment::paper_grid(Scenario::paper()).run(8).unwrap();
//! println!("{}", scenario::records_to_csv(&records));
//! ```
//! The same artifacts drive the CLI: `ddl-sched scenario-gen --grid --out
//! grid.json && ddl-sched sweep --scenario grid.json --threads 8`. See
//! docs/SCENARIOS.md for the JSON schema, and [`sim::simulate`] for the
//! low-level engine entry point that scenarios compile down to.

pub mod cluster;
pub mod coordinator;
pub mod dag;
pub mod env;
pub mod fault;
pub mod metrics;
pub mod model;
pub mod net;
pub mod placement;
pub mod runtime;
pub mod scenario;
pub mod sched;
pub mod sim;
pub mod source;
pub mod trace;
pub mod util;

/// Convenient glob imports for examples and benches.
pub mod prelude {
    pub use crate::cluster::{ClusterSpec, ClusterState};
    pub use crate::env::{
        self, BacklogReward, BuiltinAgent, EnvAgent, Obs, RandomAgent, RewardHook, SimEnv,
    };
    pub use crate::fault::{
        self, DegradeSpec, FaultEvent, FaultKind, FaultPlan, FaultTargets, FaultsSpec, GenSpec,
        HealthView,
    };
    pub use crate::metrics::{self, Evaluation};
    pub use crate::model::{self, AllReduceAlgo, CommModel, DnnModel, PerfModel};
    pub use crate::net::{self, LinkId, Topology, TopologySpec};
    pub use crate::placement::{
        self, FirstFitPlacer, HealthAwarePlacer, ListSchedulingPlacer, LwfPlacer, Placer,
        RackLwfPlacer, RandomPlacer,
    };
    pub use crate::scenario::{
        self, records_to_csv, records_to_json, registry, Experiment, OutputSpec, RunRecord,
        Scenario, TraceSource,
    };
    pub use crate::sched::{
        self,
        health::{backoff_delay, Blacklist, HealthScore},
        AdaDual, Admission, CommPolicy, SrsfCap,
    };
    pub use crate::sim::{
        self, Action, ContentionProfiler, DecisionPoint, JobPriority, JsonlSink, LegacyLog,
        MetricsObserver, PercentilesObserver, Repricing, SimConfig, SimEvent, SimObserver,
        SimResult, SimState, Step, StreamStats, TimelineObserver,
    };
    pub use crate::source::{
        self, CsvTraceSource, GeneratedSource, JobSource, VecSource,
    };
    pub use crate::trace::{self, JobSpec, JobStream, TraceConfig};
    pub use crate::util::bench::{bench, write_csv, Table};
}
