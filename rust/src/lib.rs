//! # ddl-sched — communication-contention-aware DDL job scheduling
//!
//! Full reproduction of *"Communication Contention Aware Scheduling of
//! Multiple Deep Learning Training Jobs"* (Wang, Shi, Wang, Chu — CS.DC
//! 2020) as a three-layer rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: the DAG job
//!   model ([`dag`]), the Eq (5) contention network model ([`model`]),
//!   LWF-κ placement ([`placement`]), AdaDUAL/Ada-SRSF communication
//!   scheduling ([`sched`]), the event-driven cluster simulator ([`sim`])
//!   and the evaluation metrics ([`metrics`]). A live multi-job training
//!   coordinator ([`coordinator`]) drives real AOT-compiled training jobs
//!   through the same placement + admission logic.
//! * **Layer 2/1 (python/, build-time only)** — a transformer training
//!   workload in JAX whose hot-spots are Pallas kernels, AOT-lowered to
//!   HLO text artifacts executed by [`runtime`] via the PJRT CPU client.
//!
//! Quickstart:
//! ```no_run
//! use ddl_sched::prelude::*;
//!
//! let jobs = trace::generate(&trace::TraceConfig::paper_160());
//! let cfg = sim::SimConfig::paper();
//! let mut placer = placement::LwfPlacer::new(1);
//! let policy = sched::AdaDual { model: cfg.comm };
//! let result = sim::simulate(&cfg, &jobs, &mut placer, &policy);
//! println!("avg JCT: {:.1}s", metrics::Evaluation::from_sim("Ada-SRSF", &result).jct.mean);
//! ```

pub mod cluster;
pub mod coordinator;
pub mod dag;
pub mod metrics;
pub mod model;
pub mod placement;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod trace;
pub mod util;

/// Convenient glob imports for examples and benches.
pub mod prelude {
    pub use crate::cluster::{ClusterSpec, ClusterState};
    pub use crate::metrics::{self, Evaluation};
    pub use crate::model::{self, AllReduceAlgo, CommModel, DnnModel, PerfModel};
    pub use crate::placement::{
        self, FirstFitPlacer, ListSchedulingPlacer, LwfPlacer, Placer, RandomPlacer,
    };
    pub use crate::sched::{self, AdaDual, Admission, CommPolicy, SrsfCap};
    pub use crate::sim::{self, SimConfig, SimResult};
    pub use crate::trace::{self, JobSpec, TraceConfig};
    pub use crate::util::bench::{bench, write_csv, Table};
}
