//! Gym-style environment over the resumable simulation engine
//! ([`SimState`]): `reset` builds the episode and runs to the first
//! decision point, `step` applies an external [`Action`] and runs to the
//! next one, returning `(Obs, reward, done)`. The observation is
//! featurized from the engine's live incremental indexes — per-link
//! occupancy (the slab behind `sched::NetView`), queue depth
//! (`sched::JobQueue`), the free-GPU histogram (`cluster::FreeGpuIndex`)
//! and hardware health (`fault::HealthView`) — so capturing it is O(links
//! + thresholds), never a cluster scan.
//!
//! Determinism contract (docs/EXPERIMENTS.md §SimEnv): the engine holds
//! *no* internal RNG — every random draw belongs to an agent — so an
//! episode is a pure function of `(SimConfig, jobs, action sequence)`.
//! [`SimEnv::save`] / [`SimEnv::restore`] checkpoint mid-episode; pair
//! them with [`RandomAgent::save`] ([`util::rng::PcgState`]) to resume a
//! stochastic rollout bit-for-bit. A [`BuiltinAgent`] answers decisions
//! through [`SimState::decide_builtin`] — the same code path the
//! [`sim::simulate`] facades use — so env-driven builtin runs are
//! bit-identical to the monolithic engine (property-tested in
//! `sim::tests`).
//!
//! [`util::rng::PcgState`]: crate::util::rng::PcgState
//! [`sim::simulate`]: crate::sim::simulate

use crate::bail;
use crate::cluster::GpuId;
use crate::net::LinkId;
use crate::placement::Placer;
use crate::sched::{Admission, CommPolicy};
use crate::sim::{Action, DecisionPoint, SimConfig, SimObserver, SimState};
use crate::trace::JobSpec;
use crate::util::error::Result;
use crate::util::json::Json;
use crate::util::rng::{Pcg, PcgState};

/// Featurized snapshot of the paused engine, captured at every `reset` /
/// `step` boundary. All fields read live incremental indexes; none
/// require walking jobs or GPUs (the `free_gpus` histogram has one row
/// per *distinct memory demand*, not per GPU).
#[derive(Clone, Debug, PartialEq)]
pub struct Obs {
    /// Simulation clock at the pause (last processed event's timestamp).
    pub t: f64,
    /// The episode ran to completion (no `decision` present).
    pub done: bool,
    /// The pending decision, if the engine paused at one.
    pub decision: Option<DecisionObs>,
    /// Jobs waiting for placement.
    pub queue_depth: usize,
    /// Jobs with a ready-but-unadmitted All-Reduce.
    pub pending_comms: usize,
    /// Arrivals processed so far.
    pub arrived: u64,
    /// Jobs finished so far.
    pub finished: u64,
    /// Jobs arrived and not yet finished (the backlog).
    pub in_system: u64,
    /// GPUs currently up (fault timeline).
    pub gpus_up: usize,
    /// Links currently up (fault timeline).
    pub links_up: usize,
    /// Mean device health across all GPUs and links: 1.0 on a fully
    /// healthy fleet, dropping with every gray-degraded factor and every
    /// hard-down device (which counts as 0.0).
    pub mean_health: f64,
    /// Active transfers crossing each fabric link, indexed by `LinkId`.
    pub link_occupancy: Vec<usize>,
    /// `(mem_bytes, count)` rows of the live free-GPU capacity index.
    pub free_gpus: Vec<(f64, usize)>,
}

/// The decision point's own features (who needs what, where).
#[derive(Clone, Debug, PartialEq)]
pub struct DecisionObs {
    /// `"place"`, `"admit"` or `"ff-probe"` (see [`DecisionPoint`]).
    pub kind: &'static str,
    /// The job the decision concerns.
    pub job: usize,
    /// GPUs the job needs.
    pub n_gpus: usize,
    /// Per-GPU memory demand (bytes).
    pub mem_bytes: f64,
    /// All-Reduce message size (bytes).
    pub msg_bytes: f64,
    /// Iterations the job still has to run.
    pub iters_left: u64,
    /// Fabric links its All-Reduce crosses (empty before placement).
    pub links: Vec<LinkId>,
}

impl DecisionObs {
    fn capture(state: &SimState, d: &DecisionPoint) -> DecisionObs {
        let job = d.job();
        let spec = state.job_spec(job);
        DecisionObs {
            kind: d.kind(),
            job,
            n_gpus: spec.n_gpus,
            mem_bytes: spec.mem_bytes(),
            msg_bytes: spec.message_bytes(),
            iters_left: state.iters_left(job),
            links: state.job_links(job).to_vec(),
        }
    }

    fn to_json(&self) -> Json {
        Json::obj()
            .set("kind", self.kind)
            .set("job", self.job)
            .set("n_gpus", self.n_gpus)
            .set("mem_bytes", self.mem_bytes)
            .set("msg_bytes", self.msg_bytes)
            .set("iters_left", self.iters_left)
            .set("links", Json::Arr(self.links.iter().map(|&l| Json::from(l)).collect()))
    }
}

impl Obs {
    /// Featurize `state` as of its current pause point.
    pub fn capture(state: &SimState) -> Obs {
        Obs {
            t: state.now(),
            done: state.is_done(),
            decision: state.pending().map(|d| DecisionObs::capture(state, &d)),
            queue_depth: state.queue_depth(),
            pending_comms: state.pending_comms(),
            arrived: state.arrived_jobs(),
            finished: state.finished_jobs(),
            in_system: state.jobs_in_system(),
            gpus_up: state.gpus_up(),
            links_up: state.links_up(),
            mean_health: state.mean_health(),
            link_occupancy: (0..state.n_links()).map(|l| state.link_occupancy(l)).collect(),
            free_gpus: state.free_gpu_histogram(),
        }
    }

    /// The observation as one JSON object (the `rollout` step-log schema;
    /// docs/SCENARIOS.md §Rollout).
    pub fn to_json(&self) -> Json {
        let occ = self.link_occupancy.iter().map(|&c| Json::from(c)).collect();
        let free = self
            .free_gpus
            .iter()
            .map(|&(mem, n)| Json::obj().set("mem_bytes", mem).set("count", n))
            .collect();
        let decision = match &self.decision {
            Some(d) => d.to_json(),
            None => Json::Null,
        };
        Json::obj()
            .set("t", self.t)
            .set("done", self.done)
            .set("decision", decision)
            .set("queue_depth", self.queue_depth)
            .set("pending_comms", self.pending_comms)
            .set("arrived", self.arrived)
            .set("finished", self.finished)
            .set("in_system", self.in_system)
            .set("gpus_up", self.gpus_up)
            .set("links_up", self.links_up)
            .set("mean_health", self.mean_health)
            .set("link_occupancy", Json::Arr(occ))
            .set("free_gpus", Json::Arr(free))
    }
}

/// Per-step reward, computed after the engine advanced from the previous
/// pause (`prev_t`) to the current one. Stateful hooks are allowed (e.g.
/// potential-based shaping).
pub trait RewardHook {
    fn reward(&mut self, prev_t: f64, state: &SimState) -> f64;
}

/// Default reward: negative backlog integral, `-(Δt · jobs_in_system)`.
/// Summed over an episode this is `-Σ_k JCT_k` up to the arrival-time
/// constant, so return-maximization is mean-JCT minimization — the
/// paper's objective.
pub struct BacklogReward;

impl RewardHook for BacklogReward {
    fn reward(&mut self, prev_t: f64, state: &SimState) -> f64 {
        -(state.now() - prev_t) * state.jobs_in_system() as f64
    }
}

/// A decision-making agent driving a [`SimEnv`] (see
/// [`SimEnv::run_agent`]).
pub trait EnvAgent {
    fn act(&mut self, state: &SimState, d: &DecisionPoint, obs: &Obs) -> Action;
}

/// The builtin placer/policy pair as a trivial agent: every decision goes
/// through [`SimState::decide_builtin`], the exact code path the
/// monolithic facades use — which is what pins env-driven runs
/// bit-identical to [`simulate_observed`](crate::sim::simulate_observed).
pub struct BuiltinAgent {
    placer: Box<dyn Placer>,
    policy: Box<dyn CommPolicy>,
}

impl BuiltinAgent {
    pub fn new(placer: Box<dyn Placer>, policy: Box<dyn CommPolicy>) -> BuiltinAgent {
        BuiltinAgent { placer, policy }
    }
}

impl EnvAgent for BuiltinAgent {
    fn act(&mut self, state: &SimState, d: &DecisionPoint, _obs: &Obs) -> Action {
        state.decide_builtin(d, self.placer.as_mut(), self.policy.as_ref())
    }
}

/// Uniform-random baseline agent: placements draw a uniformly random
/// feasible GPU set (declining only when too few GPUs fit, per the
/// placer contract), admissions and coalescing probes flip a fair coin.
/// Deterministic per seed; [`RandomAgent::save`] snapshots the generator
/// so a checkpointed rollout resumes bit-for-bit.
pub struct RandomAgent {
    rng: Pcg,
}

impl RandomAgent {
    pub fn new(seed: u64) -> RandomAgent {
        RandomAgent { rng: Pcg::seed(seed) }
    }

    /// Snapshot the agent's RNG (pair with [`SimEnv::save`]).
    pub fn save(&self) -> PcgState {
        self.rng.save()
    }

    /// Rebuild an agent mid-stream from a [`RandomAgent::save`] snapshot.
    pub fn restore(snap: &PcgState) -> RandomAgent {
        RandomAgent { rng: Pcg::restore(snap) }
    }
}

impl EnvAgent for RandomAgent {
    fn act(&mut self, state: &SimState, d: &DecisionPoint, _obs: &Obs) -> Action {
        match d {
            DecisionPoint::Place { job, .. } => {
                let spec = state.job_spec(*job);
                let mem = spec.mem_bytes();
                let cluster = state.cluster();
                let mut feasible: Vec<GpuId> =
                    (0..cluster.gpus.len()).filter(|&g| cluster.fits(g, mem)).collect();
                if feasible.len() < spec.n_gpus {
                    Action::Place(None)
                } else {
                    self.rng.shuffle(&mut feasible);
                    feasible.truncate(spec.n_gpus);
                    Action::Place(Some(feasible))
                }
            }
            DecisionPoint::Admit { .. } | DecisionPoint::FfProbe { .. } => {
                let a = if self.rng.chance(0.5) { Admission::Start } else { Admission::Wait };
                Action::Admit(a)
            }
        }
    }
}

/// Mid-episode checkpoint of a [`SimEnv`] ([`SimEnv::save`]). Contains
/// the full deterministic engine state plus the episode accounting; an
/// agent's own state (e.g. [`RandomAgent::save`]) is snapshotted
/// separately, since agents live outside the env.
#[derive(Clone)]
pub struct EnvSnapshot {
    state: SimState,
    steps: u64,
    prev_t: f64,
    episode_return: f64,
}

/// The gym-style environment: a [`SimState`] episode plus step/reward
/// accounting. Observers are passed to each call (never stored), so the
/// env itself stays `save`/`restore`-able.
pub struct SimEnv {
    cfg: SimConfig,
    jobs: Vec<JobSpec>,
    state: SimState,
    reward: Box<dyn RewardHook>,
    started: bool,
    steps: u64,
    prev_t: f64,
    episode_return: f64,
}

impl SimEnv {
    /// Build an env over `jobs` with the default [`BacklogReward`]. Call
    /// [`SimEnv::reset`] before stepping.
    pub fn new(cfg: &SimConfig, jobs: &[JobSpec]) -> SimEnv {
        SimEnv::with_reward(cfg, jobs, Box::new(BacklogReward))
    }

    /// Build an env with a custom per-step [`RewardHook`].
    pub fn with_reward(cfg: &SimConfig, jobs: &[JobSpec], reward: Box<dyn RewardHook>) -> SimEnv {
        SimEnv {
            cfg: cfg.clone(),
            jobs: jobs.to_vec(),
            state: SimState::new(cfg, jobs),
            reward,
            started: false,
            steps: 0,
            prev_t: 0.0,
            episode_return: 0.0,
        }
    }

    /// Start a fresh episode: notify observers (`on_start`, mirroring the
    /// monolithic facades), rebuild the engine state and run to the first
    /// decision point (or completion, for a degenerate workload).
    pub fn reset(&mut self, obs: &mut [&mut dyn SimObserver]) -> Result<Obs> {
        for o in obs.iter_mut() {
            o.on_start(&self.cfg, &self.jobs);
        }
        self.state = SimState::new(&self.cfg, &self.jobs);
        self.started = true;
        self.steps = 0;
        self.episode_return = 0.0;
        self.state.advance(obs, None)?;
        self.prev_t = self.state.now();
        Ok(Obs::capture(&self.state))
    }

    /// Apply `action` to the pending decision and run to the next one.
    /// Returns `(observation, reward, done)`. An invalid action (wrong
    /// kind, or a malformed placement) errors *without* consuming the
    /// decision — the episode is intact and the step can be retried.
    pub fn step(
        &mut self,
        action: Action,
        obs: &mut [&mut dyn SimObserver],
    ) -> Result<(Obs, f64, bool)> {
        if !self.started {
            bail!("SimEnv::step called before reset");
        }
        if self.state.is_done() {
            bail!("SimEnv::step called on a finished episode; call reset");
        }
        self.state.resolve(action, obs)?;
        self.state.advance(obs, None)?;
        self.steps += 1;
        let r = self.reward.reward(self.prev_t, &self.state);
        self.prev_t = self.state.now();
        self.episode_return += r;
        Ok((Obs::capture(&self.state), r, self.state.is_done()))
    }

    /// Drive the episode with `agent` from reset, for at most `max_steps`
    /// decisions (`None` = to completion). Returns the steps taken.
    pub fn run_agent(
        &mut self,
        agent: &mut dyn EnvAgent,
        max_steps: Option<u64>,
        obs: &mut [&mut dyn SimObserver],
    ) -> Result<u64> {
        let mut o = self.reset(obs)?;
        let mut n = 0u64;
        loop {
            if o.done {
                break;
            }
            if let Some(cap) = max_steps {
                if n >= cap {
                    break;
                }
            }
            let d = self.state.pending().expect("an unfinished episode pauses at a decision");
            let action = agent.act(&self.state, &d, &o);
            o = self.step(action, obs)?.0;
            n += 1;
        }
        Ok(n)
    }

    /// The current observation (what the last `reset`/`step` returned).
    pub fn observe(&self) -> Obs {
        Obs::capture(&self.state)
    }

    /// The underlying engine state (read-only; agents get it via `act`).
    pub fn state(&self) -> &SimState {
        &self.state
    }

    /// Decisions resolved since the last reset.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Sum of step rewards since the last reset.
    pub fn episode_return(&self) -> f64 {
        self.episode_return
    }

    /// Checkpoint the episode mid-run (engine state + step/reward
    /// accounting). Observers and agents are external; snapshot agent
    /// state separately (e.g. [`RandomAgent::save`]).
    pub fn save(&self) -> EnvSnapshot {
        EnvSnapshot {
            state: self.state.save(),
            steps: self.steps,
            prev_t: self.prev_t,
            episode_return: self.episode_return,
        }
    }

    /// Rewind to a [`SimEnv::save`] checkpoint. The resumed episode
    /// replays bit-for-bit given the same action sequence.
    pub fn restore(&mut self, snap: &EnvSnapshot) {
        self.state.restore(&snap.state);
        self.steps = snap.steps;
        self.prev_t = snap.prev_t;
        self.episode_return = snap.episode_return;
        self.started = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::fault::FaultPlan;
    use crate::model::{CommModel, DnnModel};
    use crate::net::TopologySpec;
    use crate::placement::LwfPlacer;
    use crate::sched::AdaDual;
    use crate::sim::{JobPriority, Repricing, Step};

    fn cfg(n_servers: usize, gpus_per_server: usize) -> SimConfig {
        SimConfig {
            cluster: ClusterSpec::tiny(n_servers, gpus_per_server),
            comm: CommModel::paper_10gbe(),
            topology: TopologySpec::Flat,
            repricing: Repricing::AtAdmission,
            priority: JobPriority::Srsf,
            coalescing: true,
            log_events: false,
            workers: 1,
            faults: FaultPlan::default(),
        }
    }

    fn jobs(n: usize) -> Vec<JobSpec> {
        (0..n)
            .map(|i| JobSpec {
                id: i,
                arrival: i as f64 * 5.0,
                model: DnnModel::ResNet50,
                n_gpus: 1 + (i % 3),
                iterations: 30 + 10 * (i as u64 % 4),
            })
            .collect()
    }

    fn no_obs() -> [&'static mut dyn SimObserver; 0] {
        []
    }

    #[test]
    fn builtin_agent_runs_episode_to_completion() {
        let c = cfg(2, 4);
        let js = jobs(6);
        let mut env = SimEnv::new(&c, &js);
        let mut agent = BuiltinAgent::new(
            Box::new(LwfPlacer::new(1)),
            Box::new(AdaDual { model: c.comm }),
        );
        let n = env.run_agent(&mut agent, None, &mut no_obs()).unwrap();
        assert!(n > 0, "no decisions surfaced");
        assert!(env.observe().done);
        assert_eq!(env.state().finished_jobs(), js.len() as u64);
        // Backlog reward: strictly negative once any time passes.
        assert!(env.episode_return() < 0.0, "return {}", env.episode_return());
    }

    #[test]
    fn random_agent_is_deterministic_per_seed() {
        let c = cfg(2, 2);
        let js = jobs(5);
        let run = |seed: u64| {
            let mut env = SimEnv::new(&c, &js);
            let mut agent = RandomAgent::new(seed);
            let n = env.run_agent(&mut agent, None, &mut no_obs()).unwrap();
            (n, env.observe().t.to_bits(), env.episode_return().to_bits())
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8), "different seeds produced identical episodes");
    }

    #[test]
    fn step_rejects_wrong_action_kind_without_consuming() {
        let c = cfg(1, 2);
        let js = jobs(2);
        let mut env = SimEnv::new(&c, &js);
        let first = env.reset(&mut no_obs()).unwrap();
        let d = first.decision.expect("two queued jobs must surface a placement");
        assert_eq!(d.kind, "place");
        // A placement decision rejects an admission action...
        let err = env.step(Action::Admit(Admission::Start), &mut no_obs());
        assert!(err.is_err());
        // ...and the decision survives for a retry.
        let again = env.observe().decision.expect("decision consumed by invalid action");
        assert_eq!(again, d);
        let bad = Action::Place(Some(vec![0, 0]));
        assert!(env.step(bad, &mut no_obs()).is_err(), "duplicate GPUs accepted");
        assert!(env.step(Action::Place(None), &mut no_obs()).is_ok());
    }

    #[test]
    fn observation_reads_live_indexes() {
        let c = cfg(2, 2);
        let js = jobs(4);
        let mut env = SimEnv::new(&c, &js);
        let o = env.reset(&mut no_obs()).unwrap();
        assert!(!o.done);
        assert_eq!(o.arrived, 1, "first decision pauses at the first arrival");
        assert_eq!(o.gpus_up, 4);
        assert_eq!(o.mean_health, 1.0, "healthy fleet observes mean health 1.0");
        assert_eq!(o.link_occupancy.len(), o.links_up);
        assert!(!o.free_gpus.is_empty());
        // Every registered demand starts fully feasible on an empty tiny
        // cluster: counts equal the GPU count.
        assert!(o.free_gpus.iter().all(|&(_, n)| n == 4));
        let j = o.to_json().to_string_pretty();
        assert!(j.contains("\"decision\""), "{j}");
    }

    #[test]
    fn save_restore_resumes_identically() {
        let c = cfg(2, 3);
        let js = jobs(6);
        let mut env = SimEnv::new(&c, &js);
        let mut agent = RandomAgent::new(42);
        let mut o = env.reset(&mut no_obs()).unwrap();
        for _ in 0..5 {
            assert!(!o.done, "episode too short for the checkpoint test");
            let d = env.state().pending().unwrap();
            let a = agent.act(env.state(), &d, &o);
            o = env.step(a, &mut no_obs()).unwrap().0;
        }
        let snap = env.save();
        let rng_snap = agent.save();
        // Finish the episode once...
        let mut tail_a = Vec::new();
        while !o.done {
            let d = env.state().pending().unwrap();
            let a = agent.act(env.state(), &d, &o);
            o = env.step(a, &mut no_obs()).unwrap().0;
            tail_a.push((o.t.to_bits(), o.finished));
        }
        let end_a = (env.steps(), env.episode_return().to_bits());
        // ...then rewind and replay.
        env.restore(&snap);
        let mut agent = RandomAgent::restore(&rng_snap);
        let mut o = env.observe();
        let mut tail_b = Vec::new();
        while !o.done {
            let d = env.state().pending().unwrap();
            let a = agent.act(env.state(), &d, &o);
            o = env.step(a, &mut no_obs()).unwrap().0;
            tail_b.push((o.t.to_bits(), o.finished));
        }
        assert_eq!(tail_a, tail_b);
        assert_eq!(end_a, (env.steps(), env.episode_return().to_bits()));
    }

    #[test]
    fn raw_state_machine_drives_manually() {
        // The SimState API underneath the env: advance/resolve round-trip.
        let c = cfg(1, 1);
        let js = jobs(1);
        let mut state = SimState::new(&c, &js);
        let mut obs = no_obs();
        match state.advance(&mut obs, None).unwrap() {
            Step::Decision(DecisionPoint::Place { job: 0, .. }) => {}
            s => panic!("expected the first placement decision, got {s:?}"),
        }
        state.resolve(Action::Place(Some(vec![0])), &mut obs).unwrap();
        loop {
            match state.advance(&mut obs, None).unwrap() {
                Step::Decision(d) => {
                    let a = match d {
                        DecisionPoint::Place { .. } => Action::Place(None),
                        _ => Action::Admit(Admission::Start),
                    };
                    state.resolve(a, &mut obs).unwrap();
                }
                Step::Done(stats) => {
                    assert!(stats.t_end > 0.0);
                    break;
                }
            }
        }
        assert_eq!(state.finished_jobs(), 1);
    }
}
