//! Health-aware placement: rank candidate GPUs by live + historical
//! device health under gray failures (docs/EXPERIMENTS.md §Faults).
//!
//! Each decision folds the engine's live [`HealthView`] factors into a
//! per-device [`HealthScore`] EWMA, then scores every feasible GPU as
//!
//! ```text
//! eff(g) = min(now_gpu(g), ewma_gpu(g)) * min(now_nic(g), ewma_nic(g))
//! ```
//!
//! — the live factor catches what is degraded *right now*, the EWMA
//! remembers what keeps flapping, and the NIC term steers multi-server
//! jobs away from degraded uplinks (NIC `LinkId` == `ServerId` in every
//! fabric preset; GPUs on fabrics without a matching link score on GPU
//! health alone). Candidates are taken best-eff-first, load ascending and
//! GPU id as deterministic tie-breaks, so on a fully healthy fleet the
//! placer degenerates to List-Scheduling's least-loaded choice.
//!
//! This file is on the CI unwrap/expect gate: no panicking shortcuts.

use crate::cluster::{ClusterState, GpuId};
use crate::fault::HealthView;
use crate::sched::health::HealthScore;
use crate::trace::JobSpec;

use super::{ListSchedulingPlacer, Placer};

pub struct HealthAwarePlacer {
    score: HealthScore,
}

impl HealthAwarePlacer {
    pub fn new() -> HealthAwarePlacer {
        HealthAwarePlacer { score: HealthScore::new(HealthScore::DEFAULT_ALPHA) }
    }
}

impl Default for HealthAwarePlacer {
    fn default() -> Self {
        Self::new()
    }
}

impl Placer for HealthAwarePlacer {
    fn name(&self) -> &'static str {
        "HEALTH"
    }

    /// Without a health view (legacy call path) there is nothing to rank
    /// by; behave like List-Scheduling.
    fn place(&mut self, job: &JobSpec, state: &ClusterState) -> Option<Vec<GpuId>> {
        ListSchedulingPlacer.place(job, state)
    }

    fn place_with_health(
        &mut self,
        job: &JobSpec,
        state: &ClusterState,
        health: &HealthView,
    ) -> Option<Vec<GpuId>> {
        self.score.observe(health.gpu_factors(), health.link_factors());
        let spec = state.spec;
        let eff = |g: GpuId| -> f64 {
            let gpu = health.gpu_factor(g).min(self.score.gpu(g));
            let s = spec.server_of(g);
            let nic = if s < health.n_links() {
                health.link_factor(s).min(self.score.link(s))
            } else {
                1.0
            };
            gpu * nic
        };
        let mut avail: Vec<(f64, f64, GpuId)> = (0..spec.n_gpus())
            .filter(|&g| state.fits(g, job.mem_bytes()))
            .map(|g| (eff(g), state.gpus[g].load, g))
            .collect();
        if avail.len() < job.n_gpus {
            return None;
        }
        // Best health first; load then id break ties deterministically.
        avail.sort_by(|a, b| {
            b.0.total_cmp(&a.0).then(a.1.total_cmp(&b.1)).then(a.2.cmp(&b.2))
        });
        Some(avail[..job.n_gpus].iter().map(|&(_, _, g)| g).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::model::DnnModel;

    fn job(n_gpus: usize) -> JobSpec {
        JobSpec { id: 0, arrival: 0.0, model: DnnModel::ResNet50, n_gpus, iterations: 100 }
    }

    fn state() -> ClusterState {
        ClusterState::new(ClusterSpec::tiny(4, 4))
    }

    #[test]
    fn healthy_fleet_matches_list_scheduling() {
        let mut st = state();
        st.allocate(&[0, 1, 2], 1e9, 25.0);
        let h = HealthView::new(st.spec.n_gpus(), st.spec.n_servers);
        let got = HealthAwarePlacer::new().place_with_health(&job(3), &st, &h);
        let ls = ListSchedulingPlacer.place(&job(3), &st);
        assert_eq!(got, ls, "no degradation => least-loaded choice");
    }

    #[test]
    fn avoids_slowed_gpus_and_degraded_nics() {
        let st = state();
        let mut h = HealthView::new(st.spec.n_gpus(), st.spec.n_servers);
        // GPUs 0..4 slowed badly, server 1's NIC degraded.
        for g in 0..4 {
            h.set_gpu_factor(g, 0.2);
        }
        h.set_link_factor(1, 0.5);
        let got = HealthAwarePlacer::new().place_with_health(&job(8), &st, &h).unwrap();
        assert!(
            got.iter().all(|&g| g >= 8),
            "chose a slowed GPU or a degraded server: {got:?}"
        );
    }

    #[test]
    fn ewma_remembers_flapping_devices() {
        let st = state();
        let mut p = HealthAwarePlacer::new();
        let mut h = HealthView::new(st.spec.n_gpus(), st.spec.n_servers);
        // GPU 0 observed degraded for a few decisions, then restored.
        h.set_gpu_factor(0, 0.1);
        for _ in 0..3 {
            p.place_with_health(&job(1), &st, &h);
        }
        h.set_gpu_factor(0, 1.0);
        let got = p.place_with_health(&job(1), &st, &h).unwrap();
        assert_ne!(got, vec![0], "freshly-restored flapper must rank below steady GPUs");
    }

    #[test]
    fn respects_memory_feasibility() {
        let mut st = state();
        let all: Vec<GpuId> = (0..st.spec.n_gpus()).collect();
        for _ in 0..4 {
            st.allocate(&all, 3.5e9, 1.0);
        }
        let h = HealthView::new(st.spec.n_gpus(), st.spec.n_servers);
        assert!(HealthAwarePlacer::new().place_with_health(&job(1), &st, &h).is_none());
    }
}
