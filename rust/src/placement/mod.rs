//! Job placement (§IV-A): pick the GPU set `G(J)` for a newly arrived job.
//!
//! * RAND — uniformly random feasible GPUs (the paper's worst baseline)
//! * FF   — First-Fit: the first n feasible GPUs in fixed order
//! * LS   — List-Scheduling: the n globally least-loaded feasible GPUs
//! * LWF-κ — Algorithm 1: LS for jobs needing ≤ κ GPUs; for bigger jobs,
//!   sort servers by total load and fill server-by-server (consolidation)
//! * LWF-rack — our extension for the two-tier fabric (`net`): the same
//!   consolidation idea one level up — fill rack-by-rack before
//!   server-by-server, minimising core-uplink crossings
//!
//! All placers see the same `ClusterState` (per-GPU load `L_g`, free
//! memory) and must return exactly `n_gpus` distinct feasible GPUs or None.

pub mod health;

pub use health::HealthAwarePlacer;

use crate::cluster::{ClusterState, GpuId, ServerId};
use crate::fault::HealthView;
use crate::trace::JobSpec;
use crate::util::rng::Pcg;

/// A placement algorithm. `place` must NOT mutate the cluster state; the
/// caller commits the returned set via `ClusterState::allocate`.
pub trait Placer {
    fn name(&self) -> &'static str;
    fn place(&mut self, job: &JobSpec, state: &ClusterState) -> Option<Vec<GpuId>>;

    /// Placement with the live device-health view (gray failures: per-GPU
    /// / per-link factors in [0, 1]). The engine always calls this; the
    /// default delegates to [`Placer::place`], so classic placers stay
    /// health-oblivious (down GPUs are already excluded for them by the
    /// engine's zero-free-memory hold). Only placers that *want* health
    /// (e.g. [`HealthAwarePlacer`]) override it.
    fn place_with_health(
        &mut self,
        job: &JobSpec,
        state: &ClusterState,
        _health: &HealthView,
    ) -> Option<Vec<GpuId>> {
        self.place(job, state)
    }
}

/// Feasible = enough free device memory for this job's model.
fn feasible(state: &ClusterState, job: &JobSpec) -> Vec<GpuId> {
    (0..state.spec.n_gpus())
        .filter(|&g| state.fits(g, job.mem_bytes()))
        .collect()
}

// ---------------------------------------------------------------------------

/// Uniformly random feasible GPUs.
pub struct RandomPlacer {
    rng: Pcg,
}

impl RandomPlacer {
    pub fn new(seed: u64) -> RandomPlacer {
        RandomPlacer { rng: Pcg::new(seed, 0x91ac) }
    }
}

impl Placer for RandomPlacer {
    fn name(&self) -> &'static str {
        "RAND"
    }

    fn place(&mut self, job: &JobSpec, state: &ClusterState) -> Option<Vec<GpuId>> {
        let mut avail = feasible(state, job);
        if avail.len() < job.n_gpus {
            return None;
        }
        self.rng.shuffle(&mut avail);
        avail.truncate(job.n_gpus);
        Some(avail)
    }
}

// ---------------------------------------------------------------------------

/// First-Fit: fixed scan order (server 0 gpu 0, 1, ... then server 1 ...).
pub struct FirstFitPlacer;

impl Placer for FirstFitPlacer {
    fn name(&self) -> &'static str {
        "FF"
    }

    fn place(&mut self, job: &JobSpec, state: &ClusterState) -> Option<Vec<GpuId>> {
        let avail = feasible(state, job);
        if avail.len() < job.n_gpus {
            return None;
        }
        Some(avail[..job.n_gpus].to_vec())
    }
}

// ---------------------------------------------------------------------------

/// List-Scheduling: globally least-loaded feasible GPUs.
pub struct ListSchedulingPlacer;

impl Placer for ListSchedulingPlacer {
    fn name(&self) -> &'static str {
        "LS"
    }

    fn place(&mut self, job: &JobSpec, state: &ClusterState) -> Option<Vec<GpuId>> {
        let mut avail = feasible(state, job);
        if avail.len() < job.n_gpus {
            return None;
        }
        // Stable tie-break on GPU id keeps the algorithm deterministic.
        avail.sort_by(|&a, &b| {
            state.gpus[a]
                .load
                .partial_cmp(&state.gpus[b].load)
                .unwrap()
                .then(a.cmp(&b))
        });
        avail.truncate(job.n_gpus);
        Some(avail)
    }
}

// ---------------------------------------------------------------------------

/// LWF-κ (Algorithm 1): least-workload-first with a consolidation threshold.
pub struct LwfPlacer {
    pub kappa: usize,
}

impl LwfPlacer {
    pub fn new(kappa: usize) -> LwfPlacer {
        LwfPlacer { kappa }
    }
}

impl Placer for LwfPlacer {
    fn name(&self) -> &'static str {
        "LWF-k"
    }

    fn place(&mut self, job: &JobSpec, state: &ClusterState) -> Option<Vec<GpuId>> {
        let n = job.n_gpus;
        if n <= self.kappa {
            // Lines 2–9: same as LS — top-n least-loaded feasible GPUs.
            return ListSchedulingPlacer.place(job, state);
        }
        // Lines 10–21: sort servers by total remaining workload L_S, then
        // take feasible GPUs server by server (least-loaded first within a
        // server), consolidating the job onto as few servers as possible.
        let mut servers: Vec<usize> = (0..state.spec.n_servers).collect();
        servers.sort_by(|&a, &b| {
            state
                .server_load(a)
                .partial_cmp(&state.server_load(b))
                .unwrap()
                .then(a.cmp(&b))
        });
        let mut chosen: Vec<GpuId> = Vec::with_capacity(n);
        for s in servers {
            let mut gpus: Vec<GpuId> = state
                .spec
                .gpus_of(s)
                .filter(|&g| state.fits(g, job.mem_bytes()))
                .collect();
            gpus.sort_by(|&a, &b| {
                state.gpus[a]
                    .load
                    .partial_cmp(&state.gpus[b].load)
                    .unwrap()
                    .then(a.cmp(&b))
            });
            for g in gpus {
                chosen.push(g);
                if chosen.len() == n {
                    return Some(chosen);
                }
            }
        }
        None // line 22: not enough feasible GPUs
    }
}

// ---------------------------------------------------------------------------

/// LWF-rack: rack-locality-aware LWF. Jobs needing ≤ κ GPUs behave like
/// LS (they rarely cross servers at all); bigger jobs fill rack-by-rack —
/// racks ordered by total remaining workload, servers within a rack by
/// load, GPUs within a server by load — so a job lands in as few racks as
/// possible (fewest oversubscribed core-uplink crossings) before it lands
/// in as few servers as possible. On a rackless fabric
/// (`rack_size >= n_servers`, e.g. `TopologySpec::Flat.rack_size()`)
/// everything is one rack and the ordering degenerates to LWF's.
pub struct RackLwfPlacer {
    pub kappa: usize,
    /// Servers per rack; clamped to the cluster size at decision time.
    pub rack_size: usize,
}

impl RackLwfPlacer {
    pub fn new(kappa: usize, rack_size: usize) -> RackLwfPlacer {
        RackLwfPlacer { kappa, rack_size }
    }
}

impl Placer for RackLwfPlacer {
    fn name(&self) -> &'static str {
        "LWF-rack"
    }

    fn place(&mut self, job: &JobSpec, state: &ClusterState) -> Option<Vec<GpuId>> {
        let n = job.n_gpus;
        if n <= self.kappa {
            return ListSchedulingPlacer.place(job, state);
        }
        let spec = state.spec;
        // Load keys are computed once per candidate and sorted as
        // (load, id) tuples; deriving them inside the comparators cost a
        // rack-load aggregation (a sum over every GPU of every server of
        // the rack) per *comparison* instead of per candidate. Ordering
        // is unchanged: ascending load, ties by id.
        let rack_load = |r: usize| -> f64 {
            spec.servers_of_rack(r, self.rack_size).map(|s| state.server_load(s)).sum()
        };
        let by_load = |a: &(f64, usize), b: &(f64, usize)| {
            a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
        };
        let mut racks: Vec<(f64, usize)> =
            (0..spec.n_racks(self.rack_size)).map(|r| (rack_load(r), r)).collect();
        racks.sort_by(by_load);
        let mut chosen: Vec<GpuId> = Vec::with_capacity(n);
        for (_, r) in racks {
            let mut servers: Vec<(f64, ServerId)> = spec
                .servers_of_rack(r, self.rack_size)
                .map(|s| (state.server_load(s), s))
                .collect();
            servers.sort_by(by_load);
            for (_, s) in servers {
                let mut gpus: Vec<(f64, GpuId)> = spec
                    .gpus_of(s)
                    .filter(|&g| state.fits(g, job.mem_bytes()))
                    .map(|g| (state.gpus[g].load, g))
                    .collect();
                gpus.sort_by(by_load);
                for (_, g) in gpus {
                    chosen.push(g);
                    if chosen.len() == n {
                        return Some(chosen);
                    }
                }
            }
        }
        None
    }
}

// Placer construction by name lives in `scenario::registry` (the unified
// algorithm registry shared by the CLI, scenario files and benches).

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::model::DnnModel;

    fn job(n_gpus: usize) -> JobSpec {
        JobSpec { id: 0, arrival: 0.0, model: DnnModel::ResNet50, n_gpus, iterations: 100 }
    }

    fn state() -> ClusterState {
        ClusterState::new(ClusterSpec::tiny(4, 4))
    }

    fn assert_valid(got: &[GpuId], st: &ClusterState, j: &JobSpec) {
        assert_eq!(got.len(), j.n_gpus);
        let mut sorted = got.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), j.n_gpus, "duplicate GPUs");
        for &g in got {
            assert!(st.fits(g, j.mem_bytes()));
        }
    }

    #[test]
    fn all_placers_return_valid_sets() {
        let st = state();
        let j = job(6);
        for placer in &mut [
            Box::new(RandomPlacer::new(1)) as Box<dyn Placer>,
            Box::new(FirstFitPlacer),
            Box::new(ListSchedulingPlacer),
            Box::new(LwfPlacer::new(1)),
        ] {
            let got = placer.place(&j, &st).expect(placer.name());
            assert_valid(&got, &st, &j);
        }
    }

    #[test]
    fn ff_takes_prefix() {
        let st = state();
        assert_eq!(FirstFitPlacer.place(&job(3), &st).unwrap(), vec![0, 1, 2]);
    }

    #[test]
    fn ls_prefers_least_loaded() {
        let mut st = state();
        st.allocate(&[0, 1, 2, 3, 4, 5], 1e9, 50.0); // load first 6 GPUs
        let got = ListSchedulingPlacer.place(&job(2), &st).unwrap();
        assert_eq!(got, vec![6, 7]);
    }

    #[test]
    fn lwf_small_job_acts_like_ls() {
        let mut st = state();
        st.allocate(&[0], 1e9, 10.0);
        let lwf = LwfPlacer::new(2).place(&job(2), &st).unwrap();
        let ls = ListSchedulingPlacer.place(&job(2), &st).unwrap();
        assert_eq!(lwf, ls);
    }

    #[test]
    fn lwf_large_job_consolidates() {
        let mut st = state();
        // Unbalance individual GPUs so LS would scatter: load gpu0 of each server lightly.
        st.allocate(&[0, 4, 8, 12], 1e9, 5.0);
        let got = LwfPlacer::new(1).place(&job(4), &st).unwrap();
        let servers = st.spec.servers_of(&got);
        assert_eq!(servers.len(), 1, "4-GPU job must fit one 4-GPU server, got {:?}", got);
    }

    #[test]
    fn lwf_prefers_lightest_servers() {
        let mut st = state();
        st.allocate(&[0, 1, 2, 3], 1e9, 100.0); // server 0 heavy
        st.allocate(&[4, 5], 1e9, 10.0); // server 1 light-ish
        let got = LwfPlacer::new(1).place(&job(8), &st).unwrap();
        let servers = st.spec.servers_of(&got);
        // The two empty servers (2, 3) must be used.
        assert!(servers.contains(&2) && servers.contains(&3), "{:?}", servers);
        assert!(!servers.contains(&0), "heaviest server chosen: {:?}", servers);
    }

    #[test]
    fn placement_fails_when_memory_exhausted() {
        let mut st = state();
        let j = job(1);
        // Fill every GPU to the brim.
        let all: Vec<GpuId> = (0..st.spec.n_gpus()).collect();
        for _ in 0..4 {
            st.allocate(&all, 3.5e9, 1.0);
        }
        for placer in &mut [
            Box::new(RandomPlacer::new(1)) as Box<dyn Placer>,
            Box::new(FirstFitPlacer),
            Box::new(ListSchedulingPlacer),
            Box::new(LwfPlacer::new(1)),
            Box::new(RackLwfPlacer::new(1, 2)),
        ] {
            assert!(placer.place(&j, &st).is_none(), "{}", placer.name());
        }
    }

    #[test]
    fn rack_lwf_small_job_acts_like_ls() {
        let mut st = state();
        st.allocate(&[0], 1e9, 10.0);
        let rack = RackLwfPlacer::new(2, 2).place(&job(2), &st).unwrap();
        let ls = ListSchedulingPlacer.place(&job(2), &st).unwrap();
        assert_eq!(rack, ls);
    }

    #[test]
    fn rack_lwf_consolidates_into_one_rack() {
        // 4 servers x 4 GPUs in racks of 2. An 8-GPU job fits one rack;
        // plain LWF would take the two globally lightest servers even if
        // they straddle racks.
        let mut st = state();
        // Make servers 1 and 2 the lightest pair — but they are in
        // different racks ({0,1} and {2,3}).
        st.allocate(&[0, 1, 2, 3], 1e9, 50.0); // server 0 heavy
        st.allocate(&[12, 13, 14, 15], 1e9, 60.0); // server 3 heavy
        let got = RackLwfPlacer::new(1, 2).place(&job(8), &st).unwrap();
        let servers = st.spec.servers_of(&got);
        let racks: Vec<usize> =
            servers.iter().map(|&s| st.spec.rack_of(s, 2)).collect();
        assert!(
            racks.iter().all(|&r| r == racks[0]),
            "job straddles racks: servers {servers:?}"
        );
        // And within the choice it still prefers the lighter rack (rack 0
        // carries 50, rack 1 carries 60).
        assert_eq!(racks[0], 0, "heavier rack chosen: {servers:?}");
        // Plain LWF (rackless ordering) picks servers 1 and 2 here.
        let lwf = LwfPlacer::new(1).place(&job(8), &st).unwrap();
        assert_eq!(st.spec.servers_of(&lwf), vec![1, 2]);
    }

    #[test]
    fn rack_lwf_degenerates_to_lwf_without_racks() {
        let mut st = state();
        st.allocate(&[0, 1, 2, 3], 1e9, 100.0);
        st.allocate(&[4, 5], 1e9, 10.0);
        let rack = RackLwfPlacer::new(1, usize::MAX).place(&job(8), &st).unwrap();
        let lwf = LwfPlacer::new(1).place(&job(8), &st).unwrap();
        assert_eq!(rack, lwf);
    }

    #[test]
    fn rand_is_seed_deterministic() {
        let st = state();
        let a = RandomPlacer::new(9).place(&job(5), &st).unwrap();
        let b = RandomPlacer::new(9).place(&job(5), &st).unwrap();
        assert_eq!(a, b);
    }
}
