//! Cluster topology and allocation bookkeeping: `N_s` servers × `N_g` GPUs
//! behind one non-blocking switch (§III-A). GPUs have a memory capacity and
//! a remaining-workload counter `L_g` (Algorithm 1's bookkeeping); servers
//! aggregate `L_S = Σ_j L_g` and expose the NIC contention count `|C_S|`.

use crate::model::V100_PEAK_GFLOPS;
use crate::util::json::Json;

/// Flat GPU identifier; `server = id / n_gpus_per_server`.
pub type GpuId = usize;
pub type ServerId = usize;

/// Static cluster shape + GPU grade.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClusterSpec {
    pub n_servers: usize,
    pub gpus_per_server: usize,
    /// Device memory per GPU in bytes.
    pub gpu_mem_bytes: f64,
    /// Peak throughput per GPU (GFLOPS) for Eqs (3)–(4).
    pub gpu_peak_gflops: f64,
}

impl ClusterSpec {
    /// The paper's evaluation testbed: 16 servers × 4 V100-16GB, 10 GbE.
    pub fn paper_64gpu() -> ClusterSpec {
        ClusterSpec {
            n_servers: 16,
            gpus_per_server: 4,
            gpu_mem_bytes: 16.0 * 1024.0 * 1024.0 * 1024.0,
            gpu_peak_gflops: V100_PEAK_GFLOPS,
        }
    }

    /// A small cluster for unit tests.
    pub fn tiny(n_servers: usize, gpus_per_server: usize) -> ClusterSpec {
        ClusterSpec {
            n_servers,
            gpus_per_server,
            ..ClusterSpec::paper_64gpu()
        }
    }

    pub fn n_gpus(&self) -> usize {
        self.n_servers * self.gpus_per_server
    }

    pub fn server_of(&self, gpu: GpuId) -> ServerId {
        gpu / self.gpus_per_server
    }

    pub fn gpus_of(&self, server: ServerId) -> std::ops::Range<GpuId> {
        let start = server * self.gpus_per_server;
        start..start + self.gpus_per_server
    }

    /// Distinct servers touched by a GPU set.
    pub fn servers_of(&self, gpus: &[GpuId]) -> Vec<ServerId> {
        let mut servers: Vec<ServerId> = gpus.iter().map(|&g| self.server_of(g)).collect();
        servers.sort_unstable();
        servers.dedup();
        servers
    }

    // -- rack awareness (the `net` two-tier fabric and rack-locality
    // placement group servers into racks of `rack_size`; the spec itself
    // stays rack-free so flat scenario files are unchanged) --------------

    /// `rack_size` clamped to something indexable on this cluster
    /// (`usize::MAX` — "no rack tier" — becomes one all-covering rack).
    fn clamped_rack(&self, rack_size: usize) -> usize {
        rack_size.clamp(1, self.n_servers.max(1))
    }

    /// Rack of `server` when servers are grouped into racks of `rack_size`.
    pub fn rack_of(&self, server: ServerId, rack_size: usize) -> usize {
        server / self.clamped_rack(rack_size)
    }

    /// Number of racks of `rack_size` servers (the last may be partial).
    pub fn n_racks(&self, rack_size: usize) -> usize {
        self.n_servers.div_ceil(self.clamped_rack(rack_size))
    }

    /// Servers in `rack` under racks of `rack_size`.
    pub fn servers_of_rack(&self, rack: usize, rack_size: usize) -> std::ops::Range<ServerId> {
        let rs = self.clamped_rack(rack_size);
        let start = (rack * rs).min(self.n_servers);
        start..((rack + 1) * rs).min(self.n_servers)
    }

    /// Scenario-file serialization (see docs/SCENARIOS.md).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("n_servers", self.n_servers)
            .set("gpus_per_server", self.gpus_per_server)
            .set("gpu_mem_bytes", self.gpu_mem_bytes)
            .set("gpu_peak_gflops", self.gpu_peak_gflops)
    }

    pub fn from_json(v: &Json) -> Result<ClusterSpec, String> {
        let spec = ClusterSpec {
            n_servers: v.req_usize("n_servers")?,
            gpus_per_server: v.req_usize("gpus_per_server")?,
            gpu_mem_bytes: v.req_f64("gpu_mem_bytes")?,
            gpu_peak_gflops: v.req_f64("gpu_peak_gflops")?,
        };
        if spec.n_servers == 0 || spec.gpus_per_server == 0 {
            return Err("cluster must have at least one server and one GPU".into());
        }
        if !spec.gpu_mem_bytes.is_finite() || spec.gpu_mem_bytes <= 0.0 {
            return Err(format!(
                "gpu_mem_bytes must be finite and positive, got {}",
                spec.gpu_mem_bytes
            ));
        }
        if !spec.gpu_peak_gflops.is_finite() || spec.gpu_peak_gflops <= 0.0 {
            return Err(format!(
                "gpu_peak_gflops must be finite and positive, got {}",
                spec.gpu_peak_gflops
            ));
        }
        Ok(spec)
    }
}

/// Mutable per-GPU allocation state (the placement algorithms' view).
#[derive(Clone, Debug)]
pub struct GpuState {
    /// Remaining workload L_g (seconds·GPUs, Algorithm 1 bookkeeping).
    pub load: f64,
    /// Memory currently committed to resident jobs (bytes).
    pub mem_used: f64,
    /// Number of resident jobs (for metrics/debug).
    pub residents: usize,
}

/// Cluster allocation state: what placement reads and writes.
#[derive(Clone, Debug)]
pub struct ClusterState {
    pub spec: ClusterSpec,
    pub gpus: Vec<GpuState>,
}

impl ClusterState {
    pub fn new(spec: ClusterSpec) -> ClusterState {
        ClusterState {
            spec,
            gpus: (0..spec.n_gpus())
                .map(|_| GpuState { load: 0.0, mem_used: 0.0, residents: 0 })
                .collect(),
        }
    }

    pub fn free_mem(&self, gpu: GpuId) -> f64 {
        self.spec.gpu_mem_bytes - self.gpus[gpu].mem_used
    }

    /// GPUs able to host a job needing `mem_bytes` per GPU.
    pub fn fits(&self, gpu: GpuId, mem_bytes: f64) -> bool {
        self.free_mem(gpu) >= mem_bytes
    }

    /// Server total remaining workload L_S.
    pub fn server_load(&self, server: ServerId) -> f64 {
        self.spec.gpus_of(server).map(|g| self.gpus[g].load).sum()
    }

    /// Commit a job: reserve memory, add workload to each chosen GPU.
    pub fn allocate(&mut self, gpus: &[GpuId], mem_bytes: f64, job_load: f64) {
        for &g in gpus {
            debug_assert!(self.fits(g, mem_bytes), "allocation without memory check");
            self.gpus[g].mem_used += mem_bytes;
            self.gpus[g].load += job_load;
            self.gpus[g].residents += 1;
        }
    }

    /// Release a finished job's memory (and any leftover bookkeeping load).
    pub fn release(&mut self, gpus: &[GpuId], mem_bytes: f64, leftover_load: f64) {
        for &g in gpus {
            self.gpus[g].mem_used = (self.gpus[g].mem_used - mem_bytes).max(0.0);
            self.gpus[g].load = (self.gpus[g].load - leftover_load).max(0.0);
            self.gpus[g].residents = self.gpus[g].residents.saturating_sub(1);
        }
    }

    /// Mark a GPU down by committing all of its free memory to a
    /// synthetic hold: every placer's `fits` test fails while the hold is
    /// in place, so no job can land on dead capacity without placers
    /// having to learn about health at all. Returns the held amount for
    /// the matching [`ClusterState::release_held`] at recovery.
    pub fn hold_all(&mut self, gpu: GpuId) -> f64 {
        let held = self.free_mem(gpu);
        self.gpus[gpu].mem_used += held;
        held
    }

    /// Undo a [`ClusterState::hold_all`] when the GPU recovers.
    pub fn release_held(&mut self, gpu: GpuId, held: f64) {
        self.gpus[gpu].mem_used = (self.gpus[gpu].mem_used - held).max(0.0);
    }

    /// Decay workload bookkeeping as jobs make progress.
    pub fn drain_load(&mut self, gpus: &[GpuId], amount: f64) {
        for &g in gpus {
            self.gpus[g].load = (self.gpus[g].load - amount).max(0.0);
        }
    }

    /// Drain `n` iterations' load in one call — the batched form the
    /// simulator's fast-forwarded macro-events use. Replays the exact
    /// per-iteration `drain_load` chain (the subtraction sequence is not
    /// reassociated: results must stay bit-identical to n single drains),
    /// stopping early at the chain's fixed point (a drained-to-zero
    /// counter stays zero).
    pub fn drain_load_n(&mut self, gpus: &[GpuId], amount: f64, n: u64) {
        for &g in gpus {
            let mut load = self.gpus[g].load;
            for _ in 0..n {
                let next = (load - amount).max(0.0);
                if next.to_bits() == load.to_bits() {
                    break; // fixed point: every further drain is identical
                }
                load = next;
            }
            self.gpus[g].load = load;
        }
    }
}

/// Incrementally maintained free-GPU counts per memory threshold — the
/// simulator's capacity gate for placement. For every distinct per-GPU
/// memory demand in a workload, `counts[i]` tracks how many GPUs
/// currently satisfy `free_mem >= thresholds[i]` (exactly the
/// [`ClusterState::fits`] predicate placers filter on), updated O(log T +
/// crossings) per GPU allocation/release instead of re-scanned O(GPUs)
/// per placer call. Every contract-abiding placer returns `None` iff
/// fewer feasible GPUs than requested exist, so `feasible(mem) <
/// n_gpus` proves a placement attempt hopeless without invoking it.
#[derive(Clone, Debug)]
pub struct FreeGpuIndex {
    /// Distinct memory demands, sorted ascending (all finite).
    thresholds: Vec<f64>,
    /// `counts[i]` = number of GPUs with `free_mem >= thresholds[i]`.
    counts: Vec<usize>,
}

impl FreeGpuIndex {
    /// Build over `state` for the given memory demands (deduplicated
    /// here; non-finite demands are dropped — nothing can fit them).
    pub fn new(mut thresholds: Vec<f64>, state: &ClusterState) -> FreeGpuIndex {
        thresholds.retain(|t| t.is_finite());
        thresholds.sort_by(f64::total_cmp);
        thresholds.dedup();
        let counts = thresholds
            .iter()
            .map(|&th| (0..state.spec.n_gpus()).filter(|&g| state.free_mem(g) >= th).count())
            .collect();
        FreeGpuIndex { thresholds, counts }
    }

    /// Number of GPUs currently able to host `mem_bytes`. Demands not
    /// registered at construction report `usize::MAX` ("unknown — do not
    /// gate"), so a caller's `feasible < n` test stays conservative.
    pub fn feasible(&self, mem_bytes: f64) -> usize {
        match self.thresholds.binary_search_by(|t| t.total_cmp(&mem_bytes)) {
            Ok(i) => self.counts[i],
            Err(_) => usize::MAX,
        }
    }

    /// One GPU's free memory moved `before` → `after`: adjust the count
    /// of every threshold the move crossed. A GPU counts toward
    /// threshold `t` iff `free >= t`, so a decrease loses the thresholds
    /// in `(after, before]` and an increase gains `(before, after]`.
    pub fn record(&mut self, before: f64, after: f64) {
        match after.total_cmp(&before) {
            std::cmp::Ordering::Less => {
                let lo = self.thresholds.partition_point(|&t| t <= after);
                let hi = self.thresholds.partition_point(|&t| t <= before);
                for c in &mut self.counts[lo..hi] {
                    *c -= 1;
                }
            }
            std::cmp::Ordering::Greater => {
                let lo = self.thresholds.partition_point(|&t| t <= before);
                let hi = self.thresholds.partition_point(|&t| t <= after);
                for c in &mut self.counts[lo..hi] {
                    *c += 1;
                }
            }
            std::cmp::Ordering::Equal => {}
        }
    }

    /// The live `(threshold, feasible count)` rows — a constant-size
    /// free-capacity summary (one row per distinct memory demand), used
    /// as the env observation's cluster feature.
    pub fn histogram(&self) -> Vec<(f64, usize)> {
        self.thresholds.iter().copied().zip(self.counts.iter().copied()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_indexing() {
        let spec = ClusterSpec::tiny(4, 4);
        assert_eq!(spec.n_gpus(), 16);
        assert_eq!(spec.server_of(0), 0);
        assert_eq!(spec.server_of(5), 1);
        assert_eq!(spec.server_of(15), 3);
        assert_eq!(spec.gpus_of(2).collect::<Vec<_>>(), vec![8, 9, 10, 11]);
    }

    #[test]
    fn servers_of_dedups() {
        let spec = ClusterSpec::tiny(4, 4);
        assert_eq!(spec.servers_of(&[0, 1, 2, 3]), vec![0]);
        assert_eq!(spec.servers_of(&[3, 4, 12, 5]), vec![0, 1, 3]);
    }

    #[test]
    fn alloc_release_roundtrip() {
        let mut st = ClusterState::new(ClusterSpec::tiny(2, 2));
        let mem = 4e9;
        st.allocate(&[0, 2], mem, 100.0);
        assert_eq!(st.gpus[0].residents, 1);
        assert!(st.fits(0, 4e9));
        assert!(!st.fits(0, 14e9));
        assert_eq!(st.server_load(0), 100.0);
        assert_eq!(st.server_load(1), 100.0);
        st.release(&[0, 2], mem, 100.0);
        assert_eq!(st.gpus[0].mem_used, 0.0);
        assert_eq!(st.server_load(0), 0.0);
    }

    #[test]
    fn drain_saturates_at_zero() {
        let mut st = ClusterState::new(ClusterSpec::tiny(1, 1));
        st.allocate(&[0], 1e9, 10.0);
        st.drain_load(&[0], 25.0);
        assert_eq!(st.gpus[0].load, 0.0);
    }

    #[test]
    fn drain_load_n_matches_n_single_drains_bitwise() {
        // The batched drain must replay the per-iteration chain exactly —
        // including the non-associative float subtractions — for any mix
        // of partial and past-zero drains.
        for (load, amount, n) in [
            (100.0, 0.37, 113u64),
            (100.0, 3.3, 200),
            (5.0, 0.0, 50),
            (0.0, 1.0, 10),
            (1.0, 1e-3, 1),
        ] {
            let mut a = ClusterState::new(ClusterSpec::tiny(1, 2));
            let mut b = ClusterState::new(ClusterSpec::tiny(1, 2));
            a.allocate(&[0, 1], 1e9, load);
            b.allocate(&[0, 1], 1e9, load);
            for _ in 0..n {
                a.drain_load(&[0, 1], amount);
            }
            b.drain_load_n(&[0, 1], amount, n);
            assert_eq!(
                a.gpus[0].load.to_bits(),
                b.gpus[0].load.to_bits(),
                "load={load} amount={amount} n={n}: {} vs {}",
                a.gpus[0].load,
                b.gpus[0].load
            );
        }
    }

    #[test]
    fn rack_grouping() {
        let spec = ClusterSpec::tiny(5, 2);
        assert_eq!(spec.n_racks(2), 3); // {0,1} {2,3} {4}
        assert_eq!(spec.rack_of(0, 2), 0);
        assert_eq!(spec.rack_of(3, 2), 1);
        assert_eq!(spec.rack_of(4, 2), 2);
        assert_eq!(spec.servers_of_rack(1, 2).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(spec.servers_of_rack(2, 2).collect::<Vec<_>>(), vec![4]);
        // No rack tier: everything is one rack.
        assert_eq!(spec.n_racks(usize::MAX), 1);
        assert_eq!(spec.servers_of_rack(0, usize::MAX).count(), 5);
        assert_eq!(spec.rack_of(4, usize::MAX), 0);
        // Out-of-range rack index yields an empty range, not a panic.
        assert_eq!(spec.servers_of_rack(9, 2).count(), 0);
    }

    #[test]
    fn paper_testbed_shape() {
        let spec = ClusterSpec::paper_64gpu();
        assert_eq!(spec.n_gpus(), 64);
        assert_eq!(spec.n_servers, 16);
    }

    #[test]
    fn free_gpu_index_tracks_fits_exactly() {
        let spec = ClusterSpec::tiny(2, 2); // 4 GPUs, 16 GB each
        let mut st = ClusterState::new(spec);
        let small = 3e9;
        let big = 9e9;
        let mut idx = FreeGpuIndex::new(vec![small, big, big, small], &st);
        let check = |idx: &FreeGpuIndex, st: &ClusterState| {
            for &mem in &[small, big] {
                let want = (0..st.spec.n_gpus()).filter(|&g| st.fits(g, mem)).count();
                assert_eq!(idx.feasible(mem), want, "mem={mem}");
            }
        };
        check(&idx, &st);
        // Allocate the big job on GPUs 0,1: they keep fitting small but
        // not big.
        let before: Vec<f64> = (0..2).map(|g| st.free_mem(g)).collect();
        st.allocate(&[0, 1], big, 1.0);
        for (i, g) in (0..2).enumerate() {
            idx.record(before[i], st.free_mem(g));
        }
        assert_eq!(idx.feasible(big), 2);
        assert_eq!(idx.feasible(small), 4);
        check(&idx, &st);
        // Stack small jobs on GPU 2 until nothing fits there.
        for _ in 0..5 {
            let b = st.free_mem(2);
            st.allocate(&[2], small, 1.0);
            idx.record(b, st.free_mem(2));
        }
        assert_eq!(idx.feasible(small), 3);
        check(&idx, &st);
        // Release restores the counts.
        let b = st.free_mem(0);
        st.release(&[0], big, 0.0);
        idx.record(b, st.free_mem(0));
        assert_eq!(idx.feasible(big), 2); // GPUs 0 and 3
        check(&idx, &st);
    }

    #[test]
    fn free_gpu_index_unregistered_demand_never_gates() {
        let st = ClusterState::new(ClusterSpec::tiny(1, 1));
        let idx = FreeGpuIndex::new(vec![1e9], &st);
        assert_eq!(idx.feasible(2e9), usize::MAX);
        assert!(FreeGpuIndex::new(vec![f64::NAN, 1e9], &st).feasible(1e9) > 0);
    }

    #[test]
    fn free_gpu_index_boundary_is_inclusive() {
        // `fits` is `free >= mem`: a GPU whose free memory lands exactly
        // on a threshold still counts, and a record() moving free exactly
        // onto the threshold must not lose it.
        let spec = ClusterSpec::tiny(1, 1);
        let mut st = ClusterState::new(spec);
        let half = st.free_mem(0) / 2.0;
        let mut idx = FreeGpuIndex::new(vec![half], &st);
        assert_eq!(idx.feasible(half), 1);
        let before = st.free_mem(0);
        st.allocate(&[0], half, 1.0);
        idx.record(before, st.free_mem(0));
        // free == half exactly: still feasible.
        assert_eq!(st.free_mem(0), half);
        assert_eq!(idx.feasible(half), 1);
        let before = st.free_mem(0);
        st.allocate(&[0], half, 1.0);
        idx.record(before, st.free_mem(0));
        assert_eq!(idx.feasible(half), 0);
    }
}
