//! Link-level network fabric model — the topology-aware generalisation of
//! the paper's flat per-server contention bookkeeping.
//!
//! The paper's testbed (§V-A) is 16 servers behind one non-blocking
//! switch, so its Eq (5) contention level is simply "active communication
//! tasks per server NIC". That stops being true the moment the cluster
//! has racks with oversubscribed core uplinks or mixed-bandwidth NICs —
//! the regimes where placement sensitivity actually dominates JCT. This
//! module models the fabric as a set of [`Link`]s, each with its own
//! [`CommModel`] parameters; an All-Reduce spanning a server set crosses
//! `links_between(servers)` and its effective contention level k and
//! per-byte drain time are the **max over the links it crosses** (the
//! bottleneck link), not the max over server NIC counts.
//!
//! Presets ([`TopologySpec`], the scenario-file `topology` section —
//! docs/SCENARIOS.md):
//!
//! * `flat` — one NIC link per server, all with the base comm model.
//!   `LinkId` == `ServerId`, so contention counts reduce *exactly* to the
//!   paper's per-server counts: a flat scenario reproduces the seed
//!   engine's contention structure exactly, and its timing to within
//!   the ulp-level residual-arithmetic change described in
//!   docs/EXPERIMENTS.md §Oversub (property-tested in `sim::tests`).
//! * `two-tier` — racks of `rack_size` servers; cross-rack transfers
//!   additionally cross each involved rack's core uplink, whose per-byte
//!   constants are the base model's scaled by the `oversubscription`
//!   ratio (a 4:1 oversubscribed core drains bytes 4x slower).
//! * `heterogeneous` — flat structure with explicit per-server NIC
//!   [`CommModel`]s (mixed 10/25/100 GbE fleets).

use crate::cluster::{ClusterSpec, ServerId};
use crate::model::CommModel;
use crate::util::json::Json;

/// Index into a [`Topology`]'s link table. In a `flat` fabric link ids
/// coincide with server ids; rack uplinks are appended after the NICs.
pub type LinkId = usize;

/// Rack width used when an oversubscription sweep starts from a rackless
/// base topology: the paper's 16 servers split into 4 racks of 4.
pub const DEFAULT_RACK_SIZE: usize = 4;

/// Canonical scenario-file topology preset names, in schema order
/// (`ddl-sched simulate --list` prints these for scenario authors).
pub const TOPOLOGY_PRESETS: [&str; 3] = ["flat", "two-tier", "heterogeneous"];

/// Declarative topology description — what scenario files carry.
#[derive(Clone, Debug, PartialEq, Default)]
pub enum TopologySpec {
    /// One non-blocking switch (the paper's testbed). The default.
    #[default]
    Flat,
    /// Racks of `rack_size` servers behind a shared core with the given
    /// downlink:uplink `oversubscription` ratio (1.0 = non-blocking).
    TwoTier { rack_size: usize, oversubscription: f64 },
    /// Flat structure, but each server NIC has its own comm model
    /// (`nics[s]` is server `s`'s link parameters).
    Heterogeneous { nics: Vec<CommModel> },
}

impl TopologySpec {
    /// Canonical scenario-file preset name.
    pub fn preset(&self) -> &'static str {
        match self {
            TopologySpec::Flat => "flat",
            TopologySpec::TwoTier { .. } => "two-tier",
            TopologySpec::Heterogeneous { .. } => "heterogeneous",
        }
    }

    pub fn is_flat(&self) -> bool {
        matches!(self, TopologySpec::Flat)
    }

    /// Servers per rack, for rack-locality-aware placement. Fabrics
    /// without a rack tier report `usize::MAX` ("everything is one
    /// rack"); consumers clamp to the cluster size.
    pub fn rack_size(&self) -> usize {
        match self {
            TopologySpec::TwoTier { rack_size, .. } => *rack_size,
            _ => usize::MAX,
        }
    }

    /// Number of fabric links [`Topology::build`] will create over
    /// `cluster` — NICs plus (for two-tier) one uplink per rack. Lets
    /// fault timelines be validated against link ids before a topology
    /// is actually built.
    pub fn n_links(&self, cluster: &ClusterSpec) -> usize {
        let n = cluster.n_servers;
        match self {
            TopologySpec::Flat | TopologySpec::Heterogeneous { .. } => n,
            TopologySpec::TwoTier { rack_size, .. } => {
                let rs = (*rack_size).clamp(1, n.max(1));
                n + cluster.n_racks(rs)
            }
        }
    }

    /// Method-label suffix for non-default fabrics (`None` for flat, so
    /// paper labels are untouched).
    pub fn label(&self) -> Option<String> {
        match self {
            TopologySpec::Flat => None,
            TopologySpec::TwoTier { oversubscription, .. } => {
                Some(format!("2tier-{oversubscription}:1"))
            }
            TopologySpec::Heterogeneous { .. } => Some("hetero".to_string()),
        }
    }

    /// Validate against the cluster this topology will be built over.
    pub fn validate(&self, cluster: &ClusterSpec) -> Result<(), String> {
        match self {
            TopologySpec::Flat => Ok(()),
            TopologySpec::TwoTier { rack_size, oversubscription } => {
                if *rack_size == 0 {
                    return Err("two-tier topology needs rack_size >= 1".to_string());
                }
                if !oversubscription.is_finite() || *oversubscription < 1.0 {
                    return Err(format!(
                        "invalid oversubscription {oversubscription}: must be a finite ratio >= 1"
                    ));
                }
                Ok(())
            }
            TopologySpec::Heterogeneous { nics } => {
                if nics.len() != cluster.n_servers {
                    return Err(format!(
                        "heterogeneous topology needs one NIC model per server: \
                         got {} for {} servers",
                        nics.len(),
                        cluster.n_servers
                    ));
                }
                for (s, m) in nics.iter().enumerate() {
                    m.validate().map_err(|e| format!("server {s} NIC model: {e}"))?;
                }
                Ok(())
            }
        }
    }

    /// Scenario-file serialization (docs/SCENARIOS.md §Topology).
    pub fn to_json(&self) -> Json {
        match self {
            TopologySpec::Flat => Json::obj().set("preset", "flat"),
            TopologySpec::TwoTier { rack_size, oversubscription } => Json::obj()
                .set("preset", "two-tier")
                .set("rack_size", *rack_size)
                .set("oversubscription", *oversubscription),
            TopologySpec::Heterogeneous { nics } => Json::obj()
                .set("preset", "heterogeneous")
                .set("nics", Json::Arr(nics.iter().map(CommModel::to_json).collect())),
        }
    }

    pub fn from_json(v: &Json) -> Result<TopologySpec, String> {
        match v.req_str("preset")? {
            "flat" => Ok(TopologySpec::Flat),
            "two-tier" | "two_tier" | "2tier" => Ok(TopologySpec::TwoTier {
                rack_size: v.req_usize("rack_size")?,
                oversubscription: v.req_f64("oversubscription")?,
            }),
            "heterogeneous" | "hetero" => {
                let arr = v
                    .get("nics")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| "heterogeneous topology needs a 'nics' array".to_string())?;
                Ok(TopologySpec::Heterogeneous {
                    nics: arr.iter().map(CommModel::from_json).collect::<Result<_, _>>()?,
                })
            }
            other => {
                Err(format!("unknown topology preset '{other}' (flat|two-tier|heterogeneous)"))
            }
        }
    }
}

/// What a link physically is (for diagnostics and tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkKind {
    /// Server NIC. `LinkId` == `ServerId` for these.
    Nic(ServerId),
    /// Shared rack-to-core uplink.
    RackUplink(usize),
}

/// One physical link with its own Eq (2)/(5) parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct Link {
    pub kind: LinkKind,
    pub model: CommModel,
}

/// A built fabric: resolves the server set of a transfer to the links it
/// crosses. Construction validates the spec (`Scenario` loading validates
/// earlier, so scenario-driven runs never hit the error path here).
#[derive(Clone, Debug)]
pub struct Topology {
    n_servers: usize,
    /// Servers per rack; `n_servers` when the fabric has no rack tier.
    rack_size: usize,
    /// Whether rack uplinks exist (two-tier).
    has_uplinks: bool,
    /// NIC links `[0, n_servers)`, then rack uplinks.
    links: Vec<Link>,
}

impl Topology {
    pub fn build(
        cluster: &ClusterSpec,
        base: &CommModel,
        spec: &TopologySpec,
    ) -> Result<Topology, String> {
        spec.validate(cluster)?;
        let n = cluster.n_servers;
        let mut links: Vec<Link> =
            (0..n).map(|s| Link { kind: LinkKind::Nic(s), model: *base }).collect();
        match spec {
            TopologySpec::Flat => Ok(Topology {
                n_servers: n,
                rack_size: n.max(1),
                has_uplinks: false,
                links,
            }),
            TopologySpec::TwoTier { rack_size, oversubscription } => {
                let rs = (*rack_size).clamp(1, n.max(1));
                let up = base.scaled(*oversubscription);
                for r in 0..cluster.n_racks(rs) {
                    links.push(Link { kind: LinkKind::RackUplink(r), model: up });
                }
                Ok(Topology { n_servers: n, rack_size: rs, has_uplinks: true, links })
            }
            TopologySpec::Heterogeneous { nics } => {
                for (s, m) in nics.iter().enumerate() {
                    links[s].model = *m;
                }
                Ok(Topology {
                    n_servers: n,
                    rack_size: n.max(1),
                    has_uplinks: false,
                    links,
                })
            }
        }
    }

    pub fn n_links(&self) -> usize {
        self.links.len()
    }

    pub fn link(&self, l: LinkId) -> &Link {
        &self.links[l]
    }

    /// Eq (2)/(5) parameters of link `l`.
    pub fn link_model(&self, l: LinkId) -> &CommModel {
        &self.links[l].model
    }

    pub fn rack_of(&self, server: ServerId) -> usize {
        server / self.rack_size
    }

    /// Links crossed by an All-Reduce spanning `servers` (the sorted,
    /// deduped set from `ClusterSpec::servers_of`): every server's NIC,
    /// plus — when the transfer leaves a rack — each involved rack's core
    /// uplink. In a flat fabric this is exactly `servers`, which is what
    /// makes the flat preset reproduce the seed per-server bookkeeping.
    pub fn links_between(&self, servers: &[ServerId]) -> Vec<LinkId> {
        let mut out: Vec<LinkId> = servers.to_vec();
        if self.has_uplinks && !servers.is_empty() {
            let mut racks: Vec<usize> = servers.iter().map(|&s| self.rack_of(s)).collect();
            racks.sort_unstable();
            racks.dedup();
            if racks.len() > 1 {
                for r in racks {
                    out.push(self.n_servers + r);
                }
            }
        }
        out
    }

    /// Worst-case (idle-fabric) latency over a link set: the max Eq (2)
    /// `a` among the crossed links. Uniform fabrics reduce to the base
    /// model's `a` exactly.
    pub fn latency_over(&self, links: &[LinkId]) -> f64 {
        links.iter().map(|&l| self.links[l].model.a).fold(0.0, f64::max)
    }
}

/// Do two sorted link sets share a link? ([`Topology::links_between`]
/// returns sorted ids — NICs ascending, then uplinks above them — so the
/// simulator's disjointness checks are a linear merge scan, not a
/// quadratic membership test.)
pub fn links_intersect(a: &[LinkId], b: &[LinkId]) -> bool {
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => return true,
        }
    }
    false
}

/// Per-link active-task membership a [`sched::NetView`](crate::sched::NetView)
/// can read: anything exposing a task-id slice per fabric link. Lets the
/// admission view run over either the classic nested `Vec<Vec<usize>>`
/// (tests, the materialized twin) or the engine's flat [`LinkLists`]
/// slab without copying.
pub trait LinkTasks {
    /// Number of fabric links covered.
    fn n_links(&self) -> usize;
    /// Active comm-task ids on `link`.
    fn tasks(&self, link: LinkId) -> &[usize];
}

impl LinkTasks for [Vec<usize>] {
    fn n_links(&self) -> usize {
        self.len()
    }

    fn tasks(&self, link: LinkId) -> &[usize] {
        &self[link]
    }
}

impl LinkTasks for Vec<Vec<usize>> {
    fn n_links(&self) -> usize {
        self.len()
    }

    fn tasks(&self, link: LinkId) -> &[usize] {
        &self[link]
    }
}

impl LinkTasks for LinkLists {
    fn n_links(&self) -> usize {
        LinkLists::n_links(self)
    }

    fn tasks(&self, link: LinkId) -> &[usize] {
        LinkLists::tasks(self, link)
    }
}

/// Flat structure-of-arrays per-link membership lists — the hot-path
/// replacement for the engine's `per_link: Vec<Vec<usize>>`.
///
/// The nested layout paid one heap allocation per link up front, one
/// pointer chase per occupancy probe, and scattered every link's list
/// across the heap; under contention the admission view walks several
/// links per decision, so the probes dominate. This slab keeps every
/// list in **one** contiguous allocation, row `l` occupying
/// `data[l*stride .. l*stride + lens[l]]`. Occupancy is a single indexed
/// load from `lens`; a task-id slice is a bounds-computed subslice of
/// `data`; push and swap-remove are O(1) writes with no allocator
/// traffic in steady state.
///
/// `stride` is the per-link capacity; when any link outgrows it the
/// whole slab rebuilds at double the stride (amortized like `Vec`
/// growth: O(links) moves per doubling, a handful of doublings over a
/// run). Real contention levels are small — the paper's policies cap
/// useful k at 2–3 — so the default stride of 4 makes rebuilds rare.
#[derive(Clone, Debug)]
pub struct LinkLists {
    /// Per-link row capacity (doubles on overflow).
    stride: usize,
    /// Live length of each row.
    lens: Vec<u32>,
    /// Row-major id storage: row `l` is `data[l*stride..]`.
    data: Vec<usize>,
}

impl LinkLists {
    /// Empty lists for `n_links` links at the default stride.
    pub fn new(n_links: usize) -> LinkLists {
        LinkLists::with_stride(n_links, 4)
    }

    /// Empty lists with an explicit initial per-link capacity.
    pub fn with_stride(n_links: usize, stride: usize) -> LinkLists {
        let stride = stride.max(1);
        LinkLists { stride, lens: vec![0; n_links], data: vec![0; n_links * stride] }
    }

    /// Number of fabric links covered.
    pub fn n_links(&self) -> usize {
        self.lens.len()
    }

    /// Active-task count on `link`.
    pub fn len(&self, link: LinkId) -> usize {
        self.lens[link] as usize
    }

    /// Whether `link` carries no active task.
    pub fn is_empty(&self, link: LinkId) -> bool {
        self.lens[link] == 0
    }

    /// Active task ids on `link`, in insertion (swap-remove-perturbed)
    /// order — the same order the nested layout maintained.
    pub fn tasks(&self, link: LinkId) -> &[usize] {
        let o = link * self.stride;
        &self.data[o..o + self.lens[link] as usize]
    }

    /// The id at `pos` of `link`'s row, if still in bounds — the
    /// "who moved into the vacated slot" probe after a swap-remove.
    pub fn get(&self, link: LinkId, pos: usize) -> Option<usize> {
        (pos < self.lens[link] as usize).then(|| self.data[link * self.stride + pos])
    }

    /// Append `id` to `link`'s row (O(1); doubles the slab stride first
    /// if the row is full).
    pub fn push(&mut self, link: LinkId, id: usize) {
        if self.lens[link] as usize == self.stride {
            self.grow();
        }
        self.data[link * self.stride + self.lens[link] as usize] = id;
        self.lens[link] += 1;
    }

    /// Remove and return the id at `pos` of `link`'s row by moving the
    /// row's last id into its place — `Vec::swap_remove` semantics, so
    /// the engine's recorded `link_pos` bookkeeping carries over
    /// unchanged.
    pub fn swap_remove(&mut self, link: LinkId, pos: usize) -> usize {
        let n = self.lens[link] as usize;
        assert!(pos < n, "swap_remove past the end of link {link}'s row");
        let o = link * self.stride;
        let v = self.data[o + pos];
        self.data[o + pos] = self.data[o + n - 1];
        self.lens[link] -= 1;
        v
    }

    /// Total active entries over all rows (duplicates across links count
    /// once per row, matching the nested layout's sum of lengths).
    pub fn total(&self) -> usize {
        self.lens.iter().map(|&n| n as usize).sum()
    }

    /// Rebuild the slab at double the stride, preserving every row.
    fn grow(&mut self) {
        let new_stride = self.stride * 2;
        let mut data = vec![0usize; self.lens.len() * new_stride];
        for l in 0..self.lens.len() {
            let n = self.lens[l] as usize;
            data[l * new_stride..l * new_stride + n]
                .copy_from_slice(&self.data[l * self.stride..l * self.stride + n]);
        }
        self.stride = new_stride;
        self.data = data;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> CommModel {
        CommModel::paper_10gbe()
    }

    fn cluster(n: usize) -> ClusterSpec {
        ClusterSpec::tiny(n, 4)
    }

    #[test]
    fn flat_links_are_server_nics() {
        let t = Topology::build(&cluster(4), &base(), &TopologySpec::Flat).unwrap();
        assert_eq!(t.n_links(), 4);
        assert_eq!(t.links_between(&[1, 3]), vec![1, 3]);
        assert_eq!(t.links_between(&[0]), vec![0]);
        assert_eq!(t.link(2).kind, LinkKind::Nic(2));
        assert_eq!(*t.link_model(2), base());
    }

    #[test]
    fn two_tier_within_rack_stays_off_the_core() {
        let spec = TopologySpec::TwoTier { rack_size: 2, oversubscription: 4.0 };
        let t = Topology::build(&cluster(4), &base(), &spec).unwrap();
        assert_eq!(t.n_links(), 6); // 4 NICs + 2 rack uplinks
        // Servers 0,1 share rack 0: NICs only.
        assert_eq!(t.links_between(&[0, 1]), vec![0, 1]);
        // Servers 1,2 span racks 0 and 1: NICs + both uplinks.
        assert_eq!(t.links_between(&[1, 2]), vec![1, 2, 4, 5]);
        assert_eq!(t.link(4).kind, LinkKind::RackUplink(0));
    }

    #[test]
    fn two_tier_uplink_is_oversubscribed() {
        let spec = TopologySpec::TwoTier { rack_size: 2, oversubscription: 4.0 };
        let t = Topology::build(&cluster(4), &base(), &spec).unwrap();
        let nic = t.link_model(0);
        let up = t.link_model(4);
        assert_eq!(up.a, nic.a);
        assert_eq!(up.b, 4.0 * nic.b);
        assert_eq!(up.eta, 4.0 * nic.eta);
    }

    #[test]
    fn two_tier_partial_last_rack() {
        let spec = TopologySpec::TwoTier { rack_size: 2, oversubscription: 2.0 };
        let t = Topology::build(&cluster(5), &base(), &spec).unwrap();
        assert_eq!(t.n_links(), 5 + 3); // racks {0,1} {2,3} {4}
        assert_eq!(t.rack_of(4), 2);
        assert_eq!(t.links_between(&[3, 4]), vec![3, 4, 5 + 1, 5 + 2]);
    }

    #[test]
    fn heterogeneous_keeps_per_server_models() {
        let slow = base();
        let fast = base().scaled(1.0 / 4.0);
        let spec = TopologySpec::Heterogeneous { nics: vec![slow, fast] };
        let t = Topology::build(&cluster(2), &base(), &spec).unwrap();
        assert_eq!(t.links_between(&[0, 1]), vec![0, 1]);
        assert_eq!(*t.link_model(0), slow);
        assert_eq!(*t.link_model(1), fast);
    }

    #[test]
    fn latency_over_uniform_links_is_base_latency() {
        let t = Topology::build(&cluster(4), &base(), &TopologySpec::Flat).unwrap();
        assert_eq!(t.latency_over(&[0, 2, 3]), base().a);
    }

    #[test]
    fn validate_rejects_bad_specs() {
        let c = cluster(4);
        let e = TopologySpec::TwoTier { rack_size: 0, oversubscription: 2.0 }
            .validate(&c)
            .unwrap_err();
        assert!(e.contains("rack_size"), "{e}");
        for bad in [0.5, 0.0, -1.0, f64::NAN, f64::INFINITY] {
            let e = TopologySpec::TwoTier { rack_size: 2, oversubscription: bad }
                .validate(&c)
                .unwrap_err();
            assert!(e.contains("oversubscription"), "{e}");
        }
        let e = TopologySpec::Heterogeneous { nics: vec![base(); 3] }
            .validate(&c)
            .unwrap_err();
        assert!(e.contains("one NIC model per server"), "{e}");
        assert!(TopologySpec::Flat.validate(&c).is_ok());
    }

    #[test]
    fn json_roundtrip_all_presets() {
        let specs = [
            TopologySpec::Flat,
            TopologySpec::TwoTier { rack_size: 4, oversubscription: 8.0 },
            TopologySpec::Heterogeneous { nics: vec![base(), base().scaled(2.5)] },
        ];
        for spec in specs {
            let back = TopologySpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(spec, back);
        }
    }

    #[test]
    fn json_rejects_unknown_preset() {
        let v = Json::obj().set("preset", "dragonfly");
        let e = TopologySpec::from_json(&v).unwrap_err();
        assert!(e.contains("unknown topology preset 'dragonfly'"), "{e}");
    }

    #[test]
    fn spec_n_links_matches_build() {
        let c = cluster(5);
        for spec in [
            TopologySpec::Flat,
            TopologySpec::TwoTier { rack_size: 2, oversubscription: 2.0 },
            TopologySpec::Heterogeneous { nics: vec![base(); 5] },
        ] {
            let t = Topology::build(&c, &base(), &spec).unwrap();
            assert_eq!(spec.n_links(&c), t.n_links(), "{spec:?}");
        }
    }

    #[test]
    fn rack_size_accessor() {
        assert_eq!(TopologySpec::Flat.rack_size(), usize::MAX);
        assert_eq!(
            TopologySpec::TwoTier { rack_size: 8, oversubscription: 2.0 }.rack_size(),
            8
        );
    }

    #[test]
    fn links_intersect_merge_scan() {
        assert!(links_intersect(&[0, 3, 7], &[1, 2, 3]));
        assert!(!links_intersect(&[0, 4], &[1, 2, 3, 5]));
        assert!(!links_intersect(&[], &[1, 2]));
        assert!(!links_intersect(&[1, 2], &[]));
        // links_between output stays sorted (NICs, then uplinks) — the
        // precondition the merge scan depends on.
        let spec = TopologySpec::TwoTier { rack_size: 2, oversubscription: 4.0 };
        let t = Topology::build(&cluster(4), &base(), &spec).unwrap();
        let ls = t.links_between(&[1, 2]);
        assert!(ls.windows(2).all(|w| w[0] < w[1]), "{ls:?}");
    }

    #[test]
    fn labels() {
        assert_eq!(TopologySpec::Flat.label(), None);
        assert_eq!(
            TopologySpec::TwoTier { rack_size: 4, oversubscription: 4.0 }.label().unwrap(),
            "2tier-4:1"
        );
    }

    #[test]
    fn link_lists_push_remove_get() {
        let mut ll = LinkLists::with_stride(3, 2);
        assert_eq!(ll.n_links(), 3);
        assert!(ll.is_empty(1));
        ll.push(1, 10);
        ll.push(1, 11);
        ll.push(2, 20);
        assert_eq!(ll.tasks(1), &[10, 11]);
        assert_eq!(ll.len(1), 2);
        assert_eq!(ll.total(), 3);
        // Vec::swap_remove semantics: the last id moves into the hole.
        assert_eq!(ll.swap_remove(1, 0), 10);
        assert_eq!(ll.tasks(1), &[11]);
        assert_eq!(ll.get(1, 0), Some(11));
        assert_eq!(ll.get(1, 1), None);
        assert_eq!(ll.swap_remove(2, 0), 20);
        assert!(ll.is_empty(2));
        assert!(ll.is_empty(0));
    }

    #[test]
    fn link_lists_grow_preserves_rows() {
        let mut ll = LinkLists::with_stride(4, 1);
        for id in 0..9 {
            ll.push(2, id); // forces several stride doublings
        }
        ll.push(0, 100);
        assert_eq!(ll.tasks(2), &[0, 1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(ll.tasks(0), &[100]);
        assert!(ll.is_empty(1) && ll.is_empty(3));
    }

    #[test]
    fn prop_link_lists_equivalent_to_nested_vecs() {
        // The slab must behave exactly like the Vec<Vec<usize>> it
        // replaced under any interleaving of push / swap_remove — same
        // slices, same swap-remove returns, same "who moved" probes.
        crate::util::prop::prop_check(40, |g| {
            let n_links = g.usize(1, 6);
            let mut model: Vec<Vec<usize>> = vec![Vec::new(); n_links];
            let mut ll = LinkLists::with_stride(n_links, 1);
            for id in 0..g.usize(1, 60) {
                let l = g.usize(0, n_links - 1);
                if g.bool() || model[l].is_empty() {
                    model[l].push(id);
                    ll.push(l, id);
                } else {
                    let pos = g.usize(0, model[l].len() - 1);
                    let want = model[l].swap_remove(pos);
                    let got = ll.swap_remove(l, pos);
                    if want != got {
                        return Err(format!("swap_remove({l},{pos}): {got} vs {want}"));
                    }
                    let moved = ll.get(l, pos);
                    if moved != model[l].get(pos).copied() {
                        return Err(format!("get after remove diverged: {moved:?}"));
                    }
                }
                for (l, row) in model.iter().enumerate() {
                    if ll.tasks(l) != &row[..] {
                        return Err(format!(
                            "row {l} diverged: {:?} vs {row:?}",
                            ll.tasks(l)
                        ));
                    }
                }
            }
            Ok(())
        });
    }
}
