//! Link-level network fabric model — the topology-aware generalisation of
//! the paper's flat per-server contention bookkeeping.
//!
//! The paper's testbed (§V-A) is 16 servers behind one non-blocking
//! switch, so its Eq (5) contention level is simply "active communication
//! tasks per server NIC". That stops being true the moment the cluster
//! has racks with oversubscribed core uplinks or mixed-bandwidth NICs —
//! the regimes where placement sensitivity actually dominates JCT. This
//! module models the fabric as a set of [`Link`]s, each with its own
//! [`CommModel`] parameters; an All-Reduce spanning a server set crosses
//! `links_between(servers)` and its effective contention level k and
//! per-byte drain time are the **max over the links it crosses** (the
//! bottleneck link), not the max over server NIC counts.
//!
//! Presets ([`TopologySpec`], the scenario-file `topology` section —
//! docs/SCENARIOS.md):
//!
//! * `flat` — one NIC link per server, all with the base comm model.
//!   `LinkId` == `ServerId`, so contention counts reduce *exactly* to the
//!   paper's per-server counts: a flat scenario reproduces the seed
//!   engine's contention structure exactly, and its timing to within
//!   the ulp-level residual-arithmetic change described in
//!   docs/EXPERIMENTS.md §Oversub (property-tested in `sim::tests`).
//! * `two-tier` — racks of `rack_size` servers; cross-rack transfers
//!   additionally cross each involved rack's core uplink, whose per-byte
//!   constants are the base model's scaled by the `oversubscription`
//!   ratio (a 4:1 oversubscribed core drains bytes 4x slower).
//! * `heterogeneous` — flat structure with explicit per-server NIC
//!   [`CommModel`]s (mixed 10/25/100 GbE fleets).

use crate::cluster::{ClusterSpec, ServerId};
use crate::model::CommModel;
use crate::util::json::Json;

/// Index into a [`Topology`]'s link table. In a `flat` fabric link ids
/// coincide with server ids; rack uplinks are appended after the NICs.
pub type LinkId = usize;

/// Rack width used when an oversubscription sweep starts from a rackless
/// base topology: the paper's 16 servers split into 4 racks of 4.
pub const DEFAULT_RACK_SIZE: usize = 4;

/// Canonical scenario-file topology preset names, in schema order
/// (`ddl-sched simulate --list` prints these for scenario authors).
pub const TOPOLOGY_PRESETS: [&str; 3] = ["flat", "two-tier", "heterogeneous"];

/// Declarative topology description — what scenario files carry.
#[derive(Clone, Debug, PartialEq, Default)]
pub enum TopologySpec {
    /// One non-blocking switch (the paper's testbed). The default.
    #[default]
    Flat,
    /// Racks of `rack_size` servers behind a shared core with the given
    /// downlink:uplink `oversubscription` ratio (1.0 = non-blocking).
    TwoTier { rack_size: usize, oversubscription: f64 },
    /// Flat structure, but each server NIC has its own comm model
    /// (`nics[s]` is server `s`'s link parameters).
    Heterogeneous { nics: Vec<CommModel> },
}

impl TopologySpec {
    /// Canonical scenario-file preset name.
    pub fn preset(&self) -> &'static str {
        match self {
            TopologySpec::Flat => "flat",
            TopologySpec::TwoTier { .. } => "two-tier",
            TopologySpec::Heterogeneous { .. } => "heterogeneous",
        }
    }

    pub fn is_flat(&self) -> bool {
        matches!(self, TopologySpec::Flat)
    }

    /// Servers per rack, for rack-locality-aware placement. Fabrics
    /// without a rack tier report `usize::MAX` ("everything is one
    /// rack"); consumers clamp to the cluster size.
    pub fn rack_size(&self) -> usize {
        match self {
            TopologySpec::TwoTier { rack_size, .. } => *rack_size,
            _ => usize::MAX,
        }
    }

    /// Method-label suffix for non-default fabrics (`None` for flat, so
    /// paper labels are untouched).
    pub fn label(&self) -> Option<String> {
        match self {
            TopologySpec::Flat => None,
            TopologySpec::TwoTier { oversubscription, .. } => {
                Some(format!("2tier-{oversubscription}:1"))
            }
            TopologySpec::Heterogeneous { .. } => Some("hetero".to_string()),
        }
    }

    /// Validate against the cluster this topology will be built over.
    pub fn validate(&self, cluster: &ClusterSpec) -> Result<(), String> {
        match self {
            TopologySpec::Flat => Ok(()),
            TopologySpec::TwoTier { rack_size, oversubscription } => {
                if *rack_size == 0 {
                    return Err("two-tier topology needs rack_size >= 1".to_string());
                }
                if !oversubscription.is_finite() || *oversubscription < 1.0 {
                    return Err(format!(
                        "invalid oversubscription {oversubscription}: must be a finite ratio >= 1"
                    ));
                }
                Ok(())
            }
            TopologySpec::Heterogeneous { nics } => {
                if nics.len() != cluster.n_servers {
                    return Err(format!(
                        "heterogeneous topology needs one NIC model per server: \
                         got {} for {} servers",
                        nics.len(),
                        cluster.n_servers
                    ));
                }
                Ok(())
            }
        }
    }

    /// Scenario-file serialization (docs/SCENARIOS.md §Topology).
    pub fn to_json(&self) -> Json {
        match self {
            TopologySpec::Flat => Json::obj().set("preset", "flat"),
            TopologySpec::TwoTier { rack_size, oversubscription } => Json::obj()
                .set("preset", "two-tier")
                .set("rack_size", *rack_size)
                .set("oversubscription", *oversubscription),
            TopologySpec::Heterogeneous { nics } => Json::obj()
                .set("preset", "heterogeneous")
                .set("nics", Json::Arr(nics.iter().map(CommModel::to_json).collect())),
        }
    }

    pub fn from_json(v: &Json) -> Result<TopologySpec, String> {
        match v.req_str("preset")? {
            "flat" => Ok(TopologySpec::Flat),
            "two-tier" | "two_tier" | "2tier" => Ok(TopologySpec::TwoTier {
                rack_size: v.req_usize("rack_size")?,
                oversubscription: v.req_f64("oversubscription")?,
            }),
            "heterogeneous" | "hetero" => {
                let arr = v
                    .get("nics")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| "heterogeneous topology needs a 'nics' array".to_string())?;
                Ok(TopologySpec::Heterogeneous {
                    nics: arr.iter().map(CommModel::from_json).collect::<Result<_, _>>()?,
                })
            }
            other => {
                Err(format!("unknown topology preset '{other}' (flat|two-tier|heterogeneous)"))
            }
        }
    }
}

/// What a link physically is (for diagnostics and tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkKind {
    /// Server NIC. `LinkId` == `ServerId` for these.
    Nic(ServerId),
    /// Shared rack-to-core uplink.
    RackUplink(usize),
}

/// One physical link with its own Eq (2)/(5) parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct Link {
    pub kind: LinkKind,
    pub model: CommModel,
}

/// A built fabric: resolves the server set of a transfer to the links it
/// crosses. Construction validates the spec (`Scenario` loading validates
/// earlier, so scenario-driven runs never hit the error path here).
#[derive(Clone, Debug)]
pub struct Topology {
    n_servers: usize,
    /// Servers per rack; `n_servers` when the fabric has no rack tier.
    rack_size: usize,
    /// Whether rack uplinks exist (two-tier).
    has_uplinks: bool,
    /// NIC links `[0, n_servers)`, then rack uplinks.
    links: Vec<Link>,
}

impl Topology {
    pub fn build(
        cluster: &ClusterSpec,
        base: &CommModel,
        spec: &TopologySpec,
    ) -> Result<Topology, String> {
        spec.validate(cluster)?;
        let n = cluster.n_servers;
        let mut links: Vec<Link> =
            (0..n).map(|s| Link { kind: LinkKind::Nic(s), model: *base }).collect();
        match spec {
            TopologySpec::Flat => Ok(Topology {
                n_servers: n,
                rack_size: n.max(1),
                has_uplinks: false,
                links,
            }),
            TopologySpec::TwoTier { rack_size, oversubscription } => {
                let rs = (*rack_size).clamp(1, n.max(1));
                let up = base.scaled(*oversubscription);
                for r in 0..cluster.n_racks(rs) {
                    links.push(Link { kind: LinkKind::RackUplink(r), model: up });
                }
                Ok(Topology { n_servers: n, rack_size: rs, has_uplinks: true, links })
            }
            TopologySpec::Heterogeneous { nics } => {
                for (s, m) in nics.iter().enumerate() {
                    links[s].model = *m;
                }
                Ok(Topology {
                    n_servers: n,
                    rack_size: n.max(1),
                    has_uplinks: false,
                    links,
                })
            }
        }
    }

    pub fn n_links(&self) -> usize {
        self.links.len()
    }

    pub fn link(&self, l: LinkId) -> &Link {
        &self.links[l]
    }

    /// Eq (2)/(5) parameters of link `l`.
    pub fn link_model(&self, l: LinkId) -> &CommModel {
        &self.links[l].model
    }

    pub fn rack_of(&self, server: ServerId) -> usize {
        server / self.rack_size
    }

    /// Links crossed by an All-Reduce spanning `servers` (the sorted,
    /// deduped set from `ClusterSpec::servers_of`): every server's NIC,
    /// plus — when the transfer leaves a rack — each involved rack's core
    /// uplink. In a flat fabric this is exactly `servers`, which is what
    /// makes the flat preset reproduce the seed per-server bookkeeping.
    pub fn links_between(&self, servers: &[ServerId]) -> Vec<LinkId> {
        let mut out: Vec<LinkId> = servers.to_vec();
        if self.has_uplinks && !servers.is_empty() {
            let mut racks: Vec<usize> = servers.iter().map(|&s| self.rack_of(s)).collect();
            racks.sort_unstable();
            racks.dedup();
            if racks.len() > 1 {
                for r in racks {
                    out.push(self.n_servers + r);
                }
            }
        }
        out
    }

    /// Worst-case (idle-fabric) latency over a link set: the max Eq (2)
    /// `a` among the crossed links. Uniform fabrics reduce to the base
    /// model's `a` exactly.
    pub fn latency_over(&self, links: &[LinkId]) -> f64 {
        links.iter().map(|&l| self.links[l].model.a).fold(0.0, f64::max)
    }
}

/// Do two sorted link sets share a link? ([`Topology::links_between`]
/// returns sorted ids — NICs ascending, then uplinks above them — so the
/// simulator's disjointness checks are a linear merge scan, not a
/// quadratic membership test.)
pub fn links_intersect(a: &[LinkId], b: &[LinkId]) -> bool {
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => return true,
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> CommModel {
        CommModel::paper_10gbe()
    }

    fn cluster(n: usize) -> ClusterSpec {
        ClusterSpec::tiny(n, 4)
    }

    #[test]
    fn flat_links_are_server_nics() {
        let t = Topology::build(&cluster(4), &base(), &TopologySpec::Flat).unwrap();
        assert_eq!(t.n_links(), 4);
        assert_eq!(t.links_between(&[1, 3]), vec![1, 3]);
        assert_eq!(t.links_between(&[0]), vec![0]);
        assert_eq!(t.link(2).kind, LinkKind::Nic(2));
        assert_eq!(*t.link_model(2), base());
    }

    #[test]
    fn two_tier_within_rack_stays_off_the_core() {
        let spec = TopologySpec::TwoTier { rack_size: 2, oversubscription: 4.0 };
        let t = Topology::build(&cluster(4), &base(), &spec).unwrap();
        assert_eq!(t.n_links(), 6); // 4 NICs + 2 rack uplinks
        // Servers 0,1 share rack 0: NICs only.
        assert_eq!(t.links_between(&[0, 1]), vec![0, 1]);
        // Servers 1,2 span racks 0 and 1: NICs + both uplinks.
        assert_eq!(t.links_between(&[1, 2]), vec![1, 2, 4, 5]);
        assert_eq!(t.link(4).kind, LinkKind::RackUplink(0));
    }

    #[test]
    fn two_tier_uplink_is_oversubscribed() {
        let spec = TopologySpec::TwoTier { rack_size: 2, oversubscription: 4.0 };
        let t = Topology::build(&cluster(4), &base(), &spec).unwrap();
        let nic = t.link_model(0);
        let up = t.link_model(4);
        assert_eq!(up.a, nic.a);
        assert_eq!(up.b, 4.0 * nic.b);
        assert_eq!(up.eta, 4.0 * nic.eta);
    }

    #[test]
    fn two_tier_partial_last_rack() {
        let spec = TopologySpec::TwoTier { rack_size: 2, oversubscription: 2.0 };
        let t = Topology::build(&cluster(5), &base(), &spec).unwrap();
        assert_eq!(t.n_links(), 5 + 3); // racks {0,1} {2,3} {4}
        assert_eq!(t.rack_of(4), 2);
        assert_eq!(t.links_between(&[3, 4]), vec![3, 4, 5 + 1, 5 + 2]);
    }

    #[test]
    fn heterogeneous_keeps_per_server_models() {
        let slow = base();
        let fast = base().scaled(1.0 / 4.0);
        let spec = TopologySpec::Heterogeneous { nics: vec![slow, fast] };
        let t = Topology::build(&cluster(2), &base(), &spec).unwrap();
        assert_eq!(t.links_between(&[0, 1]), vec![0, 1]);
        assert_eq!(*t.link_model(0), slow);
        assert_eq!(*t.link_model(1), fast);
    }

    #[test]
    fn latency_over_uniform_links_is_base_latency() {
        let t = Topology::build(&cluster(4), &base(), &TopologySpec::Flat).unwrap();
        assert_eq!(t.latency_over(&[0, 2, 3]), base().a);
    }

    #[test]
    fn validate_rejects_bad_specs() {
        let c = cluster(4);
        let e = TopologySpec::TwoTier { rack_size: 0, oversubscription: 2.0 }
            .validate(&c)
            .unwrap_err();
        assert!(e.contains("rack_size"), "{e}");
        for bad in [0.5, 0.0, -1.0, f64::NAN, f64::INFINITY] {
            let e = TopologySpec::TwoTier { rack_size: 2, oversubscription: bad }
                .validate(&c)
                .unwrap_err();
            assert!(e.contains("oversubscription"), "{e}");
        }
        let e = TopologySpec::Heterogeneous { nics: vec![base(); 3] }
            .validate(&c)
            .unwrap_err();
        assert!(e.contains("one NIC model per server"), "{e}");
        assert!(TopologySpec::Flat.validate(&c).is_ok());
    }

    #[test]
    fn json_roundtrip_all_presets() {
        let specs = [
            TopologySpec::Flat,
            TopologySpec::TwoTier { rack_size: 4, oversubscription: 8.0 },
            TopologySpec::Heterogeneous { nics: vec![base(), base().scaled(2.5)] },
        ];
        for spec in specs {
            let back = TopologySpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(spec, back);
        }
    }

    #[test]
    fn json_rejects_unknown_preset() {
        let v = Json::obj().set("preset", "dragonfly");
        let e = TopologySpec::from_json(&v).unwrap_err();
        assert!(e.contains("unknown topology preset 'dragonfly'"), "{e}");
    }

    #[test]
    fn rack_size_accessor() {
        assert_eq!(TopologySpec::Flat.rack_size(), usize::MAX);
        assert_eq!(
            TopologySpec::TwoTier { rack_size: 8, oversubscription: 2.0 }.rack_size(),
            8
        );
    }

    #[test]
    fn links_intersect_merge_scan() {
        assert!(links_intersect(&[0, 3, 7], &[1, 2, 3]));
        assert!(!links_intersect(&[0, 4], &[1, 2, 3, 5]));
        assert!(!links_intersect(&[], &[1, 2]));
        assert!(!links_intersect(&[1, 2], &[]));
        // links_between output stays sorted (NICs, then uplinks) — the
        // precondition the merge scan depends on.
        let spec = TopologySpec::TwoTier { rack_size: 2, oversubscription: 4.0 };
        let t = Topology::build(&cluster(4), &base(), &spec).unwrap();
        let ls = t.links_between(&[1, 2]);
        assert!(ls.windows(2).all(|w| w[0] < w[1]), "{ls:?}");
    }

    #[test]
    fn labels() {
        assert_eq!(TopologySpec::Flat.label(), None);
        assert_eq!(
            TopologySpec::TwoTier { rack_size: 4, oversubscription: 4.0 }.label().unwrap(),
            "2tier-4:1"
        );
    }
}
