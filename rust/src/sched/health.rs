//! Failure-aware scheduling primitives (docs/EXPERIMENTS.md §Faults):
//! the deterministic, side-effect-free pieces the engine and the
//! health-aware placer compose under gray failures.
//!
//! * [`backoff_delay`] — capped exponential restart backoff: a job's
//!   n-th preemption waits `min(cap, base * 2^(n-1))` seconds before
//!   requeueing. Pure arithmetic on (base, cap, n), so the schedule is
//!   reproducible and the delay sequence is monotone non-decreasing in n
//!   until it saturates at the cap; a fresh placement resets n.
//! * [`Blacklist`] — sliding-window failure counting per device: after
//!   `k` failures within `window_s`, the device is excluded until the
//!   window drains. The expiry instant is closed-form (k-th most recent
//!   failure + window), so the engine can schedule the un-blacklist as a
//!   plain timeline event.
//! * [`HealthScore`] — per-device EWMA of observed health factors. The
//!   health-aware placer feeds it the live [`HealthView`] factors each
//!   decision and ranks candidate GPUs by blended history, so a device
//!   that keeps flapping scores worse than one that just recovered.
//!
//! [`HealthView`]: crate::fault::HealthView

/// Capped exponential backoff for the `n`-th restart (n >= 1): 0 for
/// n = 0 (never preempted), else `min(cap, base * 2^(n-1))`. The shift
/// saturates at 2^63 before the cap applies, keeping the arithmetic
/// finite for any restart count.
pub fn backoff_delay(base_s: f64, cap_s: f64, restarts: u64) -> f64 {
    if restarts == 0 || base_s <= 0.0 {
        return 0.0;
    }
    let pow = restarts.saturating_sub(1).min(63);
    let delay = base_s * (1u64 << pow) as f64;
    if delay > cap_s { cap_s } else { delay }
}

/// Sliding-window failure counter with closed-form expiry. One instance
/// covers one device class (the engine keeps one sized to its GPU count).
#[derive(Clone, Debug)]
pub struct Blacklist {
    k: usize,
    window_s: f64,
    /// Failure timestamps per device, ascending; pruned lazily to the
    /// window on every touch so memory stays O(k) per device.
    times: Vec<Vec<f64>>,
    active: Vec<bool>,
}

impl Blacklist {
    /// `k` must be >= 1 (0 means "blacklisting off" and the engine never
    /// constructs a Blacklist for it); `window_s` must be positive.
    pub fn new(n_devices: usize, k: usize, window_s: f64) -> Blacklist {
        Blacklist {
            k: k.max(1),
            window_s,
            times: vec![Vec::new(); n_devices],
            active: vec![false; n_devices],
        }
    }

    fn prune(&mut self, dev: usize, now: f64) {
        let cutoff = now - self.window_s;
        let drop = self.times[dev].iter().take_while(|&&t| t <= cutoff).count();
        self.times[dev].drain(..drop);
    }

    /// Record a failure of `dev` at `now`.
    pub fn record_failure(&mut self, dev: usize, now: f64) {
        self.prune(dev, now);
        self.times[dev].push(now);
    }

    /// Number of failures of `dev` still inside the window at `now`.
    pub fn count(&mut self, dev: usize, now: f64) -> usize {
        self.prune(dev, now);
        self.times[dev].len()
    }

    /// Whether the window currently holds >= k failures (the blacklist
    /// condition), independent of the `active` marker.
    pub fn over_threshold(&mut self, dev: usize, now: f64) -> bool {
        self.count(dev, now) >= self.k
    }

    /// The instant the in-window count drops below k if no further
    /// failures occur: the k-th most recent failure leaves the window.
    /// Only meaningful while `over_threshold`.
    pub fn expiry(&mut self, dev: usize, now: f64) -> f64 {
        self.prune(dev, now);
        let n = self.times[dev].len();
        debug_assert!(n >= self.k, "expiry queried below threshold");
        self.times[dev][n - self.k] + self.window_s
    }

    /// The engine's marker for "this device is currently excluded from
    /// placement" — set/cleared by the engine alongside its memory hold.
    pub fn is_active(&self, dev: usize) -> bool {
        self.active[dev]
    }

    pub fn set_active(&mut self, dev: usize, on: bool) {
        self.active[dev] = on;
    }
}

/// Per-device exponentially-weighted moving average of health factors:
/// `score = alpha * sample + (1 - alpha) * score`, seeded at 1.0 (assume
/// healthy until observed otherwise). Scores live in [0, 1] as long as
/// samples do.
#[derive(Clone, Debug)]
pub struct HealthScore {
    alpha: f64,
    gpu: Vec<f64>,
    link: Vec<f64>,
}

impl HealthScore {
    pub const DEFAULT_ALPHA: f64 = 0.3;

    pub fn new(alpha: f64) -> HealthScore {
        HealthScore { alpha, gpu: Vec::new(), link: Vec::new() }
    }

    fn blend(alpha: f64, score: &mut f64, sample: f64) {
        *score = alpha * sample + (1.0 - alpha) * *score;
    }

    /// Fold one observation of every device's current factor into the
    /// running scores, growing the vectors on first sight of a device.
    pub fn observe(&mut self, gpu_factors: &[f64], link_factors: &[f64]) {
        self.gpu.resize(gpu_factors.len().max(self.gpu.len()), 1.0);
        self.link.resize(link_factors.len().max(self.link.len()), 1.0);
        for (score, &f) in self.gpu.iter_mut().zip(gpu_factors) {
            Self::blend(self.alpha, score, f);
        }
        for (score, &f) in self.link.iter_mut().zip(link_factors) {
            Self::blend(self.alpha, score, f);
        }
    }

    /// Blended history for a GPU; 1.0 for a device never observed.
    pub fn gpu(&self, g: usize) -> f64 {
        self.gpu.get(g).copied().unwrap_or(1.0)
    }

    /// Blended history for a link; 1.0 for a link never observed.
    pub fn link(&self, l: usize) -> f64 {
        self.link.get(l).copied().unwrap_or(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_monotone_capped_and_resets() {
        let base = 2.0;
        let cap = 50.0;
        assert_eq!(backoff_delay(base, cap, 0), 0.0, "never-preempted job waits nothing");
        let delays: Vec<f64> = (1..12).map(|n| backoff_delay(base, cap, n)).collect();
        assert_eq!(&delays[..5], &[2.0, 4.0, 8.0, 16.0, 32.0]);
        assert!(delays.windows(2).all(|w| w[0] <= w[1]), "monotone: {delays:?}");
        assert!(delays[5..].iter().all(|&d| d == cap), "saturates at cap: {delays:?}");
        // "Reset" is the caller passing restarts = 1 again after a clean
        // stretch: the delay returns to the base.
        assert_eq!(backoff_delay(base, cap, 1), 2.0);
        // Off switch and overflow safety.
        assert_eq!(backoff_delay(0.0, cap, 9), 0.0);
        assert_eq!(backoff_delay(base, cap, u64::MAX), cap);
    }

    #[test]
    fn blacklist_window_counts_and_expires() {
        let mut bl = Blacklist::new(2, 3, 10.0);
        bl.record_failure(0, 1.0);
        bl.record_failure(0, 4.0);
        assert!(!bl.over_threshold(0, 4.0));
        bl.record_failure(0, 6.0);
        assert!(bl.over_threshold(0, 6.0));
        // k-th most recent failure is at t=1; it leaves the window at 11.
        assert_eq!(bl.expiry(0, 6.0), 11.0);
        // At t=11 the count is 2 again (failure at t=1 aged out).
        assert!(!bl.over_threshold(0, 11.0));
        assert_eq!(bl.count(0, 11.0), 2);
        // A later failure re-arms with a later expiry.
        bl.record_failure(0, 12.0);
        assert!(bl.over_threshold(0, 12.0));
        assert_eq!(bl.expiry(0, 12.0), 14.0, "k-th most recent is now t=4");
        // Device 1 is independent.
        assert!(!bl.over_threshold(1, 12.0));
        // Active marker is engine-owned state.
        assert!(!bl.is_active(0));
        bl.set_active(0, true);
        assert!(bl.is_active(0));
    }

    #[test]
    fn health_score_blends_toward_observations() {
        let mut hs = HealthScore::new(0.5);
        assert_eq!(hs.gpu(0), 1.0, "unseen devices assumed healthy");
        hs.observe(&[1.0, 0.0], &[0.5]);
        assert_eq!(hs.gpu(0), 1.0);
        assert_eq!(hs.gpu(1), 0.5);
        assert_eq!(hs.link(0), 0.75);
        hs.observe(&[1.0, 0.0], &[0.5]);
        assert_eq!(hs.gpu(1), 0.25, "repeated failure keeps dragging the score down");
        assert_eq!(hs.link(0), 0.625);
        // Recovery pulls it back up, but history lingers.
        hs.observe(&[1.0, 1.0], &[1.0]);
        assert_eq!(hs.gpu(1), 0.625);
        assert!(hs.gpu(1) < hs.gpu(0), "flapping device scores below steady one");
    }
}
