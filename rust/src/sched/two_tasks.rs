//! Continuous-time micro-simulator of Problem 1 (§IV-B): two communication
//! tasks with message sizes M₁ ≤ M₂ sharing one link under the Eq (5)
//! contention model (latency term neglected, as in the paper's analysis).
//!
//! This is the brute-force oracle the property tests use to verify
//! Theorems 1 and 2 and therefore AdaDUAL's decision rule: the closed-form
//! optima of the paper must match the empirical optimum of this simulator
//! over a dense sweep of start offsets.

use crate::model::CommModel;

/// Completion times (T_a, T_b) when task a (m_a bytes) starts at time 0 and
/// task b (m_b bytes) starts at `start_b >= 0`. Pure Eq (5) dynamics: each
/// task transfers at per-byte time `k·b + (k−1)·η` where k is the number of
/// concurrently active tasks; the latency constant `a` is ignored (P1).
pub fn simulate_pair(cm: &CommModel, m_a: f64, m_b: f64, start_b: f64) -> (f64, f64) {
    assert!(m_a > 0.0 && m_b > 0.0 && start_b >= 0.0);
    let mut t = 0.0f64;
    let mut rem_a = m_a;
    let mut rem_b = m_b;
    let mut b_active = start_b <= 0.0;
    let mut done_a: Option<f64> = None;
    let mut done_b: Option<f64> = None;

    while done_a.is_none() || done_b.is_none() {
        let a_active = done_a.is_none();
        let b_on = b_active && done_b.is_none();
        let k = a_active as usize + b_on as usize;
        if k == 0 {
            // Only b remains but hasn't arrived yet: jump to its start.
            t = start_b;
            b_active = true;
            continue;
        }
        let rate = cm.rate(k); // bytes/s per task
        let drain_a = if a_active { rem_a / rate } else { f64::INFINITY };
        let drain_b = if b_on { rem_b / rate } else { f64::INFINITY };
        let arrive_b = if !b_active { (start_b - t).max(0.0) } else { f64::INFINITY };
        let dt = drain_a.min(drain_b).min(arrive_b);
        if a_active {
            rem_a -= dt * rate;
        }
        if b_on {
            rem_b -= dt * rate;
        }
        t += dt;
        if a_active && rem_a <= 1e-9 {
            done_a = Some(t);
        }
        if b_on && rem_b <= 1e-9 {
            done_b = Some(t);
        }
        if !b_active && (t - start_b).abs() < 1e-12 {
            b_active = true;
        }
    }
    (done_a.unwrap(), done_b.unwrap())
}

/// Mean completion time of the pair for a given start offset of the second
/// task — Eq (9)'s objective.
pub fn mean_completion(cm: &CommModel, m_first: f64, m_second: f64, start_second: f64) -> f64 {
    let (t1, t2) = simulate_pair(cm, m_first, m_second, start_second);
    0.5 * (t1 + t2)
}

/// Closed-form optima from the paper (Eqs 14a–14c), for cross-checking:
/// t̂_C1 = (2bM₁ + bM₂)/2 ; t̂_C2a = ((3b+2η)M₁ + bM₂)/2 ; t̂_C2b = (bM₁ + 2bM₂)/2.
pub fn theorem_optima(cm: &CommModel, m1: f64, m2: f64) -> (f64, f64, f64) {
    let b = cm.b;
    let eta = cm.eta;
    (
        (2.0 * b * m1 + b * m2) / 2.0,
        ((3.0 * b + 2.0 * eta) * m1 + b * m2) / 2.0,
        (b * m1 + 2.0 * b * m2) / 2.0,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::CommModel;
    use crate::util::prop::prop_check;

    fn cm() -> CommModel {
        CommModel::paper_10gbe()
    }

    fn feq(a: f64, b: f64, tol: f64) -> Result<(), String> {
        if (a - b).abs() <= tol * a.abs().max(b.abs()).max(1e-30) {
            Ok(())
        } else {
            Err(format!("{a} != {b}"))
        }
    }

    #[test]
    fn serial_matches_closed_form() {
        // Second task starts exactly when the first finishes: no overlap.
        let c = cm();
        let m1 = 1e8;
        let m2 = 3e8;
        let t1_free = c.b * m1;
        let (ta, tb) = simulate_pair(&c, m1, m2, t1_free);
        assert!((ta - t1_free).abs() < 1e-9);
        assert!((tb - (t1_free + c.b * m2)).abs() < 1e-9);
    }

    #[test]
    fn simultaneous_matches_eq5() {
        // Both start at 0 with equal sizes: both finish at contended time
        // (minus the latency constant which P1 neglects).
        let c = cm();
        let m = 2e8;
        let (ta, tb) = simulate_pair(&c, m, m, 0.0);
        let want = m * c.per_byte(2);
        assert!((ta - want).abs() < 1e-6, "{ta} vs {want}");
        assert!((tb - want).abs() < 1e-6);
    }

    #[test]
    fn theorem1_c1_optimum_at_t1() {
        // C1: small first. Mean completion is minimised by starting the
        // second at t = t1 (no overlap), per Theorem 1.
        let c = cm();
        prop_check(200, |g| {
            let m1 = g.f64(1e6, 5e8);
            let m2 = g.f64(m1, 1e9);
            let t1 = c.b * m1;
            let best = mean_completion(&c, m1, m2, t1);
            // Any earlier start of the big task must be no better.
            let t = g.f64(0.0, t1);
            let other = mean_completion(&c, m1, m2, t);
            if other + 1e-9 < best {
                return Err(format!("overlap at t={t} beat serial: {other} < {best}"));
            }
            // And the simulated optimum must match Eq (14a).
            let (c1, _, _) = theorem_optima(&c, m1, m2);
            feq(best, c1, 1e-6)
        });
    }

    #[test]
    fn theorem2_decision_rule() {
        // C2: big first (it is already flying), a small newcomer arrives.
        // Starting it immediately beats waiting iff M1/M2 < b/(2(b+η)).
        let c = cm();
        let th = c.adadual_threshold();
        prop_check(300, |g| {
            let m2 = g.f64(1e7, 1e9); // existing (big) task
            let ratio = g.f64(0.01, 0.99);
            let m1 = ratio * m2; // newcomer
            let immediate = mean_completion(&c, m2, m1, 0.0);
            let wait = mean_completion(&c, m2, m1, c.b * m2);
            let overlap_better = immediate < wait - 1e-9;
            let rule_says = ratio < th;
            if overlap_better != rule_says && (ratio - th).abs() > 1e-3 {
                return Err(format!(
                    "ratio={ratio:.4} th={th:.4} immediate={immediate} wait={wait}"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn theorem2_interior_never_optimal() {
        // Within C2 the optimum is at t=0 or t=t2, never strictly inside.
        let c = cm();
        prop_check(200, |g| {
            let m2 = g.f64(1e7, 1e9);
            let m1 = g.f64(1e6, m2);
            let t2 = c.b * m2;
            let ends = mean_completion(&c, m2, m1, 0.0).min(mean_completion(&c, m2, m1, t2));
            let t = g.f64(1e-12, t2 * 0.999);
            let mid = mean_completion(&c, m2, m1, t);
            if mid + 1e-9 < ends {
                return Err(format!("interior t={t} beat endpoints: {mid} < {ends}"));
            }
            Ok(())
        });
    }

    #[test]
    fn closed_forms_match_simulator() {
        let c = cm();
        let m1 = 1.2e8;
        let m2 = 6.1e8;
        let (c1, c2a, c2b) = theorem_optima(&c, m1, m2);
        // C1 at t=t1 (small first, serial):
        assert!((mean_completion(&c, m1, m2, c.b * m1) - c1).abs() / c1 < 1e-9);
        // C2a at t=0 (big first, newcomer joins immediately):
        assert!((mean_completion(&c, m2, m1, 0.0) - c2a).abs() / c2a < 1e-9);
        // C2b at t=t2 (big first, newcomer waits):
        assert!((mean_completion(&c, m2, m1, c.b * m2) - c2b).abs() / c2b < 1e-9);
    }

    #[test]
    fn c1_dominates_both_c2_variants() {
        // Eq (14): serial-smallest-first is the global optimum.
        let c = cm();
        prop_check(200, |g| {
            let m1 = g.f64(1e6, 5e8);
            let m2 = g.f64(m1, 1e9);
            let (c1, c2a, c2b) = theorem_optima(&c, m1, m2);
            if c1 <= c2a + 1e-9 && c1 <= c2b + 1e-9 {
                Ok(())
            } else {
                Err(format!("c1={c1} c2a={c2a} c2b={c2b}"))
            }
        });
    }
}
