//! Communication-task scheduling (§IV-B): admission policies deciding
//! whether a ready All-Reduce may start *now* on the fabric links it
//! crosses (`net::Topology::links_between`; in the paper's flat testbed
//! the links are exactly the server NICs, so link ids == server ids).
//!
//! * `SrsfCap(n)` — the paper's SRSF(n) family: admit iff every link the
//!   task crosses currently carries fewer than n active communication
//!   tasks. SRSF(1) forbids all contention; SRSF(2)/(3) blindly accept
//!   2-/3-way contention.
//! * `AdaDual` — Algorithm 2: admit immediately when the servers are idle;
//!   against exactly one existing task apply Theorem 2's ratio test
//!   `M_new/M_old < b/(2(b+η))`; never join ≥2 existing tasks.
//!
//! `two_tasks` contains a continuous-time micro-simulator of Problem 1
//! used by the property tests to verify Theorems 1–2 against brute force.

pub mod two_tasks;

use crate::model::CommModel;
use crate::net::LinkId;

/// A snapshot of network state for admission decisions:
/// per fabric link, the list of (comm task id, remaining message bytes).
pub struct NetView<'a> {
    pub per_link: &'a [Vec<(usize, f64)>],
}

impl<'a> NetView<'a> {
    /// Maximum count of active communication tasks over `links`
    /// (Algorithm 2 lines 2–7), plus the union of those tasks. The union
    /// is deduplicated by task id with a sort + dedup — O(n log n) over
    /// the gathered entries, versus the O(n²) `iter().any` membership
    /// scan per entry this replaced. Order is by task id (a task shared
    /// by several links carries the same remaining-bytes value on each,
    /// so which copy survives is immaterial).
    pub fn max_tasks(&self, links: &[LinkId]) -> (usize, Vec<(usize, f64)>) {
        let mut max = 0;
        let mut old: Vec<(usize, f64)> = Vec::new();
        for &s in links {
            let tasks = &self.per_link[s];
            if tasks.len() > max {
                max = tasks.len();
            }
            old.extend_from_slice(tasks);
        }
        old.sort_unstable_by_key(|&(id, _)| id);
        old.dedup_by_key(|&mut (id, _)| id);
        (max, old)
    }
}

/// Decision returned by a policy; `Reject` keeps the task in the pending
/// queue to be reconsidered at the next scheduling point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    Start,
    Wait,
}

/// A communication-task admission policy.
pub trait CommPolicy {
    fn name(&self) -> String;
    /// May a new task of `msg_bytes` crossing `links` start now?
    fn admit(&self, msg_bytes: f64, links: &[LinkId], net: &NetView) -> Admission;
}

/// SRSF(n): per-link active-communication cap of `n`.
#[derive(Clone, Copy, Debug)]
pub struct SrsfCap {
    pub cap: usize,
}

impl CommPolicy for SrsfCap {
    fn name(&self) -> String {
        format!("SRSF({})", self.cap)
    }

    fn admit(&self, _msg: f64, links: &[LinkId], net: &NetView) -> Admission {
        let (max, _) = net.max_tasks(links);
        if max < self.cap {
            Admission::Start
        } else {
            Admission::Wait
        }
    }
}

/// AdaDUAL (Algorithm 2).
#[derive(Clone, Copy, Debug)]
pub struct AdaDual {
    pub model: CommModel,
}

impl CommPolicy for AdaDual {
    fn name(&self) -> String {
        "AdaDUAL".to_string()
    }

    fn admit(&self, msg_bytes: f64, links: &[LinkId], net: &NetView) -> Admission {
        let (max, old) = net.max_tasks(links);
        match max {
            // Lines 8–10: idle servers — start immediately.
            0 => Admission::Start,
            // Lines 11–18: one existing task — Theorem 2 ratio test against
            // its remaining message size. With several distinct single
            // tasks across our links, test against the *largest*
            // remaining one (the most conservative pairing).
            1 => {
                let m_old = old.iter().map(|&(_, m)| m).fold(0.0f64, f64::max);
                if self.model.overlap_beneficial(msg_bytes, m_old) {
                    Admission::Start
                } else {
                    Admission::Wait
                }
            }
            // Lines 19–21: two or more — never join.
            _ => Admission::Wait,
        }
    }
}

/// Job priority: shortest-remaining-service-first (Tiresias' SRSF). The
/// service of a job is remaining time × occupied GPUs; smaller is served
/// first. Ties break on job id for determinism.
pub fn srsf_cmp(a: (f64, usize), b: (f64, usize)) -> std::cmp::Ordering {
    a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
}

// Policy construction by name lives in `scenario::registry` (the unified
// algorithm registry shared by the CLI, scenario files and the live gate).

#[cfg(test)]
mod tests {
    use super::*;

    fn net(per_link: Vec<Vec<(usize, f64)>>) -> Vec<Vec<(usize, f64)>> {
        per_link
    }

    #[test]
    fn srsf1_blocks_any_contention() {
        let p = SrsfCap { cap: 1 };
        let empty = net(vec![vec![], vec![]]);
        let busy = net(vec![vec![(7, 1e8)], vec![]]);
        assert_eq!(p.admit(1e6, &[0, 1], &NetView { per_link: &empty }), Admission::Start);
        assert_eq!(p.admit(1e6, &[0, 1], &NetView { per_link: &busy }), Admission::Wait);
        // ...but a task on an unrelated link does not block.
        assert_eq!(p.admit(1e6, &[1], &NetView { per_link: &busy }), Admission::Start);
    }

    #[test]
    fn srsf2_allows_one_contender() {
        let p = SrsfCap { cap: 2 };
        let one = net(vec![vec![(1, 5e8)]]);
        let two = net(vec![vec![(1, 5e8), (2, 2e8)]]);
        assert_eq!(p.admit(1e6, &[0], &NetView { per_link: &one }), Admission::Start);
        assert_eq!(p.admit(1e6, &[0], &NetView { per_link: &two }), Admission::Wait);
    }

    #[test]
    fn adadual_idle_starts() {
        let p = AdaDual { model: CommModel::paper_10gbe() };
        let empty = net(vec![vec![], vec![], vec![]]);
        assert_eq!(p.admit(5e8, &[0, 2], &NetView { per_link: &empty }), Admission::Start);
    }

    #[test]
    fn adadual_ratio_test() {
        let cm = CommModel::paper_10gbe();
        let p = AdaDual { model: cm };
        let th = cm.adadual_threshold();
        let m_old = 4e8;
        let small = net(vec![vec![(9, m_old)]]);
        // Well under the threshold: overlap pays off.
        assert_eq!(
            p.admit(m_old * th * 0.9, &[0], &NetView { per_link: &small }),
            Admission::Start
        );
        // Over the threshold: wait for the big one to finish.
        assert_eq!(
            p.admit(m_old * th * 1.1, &[0], &NetView { per_link: &small }),
            Admission::Wait
        );
    }

    #[test]
    fn adadual_never_joins_two() {
        let cm = CommModel::paper_10gbe();
        let p = AdaDual { model: cm };
        let two = net(vec![vec![(1, 9e9), (2, 9e9)]]);
        assert_eq!(p.admit(1.0, &[0], &NetView { per_link: &two }), Admission::Wait);
    }

    #[test]
    fn adadual_uses_largest_old_task_across_servers() {
        let cm = CommModel::paper_10gbe();
        let p = AdaDual { model: cm };
        let th = cm.adadual_threshold();
        // Link 0 has a small old task, link 1 a big one; test pairs
        // against the big one.
        let mixed = net(vec![vec![(1, 1e6)], vec![(2, 1e9)]]);
        let msg = 1e9 * th * 0.9; // fine vs 1e9, terrible vs 1e6
        assert_eq!(p.admit(msg, &[0, 1], &NetView { per_link: &mixed }), Admission::Start);
    }

    #[test]
    fn max_tasks_dedups_union() {
        let shared = net(vec![vec![(5, 1e8)], vec![(5, 1e8), (6, 2e8)]]);
        let view = NetView { per_link: &shared };
        let (max, old) = view.max_tasks(&[0, 1]);
        assert_eq!(max, 2);
        assert_eq!(old.len(), 2);
    }

    #[test]
    fn max_tasks_dedups_many_links_by_id() {
        // A task spanning every link must appear once in the union no
        // matter how many links repeat it (the sort-dedup rebuild), and
        // the per-id remaining bytes survive intact.
        let everywhere: Vec<Vec<(usize, f64)>> =
            (0..8).map(|l| vec![(9, 5e8), (l, 1e6)]).collect();
        let view = NetView { per_link: &everywhere };
        let links: Vec<usize> = (0..8).collect();
        let (max, old) = view.max_tasks(&links);
        assert_eq!(max, 2);
        assert_eq!(old.len(), 9); // ids 0..8 plus the shared task 9
        assert_eq!(old.iter().filter(|&&(id, _)| id == 9).count(), 1);
        let m9 = old.iter().find(|&&(id, _)| id == 9).unwrap().1;
        assert_eq!(m9, 5e8);
    }

    #[test]
    fn srsf_cmp_orders_by_service_then_id() {
        use std::cmp::Ordering::*;
        assert_eq!(srsf_cmp((1.0, 5), (2.0, 1)), Less);
        assert_eq!(srsf_cmp((2.0, 1), (2.0, 5)), Less);
        assert_eq!(srsf_cmp((3.0, 7), (3.0, 7)), Equal);
    }
}
