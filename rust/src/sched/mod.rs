//! Communication-task scheduling (§IV-B): admission policies deciding
//! whether a ready All-Reduce may start *now* on the fabric links it
//! crosses (`net::Topology::links_between`; in the paper's flat testbed
//! the links are exactly the server NICs, so link ids == server ids).
//!
//! * `SrsfCap(n)` — the paper's SRSF(n) family: admit iff every link the
//!   task crosses currently carries fewer than n active communication
//!   tasks. SRSF(1) forbids all contention; SRSF(2)/(3) blindly accept
//!   2-/3-way contention.
//! * `AdaDual` — Algorithm 2: admit immediately when the servers are idle;
//!   against exactly one existing task apply Theorem 2's ratio test
//!   `M_new/M_old < b/(2(b+η))`; never join ≥2 existing tasks.
//!
//! `two_tasks` contains a continuous-time micro-simulator of Problem 1
//! used by the property tests to verify Theorems 1–2 against brute force.

pub mod health;
pub mod two_tasks;

use crate::model::CommModel;
use crate::net::{LinkId, LinkTasks};

/// Resolver a [`NetView`] never invokes: views over an idle fabric (the
/// engine's steadiness check) carry no tasks, so any residual request is
/// a logic error worth a loud panic.
fn unresolved(_id: usize) -> f64 {
    panic!("NetView: remaining bytes requested from an occupancy-only view")
}

/// A *lazy* view of network state for admission decisions: per fabric
/// link, the engine's live list of active comm-task ids, plus a resolver
/// for a task's remaining message bytes — invoked only for tasks on the
/// links a policy actually inspects. The engine used to materialize a
/// full `Vec<Vec<(id, residual)>>` snapshot of every active transfer on
/// every link once per admission pass (O(links × active) even when the
/// policy looked at two NICs); this view reads the live per-link lists,
/// which are maintained O(Δ) at admit/complete, and prices residuals on
/// demand. The backing storage is any [`LinkTasks`] — the engine's flat
/// [`net::LinkLists`](crate::net::LinkLists) slab on the hot path,
/// nested `Vec<Vec<usize>>` for tests and the materialized twin.
pub struct NetView<'a> {
    links: &'a dyn LinkTasks,
    remaining: &'a dyn Fn(usize) -> f64,
}

impl<'a> NetView<'a> {
    pub fn new<T: LinkTasks + ?Sized>(
        links: &'a T,
        remaining: &'a dyn Fn(usize) -> f64,
    ) -> NetView<'a> {
        NetView { links, remaining }
    }

    /// View that can answer occupancy questions only (idle-fabric checks);
    /// resolving a residual through it panics.
    pub fn occupancy_only<T: LinkTasks + ?Sized>(links: &'a T) -> NetView<'a> {
        NetView { links, remaining: &unresolved }
    }

    /// Number of fabric links the view covers.
    pub fn n_links(&self) -> usize {
        self.links.n_links()
    }

    /// Active comm-task ids on `link`.
    pub fn link_tasks(&self, link: LinkId) -> &[usize] {
        self.links.tasks(link)
    }

    /// Remaining message bytes of active task `id` (resolved on demand).
    pub fn remaining_of(&self, id: usize) -> f64 {
        (self.remaining)(id)
    }

    /// Active-transfer count on `link`.
    pub fn occupancy(&self, link: LinkId) -> usize {
        self.links.tasks(link).len()
    }

    /// Maximum count of active communication tasks over `links`
    /// (Algorithm 2 lines 2–7). Pure occupancy: no residual resolution,
    /// no allocation — the whole cost of an SRSF(n) decision.
    pub fn max_occupancy(&self, links: &[LinkId]) -> usize {
        links.iter().map(|&l| self.links.tasks(l).len()).max().unwrap_or(0)
    }

    /// Largest remaining message among the tasks on `links` (0.0 when
    /// idle). A task appearing on several links resolves to the same
    /// value each time, so the max over raw entries equals the max over
    /// the deduplicated union.
    pub fn max_remaining(&self, links: &[LinkId]) -> f64 {
        let mut m = 0.0f64;
        for &l in links {
            for &id in self.links.tasks(l) {
                m = m.max((self.remaining)(id));
            }
        }
        m
    }

    /// Max occupancy plus the deduplicated (id, remaining) union over
    /// `links` — the fully materialized form, kept for policies and
    /// tests that want the whole task set. Residuals are resolved once
    /// per *distinct* task (after the sort + dedup), so even this path
    /// prices at most the tasks actually present on the inspected links.
    pub fn max_tasks(&self, links: &[LinkId]) -> (usize, Vec<(usize, f64)>) {
        let mut max = 0;
        let mut ids: Vec<usize> = Vec::new();
        for &s in links {
            let tasks = self.links.tasks(s);
            if tasks.len() > max {
                max = tasks.len();
            }
            ids.extend_from_slice(tasks);
        }
        ids.sort_unstable();
        ids.dedup();
        let old = ids.into_iter().map(|id| (id, (self.remaining)(id))).collect();
        (max, old)
    }
}

/// Owned, precomputed network snapshot — the test/bench-friendly
/// [`NetView`] backing, and the "materialized twin" the lazy-view
/// equivalence property test compares engine admissions against.
pub struct MaterializedNet {
    ids: Vec<Vec<usize>>,
    /// (task id, remaining bytes), sorted by id for binary-search lookup.
    remaining: Vec<(usize, f64)>,
}

impl MaterializedNet {
    /// Build from the classic per-link (id, remaining) tuple lists.
    pub fn from_tuples(per_link: &[Vec<(usize, f64)>]) -> MaterializedNet {
        let ids = per_link
            .iter()
            .map(|tasks| tasks.iter().map(|&(id, _)| id).collect())
            .collect();
        let mut remaining: Vec<(usize, f64)> =
            per_link.iter().flatten().copied().collect();
        remaining.sort_unstable_by_key(|&(id, _)| id);
        remaining.dedup_by_key(|&mut (id, _)| id);
        MaterializedNet { ids, remaining }
    }

    fn remaining_of(&self, id: usize) -> f64 {
        let i = self
            .remaining
            .binary_search_by_key(&id, |&(id, _)| id)
            .unwrap_or_else(|_| panic!("unknown comm task {id} in materialized view"));
        self.remaining[i].1
    }

    /// Run `f` against a [`NetView`] over this snapshot.
    pub fn with_view<R>(&self, f: impl FnOnce(&NetView<'_>) -> R) -> R {
        let remaining = |id: usize| self.remaining_of(id);
        let view = NetView::new(&self.ids, &remaining);
        f(&view)
    }
}

/// Decision returned by a policy; `Reject` keeps the task in the pending
/// queue to be reconsidered at the next scheduling point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    Start,
    Wait,
}

/// A communication-task admission policy.
pub trait CommPolicy {
    fn name(&self) -> String;
    /// May a new task of `msg_bytes` crossing `links` start now?
    fn admit(&self, msg_bytes: f64, links: &[LinkId], net: &NetView) -> Admission;
}

/// SRSF(n): per-link active-communication cap of `n`.
#[derive(Clone, Copy, Debug)]
pub struct SrsfCap {
    pub cap: usize,
}

impl CommPolicy for SrsfCap {
    fn name(&self) -> String {
        format!("SRSF({})", self.cap)
    }

    fn admit(&self, _msg: f64, links: &[LinkId], net: &NetView) -> Admission {
        // Occupancy-only: an SRSF(n) decision never needs residuals.
        if net.max_occupancy(links) < self.cap {
            Admission::Start
        } else {
            Admission::Wait
        }
    }
}

/// AdaDUAL (Algorithm 2).
#[derive(Clone, Copy, Debug)]
pub struct AdaDual {
    pub model: CommModel,
}

impl CommPolicy for AdaDual {
    fn name(&self) -> String {
        "AdaDUAL".to_string()
    }

    fn admit(&self, msg_bytes: f64, links: &[LinkId], net: &NetView) -> Admission {
        // Occupancy decides the branch; residuals are resolved only in
        // the one branch (max == 1) whose ratio test needs them.
        match net.max_occupancy(links) {
            // Lines 8–10: idle servers — start immediately.
            0 => Admission::Start,
            // Lines 11–18: one existing task — Theorem 2 ratio test against
            // its remaining message size. With several distinct single
            // tasks across our links, test against the *largest*
            // remaining one (the most conservative pairing).
            1 => {
                let m_old = net.max_remaining(links);
                if self.model.overlap_beneficial(msg_bytes, m_old) {
                    Admission::Start
                } else {
                    Admission::Wait
                }
            }
            // Lines 19–21: two or more — never join.
            _ => Admission::Wait,
        }
    }
}

/// Job priority: shortest-remaining-service-first (Tiresias' SRSF). The
/// service of a job is remaining time × occupied GPUs; smaller is served
/// first. Ties break on job id for determinism.
pub fn srsf_cmp(a: (f64, usize), b: (f64, usize)) -> std::cmp::Ordering {
    a.0.total_cmp(&b.0).then(a.1.cmp(&b.1))
}

/// The placement queue: jobs held in the `(priority key, id)` total order,
/// maintained incrementally — an O(log n) binary-search insert per
/// arrival (plus the `Vec::insert` memmove, a few hundred contiguous
/// bytes even at 100k-job scale) instead of a full O(n log n) key-driven
/// re-sort on every placement pass.
///
/// **Why the O(n) memmove stays** (evaluated against a two-stack /
/// gap-buffer layout; microbenched head-to-head in `benches/micro/`,
/// the `JobQueue insert` vs `gap-buffer insert` rows). A gap buffer
/// wins when consecutive inserts cluster near the gap — but this
/// queue's access pattern forces the gap away on *every* use: each
/// arrival triggers a placement pass, and the pass walks the whole
/// queue through [`JobQueue::take_all`]/[`JobQueue::restore`] (a full
/// linear traversal that any gap layout must first close the gap for,
/// an O(n) move of its own). So per arrival both layouts pay one O(n)
/// contiguous move; the flat `Vec` pays it as a single branch-free
/// `memmove` of a few-hundred-byte tail, while the gap buffer adds gap
/// bookkeeping to every probe and breaks `entries()`'s borrowed-slice
/// API (callers would need a two-segment iterator or an O(n)
/// compaction). At realistic queue depths — tens of entries in the
/// paper regime, low thousands under the 100k-job saturation gate —
/// the memmove is measured in nanoseconds and never shows up in the
/// sim_hotpath profile. Sound
/// because queue keys are *static* per priority rule — SRSF's queued key
/// is the job's total service (a pure function of its immutable spec,
/// E_J = 0 before placement), FIFO's is its arrival time, and LAS's is 0
/// (no service attained yet) — so the order can never drift between
/// passes (the engine debug-asserts this invariant on every walk).
#[derive(Clone, Default)]
pub struct JobQueue {
    /// Sorted ascending by `srsf_cmp` on `(key, job id)`.
    entries: Vec<(f64, usize)>,
}

impl JobQueue {
    pub fn new() -> JobQueue {
        JobQueue::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Insert `job` with its (static) priority `key`, keeping the total
    /// order: O(log n) binary search + the `Vec::insert` tail memmove.
    /// Ids are unique, so the insertion point is unambiguous.
    pub fn insert(&mut self, key: f64, job: usize) {
        let pos = self
            .entries
            .partition_point(|&e| srsf_cmp(e, (key, job)) == std::cmp::Ordering::Less);
        self.entries.insert(pos, (key, job));
    }

    /// The queue in priority order.
    pub fn entries(&self) -> &[(f64, usize)] {
        &self.entries
    }

    /// Take the whole queue out for a placement walk (the caller hands
    /// the unplaced remainder back via [`JobQueue::restore`]).
    pub fn take_all(&mut self) -> Vec<(f64, usize)> {
        std::mem::take(&mut self.entries)
    }

    /// Put back the unplaced remainder of a [`JobQueue::take_all`] walk.
    /// Walking in order and dropping placed entries preserves sortedness
    /// (debug-asserted).
    pub fn restore(&mut self, entries: Vec<(f64, usize)>) {
        debug_assert!(self.entries.is_empty(), "restore over a non-empty queue");
        debug_assert!(
            entries.windows(2).all(|w| srsf_cmp(w[0], w[1]) == std::cmp::Ordering::Less),
            "restored queue lost its sort order"
        );
        self.entries = entries;
    }
}

// Policy construction by name lives in `scenario::registry` (the unified
// algorithm registry shared by the CLI, scenario files and the live gate).

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    fn net(per_link: Vec<Vec<(usize, f64)>>) -> MaterializedNet {
        MaterializedNet::from_tuples(&per_link)
    }

    #[test]
    fn srsf1_blocks_any_contention() {
        let p = SrsfCap { cap: 1 };
        let empty = net(vec![vec![], vec![]]);
        let busy = net(vec![vec![(7, 1e8)], vec![]]);
        assert_eq!(empty.with_view(|n| p.admit(1e6, &[0, 1], n)), Admission::Start);
        assert_eq!(busy.with_view(|n| p.admit(1e6, &[0, 1], n)), Admission::Wait);
        // ...but a task on an unrelated link does not block.
        assert_eq!(busy.with_view(|n| p.admit(1e6, &[1], n)), Admission::Start);
    }

    #[test]
    fn srsf2_allows_one_contender() {
        let p = SrsfCap { cap: 2 };
        let one = net(vec![vec![(1, 5e8)]]);
        let two = net(vec![vec![(1, 5e8), (2, 2e8)]]);
        assert_eq!(one.with_view(|n| p.admit(1e6, &[0], n)), Admission::Start);
        assert_eq!(two.with_view(|n| p.admit(1e6, &[0], n)), Admission::Wait);
    }

    #[test]
    fn adadual_idle_starts() {
        let p = AdaDual { model: CommModel::paper_10gbe() };
        let empty = net(vec![vec![], vec![], vec![]]);
        assert_eq!(empty.with_view(|n| p.admit(5e8, &[0, 2], n)), Admission::Start);
    }

    #[test]
    fn adadual_idle_starts_on_occupancy_only_view() {
        // The engine's steadiness check lends policies a residual-free
        // view of an idle fabric: with no tasks anywhere, no policy may
        // ever resolve a residual through it.
        let p = AdaDual { model: CommModel::paper_10gbe() };
        let idle: Vec<Vec<usize>> = vec![Vec::new(); 3];
        let view = NetView::occupancy_only(&idle);
        assert_eq!(p.admit(5e8, &[0, 2], &view), Admission::Start);
        assert_eq!(SrsfCap { cap: 1 }.admit(5e8, &[1], &view), Admission::Start);
    }

    #[test]
    fn adadual_ratio_test() {
        let cm = CommModel::paper_10gbe();
        let p = AdaDual { model: cm };
        let th = cm.adadual_threshold();
        let m_old = 4e8;
        let small = net(vec![vec![(9, m_old)]]);
        // Well under the threshold: overlap pays off.
        assert_eq!(
            small.with_view(|n| p.admit(m_old * th * 0.9, &[0], n)),
            Admission::Start
        );
        // Over the threshold: wait for the big one to finish.
        assert_eq!(
            small.with_view(|n| p.admit(m_old * th * 1.1, &[0], n)),
            Admission::Wait
        );
    }

    #[test]
    fn adadual_never_joins_two() {
        let cm = CommModel::paper_10gbe();
        let p = AdaDual { model: cm };
        let two = net(vec![vec![(1, 9e9), (2, 9e9)]]);
        assert_eq!(two.with_view(|n| p.admit(1.0, &[0], n)), Admission::Wait);
    }

    #[test]
    fn adadual_uses_largest_old_task_across_servers() {
        let cm = CommModel::paper_10gbe();
        let p = AdaDual { model: cm };
        let th = cm.adadual_threshold();
        // Link 0 has a small old task, link 1 a big one; test pairs
        // against the big one.
        let mixed = net(vec![vec![(1, 1e6)], vec![(2, 1e9)]]);
        let msg = 1e9 * th * 0.9; // fine vs 1e9, terrible vs 1e6
        assert_eq!(mixed.with_view(|n| p.admit(msg, &[0, 1], n)), Admission::Start);
    }

    #[test]
    fn max_tasks_dedups_union() {
        let shared = net(vec![vec![(5, 1e8)], vec![(5, 1e8), (6, 2e8)]]);
        let (max, old) = shared.with_view(|n| n.max_tasks(&[0, 1]));
        assert_eq!(max, 2);
        assert_eq!(old.len(), 2);
    }

    #[test]
    fn max_tasks_dedups_many_links_by_id() {
        // A task spanning every link must appear once in the union no
        // matter how many links repeat it (the sort-dedup rebuild), and
        // the per-id remaining bytes survive intact.
        let everywhere: Vec<Vec<(usize, f64)>> =
            (0..8).map(|l| vec![(9, 5e8), (l, 1e6)]).collect();
        let view = net(everywhere);
        let links: Vec<usize> = (0..8).collect();
        let (max, old) = view.with_view(|n| n.max_tasks(&links));
        assert_eq!(max, 2);
        assert_eq!(old.len(), 9); // ids 0..8 plus the shared task 9
        assert_eq!(old.iter().filter(|&&(id, _)| id == 9).count(), 1);
        let m9 = old.iter().find(|&&(id, _)| id == 9).unwrap().1;
        assert_eq!(m9, 5e8);
    }

    #[test]
    fn lazy_accessors_resolve_on_demand() {
        let view = net(vec![vec![(3, 7e7)], vec![(3, 7e7), (4, 2e8)], vec![]]);
        view.with_view(|n| {
            assert_eq!(n.n_links(), 3);
            assert_eq!(n.occupancy(1), 2);
            assert_eq!(n.link_tasks(1), &[3, 4]);
            assert_eq!(n.max_occupancy(&[0, 2]), 1);
            assert_eq!(n.max_remaining(&[0, 1]), 2e8);
            assert_eq!(n.max_remaining(&[2]), 0.0);
            assert_eq!(n.remaining_of(4), 2e8);
        });
    }

    #[test]
    fn srsf_cmp_orders_by_service_then_id() {
        use std::cmp::Ordering::*;
        assert_eq!(srsf_cmp((1.0, 5), (2.0, 1)), Less);
        assert_eq!(srsf_cmp((2.0, 1), (2.0, 5)), Less);
        assert_eq!(srsf_cmp((3.0, 7), (3.0, 7)), Equal);
    }

    #[test]
    fn job_queue_basic_order_and_restore() {
        let mut q = JobQueue::new();
        q.insert(3.0, 0);
        q.insert(1.0, 1);
        q.insert(3.0, 2); // equal key: tie-break by id, after job 0
        q.insert(0.5, 3);
        assert_eq!(q.entries(), &[(0.5, 3), (1.0, 1), (3.0, 0), (3.0, 2)]);
        let mut walked = q.take_all();
        assert!(q.is_empty());
        walked.remove(1); // "place" job 1; the rest stays sorted
        q.restore(walked);
        assert_eq!(q.len(), 3);
        assert_eq!(q.entries(), &[(0.5, 3), (3.0, 0), (3.0, 2)]);
    }

    #[test]
    fn prop_incremental_queue_order_matches_full_sort() {
        // The load-bearing invariant behind the engine's re-sort removal:
        // inserting (static key, id) pairs one at a time — in any arrival
        // order, with heavy key duplication à la LAS — yields exactly the
        // order a full per-pass re-sort by `srsf_cmp` would produce.
        prop_check(50, |g| {
            let n = g.usize(1, 40);
            let keys: Vec<(f64, usize)> = (0..n)
                .map(|id| {
                    // Mix continuous keys with exact duplicates (LAS
                    // queues are all-zero; FIFO often shares arrivals).
                    let k = if g.bool() { g.f64(0.0, 10.0) } else { g.usize(0, 3) as f64 };
                    (k, id)
                })
                .collect();
            let mut order: Vec<usize> = (0..n).collect();
            g.rng().shuffle(&mut order);
            let mut q = JobQueue::new();
            for &i in &order {
                q.insert(keys[i].0, keys[i].1);
            }
            let mut want = keys.clone();
            want.sort_by(|&a, &b| srsf_cmp(a, b));
            if q.entries() != &want[..] {
                return Err(format!(
                    "incremental order diverged:\n  got:  {:?}\n  want: {:?}",
                    q.entries(),
                    want
                ));
            }
            Ok(())
        });
    }
}
