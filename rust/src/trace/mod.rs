//! DDL job specifications and the Microsoft-trace-like workload generator
//! (§V-A): 160 jobs over a 20-minute arrival window with the paper's
//! GPU-count histogram, iteration range 1000–6000, models drawn from the
//! Table III zoo. Traces serialize to JSON (util::json) for reuse.

use crate::model::{CommModel, DnnModel, PerfModel};
use crate::util::json::Json;
use crate::util::rng::Pcg;

/// One DDL training job as released by the trace.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    pub id: usize,
    /// Arrival timestamp A_k (seconds).
    pub arrival: f64,
    pub model: DnnModel,
    /// Number of GPUs |G(J_k)|.
    pub n_gpus: usize,
    /// Training iterations I_k.
    pub iterations: u64,
}

impl JobSpec {
    /// Per-iteration compute time (t_f + t_b) on a `peak_gflops` GPU.
    pub fn t_iter(&self, peak_gflops: f64) -> f64 {
        let spec = self.model.spec();
        PerfModel::for_model(self.model).t_iter(spec.batch_size, peak_gflops)
    }

    /// C_J of Eq (7): total compute time over all iterations.
    pub fn compute_total(&self, peak_gflops: f64) -> f64 {
        self.t_iter(peak_gflops) * self.iterations as f64
    }

    /// E_J of Eq (8) given the number of servers the placement spans.
    pub fn comm_total(&self, n_servers_spanned: usize, cm: &CommModel) -> f64 {
        if n_servers_spanned <= 1 {
            0.0
        } else {
            cm.time_free(self.model.spec().model_bytes) * self.iterations as f64
        }
    }

    /// Gradient message size M (bytes).
    pub fn message_bytes(&self) -> f64 {
        self.model.spec().model_bytes
    }

    /// Per-GPU memory requirement (bytes).
    pub fn mem_bytes(&self) -> f64 {
        self.model.spec().mem_bytes
    }

    /// Paper §V-A job taxonomy: large if > 4 GPUs.
    pub fn is_large(&self) -> bool {
        self.n_gpus > 4
    }

    /// Paper §V-A job taxonomy: long if > 1600 iterations.
    pub fn is_long(&self) -> bool {
        self.iterations > 1600
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("id", self.id)
            .set("arrival", self.arrival)
            .set("model", self.model.spec().name)
            .set("n_gpus", self.n_gpus)
            .set("iterations", self.iterations)
    }

    pub fn from_json(v: &Json) -> Result<JobSpec, String> {
        let name = v.req_str("model")?;
        let model = DnnModel::from_name(name).ok_or_else(|| format!("unknown model '{name}'"))?;
        let arrival = v.req_f64("arrival")?;
        if !arrival.is_finite() || arrival < 0.0 {
            return Err(format!("job arrival must be finite and >= 0, got {arrival}"));
        }
        let iterations = v.req_f64("iterations")?;
        if !iterations.is_finite() || iterations < 1.0 {
            return Err(format!("job iterations must be >= 1, got {iterations}"));
        }
        let n_gpus = v.req_usize("n_gpus")?;
        if n_gpus == 0 {
            return Err("job n_gpus must be >= 1".to_string());
        }
        Ok(JobSpec {
            id: v.req_usize("id")?,
            arrival,
            model,
            n_gpus,
            iterations: iterations as u64,
        })
    }
}

/// Trace generation parameters. The defaults are §V-A's published
/// marginals; everything is overridable for sweeps/ablations.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub seed: u64,
    /// Arrival window [0, horizon) seconds (paper: 1200 s).
    pub horizon: f64,
    /// (n_gpus, count) histogram; paper: 80×1, 14×2, 26×4, 30×8, 8×16, 2×32.
    pub gpu_histogram: Vec<(usize, usize)>,
    /// Iteration range (inclusive), paper: 1000–6000.
    pub iter_range: (u64, u64),
}

impl TraceConfig {
    pub fn paper_160() -> TraceConfig {
        TraceConfig {
            seed: 42,
            horizon: 1200.0,
            gpu_histogram: vec![(1, 80), (2, 14), (4, 26), (8, 30), (16, 8), (32, 2)],
            iter_range: (1000, 6000),
        }
    }

    /// A scaled-down trace for fast tests: same shape, `n` jobs.
    pub fn scaled(n: usize, seed: u64) -> TraceConfig {
        let paper = TraceConfig::paper_160();
        let total: usize = paper.gpu_histogram.iter().map(|&(_, c)| c).sum();
        let mut hist: Vec<(usize, usize)> = paper
            .gpu_histogram
            .iter()
            .map(|&(g, c)| (g, (c * n + total / 2) / total))
            .collect();
        // Make counts sum to n exactly, adjusting the 1-GPU bucket.
        let sum: usize = hist.iter().map(|&(_, c)| c).sum();
        if sum < n {
            hist[0].1 += n - sum;
        } else {
            let mut excess = sum - n;
            for entry in hist.iter_mut() {
                let take = excess.min(entry.1.saturating_sub(1));
                entry.1 -= take;
                excess -= take;
                if excess == 0 {
                    break;
                }
            }
        }
        TraceConfig {
            seed,
            horizon: paper.horizon * n as f64 / total as f64,
            gpu_histogram: hist,
            iter_range: paper.iter_range,
        }
    }

    pub fn n_jobs(&self) -> usize {
        self.gpu_histogram.iter().map(|&(_, c)| c).sum()
    }
}

/// Lazy per-job view of the synthetic generator: yields jobs one at a
/// time in **RNG draw order** (not arrival order), with `id` equal to the
/// draw index. The per-job random draws are byte-identical to what
/// [`generate`] consumes — `generate` is now literally "collect this
/// stream, sort by arrival, re-id" — so existing traces and scenario
/// JSONs are unchanged while callers that don't need a sorted `Vec`
/// (e.g. sampling a size marginal) can iterate without materializing.
///
/// Memory is O(histogram total) for the shuffled size list, not O(trace)
/// in `JobSpec`s; for an unbounded open stream with O(1) state see
/// `source::GeneratedSource`.
pub struct JobStream {
    rng: Pcg,
    /// Shuffled GPU-count list; `next_idx` walks it front to back.
    sizes: Vec<usize>,
    next_idx: usize,
    horizon: f64,
    iter_range: (u64, u64),
}

impl JobStream {
    pub fn new(cfg: &TraceConfig) -> JobStream {
        let mut rng = Pcg::new(cfg.seed, 0x7ace);
        // Expand the histogram into a gpu-count list and shuffle it so
        // arrival order decorrelates from size.
        let mut sizes: Vec<usize> = cfg
            .gpu_histogram
            .iter()
            .flat_map(|&(g, c)| std::iter::repeat(g).take(c))
            .collect();
        rng.shuffle(&mut sizes);
        JobStream {
            rng,
            sizes,
            next_idx: 0,
            horizon: cfg.horizon,
            iter_range: cfg.iter_range,
        }
    }

    /// Jobs remaining in the stream.
    pub fn remaining(&self) -> usize {
        self.sizes.len() - self.next_idx
    }
}

impl Iterator for JobStream {
    type Item = JobSpec;

    fn next(&mut self) -> Option<JobSpec> {
        let n_gpus = *self.sizes.get(self.next_idx)?;
        let id = self.next_idx;
        self.next_idx += 1;
        let arrival = self.rng.range_f64(0.0, self.horizon);
        let iterations = self.rng.range_u64(self.iter_range.0, self.iter_range.1);
        let model = *self.rng.choose(&crate::model::ALL_MODELS);
        Some(JobSpec { id, arrival, model, n_gpus, iterations })
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let r = self.remaining();
        (r, Some(r))
    }
}

/// Generate a trace: jobs sorted by arrival time, ids in arrival order.
/// Byte-identical draws to [`JobStream`]; the sort is the only step the
/// lazy view omits.
pub fn generate(cfg: &TraceConfig) -> Vec<JobSpec> {
    let mut jobs: Vec<JobSpec> = JobStream::new(cfg).collect();
    jobs.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    for (i, j) in jobs.iter_mut().enumerate() {
        j.id = i;
    }
    jobs
}

/// Serialize a trace to JSON text.
pub fn to_json(jobs: &[JobSpec]) -> String {
    Json::Arr(jobs.iter().map(JobSpec::to_json).collect()).to_string_pretty()
}

/// Parse a trace from JSON text.
pub fn from_json(text: &str) -> Result<Vec<JobSpec>, String> {
    let v = Json::parse(text).map_err(|e| e.to_string())?;
    v.as_arr()
        .ok_or_else(|| "trace must be a JSON array".to_string())?
        .iter()
        .map(JobSpec::from_json)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_histogram_sums_to_160() {
        let cfg = TraceConfig::paper_160();
        assert_eq!(cfg.n_jobs(), 160);
        let one_gpu = cfg.gpu_histogram.iter().find(|&&(g, _)| g == 1).unwrap().1;
        assert_eq!(one_gpu * 2, 160, "half of jobs are single-GPU");
    }

    #[test]
    fn generate_respects_histogram_and_ranges() {
        let cfg = TraceConfig::paper_160();
        let jobs = generate(&cfg);
        assert_eq!(jobs.len(), 160);
        for &(g, want) in &cfg.gpu_histogram {
            let got = jobs.iter().filter(|j| j.n_gpus == g).count();
            assert_eq!(got, want, "gpu bucket {g}");
        }
        for j in &jobs {
            assert!((cfg.iter_range.0..=cfg.iter_range.1).contains(&j.iterations));
            assert!((0.0..cfg.horizon).contains(&j.arrival));
        }
    }

    #[test]
    fn generate_sorted_and_deterministic() {
        let cfg = TraceConfig::paper_160();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a, b);
        for w in a.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
            assert!(w[0].id < w[1].id);
        }
        let c = generate(&TraceConfig { seed: 1, ..cfg });
        assert_ne!(a, c);
    }

    #[test]
    fn job_stream_matches_generate() {
        let cfg = TraceConfig::paper_160();
        let streamed: Vec<JobSpec> = JobStream::new(&cfg).collect();
        assert_eq!(streamed.len(), 160);
        // Draw order, draw-index ids.
        for (i, j) in streamed.iter().enumerate() {
            assert_eq!(j.id, i);
        }
        // Sorting + re-iding the stream reproduces generate() exactly.
        let mut sorted = streamed;
        sorted.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        for (i, j) in sorted.iter_mut().enumerate() {
            j.id = i;
        }
        assert_eq!(sorted, generate(&cfg));
    }

    #[test]
    fn job_stream_size_hint_exact() {
        let mut s = JobStream::new(&TraceConfig::scaled(10, 3));
        assert_eq!(s.size_hint(), (10, Some(10)));
        s.next();
        assert_eq!(s.remaining(), 9);
        assert_eq!(s.by_ref().count(), 9);
        assert_eq!(s.next(), None);
    }

    #[test]
    fn json_roundtrip() {
        let jobs = generate(&TraceConfig::scaled(20, 7));
        let text = to_json(&jobs);
        let parsed = from_json(&text).unwrap();
        assert_eq!(jobs, parsed);
    }

    #[test]
    fn from_json_rejects_bad_fields() {
        let base = |arrival: f64, n_gpus: usize, iterations: f64| {
            Json::obj()
                .set("id", 0usize)
                .set("arrival", arrival)
                .set("model", "VGG-16")
                .set("n_gpus", n_gpus)
                .set("iterations", iterations)
        };
        assert!(JobSpec::from_json(&base(0.0, 1, 100.0)).is_ok());
        for (v, want) in [
            (base(-1.0, 1, 100.0), "arrival"),
            (base(f64::NAN, 1, 100.0), "arrival"),
            (base(f64::INFINITY, 1, 100.0), "arrival"),
            (base(0.0, 0, 100.0), "n_gpus"),
            (base(0.0, 1, 0.0), "iterations"),
            (base(0.0, 1, f64::NAN), "iterations"),
        ] {
            let e = JobSpec::from_json(&v).unwrap_err();
            assert!(e.contains(want), "{want}: {e}");
        }
    }

    #[test]
    fn scaled_trace_sums_exactly() {
        for n in [1, 5, 10, 16, 40, 99] {
            let cfg = TraceConfig::scaled(n, 0);
            assert_eq!(cfg.n_jobs(), n, "n={n}");
        }
    }

    #[test]
    fn taxonomy_thresholds() {
        let j = JobSpec { id: 0, arrival: 0.0, model: DnnModel::Vgg16, n_gpus: 8, iterations: 1600 };
        assert!(j.is_large());
        assert!(!j.is_long());
        let j2 = JobSpec { n_gpus: 4, iterations: 1601, ..j.clone() };
        assert!(!j2.is_large());
        assert!(j2.is_long());
    }

    #[test]
    fn comm_total_zero_single_server() {
        let cm = CommModel::paper_10gbe();
        let j = JobSpec { id: 0, arrival: 0.0, model: DnnModel::ResNet50, n_gpus: 4, iterations: 100 };
        assert_eq!(j.comm_total(1, &cm), 0.0);
        assert!(j.comm_total(2, &cm) > 0.0);
    }
}
