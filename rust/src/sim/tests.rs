//! Simulator correctness: analytic single-job checks, conservation laws,
//! contention dynamics, and randomized property tests against invariants.

use super::*;
use crate::sim::Repricing;
use crate::cluster::ClusterSpec;
use crate::model::{CommModel, DnnModel};
use crate::net::{LinkId, TopologySpec};
use crate::placement::{FirstFitPlacer, HealthAwarePlacer, LwfPlacer, Placer};
use crate::sched::{AdaDual, Admission, CommPolicy, MaterializedNet, NetView, SrsfCap};
use crate::trace::{self, JobSpec, TraceConfig};
use crate::util::prop::prop_check;

fn cfg(n_servers: usize, gpus_per_server: usize) -> SimConfig {
    SimConfig {
        cluster: ClusterSpec::tiny(n_servers, gpus_per_server),
        comm: CommModel::paper_10gbe(),
        topology: TopologySpec::Flat,
        repricing: Repricing::Dynamic,
        priority: JobPriority::Srsf,
        coalescing: true,
        log_events: false,
        workers: 1,
        faults: FaultPlan::default(),
    }
}

fn two_tier_cfg(
    n_servers: usize,
    gpus_per_server: usize,
    rack_size: usize,
    oversub: f64,
) -> SimConfig {
    SimConfig {
        topology: TopologySpec::TwoTier { rack_size, oversubscription: oversub },
        ..cfg(n_servers, gpus_per_server)
    }
}

fn job(id: usize, arrival: f64, model: DnnModel, n_gpus: usize, iters: u64) -> JobSpec {
    JobSpec { id, arrival, model, n_gpus, iterations: iters }
}

fn run(cfg: &SimConfig, jobs: &[JobSpec]) -> SimResult {
    let mut placer = LwfPlacer::new(1);
    let policy = AdaDual { model: cfg.comm };
    simulate(cfg, jobs, &mut placer, &policy)
}

#[test]
fn single_job_single_gpu_matches_analytic() {
    let c = cfg(1, 1);
    let j = job(0, 0.0, DnnModel::ResNet50, 1, 50);
    let res = run(&c, &[j.clone()]);
    let want = j.compute_total(c.cluster.gpu_peak_gflops);
    assert!((res.jct[0] - want).abs() < 1e-6, "{} vs {want}", res.jct[0]);
    assert!((res.makespan - want).abs() < 1e-6);
    // The lone GPU is busy the whole time.
    assert!((res.avg_gpu_util() - 1.0).abs() < 1e-6);
}

#[test]
fn single_job_multi_gpu_one_server_no_comm() {
    let c = cfg(1, 4);
    let j = job(0, 0.0, DnnModel::Vgg16, 4, 20);
    let res = run(&c, &[j.clone()]);
    // Same wall time as 1 GPU: data-parallel workers run concurrently,
    // no communication inside one server.
    let want = j.compute_total(c.cluster.gpu_peak_gflops);
    assert!((res.jct[0] - want).abs() < 1e-6, "{} vs {want}", res.jct[0]);
    assert_eq!(res.clean_admissions + res.contended_admissions, 0);
}

#[test]
fn single_job_two_servers_pays_allreduce() {
    let c = cfg(2, 1);
    let j = job(0, 0.0, DnnModel::ResNet50, 2, 30);
    let res = run(&c, &[j.clone()]);
    let compute = j.compute_total(c.cluster.gpu_peak_gflops);
    let comm = c.comm.time_free(j.message_bytes()) * 30.0;
    let want = compute + comm;
    assert!(
        (res.jct[0] - want).abs() < 1e-6,
        "jct {} vs analytic {want}",
        res.jct[0]
    );
    assert_eq!(res.clean_admissions, 30);
    assert_eq!(res.contended_admissions, 0);
    assert_eq!(res.max_contention, 1);
}

#[test]
fn arrival_offset_respected() {
    let c = cfg(1, 1);
    let j = job(0, 100.0, DnnModel::LstmPtb, 1, 10);
    let res = run(&c, &[j.clone()]);
    let dur = j.compute_total(c.cluster.gpu_peak_gflops);
    assert!((res.finish[0] - (100.0 + dur)).abs() < 1e-6);
    assert!((res.jct[0] - dur).abs() < 1e-6);
}

#[test]
fn two_jobs_share_gpu_by_time_slicing() {
    // One 1-GPU cluster, two identical jobs arriving together: total busy
    // time is the sum; both finish; the later-priority one finishes last.
    let c = cfg(1, 1);
    let j0 = job(0, 0.0, DnnModel::ResNet50, 1, 40);
    let j1 = job(1, 0.0, DnnModel::ResNet50, 1, 40);
    let res = run(&c, &[j0.clone(), j1.clone()]);
    let each = j0.compute_total(c.cluster.gpu_peak_gflops);
    assert!(res.jct.iter().all(|t| t.is_finite()));
    let last = res.makespan;
    assert!((last - 2.0 * each).abs() < 1e-6, "{last} vs {}", 2.0 * each);
    // SRSF ties break to job 0, which should finish first.
    assert!(res.finish[0] < res.finish[1]);
}

#[test]
fn srsf_prefers_shorter_job() {
    let c = cfg(1, 1);
    let short = job(0, 0.0, DnnModel::ResNet50, 1, 10);
    let long = job(1, 0.0, DnnModel::ResNet50, 1, 1000);
    // Arrive simultaneously; the short one must not wait behind the long.
    let res = run(&c, &[long.clone(), short.clone()]);
    // ids: long=0? careful: ids are positional. long is job 0 here.
    let short_jct = res.jct[1];
    let want_short = short.compute_total(c.cluster.gpu_peak_gflops);
    assert!(
        short_jct < want_short * 1.5,
        "short job starved: jct={short_jct} ideal={want_short}"
    );
}

#[test]
fn contention_slows_transfers_versus_srsf1() {
    // Two 2-server jobs communicating heavily: SRSF(2) forces overlap,
    // SRSF(1) serialises. Both must respect Eq (5) timing; the overlapped
    // run has max_contention 2.
    let c = cfg(2, 2);
    let j0 = job(0, 0.0, DnnModel::Vgg16, 4, 20);
    let j1 = job(1, 0.0, DnnModel::Vgg16, 4, 20);
    // Force both jobs across servers: 4 GPUs over 2 servers of 2.
    let mut ff = FirstFitPlacer;
    let r1 = simulate(&c, &[j0.clone(), j1.clone()], &mut ff, &SrsfCap { cap: 1 });
    let mut ff = FirstFitPlacer;
    let r2 = simulate(&c, &[j0, j1], &mut ff, &SrsfCap { cap: 2 });
    assert_eq!(r1.max_contention, 1);
    assert_eq!(r2.max_contention, 2);
    assert!(r2.contended_admissions > 0);
    // Equal-size messages overlapping is exactly the paper's bad case:
    // SRSF(2) must not beat SRSF(1) here.
    let avg1 = r1.jct.iter().sum::<f64>() / 2.0;
    let avg2 = r2.jct.iter().sum::<f64>() / 2.0;
    assert!(avg2 >= avg1 - 1e-6, "blind overlap won: {avg2} < {avg1}");
}

#[test]
fn adadual_admits_small_against_large() {
    // A huge transfer in flight + a tiny newcomer: AdaDUAL overlaps
    // (ratio test passes) while SRSF(1) waits.
    let c = cfg(2, 2);
    // VGG (526 MB) long job and ResNet (99 MB) short job; ratio 0.19 < 0.387.
    let big = job(0, 0.0, DnnModel::Vgg16, 4, 40);
    let small = job(1, 0.0, DnnModel::ResNet50, 4, 40);
    let mut ff = FirstFitPlacer;
    let ada = simulate(&c, &[big.clone(), small.clone()], &mut ff, &AdaDual { model: c.comm });
    let mut ff = FirstFitPlacer;
    let srsf1 = simulate(&c, &[big, small], &mut ff, &SrsfCap { cap: 1 });
    assert!(ada.contended_admissions > 0, "AdaDUAL never overlapped");
    let avg_ada = ada.jct.iter().sum::<f64>() / 2.0;
    let avg_1 = srsf1.jct.iter().sum::<f64>() / 2.0;
    assert!(
        avg_ada <= avg_1 + 1e-6,
        "AdaDUAL {avg_ada} worse than SRSF(1) {avg_1}"
    );
}

#[test]
fn all_jobs_finish_on_paper_trace() {
    let c = SimConfig::paper();
    let jobs = trace::generate(&TraceConfig::paper_160());
    let res = run(&c, &jobs);
    assert!(res.jct.iter().all(|t| t.is_finite()), "some job never finished");
    assert!(res.makespan > 0.0);
    // Fast-forwarding coalesces most of the paper workload's events, so
    // the exact count is a perf metric (benches/sim_hotpath.rs), not an
    // invariant — but the crowded phase always leaves real events.
    assert!(res.n_events > 1_000);
}

#[test]
fn jct_at_least_critical_path() {
    let c = SimConfig::paper();
    let jobs = trace::generate(&TraceConfig::scaled(40, 3));
    let res = run(&c, &jobs);
    for (i, j) in jobs.iter().enumerate() {
        // Lower bound: contention-free compute-only critical path.
        let lb = j.compute_total(c.cluster.gpu_peak_gflops);
        assert!(
            res.jct[i] >= lb - 1e-6,
            "job {i} jct {} below lower bound {lb}",
            res.jct[i]
        );
    }
}

#[test]
fn gpu_busy_never_exceeds_makespan() {
    let c = SimConfig::paper();
    let jobs = trace::generate(&TraceConfig::scaled(30, 5));
    let res = run(&c, &jobs);
    for (g, &busy) in res.gpu_busy.iter().enumerate() {
        assert!(
            busy <= res.makespan + 1e-6,
            "gpu {g} busy {busy} > makespan {}",
            res.makespan
        );
    }
}

#[test]
fn event_log_records_lifecycle() {
    let mut c = cfg(2, 1);
    c.log_events = true;
    let jobs = [job(0, 0.0, DnnModel::ResNet50, 2, 3)];
    let res = run(&c, &jobs);
    let text: Vec<&str> = res.events.iter().map(|e| e.what.as_str()).collect();
    assert!(text.iter().any(|s| s.starts_with("arrive")));
    assert!(text.iter().any(|s| s.starts_with("place")));
    assert!(text.iter().any(|s| s.starts_with("comm-start")));
    assert!(text.iter().any(|s| s.starts_with("finish")));
}

#[test]
fn prop_simulator_invariants() {
    // Randomized small workloads: every job finishes, JCTs beat lower
    // bounds, utilisation bounded, contention never exceeds policy cap.
    prop_check(25, |g| {
        let n_servers = g.usize(1, 4);
        let gps = g.usize(1, 4);
        let c = cfg(n_servers, gps);
        let n_jobs = g.usize(1, 8);
        let total_gpus = n_servers * gps;
        let models = crate::model::ALL_MODELS;
        let jobs: Vec<JobSpec> = (0..n_jobs)
            .map(|i| {
                let n_gpus = g.usize(1, total_gpus.min(8));
                JobSpec {
                    id: i,
                    arrival: g.f64(0.0, 50.0),
                    model: *g.pick(&models),
                    n_gpus,
                    iterations: g.u64(1, 60),
                }
            })
            .collect();
        let cap = g.usize(1, 3);
        let use_ada = g.bool();
        let res = if use_ada {
            let mut p = LwfPlacer::new(1);
            simulate(&c, &jobs, &mut p, &AdaDual { model: c.comm })
        } else {
            let mut p = LwfPlacer::new(1);
            simulate(&c, &jobs, &mut p, &SrsfCap { cap })
        };
        for (i, j) in jobs.iter().enumerate() {
            if !res.jct[i].is_finite() {
                return Err(format!("job {i} unfinished"));
            }
            let lb = j.compute_total(c.cluster.gpu_peak_gflops);
            if res.jct[i] < lb - 1e-6 {
                return Err(format!("job {i} jct {} < lower bound {lb}", res.jct[i]));
            }
        }
        let max_allowed = if use_ada { 2 } else { cap };
        if res.max_contention > max_allowed {
            return Err(format!(
                "contention {} exceeded cap {max_allowed}",
                res.max_contention
            ));
        }
        let util = res.avg_gpu_util();
        if !(0.0..=1.0 + 1e-9).contains(&util) {
            return Err(format!("util {util} out of range"));
        }
        Ok(())
    });
}

#[test]
fn prop_more_contention_allowed_never_reduces_max() {
    // SRSF(3) should observe >= the contention SRSF(1) observes.
    prop_check(10, |g| {
        let c = cfg(2, 2);
        let n_jobs = g.usize(2, 6);
        let models = crate::model::ALL_MODELS;
        let jobs: Vec<JobSpec> = (0..n_jobs)
            .map(|i| JobSpec {
                id: i,
                arrival: g.f64(0.0, 5.0),
                model: *g.pick(&models),
                n_gpus: 4,
                iterations: g.u64(5, 30),
            })
            .collect();
        let mut p1 = FirstFitPlacer;
        let r1 = simulate(&c, &jobs, &mut p1, &SrsfCap { cap: 1 });
        let mut p3 = FirstFitPlacer;
        let r3 = simulate(&c, &jobs, &mut p3, &SrsfCap { cap: 3 });
        if r1.max_contention > 1 {
            return Err("SRSF(1) saw contention".into());
        }
        if r3.max_contention < r1.max_contention {
            return Err("cap-3 saw less contention than cap-1".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// net topology: the flat preset must reproduce the seed engine's
// per-server contention bookkeeping; two-tier opens genuinely new physics.

/// Replay an event log and independently re-derive per-server contention
/// counts (the seed engine's `per_server` bookkeeping), checking every
/// comm-start's logged k against them. This is an oracle *outside* the
/// link-indexed engine: it only uses placements and the comm lifecycle.
fn check_flat_matches_per_server_oracle(
    spec: &ClusterSpec,
    events: &[EventLog],
) -> Result<(), String> {
    fn job_id(rest: &str) -> Result<usize, String> {
        let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
        digits.parse().map_err(|_| format!("bad job id in '{rest}'"))
    }
    let mut servers_of_job: Vec<Option<Vec<usize>>> = Vec::new();
    let mut counts = vec![0usize; spec.n_servers];
    let mut saw_comm = false;
    for e in events {
        let w = e.what.as_str();
        if let Some(rest) = w.strip_prefix("place job") {
            let id = job_id(rest)?;
            let lb = w.find('[').ok_or_else(|| format!("no gpu list in '{w}'"))?;
            let rb = w.rfind(']').unwrap();
            let gpus: Vec<usize> = w[lb + 1..rb]
                .split(',')
                .filter_map(|x| x.trim().parse().ok())
                .collect();
            if servers_of_job.len() <= id {
                servers_of_job.resize(id + 1, None);
            }
            servers_of_job[id] = Some(spec.servers_of(&gpus));
        } else if let Some(rest) = w.strip_prefix("comm-start job") {
            saw_comm = true;
            let id = job_id(rest)?;
            let k: usize = rest
                .split("k=")
                .nth(1)
                .and_then(|x| x.parse().ok())
                .ok_or_else(|| format!("no k in '{w}'"))?;
            let servers = servers_of_job[id]
                .as_ref()
                .ok_or_else(|| format!("comm-start before place for job {id}"))?;
            let expect = 1 + servers.iter().map(|&s| counts[s]).max().unwrap();
            if k != expect {
                return Err(format!(
                    "job {id}: engine k={k} but per-server oracle says {expect}"
                ));
            }
            for &s in servers {
                counts[s] += 1;
            }
        } else if let Some(rest) = w.strip_prefix("comm-done job") {
            let id = job_id(rest)?;
            for &s in servers_of_job[id].as_ref().unwrap() {
                counts[s] -= 1;
            }
        }
    }
    if !saw_comm {
        return Err("workload produced no communication".to_string());
    }
    Ok(())
}

#[test]
fn prop_flat_topology_reproduces_seed_per_server_contention() {
    // Random multi-server workloads through both repricing modes and both
    // policy families: every admission's contention level under the
    // link-indexed flat fabric must equal the per-server count the seed
    // engine tracked.
    prop_check(25, |g| {
        let n_servers = g.usize(2, 4);
        let mut c = cfg(n_servers, g.usize(1, 2));
        c.log_events = true;
        c.repricing = if g.bool() { Repricing::Dynamic } else { Repricing::AtAdmission };
        let total = c.cluster.n_gpus();
        let n_jobs = g.usize(2, 6);
        let models = crate::model::ALL_MODELS;
        let jobs: Vec<JobSpec> = (0..n_jobs)
            .map(|i| JobSpec {
                id: i,
                arrival: g.f64(0.0, 10.0),
                model: *g.pick(&models),
                // At least 2 servers' worth of GPUs so All-Reduces happen.
                n_gpus: g.usize(c.cluster.gpus_per_server + 1, total),
                iterations: g.u64(1, 15),
            })
            .collect();
        let res = if g.bool() {
            let mut p = FirstFitPlacer;
            simulate(&c, &jobs, &mut p, &SrsfCap { cap: g.usize(1, 3) })
        } else {
            let mut p = FirstFitPlacer;
            simulate(&c, &jobs, &mut p, &AdaDual { model: c.comm })
        };
        check_flat_matches_per_server_oracle(&c.cluster, &res.events)
    });
}

#[test]
fn prop_flat_equals_uniform_heterogeneous() {
    // A heterogeneous fabric whose every NIC carries the base model is
    // physically the flat fabric; the two presets must produce identical
    // results (they exercise different Topology construction paths).
    prop_check(10, |g| {
        let n_servers = g.usize(2, 4);
        let c_flat = cfg(n_servers, 2);
        let c_het = SimConfig {
            topology: TopologySpec::Heterogeneous {
                nics: vec![c_flat.comm; n_servers],
            },
            ..c_flat.clone()
        };
        let models = crate::model::ALL_MODELS;
        let n_jobs = g.usize(2, 6);
        let jobs: Vec<JobSpec> = (0..n_jobs)
            .map(|i| JobSpec {
                id: i,
                arrival: g.f64(0.0, 10.0),
                model: *g.pick(&models),
                n_gpus: g.usize(1, n_servers * 2),
                iterations: g.u64(1, 20),
            })
            .collect();
        let mut p1 = LwfPlacer::new(1);
        let r1 = simulate(&c_flat, &jobs, &mut p1, &AdaDual { model: c_flat.comm });
        let mut p2 = LwfPlacer::new(1);
        let r2 = simulate(&c_het, &jobs, &mut p2, &AdaDual { model: c_het.comm });
        // Bitwise comparison: an unplaceable job's NaN must compare equal
        // to itself, and "identical" here really means bit-identical.
        let same = r1.jct.len() == r2.jct.len()
            && r1.jct.iter().zip(&r2.jct).all(|(a, b)| a.to_bits() == b.to_bits());
        if !same {
            return Err(format!("jct diverged: {:?} vs {:?}", r1.jct, r2.jct));
        }
        if r1.n_events != r2.n_events
            || r1.clean_admissions != r2.clean_admissions
            || r1.contended_admissions != r2.contended_admissions
            || r1.max_contention != r2.max_contention
        {
            return Err("engine counters diverged between flat and uniform-hetero".into());
        }
        Ok(())
    });
}

#[test]
fn two_tier_cross_rack_pays_oversubscribed_core_analytically() {
    // One job spanning both racks of a 4-server fabric (1 GPU each, racks
    // of 2) at 4:1: each All-Reduce crosses the core, whose per-byte time
    // is 4b, so JCT = compute + iters * (a + 4bM) exactly.
    let oversub = 4.0;
    let c = two_tier_cfg(4, 1, 2, oversub);
    let iters = 30u64;
    let j = job(0, 0.0, DnnModel::ResNet50, 4, iters);
    let res = run(&c, &[j.clone()]);
    let compute = j.compute_total(c.cluster.gpu_peak_gflops);
    let per_iter_comm = c.comm.a + oversub * c.comm.b * j.message_bytes();
    let want = compute + iters as f64 * per_iter_comm;
    assert!(
        (res.jct[0] - want).abs() < 1e-6,
        "jct {} vs analytic {want}",
        res.jct[0]
    );
    assert_eq!(res.max_contention, 1);
}

#[test]
fn two_tier_within_rack_matches_flat_exactly() {
    // A job confined to one rack never touches the core: its schedule is
    // bit-identical to the flat fabric's.
    let j = job(0, 0.0, DnnModel::Vgg16, 2, 25); // servers 0,1 = rack 0
    let flat = run(&cfg(4, 1), &[j.clone()]);
    let racked = run(&two_tier_cfg(4, 1, 2, 8.0), &[j]);
    assert_eq!(flat.jct, racked.jct);
    assert_eq!(flat.n_events, racked.n_events);
}

#[test]
fn two_tier_makespan_grows_with_oversubscription() {
    // Two cross-rack jobs under SRSF(1) (comm serialised): a slower core
    // strictly stretches the schedule.
    let jobs = [
        job(0, 0.0, DnnModel::Vgg16, 4, 15),
        job(1, 0.0, DnnModel::ResNet50, 4, 15),
    ];
    let mk = |oversub: f64| {
        let c = two_tier_cfg(4, 1, 2, oversub);
        let mut p = FirstFitPlacer;
        simulate(&c, &jobs, &mut p, &SrsfCap { cap: 1 }).makespan
    };
    let m1 = mk(1.0);
    let m4 = mk(4.0);
    let m8 = mk(8.0);
    assert!(m1 < m4 && m4 < m8, "makespans not monotonic: {m1} {m4} {m8}");
}

// ---------------------------------------------------------------------------
// steady-state fast-forwarding: `coalescing` must be a pure event-count
// optimisation — every metric bit-identical to the event-exact engine
// (docs/EXPERIMENTS.md §Perf).

fn bits_eq(label: &str, a: &[f64], b: &[f64]) -> Result<(), String> {
    if a.len() != b.len() || a.iter().zip(b).any(|(x, y)| x.to_bits() != y.to_bits()) {
        return Err(format!("{label} diverged:\n  on:  {a:?}\n  off: {b:?}"));
    }
    Ok(())
}

/// `on` ran with coalescing, `off` event-exact: everything except the
/// event count must be bit-identical.
fn check_equivalent(on: &SimResult, off: &SimResult) -> Result<(), String> {
    bits_eq("jct", &on.jct, &off.jct)?;
    bits_eq("finish", &on.finish, &off.finish)?;
    bits_eq("queue_wait", &on.queue_wait, &off.queue_wait)?;
    bits_eq("gpu_busy", &on.gpu_busy, &off.gpu_busy)?;
    bits_eq("gpu_alloc_window", &on.gpu_alloc_window, &off.gpu_alloc_window)?;
    bits_eq("makespan", &[on.makespan], &[off.makespan])?;
    if on.clean_admissions != off.clean_admissions
        || on.contended_admissions != off.contended_admissions
        || on.max_contention != off.max_contention
    {
        return Err(format!(
            "admission counters diverged: clean {} vs {}, contended {} vs {}, max_k {} vs {}",
            on.clean_admissions,
            off.clean_admissions,
            on.contended_admissions,
            off.contended_admissions,
            on.max_contention,
            off.max_contention
        ));
    }
    // n_events is deliberately NOT compared: it is the quantity coalescing
    // exists to change (and a macro-event dissolved inside its first
    // iteration can even cost one stale pop without saving any).
    Ok(())
}

#[test]
fn prop_coalescing_equivalent_to_event_exact() {
    // Randomized workloads × {flat, two-tier} × {srsf, fifo, las} × both
    // repricing modes × both policy families: the coalescing engine must
    // reproduce the event-exact engine's metrics field-for-field.
    prop_check(40, |g| {
        let n_servers = g.usize(2, 4);
        let gps = g.usize(1, 3);
        let mut c = cfg(n_servers, gps);
        c.repricing = if g.bool() { Repricing::Dynamic } else { Repricing::AtAdmission };
        c.priority = *g.pick(&JobPriority::all());
        if g.bool() {
            c.topology = TopologySpec::TwoTier { rack_size: 2, oversubscription: 4.0 };
        }
        let total = n_servers * gps;
        let n_jobs = g.usize(1, 6);
        let models = crate::model::ALL_MODELS;
        let jobs: Vec<JobSpec> = (0..n_jobs)
            .map(|i| JobSpec {
                id: i,
                arrival: g.f64(0.0, 30.0),
                model: *g.pick(&models),
                n_gpus: g.usize(1, total),
                iterations: g.u64(1, 120),
            })
            .collect();
        let cap = g.usize(1, 3);
        let use_ada = g.bool();
        let run_mode = |coalescing: bool| {
            let c = SimConfig { coalescing, ..c.clone() };
            let mut p = LwfPlacer::new(1);
            if use_ada {
                simulate(&c, &jobs, &mut p, &AdaDual { model: c.comm })
            } else {
                simulate(&c, &jobs, &mut p, &SrsfCap { cap })
            }
        };
        check_equivalent(&run_mode(true), &run_mode(false))
    });
}

#[test]
fn ff_single_job_collapses_events() {
    let c = cfg(1, 1);
    let j = job(0, 0.0, DnnModel::ResNet50, 1, 500);
    let on = run(&c, &[j.clone()]);
    let off = run(&SimConfig { coalescing: false, ..c.clone() }, &[j.clone()]);
    check_equivalent(&on, &off).unwrap();
    // 500 iterations × (fwd + bwd) collapse into one macro-event (the
    // first post-placement iteration stays event-exact by design).
    assert!(off.n_events >= 1_000, "exact run too small: {}", off.n_events);
    assert!(on.n_events < 10, "macro-event did not coalesce: {}", on.n_events);
    let want = j.compute_total(c.cluster.gpu_peak_gflops);
    assert!((on.jct[0] - want).abs() < 1e-6, "{} vs {want}", on.jct[0]);
}

#[test]
fn ff_multi_server_steady_state_matches_exact() {
    // A lone cross-server job under AtAdmission pricing: the whole
    // compute + All-Reduce chain coalesces, admission counters included.
    let mut c = cfg(2, 1);
    c.repricing = Repricing::AtAdmission;
    let j = job(0, 0.0, DnnModel::ResNet50, 2, 40);
    let on = run(&c, &[j.clone()]);
    let off = run(&SimConfig { coalescing: false, ..c.clone() }, &[j.clone()]);
    check_equivalent(&on, &off).unwrap();
    assert_eq!(on.clean_admissions, 40);
    assert_eq!(on.contended_admissions, 0);
    assert_eq!(on.max_contention, 1);
    assert!(
        on.n_events * 3 <= off.n_events,
        "wanted ≥3× fewer events: {} vs {}",
        on.n_events,
        off.n_events
    );
    let want = j.compute_total(c.cluster.gpu_peak_gflops)
        + 40.0 * c.comm.time_free(j.message_bytes());
    assert!((on.jct[0] - want).abs() < 1e-6, "{} vs {want}", on.jct[0]);
}

#[test]
fn ff_dynamic_repricing_never_coalesces_comm() {
    // Dynamic repricing invalidates the locked-rate premise, so a
    // multi-server job must stay event-exact (and still agree, trivially).
    let c = cfg(2, 1); // cfg() is Dynamic
    let j = job(0, 0.0, DnnModel::ResNet50, 2, 30);
    let on = run(&c, &[j.clone()]);
    let off = run(&SimConfig { coalescing: false, ..c.clone() }, &[j]);
    check_equivalent(&on, &off).unwrap();
    assert_eq!(on.n_events, off.n_events, "Dynamic comm must not coalesce");
}

#[test]
fn ff_arrival_mid_macro_reconciles_partial_iterations() {
    // job0 fast-forwards from t = 0; job1 arrives mid-iteration inside
    // the macro window. The reconciliation must hand the placer job0's
    // exact partial progress — all metrics bit-identical to event-exact.
    let c = cfg(1, 2);
    let j0 = job(0, 0.0, DnnModel::ResNet50, 1, 400);
    let t_iter = j0.t_iter(c.cluster.gpu_peak_gflops);
    let j1 = job(1, 13.7 * t_iter, DnnModel::ResNet50, 1, 50);
    let jobs = [j0, j1];
    let on = run(&c, &jobs);
    let off = run(&SimConfig { coalescing: false, ..c.clone() }, &jobs);
    check_equivalent(&on, &off).unwrap();
    // Separate GPUs: job0's schedule is unaffected by the interruption.
    let want0 = jobs[0].compute_total(c.cluster.gpu_peak_gflops);
    assert!((on.jct[0] - want0).abs() < 1e-6, "{} vs {want0}", on.jct[0]);
    assert!(on.n_events < off.n_events);
}

#[test]
fn ff_placement_onto_macro_gpu_preempts_exactly() {
    // One shared GPU: job1 lands on job0's GPU mid-macro, then SRSF
    // time-slices them per iteration. Still bit-identical.
    let c = cfg(1, 1);
    let j0 = job(0, 0.0, DnnModel::ResNet50, 1, 300);
    let t_iter = j0.t_iter(c.cluster.gpu_peak_gflops);
    let j1 = job(1, 10.3 * t_iter, DnnModel::ResNet50, 1, 20);
    let jobs = [j0, j1];
    let on = run(&c, &jobs);
    let off = run(&SimConfig { coalescing: false, ..c.clone() }, &jobs);
    check_equivalent(&on, &off).unwrap();
    // The short newcomer wins the SRSF race and finishes first; job0
    // re-coalesces its tail after job1 leaves.
    assert!(on.finish[1] < on.finish[0]);
    assert!(on.n_events < off.n_events);
}

#[test]
fn ff_lockstep_twins_reconcile_boundary_ties_exactly() {
    // Two same-model jobs placed at the same instant run bitwise-lockstep
    // chains, so the shorter one's finish lands *bit-exactly* on the
    // longer one's iteration boundary. Reconciliation must replay the
    // event-exact heap tie-break (placement order) for that boundary —
    // under FIFO the longer, earlier-placed job's boundary completes
    // before the finish-triggered placement pass; a third queued job then
    // observes identical cluster state in both engines.
    let mut c = cfg(1, 2);
    c.priority = JobPriority::Fifo;
    let long = job(0, 0.0, DnnModel::ResNet50, 1, 120);
    let short = job(1, 0.0, DnnModel::ResNet50, 1, 60);
    let t_iter = long.t_iter(c.cluster.gpu_peak_gflops);
    // Arrives while both GPUs are held; placeable only on a finish-
    // triggered pass (the boundary-tie reconciliation path).
    let late = job(2, 2.5 * t_iter, DnnModel::ResNet50, 1, 40);
    // Fill both GPUs' memory so the late job must wait for the short
    // twin's release.
    let mut tight = c.clone();
    tight.cluster.gpu_mem_bytes = 4.0 * 1024.0 * 1024.0 * 1024.0;
    let jobs = [long, short, late];
    let on = run(&tight, &jobs);
    let off = run(&SimConfig { coalescing: false, ..tight.clone() }, &jobs);
    check_equivalent(&on, &off).unwrap();
    // The short twin's finish time is bit-identical to the long twin's
    // 60th boundary — the collision actually happened.
    let peak = tight.cluster.gpu_peak_gflops;
    let m = crate::model::PerfModel::for_model(DnnModel::ResNet50);
    let b = DnnModel::ResNet50.spec().batch_size;
    let (t_fwd, t_bwd) = (m.t_fwd(b, peak), m.t_bwd(b, peak));
    let mut boundary = 0.0f64;
    for _ in 0..60 {
        boundary = (boundary + t_fwd) + t_bwd;
    }
    assert_eq!(
        on.finish[1].to_bits(),
        boundary.to_bits(),
        "twins did not run lockstep; the tie path was not exercised"
    );
}

#[test]
fn ff_event_log_is_synthesised_for_coalesced_comm() {
    // With event logging on, a coalesced multi-server job's comm
    // lifecycle is synthesised so log consumers (the per-server oracle
    // above) see the same k = 1 start/done pairs the exact engine logs.
    let mut c = cfg(2, 1);
    c.repricing = Repricing::AtAdmission;
    c.log_events = true;
    let res = run(&c, &[job(0, 0.0, DnnModel::ResNet50, 2, 12)]);
    let starts = res.events.iter().filter(|e| e.what.starts_with("comm-start")).count();
    let dones = res.events.iter().filter(|e| e.what.starts_with("comm-done")).count();
    assert_eq!(starts, 12);
    assert_eq!(dones, 12);
    check_flat_matches_per_server_oracle(&c.cluster, &res.events).unwrap();
}

#[test]
fn gpu_utils_zero_makespan_matches_avg() {
    // Regression: gpu_utils used to divide by an epsilon-clamped makespan
    // while avg_gpu_util returned 0 — the two must agree on a degenerate
    // (zero-length) schedule.
    let res = SimResult {
        jct: vec![],
        finish: vec![],
        queue_wait: vec![],
        gpu_busy: vec![0.0, 0.0],
        gpu_alloc_window: vec![0.0, 0.0],
        makespan: 0.0,
        n_events: 0,
        contended_admissions: 0,
        clean_admissions: 0,
        max_contention: 0,
        preempted: 0,
        restarted: 0,
        lost_iters: 0,
        events: vec![],
    };
    assert_eq!(res.avg_gpu_util(), 0.0);
    assert_eq!(res.gpu_utils(), vec![0.0, 0.0]);
}

// ---------------------------------------------------------------------------
// observer API: the typed event stream must reproduce the monolithic
// SimResult field-for-field (the facade contract), keep the legacy log
// byte-identical across coalescing, and cost nothing when detached.

/// Random small workload + engine axes shared by the observer property
/// tests: jobs, config (log_events on) and the policy choice.
fn random_setup(g: &mut crate::util::prop::Gen) -> (SimConfig, Vec<JobSpec>, bool, usize) {
    let n_servers = g.usize(2, 4);
    let gps = g.usize(1, 3);
    let mut c = cfg(n_servers, gps);
    c.log_events = true;
    c.repricing = if g.bool() { Repricing::Dynamic } else { Repricing::AtAdmission };
    c.priority = *g.pick(&JobPriority::all());
    if g.bool() {
        c.topology = TopologySpec::TwoTier { rack_size: 2, oversubscription: 4.0 };
    }
    let total = n_servers * gps;
    let n_jobs = g.usize(1, 6);
    let models = crate::model::ALL_MODELS;
    let jobs: Vec<JobSpec> = (0..n_jobs)
        .map(|i| JobSpec {
            id: i,
            arrival: g.f64(0.0, 30.0),
            model: *g.pick(&models),
            n_gpus: g.usize(1, total),
            iterations: g.u64(1, 100),
        })
        .collect();
    (c, jobs, g.bool(), g.usize(1, 3))
}

fn run_policy(c: &SimConfig, jobs: &[JobSpec], use_ada: bool, cap: usize) -> SimResult {
    let mut p = LwfPlacer::new(1);
    if use_ada {
        simulate(c, jobs, &mut p, &AdaDual { model: c.comm })
    } else {
        simulate(c, jobs, &mut p, &SrsfCap { cap })
    }
}

fn logs_eq(label: &str, a: &[EventLog], b: &[EventLog]) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{label}: {} vs {} log lines", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if x.t.to_bits() != y.t.to_bits() || x.what != y.what {
            return Err(format!(
                "{label}: line {i} diverged: ({}, '{}') vs ({}, '{}')",
                x.t, x.what, y.t, y.what
            ));
        }
    }
    Ok(())
}

#[test]
fn prop_observers_reproduce_monolithic_simresult() {
    // The facade (`simulate`) against manually attached MetricsObserver +
    // LegacyLog through `simulate_observed`: every SimResult field and
    // every log line must match bit-for-bit, across random traces x
    // topologies x priorities x repricings x coalescing on/off.
    prop_check(20, |g| {
        let (mut c, jobs, use_ada, cap) = random_setup(g);
        c.coalescing = g.bool();
        let facade = run_policy(&c, &jobs, use_ada, cap);
        let mut metrics = MetricsObserver::new();
        let mut log = LegacyLog::new();
        {
            let mut obs: [&mut dyn SimObserver; 2] = [&mut metrics, &mut log];
            let mut p = LwfPlacer::new(1);
            if use_ada {
                simulate_observed(&c, &jobs, &mut p, &AdaDual { model: c.comm }, &mut obs);
            } else {
                simulate_observed(&c, &jobs, &mut p, &SrsfCap { cap }, &mut obs);
            }
        }
        let mut manual = metrics.into_result();
        manual.events = log.into_events();
        check_equivalent(&facade, &manual)?;
        if facade.n_events != manual.n_events {
            return Err(format!(
                "n_events diverged: {} vs {}",
                facade.n_events, manual.n_events
            ));
        }
        logs_eq("facade vs manual", &facade.events, &manual.events)
    });
}

/// Wraps a policy, asserting at every admission decision that the lazy
/// [`NetView`] (live per-link lists + on-demand residual resolution)
/// yields the same answer as a fully materialized snapshot of it — the
/// per-pass `Vec<Vec<(id, remaining)>>` view the engine used to rebuild.
struct MaterializedCheck<P: CommPolicy> {
    inner: P,
}

impl<P: CommPolicy> CommPolicy for MaterializedCheck<P> {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn admit(&self, msg: f64, links: &[LinkId], net: &NetView) -> Admission {
        let lazy = self.inner.admit(msg, links, net);
        let snapshot: Vec<Vec<(usize, f64)>> = (0..net.n_links())
            .map(|l| {
                net.link_tasks(l).iter().map(|&id| (id, net.remaining_of(id))).collect()
            })
            .collect();
        let mat = MaterializedNet::from_tuples(&snapshot);
        let full = mat.with_view(|m| self.inner.admit(msg, links, m));
        assert_eq!(lazy, full, "lazy vs materialized admission diverged ({})", self.name());
        lazy
    }
}

#[test]
fn prop_lazy_netview_admissions_match_materialized_view() {
    // Random traces × {flat, two-tier} × {srsf, fifo, las} × both
    // repricings × both policy families (the
    // prop_observers_reproduce_monolithic_simresult generator): every
    // admission decision through the lazy view must equal the decision
    // over a materialized snapshot (asserted inside the wrapper), and the
    // wrapper itself must be transparent — the whole SimResult and event
    // log bit-identical to the unwrapped run.
    prop_check(15, |g| {
        let (c, jobs, use_ada, cap) = random_setup(g);
        let mut p = LwfPlacer::new(1);
        let wrapped = if use_ada {
            simulate(&c, &jobs, &mut p, &MaterializedCheck { inner: AdaDual { model: c.comm } })
        } else {
            simulate(&c, &jobs, &mut p, &MaterializedCheck { inner: SrsfCap { cap } })
        };
        let base = run_policy(&c, &jobs, use_ada, cap);
        check_equivalent(&wrapped, &base)?;
        if wrapped.n_events != base.n_events {
            return Err(format!(
                "n_events diverged: {} vs {}",
                wrapped.n_events, base.n_events
            ));
        }
        logs_eq("wrapped vs base", &wrapped.events, &base.events)
    });
}

#[test]
fn placement_gate_skips_hopeless_placer_calls() {
    // A memory-saturated cluster: job 0 fills every GPU; K later jobs
    // queue behind it. Release-generation + capacity gating must keep
    // the per-arrival placement pass from re-running the placer over the
    // whole queue (the old engine made O(queue) placer calls per
    // arrival, O(K²) overall) — while producing the same schedule.
    struct CountingPlacer {
        inner: LwfPlacer,
        calls: usize,
    }
    impl Placer for CountingPlacer {
        fn name(&self) -> &'static str {
            "counting"
        }
        fn place(
            &mut self,
            job: &JobSpec,
            state: &crate::cluster::ClusterState,
        ) -> Option<Vec<usize>> {
            self.calls += 1;
            self.inner.place(job, state)
        }
    }
    let k = 16usize;
    let mut c = cfg(1, k); // one server, K GPUs
    // Each GPU holds exactly one resident: a second ResNet50 cannot fit.
    let mem = DnnModel::ResNet50.spec().mem_bytes;
    c.cluster.gpu_mem_bytes = 1.5 * mem;
    let hog = job(0, 0.0, DnnModel::ResNet50, k, 3000); // all K GPUs, long
    let t_iter = hog.t_iter(c.cluster.gpu_peak_gflops);
    let mut jobs = vec![hog];
    for i in 1..=k {
        // All arrive while job 0 still runs (its runtime is 3000 iters).
        jobs.push(job(i, i as f64 * t_iter, DnnModel::ResNet50, 1, 5));
    }
    let mut placer = CountingPlacer { inner: LwfPlacer::new(1), calls: 0 };
    let res = simulate(&c, &jobs, &mut placer, &AdaDual { model: c.comm });
    assert!(res.jct.iter().all(|t| t.is_finite()), "some job never placed");
    // Gated engine: 1 call (job 0) + ≤1 call per arrival (the newcomer
    // only; in debug builds the capacity gate double-checks each verdict
    // against the real placer, at most doubling this) + K calls on the
    // release pass when job 0 finishes. The ungated engine needed
    // 1 + K(K+1)/2 + K ≈ 150 for K = 16.
    let bound = 1 + 2 * k + k + 2;
    assert!(
        placer.calls <= bound,
        "placement gate ineffective: {} placer calls (bound {bound})",
        placer.calls
    );
    // And the schedule itself is untouched by gating: identical to the
    // plain engine run.
    let mut plain = LwfPlacer::new(1);
    let base = simulate(&c, &jobs, &mut plain, &AdaDual { model: c.comm });
    check_equivalent(&res, &base).unwrap();
}

#[test]
fn heap_compaction_dynamic_storm_stays_exact() {
    // Dynamic repricing reprices every transfer sharing a link on every
    // admission/completion, stranding the superseded CommDone prediction
    // each time. ~96 concurrent transfers all crossing the same two NICs
    // strand thousands of stale entries during the admission burst alone
    // — far past the compaction threshold — so the heap rebuild runs
    // repeatedly and must drop exactly the stale set (debug-asserted
    // against the counter inside `compact_heap`) and no live event:
    // checked by every job finishing, the per-server contention oracle
    // holding over the full log, and coalescing on/off equivalence
    // surviving the storm.
    struct CrossPlacer; // one feasible GPU per server: every job spans both NICs
    impl Placer for CrossPlacer {
        fn name(&self) -> &'static str {
            "cross"
        }
        fn place(
            &mut self,
            job: &JobSpec,
            state: &crate::cluster::ClusterState,
        ) -> Option<Vec<usize>> {
            let mut out = Vec::with_capacity(state.spec.n_servers);
            for s in 0..state.spec.n_servers {
                let g = state
                    .spec
                    .gpus_of(s)
                    .filter(|&g| state.fits(g, job.mem_bytes()))
                    .min_by(|&a, &b| {
                        state.gpus[a]
                            .load
                            .partial_cmp(&state.gpus[b].load)
                            .unwrap()
                            .then(a.cmp(&b))
                    })?;
                out.push(g);
            }
            Some(out)
        }
    }
    let mut c = cfg(2, 32); // 64 GPUs behind 2 NICs
    c.log_events = true;
    c.repricing = Repricing::Dynamic;
    let jobs: Vec<JobSpec> = (0..100)
        .map(|i| JobSpec {
            id: i,
            arrival: i as f64 * 0.01,
            model: DnnModel::Vgg16, // big message: long flights, many repricings
            n_gpus: 2,              // one GPU on each server via CrossPlacer
            iterations: 4,
        })
        .collect();
    let run_mode = |coalescing: bool| {
        let cc = SimConfig { coalescing, ..c.clone() };
        let mut p = CrossPlacer;
        simulate(&cc, &jobs, &mut p, &SrsfCap { cap: 1000 })
    };
    let on = run_mode(true);
    let off = run_mode(false);
    assert!(on.jct.iter().all(|t| t.is_finite()), "job lost in the repricing storm");
    assert!(on.max_contention > 50, "storm never piled up: k = {}", on.max_contention);
    check_equivalent(&on, &off).unwrap();
    check_flat_matches_per_server_oracle(&c.cluster, &on.events).unwrap();
}

#[test]
fn prop_legacy_log_identical_across_coalescing() {
    // The pre-redesign engine's contract, now load-bearing for every log
    // consumer: the synthesised (coalescing=on) log equals the live
    // (coalescing=off) one line-for-line. Same-timestamp lines are
    // compared as a set (sorted by content) — the only realizable
    // bit-equal collisions are lockstep twins, whose relative order is
    // placement order in both engines, but the comparison should not
    // depend on that subtlety.
    prop_check(25, |g| {
        let (c, jobs, use_ada, cap) = random_setup(g);
        let on = run_policy(&SimConfig { coalescing: true, ..c.clone() }, &jobs, use_ada, cap);
        let off = run_policy(&SimConfig { coalescing: false, ..c.clone() }, &jobs, use_ada, cap);
        check_equivalent(&on, &off)?;
        let canon = |events: &[EventLog]| -> Vec<EventLog> {
            let mut v = events.to_vec();
            v.sort_by(|a, b| a.t.total_cmp(&b.t).then_with(|| a.what.cmp(&b.what)));
            v
        };
        logs_eq("coalescing on vs off", &canon(&on.events), &canon(&off.events))
    });
}

#[test]
fn no_legacy_log_means_no_event_strings() {
    // Structural guarantee of the redesign: SimEvent carries no heap
    // strings and all formatting lives in LegacyLog, so a run without it
    // reports an empty events vec while n_events still counts.
    let c = cfg(2, 2); // log_events: false
    let jobs = trace::generate(&TraceConfig::scaled(12, 3));
    let res = run(&c, &jobs);
    assert!(res.events.is_empty(), "events accumulated without LegacyLog");
    assert!(res.n_events > 0, "n_events not counted");
    // Through the raw observer entrypoint the engine emits typed events
    // only — a counting observer sees them without any log attached.
    struct Counter {
        n: u64,
    }
    impl SimObserver for Counter {
        fn on_event(&mut self, _ev: &SimEvent<'_>) {
            self.n += 1;
        }
    }
    let mut counter = Counter { n: 0 };
    {
        let mut obs: [&mut dyn SimObserver; 1] = [&mut counter];
        let mut p = LwfPlacer::new(1);
        simulate_observed(&c, &jobs, &mut p, &AdaDual { model: c.comm }, &mut obs);
    }
    assert!(counter.n > 0, "no typed events emitted");
}

#[test]
fn jsonl_sink_streams_parseable_lines() {
    let c = cfg(2, 1); // Dynamic repricing: comm stays event-exact
    let jobs = [
        job(0, 0.0, DnnModel::ResNet50, 2, 5),
        job(1, 1.0, DnnModel::Vgg16, 2, 5),
    ];
    let mut metrics = MetricsObserver::new();
    let mut sink = JsonlSink::new(Vec::new());
    {
        let mut obs: [&mut dyn SimObserver; 2] = [&mut metrics, &mut sink];
        let mut p = LwfPlacer::new(1);
        simulate_observed(&c, &jobs, &mut p, &AdaDual { model: c.comm }, &mut obs);
    }
    let n = sink.written();
    assert!(n > 0);
    let buf = sink.finish().unwrap();
    let text = String::from_utf8(buf).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len() as u64, n);
    let mut kinds = std::collections::BTreeSet::new();
    for line in lines {
        let v = crate::util::json::Json::parse(line).unwrap();
        assert!(v.req_f64("t").is_ok(), "line without timestamp: {line}");
        kinds.insert(v.req_str("ev").unwrap().to_string());
    }
    for want in ["job-arrived", "job-placed", "comm-admitted", "comm-finished", "job-finished"] {
        assert!(kinds.contains(want), "missing {want} in {kinds:?}");
    }
}

/// `io::Write` double: accepts the first `good` write calls, then fails
/// every call; `flush` fails iff `flush_fails`.
struct FailingWriter {
    good: usize,
    writes: usize,
    flush_fails: bool,
}

impl std::io::Write for FailingWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.writes += 1;
        if self.writes > self.good {
            Err(std::io::Error::new(std::io::ErrorKind::Other, "disk full (test double)"))
        } else {
            Ok(buf.len())
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        if self.flush_fails {
            Err(std::io::Error::new(std::io::ErrorKind::Other, "flush failed (test double)"))
        } else {
            Ok(())
        }
    }
}

#[test]
fn jsonl_sink_defers_write_errors_to_finish() {
    let c = cfg(2, 1);
    let jobs = [job(0, 0.0, DnnModel::ResNet50, 2, 5)];

    // Each event is two write calls (line + newline), so `good: 4` lets
    // exactly two events through before the disk "fills". The first
    // failure must stop writing — written() freezes — and surface from
    // finish(), not panic mid-run.
    let mut sink = JsonlSink::new(FailingWriter { good: 4, writes: 0, flush_fails: false });
    {
        let mut obs: [&mut dyn SimObserver; 1] = [&mut sink];
        let mut p = LwfPlacer::new(1);
        simulate_observed(&c, &jobs, &mut p, &AdaDual { model: c.comm }, &mut obs);
    }
    assert_eq!(sink.written(), 2, "writing must stop at the first error");
    let err = sink.finish().expect_err("write failure must surface from finish()");
    assert!(err.to_string().contains("disk full"), "{err}");

    // Flush-only failure: every write lands, but the end-of-run flush
    // fails — still deferred to finish().
    let mut sink = JsonlSink::new(FailingWriter { good: usize::MAX, writes: 0, flush_fails: true });
    {
        let mut obs: [&mut dyn SimObserver; 1] = [&mut sink];
        let mut p = LwfPlacer::new(1);
        simulate_observed(&c, &jobs, &mut p, &AdaDual { model: c.comm }, &mut obs);
    }
    assert!(sink.written() > 0);
    let err = sink.finish().expect_err("flush failure must surface from finish()");
    assert!(err.to_string().contains("flush failed"), "{err}");
}

#[test]
fn timeline_observer_records_allocation_spans() {
    let c = cfg(1, 2);
    let jobs = [
        job(0, 0.0, DnnModel::ResNet50, 1, 10),
        job(1, 0.0, DnnModel::Vgg16, 1, 5),
    ];
    let mut metrics = MetricsObserver::new();
    let mut tl = TimelineObserver::new();
    {
        let mut obs: [&mut dyn SimObserver; 2] = [&mut metrics, &mut tl];
        let mut p = LwfPlacer::new(1);
        simulate_observed(&c, &jobs, &mut p, &AdaDual { model: c.comm }, &mut obs);
    }
    let res = metrics.into_result();
    // One 1-GPU allocation span per job, ending at its finish time.
    assert_eq!(tl.spans().len(), 2);
    for s in tl.spans() {
        assert!(s.end >= s.start, "span runs backwards: {s:?}");
        assert_eq!(s.end.to_bits(), res.finish[s.job].to_bits());
    }
    assert_eq!(tl.to_json().as_arr().unwrap().len(), 2);
}

#[test]
fn contention_profiler_sees_overlap() {
    // Two equal jobs forced to overlap under SRSF(2): the shared server
    // NICs spend measurable time at contention level 2.
    let mut c = cfg(2, 2);
    c.coalescing = false; // event-exact: per-link dwell accounting is exact
    let jobs = [
        job(0, 0.0, DnnModel::Vgg16, 4, 20),
        job(1, 0.0, DnnModel::Vgg16, 4, 20),
    ];
    let mut metrics = MetricsObserver::new();
    let mut prof = ContentionProfiler::new();
    {
        let mut obs: [&mut dyn SimObserver; 2] = [&mut metrics, &mut prof];
        let mut p = FirstFitPlacer;
        simulate_observed(&c, &jobs, &mut p, &SrsfCap { cap: 2 }, &mut obs);
    }
    let res = metrics.into_result();
    assert!(res.contended_admissions > 0, "workload never overlapped");
    let two_way: f64 = (0..2).map(|l| prof.seconds_at(l, 2)).sum();
    assert!(two_way > 0.0, "no 2-way link time recorded");
    // And some clean (level-1) time exists too.
    let one_way: f64 = (0..2).map(|l| prof.seconds_at(l, 1)).sum();
    assert!(one_way > 0.0);
    // With the end-of-run closeout, each observed link's histogram sums
    // to the whole simulated span (the run ends at the last finish).
    let tol = 1e-9 * res.makespan.max(1.0);
    for l in 0..2usize {
        let total: f64 = (0..8).map(|lvl| prof.seconds_at(l, lvl)).sum();
        assert!(
            (total - res.makespan).abs() < tol,
            "link {l} histogram sums to {total}, makespan {}",
            res.makespan
        );
    }
}

#[test]
fn fast_forward_lifecycle_events_emitted() {
    // A macro-event is applied for the long job, dissolved when the
    // newcomer's placement pass reconciles it, and re-applied for the
    // tail — all visible to observers.
    #[derive(Default)]
    struct FfWatch {
        applied: u32,
        dissolved: u32,
        coalesced_iters: u64,
    }
    impl SimObserver for FfWatch {
        fn on_event(&mut self, ev: &SimEvent<'_>) {
            match *ev {
                SimEvent::FastForwardApplied { .. } => self.applied += 1,
                SimEvent::FastForwardDissolved { .. } => self.dissolved += 1,
                SimEvent::IterationsCoalesced { n, .. } => self.coalesced_iters += n,
                _ => {}
            }
        }
    }
    let c = cfg(1, 2);
    let j0 = job(0, 0.0, DnnModel::ResNet50, 1, 400);
    let t_iter = j0.t_iter(c.cluster.gpu_peak_gflops);
    let j1 = job(1, 13.7 * t_iter, DnnModel::ResNet50, 1, 50);
    let jobs = [j0, j1];
    let mut watch = FfWatch::default();
    {
        let mut obs: [&mut dyn SimObserver; 1] = [&mut watch];
        let mut p = LwfPlacer::new(1);
        simulate_observed(&c, &jobs, &mut p, &AdaDual { model: c.comm }, &mut obs);
    }
    assert!(watch.applied >= 2, "applied {} macro-events", watch.applied);
    assert!(watch.dissolved >= 1, "dissolved {}", watch.dissolved);
    assert!(watch.coalesced_iters > 0);
}

// ---------------------------------------------------------------------------
// streaming mode: polling a JobSource at arrival boundaries must be
// bit-identical to pre-seeding the whole trace (the batch path), and the
// constant-memory percentile observer must agree with exact statistics.

#[test]
fn prop_streaming_source_matches_batch_run() {
    // Random normalized traces × {flat, two-tier} × {srsf, fifo, las} ×
    // both repricings × both policy families × coalescing on/off: a
    // VecSource-fed streaming run must reproduce the batch run's
    // SimResult field-for-field, event-count-for-event-count and legacy
    // log line-for-line.
    prop_check(20, |g| {
        let (mut c, mut jobs, use_ada, cap) = random_setup(g);
        c.coalescing = g.bool();
        // The contract's precondition: "same jobs" means the normalized
        // (arrival-sorted, sequentially-id'd) trace every source yields.
        crate::source::normalize(&mut jobs);
        let batch = run_policy(&c, &jobs, use_ada, cap);
        let mut src = crate::source::VecSource::new(jobs.clone());
        let mut p = LwfPlacer::new(1);
        let streamed = if use_ada {
            simulate_stream(&c, &mut src, &mut p, &AdaDual { model: c.comm })
        } else {
            simulate_stream(&c, &mut src, &mut p, &SrsfCap { cap })
        }
        .map_err(|e| format!("streaming run failed: {e}"))?;
        check_equivalent(&streamed, &batch)?;
        if streamed.n_events != batch.n_events {
            return Err(format!(
                "n_events diverged: streamed {} vs batch {}",
                streamed.n_events, batch.n_events
            ));
        }
        logs_eq("streamed vs batch", &streamed.events, &batch.events)
    });
}

#[test]
fn streaming_empty_source_completes_cleanly() {
    let c = cfg(2, 2);
    let mut src = crate::source::VecSource::new(Vec::new());
    let mut p = LwfPlacer::new(1);
    let res = simulate_stream(&c, &mut src, &mut p, &AdaDual { model: c.comm }).unwrap();
    assert!(res.jct.is_empty());
    assert_eq!(res.makespan, 0.0);
    assert_eq!(res.n_events, 0);
    // The zero-job result evaluates without panicking (satellite of the
    // empty-percentile fix).
    let e = crate::metrics::Evaluation::from_sim("empty", &res);
    assert_eq!(e.jct.n, 0);
}

#[test]
fn streaming_rejects_out_of_order_sources() {
    // A source that breaks its ordering contract mid-stream must surface
    // a clean error, not corrupt the schedule.
    struct Backwards {
        left: Vec<JobSpec>,
    }
    impl crate::source::JobSource for Backwards {
        fn next_job(&mut self) -> crate::util::error::Result<Option<JobSpec>> {
            Ok(self.left.pop())
        }
    }
    let c = cfg(1, 2);
    let mut src = Backwards {
        left: vec![
            job(0, 5.0, DnnModel::ResNet50, 1, 5), // popped second: goes backwards
            job(1, 9.0, DnnModel::ResNet50, 1, 5),
        ],
    };
    let mut p = LwfPlacer::new(1);
    let e = simulate_stream(&c, &mut src, &mut p, &AdaDual { model: c.comm })
        .unwrap_err()
        .to_string();
    assert!(e.contains("ordering contract"), "{e}");
}

#[test]
fn percentiles_observer_matches_exact_metrics() {
    // Stream a small trace with both the exact metrics observer and the
    // constant-memory percentile observer attached: counts, means and
    // (below the P² cutover of 5 samples) exact quantiles must agree.
    let c = cfg(2, 2);
    let mut jobs = trace::generate(&TraceConfig::scaled(4, 7));
    // The scaled histogram can emit jobs wider than this 4-GPU cluster;
    // clamp (as Scenario::jobs does) so every job places and finishes.
    for j in &mut jobs {
        j.n_gpus = j.n_gpus.min(c.cluster.n_gpus());
    }
    crate::source::normalize(&mut jobs);
    let mut metrics = MetricsObserver::new();
    let mut pct = PercentilesObserver::new();
    {
        let mut src = crate::source::VecSource::new(jobs.clone());
        let mut obs: [&mut dyn SimObserver; 2] = [&mut metrics, &mut pct];
        let mut p = LwfPlacer::new(1);
        simulate_stream_observed(&c, &mut src, &mut p, &AdaDual { model: c.comm }, &mut obs)
            .unwrap();
    }
    let exact = metrics.into_result();
    let jcts: Vec<f64> = exact.jct.iter().copied().filter(|t| t.is_finite()).collect();
    assert_eq!(jcts.len(), jobs.len(), "not every job finished");
    let s = pct.jct_stats();
    assert_eq!(pct.arrived(), jobs.len() as u64);
    assert_eq!(pct.finished(), jobs.len() as u64);
    assert_eq!(pct.in_flight(), 0);
    assert_eq!(s.count, jobs.len() as u64);
    let mean = jcts.iter().sum::<f64>() / jcts.len() as f64;
    assert!((s.mean - mean).abs() < 1e-9, "{} vs {mean}", s.mean);
    let p50 = crate::util::stats::try_percentile(&jcts, 50.0).unwrap();
    assert!((s.p50 - p50).abs() < 1e-9, "{} vs {p50}", s.p50);
    assert_eq!(pct.makespan().to_bits(), exact.makespan.to_bits());
    assert_eq!(pct.n_events(), exact.n_events);
    // Queue delay is place-time minus arrival; with 4 jobs it is exact too.
    let q = pct.queue_delay_stats();
    assert_eq!(q.count, jobs.len() as u64);
    assert!(q.min >= 0.0);
    // The JSON snapshot parses and carries both distributions.
    let v = crate::util::json::Json::parse(&pct.to_json().to_string()).unwrap();
    assert!(v.get("jct").is_some() && v.get("queue_delay").is_some());
}

#[test]
fn two_tier_contention_meets_on_the_core_link() {
    // Two jobs on disjoint server pairs but both crossing racks: their
    // NICs never collide, yet SRSF(1) must still serialise them because
    // they share the rack uplinks — contention the flat model cannot see.
    let c = two_tier_cfg(4, 1, 2, 4.0);
    // servers {0,2} and {1,3}: disjoint NICs, both cross racks 0 and 1.
    struct PairPlacer;
    impl crate::placement::Placer for PairPlacer {
        fn name(&self) -> &'static str {
            "pair"
        }
        fn place(
            &mut self,
            job: &JobSpec,
            _state: &crate::cluster::ClusterState,
        ) -> Option<Vec<usize>> {
            Some(if job.id == 0 { vec![0, 2] } else { vec![1, 3] })
        }
    }
    let jobs = [
        job(0, 0.0, DnnModel::Vgg16, 2, 10),
        job(1, 0.0, DnnModel::Vgg16, 2, 10),
    ];
    let mut p = PairPlacer;
    let srsf2 = simulate(&c, &jobs, &mut p, &SrsfCap { cap: 2 });
    assert!(
        srsf2.contended_admissions > 0,
        "uplink contention never observed"
    );
    assert_eq!(srsf2.max_contention, 2);
    // On the flat fabric the same layout shows zero contention.
    let c_flat = cfg(4, 1);
    let mut p = PairPlacer;
    let flat = simulate(&c_flat, &jobs, &mut p, &SrsfCap { cap: 2 });
    assert_eq!(flat.contended_admissions, 0);
    assert_eq!(flat.max_contention, 1);
}

// ---------------------------------------------------------------------------
// parallel advancement (`SimConfig::workers`): fanning reconcile walks over
// a worker pool must be invisible — results, event counts and the legacy
// log all bit-identical to the serial engine.

#[test]
fn prop_parallel_advance_bit_identical_to_serial() {
    // Random traces × topologies × priorities × repricing × policies ×
    // 2..4 workers. Unlike coalescing, parallelism must not
    // even change `n_events` — it reorders nothing, it only computes the
    // same walks on more threads.
    prop_check(30, |g| {
        let (c, jobs, use_ada, cap) = random_setup(g);
        let serial = run_policy(&c, &jobs, use_ada, cap);
        let workers = g.usize(2, 4);
        let par = run_policy(&SimConfig { workers, ..c.clone() }, &jobs, use_ada, cap);
        check_equivalent(&par, &serial)?;
        if par.n_events != serial.n_events {
            return Err(format!(
                "n_events diverged under workers={workers}: {} vs {}",
                par.n_events, serial.n_events
            ));
        }
        logs_eq("parallel-vs-serial log", &par.events, &serial.events)
    });
}

#[test]
fn ff_mid_macro_arrival_is_serial_barrier_then_parallel_batch() {
    // Two steady jobs fast-forward on separate GPUs; a third arrives
    // mid-macro. The arrival acts as a serial barrier by construction —
    // both walk inputs are frozen at the arrival's timestamp before any
    // walk starts — and under workers = 2 the two dissolutions run as
    // exactly one parallel reconcile batch, bit-identical to serial.
    let c = cfg(1, 3);
    let j0 = job(0, 0.0, DnnModel::ResNet50, 1, 400);
    let j1 = job(1, 0.0, DnnModel::ResNet50, 1, 300);
    let t_iter = j0.t_iter(c.cluster.gpu_peak_gflops);
    let j2 = job(2, 13.5 * t_iter, DnnModel::ResNet50, 1, 50);
    let jobs = [j0, j1, j2];
    let base = super::engine::FF_PAR_BATCHES.with(|x| x.get());
    let serial = run(&c, &jobs);
    assert_eq!(
        super::engine::FF_PAR_BATCHES.with(|x| x.get()),
        base,
        "the serial engine must never run a parallel batch"
    );
    let par = run(&SimConfig { workers: 2, ..c.clone() }, &jobs);
    let batches = super::engine::FF_PAR_BATCHES.with(|x| x.get()) - base;
    assert!(batches >= 1, "mid-macro arrival did not trigger a parallel reconcile batch");
    check_equivalent(&par, &serial).unwrap();
    assert_eq!(par.n_events, serial.n_events, "worker fan-out changed the event count");
}

#[test]
fn heap_capacity_hint_clamps_sanely() {
    use super::engine::heap_capacity_hint;
    // Known horizon: 4 events per job, clamped to [64, 1<<20].
    assert_eq!(heap_capacity_hint(Some(0)), 64);
    assert_eq!(heap_capacity_hint(Some(10)), 64);
    assert_eq!(heap_capacity_hint(Some(100)), 400);
    assert_eq!(heap_capacity_hint(Some(usize::MAX)), 1 << 20);
    // Unknown horizon (streaming source without a hint): fixed default.
    assert_eq!(heap_capacity_hint(None), 1024);
}

// ---------------------------------------------------------------------------
// fault injection: deterministic failure timelines, checkpoint/restart
// recovery, health-gated placement and the chaos invariants the engine must
// hold under any schedule of failures (docs/EXPERIMENTS.md §Faults).

use crate::fault::{FaultEvent, FaultKind, FaultPlan, FaultsSpec};

/// Tracks hardware health from the typed fault events and records every
/// invariant violation: placements landing on dead GPUs, unbalanced
/// fail/recover transitions, or fault-lifecycle events running backwards
/// in time. Fault events are popped straight off the heap (never
/// synthesised retroactively like coalesced compute/comm events), so
/// their timestamps must be monotone even with coalescing on.
struct ChaosWatch {
    gpu_up: Vec<bool>,
    link_up: Vec<bool>,
    gpu_factor: Vec<f64>,
    link_factor: Vec<f64>,
    blacklisted: Vec<bool>,
    job_gpus: Vec<Vec<usize>>,
    last_fault_t: f64,
    preemptions: u64,
    restarts: u64,
    bad: Vec<String>,
}

impl ChaosWatch {
    fn new(n_gpus: usize, n_links: usize) -> ChaosWatch {
        ChaosWatch {
            gpu_up: vec![true; n_gpus],
            link_up: vec![true; n_links],
            gpu_factor: vec![1.0; n_gpus],
            link_factor: vec![1.0; n_links],
            blacklisted: vec![false; n_gpus],
            job_gpus: Vec::new(),
            last_fault_t: f64::NEG_INFINITY,
            preemptions: 0,
            restarts: 0,
            bad: Vec::new(),
        }
    }

    fn fault_tick(&mut self, t: f64, what: &str) {
        if t < self.last_fault_t {
            self.bad.push(format!("{what} at t={t} ran before t={}", self.last_fault_t));
        }
        self.last_fault_t = t;
    }

    /// End-of-run checks for a paired timeline (every failure recovers,
    /// every degradation restores): all hardware back up at full health,
    /// and fail/recover + degrade/restore transitions balanced exactly.
    /// Blacklists are deliberately NOT required to have drained: an
    /// expiry past the last finish never pops off the heap.
    fn into_verdict(self) -> Result<(), String> {
        let mut bad = self.bad;
        if let Some(g) = self.gpu_up.iter().position(|&up| !up) {
            bad.push(format!("gpu {g} still down after a paired timeline"));
        }
        if let Some(l) = self.link_up.iter().position(|&up| !up) {
            bad.push(format!("link {l} still down after a paired timeline"));
        }
        if let Some(g) = self.gpu_factor.iter().position(|&f| f != 1.0) {
            bad.push(format!("gpu {g} still degraded after a paired timeline"));
        }
        if let Some(l) = self.link_factor.iter().position(|&f| f != 1.0) {
            bad.push(format!("link {l} still degraded after a paired timeline"));
        }
        if bad.is_empty() {
            Ok(())
        } else {
            Err(bad.join("\n"))
        }
    }
}

impl SimObserver for ChaosWatch {
    fn on_event(&mut self, ev: &SimEvent<'_>) {
        match *ev {
            SimEvent::JobPlaced { t, job, gpus, .. } => {
                for &g in gpus {
                    if !self.gpu_up[g] {
                        self.bad.push(format!("job {job} placed on dead gpu {g} at t={t}"));
                    }
                    if self.blacklisted[g] {
                        self.bad.push(format!(
                            "job {job} placed on blacklisted gpu {g} at t={t}"
                        ));
                    }
                }
                if self.job_gpus.len() <= job {
                    self.job_gpus.resize(job + 1, Vec::new());
                }
                self.job_gpus[job] = gpus.to_vec();
            }
            SimEvent::JobFinished { job, .. } | SimEvent::JobPreempted { job, .. } => {
                if let SimEvent::JobPreempted { t, .. } = *ev {
                    self.preemptions += 1;
                    self.fault_tick(t, "preempt");
                    // A preemption must only follow a failure that touched
                    // the job: a dead GPU under it or a dead link. The
                    // cheap necessary condition: some hardware is down.
                    if self.gpu_up.iter().all(|&u| u) && self.link_up.iter().all(|&u| u) {
                        self.bad.push(format!("job {job} preempted with all hardware up"));
                    }
                }
                if let Some(gpus) = self.job_gpus.get_mut(job) {
                    gpus.clear();
                }
            }
            SimEvent::JobRestarted { t, .. } => {
                self.restarts += 1;
                self.fault_tick(t, "restart");
            }
            SimEvent::CheckpointTaken { t, .. } => self.fault_tick(t, "checkpoint"),
            SimEvent::GpuFailed { t, gpu } => {
                self.fault_tick(t, "gpu-fail");
                if !self.gpu_up[gpu] {
                    self.bad.push(format!("gpu {gpu} failed twice without recovery"));
                }
                self.gpu_up[gpu] = false;
            }
            SimEvent::GpuRecovered { t, gpu } => {
                self.fault_tick(t, "gpu-recover");
                if self.gpu_up[gpu] {
                    self.bad.push(format!("gpu {gpu} recovered while up"));
                }
                self.gpu_up[gpu] = true;
            }
            SimEvent::LinkFailed { t, link } => {
                self.fault_tick(t, "link-fail");
                if !self.link_up[link] {
                    self.bad.push(format!("link {link} failed twice without recovery"));
                }
                self.link_up[link] = false;
            }
            SimEvent::LinkRecovered { t, link } => {
                self.fault_tick(t, "link-recover");
                if self.link_up[link] {
                    self.bad.push(format!("link {link} recovered while up"));
                }
                self.link_up[link] = true;
            }
            SimEvent::GpuSlowed { t, gpu, factor } => {
                self.fault_tick(t, "gpu-slow");
                if !(factor > 0.0 && factor < 1.0) {
                    self.bad.push(format!("gpu {gpu} slowed by factor {factor} outside (0,1)"));
                }
                if !self.gpu_up[gpu] {
                    self.bad.push(format!("gpu {gpu} slowed while hard-down"));
                }
                self.gpu_factor[gpu] = factor;
            }
            SimEvent::GpuRestored { t, gpu } => {
                self.fault_tick(t, "gpu-restore");
                if self.gpu_factor[gpu] >= 1.0 {
                    self.bad.push(format!("gpu {gpu} restored while already healthy"));
                }
                self.gpu_factor[gpu] = 1.0;
            }
            SimEvent::LinkDegraded { t, link, factor } => {
                self.fault_tick(t, "link-degrade");
                if !(factor > 0.0 && factor < 1.0) {
                    self.bad.push(format!(
                        "link {link} degraded by factor {factor} outside (0,1)"
                    ));
                }
                if !self.link_up[link] {
                    self.bad.push(format!("link {link} degraded while hard-down"));
                }
                self.link_factor[link] = factor;
            }
            SimEvent::LinkRestored { t, link } => {
                self.fault_tick(t, "link-restore");
                if self.link_factor[link] >= 1.0 {
                    self.bad.push(format!("link {link} restored while already healthy"));
                }
                self.link_factor[link] = 1.0;
            }
            SimEvent::GpuBlacklisted { t, gpu, until } => {
                self.fault_tick(t, "blacklist");
                if until <= t {
                    self.bad.push(format!("gpu {gpu} blacklisted until {until} <= t={t}"));
                }
                self.blacklisted[gpu] = true;
            }
            SimEvent::GpuUnblacklisted { t, gpu } => {
                self.fault_tick(t, "unblacklist");
                if !self.blacklisted[gpu] {
                    self.bad.push(format!("gpu {gpu} unblacklisted while not blacklisted"));
                }
                self.blacklisted[gpu] = false;
            }
            SimEvent::RestartDeferred { t, job, until } => {
                self.fault_tick(t, "backoff");
                if until <= t {
                    self.bad.push(format!("job {job} backoff until {until} <= t={t}"));
                }
            }
            _ => {}
        }
    }
}

/// Random paired failure/recovery timeline: 1–3 fail/recover pairs over
/// the cluster's GPUs and links, every failure recovering by t = 70 so
/// the workload can always drain afterwards. Duplicate targets are fine:
/// the engine is idempotent and the emitted transitions stay alternating.
fn random_fault_spec(
    g: &mut crate::util::prop::Gen,
    n_gpus: usize,
    n_links: usize,
) -> FaultsSpec {
    let mut events = Vec::new();
    for _ in 0..g.usize(1, 3) {
        let t_fail = g.f64(0.0, 40.0);
        let t_rec = t_fail + g.f64(1.0, 30.0);
        if g.bool() {
            let gpu = g.usize(0, n_gpus - 1);
            events.push(FaultEvent { t: t_fail, kind: FaultKind::GpuFail(gpu) });
            events.push(FaultEvent { t: t_rec, kind: FaultKind::GpuRecover(gpu) });
        } else {
            let link = g.usize(0, n_links - 1);
            events.push(FaultEvent { t: t_fail, kind: FaultKind::LinkFail(link) });
            events.push(FaultEvent { t: t_rec, kind: FaultKind::LinkRecover(link) });
        }
    }
    FaultsSpec {
        checkpoint_iters: g.u64(0, 25),
        warmup_s: g.f64(0.0, 1.0),
        events,
        ..FaultsSpec::default()
    }
}

/// Gray-failure extension of [`random_fault_spec`]: adds 1–3 paired
/// degradation/restore transitions on devices the hard-fault timeline
/// leaves alone (a restore landing while its target is hard-down is
/// skipped by the engine, which would unbalance the pairing the watcher
/// checks), plus random restart-backoff and blacklist knobs.
fn random_gray_spec(
    g: &mut crate::util::prop::Gen,
    n_gpus: usize,
    n_links: usize,
) -> FaultsSpec {
    let mut spec = random_fault_spec(g, n_gpus, n_links);
    let mut used_gpus = vec![false; n_gpus];
    let mut used_links = vec![false; n_links];
    for e in &spec.events {
        match e.kind {
            FaultKind::GpuFail(x) | FaultKind::GpuRecover(x) => used_gpus[x] = true,
            FaultKind::LinkFail(x) | FaultKind::LinkRecover(x) => used_links[x] = true,
            _ => {}
        }
    }
    for _ in 0..g.usize(1, 3) {
        let t_on = g.f64(0.0, 40.0);
        let t_off = t_on + g.f64(1.0, 30.0);
        let f = g.f64(0.2, 0.9);
        if g.bool() {
            let gpu = g.usize(0, n_gpus - 1);
            if used_gpus[gpu] {
                continue;
            }
            used_gpus[gpu] = true;
            spec.events.push(FaultEvent { t: t_on, kind: FaultKind::GpuSlow(gpu, f) });
            spec.events.push(FaultEvent { t: t_off, kind: FaultKind::GpuRestore(gpu) });
        } else {
            let link = g.usize(0, n_links - 1);
            if used_links[link] {
                continue;
            }
            used_links[link] = true;
            spec.events.push(FaultEvent { t: t_on, kind: FaultKind::LinkDegrade(link, f) });
            spec.events.push(FaultEvent { t: t_off, kind: FaultKind::LinkRestore(link) });
        }
    }
    if g.bool() {
        spec.backoff_base_s = g.f64(0.5, 5.0);
    }
    if g.bool() {
        spec.blacklist_k = g.u64(1, 2);
        spec.blacklist_window_s = g.f64(5.0, 50.0);
    }
    spec
}

#[test]
fn prop_chaos_fault_invariants() {
    // Random fault schedules × {flat, two-tier} × {srsf, fifo, las} ×
    // both policy families × coalescing on/off: no placement on dead
    // hardware, alternating fail/recover transitions that balance out,
    // monotone fault-lifecycle time, and every job finishes once the
    // hardware comes back.
    prop_check(30, |g| {
        let n_servers = g.usize(2, 4);
        let gps = g.usize(1, 3);
        let mut c = cfg(n_servers, gps);
        c.priority = *g.pick(&JobPriority::all());
        c.coalescing = g.bool();
        if g.bool() {
            c.topology = TopologySpec::TwoTier { rack_size: 2, oversubscription: 4.0 };
        }
        let n_links = c.topology.n_links(&c.cluster);
        let spec = random_fault_spec(g, c.cluster.n_gpus(), n_links);
        c.faults =
            spec.compile(&c.cluster, n_links, c.cluster.n_gpus() as u64).map_err(|e| e.to_string())?;
        let total = c.cluster.n_gpus();
        let models = crate::model::ALL_MODELS;
        let jobs: Vec<JobSpec> = (0..g.usize(1, 6))
            .map(|i| JobSpec {
                id: i,
                arrival: g.f64(0.0, 30.0),
                model: *g.pick(&models),
                n_gpus: g.usize(1, total),
                iterations: g.u64(1, 80),
            })
            .collect();
        let use_ada = g.bool();
        let cap = g.usize(1, 3);
        let mut watch = ChaosWatch::new(c.cluster.n_gpus(), n_links);
        let mut metrics = MetricsObserver::new();
        {
            let mut obs: [&mut dyn SimObserver; 2] = [&mut metrics, &mut watch];
            let mut p = LwfPlacer::new(1);
            if use_ada {
                simulate_observed(&c, &jobs, &mut p, &AdaDual { model: c.comm }, &mut obs);
            } else {
                simulate_observed(&c, &jobs, &mut p, &SrsfCap { cap }, &mut obs);
            }
        }
        let res = metrics.into_result();
        for (i, t) in res.jct.iter().enumerate() {
            if !t.is_finite() {
                return Err(format!("job {i} never finished after recovery"));
            }
            let lb = jobs[i].compute_total(c.cluster.gpu_peak_gflops);
            if res.jct[i] < lb - 1e-6 {
                return Err(format!("job {i} jct {t} beat its compute lower bound {lb}"));
            }
        }
        watch.into_verdict()
    });
}

#[test]
fn prop_chaos_gray_failure_invariants() {
    // Hard faults + gray degradations + backoff + blacklisting, under
    // both the LWF baseline and the health-aware placer: factors stay in
    // (0,1), degrade/restore transitions pair up, nothing is ever placed
    // on a dead or blacklisted GPU, backoff deferrals point forward in
    // time, and every job still finishes.
    prop_check(30, |g| {
        let n_servers = g.usize(2, 4);
        let gps = g.usize(1, 3);
        let mut c = cfg(n_servers, gps);
        c.priority = *g.pick(&JobPriority::all());
        c.coalescing = g.bool();
        c.repricing = if g.bool() { Repricing::Dynamic } else { Repricing::AtAdmission };
        if g.bool() {
            c.topology = TopologySpec::TwoTier { rack_size: 2, oversubscription: 4.0 };
        }
        let n_links = c.topology.n_links(&c.cluster);
        let spec = random_gray_spec(g, c.cluster.n_gpus(), n_links);
        c.faults =
            spec.compile(&c.cluster, n_links, 7).map_err(|e| e.to_string())?;
        let total = c.cluster.n_gpus();
        let models = crate::model::ALL_MODELS;
        let jobs: Vec<JobSpec> = (0..g.usize(1, 6))
            .map(|i| JobSpec {
                id: i,
                arrival: g.f64(0.0, 30.0),
                model: *g.pick(&models),
                n_gpus: g.usize(1, total),
                iterations: g.u64(1, 80),
            })
            .collect();
        let use_health = g.bool();
        let mut watch = ChaosWatch::new(c.cluster.n_gpus(), n_links);
        let mut metrics = MetricsObserver::new();
        {
            let mut obs: [&mut dyn SimObserver; 2] = [&mut metrics, &mut watch];
            let policy = AdaDual { model: c.comm };
            if use_health {
                let mut p = HealthAwarePlacer::new();
                simulate_observed(&c, &jobs, &mut p, &policy, &mut obs);
            } else {
                let mut p = LwfPlacer::new(1);
                simulate_observed(&c, &jobs, &mut p, &policy, &mut obs);
            }
        }
        let res = metrics.into_result();
        for (i, t) in res.jct.iter().enumerate() {
            if !t.is_finite() {
                return Err(format!("job {i} never finished under gray failures"));
            }
            // A slowed GPU only ever stretches compute, so the healthy
            // compute bound still holds from below.
            let lb = jobs[i].compute_total(c.cluster.gpu_peak_gflops);
            if res.jct[i] < lb - 1e-6 {
                return Err(format!("job {i} jct {t} beat its compute lower bound {lb}"));
            }
        }
        if res.restarted > res.preempted {
            return Err(format!(
                "{} restarts exceed {} preemptions",
                res.restarted, res.preempted
            ));
        }
        watch.into_verdict()
    });
}

#[test]
fn prop_legacy_log_matches_jsonl_fault_lines() {
    // The human-readable LegacyLog and the typed JSONL sink must tell the
    // same fault story: every fault-lifecycle JSONL row maps 1:1, in
    // order and value-for-value, onto a legacy log line — under random
    // hard-fault + degradation + backoff/blacklist schedules.
    prop_check(20, |g| {
        let n_servers = g.usize(2, 4);
        let gps = g.usize(1, 3);
        let mut c = cfg(n_servers, gps);
        c.coalescing = g.bool();
        c.priority = *g.pick(&JobPriority::all());
        let n_links = c.topology.n_links(&c.cluster);
        let spec = random_gray_spec(g, c.cluster.n_gpus(), n_links);
        c.faults = spec.compile(&c.cluster, n_links, 7).map_err(|e| e.to_string())?;
        let total = c.cluster.n_gpus();
        let models = crate::model::ALL_MODELS;
        let jobs: Vec<JobSpec> = (0..g.usize(1, 5))
            .map(|i| JobSpec {
                id: i,
                arrival: g.f64(0.0, 30.0),
                model: *g.pick(&models),
                n_gpus: g.usize(1, total),
                iterations: g.u64(1, 60),
            })
            .collect();
        let mut legacy = LegacyLog::new();
        let mut sink = JsonlSink::new(Vec::new());
        {
            let mut obs: [&mut dyn SimObserver; 2] = [&mut legacy, &mut sink];
            let mut p = LwfPlacer::new(1);
            simulate_observed(&c, &jobs, &mut p, &AdaDual { model: c.comm }, &mut obs);
        }
        let buf = sink.finish().map_err(|e| e.to_string())?;
        let text = String::from_utf8(buf).map_err(|e| e.to_string())?;
        // Rebuild the legacy fault lines from the typed rows.
        let mut rebuilt: Vec<EventLog> = Vec::new();
        for line in text.lines() {
            let v = crate::util::json::Json::parse(line).map_err(|e| format!("{e:?}"))?;
            let t = v.get("t").and_then(|x| x.as_f64()).ok_or("row missing t")?;
            let kind = v.get("ev").and_then(|x| x.as_str()).ok_or("row missing ev")?;
            let us = |k: &str| {
                v.get(k).and_then(|x| x.as_usize()).ok_or(format!("row missing {k}"))
            };
            let u64s = |k: &str| {
                v.get(k).and_then(|x| x.as_u64()).ok_or(format!("row missing {k}"))
            };
            let f64s = |k: &str| {
                v.get(k).and_then(|x| x.as_f64()).ok_or(format!("row missing {k}"))
            };
            let what = match kind {
                "gpu-failed" => format!("gpu-fail gpu{}", us("gpu")?),
                "gpu-recovered" => format!("gpu-recover gpu{}", us("gpu")?),
                "link-failed" => format!("link-fail link{}", us("link")?),
                "link-recovered" => format!("link-recover link{}", us("link")?),
                "job-preempted" => {
                    format!("preempt job{} lost={}", us("job")?, u64s("lost_iters")?)
                }
                "job-restarted" => {
                    format!("restart job{} n={}", us("job")?, u64s("restarts")?)
                }
                "checkpoint-taken" => {
                    format!("checkpoint job{} iters={}", us("job")?, u64s("iters")?)
                }
                "gpu-slowed" => {
                    format!("gpu-slow gpu{} factor={}", us("gpu")?, f64s("factor")?)
                }
                "gpu-restored" => format!("gpu-restore gpu{}", us("gpu")?),
                "link-degraded" => {
                    format!("link-degrade link{} factor={}", us("link")?, f64s("factor")?)
                }
                "link-restored" => format!("link-restore link{}", us("link")?),
                "gpu-blacklisted" => {
                    format!("blacklist gpu{} until={}", us("gpu")?, f64s("until")?)
                }
                "gpu-unblacklisted" => format!("unblacklist gpu{}", us("gpu")?),
                "restart-deferred" => {
                    format!("backoff job{} until={}", us("job")?, f64s("until")?)
                }
                _ => continue,
            };
            rebuilt.push(EventLog { t, what });
        }
        rebuilt.sort_by(|a, b| a.t.total_cmp(&b.t));
        // The same stable t-sort LegacyLog applies, filtered to the fault
        // lines (filter-then-sort == sort-then-filter for a stable sort).
        let prefixes = [
            "gpu-", "link-", "preempt ", "restart ", "checkpoint ", "blacklist ",
            "unblacklist ", "backoff ",
        ];
        let legacy_lines: Vec<EventLog> = legacy
            .into_events()
            .into_iter()
            .filter(|e| prefixes.iter().any(|p| e.what.starts_with(p)))
            .collect();
        logs_eq("legacy vs jsonl fault lines", &legacy_lines, &rebuilt)
    });
}

#[test]
fn prop_zero_degradation_knobs_bit_invisible() {
    // The tentpole's bit-identity contract: a degradation generator that
    // draws nothing (zero horizon) plus backoff/blacklist knobs at their
    // off-defaults must leave a hard-faulted run bit-identical — metrics,
    // event count and legacy log alike. The unused cap/window values are
    // deliberately non-default to prove they are never even read.
    prop_check(15, |g| {
        let n_servers = g.usize(2, 4);
        let gps = g.usize(1, 3);
        let mut c = cfg(n_servers, gps);
        c.log_events = true;
        c.coalescing = g.bool();
        let n_links = c.topology.n_links(&c.cluster);
        let spec = random_fault_spec(g, c.cluster.n_gpus(), n_links);
        let mut gray = spec.clone();
        gray.degraded = Some(crate::fault::DegradeSpec {
            horizon_s: 0.0,
            ..crate::fault::DegradeSpec::with_mtbd(50.0)
        });
        gray.backoff_cap_s = 123.0;
        gray.blacklist_window_s = 77.0;
        let mut plain_cfg = c.clone();
        plain_cfg.faults = spec.compile(&c.cluster, n_links, 7).map_err(|e| e.to_string())?;
        let mut gray_cfg = c.clone();
        gray_cfg.faults = gray.compile(&c.cluster, n_links, 7).map_err(|e| e.to_string())?;
        let total = c.cluster.n_gpus();
        let models = crate::model::ALL_MODELS;
        let jobs: Vec<JobSpec> = (0..g.usize(1, 5))
            .map(|i| JobSpec {
                id: i,
                arrival: g.f64(0.0, 30.0),
                model: *g.pick(&models),
                n_gpus: g.usize(1, total),
                iterations: g.u64(1, 60),
            })
            .collect();
        let a = run(&plain_cfg, &jobs);
        let b = run(&gray_cfg, &jobs);
        check_equivalent(&a, &b)?;
        if a.n_events != b.n_events {
            return Err(format!("n_events diverged: {} vs {}", a.n_events, b.n_events));
        }
        logs_eq("zero-degradation gray knobs", &a.events, &b.events)
    });
}

#[test]
fn health_placer_beats_lwf_under_severe_degradation() {
    // Server 0's GPUs are crippled (factor 0.05) before any job arrives.
    // LWF-1's consolidation tie-break picks server 0 on an empty cluster
    // (equal loads, lowest ids win) and eats the 20x compute stretch; the
    // health-aware placer reads the HealthView and routes the job to
    // server 1 at full speed.
    let mut c = cfg(2, 2);
    let spec = FaultsSpec {
        events: vec![
            FaultEvent { t: 0.0, kind: FaultKind::GpuSlow(0, 0.05) },
            FaultEvent { t: 0.0, kind: FaultKind::GpuSlow(1, 0.05) },
        ],
        ..FaultsSpec::default()
    };
    c.faults = spec.compile(&c.cluster, c.topology.n_links(&c.cluster), 7).unwrap();
    let jobs = [job(0, 1.0, DnnModel::ResNet50, 2, 30)];
    let policy = AdaDual { model: c.comm };
    let mut lwf_placer = LwfPlacer::new(1);
    let lwf = simulate(&c, &jobs, &mut lwf_placer, &policy);
    let mut health_placer = HealthAwarePlacer::new();
    let health = simulate(&c, &jobs, &mut health_placer, &policy);
    assert!(lwf.jct[0].is_finite() && health.jct[0].is_finite());
    assert!(
        health.jct[0] * 4.0 < lwf.jct[0],
        "health-aware placer did not dodge the slowed server: health {} vs lwf {}",
        health.jct[0],
        lwf.jct[0]
    );
}

#[test]
fn prop_coalescing_equivalent_under_faults() {
    // The fast-forward engine must stay a pure event-count optimisation
    // when the timeline dissolves its macro-events mid-flight: every
    // metric bit-identical to the event-exact engine under random faults.
    prop_check(20, |g| {
        let n_servers = g.usize(2, 4);
        let gps = g.usize(1, 3);
        let mut c = cfg(n_servers, gps);
        c.log_events = true;
        c.priority = *g.pick(&JobPriority::all());
        c.repricing = if g.bool() { Repricing::Dynamic } else { Repricing::AtAdmission };
        if g.bool() {
            c.topology = TopologySpec::TwoTier { rack_size: 2, oversubscription: 4.0 };
        }
        let n_links = c.topology.n_links(&c.cluster);
        let spec = random_fault_spec(g, c.cluster.n_gpus(), n_links);
        c.faults = spec.compile(&c.cluster, n_links, 7).map_err(|e| e.to_string())?;
        let total = c.cluster.n_gpus();
        let models = crate::model::ALL_MODELS;
        let jobs: Vec<JobSpec> = (0..g.usize(1, 5))
            .map(|i| JobSpec {
                id: i,
                arrival: g.f64(0.0, 30.0),
                model: *g.pick(&models),
                n_gpus: g.usize(1, total),
                iterations: g.u64(1, 100),
            })
            .collect();
        let use_ada = g.bool();
        let cap = g.usize(1, 3);
        let on = run_policy(&SimConfig { coalescing: true, ..c.clone() }, &jobs, use_ada, cap);
        let off = run_policy(&SimConfig { coalescing: false, ..c.clone() }, &jobs, use_ada, cap);
        check_equivalent(&on, &off)?;
        let canon = |events: &[EventLog]| -> Vec<EventLog> {
            let mut v = events.to_vec();
            v.sort_by(|a, b| a.t.total_cmp(&b.t).then_with(|| a.what.cmp(&b.what)));
            v
        };
        logs_eq("faulted coalescing on vs off", &canon(&on.events), &canon(&off.events))
    });
}

#[test]
fn trailing_faults_after_makespan_are_bit_invisible() {
    // Faults strictly after the last finish never pop off the heap: the
    // run must be byte-identical to the zero-fault run — metrics, event
    // count and legacy log alike. This is the boundary case of the
    // empty-plan bit-identity contract.
    let mut c = cfg(2, 2);
    c.log_events = true;
    let jobs = [
        job(0, 0.0, DnnModel::Vgg16, 4, 30),
        job(1, 2.0, DnnModel::ResNet50, 2, 40),
    ];
    let clean = run(&c, &jobs);
    assert!(clean.makespan > 0.0);
    let spec = FaultsSpec {
        events: vec![
            FaultEvent { t: clean.makespan + 10.0, kind: FaultKind::GpuFail(0) },
            FaultEvent { t: clean.makespan + 20.0, kind: FaultKind::GpuRecover(0) },
        ],
        ..FaultsSpec::default()
    };
    let mut faulted_cfg = c.clone();
    faulted_cfg.faults =
        spec.compile(&c.cluster, c.topology.n_links(&c.cluster), c.cluster.n_gpus() as u64).unwrap();
    let faulted = run(&faulted_cfg, &jobs);
    check_equivalent(&faulted, &clean).unwrap();
    assert_eq!(faulted.n_events, clean.n_events, "trailing faults changed the event count");
    logs_eq("trailing faults vs clean", &faulted.events, &clean.events).unwrap();
}

#[test]
fn gpu_failure_preempts_and_checkpoint_limits_lost_work() {
    // One job, one GPU, a mid-run failure: the job is preempted, the GPU
    // recovers, the job restarts from its checkpoint and still finishes.
    // A tighter checkpoint interval loses fewer iterations and can only
    // finish earlier (or at the same instant).
    let c = cfg(1, 1);
    let j = job(0, 0.0, DnnModel::ResNet50, 1, 200);
    let clean = run(&c, &[j.clone()]);
    let t_fail = clean.makespan * 0.5;
    let t_rec = clean.makespan * 0.75;
    let run_ckpt = |ckpt: u64| {
        let spec = FaultsSpec {
            checkpoint_iters: ckpt,
            events: vec![
                FaultEvent { t: t_fail, kind: FaultKind::GpuFail(0) },
                FaultEvent { t: t_rec, kind: FaultKind::GpuRecover(0) },
            ],
            ..FaultsSpec::default()
        };
        let mut cc = c.clone();
        cc.log_events = true;
        cc.faults =
            spec.compile(&cc.cluster, cc.topology.n_links(&cc.cluster), 1).unwrap();
        run(&cc, &[j.clone()])
    };
    let scratch = run_ckpt(0); // checkpoint disabled: restart from zero
    let tight = run_ckpt(10);
    for r in [&scratch, &tight] {
        assert!(r.jct[0].is_finite(), "job never finished after recovery");
        assert!(r.finish[0] > t_rec, "finish {} before recovery {t_rec}", r.finish[0]);
        assert!(r.finish[0] > clean.finish[0], "failure cost nothing");
        let text: Vec<&str> = r.events.iter().map(|e| e.what.as_str()).collect();
        assert!(text.iter().any(|s| s.starts_with("gpu-fail gpu0")), "{text:?}");
        assert!(text.iter().any(|s| s.starts_with("preempt job0")), "{text:?}");
        assert!(text.iter().any(|s| s.starts_with("checkpoint job0")), "{text:?}");
        assert!(text.iter().any(|s| s.starts_with("gpu-recover gpu0")), "{text:?}");
        assert!(text.iter().any(|s| s.starts_with("restart job0")), "{text:?}");
    }
    assert!(
        tight.finish[0] <= scratch.finish[0] + 1e-9,
        "checkpointing lost more work than restarting from scratch: {} vs {}",
        tight.finish[0],
        scratch.finish[0]
    );
}

#[test]
fn link_failure_freezes_comm_until_recovery() {
    // A 2-server job All-Reduces across server NICs; killing one NIC
    // mid-run freezes its transfers (no progress while down) but does not
    // preempt the job. It finishes after the link recovers, strictly
    // later than the healthy run.
    let c = cfg(2, 1);
    let j = job(0, 0.0, DnnModel::Vgg16, 2, 50);
    let clean = run(&c, &[j.clone()]);
    let t_fail = clean.makespan * 0.4;
    let down_for = clean.makespan * 0.5;
    let spec = FaultsSpec {
        events: vec![
            FaultEvent { t: t_fail, kind: FaultKind::LinkFail(0) },
            FaultEvent { t: t_fail + down_for, kind: FaultKind::LinkRecover(0) },
        ],
        ..FaultsSpec::default()
    };
    let mut cc = c.clone();
    cc.log_events = true;
    cc.faults = spec.compile(&cc.cluster, cc.topology.n_links(&cc.cluster), 1).unwrap();
    let faulted = run(&cc, &[j.clone()]);
    assert!(faulted.jct[0].is_finite());
    assert!(
        faulted.finish[0] > clean.finish[0] + down_for * 0.5,
        "link outage barely cost anything: {} vs clean {}",
        faulted.finish[0],
        clean.finish[0]
    );
    let text: Vec<&str> = faulted.events.iter().map(|e| e.what.as_str()).collect();
    assert!(text.iter().any(|s| s.starts_with("link-fail link0")), "{text:?}");
    assert!(text.iter().any(|s| s.starts_with("link-recover link0")), "{text:?}");
    // No preemption: link outages stall communication, they don't kill
    // placements.
    assert!(!text.iter().any(|s| s.starts_with("preempt")), "{text:?}");
}

#[test]
fn mtbf_generator_is_deterministic_and_gated_by_seed() {
    // The MTBF/MTTR-generated timeline is a pure function of the seed:
    // byte-identical across compiles, different under a different seed.
    let cluster = ClusterSpec::tiny(2, 2);
    let spec = FaultsSpec {
        gen: Some(crate::fault::GenSpec::with_mtbf(120.0)),
        ..FaultsSpec::default()
    };
    let a = spec.compile(&cluster, 2, 9).unwrap();
    let b = spec.compile(&cluster, 2, 9).unwrap();
    assert_eq!(a.events.len(), b.events.len());
    for (x, y) in a.events.iter().zip(&b.events) {
        assert_eq!(x.0.to_bits(), y.0.to_bits());
        assert_eq!(x.1, y.1);
    }
    assert!(!a.is_empty(), "a 120s-MTBF generator over a 1200s horizon produced nothing");
    let other = spec.compile(&cluster, 2, 10).unwrap();
    let same = a.events.len() == other.events.len()
        && a.events.iter().zip(&other.events).all(|(x, y)| x.0.to_bits() == y.0.to_bits());
    assert!(!same, "fault timeline ignored the seed");
}

#[test]
fn mtbf_generated_run_completes_all_jobs() {
    // End-to-end: a generated timeline over a small cluster still lets
    // every job finish (each failure recovers after MTTR), and the run is
    // deterministic — two simulations agree bit-for-bit.
    let mut c = cfg(2, 2);
    let spec = FaultsSpec {
        checkpoint_iters: 20,
        warmup_s: 0.5,
        gen: Some(crate::fault::GenSpec {
            mtbf_s: 60.0,
            mttr_s: 10.0,
            horizon_s: 300.0,
            targets: crate::fault::FaultTargets::Both,
            seed: None,
        }),
        ..FaultsSpec::default()
    };
    c.faults = spec.compile(&c.cluster, c.topology.n_links(&c.cluster), 5).unwrap();
    let jobs = [
        job(0, 0.0, DnnModel::ResNet50, 2, 60),
        job(1, 5.0, DnnModel::Vgg16, 4, 40),
        job(2, 12.0, DnnModel::LstmPtb, 1, 80),
    ];
    let r1 = run(&c, &jobs);
    let r2 = run(&c, &jobs);
    assert!(r1.jct.iter().all(|t| t.is_finite()), "job lost to the generated timeline");
    check_equivalent(&r1, &r2).unwrap();
    assert_eq!(r1.n_events, r2.n_events);
}

#[test]
fn prop_env_builtin_agent_bit_identical_to_engine() {
    // The gym-style env driven by a BuiltinAgent (the engine's own placer
    // + policy re-wrapped as an agent) against the monolithic facade:
    // every SimResult field and every log line must match bit-for-bit,
    // across random traces x topologies x priorities x repricings x
    // coalescing on/off x with/without faults.
    prop_check(20, |g| {
        let (mut c, jobs, use_ada, cap) = random_setup(g);
        c.coalescing = g.bool();
        if g.bool() {
            let n_links = c.topology.n_links(&c.cluster);
            let spec = random_fault_spec(g, c.cluster.n_gpus(), n_links);
            c.faults = spec.compile(&c.cluster, n_links, 11).map_err(|e| e.to_string())?;
        }
        let facade = run_policy(&c, &jobs, use_ada, cap);
        let mut metrics = MetricsObserver::new();
        let mut log = LegacyLog::new();
        let steps = {
            let mut obs: [&mut dyn SimObserver; 2] = [&mut metrics, &mut log];
            let policy: Box<dyn CommPolicy> = if use_ada {
                Box::new(AdaDual { model: c.comm })
            } else {
                Box::new(SrsfCap { cap })
            };
            let mut agent = crate::env::BuiltinAgent::new(Box::new(LwfPlacer::new(1)), policy);
            let mut env = crate::env::SimEnv::new(&c, &jobs);
            env.run_agent(&mut agent, None, &mut obs).map_err(|e| e.to_string())?
        };
        if steps == 0 {
            return Err("env resolved zero decisions over a non-empty trace".to_string());
        }
        let mut manual = metrics.into_result();
        manual.events = log.into_events();
        check_equivalent(&facade, &manual)?;
        if facade.n_events != manual.n_events {
            return Err(format!(
                "n_events diverged: {} vs {}",
                facade.n_events, manual.n_events
            ));
        }
        logs_eq("env-driven vs facade", &facade.events, &manual.events)
    });
}

#[test]
fn prop_env_save_restore_resumes_bit_identically() {
    // Checkpoint an episode at a random decision index (env snapshot +
    // the RandomAgent's PcgState), drive the original to the end, then
    // rewind a second env over the same workload and replay: step count,
    // episode return, final clock, event count and finish tallies must
    // all agree bit-for-bit — same random grid as the bit-identity test,
    // faults included.
    prop_check(15, |g| {
        let (mut c, jobs, _use_ada, _cap) = random_setup(g);
        c.coalescing = g.bool();
        if g.bool() {
            let n_links = c.topology.n_links(&c.cluster);
            let spec = random_fault_spec(g, c.cluster.n_gpus(), n_links);
            c.faults = spec.compile(&c.cluster, n_links, 13).map_err(|e| e.to_string())?;
        }
        let mut no_obs: [&mut dyn SimObserver; 0] = [];
        let mut env = crate::env::SimEnv::new(&c, &jobs);
        let mut agent = crate::env::RandomAgent::new(g.u64(0, 1 << 40));
        let snap_at = g.u64(0, 3);
        let cap = 20_000u64;
        let mut snap = None;
        let mut o = env.reset(&mut no_obs).map_err(|e| e.to_string())?;
        while !o.done && env.steps() < cap {
            if env.steps() == snap_at {
                snap = Some((env.save(), agent.save()));
            }
            let d = env
                .state()
                .pending()
                .ok_or_else(|| "unfinished episode paused without a decision".to_string())?;
            let action = agent.act(env.state(), &d, &o);
            o = env.step(action, &mut no_obs).map_err(|e| e.to_string())?.0;
        }
        let (env_snap, rng_snap) = match snap {
            // Degenerate trace with fewer decisions than the snapshot
            // index: nothing to resume, vacuously fine.
            None => return Ok(()),
            Some(s) => s,
        };
        let mut env2 = crate::env::SimEnv::new(&c, &jobs);
        env2.restore(&env_snap);
        let mut agent2 = crate::env::RandomAgent::restore(&rng_snap);
        let mut o2 = env2.observe();
        while !o2.done && env2.steps() < cap {
            let d = env2
                .state()
                .pending()
                .ok_or_else(|| "resumed episode paused without a decision".to_string())?;
            let action = agent2.act(env2.state(), &d, &o2);
            o2 = env2.step(action, &mut no_obs).map_err(|e| e.to_string())?.0;
        }
        if env.steps() != env2.steps() {
            return Err(format!("steps diverged: {} vs {}", env.steps(), env2.steps()));
        }
        bits_eq("episode return", &[env.episode_return()], &[env2.episode_return()])?;
        bits_eq("final clock", &[env.state().now()], &[env2.state().now()])?;
        if env.state().events_processed() != env2.state().events_processed() {
            return Err(format!(
                "events diverged: {} vs {}",
                env.state().events_processed(),
                env2.state().events_processed()
            ));
        }
        if env.state().finished_jobs() != env2.state().finished_jobs() {
            return Err(format!(
                "finishes diverged: {} vs {}",
                env.state().finished_jobs(),
                env2.state().finished_jobs()
            ));
        }
        Ok(())
    });
}
