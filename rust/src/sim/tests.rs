//! Simulator correctness: analytic single-job checks, conservation laws,
//! contention dynamics, and randomized property tests against invariants.

use super::*;
use crate::sim::Repricing;
use crate::cluster::ClusterSpec;
use crate::model::{CommModel, DnnModel};
use crate::placement::{FirstFitPlacer, LwfPlacer};
use crate::sched::{AdaDual, SrsfCap};
use crate::trace::{self, JobSpec, TraceConfig};
use crate::util::prop::prop_check;

fn cfg(n_servers: usize, gpus_per_server: usize) -> SimConfig {
    SimConfig {
        cluster: ClusterSpec::tiny(n_servers, gpus_per_server),
        comm: CommModel::paper_10gbe(),
        repricing: Repricing::Dynamic,
        priority: JobPriority::Srsf,
        log_events: false,
    }
}

fn job(id: usize, arrival: f64, model: DnnModel, n_gpus: usize, iters: u64) -> JobSpec {
    JobSpec { id, arrival, model, n_gpus, iterations: iters }
}

fn run(cfg: &SimConfig, jobs: &[JobSpec]) -> SimResult {
    let mut placer = LwfPlacer::new(1);
    let policy = AdaDual { model: cfg.comm };
    simulate(cfg, jobs, &mut placer, &policy)
}

#[test]
fn single_job_single_gpu_matches_analytic() {
    let c = cfg(1, 1);
    let j = job(0, 0.0, DnnModel::ResNet50, 1, 50);
    let res = run(&c, &[j.clone()]);
    let want = j.compute_total(c.cluster.gpu_peak_gflops);
    assert!((res.jct[0] - want).abs() < 1e-6, "{} vs {want}", res.jct[0]);
    assert!((res.makespan - want).abs() < 1e-6);
    // The lone GPU is busy the whole time.
    assert!((res.avg_gpu_util() - 1.0).abs() < 1e-6);
}

#[test]
fn single_job_multi_gpu_one_server_no_comm() {
    let c = cfg(1, 4);
    let j = job(0, 0.0, DnnModel::Vgg16, 4, 20);
    let res = run(&c, &[j.clone()]);
    // Same wall time as 1 GPU: data-parallel workers run concurrently,
    // no communication inside one server.
    let want = j.compute_total(c.cluster.gpu_peak_gflops);
    assert!((res.jct[0] - want).abs() < 1e-6, "{} vs {want}", res.jct[0]);
    assert_eq!(res.clean_admissions + res.contended_admissions, 0);
}

#[test]
fn single_job_two_servers_pays_allreduce() {
    let c = cfg(2, 1);
    let j = job(0, 0.0, DnnModel::ResNet50, 2, 30);
    let res = run(&c, &[j.clone()]);
    let compute = j.compute_total(c.cluster.gpu_peak_gflops);
    let comm = c.comm.time_free(j.message_bytes()) * 30.0;
    let want = compute + comm;
    assert!(
        (res.jct[0] - want).abs() < 1e-6,
        "jct {} vs analytic {want}",
        res.jct[0]
    );
    assert_eq!(res.clean_admissions, 30);
    assert_eq!(res.contended_admissions, 0);
    assert_eq!(res.max_contention, 1);
}

#[test]
fn arrival_offset_respected() {
    let c = cfg(1, 1);
    let j = job(0, 100.0, DnnModel::LstmPtb, 1, 10);
    let res = run(&c, &[j.clone()]);
    let dur = j.compute_total(c.cluster.gpu_peak_gflops);
    assert!((res.finish[0] - (100.0 + dur)).abs() < 1e-6);
    assert!((res.jct[0] - dur).abs() < 1e-6);
}

#[test]
fn two_jobs_share_gpu_by_time_slicing() {
    // One 1-GPU cluster, two identical jobs arriving together: total busy
    // time is the sum; both finish; the later-priority one finishes last.
    let c = cfg(1, 1);
    let j0 = job(0, 0.0, DnnModel::ResNet50, 1, 40);
    let j1 = job(1, 0.0, DnnModel::ResNet50, 1, 40);
    let res = run(&c, &[j0.clone(), j1.clone()]);
    let each = j0.compute_total(c.cluster.gpu_peak_gflops);
    assert!(res.jct.iter().all(|t| t.is_finite()));
    let last = res.makespan;
    assert!((last - 2.0 * each).abs() < 1e-6, "{last} vs {}", 2.0 * each);
    // SRSF ties break to job 0, which should finish first.
    assert!(res.finish[0] < res.finish[1]);
}

#[test]
fn srsf_prefers_shorter_job() {
    let c = cfg(1, 1);
    let short = job(0, 0.0, DnnModel::ResNet50, 1, 10);
    let long = job(1, 0.0, DnnModel::ResNet50, 1, 1000);
    // Arrive simultaneously; the short one must not wait behind the long.
    let res = run(&c, &[long.clone(), short.clone()]);
    // ids: long=0? careful: ids are positional. long is job 0 here.
    let short_jct = res.jct[1];
    let want_short = short.compute_total(c.cluster.gpu_peak_gflops);
    assert!(
        short_jct < want_short * 1.5,
        "short job starved: jct={short_jct} ideal={want_short}"
    );
}

#[test]
fn contention_slows_transfers_versus_srsf1() {
    // Two 2-server jobs communicating heavily: SRSF(2) forces overlap,
    // SRSF(1) serialises. Both must respect Eq (5) timing; the overlapped
    // run has max_contention 2.
    let c = cfg(2, 2);
    let j0 = job(0, 0.0, DnnModel::Vgg16, 4, 20);
    let j1 = job(1, 0.0, DnnModel::Vgg16, 4, 20);
    // Force both jobs across servers: 4 GPUs over 2 servers of 2.
    let mut ff = FirstFitPlacer;
    let r1 = simulate(&c, &[j0.clone(), j1.clone()], &mut ff, &SrsfCap { cap: 1 });
    let mut ff = FirstFitPlacer;
    let r2 = simulate(&c, &[j0, j1], &mut ff, &SrsfCap { cap: 2 });
    assert_eq!(r1.max_contention, 1);
    assert_eq!(r2.max_contention, 2);
    assert!(r2.contended_admissions > 0);
    // Equal-size messages overlapping is exactly the paper's bad case:
    // SRSF(2) must not beat SRSF(1) here.
    let avg1 = r1.jct.iter().sum::<f64>() / 2.0;
    let avg2 = r2.jct.iter().sum::<f64>() / 2.0;
    assert!(avg2 >= avg1 - 1e-6, "blind overlap won: {avg2} < {avg1}");
}

#[test]
fn adadual_admits_small_against_large() {
    // A huge transfer in flight + a tiny newcomer: AdaDUAL overlaps
    // (ratio test passes) while SRSF(1) waits.
    let c = cfg(2, 2);
    // VGG (526 MB) long job and ResNet (99 MB) short job; ratio 0.19 < 0.387.
    let big = job(0, 0.0, DnnModel::Vgg16, 4, 40);
    let small = job(1, 0.0, DnnModel::ResNet50, 4, 40);
    let mut ff = FirstFitPlacer;
    let ada = simulate(&c, &[big.clone(), small.clone()], &mut ff, &AdaDual { model: c.comm });
    let mut ff = FirstFitPlacer;
    let srsf1 = simulate(&c, &[big, small], &mut ff, &SrsfCap { cap: 1 });
    assert!(ada.contended_admissions > 0, "AdaDUAL never overlapped");
    let avg_ada = ada.jct.iter().sum::<f64>() / 2.0;
    let avg_1 = srsf1.jct.iter().sum::<f64>() / 2.0;
    assert!(
        avg_ada <= avg_1 + 1e-6,
        "AdaDUAL {avg_ada} worse than SRSF(1) {avg_1}"
    );
}

#[test]
fn all_jobs_finish_on_paper_trace() {
    let c = SimConfig::paper();
    let jobs = trace::generate(&TraceConfig::paper_160());
    let res = run(&c, &jobs);
    assert!(res.jct.iter().all(|t| t.is_finite()), "some job never finished");
    assert!(res.makespan > 0.0);
    assert!(res.n_events > 100_000);
}

#[test]
fn jct_at_least_critical_path() {
    let c = SimConfig::paper();
    let jobs = trace::generate(&TraceConfig::scaled(40, 3));
    let res = run(&c, &jobs);
    for (i, j) in jobs.iter().enumerate() {
        // Lower bound: contention-free compute-only critical path.
        let lb = j.compute_total(c.cluster.gpu_peak_gflops);
        assert!(
            res.jct[i] >= lb - 1e-6,
            "job {i} jct {} below lower bound {lb}",
            res.jct[i]
        );
    }
}

#[test]
fn gpu_busy_never_exceeds_makespan() {
    let c = SimConfig::paper();
    let jobs = trace::generate(&TraceConfig::scaled(30, 5));
    let res = run(&c, &jobs);
    for (g, &busy) in res.gpu_busy.iter().enumerate() {
        assert!(
            busy <= res.makespan + 1e-6,
            "gpu {g} busy {busy} > makespan {}",
            res.makespan
        );
    }
}

#[test]
fn event_log_records_lifecycle() {
    let mut c = cfg(2, 1);
    c.log_events = true;
    let jobs = [job(0, 0.0, DnnModel::ResNet50, 2, 3)];
    let res = run(&c, &jobs);
    let text: Vec<&str> = res.events.iter().map(|e| e.what.as_str()).collect();
    assert!(text.iter().any(|s| s.starts_with("arrive")));
    assert!(text.iter().any(|s| s.starts_with("place")));
    assert!(text.iter().any(|s| s.starts_with("comm-start")));
    assert!(text.iter().any(|s| s.starts_with("finish")));
}

#[test]
fn prop_simulator_invariants() {
    // Randomized small workloads: every job finishes, JCTs beat lower
    // bounds, utilisation bounded, contention never exceeds policy cap.
    prop_check(25, |g| {
        let n_servers = g.usize(1, 4);
        let gps = g.usize(1, 4);
        let c = cfg(n_servers, gps);
        let n_jobs = g.usize(1, 8);
        let total_gpus = n_servers * gps;
        let models = crate::model::ALL_MODELS;
        let jobs: Vec<JobSpec> = (0..n_jobs)
            .map(|i| {
                let n_gpus = g.usize(1, total_gpus.min(8));
                JobSpec {
                    id: i,
                    arrival: g.f64(0.0, 50.0),
                    model: *g.pick(&models),
                    n_gpus,
                    iterations: g.u64(1, 60),
                }
            })
            .collect();
        let cap = g.usize(1, 3);
        let use_ada = g.bool();
        let res = if use_ada {
            let mut p = LwfPlacer::new(1);
            simulate(&c, &jobs, &mut p, &AdaDual { model: c.comm })
        } else {
            let mut p = LwfPlacer::new(1);
            simulate(&c, &jobs, &mut p, &SrsfCap { cap })
        };
        for (i, j) in jobs.iter().enumerate() {
            if !res.jct[i].is_finite() {
                return Err(format!("job {i} unfinished"));
            }
            let lb = j.compute_total(c.cluster.gpu_peak_gflops);
            if res.jct[i] < lb - 1e-6 {
                return Err(format!("job {i} jct {} < lower bound {lb}", res.jct[i]));
            }
        }
        let max_allowed = if use_ada { 2 } else { cap };
        if res.max_contention > max_allowed {
            return Err(format!(
                "contention {} exceeded cap {max_allowed}",
                res.max_contention
            ));
        }
        let util = res.avg_gpu_util();
        if !(0.0..=1.0 + 1e-9).contains(&util) {
            return Err(format!("util {util} out of range"));
        }
        Ok(())
    });
}

#[test]
fn prop_more_contention_allowed_never_reduces_max() {
    // SRSF(3) should observe >= the contention SRSF(1) observes.
    prop_check(10, |g| {
        let c = cfg(2, 2);
        let n_jobs = g.usize(2, 6);
        let models = crate::model::ALL_MODELS;
        let jobs: Vec<JobSpec> = (0..n_jobs)
            .map(|i| JobSpec {
                id: i,
                arrival: g.f64(0.0, 5.0),
                model: *g.pick(&models),
                n_gpus: 4,
                iterations: g.u64(5, 30),
            })
            .collect();
        let mut p1 = FirstFitPlacer;
        let r1 = simulate(&c, &jobs, &mut p1, &SrsfCap { cap: 1 });
        let mut p3 = FirstFitPlacer;
        let r3 = simulate(&c, &jobs, &mut p3, &SrsfCap { cap: 3 });
        if r1.max_contention > 1 {
            return Err("SRSF(1) saw contention".into());
        }
        if r3.max_contention < r1.max_contention {
            return Err("cap-3 saw less contention than cap-1".into());
        }
        Ok(())
    });
}
