//! The simulation engine. See module docs in `sim/mod.rs`.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::bail;
use crate::cluster::{ClusterSpec, ClusterState, FreeGpuIndex, GpuId};
use crate::fault::{FaultPlan, HealthView, PrimFault};
use crate::model::CommModel;
use crate::net::{links_intersect, LinkId, LinkLists, Topology, TopologySpec};
use crate::placement::Placer;
use crate::sched::health::{backoff_delay, Blacklist};
use crate::sched::{srsf_cmp, Admission, CommPolicy, JobQueue, NetView};
use crate::source::JobSource;
use crate::trace::JobSpec;
use crate::util::error::Result;

use super::observe::{
    LegacyLog, MetricsObserver, RunStats, SimEvent, SimObserver, TaskPhase as Phase,
};

const EPS: f64 = 1e-9;

/// Sequence-number domain split for streaming runs. The batch path pushes
/// every arrival up front with `seq = job index` and then counts runtime
/// events from `jobs.len()`; a streaming run doesn't know the trace length,
/// so arrival events keep `seq = job index` while runtime events count up
/// from this base. The heap pops by `(t, seq)`, so this preserves the batch
/// path's order bit-for-bit: at equal timestamps an arrival still precedes
/// every runtime event (`index < RUNTIME_BASE <= runtime seq`), arrivals
/// keep their id order, and runtime events keep their push order.
const RUNTIME_BASE: u64 = 1 << 63;

/// How a transfer's rate reacts to contention changes mid-flight.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Repricing {
    /// Every affected transfer is repriced whenever a task starts or
    /// finishes on a shared server — the physically exact differential
    /// form of Eq (5). Under this model a newcomer slows already-running
    /// elephants down, which *erodes* AdaDUAL's pairwise win (see
    /// docs/EXPERIMENTS.md §TableV-discussion).
    Dynamic,
    /// A transfer's k (and thus duration) is fixed once, at admission —
    /// the behaviour of the paper's slot-based simulator: each task's cost
    /// is `a + k·b·M + (k−1)·η·M` with k evaluated when it starts. The
    /// newcomer pays the contention price; existing transfers keep theirs.
    AtAdmission,
}

impl Repricing {
    /// Canonical scenario-file spelling.
    pub fn name(self) -> &'static str {
        match self {
            Repricing::Dynamic => "dynamic",
            Repricing::AtAdmission => "at-admission",
        }
    }

    /// Parse the scenario-file spelling (also accepts the variant names).
    pub fn parse(s: &str) -> Option<Repricing> {
        match s {
            "dynamic" | "Dynamic" | "exact" => Some(Repricing::Dynamic),
            "at-admission" | "AtAdmission" | "paper" => Some(Repricing::AtAdmission),
            _ => None,
        }
    }
}

/// Job priority rule used for queueing, per-GPU task selection and
/// pending-communication ordering. The paper uses SRSF (Tiresias); FIFO
/// and LAS are the classical baselines its related-work section contrasts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobPriority {
    /// Shortest remaining service (remaining time × GPUs) first — paper.
    Srsf,
    /// Earliest arrival first.
    Fifo,
    /// Least attained service (elapsed work × GPUs) first — Tiresias' 2D-LAS.
    Las,
}

impl JobPriority {
    /// Canonical scenario-file spelling.
    pub fn name(self) -> &'static str {
        match self {
            JobPriority::Srsf => "srsf",
            JobPriority::Fifo => "fifo",
            JobPriority::Las => "las",
        }
    }

    /// Parse the scenario-file spelling (case-insensitive).
    pub fn parse(s: &str) -> Option<JobPriority> {
        match s.to_ascii_lowercase().as_str() {
            "srsf" => Some(JobPriority::Srsf),
            "fifo" => Some(JobPriority::Fifo),
            "las" => Some(JobPriority::Las),
            _ => None,
        }
    }

    /// Every priority rule, in scenario-sweep order.
    pub fn all() -> [JobPriority; 3] {
        [JobPriority::Srsf, JobPriority::Fifo, JobPriority::Las]
    }
}

/// Simulator configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub cluster: ClusterSpec,
    pub comm: CommModel,
    /// Fabric topology (paper: `Flat` — contention on server NICs only).
    /// `comm` stays the base link model; presets derive per-link
    /// parameters from it (see `net::Topology::build`).
    pub topology: TopologySpec,
    /// Contention repricing mode (paper: `AtAdmission`).
    pub repricing: Repricing,
    /// Job priority rule (paper: SRSF).
    pub priority: JobPriority,
    /// Steady-state iteration fast-forwarding: jobs in a provably
    /// non-interacting regime jump many iterations per heap event
    /// (docs/EXPERIMENTS.md §Perf). Results are identical to the
    /// event-exact engine (property-tested); `false` forces one event per
    /// task, for debugging and as the equivalence oracle.
    pub coalescing: bool,
    /// Record a per-event log (for debugging / the contention demo).
    /// Compatibility switch: `simulate` attaches a [`LegacyLog`] observer
    /// iff this is set; the engine itself never formats strings.
    pub log_events: bool,
    /// Worker threads for reconcile-time advancement of non-interacting
    /// jobs (1 = serial, the default). When a placement pass dissolves
    /// several live macro-events at once, each job's pure float-chain
    /// walk fans out over up to `workers` scoped threads; the results
    /// are applied serially in the serial engine's order, so every
    /// emission, heap push and float operation is bit-identical for any
    /// value (property-tested across the generator grid). Only the jobs
    /// steadiness already proved non-interacting ever run concurrently.
    pub workers: usize,
    /// Compiled fault timeline (GPU/link failures and recoveries) plus
    /// checkpoint/restart knobs. The default empty plan leaves the engine
    /// bit-identical to a fault-less build: no heap pushes, no extra
    /// float operations, no RNG draws (see docs/EXPERIMENTS.md §Faults).
    pub faults: FaultPlan,
}

impl SimConfig {
    /// The paper's evaluation setup (Tables IV–V, Figs 4–6).
    pub fn paper() -> SimConfig {
        SimConfig {
            cluster: ClusterSpec::paper_64gpu(),
            comm: CommModel::paper_10gbe(),
            topology: TopologySpec::Flat,
            repricing: Repricing::AtAdmission,
            priority: JobPriority::Srsf,
            coalescing: true,
            log_events: false,
            workers: 1,
            faults: FaultPlan::default(),
        }
    }

    /// Physically exact contention dynamics (our extension/ablation).
    pub fn exact() -> SimConfig {
        SimConfig { repricing: Repricing::Dynamic, ..SimConfig::paper() }
    }
}

/// One entry of the optional event log.
#[derive(Clone, Debug)]
pub struct EventLog {
    pub t: f64,
    pub what: String,
}

/// Simulation outputs: everything the paper's metrics need. Since the
/// observer redesign this is a compatibility facade assembled from
/// [`MetricsObserver`] (and [`LegacyLog`] for `events`) by [`simulate`];
/// the engine itself only emits typed [`SimEvent`]s.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Per-job completion time F_k − A_k, indexed by job id.
    pub jct: Vec<f64>,
    /// Per-job finish timestamps F_k.
    pub finish: Vec<f64>,
    /// Per-job time spent waiting for placement.
    pub queue_wait: Vec<f64>,
    /// Busy seconds per GPU.
    pub gpu_busy: Vec<f64>,
    /// Allocated-window seconds per GPU (first placement to last release).
    pub gpu_alloc_window: Vec<f64>,
    /// Simulated span (max finish time).
    pub makespan: f64,
    pub n_events: u64,
    /// Comm tasks admitted into contention (k >= 2 at admission).
    pub contended_admissions: u64,
    /// Comm tasks admitted onto idle links.
    pub clean_admissions: u64,
    /// Highest contention level any task experienced.
    pub max_contention: usize,
    /// Fault-induced preemptions over the run.
    pub preempted: u64,
    /// Restart commits (a preempted job re-placed and resumed).
    pub restarted: u64,
    /// Iterations of progress rolled back across all preemptions.
    pub lost_iters: u64,
    pub events: Vec<EventLog>,
}

impl SimResult {
    /// Average GPU utilisation = busy / makespan, averaged over GPUs.
    pub fn avg_gpu_util(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        let per: f64 = self.gpu_busy.iter().map(|b| b / self.makespan).sum();
        per / self.gpu_busy.len() as f64
    }

    /// Per-GPU utilisations (for the Fig 4b/5b/6b distributions). A run
    /// with no makespan reports zero utilisation everywhere, matching
    /// `avg_gpu_util` (the two used to disagree: this divided by an
    /// epsilon-clamped makespan — docs/EXPERIMENTS.md §Perf).
    pub fn gpu_utils(&self) -> Vec<f64> {
        if self.makespan <= 0.0 {
            return vec![0.0; self.gpu_busy.len()];
        }
        self.gpu_busy.iter().map(|b| b / self.makespan).collect()
    }

    /// Utilisation over each GPU's *allocated window* (first placement to
    /// last release) instead of the global makespan — closer to how a
    /// cluster operator reads per-GPU utilisation, and less sensitive to
    /// long idle tails. Reported alongside the headline number.
    pub fn avg_alloc_util(&self) -> f64 {
        let mut acc = 0.0;
        let mut n = 0usize;
        for (b, w) in self.gpu_busy.iter().zip(&self.gpu_alloc_window) {
            if *w > EPS {
                acc += (b / w).min(1.0);
                n += 1;
            }
        }
        if n == 0 { 0.0 } else { acc / n as f64 }
    }
}

// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq)]
enum Ev {
    Arrive { job: usize },
    /// `epoch` stamps the job's run generation at push time: a preemption
    /// bumps [`JobRt::run_epoch`], so compute completions from before the
    /// preemption pop as stale instead of crediting a cancelled task.
    /// Zero-fault runs never preempt, so the stamp is always 0 there.
    ComputeDone { gpu: GpuId, job: usize, phase: Phase, epoch: u32 },
    CommDone { comm: usize, version: u64 },
    /// Macro-event: `job` runs its whole remaining steady-state iteration
    /// chain analytically and finishes when this fires. Version-stamped
    /// like `CommDone`: any interaction dissolves the macro-event
    /// (reconciling partial progress) and bumps the version, so the stale
    /// completion is skipped.
    FastForward { job: usize, version: u64 },
    /// The fault timeline's entry `idx` fires. Exactly one fault event is
    /// in the heap at a time (the next one is pushed when this pops), so
    /// an empty timeline pushes nothing and perturbs nothing.
    Fault { idx: usize },
    /// A restarted job's warmup ends and its first iteration starts.
    /// Epoch-stamped like `ComputeDone`: a second preemption during the
    /// warmup strands this event as stale.
    Warmup { job: usize, epoch: u32 },
    /// A preempted job's restart backoff elapsed: re-queue it for
    /// placement. Epoch-stamped defensively — a job waiting out its
    /// backoff holds no GPUs, so nothing can preempt it and bump the
    /// epoch; the stamp documents and checks that invariant. Never
    /// pushed while `faults.backoff_base_s == 0` (the default).
    Retry { job: usize, epoch: u32 },
    /// A blacklisted GPU's failure window drained: release the memory
    /// hold and let placements land on it again (see `on_unblacklist`).
    /// Never pushed while `faults.blacklist_k == 0` (the default).
    Unblacklist { gpu: GpuId },
}

#[derive(Clone, Copy, PartialEq)]
struct Timed {
    t: f64,
    seq: u64, // FIFO tie-break for equal times, keeps runs deterministic
    ev: Ev,
}

impl Eq for Timed {}

impl Ord for Timed {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert for earliest-first. total_cmp
        // keeps the heap a total order even if an event time goes NaN
        // (a poisoned comm model must surface as a wrong result, not a
        // panic mid-event-loop); for the finite times of a healthy run it
        // agrees with partial_cmp.
        other
            .t
            .total_cmp(&self.t)
            .then(other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Timed {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Active macro-event: the analytic stand-in for a steady-state job's
/// remaining per-iteration event chain (see `ff_commit`).
#[derive(Clone)]
struct FfState {
    /// Start of the first coalesced iteration (an exact event time).
    start_t: f64,
    /// Iterations the macro-event covers — all that remain.
    iters: u64,
    /// Finish time of the last covered iteration, computed by replaying
    /// the exact engine's per-event float arithmetic, so completion is
    /// bit-identical to the event-exact schedule.
    end_t: f64,
    /// Worst-link latency `a` per All-Reduce (0 for single-server jobs).
    lat: f64,
    /// Locked k = 1 bottleneck per-byte price (0 for single-server jobs).
    per_byte: f64,
}

/// Per-job runtime state.
#[derive(Clone)]
struct JobRt {
    spec: JobSpec,
    gpus: Vec<GpuId>,
    /// Fabric links this job's All-Reduce crosses (fixed at placement).
    links: Vec<LinkId>,
    multi_server: bool,
    t_fwd: f64,
    t_bwd: f64,
    /// Uncontended All-Reduce time `time_free(message_bytes)` — fixed at
    /// placement (0 for single-server jobs) so the SRSF/LAS priority keys
    /// don't re-derive it per comparison.
    t_comm_free: f64,
    iters_done: u64,
    bwd_remaining: usize,
    comm_pending: bool,
    /// Bookkeeping load drained from its GPUs per finished iteration.
    load_per_iter: f64,
    /// Total bookkeeping load committed at placement (for final release).
    load_total: f64,
    /// Placement order (1-based commit counter). Two jobs placed in the
    /// same pass with the same model run bitwise-lockstep iteration
    /// chains, and their same-timestamp events always process in
    /// placement order — the tie-break reconciliation needs when a
    /// macro-event boundary lands exactly on an interrupting finish.
    placed_seq: u64,
    /// Active macro-event, if the job is currently fast-forwarded.
    ff: Option<FfState>,
    /// Stamp carried by `FastForward` events; reconciliation bumps it so
    /// a dissolved macro-event's completion is skipped as stale.
    ff_version: u64,
    /// Run generation, bumped by every preemption: `ComputeDone` /
    /// `Warmup` events carry the epoch they were pushed under and pop as
    /// stale after a mismatch. Always 0 in a fault-less run.
    run_epoch: u32,
    /// Live (current-epoch) `ComputeDone` events in the heap — exactly
    /// the predictions a preemption strands, so `heap_stale` stays an
    /// exact count.
    inflight_compute: usize,
    /// Times this job has been preempted and re-queued.
    restarts: u64,
    /// Set by preemption, consumed by the next placement: emit
    /// `JobRestarted` and charge the warmup cost.
    pending_restart: bool,
    /// A `Warmup` event for the current epoch is in the heap.
    warmup_pending: bool,
}

impl JobRt {
    fn remaining_service(&self) -> f64 {
        let iters_left = (self.spec.iterations - self.iters_done) as f64;
        iters_left * (self.t_fwd + self.t_bwd + self.t_comm_free) * self.spec.n_gpus as f64
    }

    /// SRSF key before placement (E_J = 0, §IV-A Job Priority).
    fn queued_service(&self, peak_gflops: f64) -> f64 {
        self.spec.compute_total(peak_gflops) * self.spec.n_gpus as f64
    }
}

/// One active All-Reduce transfer. `latency_left`/`remaining` are the
/// residuals *at* `anchor_t` (admission, or the last repricing); state at
/// any later time is derived in closed form by `SimState::residual_at`
/// rather than advanced incrementally — so the values are independent of
/// when intermediate events happened to look, which is what lets
/// fast-forwarding skip events without perturbing other transfers.
#[derive(Clone)]
struct CommTask {
    job: usize,
    /// Logical transfer id reported to observers. Comm *slots* are
    /// recycled (`SimState::free_slots`) so steady-state admission reuses a
    /// dead task's storage, but the ids observers see keep counting
    /// monotonically — event streams stay byte-identical to the
    /// grow-only engine this replaced.
    pub_id: usize,
    /// Links the transfer crosses (== its job's `links`, sorted).
    links: Vec<LinkId>,
    /// Position of this task's id inside each `per_link` row for
    /// `links[i]`, maintained under swap-removes so completion leaves
    /// every crossed link in O(1) instead of an O(occupancy) retain scan.
    link_pos: Vec<usize>,
    /// A `CommDone` for the *current* `version` sits unpopped in the
    /// heap. Lets `repredict` count exactly the predictions it strands
    /// (the stale-entry counter driving heap compaction).
    predicted: bool,
    latency_left: f64,
    remaining: f64,
    /// Effective contention level: max active-task count over `links`.
    k: usize,
    /// Effective per-byte drain time: the bottleneck link's Eq (5) price
    /// at its current occupancy (on a flat fabric this is exactly
    /// `comm.per_byte(k)`, the seed engine's pricing).
    per_byte: f64,
    /// Time the residuals above were last fixed (admission / repricing).
    anchor_t: f64,
    /// Prediction generation. Continues across slot reuse — never reset —
    /// so a `CommDone` stranded in the heap by a previous tenant of this
    /// slot can never collide with a live prediction.
    version: u64,
    /// Under `Repricing::AtAdmission`, set once the admission price has
    /// been fixed (by `repredict`, or directly when a reconcile rebuilds
    /// an uncontended in-flight transfer): later network changes must not
    /// reprice the task. Replaces the old `version > 0` test, which slot
    /// reuse breaks (a recycled slot starts life with `version > 0`).
    repriced: bool,
    /// How many of this task's links are currently failed. While > 0 the
    /// residuals above are *frozen* (no drain progress, no prediction in
    /// the heap); recovery of the last failed link re-anchors and
    /// re-predicts. Always 0 in a fault-less run.
    paused_links: usize,
    done: bool,
}

/// Per-GPU runtime state. Busy time, allocation windows and release
/// times are no longer accumulated here — observers derive them from
/// `ComputeStarted` / `JobPlaced` / `JobFinished` events.
#[derive(Clone)]
struct GpuRt {
    busy: bool,
    /// Job whose compute task occupies this GPU (meaningful only while
    /// `busy`) — lets a preemption identify its own in-flight task
    /// without scanning the heap.
    running: usize,
    ready: Vec<(usize, Phase)>, // compute-ready (job, phase) on this GPU
    /// Predicted completion of the in-flight task (meaningful only while
    /// `busy`) — lets a gray-failure slowdown rebase the remaining work
    /// in closed form without scanning the heap.
    done_at: f64,
    /// Phase of the in-flight task (meaningful only while `busy`).
    phase: Phase,
}

/// Run one simulation: `jobs` through `placer` + `policy` on
/// `cfg.cluster`. A thin facade over [`simulate_observed`]: attaches a
/// [`MetricsObserver`] (plus a [`LegacyLog`] iff `cfg.log_events`) and
/// assembles the compatibility [`SimResult`] from them.
pub fn simulate(
    cfg: &SimConfig,
    jobs: &[JobSpec],
    placer: &mut dyn Placer,
    policy: &dyn CommPolicy,
) -> SimResult {
    let mut metrics = MetricsObserver::new();
    if cfg.log_events {
        let mut log = LegacyLog::new();
        {
            let mut obs: [&mut dyn SimObserver; 2] = [&mut metrics, &mut log];
            simulate_observed(cfg, jobs, placer, policy, &mut obs);
        }
        let mut res = metrics.into_result();
        res.events = log.into_events();
        res
    } else {
        let mut obs: [&mut dyn SimObserver; 1] = [&mut metrics];
        simulate_observed(cfg, jobs, placer, policy, &mut obs);
        metrics.into_result()
    }
}

/// Run one simulation, streaming typed [`SimEvent`]s to `observers`
/// instead of accumulating anything. The engine allocates no event
/// strings and keeps no per-event state, so memory stays bounded for
/// arbitrarily long traces; what a run "returns" is whatever the
/// attached observers collected.
pub fn simulate_observed(
    cfg: &SimConfig,
    jobs: &[JobSpec],
    placer: &mut dyn Placer,
    policy: &dyn CommPolicy,
    observers: &mut [&mut dyn SimObserver],
) {
    for o in observers.iter_mut() {
        o.on_start(cfg, jobs);
    }
    let mut state = SimState::new(cfg, jobs);
    drive(&mut state, placer, policy, observers, None)
        .expect("batch simulation with builtin agents cannot fail: no job source to error");
}

/// Run one simulation fed by a streaming [`JobSource`] instead of a
/// materialized trace: the engine pulls the next job lazily whenever an
/// arrival is processed, so the heap holds at most one pending arrival and
/// memory stays bounded by the jobs *in flight*, not the trace length.
/// Job ids are assigned in pull order (the source's ids are overwritten);
/// arrivals must be nondecreasing and finite or the run errors out.
///
/// Fed the same (arrival-sorted, sequentially-id'd) jobs, results are
/// bit-identical to [`simulate`] — property-tested across topologies,
/// priorities and admission policies.
pub fn simulate_stream(
    cfg: &SimConfig,
    source: &mut dyn JobSource,
    placer: &mut dyn Placer,
    policy: &dyn CommPolicy,
) -> Result<SimResult> {
    let mut metrics = MetricsObserver::new();
    if cfg.log_events {
        let mut log = LegacyLog::new();
        {
            let mut obs: [&mut dyn SimObserver; 2] = [&mut metrics, &mut log];
            simulate_stream_observed(cfg, source, placer, policy, &mut obs)?;
        }
        let mut res = metrics.into_result();
        res.events = log.into_events();
        Ok(res)
    } else {
        let mut obs: [&mut dyn SimObserver; 1] = [&mut metrics];
        simulate_stream_observed(cfg, source, placer, policy, &mut obs)?;
        Ok(metrics.into_result())
    }
}

/// Streaming counterpart of [`simulate_observed`]. `on_start` receives an
/// empty job slice — the horizon is unknown — so per-job observers must
/// size their state on demand (every observer in this crate does).
pub fn simulate_stream_observed(
    cfg: &SimConfig,
    source: &mut dyn JobSource,
    placer: &mut dyn Placer,
    policy: &dyn CommPolicy,
    observers: &mut [&mut dyn SimObserver],
) -> Result<()> {
    for o in observers.iter_mut() {
        o.on_start(cfg, &[]);
    }
    let mut state = SimState::new_streaming(cfg, source.size_hint());
    drive(&mut state, placer, policy, observers, Some(source))
}

/// Drive a [`SimState`] to completion with the builtin placer/policy
/// answering every decision point — the monolithic facades' engine loop.
/// One code path serves both the facades and an env-hosted builtin
/// agent, which is what pins their bit-identity.
fn drive(
    state: &mut SimState,
    placer: &mut dyn Placer,
    policy: &dyn CommPolicy,
    obs: &mut [&mut dyn SimObserver],
    mut source: Option<&mut dyn JobSource>,
) -> Result<()> {
    loop {
        match state.advance(obs, source.as_mut().map(|s| &mut **s))? {
            Step::Decision(d) => {
                let action = state.decide_builtin(&d, placer, policy);
                state.resolve(action, obs)?;
            }
            Step::Done(_) => return Ok(()),
        }
    }
}

/// Fan one event out to every attached observer.
fn emit(observers: &mut [&mut dyn SimObserver], ev: SimEvent<'_>) {
    for o in observers.iter_mut() {
        o.on_event(&ev);
    }
}

/// One steady iteration's event-time chain, replicating the exact
/// engine's float-operation order bit-for-bit: the forward `ComputeDone`
/// lands at `s + t_fwd`, the backward at `(s + t_fwd) + t_bwd`, and the
/// `CommDone` prediction made at admission at `(t2 + lat) + drain` where
/// `drain = msg · per_byte(1)`. Returns (fwd done, bwd done, iteration
/// end).
#[inline]
pub(crate) fn iter_bounds(
    s: f64,
    t_fwd: f64,
    t_bwd: f64,
    multi: bool,
    lat: f64,
    drain: f64,
) -> (f64, f64, f64) {
    let t1 = s + t_fwd;
    let t2 = t1 + t_bwd;
    let c = if multi { t2 + lat + drain } else { t2 };
    (t1, t2, c)
}

/// Initial event-heap capacity from a trace-size hint. The seed sized the
/// heap as `jobs.len() * 4`, which degenerates to zero for a streaming
/// run (no pre-seeded jobs) and over-reserves for huge batch traces whose
/// live event set is bounded by the jobs *in flight*, not the trace. Size
/// from [`crate::source::JobSource::size_hint`] where one exists, with a
/// sane clamp either way; an unknown horizon gets a fixed steady-state
/// default.
pub(crate) fn heap_capacity_hint(jobs_hint: Option<usize>) -> usize {
    const MIN: usize = 64;
    const MAX: usize = 1 << 20;
    jobs_hint.map_or(1024, |n| n.saturating_mul(4)).clamp(MIN, MAX)
}

thread_local! {
    /// Parallel reconcile batches run by engines on this thread — test
    /// observability for the `workers > 1` path. Thread-local (not a
    /// process-wide atomic) so concurrently running tests cannot race on
    /// each other's counts.
    pub(crate) static FF_PAR_BATCHES: std::cell::Cell<u64> =
        const { std::cell::Cell::new(0) };
}

/// Pure inputs of one macro-event reconcile walk: everything the float
/// chain depends on, copied out of the engine so the walk can run on a
/// worker thread with no access to shared state.
#[derive(Clone, Copy)]
struct FfWalk {
    start_t: f64,
    iters: u64,
    t_fwd: f64,
    t_bwd: f64,
    multi: bool,
    lat: f64,
    drain: f64,
    /// Exact-tie heap order against the interrupter (see
    /// [`SimState::reconcile_all_ffs`] for the derivation).
    boundary_first: bool,
}

/// Outputs of a reconcile walk: iterations completed strictly before the
/// interruption (tie-break included), the in-flight iteration's start
/// `s`, its exact event times, and whether the chain ran to completion.
#[derive(Clone, Copy, Default)]
struct FfWalkOut {
    done: u64,
    s: f64,
    t1: f64,
    t2: f64,
    c: f64,
    finished: bool,
}

/// Replay a macro-event's iteration chain up to `t` — a pure function of
/// the walk inputs, so the result is bit-identical whether it runs
/// inline or on a worker thread. This is the only part of a reconcile
/// that is O(iterations); mutating the engine from the result is O(gpus).
fn ff_walk(w: &FfWalk, t: f64) -> FfWalkOut {
    let mut done = 0u64;
    let mut s = w.start_t;
    let (mut t1, mut t2, mut c) = iter_bounds(s, w.t_fwd, w.t_bwd, w.multi, w.lat, w.drain);
    // Both comparisons are false on a NaN chain (poisoned comm model),
    // so this stops with wrong results, never a hang — the heap order's
    // stance.
    while c < t || (c == t && w.boundary_first) {
        done += 1;
        s = c;
        if done == w.iters {
            return FfWalkOut { done, s, t1, t2, c, finished: true };
        }
        let next = iter_bounds(s, w.t_fwd, w.t_bwd, w.multi, w.lat, w.drain);
        t1 = next.0;
        t2 = next.1;
        c = next.2;
    }
    FfWalkOut { done, s, t1, t2, c, finished: false }
}

/// Fan the walks over up to `workers` scoped threads, each output landing
/// in its input's slot. Deterministic by construction: chunk boundaries
/// only decide *where* a walk runs, never what it computes ([`ff_walk`]
/// is pure) nor the order the caller applies the results in.
fn par_walk(workers: usize, walks: &[FfWalk], t: f64) -> Vec<FfWalkOut> {
    let mut outs = vec![FfWalkOut::default(); walks.len()];
    let n_workers = workers.min(walks.len()).max(1);
    let chunk = walks.len().div_ceil(n_workers);
    std::thread::scope(|scope| {
        for (ws, os) in walks.chunks(chunk).zip(outs.chunks_mut(chunk)) {
            scope.spawn(move || {
                for (w, o) in ws.iter().zip(os.iter_mut()) {
                    *o = ff_walk(w, t);
                }
            });
        }
    });
    outs
}

/// Stale heap entries (superseded `CommDone` / dissolved `FastForward`
/// predictions) tolerated before the heap is rebuilt without them.
/// Dynamic repricing supersedes every affected task's prediction on every
/// network change, so a contended phase otherwise grows the heap without
/// bound; compaction keeps it proportional to the live event set. The
/// second trigger condition (`stale ≥ half the heap`) keeps the rebuild
/// amortized O(1) per processed event.
const STALE_COMPACT_MIN: usize = 1024;

/// A unit of deferred engine work. The old engine nested pausable calls
/// (placement passes, admission passes, iteration starts) inside event
/// handlers; the resumable engine queues them on a LIFO stack instead —
/// popping in exactly the old call order — so [`SimState::advance`] can
/// return to the caller mid-event when an op pauses at a decision point.
#[derive(Clone, Copy, Debug)]
enum Op {
    /// Credit a finished iteration (and maybe finish the job).
    IterComplete { t: f64, job: usize },
    /// Begin the job's next iteration (may reach the coalescing probe).
    StartIteration { t: f64, job: usize },
    /// Run an admission pass over the pending-communication set.
    AdmitPass { t: f64 },
    /// Start the highest-priority ready task on a GPU.
    ScheduleGpu { t: f64, gpu: GpuId },
    /// Run a placement pass unconditionally (arrivals).
    PlacePass { t: f64, interrupter: Option<usize> },
    /// Run a placement pass iff `need_place` was raised (completions).
    PlaceIfNeeded { t: f64, interrupter: Option<usize> },
}

/// Where a paused pass stopped: the walk's frozen cursor, consumed by
/// [`SimState::resolve`] to continue from the exact element it paused at.
#[derive(Clone)]
enum Paused {
    /// Placement walk paused at `entries[idx]` — a placer candidate.
    Place { t: f64, entries: Vec<(f64, usize)>, idx: usize, kept: Vec<(f64, usize)> },
    /// Admission walk paused at `order[idx]` — its links are all up.
    Admit { t: f64, order: Vec<usize>, idx: usize },
    /// Coalescing probe for a provably steady `job`: Start fast-forwards
    /// it, Wait runs the next iteration event-exact.
    Ff { t: f64, job: usize },
}

/// A decision the engine needs before it can continue.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DecisionPoint {
    /// Choose GPUs for `job`, or decline. The capacity gate has already
    /// proven enough feasible GPUs exist for a contract-abiding placer.
    Place { t: f64, job: usize },
    /// Admit `job`'s ready All-Reduce now, or leave it pending.
    Admit { t: f64, job: usize },
    /// `job` is provably steady: admit its (uncontended) per-iteration
    /// All-Reduce — committing to the analytic fast-forward — or keep it
    /// event-exact. Builtin policies see idle links here, so for them
    /// this is the same pure call the monolithic engine made.
    FfProbe { t: f64, job: usize },
}

impl DecisionPoint {
    /// The decision's timestamp.
    pub fn t(&self) -> f64 {
        match *self {
            DecisionPoint::Place { t, .. }
            | DecisionPoint::Admit { t, .. }
            | DecisionPoint::FfProbe { t, .. } => t,
        }
    }

    /// The job the decision concerns.
    pub fn job(&self) -> usize {
        match *self {
            DecisionPoint::Place { job, .. }
            | DecisionPoint::Admit { job, .. }
            | DecisionPoint::FfProbe { job, .. } => job,
        }
    }

    /// Stable kind label (step logs, observations).
    pub fn kind(&self) -> &'static str {
        match self {
            DecisionPoint::Place { .. } => "place",
            DecisionPoint::Admit { .. } => "admit",
            DecisionPoint::FfProbe { .. } => "ff-probe",
        }
    }
}

/// An external answer to a [`DecisionPoint`].
#[derive(Clone, Debug, PartialEq)]
pub enum Action {
    /// For [`DecisionPoint::Place`]: the chosen GPUs, or `None` to leave
    /// the job queued until more memory frees.
    Place(Option<Vec<GpuId>>),
    /// For [`DecisionPoint::Admit`] and [`DecisionPoint::FfProbe`].
    Admit(Admission),
}

/// What [`SimState::advance`] ran into.
#[derive(Debug)]
pub enum Step {
    /// Paused at a decision point; answer with [`SimState::resolve`].
    Decision(DecisionPoint),
    /// The run completed (idempotent: further calls return this again).
    Done(RunStats),
}

/// The complete simulation state — the old monolithic engine with its
/// run-to-completion loop inverted into a resumable state machine.
/// [`SimState::advance`] runs the event loop until the next decision
/// point (placement candidate, admission gate or coalescing probe) and
/// returns it; [`SimState::resolve`] applies an external [`Action`] and
/// the next `advance` resumes exactly where the walk paused. Observers
/// and the streaming job source stay *outside* the state — passed to
/// each call — so `SimState` is `Clone`: [`SimState::save`] and
/// [`SimState::restore`] checkpoint the full deterministic state.
#[derive(Clone)]
pub struct SimState {
    cfg: SimConfig,
    topo: Topology,
    cluster: ClusterState,
    jobs: Vec<JobRt>,
    gpus: Vec<GpuRt>,
    heap: BinaryHeap<Timed>,
    seq: u64,
    /// Jobs waiting for placement, held in `(static queue key, id)` order
    /// incrementally — no per-pass re-sort (keys cannot drift; see
    /// [`JobQueue`]).
    queue: JobQueue,
    /// Bumped whenever a finished job releases memory/GPUs. Placement
    /// feasibility is monotone between releases (allocations only shrink
    /// free memory), so a job that failed to place at generation G must
    /// fail again while the generation is still G.
    release_gen: u64,
    /// Per job: `release_gen` at its last failed placement attempt
    /// (`u64::MAX` = never failed, always eligible).
    place_stamp: Vec<u64>,
    /// Queued jobs whose stamp differs from `release_gen` — the number of
    /// placer calls the next pass can possibly make; 0 proves the pass a
    /// no-op before reconciling any macro-event.
    queue_eligible: usize,
    /// Free-GPU counts per distinct memory demand, maintained O(Δ) at
    /// allocate/release: proves `place` would return `None` (fewer
    /// feasible GPUs than requested) without the placer's O(cluster)
    /// feasibility scan.
    capacity: FreeGpuIndex,
    /// Scratch for per-GPU free-memory readings around allocate/release.
    scratch_free: Vec<f64>,
    /// Job ids with a ready-but-unadmitted All-Reduce.
    pending_comm: Vec<usize>,
    comms: Vec<CommTask>,
    /// Ids of in-flight comm tasks (the only ones the per-pass admission
    /// view visits; scanning the whole historical `comms` vec would be
    /// quadratic).
    active_comms: Vec<usize>,
    /// Position of each comm slot inside `active_comms` (usize::MAX once
    /// inactive), so completion is an O(1) swap-remove instead of an O(n)
    /// retain scan over every in-flight transfer.
    active_pos: Vec<usize>,
    /// Recycled `comms` slots. A completed task returns its slot (with
    /// its `links`/`link_pos` capacity) to this free list; the next
    /// admission pops it instead of growing `comms` — steady state runs
    /// with a bounded slab no matter how many transfers the trace makes.
    free_slots: Vec<usize>,
    /// Next logical transfer id (`CommTask::pub_id`) — monotone even
    /// though slots recycle, reproducing the grow-only engine's observer
    /// id sequence exactly.
    next_comm_id: usize,
    /// Active comm-task slots per fabric link (NICs, then rack uplinks),
    /// as a flat stride-capped slab — one allocation for the whole
    /// fabric instead of a `Vec<Vec<usize>>`'s row-per-link spine, and
    /// row access without the double indirection.
    per_link: LinkLists,
    /// Placement commits so far (feeds `JobRt::placed_seq`).
    placements: u64,
    /// Running (placed, unfinished) multi-server jobs — the set a
    /// multi-server macro-event must stay link-disjoint from. Maintained
    /// at placement/finish so the steadiness check scans this handful
    /// instead of every job in the trace.
    running_multi: Vec<usize>,
    /// Per job: its position inside `running_multi` (`usize::MAX` when
    /// absent) — finish is an O(1) swap-remove, not an O(n) retain.
    running_multi_pos: Vec<usize>,
    /// Always-empty per-link occupancy view lent to the policy by the
    /// steadiness probe (allocated once, never mutated — the probe runs
    /// at every iteration boundary of every uncontended multi job).
    empty_view: LinkLists,
    /// Jobs currently running under a macro-event (`JobRt::ff` set).
    ff_jobs: Vec<usize>,
    /// Per job: its position inside `ff_jobs` (`usize::MAX` when absent).
    ff_pos: Vec<usize>,
    /// Stale entries currently in the heap: superseded `CommDone`
    /// predictions plus dissolved `FastForward` macro-events. Past
    /// `STALE_COMPACT_MIN` (and half the heap) the heap is rebuilt
    /// without them.
    heap_stale: usize,
    /// Scratch for `refresh_links`' affected-task set — reused across
    /// Dynamic-repricing passes instead of allocated per network change.
    scratch_affected: Vec<usize>,
    /// Scratch for `schedule_gpu`'s per-candidate priority keys.
    scratch_keys: Vec<(f64, usize)>,
    /// DDL_SIM_DEBUG progress logging, read once at construction instead
    /// of one env lookup per million-event heartbeat.
    debug: bool,
    n_events: u64,
    unfinished: usize,
    /// Set when a job finished (memory freed) so the event loop re-attempts
    /// placement of queued jobs.
    need_place: bool,
    /// Streaming mode: arrivals are pulled from the `JobSource` handed to
    /// [`SimState::advance`] (batch mode pre-seeds every arrival and
    /// never pulls).
    streaming: bool,
    /// True once the source reported exhaustion (always true in batch
    /// mode): together with `unfinished == 0` this ends the run.
    drained: bool,
    /// Last pulled arrival time — enforces the source's nondecreasing
    /// contract.
    last_arrival: f64,
    /// Live hardware up/down map, driven by the fault timeline. Admission
    /// and fast-forwarding consult it directly; placement indirectly (a
    /// down GPU's free memory is held at zero — see `on_gpu_failed`).
    health: HealthView,
    /// Free memory synthetically held per down GPU (restored at recovery).
    health_hold: Vec<f64>,
    /// Sliding-window failure blacklist over GPUs. `None` while
    /// `faults.blacklist_k == 0` (the default): the recovery path takes
    /// its original branch untouched.
    blacklist: Option<Blacklist>,
    /// The compiled fault timeline contains at least one `GpuSlow`:
    /// placement commits must derate compute durations by the chosen
    /// GPUs' health factors. False (the default) skips that work, so
    /// degradation-free runs stay bit-identical by construction.
    has_gpu_degrade: bool,
    /// Next unprocessed entry of `cfg.faults.events`.
    fault_idx: usize,
    /// Deferred engine work, popped LIFO by `advance` (see [`Op`]).
    ops: Vec<Op>,
    /// The pass currently paused at a decision point, if any.
    paused: Option<Paused>,
    /// The first `advance` call primed the streaming source and the fault
    /// timeline.
    primed: bool,
    /// The event loop ran to completion.
    finished: bool,
    /// A live event was dispatched since the last compaction check (the
    /// stale arms `continue` past arming it, as the old loop's did).
    compact_pending: bool,
    /// Timestamp of the last processed event (the final `RunStats::t_end`).
    t_end: f64,
    /// Arrivals processed (drives the env's jobs-in-system signal).
    arrived: u64,
    /// Jobs finished (ditto).
    done_jobs: u64,
}

impl SimState {
    /// Batch-mode constructor: every arrival pre-seeded in the heap.
    pub fn new(cfg: &SimConfig, jobs: &[JobSpec]) -> SimState {
        let peak = cfg.cluster.gpu_peak_gflops;
        let rt: Vec<JobRt> = jobs
            .iter()
            .map(|spec| {
                let m = crate::model::PerfModel::for_model(spec.model);
                let b = spec.model.spec().batch_size;
                JobRt {
                    spec: spec.clone(),
                    gpus: Vec::new(),
                    links: Vec::new(),
                    multi_server: false,
                    t_fwd: m.t_fwd(b, peak),
                    t_bwd: m.t_bwd(b, peak),
                    t_comm_free: 0.0,
                    iters_done: 0,
                    bwd_remaining: 0,
                    comm_pending: false,
                    load_per_iter: 0.0,
                    load_total: 0.0,
                    placed_seq: 0,
                    ff: None,
                    ff_version: 0,
                    run_epoch: 0,
                    inflight_compute: 0,
                    restarts: 0,
                    pending_restart: false,
                    warmup_pending: false,
                }
            })
            .collect();
        let mut heap = BinaryHeap::with_capacity(heap_capacity_hint(Some(jobs.len())));
        for (i, j) in jobs.iter().enumerate() {
            heap.push(Timed { t: j.arrival, seq: i as u64, ev: Ev::Arrive { job: i } });
        }
        // Scenario loading validates the topology against the cluster up
        // front; direct engine users get the same message via panic.
        let topo = Topology::build(&cfg.cluster, &cfg.comm, &cfg.topology)
            .unwrap_or_else(|e| panic!("invalid SimConfig topology: {e}"));
        let n_links = topo.n_links();
        let cluster = ClusterState::new(cfg.cluster);
        // Every distinct per-GPU memory demand in the trace becomes a
        // capacity-index threshold, so the placement gate answers the
        // exact `fits` count for any job without scanning GPUs.
        let capacity =
            FreeGpuIndex::new(jobs.iter().map(JobSpec::mem_bytes).collect(), &cluster);
        SimState {
            cfg: cfg.clone(),
            topo,
            cluster,
            gpus: (0..cfg.cluster.n_gpus())
                .map(|_| GpuRt {
                    busy: false,
                    running: usize::MAX,
                    ready: Vec::new(),
                    done_at: 0.0,
                    phase: Phase::Fwd,
                })
                .collect(),
            heap,
            seq: jobs.len() as u64,
            queue: JobQueue::new(),
            release_gen: 0,
            place_stamp: vec![u64::MAX; jobs.len()],
            queue_eligible: 0,
            capacity,
            scratch_free: Vec::new(),
            pending_comm: Vec::new(),
            comms: Vec::new(),
            active_comms: Vec::new(),
            active_pos: Vec::new(),
            free_slots: Vec::new(),
            next_comm_id: 0,
            per_link: LinkLists::new(n_links),
            placements: 0,
            running_multi: Vec::new(),
            running_multi_pos: vec![usize::MAX; jobs.len()],
            empty_view: LinkLists::new(n_links),
            ff_jobs: Vec::new(),
            ff_pos: vec![usize::MAX; jobs.len()],
            heap_stale: 0,
            scratch_affected: Vec::new(),
            scratch_keys: Vec::new(),
            debug: std::env::var_os("DDL_SIM_DEBUG").is_some(),
            n_events: 0,
            unfinished: jobs.len(),
            need_place: false,
            jobs: rt,
            streaming: false,
            drained: true,
            last_arrival: f64::NEG_INFINITY,
            health: HealthView::new(cfg.cluster.n_gpus(), n_links),
            health_hold: vec![0.0; cfg.cluster.n_gpus()],
            blacklist: (cfg.faults.blacklist_k > 0).then(|| {
                Blacklist::new(
                    cfg.cluster.n_gpus(),
                    cfg.faults.blacklist_k,
                    cfg.faults.blacklist_window_s,
                )
            }),
            has_gpu_degrade: cfg
                .faults
                .events
                .iter()
                .any(|&(_, f)| matches!(f, PrimFault::GpuSlow(..))),
            fault_idx: 0,
            ops: Vec::new(),
            paused: None,
            primed: false,
            finished: false,
            compact_pending: false,
            t_end: 0.0,
            arrived: 0,
            done_jobs: 0,
        }
    }

    /// Streaming-mode constructor: no pre-seeded jobs; arrivals are
    /// pulled one at a time from the `JobSource` handed to every
    /// [`SimState::advance`] call (see [`simulate_stream_observed`]).
    pub fn new_streaming(cfg: &SimConfig, size_hint: Option<usize>) -> SimState {
        let mut eng = SimState::new(cfg, &[]);
        // The batch constructor saw zero jobs; resize the heap from the
        // source's own estimate of the trace length (bounded — streaming
        // exists precisely so memory does not scale with the trace).
        eng.heap = BinaryHeap::with_capacity(heap_capacity_hint(size_hint));
        // The trace's memory demands are unknown up front; per-GPU demand
        // is a function of the model alone, so registering every zoo
        // model's footprint keeps the capacity gate exact for any
        // streamed job.
        eng.capacity = FreeGpuIndex::new(
            crate::model::ALL_MODELS.iter().map(|m| m.spec().mem_bytes).collect(),
            &eng.cluster,
        );
        eng.seq = RUNTIME_BASE;
        eng.streaming = true;
        eng.drained = false;
        eng
    }

    /// Register a streamed job: validate the source contract, assign the
    /// next id, build runtime state, grow the per-job side tables. Returns
    /// the id and arrival time for the arrival event.
    fn add_job(&mut self, mut spec: JobSpec) -> Result<(usize, f64)> {
        if !spec.arrival.is_finite() {
            bail!("job source yielded a non-finite arrival time {}", spec.arrival);
        }
        if spec.arrival < self.last_arrival {
            bail!(
                "job source violated its ordering contract: arrival {} after {}",
                spec.arrival,
                self.last_arrival
            );
        }
        self.last_arrival = spec.arrival;
        let id = self.jobs.len();
        debug_assert!((id as u64) < RUNTIME_BASE, "job-id seq domain exhausted");
        spec.id = id;
        let arrival = spec.arrival;
        let peak = self.cfg.cluster.gpu_peak_gflops;
        let m = crate::model::PerfModel::for_model(spec.model);
        let b = spec.model.spec().batch_size;
        self.jobs.push(JobRt {
            t_fwd: m.t_fwd(b, peak),
            t_bwd: m.t_bwd(b, peak),
            spec,
            gpus: Vec::new(),
            links: Vec::new(),
            multi_server: false,
            t_comm_free: 0.0,
            iters_done: 0,
            bwd_remaining: 0,
            comm_pending: false,
            load_per_iter: 0.0,
            load_total: 0.0,
            placed_seq: 0,
            ff: None,
            ff_version: 0,
            run_epoch: 0,
            inflight_compute: 0,
            restarts: 0,
            pending_restart: false,
            warmup_pending: false,
        });
        self.place_stamp.push(u64::MAX);
        self.running_multi_pos.push(usize::MAX);
        self.ff_pos.push(usize::MAX);
        self.unfinished += 1;
        Ok((id, arrival))
    }

    /// Streaming mode: pull the next job from the source and schedule its
    /// arrival. Called once at run start and once per processed arrival,
    /// so the heap holds at most one pending arrival at any time.
    fn pull_next(&mut self, source: &mut Option<&mut dyn JobSource>) -> Result<()> {
        if !self.streaming {
            return Ok(());
        }
        let Some(src) = source.as_mut() else {
            bail!("streaming simulation advanced without its job source");
        };
        match src.next_job()? {
            Some(spec) => {
                let (id, arrival) = self.add_job(spec)?;
                // Arrival events live in the job-index seq domain (below
                // RUNTIME_BASE) — matching the batch path's pre-seeded
                // `seq = i` pushes, not the runtime counter.
                self.heap.push(Timed { t: arrival, seq: id as u64, ev: Ev::Arrive { job: id } });
            }
            None => self.drained = true,
        }
        Ok(())
    }

    fn push(&mut self, t: f64, ev: Ev) {
        self.seq += 1;
        self.heap.push(Timed { t, seq: self.seq, ev });
    }

    /// Push an epoch-stamped compute completion and count it as in
    /// flight (the preemption-staleness bookkeeping; see [`Ev`]).
    fn push_compute(&mut self, t: f64, gpu: GpuId, job: usize, phase: Phase) {
        let epoch = self.jobs[job].run_epoch;
        self.jobs[job].inflight_compute += 1;
        self.push(t, Ev::ComputeDone { gpu, job, phase, epoch });
    }

    /// Schedule the next unprocessed fault timeline entry, if any. The
    /// timeline is consumed one event at a time — an empty plan never
    /// touches the heap, which is what keeps a zero-fault run
    /// bit-identical to a fault-less build (seq numbers included).
    fn push_next_fault(&mut self) {
        if let Some(&(t, _)) = self.cfg.faults.events.get(self.fault_idx) {
            let idx = self.fault_idx;
            self.push(t, Ev::Fault { idx });
        }
    }

    /// Run the event loop until the next decision point or completion.
    ///
    /// This is the old run-to-completion loop inverted: instead of
    /// consulting the placer/policy inline, pausable work is queued as
    /// micro-ops ([`Op`] — a LIFO stack replaying the old call nesting
    /// exactly) and the loop returns [`Step::Decision`] whenever an op
    /// reaches a placement candidate, an admission gate or a coalescing
    /// probe. The caller answers with [`SimState::resolve`] and calls
    /// `advance` again. Calling `advance` with a decision still pending
    /// (or after completion) is idempotent.
    ///
    /// `source` must be `Some` — the *same* source across calls — for a
    /// state built by [`SimState::new_streaming`]; batch mode ignores it.
    pub fn advance(
        &mut self,
        obs: &mut [&mut dyn SimObserver],
        mut source: Option<&mut dyn JobSource>,
    ) -> Result<Step> {
        if self.paused.is_some() {
            return Ok(Step::Decision(self.decision()));
        }
        if self.finished {
            return Ok(Step::Done(RunStats { n_events: self.n_events, t_end: self.t_end }));
        }
        if !self.primed {
            // Streaming mode: prime the first arrival (no-op in batch
            // mode), then the first fault timeline entry.
            self.primed = true;
            self.pull_next(&mut source)?;
            self.push_next_fault();
        }
        loop {
            // Drain deferred work from the last dispatched event first —
            // it may pause at a decision point mid-drain.
            while let Some(op) = self.ops.pop() {
                self.run_op(op, obs);
                if self.paused.is_some() {
                    return Ok(Step::Decision(self.decision()));
                }
            }
            // Compaction runs where the old loop ran it: after an event's
            // nested work completed, before the next pop. The stale arms
            // `continue` past arming it, exactly as they skipped the old
            // end-of-iteration check.
            if self.compact_pending {
                self.compact_pending = false;
                let stale = self.heap_stale;
                if stale >= STALE_COMPACT_MIN && stale * 2 >= self.heap.len() {
                    self.compact_heap();
                }
            }
            let Some(Timed { t, ev, .. }) = self.heap.pop() else {
                break;
            };
            if self.unfinished == 0 && self.drained {
                break;
            }
            self.t_end = t;
            self.n_events += 1;
            if self.n_events % 1_000_000 == 0 && self.debug {
                eprintln!(
                    "[sim] ev={}M t={:.1} heap={} active={} pending={} queue={} unfinished={}",
                    self.n_events / 1_000_000,
                    t,
                    self.heap.len(),
                    self.active_comms.len(),
                    self.pending_comm.len(),
                    self.queue.len(),
                    self.unfinished
                );
            }
            match ev {
                Ev::Arrive { job } => {
                    // Streaming: replace the consumed pending arrival
                    // before processing, so same-timestamp arrivals keep
                    // the batch path's pop order.
                    self.pull_next(&mut source)?;
                    self.arrived += 1;
                    emit(&mut *obs, SimEvent::JobArrived { t, job });
                    let key = self.queue_key(job);
                    self.queue.insert(key, job);
                    self.queue_eligible += 1;
                    self.ops.push(Op::PlacePass { t, interrupter: None });
                }
                Ev::ComputeDone { gpu, job, phase, epoch } => {
                    if self.jobs[job].run_epoch != epoch {
                        // The task was cancelled by a preemption.
                        debug_assert!(self.heap_stale > 0, "stale-entry counter underflow");
                        self.heap_stale = self.heap_stale.saturating_sub(1);
                        continue;
                    }
                    self.jobs[job].inflight_compute -= 1;
                    self.on_compute_done(t, gpu, job, phase);
                }
                Ev::CommDone { comm, version } => {
                    if self.comms[comm].done || self.comms[comm].version != version {
                        // Stale prediction (superseded by a repricing or
                        // outlived by its task's completion).
                        debug_assert!(self.heap_stale > 0, "stale-entry counter underflow");
                        self.heap_stale = self.heap_stale.saturating_sub(1);
                        continue;
                    }
                    // The live prediction is consumed by this pop.
                    self.comms[comm].predicted = false;
                    // Completion test in the *time* domain: once the
                    // residual drain time falls below one ulp of the clock,
                    // a repredicted event can land exactly at `t` forever
                    // (observed livelock); treat sub-ulp residue as done.
                    let (lat_left, rem) = self.residual_at(comm, t);
                    let residual = lat_left + rem * self.comms[comm].per_byte;
                    let eps_t = EPS + t.abs() * 1e-12;
                    if residual > eps_t {
                        self.repredict(t, comm);
                        continue;
                    }
                    let job = self.complete_comm_flat(t, comm, obs);
                    // Queued in reverse of the old `complete_comm` tail:
                    // iteration credit, then the admission pass, then a
                    // placement pass iff the credit finished the job.
                    self.ops.push(Op::PlaceIfNeeded { t, interrupter: Some(job) });
                    self.ops.push(Op::AdmitPass { t });
                    self.ops.push(Op::IterComplete { t, job });
                }
                Ev::FastForward { job, version } => {
                    if self.jobs[job].ff_version != version {
                        // Macro-event dissolved by reconciliation.
                        debug_assert!(self.heap_stale > 0, "stale-entry counter underflow");
                        self.heap_stale = self.heap_stale.saturating_sub(1);
                        continue;
                    }
                    self.complete_fast_forward(t, job, obs);
                    self.ops.push(Op::PlaceIfNeeded { t, interrupter: Some(job) });
                }
                Ev::Fault { idx } => {
                    let (_, fault) = self.cfg.faults.events[idx];
                    self.fault_idx = idx + 1;
                    self.push_next_fault();
                    // Preemptions free memory and recoveries restore
                    // capacity — either way queued jobs deserve a pass.
                    // Pushed *before* `process_fault` so the admission
                    // pass a link recovery queues pops first, as the old
                    // inline order had it.
                    self.ops.push(Op::PlaceIfNeeded { t, interrupter: None });
                    self.process_fault(t, fault, obs);
                }
                Ev::Warmup { job, epoch } => {
                    if self.jobs[job].run_epoch != epoch {
                        // A second preemption cancelled the warmup.
                        debug_assert!(self.heap_stale > 0, "stale-entry counter underflow");
                        self.heap_stale = self.heap_stale.saturating_sub(1);
                        continue;
                    }
                    self.jobs[job].warmup_pending = false;
                    // Dispatch never pauses inline — queue the iteration
                    // start (it may reach the coalescing probe).
                    self.ops.push(Op::StartIteration { t, job });
                }
                Ev::Retry { job, epoch } => {
                    if self.jobs[job].run_epoch != epoch {
                        // A job waiting out its backoff holds no GPUs, so
                        // nothing should bump its epoch.
                        debug_assert!(false, "stale backoff retry for job {job}");
                        continue;
                    }
                    let key = self.queue_key(job);
                    self.queue.insert(key, job);
                    // The job sat out release generations; mark it
                    // always-eligible so the next pass consults a placer.
                    self.place_stamp[job] = u64::MAX;
                    self.queue_eligible += 1;
                    self.need_place = true;
                    self.ops.push(Op::PlaceIfNeeded { t, interrupter: None });
                }
                Ev::Unblacklist { gpu } => {
                    self.on_unblacklist(t, gpu, obs);
                }
            }
            self.compact_pending = true;
        }
        self.finished = true;
        let stats = RunStats { n_events: self.n_events, t_end: self.t_end };
        for o in obs.iter_mut() {
            o.on_end(&stats);
        }
        Ok(Step::Done(stats))
    }

    /// Execute one queued micro-op. Ops are the only place `paused` can
    /// be set — event dispatch itself never pauses.
    fn run_op(&mut self, op: Op, obs: &mut [&mut dyn SimObserver]) {
        match op {
            Op::IterComplete { t, job } => self.op_iteration_complete(t, job, obs),
            Op::StartIteration { t, job } => self.op_start_iteration(t, job, obs),
            Op::AdmitPass { t } => self.op_admit_pass(t),
            Op::ScheduleGpu { t, gpu } => self.schedule_gpu(t, gpu, obs),
            Op::PlacePass { t, interrupter } => self.op_place_pass(t, interrupter, obs),
            Op::PlaceIfNeeded { t, interrupter } => {
                if self.need_place {
                    self.need_place = false;
                    self.op_place_pass(t, interrupter, obs);
                }
            }
        }
    }

    /// The pending decision point (`paused` must be set).
    fn decision(&self) -> DecisionPoint {
        match self.paused.as_ref().expect("no pending decision") {
            Paused::Place { t, entries, idx, .. } => {
                DecisionPoint::Place { t: *t, job: entries[*idx].1 }
            }
            Paused::Admit { t, order, idx } => DecisionPoint::Admit { t: *t, job: order[*idx] },
            Paused::Ff { t, job } => DecisionPoint::FfProbe { t: *t, job: *job },
        }
    }

    /// The pending decision point, if the engine is paused at one.
    pub fn pending(&self) -> Option<DecisionPoint> {
        self.paused.as_ref().map(|_| self.decision())
    }

    /// Apply an external decision to the pending decision point and let
    /// the paused pass continue — it may immediately pause at its next
    /// candidate, so call [`SimState::advance`] to find out. A mismatched
    /// action kind or an invalid placement is rejected *without*
    /// consuming the decision, so a driver can retry.
    pub fn resolve(&mut self, action: Action, obs: &mut [&mut dyn SimObserver]) -> Result<()> {
        let Some(paused) = self.paused.take() else {
            bail!("resolve called with no pending decision");
        };
        match paused {
            Paused::Place { t, entries, idx, mut kept } => {
                let (key, job) = entries[idx];
                let Action::Place(choice) = action else {
                    self.paused = Some(Paused::Place { t, entries, idx, kept });
                    bail!("pending decision is a placement; got an admission action");
                };
                match choice {
                    Some(gpus) => {
                        if let Err(e) = self.validate_placement(job, &gpus) {
                            self.paused = Some(Paused::Place { t, entries, idx, kept });
                            return Err(e);
                        }
                        self.queue_eligible -= 1;
                        self.commit_placement(t, job, gpus, obs);
                    }
                    None => {
                        self.place_stamp[job] = self.release_gen;
                        self.queue_eligible -= 1;
                        kept.push((key, job));
                    }
                }
                self.place_cont(t, entries, idx + 1, kept);
            }
            Paused::Admit { t, order, idx } => {
                let job = order[idx];
                let Action::Admit(admission) = action else {
                    self.paused = Some(Paused::Admit { t, order, idx });
                    bail!("pending decision is an admission; got a placement action");
                };
                match admission {
                    Admission::Start => self.admit_start(t, job, obs),
                    Admission::Wait => self.pending_comm.push(job),
                }
                self.admit_cont(t, order, idx + 1);
            }
            Paused::Ff { t, job } => {
                let Action::Admit(admission) = action else {
                    self.paused = Some(Paused::Ff { t, job });
                    bail!("pending decision is an admission probe; got a placement action");
                };
                match admission {
                    Admission::Start => self.ff_commit(t, job, obs),
                    Admission::Wait => self.start_iteration_exact(t, job, obs),
                }
            }
        }
        Ok(())
    }

    /// Answer a decision point the way the monolithic engine did: ask the
    /// placer for placements, the admission policy — over the same lazy
    /// [`NetView`] — for admissions and coalescing probes. [`drive`] plus
    /// this method is the single code path behind [`simulate_observed`],
    /// which is what pins env-driven builtin-agent runs bit-identical to
    /// the facades.
    pub fn decide_builtin(
        &self,
        d: &DecisionPoint,
        placer: &mut dyn Placer,
        policy: &dyn CommPolicy,
    ) -> Action {
        match *d {
            DecisionPoint::Place { job, .. } => Action::Place(placer.place_with_health(
                &self.jobs[job].spec,
                &self.cluster,
                &self.health,
            )),
            DecisionPoint::Admit { t, job } => {
                let msg = self.jobs[job].spec.message_bytes();
                let remaining = |c: usize| self.residual_at(c, t).1;
                let net = NetView::new(&self.per_link, &remaining);
                Action::Admit(policy.admit(msg, &self.jobs[job].links, &net))
            }
            DecisionPoint::FfProbe { job, .. } => {
                // The per-iteration admission decision on (provably) idle
                // links: builtin policies see the always-empty view, so
                // this is the same pure call the old steadiness check made.
                let msg = self.jobs[job].spec.message_bytes();
                let view = NetView::occupancy_only(&self.empty_view);
                Action::Admit(policy.admit(msg, &self.jobs[job].links, &view))
            }
        }
    }

    /// Sanity-check an externally supplied placement: right GPU count, no
    /// duplicates, every GPU exists and fits the job's memory demand. A
    /// down GPU's free memory is held at zero (see `on_gpu_failed`), so
    /// the fit test covers health too.
    fn validate_placement(&self, job: usize, gpus: &[GpuId]) -> Result<()> {
        let spec = &self.jobs[job].spec;
        if gpus.len() != spec.n_gpus {
            bail!("placement for job {} names {} GPUs, not {}", job, gpus.len(), spec.n_gpus);
        }
        let mem = spec.mem_bytes();
        for (i, &g) in gpus.iter().enumerate() {
            if g >= self.cluster.gpus.len() {
                bail!("placement for job {job} names GPU {g}, which does not exist");
            }
            if gpus[..i].contains(&g) {
                bail!("placement for job {job} names GPU {g} twice");
            }
            if !self.cluster.fits(g, mem) {
                bail!("placement for job {job} names GPU {g}, which cannot fit it");
            }
        }
        Ok(())
    }

    /// Snapshot the full deterministic simulation state. Everything the
    /// event loop reads lives in `self` — observers and the streaming job
    /// source are external, passed to each [`SimState::advance`] call —
    /// so a deep clone is a complete checkpoint.
    pub fn save(&self) -> SimState {
        self.clone()
    }

    /// Rewind to a snapshot taken by [`SimState::save`].
    pub fn restore(&mut self, snap: &SimState) {
        *self = snap.clone();
    }

    // -- read-only state (observation surface) --------------------------------

    /// Current simulation clock: the last processed event's timestamp.
    pub fn now(&self) -> f64 {
        self.t_end
    }

    /// True once the event loop has run to completion.
    pub fn is_done(&self) -> bool {
        self.finished
    }

    /// Events processed so far.
    pub fn events_processed(&self) -> u64 {
        self.n_events
    }

    /// Jobs waiting for placement.
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Jobs with a ready-but-unadmitted All-Reduce.
    pub fn pending_comms(&self) -> usize {
        self.pending_comm.len()
    }

    /// Arrivals processed so far.
    pub fn arrived_jobs(&self) -> u64 {
        self.arrived
    }

    /// Jobs finished so far.
    pub fn finished_jobs(&self) -> u64 {
        self.done_jobs
    }

    /// Jobs arrived and not yet finished.
    pub fn jobs_in_system(&self) -> u64 {
        self.arrived - self.done_jobs
    }

    /// Fabric links in the topology.
    pub fn n_links(&self) -> usize {
        self.per_link.n_links()
    }

    /// Active transfers crossing link `l`.
    pub fn link_occupancy(&self, l: LinkId) -> usize {
        self.per_link.len(l)
    }

    /// GPUs currently up.
    pub fn gpus_up(&self) -> usize {
        self.health.n_gpus_up()
    }

    /// Links currently up.
    pub fn links_up(&self) -> usize {
        self.health.n_links_up()
    }

    /// Mean gray-failure health factor over every GPU and link
    /// (1.0 = fully healthy fleet; a down device contributes 0.0).
    pub fn mean_health(&self) -> f64 {
        self.health.mean_health()
    }

    /// Free-GPU counts per registered memory demand: `(mem_bytes, count)`
    /// rows from the live capacity index.
    pub fn free_gpu_histogram(&self) -> Vec<(f64, usize)> {
        self.capacity.histogram()
    }

    /// A job's immutable spec.
    pub fn job_spec(&self, job: usize) -> &JobSpec {
        &self.jobs[job].spec
    }

    /// Fabric links a job's All-Reduce crosses (empty before placement).
    pub fn job_links(&self, job: usize) -> &[LinkId] {
        &self.jobs[job].links
    }

    /// Iterations a job still has to run.
    pub fn iters_left(&self, job: usize) -> u64 {
        self.jobs[job].spec.iterations - self.jobs[job].iters_done
    }

    /// The live cluster state (what placers read).
    pub fn cluster(&self) -> &ClusterState {
        &self.cluster
    }

    /// The run's configuration.
    pub fn config(&self) -> &SimConfig {
        &self.cfg
    }

    // -- priorities -----------------------------------------------------------

    /// Priority key for a *running* job (smaller = served first). SRSF
    /// and LAS read the job's cached `t_comm_free` (fixed at placement)
    /// instead of re-deriving `time_free(message_bytes)` inside every
    /// comparison of every scheduling burst.
    fn run_key(&self, job: usize) -> f64 {
        let j = &self.jobs[job];
        match self.cfg.priority {
            JobPriority::Srsf => j.remaining_service(),
            JobPriority::Fifo => j.spec.arrival,
            JobPriority::Las => {
                j.iters_done as f64 * (j.t_fwd + j.t_bwd + j.t_comm_free) * j.spec.n_gpus as f64
            }
        }
    }

    /// Priority key for a *queued* job (E_J = 0: communication unknown
    /// before placement, §IV-A "Job Priority").
    fn queue_key(&self, job: usize) -> f64 {
        let j = &self.jobs[job];
        match self.cfg.priority {
            JobPriority::Srsf => j.queued_service(self.cfg.cluster.gpu_peak_gflops),
            JobPriority::Fifo => j.spec.arrival,
            JobPriority::Las => 0.0, // no service attained yet: FIFO by id
        }
    }

    // -- placement ----------------------------------------------------------

    /// One placement pass. `interrupter` is the job whose finish
    /// triggered it (`None` for arrivals) — the tie-break reconciliation
    /// needs when a macro-event boundary coincides bit-exactly with this
    /// timestamp. Each job the capacity gate cannot prove hopeless is a
    /// placement *decision point*: the walk pauses there and
    /// [`SimState::resolve`] (or the builtin placer via
    /// [`SimState::decide_builtin`]) supplies the GPU set (or a decline)
    /// before `place_cont` resumes.
    fn op_place_pass(
        &mut self,
        t: f64,
        interrupter: Option<usize>,
        obs: &mut [&mut dyn SimObserver],
    ) {
        // Every queued job already failed at the current release
        // generation → free memory can only have shrunk since, so the
        // placer would return None for all of them. The pass — including
        // macro-event reconciliation, which only exists to give the
        // placer exact state to read — is a provable no-op.
        if self.queue.is_empty() || self.queue_eligible == 0 {
            return;
        }
        // The placer is about to read per-GPU load/residency, and may put
        // a newcomer on a fast-forwarded job's GPUs: fold every
        // macro-event's progress back into real state first. (This is the
        // single invalidation point — everything that can perturb a
        // steady job goes through a placement pass; see `ff_ready` for
        // why admissions can't touch one.)
        self.reconcile_all_ffs(t, interrupter, obs);
        // A macro-event that ran to completion during reconciliation
        // finished its job through `finish_job`, which raises
        // `need_place` — but this very pass is the placement attempt that
        // flag requests. Consume it now instead of leaking a spurious
        // extra pass to the next unrelated event.
        self.need_place = false;
        // Walk the incrementally maintained priority order (no re-sort:
        // queue keys are static — see `queue_key`), calling the placer
        // only for jobs the release-generation stamp and the capacity
        // index cannot prove hopeless. Dropping placed entries while
        // walking keeps the remainder sorted for `restore`.
        let entries = self.queue.take_all();
        let kept: Vec<(f64, usize)> = Vec::with_capacity(entries.len());
        self.place_cont(t, entries, 0, kept);
    }

    /// Resume the placement walk at `entries[idx]`, pausing at the next
    /// decision point — a job the capacity gate cannot prove hopeless, so
    /// a placer must be consulted. `kept` carries the entries to restore
    /// to the queue, still in sorted order.
    fn place_cont(
        &mut self,
        t: f64,
        entries: Vec<(f64, usize)>,
        mut idx: usize,
        mut kept: Vec<(f64, usize)>,
    ) {
        while idx < entries.len() {
            let (key, job) = entries[idx];
            debug_assert_eq!(
                key.to_bits(),
                self.queue_key(job).to_bits(),
                "static queue key drifted for job {job}"
            );
            if self.place_stamp[job] == self.release_gen {
                // Failed already at this generation; nothing has been
                // released since.
                kept.push((key, job));
                idx += 1;
                continue;
            }
            let n_gpus = self.jobs[job].spec.n_gpus;
            if self.capacity.feasible(self.jobs[job].spec.mem_bytes()) < n_gpus {
                // Fewer feasible GPUs than the job needs: any
                // contract-abiding placer returns None.
                self.place_stamp[job] = self.release_gen;
                self.queue_eligible -= 1;
                kept.push((key, job));
                idx += 1;
                continue;
            }
            self.paused = Some(Paused::Place { t, entries, idx, kept });
            return;
        }
        self.queue.restore(kept);
    }

    fn commit_placement(
        &mut self,
        t: f64,
        job: usize,
        gpus: Vec<GpuId>,
        obs: &mut [&mut dyn SimObserver],
    ) {
        let servers = self.cfg.cluster.servers_of(&gpus);
        let links = self.topo.links_between(&servers);
        let multi = servers.len() > 1;
        // Algorithm 1 bookkeeping: L_J = (C_J + E_J) · |G(J)| added to each
        // chosen GPU, drained as iterations complete.
        let c_j = self.jobs[job].spec.compute_total(self.cfg.cluster.gpu_peak_gflops);
        let e_j = self.jobs[job]
            .spec
            .comm_total(servers.len(), &self.cfg.comm);
        let full = (c_j + e_j) * gpus.len() as f64;
        // A restarted job resumes from its checkpoint: only the remaining
        // iterations' load is committed. The fresh-placement arm keeps the
        // original expression so fault-less runs stay bit-identical.
        let done = self.jobs[job].iters_done;
        let (load, load_per_iter) = if done == 0 {
            (full, full / self.jobs[job].spec.iterations as f64)
        } else {
            let per = full / self.jobs[job].spec.iterations as f64;
            (per * (self.jobs[job].spec.iterations - done) as f64, per)
        };
        let mem = self.jobs[job].spec.mem_bytes();
        let mut frees = std::mem::take(&mut self.scratch_free);
        frees.clear();
        frees.extend(gpus.iter().map(|&g| self.cluster.free_mem(g)));
        self.cluster.allocate(&gpus, mem, load);
        for (i, &g) in gpus.iter().enumerate() {
            self.capacity.record(frees[i], self.cluster.free_mem(g));
        }
        self.scratch_free = frees;
        self.placements += 1;
        let t_comm_free = if multi {
            self.cfg.comm.time_free(self.jobs[job].spec.message_bytes())
        } else {
            0.0
        };
        {
            let j = &mut self.jobs[job];
            j.load_total = load;
            j.load_per_iter = load_per_iter;
            j.gpus = gpus;
            j.links = links;
            j.multi_server = multi;
            j.t_comm_free = t_comm_free;
            j.placed_seq = self.placements;
        }
        if self.has_gpu_degrade {
            // The chosen GPUs may be slowed right now — or the job may
            // carry durations scaled for its *previous* placement's
            // factors: re-derive them from the live health view. No
            // in-flight compute exists at commit time, so this only
            // rewrites `t_fwd`/`t_bwd`.
            self.rebase_job_speed(t, job);
        }
        if multi {
            self.running_multi_pos[job] = self.running_multi.len();
            self.running_multi.push(job);
        }
        emit(
            &mut *obs,
            SimEvent::JobPlaced {
                t,
                job,
                gpus: &self.jobs[job].gpus,
                links: &self.jobs[job].links,
                multi_server: multi,
            },
        );
        if self.jobs[job].pending_restart {
            self.jobs[job].pending_restart = false;
            emit(
                &mut *obs,
                SimEvent::JobRestarted { t, job, restarts: self.jobs[job].restarts },
            );
            // Restart pays the warmup cost before iterating: the GPUs sit
            // allocated-but-idle until the `Warmup` event fires.
            let warmup = self.cfg.faults.warmup_s;
            if warmup > 0.0 {
                self.jobs[job].warmup_pending = true;
                let epoch = self.jobs[job].run_epoch;
                self.push(t + warmup, Ev::Warmup { job, epoch });
                return;
            }
        }
        // The first iteration always runs event-exact (no macro-event):
        // we are inside a placement pass, and a *later* placement in this
        // same pass could still land on these GPUs. Steadiness is
        // re-checked at every subsequent iteration boundary.
        self.start_iteration_exact(t, job, obs);
    }

    // -- compute ------------------------------------------------------------

    /// Begin `job`'s next iteration. With coalescing on and the job
    /// provably steady this is a decision point: single-server jobs
    /// fast-forward unconditionally (no admission involved, exactly the
    /// old behaviour), multi-server jobs pause at the admission probe
    /// ([`DecisionPoint::FfProbe`]) whose Start commits the macro-event.
    fn op_start_iteration(&mut self, t: f64, job: usize, obs: &mut [&mut dyn SimObserver]) {
        if self.cfg.coalescing && self.ff_ready(job) {
            if !self.jobs[job].multi_server {
                self.ff_commit(t, job, obs);
                return;
            }
            if self.ff_multi_ready(job) {
                self.paused = Some(Paused::Ff { t, job });
                return;
            }
        }
        self.start_iteration_exact(t, job, obs);
    }

    fn start_iteration_exact(&mut self, t: f64, job: usize, obs: &mut [&mut dyn SimObserver]) {
        // Borrow the GPU set by take/restore instead of the per-iteration
        // clone this replaced — the engine's #1 steady-state allocation
        // site (`schedule_gpu` never touches `JobRt::gpus`).
        let gpus = std::mem::take(&mut self.jobs[job].gpus);
        self.jobs[job].bwd_remaining = gpus.len();
        for &g in &gpus {
            self.gpus[g].ready.push((job, Phase::Fwd));
            self.schedule_gpu(t, g, obs);
        }
        self.jobs[job].gpus = gpus;
    }

    fn schedule_gpu(&mut self, t: f64, gpu: GpuId, obs: &mut [&mut dyn SimObserver]) {
        if self.gpus[gpu].busy || self.gpus[gpu].ready.is_empty() {
            return;
        }
        if !self.health.gpu_up(gpu) {
            // Defense in depth: a failed GPU's residents are preempted
            // and its ready set cleared, so this should be unreachable.
            debug_assert!(false, "scheduling on a failed GPU");
            return;
        }
        // Priority rule among the compute-ready tasks resident on this
        // GPU. Keys are computed once per candidate — deriving them
        // inside every `min` comparison cost O(ready²) evaluations per
        // scheduling burst under SRSF/LAS — and the one-candidate case
        // (the common one) skips key derivation entirely.
        let n_ready = self.gpus[gpu].ready.len();
        let best = if n_ready == 1 {
            0
        } else {
            let mut keys = std::mem::take(&mut self.scratch_keys);
            keys.clear();
            for &(job, _) in &self.gpus[gpu].ready {
                keys.push((self.run_key(job), job));
            }
            let mut best = 0;
            for (i, &key) in keys.iter().enumerate().skip(1) {
                if srsf_cmp(key, keys[best]) == Ordering::Less {
                    best = i;
                }
            }
            self.scratch_keys = keys;
            best
        };
        let (job, phase) = self.gpus[gpu].ready.swap_remove(best);
        let dur = match phase {
            Phase::Fwd => self.jobs[job].t_fwd,
            Phase::Bwd => self.jobs[job].t_bwd,
        };
        let done_at = t + dur;
        self.gpus[gpu].busy = true;
        self.gpus[gpu].running = job;
        self.gpus[gpu].done_at = done_at;
        self.gpus[gpu].phase = phase;
        emit(&mut *obs, SimEvent::ComputeStarted { t, gpu, job, phase, dur });
        self.push_compute(done_at, gpu, job, phase);
    }

    fn on_compute_done(&mut self, t: f64, gpu: GpuId, job: usize, phase: Phase) {
        self.gpus[gpu].busy = false;
        // Queued in reverse (the op stack is LIFO): the phase op — pushed
        // last, inside the match — runs first, then the GPU's next task,
        // then, exactly where the old event loop re-attempted placement
        // after this handler returned, a pass iff a finish raised
        // `need_place` (feasibility only changes when memory frees;
        // re-attempting on every compute event would dominate the run).
        self.ops.push(Op::PlaceIfNeeded { t, interrupter: Some(job) });
        self.ops.push(Op::ScheduleGpu { t, gpu });
        match phase {
            Phase::Fwd => {
                // Backward on the same worker immediately becomes ready.
                self.gpus[gpu].ready.push((job, Phase::Bwd));
            }
            Phase::Bwd => {
                self.jobs[job].bwd_remaining -= 1;
                if self.jobs[job].bwd_remaining == 0 {
                    if self.jobs[job].multi_server {
                        self.jobs[job].comm_pending = true;
                        self.pending_comm.push(job);
                        self.ops.push(Op::AdmitPass { t });
                    } else {
                        self.ops.push(Op::IterComplete { t, job });
                    }
                }
            }
        }
    }

    fn op_iteration_complete(&mut self, t: f64, job: usize, obs: &mut [&mut dyn SimObserver]) {
        self.jobs[job].iters_done += 1;
        let gpus = std::mem::take(&mut self.jobs[job].gpus);
        self.cluster.drain_load(&gpus, self.jobs[job].load_per_iter);
        if self.jobs[job].iters_done >= self.jobs[job].spec.iterations {
            self.finish_job(t, job, &gpus, obs);
        } else {
            self.jobs[job].gpus = gpus;
            self.op_start_iteration(t, job, obs);
        }
    }

    /// Final-iteration bookkeeping, shared by the event-exact path and
    /// macro-event completion: release memory, free the GPUs, let queued
    /// jobs try to place.
    fn finish_job(
        &mut self,
        t: f64,
        job: usize,
        gpus: &[GpuId],
        obs: &mut [&mut dyn SimObserver],
    ) {
        self.unfinished -= 1;
        self.done_jobs += 1;
        if self.jobs[job].multi_server {
            let pos = self.running_multi_pos[job];
            self.running_multi.swap_remove(pos);
            if let Some(&moved) = self.running_multi.get(pos) {
                self.running_multi_pos[moved] = pos;
            }
            self.running_multi_pos[job] = usize::MAX;
        }
        let mem = self.jobs[job].spec.mem_bytes();
        let mut frees = std::mem::take(&mut self.scratch_free);
        frees.clear();
        frees.extend(gpus.iter().map(|&g| self.cluster.free_mem(g)));
        self.cluster.release(gpus, mem, 0.0);
        for (i, &g) in gpus.iter().enumerate() {
            self.capacity.record(frees[i], self.cluster.free_mem(g));
        }
        self.scratch_free = frees;
        // Memory freed: every queued job is worth a fresh placement
        // attempt at the next pass.
        self.release_gen += 1;
        self.queue_eligible = self.queue.len();
        self.need_place = true;
        emit(&mut *obs, SimEvent::JobFinished { t, job });
        // A finished job is never scheduled, priced or placed again:
        // drop its heap-allocated placement state so a streamed run's
        // per-finished-job footprint is the flat JobRt alone.
        self.jobs[job].gpus = Vec::new();
        self.jobs[job].links = Vec::new();
    }

    // -- faults ---------------------------------------------------------------

    fn process_fault(&mut self, t: f64, fault: PrimFault, obs: &mut [&mut dyn SimObserver]) {
        match fault {
            PrimFault::GpuFail(g) => self.on_gpu_failed(t, g, obs),
            PrimFault::GpuRecover(g) => self.on_gpu_recovered(t, g, obs),
            PrimFault::LinkFail(l) => self.on_link_failed(t, l, obs),
            PrimFault::LinkRecover(l) => self.on_link_recovered(t, l, obs),
            PrimFault::GpuSlow(g, f) => self.on_gpu_slowed(t, g, f, obs),
            PrimFault::GpuRestore(g) => self.on_gpu_restored(t, g, obs),
            PrimFault::LinkDegrade(l, f) => self.on_link_degraded(t, l, f, obs),
            PrimFault::LinkRestore(l) => self.on_link_restored(t, l, obs),
        }
    }

    /// A GPU died: preempt every resident job, then hold the GPU's free
    /// memory at zero so every placer's `fits` test fails while it is
    /// down (placers stay health-oblivious; the capacity index sees the
    /// same transition, so its gate stays exact).
    fn on_gpu_failed(&mut self, t: f64, g: GpuId, obs: &mut [&mut dyn SimObserver]) {
        if !self.health.gpu_up(g) {
            return; // scenario timelines may repeat a failure; idempotent
        }
        // A fault is an interaction steadiness never accounted for: fold
        // every macro-event back to exact state before inspecting victims.
        self.reconcile_all_ffs(t, None, obs);
        self.health.set_gpu(g, false);
        emit(&mut *obs, SimEvent::GpuFailed { t, gpu: g });
        if let Some(bl) = &mut self.blacklist {
            bl.record_failure(g, t);
        }
        let victims: Vec<usize> =
            (0..self.jobs.len()).filter(|&j| self.jobs[j].gpus.contains(&g)).collect();
        for job in victims {
            self.preempt_job(t, job, obs);
        }
        // Hold after preemption: the victims' releases restored their
        // memory to `g` first, so the hold freezes the whole capacity.
        // `+=`: a blacklisted GPU (up, hold kept) can fail again, and
        // overwriting would leak the original hold.
        let before = self.cluster.free_mem(g);
        let held = self.cluster.hold_all(g);
        self.health_hold[g] += held;
        self.capacity.record(before, self.cluster.free_mem(g));
    }

    /// A GPU came back: restore its held memory and let queued jobs try
    /// to place on it — unless its failure window holds `blacklist_k`
    /// failures, in which case the device comes back *up* but stays
    /// excluded (the memory hold is kept) until the window drains.
    fn on_gpu_recovered(&mut self, t: f64, g: GpuId, obs: &mut [&mut dyn SimObserver]) {
        if self.health.gpu_up(g) {
            return;
        }
        self.health.set_gpu(g, true);
        let (was_active, until) = match &mut self.blacklist {
            Some(bl) => {
                let active = bl.is_active(g);
                let until =
                    if bl.over_threshold(g, t) { Some(bl.expiry(g, t)) } else { None };
                (active, until)
            }
            None => (false, None),
        };
        if let Some(until) = until {
            if let Some(bl) = &mut self.blacklist {
                bl.set_active(g, true);
            }
            emit(&mut *obs, SimEvent::GpuRecovered { t, gpu: g });
            if !was_active {
                emit(&mut *obs, SimEvent::GpuBlacklisted { t, gpu: g, until });
            }
            self.push(until, Ev::Unblacklist { gpu: g });
            return;
        }
        let before = self.cluster.free_mem(g);
        self.cluster.release_held(g, self.health_hold[g]);
        self.health_hold[g] = 0.0;
        self.capacity.record(before, self.cluster.free_mem(g));
        emit(&mut *obs, SimEvent::GpuRecovered { t, gpu: g });
        if was_active {
            // Window drained while the GPU was down: clear the marker.
            if let Some(bl) = &mut self.blacklist {
                bl.set_active(g, false);
            }
            emit(&mut *obs, SimEvent::GpuUnblacklisted { t, gpu: g });
        }
        self.release_gen += 1;
        self.queue_eligible = self.queue.len();
        self.need_place = true;
    }

    /// A blacklisted GPU's window expiry fired: re-check (the window may
    /// have been re-armed by later failures) and, if it really drained,
    /// release the hold and reopen the device for placement.
    fn on_unblacklist(&mut self, t: f64, g: GpuId, obs: &mut [&mut dyn SimObserver]) {
        let rearmed = match &mut self.blacklist {
            Some(bl) if bl.is_active(g) => {
                if bl.over_threshold(g, t) {
                    Some(bl.expiry(g, t))
                } else {
                    None
                }
            }
            _ => return, // stale: already released (or blacklisting off)
        };
        if !self.health.gpu_up(g) {
            // Failed again while blacklisted: the next recovery re-arms
            // the expiry; this event has nothing to release.
            return;
        }
        if let Some(until) = rearmed {
            self.push(until, Ev::Unblacklist { gpu: g });
            return;
        }
        if let Some(bl) = &mut self.blacklist {
            bl.set_active(g, false);
        }
        let before = self.cluster.free_mem(g);
        self.cluster.release_held(g, self.health_hold[g]);
        self.health_hold[g] = 0.0;
        self.capacity.record(before, self.cluster.free_mem(g));
        emit(&mut *obs, SimEvent::GpuUnblacklisted { t, gpu: g });
        self.release_gen += 1;
        self.queue_eligible = self.queue.len();
        self.need_place = true;
        self.ops.push(Op::PlaceIfNeeded { t, interrupter: None });
    }

    /// Preempt a running job with checkpoint/restart semantics: rewind to
    /// the last checkpoint (iterations since it are lost), cancel its
    /// in-flight compute and communication, release its GPUs and memory,
    /// and re-queue it for placement.
    fn preempt_job(&mut self, t: f64, job: usize, obs: &mut [&mut dyn SimObserver]) {
        debug_assert!(self.jobs[job].ff.is_none(), "preempting a live macro-event");
        let ckpt = self.cfg.faults.checkpoint_iters;
        let done = self.jobs[job].iters_done;
        let kept = if ckpt == 0 { 0 } else { done - done % ckpt };
        let lost = done - kept;
        // Cancel in-flight compute: clear this job's tasks from its GPUs'
        // ready sets and busy slots; the epoch bump strands every pushed
        // `ComputeDone` as stale.
        let gpus = std::mem::take(&mut self.jobs[job].gpus);
        for &g in &gpus {
            self.gpus[g].ready.retain(|&(j, _)| j != job);
            if self.gpus[g].busy && self.gpus[g].running == job {
                self.gpus[g].busy = false;
            }
        }
        self.heap_stale += self.jobs[job].inflight_compute;
        self.jobs[job].inflight_compute = 0;
        if self.jobs[job].warmup_pending {
            self.jobs[job].warmup_pending = false;
            self.heap_stale += 1; // its Warmup event goes stale
        }
        self.jobs[job].run_epoch += 1;
        // Abort communication, pending or in flight.
        if self.jobs[job].comm_pending {
            self.pending_comm.retain(|&j| j != job);
            self.jobs[job].comm_pending = false;
        }
        let active_comm =
            self.active_comms.iter().copied().find(|&c| self.comms[c].job == job);
        if let Some(id) = active_comm {
            self.abort_comm(t, id, obs);
        }
        // Release memory and the undrained share of the bookkeeping load
        // (the drained share left with the completed iterations).
        let mem = self.jobs[job].spec.mem_bytes();
        let undrained =
            self.jobs[job].load_per_iter * (self.jobs[job].spec.iterations - done) as f64;
        let mut frees = std::mem::take(&mut self.scratch_free);
        frees.clear();
        frees.extend(gpus.iter().map(|&g| self.cluster.free_mem(g)));
        self.cluster.release(&gpus, mem, undrained);
        for (i, &g) in gpus.iter().enumerate() {
            self.capacity.record(frees[i], self.cluster.free_mem(g));
        }
        self.scratch_free = frees;
        if self.jobs[job].multi_server {
            let pos = self.running_multi_pos[job];
            self.running_multi.swap_remove(pos);
            if let Some(&moved) = self.running_multi.get(pos) {
                self.running_multi_pos[moved] = pos;
            }
            self.running_multi_pos[job] = usize::MAX;
        }
        emit(&mut *obs, SimEvent::CheckpointTaken { t, job, iters: kept });
        emit(&mut *obs, SimEvent::JobPreempted { t, job, lost_iters: lost });
        // Reset to queued state, resuming from the checkpoint.
        {
            let j = &mut self.jobs[job];
            j.iters_done = kept;
            j.bwd_remaining = 0;
            j.multi_server = false;
            j.t_comm_free = 0.0;
            j.load_per_iter = 0.0;
            j.load_total = 0.0;
            j.links = Vec::new();
            j.pending_restart = true;
            j.restarts += 1;
        }
        let backoff = backoff_delay(
            self.cfg.faults.backoff_base_s,
            self.cfg.faults.backoff_cap_s,
            self.jobs[job].restarts,
        );
        if backoff > 0.0 {
            // Capped exponential restart backoff: the job sits out the
            // delay before re-entering the queue (`Ev::Retry` re-inserts
            // it). A zero base — the default — takes the immediate path.
            let until = t + backoff;
            let epoch = self.jobs[job].run_epoch;
            self.push(until, Ev::Retry { job, epoch });
            emit(&mut *obs, SimEvent::RestartDeferred { t, job, until });
        } else {
            let key = self.queue_key(job);
            self.queue.insert(key, job);
        }
        // Memory freed: every queued job is worth a fresh attempt.
        self.release_gen += 1;
        self.queue_eligible = self.queue.len();
        self.need_place = true;
        // Freed healthy GPUs may have other residents' tasks waiting.
        for &g in &gpus {
            if self.health.gpu_up(g) {
                self.schedule_gpu(t, g, obs);
            }
        }
    }

    /// Abort an in-flight transfer (its job is being preempted): the
    /// removal half of `complete_comm` without the iteration credit.
    fn abort_comm(&mut self, t: f64, id: usize, obs: &mut [&mut dyn SimObserver]) {
        let links = std::mem::take(&mut self.comms[id].links);
        let link_pos = std::mem::take(&mut self.comms[id].link_pos);
        {
            let c = &mut self.comms[id];
            c.done = true;
            c.paused_links = 0;
            if c.predicted {
                c.predicted = false;
                self.heap_stale += 1; // its CommDone prediction goes stale
            }
        }
        let pos = self.active_pos[id];
        let _ = self.active_comms.swap_remove(pos);
        if let Some(&moved) = self.active_comms.get(pos) {
            self.active_pos[moved] = pos;
        }
        self.active_pos[id] = usize::MAX;
        for (i, &l) in links.iter().enumerate() {
            let lp = link_pos[i];
            self.per_link.swap_remove(l, lp);
            if let Some(moved) = self.per_link.get(l, lp) {
                let li = self.comms[moved]
                    .links
                    .binary_search(&l)
                    .expect("displaced comm task not registered on link");
                self.comms[moved].link_pos[li] = lp;
            }
        }
        for &l in &links {
            emit(
                &mut *obs,
                SimEvent::ContentionChanged { t, link: l, level: self.per_link.len(l) },
            );
        }
        self.refresh_links(t, &links);
        let mut links = links;
        let mut link_pos = link_pos;
        links.clear();
        link_pos.clear();
        self.comms[id].links = links;
        self.comms[id].link_pos = link_pos;
        self.free_slots.push(id);
    }

    /// A link died: freeze every in-flight transfer crossing it. Frozen
    /// tasks keep their link occupancy (admission still sees the fabric
    /// as busy — conservative) but make no drain progress and hold no
    /// prediction until every crossed link is back up. Jobs are *not*
    /// preempted by link faults: their compute proceeds and their next
    /// All-Reduce waits in the pending set behind the health gate.
    fn on_link_failed(&mut self, t: f64, l: LinkId, obs: &mut [&mut dyn SimObserver]) {
        if !self.health.link_up(l) {
            return;
        }
        // Macro-events assumed their comm proceeds undisturbed: dissolve
        // them before freezing (a rebuilt in-flight transfer crossing `l`
        // lands on the per-link row and is frozen right below).
        self.reconcile_all_ffs(t, None, obs);
        self.health.set_link(l, false);
        emit(&mut *obs, SimEvent::LinkFailed { t, link: l });
        let ids: Vec<usize> = self.per_link.tasks(l).to_vec();
        for id in ids {
            if self.comms[id].paused_links == 0 {
                let (lat_left, rem) = self.residual_at(id, t);
                let c = &mut self.comms[id];
                c.latency_left = lat_left;
                c.remaining = rem;
                c.anchor_t = t;
                c.version += 1; // strand the prediction
                let was_predicted = c.predicted;
                c.predicted = false;
                if was_predicted {
                    self.heap_stale += 1;
                }
            }
            self.comms[id].paused_links += 1;
        }
    }

    /// A link recovered: unfreeze transfers whose last failed link this
    /// was (re-anchor and re-predict from the frozen residuals), then
    /// give the pending set a chance — something may have been waiting
    /// for exactly this link.
    fn on_link_recovered(&mut self, t: f64, l: LinkId, obs: &mut [&mut dyn SimObserver]) {
        if self.health.link_up(l) {
            return;
        }
        self.health.set_link(l, true);
        emit(&mut *obs, SimEvent::LinkRecovered { t, link: l });
        let ids: Vec<usize> = self.per_link.tasks(l).to_vec();
        for id in ids {
            self.comms[id].paused_links -= 1;
            if self.comms[id].paused_links == 0 {
                self.comms[id].anchor_t = t;
                self.repredict(t, id);
            }
        }
        self.ops.push(Op::AdmitPass { t });
    }

    // -- gray failures (degraded performance; docs/EXPERIMENTS.md §Faults) ----

    /// A link degraded: every byte now takes `1/factor` as long to move.
    /// In-flight transfers crossing it are repriced: residuals fixed at
    /// `t` in closed form, then re-predicted at the derated bottleneck
    /// price. The repricing is *forced* — even `AtAdmission`-locked tasks
    /// reprice, because the physical link changed under them, which is
    /// precisely the case the admission-time lock does not model.
    fn on_link_degraded(&mut self, t: f64, l: LinkId, f: f64, obs: &mut [&mut dyn SimObserver]) {
        if !self.health.link_up(l) {
            return; // a down link has no rate to derate
        }
        if self.health.link_factor(l) == f {
            return; // idempotent under timeline repeats
        }
        // Macro-events replayed their comm at the old price: dissolve
        // them before it changes.
        self.reconcile_all_ffs(t, None, obs);
        self.health.set_link_factor(l, f);
        emit(&mut *obs, SimEvent::LinkDegraded { t, link: l, factor: f });
        self.reprice_link(t, l);
    }

    /// A degraded link recovered to full health: restore the factor and
    /// reprice survivors at the healthy rate.
    fn on_link_restored(&mut self, t: f64, l: LinkId, obs: &mut [&mut dyn SimObserver]) {
        if !self.health.link_up(l) || self.health.link_factor(l) == 1.0 {
            return;
        }
        self.reconcile_all_ffs(t, None, obs);
        self.health.set_link_factor(l, 1.0);
        emit(&mut *obs, SimEvent::LinkRestored { t, link: l });
        self.reprice_link(t, l);
    }

    /// Force-reprice every in-flight transfer crossing `l` after its
    /// health factor changed. Frozen tasks (a *failed* link elsewhere in
    /// their path) are skipped — `repredict_inner` leaves them to their
    /// recovery re-anchor, which prices at the then-current factors.
    fn reprice_link(&mut self, t: f64, l: LinkId) {
        let ids: Vec<usize> = self.per_link.tasks(l).to_vec();
        for id in ids {
            self.repredict_inner(t, id, true);
        }
    }

    /// A GPU slowed (gray failure): stretch the compute phases of every
    /// job running on it. Restores and multi-GPU overlaps all funnel
    /// through [`Self::rebase_job_speed`], which rebases in-flight work
    /// in closed form.
    fn on_gpu_slowed(&mut self, t: f64, g: GpuId, f: f64, obs: &mut [&mut dyn SimObserver]) {
        if !self.health.gpu_up(g) {
            return; // a down GPU has no speed to derate
        }
        if self.health.gpu_factor(g) == f {
            return; // idempotent under timeline repeats
        }
        // Reconcile walks read `t_fwd`/`t_bwd` live: dissolve every
        // macro-event before any duration changes under it.
        self.reconcile_all_ffs(t, None, obs);
        self.health.set_gpu_factor(g, f);
        emit(&mut *obs, SimEvent::GpuSlowed { t, gpu: g, factor: f });
        self.rebase_gpu_jobs(t, g);
    }

    /// A slowed GPU recovered to full speed.
    fn on_gpu_restored(&mut self, t: f64, g: GpuId, obs: &mut [&mut dyn SimObserver]) {
        if !self.health.gpu_up(g) || self.health.gpu_factor(g) == 1.0 {
            return;
        }
        self.reconcile_all_ffs(t, None, obs);
        self.health.set_gpu_factor(g, 1.0);
        emit(&mut *obs, SimEvent::GpuRestored { t, gpu: g });
        self.rebase_gpu_jobs(t, g);
    }

    /// Rebase every job occupying GPU `g` after its factor changed.
    fn rebase_gpu_jobs(&mut self, t: f64, g: GpuId) {
        let victims: Vec<usize> =
            (0..self.jobs.len()).filter(|&j| self.jobs[j].gpus.contains(&g)).collect();
        for job in victims {
            self.rebase_job_speed(t, job);
        }
    }

    /// Healthy per-phase compute durations for `job` — the exact
    /// expressions constructor/`add_job` initialization uses, re-derived
    /// so the healthy path stays bit-identical without storing them.
    fn base_durations(&self, job: usize) -> (f64, f64) {
        let spec = &self.jobs[job].spec;
        let m = crate::model::PerfModel::for_model(spec.model);
        let b = spec.model.spec().batch_size;
        let peak = self.cfg.cluster.gpu_peak_gflops;
        (m.t_fwd(b, peak), m.t_bwd(b, peak))
    }

    /// The speed factor `job`'s compute runs at: the worst health factor
    /// over its GPUs (data-parallel phases end at the slowest worker).
    fn job_speed_factor(&self, job: usize) -> f64 {
        let mut f = 1.0f64;
        for &g in &self.jobs[job].gpus {
            let gf = self.health.gpu_factor(g);
            if gf < f {
                f = gf;
            }
        }
        f
    }

    /// Re-derive `job`'s phase durations from the live health factors and
    /// rebase its in-flight compute in closed form: a task that would
    /// finish at `done_at` under the old duration has the same *fraction*
    /// of its phase left under the new one, so the new completion is
    /// `t + (done_at - t) * new/old`. The epoch bump strands the old
    /// `ComputeDone` predictions exactly as a preemption does; a job with
    /// no compute in flight (queued, warming up, or mid-All-Reduce) only
    /// has its durations rewritten — bumping its epoch would strand a
    /// pending `Warmup`.
    fn rebase_job_speed(&mut self, t: f64, job: usize) {
        let (base_fwd, base_bwd) = self.base_durations(job);
        let f = self.job_speed_factor(job);
        // The healthy path (f == 1.0) keeps the original expressions
        // bit-exactly; only genuine slowdowns divide.
        let (new_fwd, new_bwd) =
            if f < 1.0 { (base_fwd / f, base_bwd / f) } else { (base_fwd, base_bwd) };
        let old_fwd = self.jobs[job].t_fwd;
        let old_bwd = self.jobs[job].t_bwd;
        if new_fwd.to_bits() == old_fwd.to_bits() && new_bwd.to_bits() == old_bwd.to_bits() {
            return;
        }
        if self.jobs[job].inflight_compute > 0 {
            self.heap_stale += self.jobs[job].inflight_compute;
            self.jobs[job].inflight_compute = 0;
            self.jobs[job].run_epoch += 1;
            let gpus = std::mem::take(&mut self.jobs[job].gpus);
            for &g in &gpus {
                if !(self.gpus[g].busy && self.gpus[g].running == job) {
                    continue;
                }
                let phase = self.gpus[g].phase;
                let (old_d, new_d) = match phase {
                    Phase::Fwd => (old_fwd, new_fwd),
                    Phase::Bwd => (old_bwd, new_bwd),
                };
                let done = t + (self.gpus[g].done_at - t) * (new_d / old_d);
                self.gpus[g].done_at = done;
                self.push_compute(done, g, job, phase);
            }
            self.jobs[job].gpus = gpus;
        }
        self.jobs[job].t_fwd = new_fwd;
        self.jobs[job].t_bwd = new_bwd;
    }

    // -- steady-state fast-forwarding -----------------------------------------

    /// GPU-side steadiness for `job` (docs/EXPERIMENTS.md §Perf): it has
    /// iterations left and every GPU it occupies hosts it exclusively (no
    /// other resident job, so no ready-queue contention and no priority
    /// preemption). The old `try_fast_forward` is split three ways —
    /// `ff_ready` / [`Self::ff_multi_ready`] / [`Self::ff_commit`] — so
    /// the per-iteration admission decision between the checks and the
    /// commit can surface as an env decision point
    /// ([`DecisionPoint::FfProbe`]).
    ///
    /// Invalidation is unchanged: the only way steadiness can break
    /// afterwards is a placement (a newcomer onto the job's GPUs, or a
    /// new multi-server job overlapping its links), and every placement
    /// pass reconciles every macro-event before its first decision.
    /// Admissions never interact: while a macro-event is live, no pending
    /// job's links intersect its links (debug-asserted in
    /// `op_admit_pass`).
    fn ff_ready(&self, job: usize) -> bool {
        let iters_left = self.jobs[job].spec.iterations - self.jobs[job].iters_done;
        if iters_left == 0 {
            return false;
        }
        for &g in &self.jobs[job].gpus {
            if self.gpus[g].busy
                || !self.gpus[g].ready.is_empty()
                || self.cluster.gpus[g].residents != 1
            {
                return false;
            }
        }
        true
    }

    /// Network-side steadiness for a multi-server job: `AtAdmission`
    /// pricing (an uncontended transfer's rate is locked at k = 1),
    /// healthy idle links, and no other *running* multi-server job
    /// sharing them (such a job's future admissions would contend
    /// without generating an event we could hook). The admission
    /// policy's per-iteration decision on those idle links is *not*
    /// checked here — it is the decision point between this and
    /// [`Self::ff_commit`].
    fn ff_multi_ready(&self, job: usize) -> bool {
        if self.cfg.repricing != Repricing::AtAdmission {
            return false;
        }
        // A failed link stalls the analytic chain's All-Reduces: stay
        // event-exact so the pending-comm health gate applies.
        if !self.health.links_up(&self.jobs[job].links) {
            return false;
        }
        for &l in &self.jobs[job].links {
            if !self.per_link.is_empty(l) {
                return false;
            }
        }
        for &other in &self.running_multi {
            if other != job && links_intersect(&self.jobs[other].links, &self.jobs[job].links) {
                return false;
            }
        }
        true
    }

    /// Replace `job`'s remaining per-iteration event chain with one
    /// analytic macro-event: replay the exact engine's float-operation
    /// chain to the finish and push a single `FastForward` event.
    /// Steadiness ([`Self::ff_ready`], and [`Self::ff_multi_ready`] plus
    /// an admission Start for multi-server jobs) must already hold.
    fn ff_commit(&mut self, t: f64, job: usize, obs: &mut [&mut dyn SimObserver]) {
        let iters_left = self.jobs[job].spec.iterations - self.jobs[job].iters_done;
        let multi = self.jobs[job].multi_server;
        let (lat, per_byte) = if multi {
            // Exactly `repredict`'s unlocked k = 1 bottleneck price
            // (health-derated like it; degradation transitions dissolve
            // live macro-events before the factor changes).
            let mut pb = 0.0f64;
            for &l in &self.jobs[job].links {
                let p = self.link_price(l, 1);
                if p > pb {
                    pb = p;
                }
            }
            if pb <= 0.0 {
                pb = self.cfg.comm.per_byte(1); // no links: degenerate fabric
            }
            (self.topo.latency_over(&self.jobs[job].links), pb)
        } else {
            (0.0, 0.0)
        };
        // Replay the exact per-event time chain analytically to the finish.
        let t_fwd = self.jobs[job].t_fwd;
        let t_bwd = self.jobs[job].t_bwd;
        let drain = self.jobs[job].spec.message_bytes() * per_byte;
        let mut s = t;
        for _ in 0..iters_left {
            s = iter_bounds(s, t_fwd, t_bwd, multi, lat, drain).2;
        }
        let j = &mut self.jobs[job];
        j.ff = Some(FfState { start_t: t, iters: iters_left, end_t: s, lat, per_byte });
        j.ff_version += 1;
        let v = j.ff_version;
        self.ff_pos[job] = self.ff_jobs.len();
        self.ff_jobs.push(job);
        self.push(s, Ev::FastForward { job, version: v });
        emit(&mut *obs, SimEvent::FastForwardApplied { t, job, iters: iters_left, end_t: s });
    }

    /// The macro-event fired: the job ran its whole remaining iteration
    /// chain undisturbed. Apply the batched side-effects and finish it.
    fn complete_fast_forward(&mut self, t: f64, job: usize, obs: &mut [&mut dyn SimObserver]) {
        let Some(ff) = self.jobs[job].ff.take() else {
            return; // defensive: version matched but state already gone
        };
        let pos = self.ff_pos[job];
        self.ff_jobs.swap_remove(pos);
        if let Some(&moved) = self.ff_jobs.get(pos) {
            self.ff_pos[moved] = pos;
        }
        self.ff_pos[job] = usize::MAX;
        debug_assert_eq!(t.to_bits(), ff.end_t.to_bits());
        self.apply_iterations(job, &ff, ff.iters, ff.end_t, obs);
        debug_assert_eq!(self.jobs[job].iters_done, self.jobs[job].spec.iterations);
        let gpus = std::mem::take(&mut self.jobs[job].gpus);
        self.finish_job(t, job, &gpus, obs);
    }

    /// Batched side-effects of `n` coalesced iterations ending at
    /// `end_t`: the engine drains bookkeeping load and advances the
    /// iteration counter; everything observable — per-GPU busy time,
    /// admission counters, the synthesized legacy-log comm lifecycle —
    /// rides on the single `IterationsCoalesced` event, whose constants
    /// let observers replay the exact per-iteration float chains
    /// (bit-identity matters; see `MetricsObserver` / `LegacyLog`).
    fn apply_iterations(
        &mut self,
        job: usize,
        ff: &FfState,
        n: u64,
        end_t: f64,
        obs: &mut [&mut dyn SimObserver],
    ) {
        if n == 0 {
            return;
        }
        emit(
            &mut *obs,
            SimEvent::IterationsCoalesced {
                job,
                gpus: &self.jobs[job].gpus,
                links: &self.jobs[job].links,
                n,
                start_t: ff.start_t,
                end_t,
                t_fwd: self.jobs[job].t_fwd,
                t_bwd: self.jobs[job].t_bwd,
                multi_server: self.jobs[job].multi_server,
                lat: ff.lat,
                per_byte: ff.per_byte,
                msg_bytes: self.jobs[job].spec.message_bytes(),
            },
        );
        let gpus = std::mem::take(&mut self.jobs[job].gpus);
        self.cluster.drain_load_n(&gpus, self.jobs[job].load_per_iter, n);
        self.jobs[job].gpus = gpus;
        self.jobs[job].iters_done += n;
    }

    /// Dissolve every active macro-event, rebuilding each job's exact
    /// micro-state at `t` — called before a placement pass reads cluster
    /// state. Iterations that completed before `t` are applied in batch;
    /// the in-flight one is reconstructed as real heap events.
    ///
    /// With `cfg.workers > 1` the O(iterations) walks — the whole cost of
    /// a reconcile — fan out over a scoped worker pool. This is safe
    /// precisely because these jobs are the ones steadiness proved
    /// non-interacting: each walk is a pure function of its own job's
    /// frozen chain constants, sharing nothing. The engine mutations then
    /// apply serially in `ff_jobs` order — the same order the serial loop
    /// used — so every emission, heap push (and thus `seq` assignment)
    /// and float operation is bit-identical to `workers == 1`.
    ///
    /// A mid-macro arrival is already a serial barrier by construction:
    /// the arrival pops, the placement pass calls this once, and no walk
    /// until every input is frozen at the arrival's timestamp.
    fn reconcile_all_ffs(
        &mut self,
        t: f64,
        interrupter: Option<usize>,
        obs: &mut [&mut dyn SimObserver],
    ) {
        if self.ff_jobs.is_empty() {
            return;
        }
        let jobs = std::mem::take(&mut self.ff_jobs);
        for &job in &jobs {
            self.ff_pos[job] = usize::MAX;
        }
        if self.cfg.workers > 1 && jobs.len() > 1 {
            let walks: Vec<FfWalk> =
                jobs.iter().map(|&job| self.walk_inputs(job, interrupter)).collect();
            let outs = par_walk(self.cfg.workers, &walks, t);
            FF_PAR_BATCHES.with(|c| c.set(c.get() + 1));
            for (i, &job) in jobs.iter().enumerate() {
                self.reconcile_ff_apply(t, job, &outs[i], obs);
            }
        } else {
            for &job in &jobs {
                let out = ff_walk(&self.walk_inputs(job, interrupter), t);
                self.reconcile_ff_apply(t, job, &out, obs);
            }
        }
    }

    /// Snapshot the pure inputs of `job`'s reconcile walk (see
    /// [`FfWalk`]). Walk inputs never depend on another job's reconcile
    /// side-effects — chain constants were frozen at macro-event creation
    /// and `placed_seq` at placement — which is what lets
    /// `reconcile_all_ffs` collect every snapshot before applying any.
    fn walk_inputs(&self, job: usize, interrupter: Option<usize>) -> FfWalk {
        let j = &self.jobs[job];
        let ff = j.ff.as_ref().expect("reconcile without a macro-event");
        FfWalk {
            start_t: ff.start_t,
            iters: ff.iters,
            t_fwd: j.t_fwd,
            t_bwd: j.t_bwd,
            multi: j.multi_server,
            lat: ff.lat,
            drain: j.spec.message_bytes() * ff.per_byte,
            boundary_first: interrupter
                .is_some_and(|f| j.placed_seq < self.jobs[f].placed_seq),
        }
    }

    /// Materialise a fast-forwarded job's exact micro-state at time `t`
    /// (start ≤ t ≤ end) from a completed [`ff_walk`]: apply every
    /// iteration that finished before `t`, and push the in-flight
    /// iteration's pending events — with timestamps bit-identical to the
    /// ones the event-exact engine would be holding in its heap.
    ///
    /// A boundary landing exactly *at* `t` needs the exact engine's heap
    /// tie-break. Arrivals (`interrupter == None`) always sort first
    /// (their sequence numbers predate every runtime event), so the
    /// boundary stays pending. A finish of job F sorts against our
    /// boundary by push order; the only way the two timestamps collide
    /// bit-exactly in practice is bitwise-lockstep chains (same model,
    /// placed in the same pass), where same-timestamp events always
    /// process in placement order — so the boundary completed first iff
    /// this job was placed before F. (A trace *crafted* so an arrival is
    /// bit-equal to an interior boundary can invert that order; see the
    /// caveat in docs/EXPERIMENTS.md §Perf.)
    fn reconcile_ff_apply(
        &mut self,
        t: f64,
        job: usize,
        out: &FfWalkOut,
        obs: &mut [&mut dyn SimObserver],
    ) {
        let ff = self.jobs[job].ff.take().expect("reconcile without a macro-event");
        self.jobs[job].ff_version += 1; // the pending FastForward goes stale
        self.heap_stale += 1;
        emit(&mut *obs, SimEvent::FastForwardDissolved { t, job });
        let t_fwd = self.jobs[job].t_fwd;
        let t_bwd = self.jobs[job].t_bwd;
        let multi = self.jobs[job].multi_server;
        let msg = self.jobs[job].spec.message_bytes();
        if out.finished {
            // The whole macro-event ran: the interrupter shares the
            // final timestamp but sorts after the finish.
            self.apply_iterations(job, &ff, out.done, out.s, obs);
            let gpus = std::mem::take(&mut self.jobs[job].gpus);
            self.finish_job(t, job, &gpus, obs);
            return;
        }
        self.apply_iterations(job, &ff, out.done, out.s, obs);
        // Rebuild the iteration in flight at `t` (it started at `out.s`).
        // The `ComputeStarted` emissions carry the in-flight tasks' real
        // (past) start times; per-GPU busy accumulation replays the same
        // per-accumulator addition order the event-exact engine used.
        let gpus = std::mem::take(&mut self.jobs[job].gpus);
        if t <= out.t1 {
            // Forward pass running on every GPU.
            self.jobs[job].bwd_remaining = gpus.len();
            for &g in &gpus {
                self.gpus[g].busy = true;
                self.gpus[g].running = job;
                self.gpus[g].done_at = out.t1;
                self.gpus[g].phase = Phase::Fwd;
                emit(
                    &mut *obs,
                    SimEvent::ComputeStarted {
                        t: out.s,
                        gpu: g,
                        job,
                        phase: Phase::Fwd,
                        dur: t_fwd,
                    },
                );
                self.push_compute(out.t1, g, job, Phase::Fwd);
            }
        } else if t <= out.t2 {
            // Backward pass running on every GPU.
            self.jobs[job].bwd_remaining = gpus.len();
            for &g in &gpus {
                self.gpus[g].busy = true;
                self.gpus[g].running = job;
                self.gpus[g].done_at = out.t2;
                self.gpus[g].phase = Phase::Bwd;
                emit(
                    &mut *obs,
                    SimEvent::ComputeStarted {
                        t: out.s,
                        gpu: g,
                        job,
                        phase: Phase::Fwd,
                        dur: t_fwd,
                    },
                );
                emit(
                    &mut *obs,
                    SimEvent::ComputeStarted {
                        t: out.t1,
                        gpu: g,
                        job,
                        phase: Phase::Bwd,
                        dur: t_bwd,
                    },
                );
                self.push_compute(out.t2, g, job, Phase::Bwd);
            }
        } else {
            // All-Reduce in flight: admitted clean (k = 1) at t2,
            // completion predicted for `c` — the exact engine's comm task,
            // reconstructed field-for-field in a recycled slot.
            debug_assert!(multi);
            self.jobs[job].bwd_remaining = 0;
            for &g in &gpus {
                emit(
                    &mut *obs,
                    SimEvent::ComputeStarted {
                        t: out.s,
                        gpu: g,
                        job,
                        phase: Phase::Fwd,
                        dur: t_fwd,
                    },
                );
                emit(
                    &mut *obs,
                    SimEvent::ComputeStarted {
                        t: out.t1,
                        gpu: g,
                        job,
                        phase: Phase::Bwd,
                        dur: t_bwd,
                    },
                );
            }
            let links = std::mem::take(&mut self.jobs[job].links);
            let slot = self.alloc_comm_slot();
            let pub_id = self.next_comm_id;
            self.next_comm_id += 1;
            {
                let c = &mut self.comms[slot];
                c.job = job;
                c.pub_id = pub_id;
                c.predicted = true;
                c.latency_left = ff.lat;
                c.remaining = msg;
                c.k = 1;
                c.per_byte = ff.per_byte;
                c.anchor_t = out.t2;
                c.version += 1;
                c.repriced = true; // k = 1 price locked, as at a clean admission
                c.paused_links = 0;
                c.done = false;
            }
            // Record where the slot lands in each per-link row (the
            // completion-time swap-remove positions), then occupy.
            for &l in &links {
                self.comms[slot].link_pos.push(self.per_link.len(l));
                self.per_link.push(l, slot);
            }
            self.comms[slot].links.extend_from_slice(&links);
            self.active_pos[slot] = self.active_comms.len();
            self.active_comms.push(slot);
            emit(
                &mut *obs,
                SimEvent::CommAdmitted {
                    t: out.t2,
                    job,
                    comm: pub_id,
                    links: &links,
                    contention: 1,
                },
            );
            for &l in &links {
                emit(
                    &mut *obs,
                    SimEvent::ContentionChanged { t: out.t2, link: l, level: self.per_link.len(l) },
                );
            }
            let version = self.comms[slot].version;
            self.jobs[job].links = links;
            self.push(out.c, Ev::CommDone { comm: slot, version });
        }
        self.jobs[job].gpus = gpus;
    }

    // -- network ------------------------------------------------------------

    /// Latency and bytes left of comm `id` at time `t`, in closed form
    /// from the task's pricing anchor. Derived on demand — never advanced
    /// incrementally — so the value is independent of how many events
    /// happened to look in between (fast-forwarding removes such events).
    fn residual_at(&self, id: usize, t: f64) -> (f64, f64) {
        let c = &self.comms[id];
        if c.paused_links > 0 {
            // Frozen by a link failure: no progress since the freeze.
            return (c.latency_left, c.remaining);
        }
        let mut dt = t - c.anchor_t;
        if dt <= 0.0 {
            return (c.latency_left, c.remaining);
        }
        let lat_use = c.latency_left.min(dt);
        dt -= lat_use;
        let mut rem = c.remaining;
        if dt > 0.0 {
            // Drain at the bottleneck link's rate (1/per_byte); on a
            // flat fabric this is exactly `comm.rate(k)`.
            rem -= dt * (1.0 / c.per_byte);
            if rem < 0.0 {
                rem = 0.0;
            }
        }
        (c.latency_left - lat_use, rem)
    }

    /// Contention level for a task crossing `links`: max |C_l| — Eq (5)
    /// generalised from server NICs to fabric links.
    fn contention_on(&self, links: &[LinkId]) -> usize {
        links.iter().map(|&l| self.per_link.len(l)).max().unwrap_or(0)
    }

    /// Eq (5) per-byte price of link `l` at occupancy `occ`, derated by
    /// the link's gray-failure health factor: a link at factor `f` moves
    /// bytes at `f` times its healthy rate, so the per-byte time divides
    /// by `f`. The healthy branch executes the original pricing
    /// expression untouched — degradation-free runs stay bit-identical
    /// by construction.
    fn link_price(&self, l: LinkId, occ: usize) -> f64 {
        let p = self.topo.link_model(l).per_byte(occ);
        let f = self.health.link_factor(l);
        if f < 1.0 {
            p / f
        } else {
            p
        }
    }

    /// Re-derive k, the bottleneck per-byte price and the predicted
    /// completion of comm task `id` at time t, re-anchoring its residual
    /// so the new price applies strictly forward. Under AtAdmission
    /// pricing, k and the price are computed only while the task has not
    /// started draining (i.e. at admission); afterwards they stay locked.
    fn repredict(&mut self, t: f64, id: usize) {
        self.repredict_inner(t, id, false);
    }

    /// [`Self::repredict`] with an escape hatch: `force_unlock` reprices
    /// even an `AtAdmission`-locked task — used only by gray-failure
    /// transitions (`reprice_link`), where the physical link rate changed
    /// underneath the locked price. The task re-locks at the new price.
    fn repredict_inner(&mut self, t: f64, id: usize, force_unlock: bool) {
        if self.comms[id].paused_links > 0 {
            // Frozen by a link failure: no prediction until recovery
            // re-anchors it (refresh_links may sweep past a frozen task).
            return;
        }
        let locked = !force_unlock
            && self.cfg.repricing == Repricing::AtAdmission
            && self.comms[id].repriced;
        let (k, per_byte) = if locked {
            (self.comms[id].k, self.comms[id].per_byte)
        } else {
            // Inline max over this task's links (no allocation; this is
            // on the Dynamic-repricing hot path). The effective price is
            // the *bottleneck* link's: max per-link Eq (5) per-byte time
            // at that link's own occupancy. On a uniform fabric both
            // maxima land on the same link and this reduces to the seed
            // engine's `comm.per_byte(max |C_s|)` exactly.
            let mut k = 1;
            let mut pb = 0.0f64;
            for i in 0..self.comms[id].links.len() {
                let l = self.comms[id].links[i];
                let occ = self.per_link.len(l).max(1);
                k = k.max(occ);
                let p = self.link_price(l, occ);
                if p > pb {
                    pb = p;
                }
            }
            if pb <= 0.0 {
                pb = self.cfg.comm.per_byte(k); // no links: degenerate fabric
            }
            (k, pb)
        };
        let (lat_left, rem) = self.residual_at(id, t);
        let c = &mut self.comms[id];
        c.latency_left = lat_left;
        c.remaining = rem;
        c.anchor_t = t;
        c.k = k;
        c.per_byte = per_byte;
        c.repriced = true;
        c.version += 1;
        // An unpopped prediction for the previous version is stranded in
        // the heap by this supersession (Dynamic repricing does this to
        // every affected task per network change — the compaction
        // counter's main feeder).
        if c.predicted {
            self.heap_stale += 1;
        }
        c.predicted = true;
        let eta = t + c.latency_left + c.remaining * per_byte;
        let v = c.version;
        // No max-contention bookkeeping here any more: occupancy peaks
        // are realized at admissions, so the `CommAdmitted` contention
        // field already bounds every repredicted k (MetricsObserver).
        self.push(eta, Ev::CommDone { comm: id, version: v });
    }

    /// After membership on `links` changed, refresh every task touching
    /// them (Dynamic repricing). Under AtAdmission pricing, rates are
    /// locked at start and this is a no-op for existing tasks.
    fn refresh_links(&mut self, t: f64, links: &[LinkId]) {
        if self.cfg.repricing == Repricing::AtAdmission {
            return;
        }
        // Reuse one scratch buffer across passes — this runs on every
        // Dynamic-repricing network change and used to allocate (and
        // sort/dedup) a fresh vec each time.
        let mut affected = std::mem::take(&mut self.scratch_affected);
        affected.clear();
        for &l in links {
            affected.extend_from_slice(self.per_link.tasks(l));
        }
        affected.sort_unstable();
        affected.dedup();
        for &id in &affected {
            self.repredict(t, id);
        }
        self.scratch_affected = affected;
    }

    /// Pop a recycled `comms` slot, or grow the slab by one. The returned
    /// slot's `links`/`link_pos` are empty (capacity retained from the
    /// previous tenant); every other field is stale and must be
    /// overwritten by the caller — except `version`, which deliberately
    /// survives reuse (see [`CommTask::version`]).
    fn alloc_comm_slot(&mut self) -> usize {
        if let Some(slot) = self.free_slots.pop() {
            debug_assert!(self.comms[slot].done, "recycling a live comm slot");
            debug_assert!(self.comms[slot].links.is_empty());
            return slot;
        }
        let slot = self.comms.len();
        self.comms.push(CommTask {
            job: 0,
            pub_id: 0,
            links: Vec::new(),
            link_pos: Vec::new(),
            predicted: false,
            latency_left: 0.0,
            remaining: 0.0,
            k: 1,
            per_byte: 0.0,
            anchor_t: 0.0,
            version: 0,
            repriced: false,
            paused_links: 0,
            done: true,
        });
        self.active_pos.push(usize::MAX);
        debug_assert_eq!(self.active_pos.len(), self.comms.len());
        slot
    }

    /// Sort the pending-communication set and walk it in priority order.
    /// Each pending job whose links are all healthy is an admission
    /// *decision point*: the walk pauses there and [`SimState::resolve`]
    /// (or the builtin policy via [`SimState::decide_builtin`]) supplies
    /// Start/Wait before `admit_cont` resumes.
    fn op_admit_pass(&mut self, t: f64) {
        if self.pending_comm.is_empty() {
            return;
        }
        // Take the pending set and rebuild it from the rejects while
        // walking the sorted order — O(n log n), versus the O(n²)
        // `retain(admitted.contains)` difference this replaced (the set
        // is re-sorted by the total order `(run_key, id)` every pass, so
        // its carry-over order is irrelevant).
        let mut order = std::mem::take(&mut self.pending_comm);
        order.sort_by(|&a, &b| srsf_cmp((self.run_key(a), a), (self.run_key(b), b)));
        // Macro-events need no invalidation here: a fast-forwarded
        // multi-server job never shares links with any running
        // multi-server job (checked at creation, and placements — the
        // only way a new sharer appears — reconcile first), so no pending
        // admission can see or touch its virtually-occupied links.
        if cfg!(debug_assertions) {
            for &mj in &self.ff_jobs {
                let clear = !self.jobs[mj].multi_server
                    || order
                        .iter()
                        .all(|&pj| !links_intersect(&self.jobs[mj].links, &self.jobs[pj].links));
                debug_assert!(clear, "macro-event job {mj} shares links with a pending admission");
            }
        }
        self.admit_cont(t, order, 0);
    }

    /// Resume the admission walk at `order[idx]`, pausing at the next
    /// decision point (a pending job whose links are all up).
    fn admit_cont(&mut self, t: f64, order: Vec<usize>, mut idx: usize) {
        while idx < order.len() {
            let job = order[idx];
            // Health gate: never start a transfer over a failed link. The
            // job stays pending; the link's recovery re-runs admission.
            if !self.health.links_up(&self.jobs[job].links) {
                self.pending_comm.push(job);
                idx += 1;
                continue;
            }
            self.paused = Some(Paused::Admit { t, order, idx });
            return;
        }
    }

    /// Start `job`'s pending All-Reduce at `t` — the old `try_admit`
    /// admission arm, verbatim. The admission view the *decision* read is
    /// lazy (live per-link id lists, residuals priced on inspection — see
    /// [`SimState::decide_builtin`]); by the time this runs the decision
    /// is made, so only the bookkeeping side remains.
    fn admit_start(&mut self, t: f64, job: usize, obs: &mut [&mut dyn SimObserver]) {
        let msg = self.jobs[job].spec.message_bytes();
        // Borrow the job's link set for the setup (restored below)
        // instead of the per-pass clone this replaced; only the comm
        // task it creates copies it.
        let links = std::mem::take(&mut self.jobs[job].links);
        let pre = self.contention_on(&links);
        let latency = self.topo.latency_over(&links);
        let slot = self.alloc_comm_slot();
        let pub_id = self.next_comm_id;
        self.next_comm_id += 1;
        {
            let c = &mut self.comms[slot];
            c.job = job;
            c.pub_id = pub_id;
            c.predicted = false;
            c.latency_left = latency;
            c.remaining = msg;
            c.k = 1;
            c.per_byte = self.cfg.comm.per_byte(1);
            c.anchor_t = t;
            // `version` continues from the slot's previous tenant
            // (see the field docs); `repredict` below bumps it and
            // pushes the first live prediction.
            c.repriced = false;
            c.paused_links = 0;
            c.done = false;
        }
        for &l in &links {
            self.comms[slot].link_pos.push(self.per_link.len(l));
            self.per_link.push(l, slot);
        }
        self.comms[slot].links.extend_from_slice(&links);
        self.active_pos[slot] = self.active_comms.len();
        self.active_comms.push(slot);
        self.jobs[job].comm_pending = false;
        emit(
            &mut *obs,
            SimEvent::CommAdmitted { t, job, comm: pub_id, links: &links, contention: pre + 1 },
        );
        for &l in &links {
            emit(
                &mut *obs,
                SimEvent::ContentionChanged { t, link: l, level: self.per_link.len(l) },
            );
        }
        // Price the new task; under Dynamic repricing also refresh
        // everyone sharing its links.
        self.repredict(t, slot);
        self.refresh_links(t, &links);
        self.jobs[job].links = links;
    }

    /// Tear down a finished transfer — the removal half of the old
    /// `complete_comm`. The iteration credit, admission pass and
    /// placement pass that used to follow inline now run as queued
    /// micro-ops, so the event loop can pause at the decisions they
    /// contain. Returns the owning job for those ops.
    fn complete_comm_flat(
        &mut self,
        t: f64,
        id: usize,
        obs: &mut [&mut dyn SimObserver],
    ) -> usize {
        let job = self.comms[id].job;
        let pub_id = self.comms[id].pub_id;
        // Borrow the task's link state by take/restore — the per-event
        // `links.clone()` here was the #2 steady-state allocation site.
        let links = std::mem::take(&mut self.comms[id].links);
        let link_pos = std::mem::take(&mut self.comms[id].link_pos);
        self.comms[id].done = true;
        // O(1) swap-remove from the in-flight set.
        let pos = self.active_pos[id];
        let _ = self.active_comms.swap_remove(pos);
        if let Some(&moved) = self.active_comms.get(pos) {
            self.active_pos[moved] = pos;
        }
        self.active_pos[id] = usize::MAX;
        // O(1) swap-remove from each crossed link's active list via the
        // positions recorded at admission (was an O(occupancy) retain
        // scan per link). A displaced task finds which of its links this
        // is by binary search — its link set is sorted.
        for (i, &l) in links.iter().enumerate() {
            let lp = link_pos[i];
            self.per_link.swap_remove(l, lp);
            if let Some(moved) = self.per_link.get(l, lp) {
                let li = self.comms[moved]
                    .links
                    .binary_search(&l)
                    .expect("displaced comm task not registered on link");
                self.comms[moved].link_pos[li] = lp;
            }
        }
        emit(&mut *obs, SimEvent::CommFinished { t, job, comm: pub_id, links: &links });
        for &l in &links {
            emit(
                &mut *obs,
                SimEvent::ContentionChanged { t, link: l, level: self.per_link.len(l) },
            );
        }
        self.refresh_links(t, &links);
        // Recycle the slot — its cleared `links`/`link_pos` capacity goes
        // with it, so the next admission allocates nothing.
        let mut links = links;
        let mut link_pos = link_pos;
        links.clear();
        link_pos.clear();
        self.comms[id].links = links;
        self.comms[id].link_pos = link_pos;
        self.free_slots.push(id);
        job
    }

    /// Rebuild the heap without its stale entries (superseded `CommDone`
    /// predictions, dissolved `FastForward` macro-events). Pop order is
    /// the total order on `(t, seq)`, so dropping entries that would be
    /// skipped anyway cannot reorder anything live — the only observable
    /// effect is `n_events` no longer counting the skipped pops.
    fn compact_heap(&mut self) {
        let mut entries = std::mem::take(&mut self.heap).into_vec();
        let before = entries.len();
        entries.retain(|e| match e.ev {
            Ev::CommDone { comm, version } => {
                !self.comms[comm].done && self.comms[comm].version == version
            }
            Ev::FastForward { job, version } => self.jobs[job].ff_version == version,
            Ev::ComputeDone { job, epoch, .. } | Ev::Warmup { job, epoch } => {
                self.jobs[job].run_epoch == epoch
            }
            _ => true,
        });
        debug_assert_eq!(
            before - entries.len(),
            self.heap_stale,
            "stale-entry counter drifted from heap contents"
        );
        self.heap = BinaryHeap::from(entries);
        self.heap_stale = 0;
    }
}
