//! Event-driven discrete-event simulator of the cluster (§V): jobs arrive,
//! are placed by a `Placer`, and execute their DAG of forward / backward /
//! All-Reduce tasks under a `CommPolicy` admission rule and the Eq (5)
//! contention network model.
//!
//! The engine is event-driven rather than 1-second-slotted (the paper's
//! "time-discrete procedure"): task durations are tens of milliseconds, so
//! slotting would either quantise them away or cost 10^6 idle ticks.
//! Semantics are identical — scheduling decisions happen exactly at task
//! boundaries, which is when Algorithm 3's per-slot loop would act.
//!
//! Network dynamics: an active All-Reduce crossing fabric links L(J)
//! (`net::Topology::links_between` over its servers — just the server
//! NICs in the paper's flat testbed, plus rack uplinks in a two-tier
//! fabric) first pays the worst-link latency `a`, then drains its M bytes
//! at the bottleneck link's per-byte time `k·b_l + (k−1)·η_l` where
//! `k = max_{l∈L} |C_l|` (Eq 5's differential form, generalised per
//! link). Whenever a task starts or finishes, the contention level — and
//! hence the predicted completion — of every task sharing a link is
//! recomputed; stale completion events are skipped via per-task version
//! counters. `SimConfig::topology` picks the fabric; the `flat` preset
//! reproduces the seed per-server engine's contention bookkeeping exactly
//! (property-tested in `tests`; seed *timing* is also bit-identical under
//! `AtAdmission` pricing, while `Dynamic` repricing now derives transfer
//! residuals in closed form rather than the seed's incremental advances,
//! an ulp-level difference).
//!
//! Steady-state fast-forwarding (`SimConfig::coalescing`, default on): a
//! job whose GPUs host nothing else and whose links — if it communicates
//! at all — are idle, unshared and priced `AtAdmission` runs a
//! closed-form recurrence, so its whole remaining Fwd/Bwd/Comm event
//! chain is replaced by one version-stamped macro-event. Anything that
//! could break steadiness (every such change goes through a placement
//! pass) dissolves the macro-event first, reconciling the partial
//! iterations at the interruption time; the replayed float arithmetic is
//! the event chain's own, so results are *identical* to the event-exact
//! engine (property-tested field-for-field in `tests`; before/after event
//! counts in benches/sim_hotpath.rs; design note in docs/EXPERIMENTS.md
//! §Perf). The equivalence guarantee assumes stateless admission policies
//! that read only the links of the task under decision — true of every
//! registry policy.
//!
//! Parallel advancement (`SimConfig::workers`, default 1): when a
//! placement pass dissolves several macro-events at once, each job's
//! O(iterations) reconcile walk is a pure function of its own frozen
//! chain constants — the jobs were proven non-interacting to get a
//! macro-event at all — so the walks fan out over a scoped worker pool
//! and the results apply serially in the serial engine's order. Output
//! is bit-identical for any worker count (property-tested across the
//! generator grid); a mid-macro arrival is a serial barrier by
//! construction, since every walk input is frozen at the arrival's
//! timestamp before any walk starts (docs/EXPERIMENTS.md §Perf).

//! Output layer ([`observe`]): the engine emits a stream of typed
//! [`SimEvent`]s to a composable set of [`SimObserver`]s
//! ([`simulate_observed`]); [`simulate`] is a thin facade that attaches
//! [`MetricsObserver`] (and [`LegacyLog`] iff `log_events`) and
//! assembles the classic [`SimResult`] from them. Built-in sinks:
//! [`JsonlSink`] (constant-memory JSONL streaming), [`TimelineObserver`]
//! (per-GPU Gantt rows) and [`ContentionProfiler`] (per-link
//! time-at-contention-level histograms). SPI notes — hook order,
//! coalescing interaction, consumer guidance — in docs/EXPERIMENTS.md
//! §Observers.

//! Streaming mode ([`simulate_stream`] / [`simulate_stream_observed`]):
//! instead of pre-seeding every arrival from a materialized `Vec`, the
//! engine polls a [`source::JobSource`](crate::source::JobSource) at
//! arrival boundaries — the heap holds at most one pending arrival, the
//! horizon is unknown until the source reports exhaustion, and memory is
//! bounded by jobs in flight plus a flat per-seen-job record. Fed the same
//! normalized trace, streamed results are bit-identical to the batch path
//! (property-tested across topologies × priorities × policies in `tests`).
//! Pair with [`observe::PercentilesObserver`] for constant-memory tail
//! metrics over million-job replays (docs/EXPERIMENTS.md §Streaming).

//! Resumable state machine ([`SimState`]): the engine underneath every
//! facade above. [`SimState::advance`] runs the event loop to the next
//! *decision point* — a placement candidate, an admission gate or a
//! coalescing probe — and returns it as a [`Step::Decision`];
//! [`SimState::resolve`] applies an external [`Action`] and the walk
//! resumes exactly where it paused. The builtin placers/policies answer
//! decisions through [`SimState::decide_builtin`] — the same code path
//! the facades use — so externally-driven runs with builtin agents are
//! bit-identical to [`simulate_observed`] (property-tested in `tests`).
//! `SimState` is `Clone`; [`SimState::save`] / [`SimState::restore`]
//! checkpoint mid-run. The gym-style wrapper lives in
//! [`env`](crate::env) (docs/EXPERIMENTS.md §SimEnv).

mod engine;
pub mod observe;

pub use engine::{
    simulate, simulate_observed, simulate_stream, simulate_stream_observed, Action,
    DecisionPoint, EventLog, JobPriority, Repricing, SimConfig, SimResult, SimState, Step,
};
pub use observe::{
    ContentionProfiler, JsonlSink, LegacyLog, MetricsObserver, PercentilesObserver, RunStats,
    SimEvent, SimObserver, StreamStats, TaskPhase, TimelineObserver, TimelineSpan,
};

#[cfg(test)]
mod tests;
