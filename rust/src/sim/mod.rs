//! Event-driven discrete-event simulator of the cluster (§V): jobs arrive,
//! are placed by a `Placer`, and execute their DAG of forward / backward /
//! All-Reduce tasks under a `CommPolicy` admission rule and the Eq (5)
//! contention network model.
//!
//! The engine is event-driven rather than 1-second-slotted (the paper's
//! "time-discrete procedure"): task durations are tens of milliseconds, so
//! slotting would either quantise them away or cost 10^6 idle ticks.
//! Semantics are identical — scheduling decisions happen exactly at task
//! boundaries, which is when Algorithm 3's per-slot loop would act.
//!
//! Network dynamics: an active All-Reduce crossing fabric links L(J)
//! (`net::Topology::links_between` over its servers — just the server
//! NICs in the paper's flat testbed, plus rack uplinks in a two-tier
//! fabric) first pays the worst-link latency `a`, then drains its M bytes
//! at the bottleneck link's per-byte time `k·b_l + (k−1)·η_l` where
//! `k = max_{l∈L} |C_l|` (Eq 5's differential form, generalised per
//! link). Whenever a task starts or finishes, the contention level — and
//! hence the predicted completion — of every task sharing a link is
//! recomputed; stale completion events are skipped via per-task version
//! counters. `SimConfig::topology` picks the fabric; the `flat` preset
//! reproduces the seed per-server engine bit-for-bit (property-tested in
//! `tests`).

mod engine;

pub use engine::{simulate, EventLog, JobPriority, Repricing, SimConfig, SimResult};

#[cfg(test)]
mod tests;
