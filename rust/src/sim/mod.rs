//! Event-driven discrete-event simulator of the cluster (§V): jobs arrive,
//! are placed by a `Placer`, and execute their DAG of forward / backward /
//! All-Reduce tasks under a `CommPolicy` admission rule and the Eq (5)
//! contention network model.
//!
//! The engine is event-driven rather than 1-second-slotted (the paper's
//! "time-discrete procedure"): task durations are tens of milliseconds, so
//! slotting would either quantise them away or cost 10^6 idle ticks.
//! Semantics are identical — scheduling decisions happen exactly at task
//! boundaries, which is when Algorithm 3's per-slot loop would act.
//!
//! Network dynamics: an active All-Reduce on servers S(J) first pays the
//! latency `a`, then drains its M bytes at per-byte time `k·b + (k−1)·η`
//! where `k = max_{s∈S} |C_s|` (Eq 5's differential form). Whenever a task
//! starts or finishes, the contention level — and hence the predicted
//! completion — of every task sharing a server is recomputed; stale
//! completion events are skipped via per-task version counters.

mod engine;

pub use engine::{simulate, EventLog, JobPriority, Repricing, SimConfig, SimResult};

#[cfg(test)]
mod tests;
