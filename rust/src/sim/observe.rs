//! Typed event stream + observer/sink API — the engine's output layer.
//!
//! The engine no longer accumulates a monolithic result: it *emits* a
//! stream of typed [`SimEvent`]s to a composable set of [`SimObserver`]s
//! (`sim::simulate_observed`), and the classic [`SimResult`] is a
//! compatibility facade assembled from [`MetricsObserver`] by the thin
//! [`simulate`](super::simulate) wrapper. That buys two things at once:
//! bounded-memory million-job runs (no per-event strings unless a
//! [`LegacyLog`] is attached), and stepwise cluster/network signals —
//! contention levels over time, per-GPU timelines — that observation-
//! driven schedulers (RL contention schedulers, placement-sensitive
//! schedulers à la Dally) consume but a post-hoc summary cannot recover.
//!
//! Built-in observers:
//!
//! * [`MetricsObserver`] — rebuilds every `SimResult` field incrementally
//!   from the stream, replaying the engine's own float-operation order so
//!   the facade is *bit-identical* to the pre-observer engine
//!   (property-tested in `sim::tests`).
//! * [`LegacyLog`] — reproduces the old `SimResult::events` strings
//!   byte-for-byte; attach only when the formatted log is wanted (string
//!   formatting is this observer's whole cost).
//! * [`JsonlSink`] — streams each event as one compact JSON line to any
//!   `io::Write` with constant memory.
//! * [`TimelineObserver`] — per-GPU Gantt rows (job allocation spans).
//! * [`ContentionProfiler`] — per-link time-at-contention-level
//!   histograms for paper-style figures.
//! * [`PercentilesObserver`] — constant-memory streaming p50/p95/p99 of
//!   JCT and queueing delay (P² estimators), for open-ended
//!   [`simulate_stream`](super::simulate_stream) runs where per-job
//!   vectors would defeat the point.
//!
//! Every observer sizes its per-job state on demand (not from `on_start`'s
//! job slice), because streaming runs pass an empty slice there — the
//! horizon is unknown.
//!
//! Hook order, the coalescing interaction (reconciliation can emit
//! batches stamped with past timestamps) and consumer guidance are
//! documented in docs/EXPERIMENTS.md §Observers.

use std::collections::HashMap;
use std::io::{self, Write};

use crate::cluster::GpuId;
use crate::net::LinkId;
use crate::trace::JobSpec;
use crate::util::json::Json;
use crate::util::stats::P2Quantile;

use super::engine::{iter_bounds, EventLog, SimConfig, SimResult};

/// Which half of an iteration a compute task runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskPhase {
    Fwd,
    Bwd,
}

impl TaskPhase {
    /// Stable serialized spelling.
    pub fn name(self) -> &'static str {
        match self {
            TaskPhase::Fwd => "fwd",
            TaskPhase::Bwd => "bwd",
        }
    }
}

/// One typed engine event. Borrowed slices point into engine state and
/// are only valid for the duration of the `on_event` call — observers
/// that keep them copy (`to_vec`) what they need.
///
/// Events are emitted in engine-processing order. With coalescing on,
/// macro-event reconciliation emits batches whose timestamps lie in the
/// past (`IterationsCoalesced`, plus rebuilt `ComputeStarted` /
/// `CommAdmitted` events); consumers that need a strictly time-ordered
/// stream sort by [`SimEvent::t`] or run with `coalescing: false`.
#[derive(Clone, Copy, Debug)]
pub enum SimEvent<'a> {
    /// A job entered the placement queue.
    JobArrived { t: f64, job: usize },
    /// A job was committed to `gpus`, crossing `links` when it
    /// communicates (`multi_server`).
    JobPlaced {
        t: f64,
        job: usize,
        gpus: &'a [GpuId],
        links: &'a [LinkId],
        multi_server: bool,
    },
    /// A job completed its final iteration; memory and GPUs are released.
    JobFinished { t: f64, job: usize },
    /// A forward/backward task started on `gpu` and will run for `dur`.
    ComputeStarted { t: f64, gpu: GpuId, job: usize, phase: TaskPhase, dur: f64 },
    /// An All-Reduce was admitted onto `links` at effective contention
    /// level `contention` (the Eq (5) k it is priced at; 1 = clean).
    CommAdmitted {
        t: f64,
        job: usize,
        comm: usize,
        links: &'a [LinkId],
        contention: usize,
    },
    /// An All-Reduce drained completely and left its links.
    CommFinished { t: f64, job: usize, comm: usize, links: &'a [LinkId] },
    /// A link's active-transfer count changed to `level`.
    ContentionChanged { t: f64, link: LinkId, level: usize },
    /// The engine replaced a steady job's remaining `iters` iterations
    /// with one macro-event completing at `end_t`.
    FastForwardApplied { t: f64, job: usize, iters: u64, end_t: f64 },
    /// A macro-event was dissolved by an interaction at `t`; the covered
    /// iterations arrive as `IterationsCoalesced`.
    FastForwardDissolved { t: f64, job: usize },
    /// Batched side-effects of `n` coalesced steady-state iterations
    /// spanning `[start_t, end_t]`. Carries the exact per-iteration
    /// constants so observers can replay the event-exact engine's float
    /// chains (busy time, synthesized comm lifecycle) bit-for-bit.
    IterationsCoalesced {
        job: usize,
        gpus: &'a [GpuId],
        links: &'a [LinkId],
        n: u64,
        start_t: f64,
        end_t: f64,
        t_fwd: f64,
        t_bwd: f64,
        multi_server: bool,
        lat: f64,
        per_byte: f64,
        msg_bytes: f64,
    },
    /// A GPU failed; running jobs touching it are preempted.
    GpuFailed { t: f64, gpu: GpuId },
    /// A failed GPU came back; its capacity is placeable again.
    GpuRecovered { t: f64, gpu: GpuId },
    /// A fabric link failed; in-flight transfers crossing it freeze.
    LinkFailed { t: f64, link: LinkId },
    /// A failed link came back; frozen transfers resume draining.
    LinkRecovered { t: f64, link: LinkId },
    /// A running job was torn off failed hardware and re-queued, losing
    /// `lost_iters` iterations of progress since its last checkpoint.
    JobPreempted { t: f64, job: usize, lost_iters: u64 },
    /// A preempted job was re-placed (its `restarts`-th restart); it
    /// resumes from its checkpoint after any configured warmup cost.
    JobRestarted { t: f64, job: usize, restarts: u64 },
    /// Preemption rolled the job back to its last checkpoint boundary:
    /// `iters` completed iterations survive.
    CheckpointTaken { t: f64, job: usize, iters: u64 },
    /// A GPU entered gray-failure slowdown: its compute runs at `factor`
    /// times healthy speed until the matching `GpuRestored`.
    GpuSlowed { t: f64, gpu: GpuId, factor: f64 },
    /// A slowed GPU recovered to full speed.
    GpuRestored { t: f64, gpu: GpuId },
    /// A link entered gray-failure degradation: it moves bytes at
    /// `factor` times its healthy rate until the matching `LinkRestored`.
    LinkDegraded { t: f64, link: LinkId, factor: f64 },
    /// A degraded link recovered to its healthy rate.
    LinkRestored { t: f64, link: LinkId },
    /// A recovered GPU was kept out of placement (its failure window
    /// holds `blacklist_k` failures) until `until`.
    GpuBlacklisted { t: f64, gpu: GpuId, until: f64 },
    /// A blacklisted GPU's failure window drained; it is placeable again.
    GpuUnblacklisted { t: f64, gpu: GpuId },
    /// A preempted job's re-queue was deferred to `until` by restart
    /// backoff.
    RestartDeferred { t: f64, job: usize, until: f64 },
}

impl<'a> SimEvent<'a> {
    /// Event timestamp (coalesced batches report their start).
    pub fn t(&self) -> f64 {
        match *self {
            SimEvent::JobArrived { t, .. }
            | SimEvent::JobPlaced { t, .. }
            | SimEvent::JobFinished { t, .. }
            | SimEvent::ComputeStarted { t, .. }
            | SimEvent::CommAdmitted { t, .. }
            | SimEvent::CommFinished { t, .. }
            | SimEvent::ContentionChanged { t, .. }
            | SimEvent::FastForwardApplied { t, .. }
            | SimEvent::FastForwardDissolved { t, .. }
            | SimEvent::GpuFailed { t, .. }
            | SimEvent::GpuRecovered { t, .. }
            | SimEvent::LinkFailed { t, .. }
            | SimEvent::LinkRecovered { t, .. }
            | SimEvent::JobPreempted { t, .. }
            | SimEvent::JobRestarted { t, .. }
            | SimEvent::CheckpointTaken { t, .. }
            | SimEvent::GpuSlowed { t, .. }
            | SimEvent::GpuRestored { t, .. }
            | SimEvent::LinkDegraded { t, .. }
            | SimEvent::LinkRestored { t, .. }
            | SimEvent::GpuBlacklisted { t, .. }
            | SimEvent::GpuUnblacklisted { t, .. }
            | SimEvent::RestartDeferred { t, .. } => t,
            SimEvent::IterationsCoalesced { start_t, .. } => start_t,
        }
    }

    /// Stable kebab-case tag used by serialized streams.
    pub fn kind(&self) -> &'static str {
        match self {
            SimEvent::JobArrived { .. } => "job-arrived",
            SimEvent::JobPlaced { .. } => "job-placed",
            SimEvent::JobFinished { .. } => "job-finished",
            SimEvent::ComputeStarted { .. } => "compute-started",
            SimEvent::CommAdmitted { .. } => "comm-admitted",
            SimEvent::CommFinished { .. } => "comm-finished",
            SimEvent::ContentionChanged { .. } => "contention-changed",
            SimEvent::FastForwardApplied { .. } => "fast-forward-applied",
            SimEvent::FastForwardDissolved { .. } => "fast-forward-dissolved",
            SimEvent::IterationsCoalesced { .. } => "iterations-coalesced",
            SimEvent::GpuFailed { .. } => "gpu-failed",
            SimEvent::GpuRecovered { .. } => "gpu-recovered",
            SimEvent::LinkFailed { .. } => "link-failed",
            SimEvent::LinkRecovered { .. } => "link-recovered",
            SimEvent::JobPreempted { .. } => "job-preempted",
            SimEvent::JobRestarted { .. } => "job-restarted",
            SimEvent::CheckpointTaken { .. } => "checkpoint-taken",
            SimEvent::GpuSlowed { .. } => "gpu-slowed",
            SimEvent::GpuRestored { .. } => "gpu-restored",
            SimEvent::LinkDegraded { .. } => "link-degraded",
            SimEvent::LinkRestored { .. } => "link-restored",
            SimEvent::GpuBlacklisted { .. } => "gpu-blacklisted",
            SimEvent::GpuUnblacklisted { .. } => "gpu-unblacklisted",
            SimEvent::RestartDeferred { .. } => "restart-deferred",
        }
    }

    /// Compact JSON form (one [`JsonlSink`] line).
    pub fn to_json(&self) -> Json {
        fn ids(xs: &[usize]) -> Json {
            Json::Arr(xs.iter().map(|&x| Json::from(x)).collect())
        }
        let v = Json::obj().set("t", self.t()).set("ev", self.kind());
        match *self {
            SimEvent::JobArrived { job, .. } | SimEvent::JobFinished { job, .. } => {
                v.set("job", job)
            }
            SimEvent::JobPlaced { job, gpus, links, multi_server, .. } => v
                .set("job", job)
                .set("gpus", ids(gpus))
                .set("links", ids(links))
                .set("multi_server", multi_server),
            SimEvent::ComputeStarted { gpu, job, phase, dur, .. } => {
                v.set("gpu", gpu).set("job", job).set("phase", phase.name()).set("dur", dur)
            }
            SimEvent::CommAdmitted { job, comm, links, contention, .. } => v
                .set("job", job)
                .set("comm", comm)
                .set("links", ids(links))
                .set("contention", contention),
            SimEvent::CommFinished { job, comm, links, .. } => {
                v.set("job", job).set("comm", comm).set("links", ids(links))
            }
            SimEvent::ContentionChanged { link, level, .. } => {
                v.set("link", link).set("level", level)
            }
            SimEvent::FastForwardApplied { job, iters, end_t, .. } => {
                v.set("job", job).set("iters", iters).set("end_t", end_t)
            }
            SimEvent::FastForwardDissolved { job, .. } => v.set("job", job),
            SimEvent::IterationsCoalesced {
                job,
                gpus,
                links,
                n,
                end_t,
                t_fwd,
                t_bwd,
                multi_server,
                lat,
                per_byte,
                msg_bytes,
                ..
            } => v
                .set("job", job)
                .set("gpus", ids(gpus))
                .set("links", ids(links))
                .set("n", n)
                .set("end_t", end_t)
                // The per-iteration replay constants: a stream consumer
                // can reconstruct every compute window and (for
                // multi-server jobs) every transfer window inside the
                // coalesced span from these alone.
                .set("t_fwd", t_fwd)
                .set("t_bwd", t_bwd)
                .set("multi_server", multi_server)
                .set("lat", lat)
                .set("per_byte", per_byte)
                .set("msg_bytes", msg_bytes),
            SimEvent::GpuFailed { gpu, .. } | SimEvent::GpuRecovered { gpu, .. } => {
                v.set("gpu", gpu)
            }
            SimEvent::LinkFailed { link, .. } | SimEvent::LinkRecovered { link, .. } => {
                v.set("link", link)
            }
            SimEvent::JobPreempted { job, lost_iters, .. } => {
                v.set("job", job).set("lost_iters", lost_iters)
            }
            SimEvent::JobRestarted { job, restarts, .. } => {
                v.set("job", job).set("restarts", restarts)
            }
            SimEvent::CheckpointTaken { job, iters, .. } => {
                v.set("job", job).set("iters", iters)
            }
            SimEvent::GpuSlowed { gpu, factor, .. } => {
                v.set("gpu", gpu).set("factor", factor)
            }
            SimEvent::GpuRestored { gpu, .. } | SimEvent::GpuUnblacklisted { gpu, .. } => {
                v.set("gpu", gpu)
            }
            SimEvent::LinkDegraded { link, factor, .. } => {
                v.set("link", link).set("factor", factor)
            }
            SimEvent::LinkRestored { link, .. } => v.set("link", link),
            SimEvent::GpuBlacklisted { gpu, until, .. } => {
                v.set("gpu", gpu).set("until", until)
            }
            SimEvent::RestartDeferred { job, until, .. } => {
                v.set("job", job).set("until", until)
            }
        }
    }
}

/// End-of-run engine counters handed to `on_end`.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunStats {
    /// Heap events the engine processed.
    pub n_events: u64,
    /// Timestamp of the last processed event — the end of simulated
    /// time. Lets observers close out open intervals (e.g. the
    /// [`ContentionProfiler`]'s final idle stretch).
    pub t_end: f64,
}

/// Lifecycle hooks for simulation observers. `on_start` fires once
/// before the first event (sizing information), `on_event` for every
/// emission, `on_end` once after the event loop drains.
pub trait SimObserver {
    fn on_start(&mut self, _cfg: &SimConfig, _jobs: &[JobSpec]) {}
    fn on_event(&mut self, ev: &SimEvent<'_>);
    fn on_end(&mut self, _stats: &RunStats) {}
}

// ---------------------------------------------------------------------------

/// Rebuilds every [`SimResult`] field incrementally from the event
/// stream; [`simulate`](super::simulate) is a thin facade over this
/// observer. Every float operation replays the engine's own emission
/// order, so the assembled result is bit-identical to the pre-observer
/// engine's (property-tested in `sim::tests`).
#[derive(Default)]
pub struct MetricsObserver {
    arrival: Vec<f64>,
    jct: Vec<f64>,
    finish: Vec<f64>,
    queue_wait: Vec<f64>,
    job_gpus: Vec<Vec<GpuId>>,
    gpu_busy: Vec<f64>,
    first_alloc: Vec<Option<f64>>,
    last_release: Vec<f64>,
    makespan: f64,
    n_events: u64,
    contended_admissions: u64,
    clean_admissions: u64,
    max_contention: usize,
    preempted: u64,
    restarted: u64,
    lost_iters: u64,
}

impl MetricsObserver {
    pub fn new() -> MetricsObserver {
        MetricsObserver::default()
    }

    /// Heap events the engine processed (available after `on_end`).
    pub fn n_events(&self) -> u64 {
        self.n_events
    }

    /// Ensure the per-job vectors cover `job`. Batch runs pre-size in
    /// `on_start`; streaming runs grow here as arrivals come in.
    fn grow_job(&mut self, job: usize) {
        if self.arrival.len() <= job {
            let n = job + 1;
            self.arrival.resize(n, f64::NAN);
            self.jct.resize(n, f64::NAN);
            self.finish.resize(n, f64::NAN);
            self.queue_wait.resize(n, f64::NAN);
            self.job_gpus.resize(n, Vec::new());
        }
    }

    /// Assemble the compatibility [`SimResult`]. `events` is empty —
    /// attach a [`LegacyLog`] alongside when the formatted log is wanted.
    pub fn into_result(self) -> SimResult {
        SimResult {
            jct: self.jct,
            finish: self.finish,
            queue_wait: self.queue_wait,
            gpu_busy: self.gpu_busy,
            gpu_alloc_window: self
                .first_alloc
                .iter()
                .zip(&self.last_release)
                .map(|(fa, lr)| (lr - fa.unwrap_or(0.0)).max(0.0))
                .collect(),
            makespan: self.makespan,
            n_events: self.n_events,
            contended_admissions: self.contended_admissions,
            clean_admissions: self.clean_admissions,
            max_contention: self.max_contention,
            preempted: self.preempted,
            restarted: self.restarted,
            lost_iters: self.lost_iters,
            events: Vec::new(),
        }
    }
}

impl SimObserver for MetricsObserver {
    fn on_start(&mut self, cfg: &SimConfig, jobs: &[JobSpec]) {
        let n_gpus = cfg.cluster.n_gpus();
        self.arrival = jobs.iter().map(|j| j.arrival).collect();
        self.jct = vec![f64::NAN; jobs.len()];
        self.finish = vec![f64::NAN; jobs.len()];
        self.queue_wait = vec![f64::NAN; jobs.len()];
        self.job_gpus = vec![Vec::new(); jobs.len()];
        self.gpu_busy = vec![0.0; n_gpus];
        self.first_alloc = vec![None; n_gpus];
        self.last_release = vec![0.0; n_gpus];
        self.makespan = 0.0;
        self.n_events = 0;
        self.contended_admissions = 0;
        self.clean_admissions = 0;
        self.max_contention = 0;
        self.preempted = 0;
        self.restarted = 0;
        self.lost_iters = 0;
    }

    fn on_event(&mut self, ev: &SimEvent<'_>) {
        match *ev {
            SimEvent::JobArrived { t, job } => {
                // In a batch run this rewrites the pre-sized slot with the
                // very value it holds (the arrival event's timestamp IS
                // the spec's arrival, bit for bit); in a streaming run it
                // is what sizes the vectors.
                self.grow_job(job);
                self.arrival[job] = t;
            }
            SimEvent::JobPlaced { t, job, gpus, .. } => {
                self.queue_wait[job] = t - self.arrival[job];
                self.job_gpus[job] = gpus.to_vec();
                for &g in gpus {
                    self.first_alloc[g].get_or_insert(t);
                }
            }
            SimEvent::JobFinished { t, job } => {
                self.finish[job] = t;
                self.jct[job] = t - self.arrival[job];
                self.makespan = self.makespan.max(t);
                for &g in &self.job_gpus[job] {
                    self.last_release[g] = self.last_release[g].max(t);
                }
                // The GPU list has served its purpose (the release-time
                // fold above); keep finished jobs' footprint flat.
                self.job_gpus[job] = Vec::new();
            }
            SimEvent::ComputeStarted { gpu, dur, .. } => {
                self.gpu_busy[gpu] += dur;
            }
            SimEvent::CommAdmitted { contention, .. } => {
                if contention <= 1 {
                    self.clean_admissions += 1;
                } else {
                    self.contended_admissions += 1;
                }
                // The admission-time k bounds every later repricing of any
                // affected task (occupancy peaks are realized at
                // admissions), so tracking it here reproduces the
                // engine's old repredict-time max exactly.
                self.max_contention = self.max_contention.max(contention);
            }
            SimEvent::IterationsCoalesced { gpus, n, t_fwd, t_bwd, multi_server, .. } => {
                // Replay the exact per-iteration addition chain — not a
                // reassociated `n * (t_fwd + t_bwd)` — bit-identity with
                // the event-exact engine is the contract.
                for &g in gpus {
                    let busy = &mut self.gpu_busy[g];
                    for _ in 0..n {
                        *busy += t_fwd;
                        *busy += t_bwd;
                    }
                }
                if multi_server {
                    // Every coalesced All-Reduce started on idle links.
                    self.clean_admissions += n;
                    self.max_contention = self.max_contention.max(1);
                }
            }
            SimEvent::JobPreempted { t, job, lost_iters } => {
                // The job's allocation window on these GPUs closes here;
                // a restart opens a fresh one via its new JobPlaced.
                for &g in &self.job_gpus[job] {
                    self.last_release[g] = self.last_release[g].max(t);
                }
                self.job_gpus[job] = Vec::new();
                self.preempted += 1;
                self.lost_iters += lost_iters;
            }
            SimEvent::JobRestarted { .. } => {
                self.restarted += 1;
            }
            _ => {}
        }
    }

    fn on_end(&mut self, stats: &RunStats) {
        self.n_events = stats.n_events;
    }
}

// ---------------------------------------------------------------------------

/// Reproduces the pre-observer `SimResult::events` strings byte-for-byte.
/// Attach only when the formatted log is actually wanted — the string
/// formatting this observer performs is exactly the hot-path cost the
/// event redesign removed from the engine.
#[derive(Default)]
pub struct LegacyLog {
    events: Vec<EventLog>,
}

impl LegacyLog {
    pub fn new() -> LegacyLog {
        LegacyLog::default()
    }

    /// The chronologically sorted log (the engine's old end-of-run sort:
    /// stable, so same-timestamp emission order is preserved).
    pub fn into_events(mut self) -> Vec<EventLog> {
        self.events.sort_by(|a, b| a.t.total_cmp(&b.t));
        self.events
    }

    fn push(&mut self, t: f64, what: String) {
        self.events.push(EventLog { t, what });
    }
}

impl SimObserver for LegacyLog {
    fn on_event(&mut self, ev: &SimEvent<'_>) {
        match *ev {
            SimEvent::JobArrived { t, job } => self.push(t, format!("arrive job{job}")),
            SimEvent::JobPlaced { t, job, gpus, .. } => {
                self.push(t, format!("place job{job} gpus={gpus:?}"));
            }
            SimEvent::JobFinished { t, job } => self.push(t, format!("finish job{job}")),
            SimEvent::CommAdmitted { t, job, contention, .. } => {
                self.push(t, format!("comm-start job{job} k={contention}"));
            }
            SimEvent::CommFinished { t, job, .. } => {
                self.push(t, format!("comm-done job{job}"));
            }
            SimEvent::IterationsCoalesced {
                job,
                n,
                start_t,
                t_fwd,
                t_bwd,
                multi_server,
                lat,
                per_byte,
                msg_bytes,
                ..
            } => {
                if !multi_server {
                    return;
                }
                // Synthesise the comm lifecycle exactly as the
                // event-exact engine would have logged it (same float
                // chain as the engine's old `apply_iterations`).
                let drain = msg_bytes * per_byte;
                let mut s = start_t;
                for _ in 0..n {
                    let (_, t2, c) = iter_bounds(s, t_fwd, t_bwd, true, lat, drain);
                    self.push(t2, format!("comm-start job{job} k=1"));
                    self.push(c, format!("comm-done job{job}"));
                    s = c;
                }
            }
            // Fault lines only ever appear in faulted runs, so the
            // zero-fault log stays byte-identical to the pre-fault
            // engine's.
            SimEvent::GpuFailed { t, gpu } => self.push(t, format!("gpu-fail gpu{gpu}")),
            SimEvent::GpuRecovered { t, gpu } => {
                self.push(t, format!("gpu-recover gpu{gpu}"));
            }
            SimEvent::LinkFailed { t, link } => self.push(t, format!("link-fail link{link}")),
            SimEvent::LinkRecovered { t, link } => {
                self.push(t, format!("link-recover link{link}"));
            }
            SimEvent::JobPreempted { t, job, lost_iters } => {
                self.push(t, format!("preempt job{job} lost={lost_iters}"));
            }
            SimEvent::JobRestarted { t, job, restarts } => {
                self.push(t, format!("restart job{job} n={restarts}"));
            }
            SimEvent::CheckpointTaken { t, job, iters } => {
                self.push(t, format!("checkpoint job{job} iters={iters}"));
            }
            // Gray-failure lines: same convention as the hard-fault ones
            // above — absent entirely from degradation-free runs.
            SimEvent::GpuSlowed { t, gpu, factor } => {
                self.push(t, format!("gpu-slow gpu{gpu} factor={factor}"));
            }
            SimEvent::GpuRestored { t, gpu } => {
                self.push(t, format!("gpu-restore gpu{gpu}"));
            }
            SimEvent::LinkDegraded { t, link, factor } => {
                self.push(t, format!("link-degrade link{link} factor={factor}"));
            }
            SimEvent::LinkRestored { t, link } => {
                self.push(t, format!("link-restore link{link}"));
            }
            SimEvent::GpuBlacklisted { t, gpu, until } => {
                self.push(t, format!("blacklist gpu{gpu} until={until}"));
            }
            SimEvent::GpuUnblacklisted { t, gpu } => {
                self.push(t, format!("unblacklist gpu{gpu}"));
            }
            SimEvent::RestartDeferred { t, job, until } => {
                self.push(t, format!("backoff job{job} until={until}"));
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------------------

/// Streams every typed event as one compact JSON line to any
/// [`io::Write`] — constant memory regardless of run length. I/O errors
/// are deferred: the first one stops writing and surfaces from
/// [`JsonlSink::finish`].
pub struct JsonlSink<W: Write> {
    w: W,
    written: u64,
    error: Option<io::Error>,
}

impl<W: Write> JsonlSink<W> {
    pub fn new(w: W) -> JsonlSink<W> {
        JsonlSink { w, written: 0, error: None }
    }

    /// Events written so far.
    pub fn written(&self) -> u64 {
        self.written
    }

    /// Flush, surface any deferred I/O error, and return the writer.
    pub fn finish(mut self) -> io::Result<W> {
        if let Some(e) = self.error.take() {
            return Err(e);
        }
        self.w.flush()?;
        Ok(self.w)
    }
}

impl<W: Write> SimObserver for JsonlSink<W> {
    fn on_event(&mut self, ev: &SimEvent<'_>) {
        if self.error.is_some() {
            return;
        }
        let line = ev.to_json().to_string();
        let res = self.w.write_all(line.as_bytes()).and_then(|()| self.w.write_all(b"\n"));
        match res {
            Ok(()) => self.written += 1,
            Err(e) => self.error = Some(e),
        }
    }

    fn on_end(&mut self, _stats: &RunStats) {
        if let Err(e) = self.w.flush() {
            self.error.get_or_insert(e);
        }
    }
}

// ---------------------------------------------------------------------------

/// One per-GPU Gantt row: `job` held `gpu` from `start` to `end`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TimelineSpan {
    pub gpu: GpuId,
    pub job: usize,
    pub start: f64,
    pub end: f64,
}

/// Per-GPU Gantt rows built from placement/finish events (allocation
/// spans, exact regardless of coalescing). Jobs still running when the
/// event loop drains yield no span.
#[derive(Default)]
pub struct TimelineObserver {
    placed: Vec<Option<(f64, Vec<GpuId>)>>,
    spans: Vec<TimelineSpan>,
}

impl TimelineObserver {
    pub fn new() -> TimelineObserver {
        TimelineObserver::default()
    }

    pub fn spans(&self) -> &[TimelineSpan] {
        &self.spans
    }

    /// Gantt rows sorted by (gpu, start) — the figure-ready form.
    pub fn to_json(&self) -> Json {
        let mut spans = self.spans.clone();
        spans.sort_by(|a, b| a.gpu.cmp(&b.gpu).then(a.start.total_cmp(&b.start)));
        Json::Arr(
            spans
                .iter()
                .map(|s| {
                    Json::obj()
                        .set("gpu", s.gpu)
                        .set("job", s.job)
                        .set("start", s.start)
                        .set("end", s.end)
                })
                .collect(),
        )
    }
}

impl SimObserver for TimelineObserver {
    fn on_start(&mut self, _cfg: &SimConfig, jobs: &[JobSpec]) {
        self.placed = vec![None; jobs.len()];
        self.spans.clear();
    }

    fn on_event(&mut self, ev: &SimEvent<'_>) {
        match *ev {
            SimEvent::JobPlaced { t, job, gpus, .. } => {
                if self.placed.len() <= job {
                    self.placed.resize(job + 1, None);
                }
                self.placed[job] = Some((t, gpus.to_vec()));
            }
            SimEvent::JobFinished { t, job } | SimEvent::JobPreempted { t, job, .. } => {
                if let Some((start, gpus)) = self.placed.get_mut(job).and_then(Option::take) {
                    for gpu in gpus {
                        self.spans.push(TimelineSpan { gpu, job, start, end: t });
                    }
                }
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------------------

/// Per-link time-at-contention-level histogram: how many seconds each
/// fabric link spent with 0, 1, 2, ... active transfers. Each observed
/// link's open interval is closed out to the run's end time at `on_end`,
/// so with `coalescing: false` a link's level histogram sums to exactly
/// the simulated span. Coalesced iterations attribute their
/// per-iteration transfer windows to level 1 directly (no level
/// transitions are synthesized), so level-0 time is approximate under
/// coalescing; run with `coalescing: false` for an exact profile
/// (docs/EXPERIMENTS.md §Observers).
#[derive(Default)]
pub struct ContentionProfiler {
    level: Vec<usize>,
    last_t: Vec<f64>,
    seconds: Vec<Vec<f64>>,
}

impl ContentionProfiler {
    pub fn new() -> ContentionProfiler {
        ContentionProfiler::default()
    }

    /// Seconds `link` spent at exactly `level` concurrent transfers.
    pub fn seconds_at(&self, link: LinkId, level: usize) -> f64 {
        self.seconds.get(link).and_then(|row| row.get(level)).copied().unwrap_or(0.0)
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.seconds
                .iter()
                .enumerate()
                .map(|(l, row)| {
                    Json::obj().set("link", l).set(
                        "seconds_at_level",
                        Json::Arr(row.iter().map(|&s| Json::from(s)).collect()),
                    )
                })
                .collect(),
        )
    }

    fn grow(&mut self, link: LinkId) {
        if self.level.len() <= link {
            self.level.resize(link + 1, 0);
            self.last_t.resize(link + 1, 0.0);
            self.seconds.resize(link + 1, Vec::new());
        }
    }

    fn add(&mut self, link: LinkId, level: usize, secs: f64) {
        let row = &mut self.seconds[link];
        if row.len() <= level {
            row.resize(level + 1, 0.0);
        }
        row[level] += secs;
    }
}

impl SimObserver for ContentionProfiler {
    fn on_event(&mut self, ev: &SimEvent<'_>) {
        match *ev {
            SimEvent::ContentionChanged { t, link, level } => {
                self.grow(link);
                // Reconciliation can emit changes stamped in the past;
                // clamp so a rebuilt transfer cannot produce negative
                // dwell time.
                let dt = (t - self.last_t[link]).max(0.0);
                let cur = self.level[link];
                self.add(link, cur, dt);
                self.level[link] = level;
                self.last_t[link] = t.max(self.last_t[link]);
            }
            SimEvent::IterationsCoalesced {
                links, n, multi_server, lat, per_byte, msg_bytes, ..
            } => {
                if !multi_server {
                    return;
                }
                // Each coalesced iteration occupied the links for one
                // uncontended transfer window.
                let occupied = n as f64 * (lat + msg_bytes * per_byte);
                for &l in links {
                    self.grow(l);
                    self.add(l, 1, occupied);
                }
            }
            _ => {}
        }
    }

    fn on_end(&mut self, stats: &RunStats) {
        // Close every observed link's open interval at the end of
        // simulated time — without this the histogram drops the tail
        // after each link's last membership change (usually idle time)
        // and per-link totals would not sum to the run length.
        for link in 0..self.level.len() {
            let dt = (stats.t_end - self.last_t[link]).max(0.0);
            let cur = self.level[link];
            self.add(link, cur, dt);
            self.last_t[link] = stats.t_end.max(self.last_t[link]);
        }
    }
}

// ---------------------------------------------------------------------------

/// Snapshot of one streamed distribution: count, mean, extremes and the
/// P²-estimated p50/p95/p99. All statistics are 0.0 at count 0.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StreamStats {
    pub count: u64,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// One streamed distribution: exact count/mean/min/max plus three P²
/// quantile markers — constant memory per sample stream.
struct StreamDist {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    p50: P2Quantile,
    p95: P2Quantile,
    p99: P2Quantile,
}

impl StreamDist {
    fn new() -> StreamDist {
        StreamDist {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            p50: P2Quantile::new(0.50),
            p95: P2Quantile::new(0.95),
            p99: P2Quantile::new(0.99),
        }
    }

    fn observe(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.p50.observe(x);
        self.p95.observe(x);
        self.p99.observe(x);
    }

    fn stats(&self) -> StreamStats {
        if self.count == 0 {
            return StreamStats {
                count: 0,
                mean: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p95: 0.0,
                p99: 0.0,
            };
        }
        StreamStats {
            count: self.count,
            mean: self.sum / self.count as f64,
            min: self.min,
            max: self.max,
            p50: self.p50.value().unwrap_or(0.0),
            p95: self.p95.value().unwrap_or(0.0),
            p99: self.p99.value().unwrap_or(0.0),
        }
    }
}

/// Constant-memory tail-latency observer for open-ended streamed runs:
/// p50/p95/p99 of JCT and of queueing delay (arrival → placement) via P²
/// estimators, plus exact counts, means and extremes. State is
/// O(jobs in flight) — arrival timestamps are held only between a job's
/// `JobArrived` and its `JobFinished` — so a million-job replay reports
/// tails without a million-entry vector anywhere.
///
/// Means alone are the wrong summary at this scale: an open stream near
/// saturation has heavy-tailed waiting, and the scheduler differences the
/// paper cares about (Ada-SRSF's long-job protection) live in the tail.
pub struct PercentilesObserver {
    /// Arrival time per in-flight job; removed at finish.
    arrival: HashMap<usize, f64>,
    jct: StreamDist,
    queue_delay: StreamDist,
    arrived: u64,
    makespan: f64,
    n_events: u64,
    /// Fault-free compute lower bound per batch job (`iterations *
    /// (t_fwd + t_bwd)` on a healthy GPU), captured from `on_start`'s
    /// job slice. Streaming runs pass an empty slice there, so the map
    /// stays empty and restart inflation is elided rather than guessed.
    compute_bound: HashMap<usize, f64>,
    /// Restart-inflation accumulators: sums of finished jobs' JCTs and
    /// of those same jobs' compute bounds.
    jct_bound_sum: f64,
    bound_sum: f64,
    preempted: u64,
    restarted: u64,
    lost_iters: u64,
}

impl Default for PercentilesObserver {
    fn default() -> Self {
        Self::new()
    }
}

impl PercentilesObserver {
    pub fn new() -> PercentilesObserver {
        PercentilesObserver {
            arrival: HashMap::new(),
            jct: StreamDist::new(),
            queue_delay: StreamDist::new(),
            arrived: 0,
            makespan: 0.0,
            n_events: 0,
            compute_bound: HashMap::new(),
            jct_bound_sum: 0.0,
            bound_sum: 0.0,
            preempted: 0,
            restarted: 0,
            lost_iters: 0,
        }
    }

    /// JCT distribution over finished jobs.
    pub fn jct_stats(&self) -> StreamStats {
        self.jct.stats()
    }

    /// Queueing-delay (arrival → placement) distribution over placed jobs.
    pub fn queue_delay_stats(&self) -> StreamStats {
        self.queue_delay.stats()
    }

    /// Jobs that arrived over the run.
    pub fn arrived(&self) -> u64 {
        self.arrived
    }

    /// Finished-job count (== `jct_stats().count`).
    pub fn finished(&self) -> u64 {
        self.jct.count
    }

    /// Jobs arrived but not yet finished when the run ended.
    pub fn in_flight(&self) -> usize {
        self.arrival.len()
    }

    pub fn makespan(&self) -> f64 {
        self.makespan
    }

    pub fn n_events(&self) -> u64 {
        self.n_events
    }

    /// Fault-induced preemptions observed.
    pub fn preempted(&self) -> u64 {
        self.preempted
    }

    /// Restart commits observed.
    pub fn restarted(&self) -> u64 {
        self.restarted
    }

    /// Iterations rolled back across all preemptions.
    pub fn lost_iters(&self) -> u64 {
        self.lost_iters
    }

    /// Mean JCT inflation over the fault-free compute bound: the ratio
    /// Σ JCT / Σ (iterations · (t_fwd + t_bwd)) over finished jobs. 1.0
    /// means every finished job ran at its healthy single-allocation
    /// compute bound (no queueing, no contention, no faults); faults,
    /// backoff and lost iterations push it up. `None` when no bounded
    /// job finished — streaming runs (unknown horizon) always elide it.
    pub fn restart_inflation(&self) -> Option<f64> {
        (self.bound_sum > 0.0).then(|| self.jct_bound_sum / self.bound_sum)
    }

    pub fn to_json(&self) -> Json {
        fn dist(s: StreamStats) -> Json {
            Json::obj()
                .set("count", s.count)
                .set("mean", s.mean)
                .set("min", s.min)
                .set("max", s.max)
                .set("p50", s.p50)
                .set("p95", s.p95)
                .set("p99", s.p99)
        }
        let mut v = Json::obj()
            .set("arrived", self.arrived)
            .set("finished", self.finished())
            .set("in_flight", self.in_flight())
            .set("makespan", self.makespan)
            .set("n_events", self.n_events)
            .set("preempted", self.preempted)
            .set("restarted", self.restarted)
            .set("lost_iters", self.lost_iters)
            .set("jct", dist(self.jct_stats()))
            .set("queue_delay", dist(self.queue_delay_stats()));
        if let Some(r) = self.restart_inflation() {
            v = v.set("restart_inflation", r);
        }
        v
    }
}

impl SimObserver for PercentilesObserver {
    fn on_start(&mut self, cfg: &SimConfig, jobs: &[JobSpec]) {
        *self = PercentilesObserver::new();
        // Known-horizon (batch) runs declare every job up front; record
        // each one's healthy compute bound for the restart-inflation
        // ratio. Streaming runs pass an empty slice — the map stays
        // empty and the ratio is elided.
        let peak = cfg.cluster.gpu_peak_gflops;
        for j in jobs {
            let m = crate::model::PerfModel::for_model(j.model);
            let b = j.model.spec().batch_size;
            let bound = j.iterations as f64 * (m.t_fwd(b, peak) + m.t_bwd(b, peak));
            self.compute_bound.insert(j.id, bound);
        }
    }

    fn on_event(&mut self, ev: &SimEvent<'_>) {
        match *ev {
            SimEvent::JobArrived { t, job } => {
                self.arrived += 1;
                self.arrival.insert(job, t);
            }
            SimEvent::JobPlaced { t, job, .. } => {
                if let Some(&a) = self.arrival.get(&job) {
                    self.queue_delay.observe(t - a);
                }
            }
            SimEvent::JobFinished { t, job } => {
                if let Some(a) = self.arrival.remove(&job) {
                    let jct = t - a;
                    self.jct.observe(jct);
                    if let Some(bound) = self.compute_bound.remove(&job) {
                        self.jct_bound_sum += jct;
                        self.bound_sum += bound;
                    }
                }
                self.makespan = self.makespan.max(t);
            }
            SimEvent::JobPreempted { lost_iters, .. } => {
                self.preempted += 1;
                self.lost_iters += lost_iters;
            }
            SimEvent::JobRestarted { .. } => {
                self.restarted += 1;
            }
            _ => {}
        }
    }

    fn on_end(&mut self, stats: &RunStats) {
        self.n_events = stats.n_events;
    }
}
