//! `ddl-sched` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   trace-gen   --jobs N --seed S --out FILE          generate a workload trace
//!   simulate    --placer lwf --policy ada [--trace F] run one simulation
//!   sweep       --what placer|policy|kappa            compare algorithms
//!   e2e         --jobs N --steps N [--no-pallas]      live coordinator run
//!   fit         [--m-max BYTES]                       Fig 2 model fit demo
//!   info                                              print zoo + models

use std::process::ExitCode;

use ddl_sched::coordinator::{self, CoordinatorConfig, JobRequest};
use ddl_sched::metrics::Evaluation;
use ddl_sched::prelude::*;
use ddl_sched::runtime::default_artifacts_dir;
use ddl_sched::util::cli::Args;

fn main() -> ExitCode {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("trace-gen") => cmd_trace_gen(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("e2e") => cmd_e2e(&args),
        Some("fit") => cmd_fit(&args),
        Some("info") => cmd_info(),
        _ => {
            print_help();
            Ok(())
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!(
        "ddl-sched — communication-contention-aware DDL job scheduling\n\
         \n\
         USAGE: ddl-sched <subcommand> [--options]\n\
         \n\
         SUBCOMMANDS\n\
         \x20 trace-gen  --jobs N --seed S [--out trace.json]   generate a workload\n\
         \x20 simulate   [--trace F] [--placer lwf|ff|ls|rand] [--kappa K]\n\
         \x20            [--policy ada|srsf1|srsf2|srsf3] [--seed S] [--jobs N]\n\
         \x20 sweep      --what placer|policy|kappa [--jobs N] [--seed S]\n\
         \x20 e2e        [--jobs N] [--steps N] [--workers W] [--no-pallas]\n\
         \x20            [--policy ada|srsf1|...] [--time-scale X]\n\
         \x20 fit        [--mb-max MB]                          Fig 2 cost-model fit\n\
         \x20 info       print the model zoo and comm model constants"
    );
}

fn load_or_generate(args: &Args) -> anyhow::Result<Vec<JobSpec>> {
    if let Some(path) = args.get("trace") {
        let text = std::fs::read_to_string(path)?;
        return trace::from_json(&text).map_err(|e| anyhow::anyhow!(e));
    }
    let n = args.usize_or("jobs", 160)?;
    let seed = args.u64_or("seed", 42)?;
    let cfg = if n == 160 {
        TraceConfig { seed, ..TraceConfig::paper_160() }
    } else {
        TraceConfig::scaled(n, seed)
    };
    Ok(trace::generate(&cfg))
}

fn cmd_trace_gen(args: &Args) -> anyhow::Result<()> {
    let jobs = load_or_generate(args)?;
    let out = args.str_or("out", "trace.json");
    std::fs::write(out, trace::to_json(&jobs))?;
    println!("wrote {} jobs to {out}", jobs.len());
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let jobs = load_or_generate(args)?;
    let cfg = SimConfig::paper();
    let kappa = args.usize_or("kappa", 1)?;
    let seed = args.u64_or("seed", 42)?;
    let placer_name = args.str_or("placer", "lwf");
    let policy_name = args.str_or("policy", "ada");
    let mut placer = placement::by_name(placer_name, kappa, seed)
        .ok_or_else(|| anyhow::anyhow!("unknown placer '{placer_name}'"))?;
    let policy = sched::by_name(policy_name, cfg.comm)
        .ok_or_else(|| anyhow::anyhow!("unknown policy '{policy_name}'"))?;
    let res = sim::simulate(&cfg, &jobs, placer.as_mut(), policy.as_ref());
    let eval = Evaluation::from_sim(&format!("{placer_name}/{policy_name}"), &res);
    let mut t = Table::new(
        "simulation result",
        &["method", "avg util", "avg JCT(s)", "median JCT(s)", "95th JCT(s)"],
    );
    t.row(&eval.table_row());
    t.print();
    println!(
        "jobs={} events={} makespan={:.1}s comm: clean={} contended={} max_k={}",
        jobs.len(),
        res.n_events,
        res.makespan,
        res.clean_admissions,
        res.contended_admissions,
        res.max_contention
    );
    Ok(())
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    let jobs = load_or_generate(args)?;
    let cfg = SimConfig::paper();
    let seed = args.u64_or("seed", 42)?;
    let what = args.str_or("what", "policy");
    let mut table = Table::new(
        &format!("{what} sweep ({} jobs)", jobs.len()),
        &["method", "avg util", "avg JCT(s)", "median JCT(s)", "95th JCT(s)"],
    );
    match what {
        "placer" => {
            for name in ["rand", "ff", "ls", "lwf"] {
                let mut p = placement::by_name(name, 1, seed).unwrap();
                let policy = AdaDual { model: cfg.comm };
                let res = sim::simulate(&cfg, &jobs, p.as_mut(), &policy);
                table.row(&Evaluation::from_sim(name, &res).table_row());
            }
        }
        "policy" => {
            for name in ["srsf1", "srsf2", "srsf3", "ada"] {
                let mut p = LwfPlacer::new(1);
                let policy = sched::by_name(name, cfg.comm).unwrap();
                let res = sim::simulate(&cfg, &jobs, &mut p, policy.as_ref());
                table.row(&Evaluation::from_sim(name, &res).table_row());
            }
        }
        "kappa" => {
            for kappa in [1usize, 2, 4, 8, 16] {
                let mut p = LwfPlacer::new(kappa);
                let policy = AdaDual { model: cfg.comm };
                let res = sim::simulate(&cfg, &jobs, &mut p, &policy);
                table.row(&Evaluation::from_sim(&format!("LWF-{kappa}"), &res).table_row());
            }
        }
        other => anyhow::bail!("unknown sweep '{other}' (placer|policy|kappa)"),
    }
    table.print();
    Ok(())
}

fn cmd_e2e(args: &Args) -> anyhow::Result<()> {
    let n_jobs = args.usize_or("jobs", 4)?;
    let steps = args.usize_or("steps", 30)?;
    let workers = args.usize_or("workers", 2)?;
    let policy = args.str_or("policy", "ada").to_string();
    let time_scale = args.f64_or("time-scale", 1.0)?;
    let server = coordinator::RtServer::start(default_artifacts_dir())?;
    println!(
        "runtime: preset={} params={}",
        server.meta.preset, server.meta.n_params
    );
    let cfg = CoordinatorConfig {
        cluster: ClusterSpec::tiny(4, 2),
        use_pallas: !args.flag("no-pallas"),
        policy,
        time_scale,
        ..CoordinatorConfig::default_ada(ClusterSpec::tiny(4, 2))
    };
    let jobs: Vec<JobRequest> = (0..n_jobs)
        .map(|id| JobRequest { id, n_workers: workers, steps, seed: 100 + id as u64 })
        .collect();
    let reports = coordinator::run_jobs(&cfg, &server, &jobs)?;
    let mut t = Table::new(
        "e2e training",
        &["job", "gpus", "multi-server", "steps", "first loss", "last loss", "jct(s)", "comm", "contended"],
    );
    for r in &reports {
        t.row(&[
            format!("{}", r.id),
            format!("{:?}", r.gpus),
            format!("{}", r.multi_server),
            format!("{}", r.losses.len()),
            format!("{:.3}", r.losses.first().copied().unwrap_or(f32::NAN)),
            format!("{:.3}", r.losses.last().copied().unwrap_or(f32::NAN)),
            format!("{:.2}", r.jct),
            format!("{}", r.comm_rounds),
            format!("{}", r.contended_rounds),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_fit(args: &Args) -> anyhow::Result<()> {
    let cm = CommModel::paper_10gbe();
    let mb_max = args.f64_or("mb-max", 512.0)?;
    println!("paper constants: a={:.3e}s b={:.3e}s/B eta={:.3e}s/B", cm.a, cm.b, cm.eta);
    println!("AdaDUAL threshold b/(2(b+eta)) = {:.4}", cm.adadual_threshold());
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut m = 1.0e6;
    while m <= mb_max * 1e6 {
        xs.push(m);
        ys.push(cm.time_free(m));
        m *= 2.0;
    }
    let (a, b, r2) = ddl_sched::util::stats::linear_fit(&xs, &ys);
    println!("re-fit on generated points: a={a:.3e} b={b:.3e} r2={r2:.6}");
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    let mut t = Table::new(
        "Table III — DNN zoo (V100)",
        &["model", "size(MB)", "mem(MB)", "batch", "t_f(ms)", "t_b(ms)"],
    );
    for m in model::ALL_MODELS {
        let s = m.spec();
        t.row(&[
            s.name.to_string(),
            format!("{:.1}", s.model_bytes / 1048576.0),
            format!("{:.0}", s.mem_bytes / 1048576.0),
            format!("{}", s.batch_size),
            format!("{:.1}", s.t_fwd * 1e3),
            format!("{:.1}", s.t_bwd * 1e3),
        ]);
    }
    t.print();
    let cm = CommModel::paper_10gbe();
    println!(
        "\ncomm model: a={:.3e}s b={:.3e}s/B eta={:.3e}s/B threshold={:.4}",
        cm.a,
        cm.b,
        cm.eta,
        cm.adadual_threshold()
    );
    Ok(())
}
