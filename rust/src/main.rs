//! `ddl-sched` CLI — the leader entrypoint, built around the declarative
//! Scenario/Experiment API (rust/src/scenario/, docs/SCENARIOS.md).
//!
//! Subcommands:
//!   scenario-gen  [--grid] [--out FILE]                emit a scenario/grid JSON
//!   trace-gen     --jobs N --seed S --out FILE         generate a workload trace
//!   ingest        --csv FILE [--out FILE]              CSV trace -> trace JSON
//!   simulate      [--scenario FILE | flags]            run one scenario
//!   rollout       --agent random|builtin[:P/Q] ...     gym-style env rollout
//!   sweep         [--what AXIS | --grid] [--threads N] run a scenario grid
//!   e2e           --jobs N --steps N [--no-pallas]     live coordinator run
//!   fit           [--mb-max MB]                        Fig 2 model fit demo
//!   info                                               print zoo + models

use std::process::ExitCode;
use std::time::Instant;

use ddl_sched::coordinator::{self, CoordinatorConfig, JobRequest};
use ddl_sched::prelude::*;
use ddl_sched::runtime::default_artifacts_dir;
use ddl_sched::util::cli::Args;
use ddl_sched::util::error::Result;
use ddl_sched::util::json::Json;
use ddl_sched::{bail, err};

fn main() -> ExitCode {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("scenario-gen") => cmd_scenario_gen(&args),
        Some("trace-gen") => cmd_trace_gen(&args),
        Some("ingest") => cmd_ingest(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("rollout") => cmd_rollout(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("e2e") => cmd_e2e(&args),
        Some("fit") => cmd_fit(&args),
        Some("info") => cmd_info(),
        _ => {
            print_help();
            Ok(())
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!(
        "ddl-sched — communication-contention-aware DDL job scheduling\n\
         \n\
         USAGE: ddl-sched <subcommand> [--options]\n\
         \n\
         A run is described by a *scenario*: a JSON file naming the cluster,\n\
         comm model, fabric topology (flat | two-tier | heterogeneous),\n\
         trace source, placer, kappa, policy, priority, repricing, the\n\
         coalescing engine knob and seed (schema: docs/SCENARIOS.md). A\n\
         *sweep* expands a scenario across grid axes and runs it on worker\n\
         threads.\n\
         \n\
         SUBCOMMANDS\n\
         \x20 scenario-gen [--grid] [--out scenario.json]\n\
         \x20            emit the paper scenario (or the full placer x policy\n\
         \x20            grid with --grid) as a starting-point JSON file\n\
         \x20 trace-gen  --jobs N --seed S [--out trace.json]   generate a workload\n\
         \x20 ingest     --csv trace.csv [--out trace.json] [--max-jobs N]\n\
         \x20            [--skip-bad-rows]\n\
         \x20            convert an Alibaba/Philly-style cluster-trace CSV into a\n\
         \x20            committed trace JSON (sorted, rebased to t=0, re-id'd);\n\
         \x20            --skip-bad-rows drops malformed rows (counted) instead\n\
         \x20            of erroring on the first one\n\
         \x20 simulate   [--scenario F] [--trace F] [--placer lwf|lwf-rack|ff|ls|rand|health]\n\
         \x20            [--kappa K] [--policy ada|srsf1|srsf2|srsf3]\n\
         \x20            [--priority srsf|fifo|las] [--repricing at-admission|dynamic]\n\
         \x20            [--oversub R] [--rack-size N] [--coalescing on|off]\n\
         \x20            [--mtbf S [--mttr S] [--fault-horizon S]\n\
         \x20            [--fault-targets gpus|links|both] [--ckpt-iters N] [--warmup S]]\n\
         \x20            (--mttr defaults to 60s when omitted)\n\
         \x20            [--degrade-mtbd S [--degrade-mttr S] [--degrade-factor F]]\n\
         \x20            [--backoff-base S] [--backoff-cap S]\n\
         \x20            [--blacklist-k N] [--blacklist-window S]\n\
         \x20            [--events-out F.jsonl] [--timeline-out F] [--contention-out F]\n\
         \x20            [--no-events] [--seed S] [--jobs N]    run one scenario\n\
         \x20 simulate   --list        print registry placers/policies/topology presets\n\
         \x20 rollout    [--scenario F | simulate flags] [--agent random|builtin[:P/Q]]\n\
         \x20            [--steps N] [--agent-seed S] [--out steps.jsonl]\n\
         \x20            [--events-out F.jsonl]\n\
         \x20            drive the gym-style SimEnv one decision at a time\n\
         \x20            (placement / admission / coalescing probes), writing a\n\
         \x20            JSONL step log; builtin:P/Q names registry algorithms\n\
         \x20            [--grid] [--threads N] [--out-json F] [--out-csv F]\n\
         \x20            [--jobs N] [--seed S]                  run a scenario grid\n\
         \x20 e2e        [--jobs N] [--steps N] [--workers W] [--no-pallas]\n\
         \x20            [--policy ada|srsf1|...] [--time-scale X]\n\
         \x20 fit        [--mb-max MB]                          Fig 2 cost-model fit\n\
         \x20 info       print the model zoo and comm model constants\n\
         \n\
         EXAMPLES\n\
         \x20 ddl-sched scenario-gen --grid --out grid.json\n\
         \x20 ddl-sched sweep --scenario grid.json --threads 8 --out-csv grid.csv\n\
         \x20 ddl-sched sweep --scenario scenarios/oversub_sweep.json --threads 8\n\
         \x20 ddl-sched simulate --placer lwf --policy ada --jobs 160\n\
         \x20 ddl-sched simulate --placer lwf-rack --oversub 4 --rack-size 4\n\
         \x20 ddl-sched simulate --jobs 40 --mtbf 600 --mttr 60 --ckpt-iters 50\n\
         \x20 ddl-sched simulate --jobs 40 --placer health --mtbf 600 \\\n\
         \x20            --degrade-mtbd 120 --blacklist-k 2 --backoff-base 10\n\
         \x20 ddl-sched sweep --scenario scenarios/fault_sweep.json --threads 4\n\
         \x20 ddl-sched ingest --csv scenarios/sample_trace.csv --out trace.json\n\
         \x20 ddl-sched simulate --jobs 40 --events-out events.jsonl --timeline-out gantt.json\n\
         \x20 ddl-sched rollout --jobs 24 --agent builtin --steps 500 --out steps.jsonl"
    );
}

/// Build a scenario from CLI flags (the non-file path). Flags override the
/// paper defaults; `--trace F` reads a trace file, `--jobs N` generates.
fn scenario_from_flags(args: &Args) -> Result<Scenario> {
    let mut s = Scenario::paper();
    s.seed = args.u64_or("seed", s.seed)?;
    // Scenario JSON stores numbers as f64; seeds past 2^53 would be
    // silently rounded on write and rejected on read. Refuse up front.
    if s.seed > (1 << 53) {
        bail!("--seed {} exceeds 2^53; scenario files cannot represent it exactly", s.seed);
    }
    s.kappa = args.usize_or("kappa", s.kappa)?;
    if let Some(p) = args.get("placer") {
        s.placer = p.to_string();
    }
    if let Some(p) = args.get("policy") {
        s.policy = p.to_string();
    }
    if let Some(p) = args.get("priority") {
        s.priority = sim::JobPriority::parse(p)
            .ok_or_else(|| err!("unknown priority '{p}' (srsf|fifo|las)"))?;
    }
    if let Some(r) = args.get("repricing") {
        s.repricing = sim::Repricing::parse(r)
            .ok_or_else(|| err!("unknown repricing '{r}' (at-admission|dynamic)"))?;
    }
    // Engine-speed knob: steady-state iteration fast-forwarding (results
    // are identical either way; `off` is the event-exact oracle).
    if let Some(c) = args.get("coalescing") {
        s.coalescing = match c {
            "on" | "true" => true,
            "off" | "false" => false,
            other => bail!("unknown --coalescing '{other}' (on|off)"),
        };
    }
    // --oversub R puts the run on a two-tier fabric (racks of --rack-size
    // servers, default net::DEFAULT_RACK_SIZE) with an R:1 core.
    if args.get("rack-size").is_some() && args.get("oversub").is_none() {
        bail!("--rack-size only applies to a two-tier fabric; add --oversub R");
    }
    if args.get("oversub").is_some() {
        let topo = net::TopologySpec::TwoTier {
            rack_size: args.usize_or("rack-size", net::DEFAULT_RACK_SIZE)?,
            oversubscription: args.f64_or("oversub", 1.0)?,
        };
        topo.validate(&s.cluster).map_err(ddl_sched::util::error::Error::msg)?;
        s.topology = topo;
    }
    // --mtbf M attaches a seeded MTBF/MTTR failure generator and
    // --degrade-mtbd M a gray-failure (degradation) generator (seconds);
    // the companion knobs refine them and are rejected without them.
    // Placed after the topology flags so link faults validate against the
    // fabric the run will actually use.
    for dep in ["mttr", "fault-horizon", "fault-targets", "ckpt-iters", "warmup"] {
        if args.get(dep).is_some() && args.get("mtbf").is_none() {
            bail!("--{dep} only applies to fault injection; add --mtbf SECONDS");
        }
    }
    for dep in ["degrade-mttr", "degrade-factor"] {
        if args.get(dep).is_some() && args.get("degrade-mtbd").is_none() {
            bail!("--{dep} only applies to gray-failure injection; add --degrade-mtbd SECONDS");
        }
    }
    let faulted = args.get("mtbf").is_some() || args.get("degrade-mtbd").is_some();
    for dep in ["backoff-base", "backoff-cap", "blacklist-k", "blacklist-window"] {
        if args.get(dep).is_some() && !faulted {
            bail!("--{dep} only applies to faulted runs; add --mtbf or --degrade-mtbd SECONDS");
        }
    }
    if faulted {
        let gen = if args.get("mtbf").is_some() {
            let mut gen = fault::GenSpec::with_mtbf(args.f64_or("mtbf", 0.0)?);
            // --mttr is optional: omitted, repairs follow the documented
            // default of GenSpec::DEFAULT_MTTR_S seconds.
            gen.mttr_s = args.f64_or("mttr", gen.mttr_s)?;
            gen.horizon_s = args.f64_or("fault-horizon", gen.horizon_s)?;
            if gen.horizon_s <= 0.0 {
                bail!(
                    "--fault-horizon must be positive, got {}: no fault can be generated \
                     before t=0, so this run would be fault-free — drop --mtbf instead",
                    gen.horizon_s
                );
            }
            if let Some(t) = args.get("fault-targets") {
                gen.targets = FaultTargets::parse(t)
                    .ok_or_else(|| err!("unknown --fault-targets '{t}' (gpus|links|both)"))?;
            }
            Some(gen)
        } else {
            None
        };
        let degraded = if args.get("degrade-mtbd").is_some() {
            let mut d = fault::DegradeSpec::with_mtbd(args.f64_or("degrade-mtbd", 0.0)?);
            d.mttr_s = args.f64_or("degrade-mttr", d.mttr_s)?;
            if args.get("degrade-factor").is_some() {
                // A single severity pins the drawn factor exactly
                // (factor_min == factor_max), like the sweep's degrade axis.
                let f = args.f64_or("degrade-factor", 0.0)?;
                d.factor_min = f;
                d.factor_max = f;
            }
            Some(d)
        } else {
            None
        };
        let defaults = FaultsSpec::default();
        let spec = FaultsSpec {
            checkpoint_iters: args.u64_or("ckpt-iters", defaults.checkpoint_iters)?,
            warmup_s: args.f64_or("warmup", defaults.warmup_s)?,
            events: Vec::new(),
            gen,
            degraded,
            backoff_base_s: args.f64_or("backoff-base", defaults.backoff_base_s)?,
            backoff_cap_s: args.f64_or("backoff-cap", defaults.backoff_cap_s)?,
            blacklist_k: args.u64_or("blacklist-k", defaults.blacklist_k)?,
            blacklist_window_s: args.f64_or("blacklist-window", defaults.blacklist_window_s)?,
        };
        spec.validate(&s.cluster, s.topology.n_links(&s.cluster))?;
        s.faults = Some(spec);
    }
    s.trace = if let Some(path) = args.get("trace") {
        TraceSource::File(path.to_string())
    } else {
        TraceSource::Generated { jobs: args.usize_or("jobs", 160)?, seed: None }
    };
    Ok(s)
}

fn cmd_scenario_gen(args: &Args) -> Result<()> {
    let base = scenario_from_flags(args)?;
    let text = if args.flag("grid") {
        Experiment::paper_grid(base).to_json_text()
    } else {
        base.to_json_text()
    };
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &text)?;
            println!("wrote {path}");
        }
        None => println!("{text}"),
    }
    Ok(())
}

fn cmd_trace_gen(args: &Args) -> Result<()> {
    let jobs = scenario_from_flags(args)?.jobs()?;
    let out = args.str_or("out", "trace.json");
    std::fs::write(out, trace::to_json(&jobs))?;
    println!("wrote {} jobs to {out}", jobs.len());
    Ok(())
}

/// `ingest`: convert a raw cluster-trace CSV (Alibaba/Philly-style header
/// names; column contract in docs/SCENARIOS.md §Trace sources) into a
/// committed trace JSON — sorted by submit time, rebased to t = 0 and
/// sequentially re-id'd — ready for `--trace F` or a scenario `file`
/// source. `--max-jobs N` keeps only the first N jobs after sorting.
fn cmd_ingest(args: &Args) -> Result<()> {
    let csv = args.require("csv")?;
    let out = args.str_or("out", "trace.json");
    let (mut jobs, skipped) = source::read_csv_jobs_counting(csv, args.flag("skip-bad-rows"))?;
    jobs.truncate(args.usize_or("max-jobs", usize::MAX)?);
    if jobs.is_empty() {
        bail!("{csv}: no data rows to ingest");
    }
    std::fs::write(out, trace::to_json(&jobs))?;
    println!("ingested {} jobs from {csv} into {out}", jobs.len());
    if skipped > 0 {
        println!("warning: skipped {skipped} malformed row(s) (--skip-bad-rows)");
    }
    Ok(())
}

/// `simulate --list`: the registry's algorithms and topology presets, so
/// scenario authors stop grepping the source for valid names.
fn cmd_list() -> Result<()> {
    let mut t = Table::new("scenario registry", &["kind", "name", "label"]);
    for p in registry::PLACERS {
        t.row(&["placer".into(), p.to_string(), registry::placer_label(p, 1)]);
    }
    for p in registry::POLICIES {
        t.row(&["policy".into(), p.to_string(), registry::policy_label(p)]);
    }
    for pr in sim::JobPriority::all() {
        t.row(&["priority".into(), pr.name().to_string(), String::new()]);
    }
    for r in [sim::Repricing::AtAdmission, sim::Repricing::Dynamic] {
        t.row(&["repricing".into(), r.name().to_string(), String::new()]);
    }
    for preset in net::TOPOLOGY_PRESETS {
        t.row(&["topology".into(), preset.to_string(), String::new()]);
    }
    for src in registry::TRACE_SOURCES {
        t.row(&["trace-source".into(), src.to_string(), String::new()]);
    }
    t.print();
    println!(
        "\nschema: docs/SCENARIOS.md (LWF labels shown for kappa=1; \
         outputs sinks: events|timeline|contention)"
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    if args.flag("list") {
        return cmd_list();
    }
    let mut scenario = match args.get("scenario") {
        Some(path) => Scenario::from_file(path)?,
        None => scenario_from_flags(args)?,
    };
    // Observer sinks: --no-events drops whatever the scenario file asked
    // for; the --*-out flags then (re)attach individual sinks.
    if args.flag("no-events") {
        scenario.outputs = OutputSpec::default();
    }
    if let Some(p) = args.get("events-out") {
        scenario.outputs.events = Some(p.to_string());
    }
    if let Some(p) = args.get("timeline-out") {
        scenario.outputs.timeline = Some(p.to_string());
    }
    if let Some(p) = args.get("contention-out") {
        scenario.outputs.contention = Some(p.to_string());
    }
    let record = scenario.run()?;
    let mut t = Table::new(
        &format!("scenario '{}'", record.scenario.name),
        &["method", "avg util", "avg JCT(s)", "median JCT(s)", "95th JCT(s)"],
    );
    t.row(&record.eval.table_row());
    t.print();
    println!(
        "finished={} events={} makespan={:.1}s comm: clean={} contended={} max_k={}",
        record.eval.jct.n,
        record.n_events,
        record.eval.makespan,
        record.eval.clean_admissions,
        record.eval.contended_admissions,
        record.max_contention
    );
    for (what, path) in [
        ("events", &record.scenario.outputs.events),
        ("timeline", &record.scenario.outputs.timeline),
        ("contention profile", &record.scenario.outputs.contention),
    ] {
        if let Some(path) = path {
            println!("wrote {what} to {path}");
        }
    }
    Ok(())
}

/// Resolve a `rollout` agent spec: `random` (seeded uniform baseline),
/// `builtin` (the scenario's own placer/policy pair) or
/// `builtin:<placer>/<policy>` (any registry pair).
fn make_agent(spec: &str, scenario: &Scenario, seed: u64) -> Result<Box<dyn EnvAgent>> {
    if spec == "random" {
        return Ok(Box::new(RandomAgent::new(seed)));
    }
    let (placer_name, policy_name) = if spec == "builtin" {
        (scenario.placer.clone(), scenario.policy.clone())
    } else if let Some(rest) = spec.strip_prefix("builtin:") {
        match rest.split_once('/') {
            Some((p, q)) => (p.to_string(), q.to_string()),
            None => bail!("--agent builtin takes <placer>/<policy> (got '{spec}')"),
        }
    } else {
        bail!("unknown --agent '{spec}' (random | builtin | builtin:<placer>/<policy>)");
    };
    let placer = registry::make_placer(
        &placer_name,
        scenario.kappa,
        scenario.seed,
        scenario.topology.rack_size(),
    )?;
    let policy = registry::make_policy(&policy_name, scenario.comm)?;
    Ok(Box::new(BuiltinAgent::new(placer, policy)))
}

/// One step-log line: the observation the agent saw, what it did, and
/// what it earned (schema: docs/SCENARIOS.md §Rollout).
fn action_json(action: &Action) -> Json {
    match action {
        Action::Place(None) => Json::obj().set("kind", "decline"),
        Action::Place(Some(gpus)) => {
            let ids = gpus.iter().map(|&g| Json::from(g)).collect();
            Json::obj().set("kind", "place").set("gpus", Json::Arr(ids))
        }
        Action::Admit(Admission::Start) => Json::obj().set("kind", "start"),
        Action::Admit(Admission::Wait) => Json::obj().set("kind", "wait"),
    }
}

/// `rollout`: drive the gym-style [`SimEnv`] with an agent, one decision
/// point at a time — the training-loop substrate, exposed for inspection.
/// `--out` writes a JSONL step log (one line per decision); `--events-out`
/// additionally attaches the standard engine-event JSONL sink.
fn cmd_rollout(args: &Args) -> Result<()> {
    use std::io::Write as _;
    let scenario = match args.get("scenario") {
        Some(path) => Scenario::from_file(path)?,
        None => scenario_from_flags(args)?,
    };
    let cfg = scenario.engine_config()?;
    let jobs = scenario.jobs()?;
    let agent_spec = args.str_or("agent", "random").to_string();
    let mut agent = make_agent(&agent_spec, &scenario, args.u64_or("agent-seed", scenario.seed)?)?;
    let max_steps = args.u64_or("steps", u64::MAX)?;
    let mut env = SimEnv::new(&cfg, &jobs);
    let mut step_log = match args.get("out") {
        Some(p) => Some(std::io::BufWriter::new(std::fs::File::create(p)?)),
        None => None,
    };
    let mut sink = match args.get("events-out") {
        Some(p) => {
            let f = std::fs::File::create(p)?;
            Some(JsonlSink::new(std::io::BufWriter::new(f)))
        }
        None => None,
    };
    let t0 = Instant::now();
    let steps = {
        let mut obs: Vec<&mut dyn SimObserver> = Vec::new();
        if let Some(s) = sink.as_mut() {
            obs.push(s);
        }
        let mut o = env.reset(obs.as_mut_slice())?;
        let mut n = 0u64;
        while !o.done && n < max_steps {
            let d = env
                .state()
                .pending()
                .ok_or_else(|| err!("engine paused without a pending decision"))?;
            let action = agent.act(env.state(), &d, &o);
            let aj = action_json(&action);
            let (next, reward, _done) = env.step(action, obs.as_mut_slice())?;
            if let Some(w) = step_log.as_mut() {
                let line = Json::obj()
                    .set("step", n)
                    .set("obs", o.to_json())
                    .set("action", aj)
                    .set("reward", reward)
                    .set("return", env.episode_return());
                writeln!(w, "{line}")?;
            }
            o = next;
            n += 1;
        }
        n
    };
    let wall = t0.elapsed().as_secs_f64();
    if let Some(w) = step_log.as_mut() {
        w.flush()?;
    }
    if let Some(s) = sink {
        s.finish()?;
    }
    println!(
        "rollout '{}': agent={} steps={} sim_t={:.1}s return={:.3e}",
        scenario.name,
        agent_spec,
        steps,
        env.state().now(),
        env.episode_return()
    );
    println!(
        "jobs: arrived={} finished={} in_system={}; wall {:.2}s ({:.0} steps/s)",
        env.state().arrived_jobs(),
        env.state().finished_jobs(),
        env.state().jobs_in_system(),
        wall,
        steps as f64 / wall.max(1e-9)
    );
    for (what, path) in [("step log", args.get("out")), ("events", args.get("events-out"))] {
        if let Some(path) = path {
            println!("wrote {what} to {path}");
        }
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let mut exp = match args.get("scenario") {
        Some(path) => Experiment::from_file(path)?,
        None => Experiment::single(scenario_from_flags(args)?),
    };
    // Axes from flags: --grid is the paper placer x policy product; --what
    // sweeps a single axis. A scenario file with its own axes wins, and a
    // bare (axis-less) scenario file stays a single run unless the user
    // explicitly asks for an axis — the default --what only applies to the
    // flags-built path, where `sweep` without arguments means a policy sweep.
    let has_axes = exp != Experiment::single(exp.base.clone());
    if !has_axes {
        let what = match args.get("what") {
            Some(w) => Some(w),
            None if args.get("scenario").is_none() && !args.flag("grid") => Some("policy"),
            None => None,
        };
        if args.flag("grid") {
            exp = Experiment::paper_grid(exp.base);
        } else if let Some(what) = what {
            match what {
                "placer" => {
                    exp.placers =
                        registry::PAPER_PLACERS.iter().map(|s| s.to_string()).collect()
                }
                "policy" => {
                    exp.policies = registry::POLICIES.iter().map(|s| s.to_string()).collect()
                }
                "kappa" => exp.kappas = vec![1, 2, 4, 8, 16],
                "priority" => exp.priorities = sim::JobPriority::all().to_vec(),
                "oversub" => exp.oversubs = vec![2.0, 4.0, 8.0],
                "mtbf" => exp.mtbfs = vec![300.0, 600.0, 1200.0],
                "degrade" => exp.degrades = vec![0.25, 0.5, 0.75],
                other => {
                    bail!(
                        "unknown sweep '{other}' \
                         (placer|policy|kappa|priority|oversub|mtbf|degrade)"
                    )
                }
            }
        }
    }
    let threads = args.usize_or("threads", 1)?;
    let t0 = Instant::now();
    let records = exp.run(threads)?;
    let wall = t0.elapsed().as_secs_f64();

    let title =
        format!("sweep '{}' — {} runs, {} thread(s)", exp.base.name, records.len(), threads.max(1));
    let mut table = Table::new(
        &title,
        &["method", "avg util", "avg JCT(s)", "median JCT(s)", "95th JCT(s)"],
    );
    for r in &records {
        table.row(&r.eval.table_row());
    }
    table.print();
    println!("wall {wall:.2}s");
    if let Some(path) = args.get("out-json") {
        std::fs::write(path, records_to_json(&records))?;
        println!("wrote {path}");
    }
    if let Some(path) = args.get("out-csv") {
        std::fs::write(path, records_to_csv(&records))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    let n_jobs = args.usize_or("jobs", 4)?;
    let steps = args.usize_or("steps", 30)?;
    let workers = args.usize_or("workers", 2)?;
    let policy = args.str_or("policy", "ada").to_string();
    let time_scale = args.f64_or("time-scale", 1.0)?;
    let server = coordinator::RtServer::start(default_artifacts_dir())?;
    println!(
        "runtime: preset={} params={}",
        server.meta.preset, server.meta.n_params
    );
    let cfg = CoordinatorConfig {
        cluster: ClusterSpec::tiny(4, 2),
        use_pallas: !args.flag("no-pallas"),
        policy,
        time_scale,
        ..CoordinatorConfig::default_ada(ClusterSpec::tiny(4, 2))
    };
    let jobs: Vec<JobRequest> = (0..n_jobs)
        .map(|id| JobRequest { id, n_workers: workers, steps, seed: 100 + id as u64 })
        .collect();
    let reports = coordinator::run_jobs(&cfg, &server, &jobs)?;
    let mut t = Table::new(
        "e2e training",
        &["job", "gpus", "multi-server", "steps", "first loss", "last loss", "jct(s)", "comm", "contended"],
    );
    for r in &reports {
        t.row(&[
            format!("{}", r.id),
            format!("{:?}", r.gpus),
            format!("{}", r.multi_server),
            format!("{}", r.losses.len()),
            format!("{:.3}", r.losses.first().copied().unwrap_or(f32::NAN)),
            format!("{:.3}", r.losses.last().copied().unwrap_or(f32::NAN)),
            format!("{:.2}", r.jct),
            format!("{}", r.comm_rounds),
            format!("{}", r.contended_rounds),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_fit(args: &Args) -> Result<()> {
    let cm = CommModel::paper_10gbe();
    let mb_max = args.f64_or("mb-max", 512.0)?;
    println!("paper constants: a={:.3e}s b={:.3e}s/B eta={:.3e}s/B", cm.a, cm.b, cm.eta);
    println!("AdaDUAL threshold b/(2(b+eta)) = {:.4}", cm.adadual_threshold());
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut m = 1.0e6;
    while m <= mb_max * 1e6 {
        xs.push(m);
        ys.push(cm.time_free(m));
        m *= 2.0;
    }
    let (a, b, r2) = ddl_sched::util::stats::linear_fit(&xs, &ys);
    println!("re-fit on generated points: a={a:.3e} b={b:.3e} r2={r2:.6}");
    Ok(())
}

fn cmd_info() -> Result<()> {
    let mut t = Table::new(
        "Table III — DNN zoo (V100)",
        &["model", "size(MB)", "mem(MB)", "batch", "t_f(ms)", "t_b(ms)"],
    );
    for m in model::ALL_MODELS {
        let s = m.spec();
        t.row(&[
            s.name.to_string(),
            format!("{:.1}", s.model_bytes / 1048576.0),
            format!("{:.0}", s.mem_bytes / 1048576.0),
            format!("{}", s.batch_size),
            format!("{:.1}", s.t_fwd * 1e3),
            format!("{:.1}", s.t_bwd * 1e3),
        ]);
    }
    t.print();
    let cm = CommModel::paper_10gbe();
    println!(
        "\ncomm model: a={:.3e}s b={:.3e}s/B eta={:.3e}s/B threshold={:.4}",
        cm.a,
        cm.b,
        cm.eta,
        cm.adadual_threshold()
    );
    println!("\nregistry: placers {:?}, policies {:?}", registry::PLACERS, registry::POLICIES);
    Ok(())
}
