//! Evaluation metrics (§V-A "Metrics"): JCT statistics (average / median /
//! 95th-percentile), JCT CDFs, and GPU utilisation distributions — the
//! exact quantities behind Tables IV–V and Figs 4–6 — plus CSV emission.

use crate::sim::SimResult;
use crate::util::json::Json;
use crate::util::stats::{self, Summary};

/// One algorithm's evaluation row (a row of Table IV or V).
#[derive(Clone, Debug)]
pub struct Evaluation {
    pub method: String,
    pub avg_gpu_util: f64,
    /// Utilisation over each GPU's allocated window (secondary metric).
    pub avg_alloc_util: f64,
    pub jct: Summary,
    pub jct_cdf: Vec<(f64, f64)>,
    pub gpu_utils: Vec<f64>,
    pub makespan: f64,
    pub contended_admissions: u64,
    pub clean_admissions: u64,
}

impl Evaluation {
    pub fn from_sim(method: &str, res: &SimResult) -> Evaluation {
        let jcts: Vec<f64> = res.jct.iter().copied().filter(|t| t.is_finite()).collect();
        // Zero finished jobs (empty trace, or all jobs still running at the
        // horizon) yields an all-zero row rather than a panic.
        let jct = if jcts.is_empty() { Summary::empty() } else { Summary::of(&jcts) };
        Evaluation {
            method: method.to_string(),
            avg_gpu_util: res.avg_gpu_util(),
            avg_alloc_util: res.avg_alloc_util(),
            jct,
            jct_cdf: stats::ecdf(&jcts),
            gpu_utils: res.gpu_utils(),
            makespan: res.makespan,
            contended_admissions: res.contended_admissions,
            clean_admissions: res.clean_admissions,
        }
    }

    /// Table IV/V row: method, avg util %, avg/median/95th JCT seconds.
    pub fn table_row(&self) -> Vec<String> {
        vec![
            self.method.clone(),
            format!("{:.2}%", self.avg_gpu_util * 100.0),
            format!("{:.1}", self.jct.mean),
            format!("{:.1}", self.jct.median),
            format!("{:.1}", self.jct.p95),
        ]
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("method", self.method.as_str())
            .set("avg_gpu_util", self.avg_gpu_util)
            .set("avg_alloc_util", self.avg_alloc_util)
            .set("avg_jct", self.jct.mean)
            .set("median_jct", self.jct.median)
            .set("p95_jct", self.jct.p95)
            .set("makespan", self.makespan)
            .set("contended_admissions", self.contended_admissions)
            .set("clean_admissions", self.clean_admissions)
    }

    /// CSV rows of the JCT CDF (Figs 4a/5a/6a series).
    pub fn cdf_rows(&self) -> Vec<Vec<f64>> {
        self.jct_cdf.iter().map(|&(x, p)| vec![x, p]).collect()
    }

    /// GPU-utilisation histogram over [0,1] (Figs 4b/5b/6b series).
    pub fn util_histogram(&self, bins: usize) -> Vec<usize> {
        stats::histogram(&self.gpu_utils, 0.0, 1.0 + 1e-12, bins)
    }
}

/// Relative improvement `(base - ours) / base` (the paper's "saves X%").
pub fn saving(base: f64, ours: f64) -> f64 {
    (base - ours) / base
}

/// Ratio `ours / base` expressed as the paper's "N.NNx improvement".
pub fn improvement(base: f64, ours: f64) -> f64 {
    ours / base
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimResult;

    fn fake_result() -> SimResult {
        SimResult {
            jct: vec![10.0, 20.0, 30.0, f64::NAN],
            finish: vec![10.0, 20.0, 30.0, f64::NAN],
            queue_wait: vec![0.0; 4],
            gpu_busy: vec![15.0, 30.0],
            gpu_alloc_window: vec![20.0, 30.0],
            makespan: 30.0,
            n_events: 100,
            contended_admissions: 3,
            clean_admissions: 7,
            max_contention: 2,
            preempted: 0,
            restarted: 0,
            lost_iters: 0,
            events: vec![],
        }
    }

    #[test]
    fn evaluation_filters_unfinished() {
        let e = Evaluation::from_sim("X", &fake_result());
        assert_eq!(e.jct.n, 3);
        assert!((e.jct.mean - 20.0).abs() < 1e-9);
        assert!((e.avg_gpu_util - 0.75).abs() < 1e-9); // (0.5 + 1.0)/2
    }

    #[test]
    fn cdf_rows_match_count() {
        let e = Evaluation::from_sim("X", &fake_result());
        assert_eq!(e.cdf_rows().len(), 3);
        assert!((e.cdf_rows().last().unwrap()[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn util_histogram_sums_to_gpus() {
        let e = Evaluation::from_sim("X", &fake_result());
        let h = e.util_histogram(10);
        assert_eq!(h.iter().sum::<usize>(), 2);
    }

    #[test]
    fn table_row_shape() {
        let e = Evaluation::from_sim("LWF-1", &fake_result());
        let row = e.table_row();
        assert_eq!(row.len(), 5);
        assert_eq!(row[0], "LWF-1");
        assert!(row[1].ends_with('%'));
    }

    #[test]
    fn evaluation_of_zero_finished_jobs_is_all_zero() {
        let mut res = fake_result();
        res.jct = vec![f64::NAN; 4];
        res.finish = vec![f64::NAN; 4];
        res.makespan = 0.0;
        let e = Evaluation::from_sim("X", &res);
        assert_eq!(e.jct.n, 0);
        assert_eq!(e.jct.mean, 0.0);
        assert_eq!(e.jct.p95, 0.0);
        assert!(e.jct_cdf.is_empty());
        assert_eq!(e.avg_gpu_util, 0.0);
        // Downstream consumers still work on the empty row.
        assert_eq!(e.cdf_rows().len(), 0);
        assert_eq!(e.table_row().len(), 5);
        assert!(e.to_json().to_string().contains("\"avg_jct\""));
    }

    #[test]
    fn saving_and_improvement() {
        assert!((saving(100.0, 80.0) - 0.2).abs() < 1e-12);
        assert!((improvement(20.0, 43.0) - 2.15).abs() < 1e-12);
    }

    #[test]
    fn json_emission_parses() {
        let e = Evaluation::from_sim("X", &fake_result());
        let text = e.to_json().to_string();
        let v = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(v.req_str("method").unwrap(), "X");
    }
}
