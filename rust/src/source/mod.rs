//! Streaming job sources — the layer between traces and the engine.
//!
//! The paper's evaluation drains a fully materialized 160-job trace, but a
//! production scheduler sees an *open-ended* arrival stream with unknown
//! horizon. [`JobSource`] is the pull-based abstraction the engine polls at
//! arrival boundaries: `next_job()` yields `JobSpec`s with nondecreasing
//! arrival times, `Ok(None)` once the stream is exhausted. Implementations:
//!
//! - [`VecSource`] — adapter over a materialized trace (the batch path).
//! - [`GeneratedSource`] — the synthetic workload as an O(1)-memory open
//!   stream (gap-process arrivals, i.i.d. size/iteration/model marginals).
//! - [`CsvTraceSource`] — Alibaba/Philly-style cluster-trace CSVs, streamed
//!   line-by-line with bounded RSS.
//!
//! Contract: arrivals are nondecreasing and finite (the engine re-checks
//! and errors on violation), and job ids are assigned by the consumer in
//! pull order — sources need not produce meaningful ids.

use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::Path;

use crate::bail;
use crate::model::{DnnModel, ALL_MODELS, V100_PEAK_GFLOPS};
use crate::trace::{JobSpec, TraceConfig};
use crate::util::error::{Context, Result};
use crate::util::rng::Pcg;

/// A pull-based stream of jobs with unknown horizon.
pub trait JobSource {
    /// Pull the next job, or `Ok(None)` when the stream is exhausted (and
    /// on every call thereafter). Arrivals must be nondecreasing.
    fn next_job(&mut self) -> Result<Option<JobSpec>>;

    /// Jobs remaining, when the source knows (materialized traces do;
    /// open streams return `None`).
    fn size_hint(&self) -> Option<usize> {
        None
    }
}

/// Drain a (finite!) source into a `Vec`. Calling this on an uncapped
/// [`GeneratedSource`] never returns.
pub fn drain(source: &mut dyn JobSource) -> Result<Vec<JobSpec>> {
    let mut out = Vec::with_capacity(source.size_hint().unwrap_or(0));
    while let Some(j) = source.next_job()? {
        out.push(j);
    }
    Ok(out)
}

/// Normalize a trace into source order in place: stable-sort by arrival,
/// rebase so the first arrival is t = 0, re-id sequentially. This is the
/// canonical form every source yields and the batch engine path expects.
pub fn normalize(jobs: &mut [JobSpec]) {
    jobs.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    let t0 = jobs.first().map(|j| j.arrival).unwrap_or(0.0);
    for (i, j) in jobs.iter_mut().enumerate() {
        j.id = i;
        j.arrival -= t0;
    }
}

// ---------------------------------------------------------------------------
// VecSource
// ---------------------------------------------------------------------------

/// Adapter over a materialized, arrival-sorted trace.
pub struct VecSource {
    jobs: Vec<JobSpec>,
    next: usize,
}

impl VecSource {
    /// Wrap an already arrival-sorted trace (e.g. the output of
    /// `trace::generate` or a committed scenario trace).
    pub fn new(jobs: Vec<JobSpec>) -> VecSource {
        debug_assert!(
            jobs.windows(2).all(|w| w[0].arrival <= w[1].arrival),
            "VecSource::new expects arrival-sorted jobs; use from_unsorted"
        );
        VecSource { jobs, next: 0 }
    }

    /// Wrap an arbitrary trace, normalizing it first (stable sort by
    /// arrival, rebase to t = 0, sequential ids).
    pub fn from_unsorted(mut jobs: Vec<JobSpec>) -> VecSource {
        normalize(&mut jobs);
        VecSource { jobs, next: 0 }
    }
}

impl JobSource for VecSource {
    fn next_job(&mut self) -> Result<Option<JobSpec>> {
        if self.next >= self.jobs.len() {
            return Ok(None);
        }
        let j = self.jobs[self.next].clone();
        self.next += 1;
        Ok(Some(j))
    }

    fn size_hint(&self) -> Option<usize> {
        Some(self.jobs.len() - self.next)
    }
}

// ---------------------------------------------------------------------------
// GeneratedSource
// ---------------------------------------------------------------------------

/// The synthetic workload as an open stream with O(1) state.
///
/// Arrival gaps are uniform in `[0, 2·mean_gap)` where `mean_gap =
/// horizon / n_jobs(cfg)` — the same mean arrival rate as the batch
/// generator. GPU counts are drawn i.i.d. by histogram weight; iterations
/// and model match the batch marginals exactly.
///
/// This is *statistically* matched to `trace::generate`, not byte-identical:
/// the batch generator draws all arrivals then sorts, which no lazy
/// bounded-memory stream can reproduce. For a byte-identical lazy view of
/// the batch draws (unsorted, bounded by the histogram) see
/// `trace::JobStream`; for bit-identical streaming-vs-batch *engine* runs,
/// feed the same normalized trace through [`VecSource`].
pub struct GeneratedSource {
    rng: Pcg,
    t: f64,
    mean_gap: f64,
    /// (n_gpus, cumulative weight) for the size draw.
    cum_hist: Vec<(usize, u64)>,
    total_weight: u64,
    iter_range: (u64, u64),
    /// Jobs still to emit; `None` = unbounded.
    remaining: Option<usize>,
    count: usize,
}

impl GeneratedSource {
    /// `cap = Some(n)` emits exactly `n` jobs; `None` streams forever.
    pub fn new(cfg: &TraceConfig, cap: Option<usize>) -> GeneratedSource {
        let n = cfg.n_jobs();
        assert!(n > 0, "GeneratedSource needs a non-empty gpu_histogram");
        let mut cum = 0u64;
        let cum_hist: Vec<(usize, u64)> = cfg
            .gpu_histogram
            .iter()
            .filter(|&&(_, c)| c > 0)
            .map(|&(g, c)| {
                cum += c as u64;
                (g, cum)
            })
            .collect();
        GeneratedSource {
            // Distinct stream id from trace::generate's 0x7ace: this is an
            // open stream, not a replay of the batch draws.
            rng: Pcg::new(cfg.seed, 0x57ea),
            t: 0.0,
            mean_gap: cfg.horizon / n as f64,
            cum_hist,
            total_weight: cum,
            iter_range: cfg.iter_range,
            remaining: cap,
            count: 0,
        }
    }
}

impl JobSource for GeneratedSource {
    fn next_job(&mut self) -> Result<Option<JobSpec>> {
        if let Some(r) = &mut self.remaining {
            if *r == 0 {
                return Ok(None);
            }
            *r -= 1;
        }
        self.t += self.rng.range_f64(0.0, 2.0 * self.mean_gap);
        let w = self.rng.next_below(self.total_weight);
        let n_gpus = match self.cum_hist.iter().find(|&&(_, cum)| w < cum) {
            Some(&(g, _)) => g,
            None => unreachable!("w < total_weight by construction"),
        };
        let iterations = self.rng.range_u64(self.iter_range.0, self.iter_range.1);
        let model = *self.rng.choose(&ALL_MODELS);
        let id = self.count;
        self.count += 1;
        Ok(Some(JobSpec { id, arrival: self.t, model, n_gpus, iterations }))
    }

    fn size_hint(&self) -> Option<usize> {
        self.remaining
    }
}

// ---------------------------------------------------------------------------
// CsvTraceSource
// ---------------------------------------------------------------------------

/// Which header column plays which role. See the alias table in
/// `docs/SCENARIOS.md` §Trace sources.
struct ColumnMap {
    submit: usize,
    gpus: usize,
    model: Option<usize>,
    iterations: Option<usize>,
    duration: Option<usize>,
    n_cols: usize,
}

const SUBMIT_ALIASES: &[&str] = &["submit_time", "arrival", "arrival_time", "submit"];
const GPU_ALIASES: &[&str] = &["n_gpus", "gpu_num", "num_gpu", "gpus", "plan_gpu"];
const MODEL_ALIASES: &[&str] = &["model", "model_name", "workload"];
const ITER_ALIASES: &[&str] = &["iterations", "iters", "num_iterations"];
const DURATION_ALIASES: &[&str] = &["duration", "duration_s", "run_time", "runtime"];

impl ColumnMap {
    fn from_header(header: &str, name: &str) -> Result<ColumnMap> {
        let cols: Vec<String> = header
            .trim_start_matches('\u{feff}') // tolerate a UTF-8 BOM
            .split(',')
            .map(|c| c.trim().to_ascii_lowercase())
            .collect();
        let find = |aliases: &[&str]| cols.iter().position(|c| aliases.contains(&c.as_str()));
        let Some(submit) = find(SUBMIT_ALIASES) else {
            bail!("{name}: no submit-time column (one of {SUBMIT_ALIASES:?}) in header '{header}'");
        };
        let Some(gpus) = find(GPU_ALIASES) else {
            bail!("{name}: no GPU-count column (one of {GPU_ALIASES:?}) in header '{header}'");
        };
        let iterations = find(ITER_ALIASES);
        let duration = find(DURATION_ALIASES);
        if iterations.is_none() && duration.is_none() {
            bail!(
                "{name}: need an iterations column ({ITER_ALIASES:?}) or a duration column \
                 ({DURATION_ALIASES:?}) in header '{header}'"
            );
        }
        Ok(ColumnMap {
            submit,
            gpus,
            model: find(MODEL_ALIASES),
            iterations,
            duration,
            n_cols: cols.len(),
        })
    }
}

/// Case/punctuation-forgiving model lookup: "vgg16", "VGG_16" and
/// "VGG-16" all resolve to [`DnnModel::Vgg16`].
pub fn model_from_loose_name(s: &str) -> Option<DnnModel> {
    fn squash(s: &str) -> String {
        s.chars()
            .filter(|c| c.is_ascii_alphanumeric())
            .collect::<String>()
            .to_ascii_lowercase()
    }
    let want = squash(s);
    ALL_MODELS.iter().copied().find(|m| squash(m.spec().name) == want)
}

/// Streaming reader of Alibaba/Philly-style trace CSVs: one `JobSpec` per
/// data row, constant memory (one line buffered at a time). The header row
/// is mandatory; fields are plain comma-separated (no quoting). Submit
/// times must be nondecreasing — for raw unsorted dumps, run the `ingest`
/// subcommand (or [`read_csv_jobs`]) which sorts before committing.
/// Arrivals are rebased so the first job arrives at t = 0.
pub struct CsvTraceSource<R: BufRead> {
    reader: R,
    cols: ColumnMap,
    name: String,
    buf: String,
    line_no: usize,
    /// Raw submit time of the first job (rebase origin).
    t0: Option<f64>,
    /// Last raw submit time seen (ordering check).
    last_submit: f64,
    count: usize,
    /// Tolerate malformed data rows instead of erroring (see
    /// [`skip_bad_rows`](Self::skip_bad_rows)).
    skip_bad: bool,
    skipped: usize,
}

impl CsvTraceSource<BufReader<File>> {
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref();
        let name = path.display().to_string();
        let file = File::open(path).with_context(|| format!("opening trace CSV {name}"))?;
        Self::from_reader(BufReader::new(file), &name)
    }
}

impl<R: BufRead> CsvTraceSource<R> {
    /// Build from any buffered reader; `name` labels error messages.
    pub fn from_reader(mut reader: R, name: &str) -> Result<Self> {
        let mut buf = String::new();
        let mut line_no = 0usize;
        // First non-empty, non-comment line is the header.
        let cols = loop {
            buf.clear();
            line_no += 1;
            if reader.read_line(&mut buf)? == 0 {
                bail!("{name}: empty file, expected a CSV header row");
            }
            let line = buf.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            break ColumnMap::from_header(line, name)?;
        };
        Ok(CsvTraceSource {
            reader,
            cols,
            name: name.to_string(),
            buf,
            line_no,
            t0: None,
            last_submit: f64::NEG_INFINITY,
            count: 0,
            skip_bad: false,
            skipped: 0,
        })
    }

    /// Skip malformed data rows instead of erroring on the first one.
    /// Real cluster dumps routinely contain truncated or sentinel rows;
    /// with this set, each bad row is counted (see [`skipped`](Self::skipped))
    /// and the stream continues at the next line. Header problems still
    /// error — a bad header means every row would be misread.
    pub fn skip_bad_rows(mut self, yes: bool) -> Self {
        self.skip_bad = yes;
        self
    }

    /// Malformed rows tolerated so far under [`skip_bad_rows`](Self::skip_bad_rows).
    pub fn skipped(&self) -> usize {
        self.skipped
    }

    /// Parse the next data row into a `JobSpec` whose `arrival` is the raw
    /// (un-rebased) submit time and `id` the row index. Used by both the
    /// strict streaming path and the sort-then-commit ingest path.
    fn next_raw(&mut self) -> Result<Option<JobSpec>> {
        loop {
            self.buf.clear();
            self.line_no += 1;
            if self.reader.read_line(&mut self.buf)? == 0 {
                return Ok(None);
            }
            let line = self.buf.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            match parse_row(line, &self.cols, &self.name, self.line_no, self.count) {
                Ok(job) => {
                    self.count += 1;
                    return Ok(Some(job));
                }
                Err(_) if self.skip_bad => {
                    self.skipped += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }
}

/// Parse one data row into a `JobSpec` with the raw submit time as
/// `arrival` and `row_idx` as the id. Every rejection is a line-numbered
/// diagnostic naming the offending field.
fn parse_row(
    line: &str,
    cols: &ColumnMap,
    name: &str,
    ln: usize,
    row_idx: usize,
) -> Result<JobSpec> {
    let fields: Vec<&str> = line.split(',').map(str::trim).collect();
    if fields.len() != cols.n_cols {
        bail!(
            "{name}: line {ln}: expected {} comma-separated fields, got {}",
            cols.n_cols,
            fields.len()
        );
    }
    let submit: f64 = fields[cols.submit].parse().map_err(|_| {
        crate::err!("{name}: line {ln}: bad submit time '{}'", fields[cols.submit])
    })?;
    if !submit.is_finite() || submit < 0.0 {
        bail!("{name}: line {ln}: submit time must be finite and >= 0, got '{submit}'");
    }
    let n_gpus: usize = fields[cols.gpus].parse().map_err(|_| {
        crate::err!("{name}: line {ln}: bad GPU count '{}'", fields[cols.gpus])
    })?;
    if n_gpus == 0 {
        bail!("{name}: line {ln}: GPU count must be >= 1");
    }
    let model = match cols.model {
        Some(i) => model_from_loose_name(fields[i]).ok_or_else(|| {
            let known: Vec<&str> = ALL_MODELS.iter().map(|m| m.spec().name).collect();
            crate::err!("{name}: line {ln}: unknown model '{}' ({known:?})", fields[i])
        })?,
        // No model column: assign round-robin so the mix stays even.
        None => ALL_MODELS[row_idx % ALL_MODELS.len()],
    };
    let iterations = match (cols.iterations, cols.duration) {
        (Some(i), _) => {
            let it: u64 = fields[i].parse().map_err(|_| {
                crate::err!("{name}: line {ln}: bad iteration count '{}'", fields[i])
            })?;
            if it == 0 {
                bail!("{name}: line {ln}: iterations must be >= 1");
            }
            it
        }
        (None, Some(i)) => {
            let dur: f64 = fields[i].parse().map_err(|_| {
                crate::err!("{name}: line {ln}: bad duration '{}'", fields[i])
            })?;
            if !dur.is_finite() || dur <= 0.0 {
                bail!("{name}: line {ln}: duration must be positive, got '{}'", fields[i]);
            }
            duration_to_iterations(dur, model)
        }
        (None, None) => unreachable!("ColumnMap::from_header requires one"),
    };
    Ok(JobSpec { id: row_idx, arrival: submit, model, n_gpus, iterations })
}

/// Convert a wall-clock duration (seconds) into an iteration count using
/// the model's per-iteration compute time on the paper's reference V100
/// (`V100_PEAK_GFLOPS`). Communication/queueing time in the original
/// cluster is deliberately ignored — the simulator re-derives it.
pub fn duration_to_iterations(duration_s: f64, model: DnnModel) -> u64 {
    let spec = JobSpec { id: 0, arrival: 0.0, model, n_gpus: 1, iterations: 1 };
    let t_iter = spec.t_iter(V100_PEAK_GFLOPS);
    ((duration_s / t_iter).round() as u64).max(1)
}

impl<R: BufRead> JobSource for CsvTraceSource<R> {
    fn next_job(&mut self) -> Result<Option<JobSpec>> {
        let Some(mut job) = self.next_raw()? else {
            return Ok(None);
        };
        if job.arrival < self.last_submit {
            bail!(
                "{}: line {}: out-of-order submit time {} after {} — streaming ingestion \
                 requires nondecreasing arrivals; run `ddl-sched ingest` to sort and commit \
                 the trace first",
                self.name,
                self.line_no,
                job.arrival,
                self.last_submit
            );
        }
        self.last_submit = job.arrival;
        let t0 = *self.t0.get_or_insert(job.arrival);
        job.arrival -= t0;
        Ok(Some(job))
    }
}

/// Materialize a trace CSV: parse every row (out-of-order submit times
/// allowed here), then normalize — stable sort by arrival, rebase to
/// t = 0, sequential ids. This is what `ingest` commits to JSON.
pub fn read_csv_jobs<P: AsRef<Path>>(path: P) -> Result<Vec<JobSpec>> {
    Ok(read_csv_jobs_counting(path, false)?.0)
}

/// [`read_csv_jobs`] with malformed-row policy: when `skip_bad_rows` is
/// set, bad data rows are dropped instead of erroring, and the second
/// element reports how many were dropped (always 0 in strict mode).
pub fn read_csv_jobs_counting<P: AsRef<Path>>(
    path: P,
    skip_bad_rows: bool,
) -> Result<(Vec<JobSpec>, usize)> {
    let path = path.as_ref();
    let name = path.display().to_string();
    let file = File::open(path).with_context(|| format!("opening trace CSV {name}"))?;
    read_csv_from_counting(BufReader::new(file), &name, skip_bad_rows)
}

/// [`read_csv_jobs`] over any buffered reader.
pub fn read_csv_from<R: BufRead>(reader: R, name: &str) -> Result<Vec<JobSpec>> {
    Ok(read_csv_from_counting(reader, name, false)?.0)
}

/// [`read_csv_jobs_counting`] over any buffered reader.
pub fn read_csv_from_counting<R: BufRead>(
    reader: R,
    name: &str,
    skip_bad_rows: bool,
) -> Result<(Vec<JobSpec>, usize)> {
    let mut src = CsvTraceSource::from_reader(reader, name)?.skip_bad_rows(skip_bad_rows);
    let mut jobs = Vec::new();
    while let Some(j) = src.next_raw()? {
        jobs.push(j);
    }
    normalize(&mut jobs);
    Ok((jobs, src.skipped()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn csv_source(text: &str) -> CsvTraceSource<&[u8]> {
        CsvTraceSource::from_reader(text.as_bytes(), "test.csv").unwrap()
    }

    #[test]
    fn vec_source_drains_in_order() {
        let cfg = TraceConfig::scaled(12, 5);
        let jobs = crate::trace::generate(&cfg);
        let mut src = VecSource::new(jobs.clone());
        assert_eq!(src.size_hint(), Some(12));
        let got = drain(&mut src).unwrap();
        assert_eq!(got, jobs);
        assert_eq!(src.size_hint(), Some(0));
        assert!(src.next_job().unwrap().is_none());
    }

    #[test]
    fn from_unsorted_normalizes() {
        let mk = |id, arrival| JobSpec {
            id,
            arrival,
            model: DnnModel::Vgg16,
            n_gpus: 1,
            iterations: 10,
        };
        let mut src = VecSource::from_unsorted(vec![mk(7, 30.0), mk(3, 10.0), mk(9, 20.0)]);
        let got = drain(&mut src).unwrap();
        let arrivals: Vec<f64> = got.iter().map(|j| j.arrival).collect();
        assert_eq!(arrivals, vec![0.0, 10.0, 20.0]);
        let ids: Vec<usize> = got.iter().map(|j| j.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn generated_source_is_deterministic_and_sorted() {
        let cfg = TraceConfig::paper_160();
        let mut a = GeneratedSource::new(&cfg, Some(500));
        let mut b = GeneratedSource::new(&cfg, Some(500));
        let ja = drain(&mut a).unwrap();
        let jb = drain(&mut b).unwrap();
        assert_eq!(ja, jb);
        assert_eq!(ja.len(), 500);
        for w in ja.windows(2) {
            assert!(w[0].arrival <= w[1].arrival, "arrivals nondecreasing");
        }
        // Sizes come from the histogram support only.
        let support: Vec<usize> = cfg.gpu_histogram.iter().map(|&(g, _)| g).collect();
        for j in &ja {
            assert!(support.contains(&j.n_gpus), "size {} off-histogram", j.n_gpus);
            assert!((cfg.iter_range.0..=cfg.iter_range.1).contains(&j.iterations));
        }
        // Mean arrival rate tracks horizon / n_jobs within a loose band.
        let mean_gap = ja.last().unwrap().arrival / 500.0;
        let want = cfg.horizon / cfg.n_jobs() as f64;
        assert!((mean_gap / want - 1.0).abs() < 0.25, "gap {mean_gap} vs {want}");
    }

    #[test]
    fn generated_source_uncapped_has_no_hint() {
        let mut src = GeneratedSource::new(&TraceConfig::paper_160(), None);
        assert_eq!(src.size_hint(), None);
        for _ in 0..100 {
            assert!(src.next_job().unwrap().is_some());
        }
    }

    #[test]
    fn csv_header_aliases_and_case() {
        let mut src = csv_source(
            "Job_ID,Submit_Time,Model,GPU_Num,Iterations\n\
             a,100.0,vgg16,2,500\n\
             b,103.5,resnet-50,1,800\n",
        );
        let j1 = src.next_job().unwrap().unwrap();
        assert_eq!(j1.arrival, 0.0); // rebased
        assert_eq!(j1.model, DnnModel::Vgg16);
        assert_eq!(j1.n_gpus, 2);
        assert_eq!(j1.iterations, 500);
        let j2 = src.next_job().unwrap().unwrap();
        assert!((j2.arrival - 3.5).abs() < 1e-12);
        assert_eq!(j2.model, DnnModel::ResNet50);
        assert!(src.next_job().unwrap().is_none());
    }

    #[test]
    fn csv_duration_fallback_and_default_model() {
        // No model and no iteration column: round-robin models, duration
        // converted via the reference V100 iteration time.
        let mut src = csv_source("submit_time,gpus,duration\n0,1,60\n1,1,60\n");
        let j1 = src.next_job().unwrap().unwrap();
        let j2 = src.next_job().unwrap().unwrap();
        assert_eq!(j1.model, ALL_MODELS[0]);
        assert_eq!(j2.model, ALL_MODELS[1]);
        assert_eq!(j1.iterations, duration_to_iterations(60.0, ALL_MODELS[0]));
        assert!(j1.iterations >= 1);
    }

    #[test]
    fn csv_malformed_rows_error() {
        // Wrong field count.
        let mut src = csv_source("submit_time,n_gpus,iterations\n1.0,2\n");
        assert!(src.next_job().unwrap_err().to_string().contains("line 2"));
        // Unparseable GPU count.
        let mut src = csv_source("submit_time,n_gpus,iterations\n1.0,two,5\n");
        assert!(src.next_job().unwrap_err().to_string().contains("bad GPU count"));
        // Zero GPUs.
        let mut src = csv_source("submit_time,n_gpus,iterations\n1.0,0,5\n");
        assert!(src.next_job().unwrap_err().to_string().contains(">= 1"));
        // Unknown model names the known zoo.
        let mut src = csv_source("submit_time,n_gpus,model,iterations\n1.0,1,bert,5\n");
        let e = src.next_job().unwrap_err().to_string();
        assert!(e.contains("unknown model 'bert'") && e.contains("VGG-16"), "{e}");
        // Zero iterations.
        let mut src = csv_source("submit_time,n_gpus,iterations\n1.0,1,0\n");
        assert!(src.next_job().unwrap_err().to_string().contains("iterations"));
        // Missing required column.
        let e = CsvTraceSource::from_reader("when,n_gpus,iterations\n".as_bytes(), "t")
            .unwrap_err()
            .to_string();
        assert!(e.contains("submit-time"), "{e}");
        // Neither iterations nor duration.
        let e = CsvTraceSource::from_reader("submit_time,n_gpus,model\n".as_bytes(), "t")
            .unwrap_err()
            .to_string();
        assert!(e.contains("iterations") && e.contains("duration"), "{e}");
    }

    #[test]
    fn csv_out_of_order_streaming_errors_but_ingest_sorts() {
        let text = "submit_time,n_gpus,iterations\n10,1,5\n4,1,5\n";
        let mut src = csv_source(text);
        assert!(src.next_job().unwrap().is_some());
        let e = src.next_job().unwrap_err().to_string();
        assert!(e.contains("out-of-order"), "{e}");
        // The collect path sorts, rebases and re-ids instead.
        let jobs = read_csv_from(text.as_bytes(), "t").unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].arrival, 0.0);
        assert!((jobs[1].arrival - 6.0).abs() < 1e-12);
        assert_eq!((jobs[0].id, jobs[1].id), (0, 1));
    }

    #[test]
    fn csv_skip_bad_rows_counts_and_continues() {
        // Four data rows, two malformed (short row, bad GPU count).
        let text = "submit_time,n_gpus,iterations\n\
                    0,1,5\n\
                    1,2\n\
                    2,two,5\n\
                    3,1,9\n";
        // Strict mode still errors with the line number.
        let e = read_csv_from(text.as_bytes(), "t").unwrap_err().to_string();
        assert!(e.contains("line 3"), "{e}");
        // Tolerant mode keeps the good rows and counts the drops.
        let (jobs, skipped) = read_csv_from_counting(text.as_bytes(), "t", true).unwrap();
        assert_eq!(skipped, 2);
        assert_eq!(jobs.len(), 2);
        assert_eq!((jobs[0].iterations, jobs[1].iterations), (5, 9));
        // Ids stay sequential over the surviving rows.
        assert_eq!((jobs[0].id, jobs[1].id), (0, 1));
        // The streaming path honors the same toggle.
        let mut src = csv_source(text).skip_bad_rows(true);
        let got = drain(&mut src).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(src.skipped(), 2);
    }

    #[test]
    fn csv_rejects_negative_and_nonfinite_submit() {
        for bad in ["-1.0", "nan", "inf"] {
            let text = format!("submit_time,n_gpus,iterations\n{bad},1,5\n");
            let e = read_csv_from(text.as_bytes(), "t").unwrap_err().to_string();
            assert!(e.contains("submit time"), "{bad}: {e}");
            assert!(e.contains("line 2"), "{bad}: {e}");
        }
    }

    #[test]
    fn csv_skips_blank_and_comment_lines() {
        let mut src = csv_source(
            "# anonymized sample\n\nsubmit_time,n_gpus,iterations\n\n# mid comment\n0,1,5\n",
        );
        assert!(src.next_job().unwrap().is_some());
        assert!(src.next_job().unwrap().is_none());
    }

    #[test]
    fn loose_model_names() {
        assert_eq!(model_from_loose_name("VGG-16"), Some(DnnModel::Vgg16));
        assert_eq!(model_from_loose_name("vgg_16"), Some(DnnModel::Vgg16));
        assert_eq!(model_from_loose_name("inceptionv3"), Some(DnnModel::InceptionV3));
        assert_eq!(model_from_loose_name("LSTM PTB"), Some(DnnModel::LstmPtb));
        assert_eq!(model_from_loose_name("gpt2"), None);
    }
}
