//! The communication-contention model — Eqs (2) and (5) of the paper, plus
//! the AdaDUAL admission threshold derived from Theorem 2.
//!
//! Contention-free all-reduce: `T = a + b·M` with the paper's measured
//! constants on 2 nodes / 10 GbE: a = 6.69e-4 s, b = 8.53e-10 s/B.
//!
//! Under k-way contention: `T̄ = a + k·b·M + (k−1)·η·M` — bandwidth is
//! shared k ways (k·b·M) and an extra per-byte penalty η accrues per
//! additional contender. Equivalently the instantaneous per-byte transfer
//! time is `k·b + (k−1)·η`, which is how the event-driven simulator applies
//! the model to partially transferred messages when k changes mid-flight.

use crate::util::json::Json;

/// Contention-model parameters (a, b, η).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CommModel {
    /// Latency component of Eq (2) (seconds).
    pub a: f64,
    /// Per-byte time of Eq (2) (seconds/byte).
    pub b: f64,
    /// Per-byte contention penalty of Eq (5) (seconds/byte per extra task).
    pub eta: f64,
}

impl CommModel {
    /// The paper's fitted constants (Fig 2a) with η fitted from the Fig 2b
    /// k-way sweep (see `fit_eta` + docs/EXPERIMENTS.md §Fig2): η ≈ 0.3·b.
    pub fn paper_10gbe() -> CommModel {
        let b = 8.53e-10;
        CommModel { a: 6.69e-4, b, eta: 0.3 * b }
    }

    /// Per-byte constants scaled by `factor`, latency unchanged — how the
    /// `net` fabric derives an oversubscribed core uplink (factor = the
    /// oversubscription ratio, draining bytes `factor`× slower) or a
    /// faster NIC grade (factor < 1) from a base model.
    pub fn scaled(&self, factor: f64) -> CommModel {
        CommModel { a: self.a, b: self.b * factor, eta: self.eta * factor }
    }

    /// Eq (2): contention-free all-reduce of `m` bytes.
    pub fn time_free(&self, m: f64) -> f64 {
        self.a + self.b * m
    }

    /// Eq (5): all-reduce of `m` bytes entirely under k-way contention.
    pub fn time_contended(&self, m: f64, k: usize) -> f64 {
        assert!(k >= 1);
        let kf = k as f64;
        self.a + kf * self.b * m + (kf - 1.0) * self.eta * m
    }

    /// Instantaneous per-byte transfer time under k-way contention — the
    /// differential form of Eq (5) used when k changes mid-transfer.
    pub fn per_byte(&self, k: usize) -> f64 {
        assert!(k >= 1);
        let kf = k as f64;
        kf * self.b + (kf - 1.0) * self.eta
    }

    /// Effective bandwidth (bytes/s) seen by one task under k-way contention.
    pub fn rate(&self, k: usize) -> f64 {
        1.0 / self.per_byte(k)
    }

    /// Theorem 2's admission threshold: starting a new task of size
    /// `m_new` against an existing task with `m_old` bytes remaining
    /// lowers mean completion time iff `m_new / m_old < b / (2(b+η))`.
    pub fn adadual_threshold(&self) -> f64 {
        self.b / (2.0 * (self.b + self.eta))
    }

    /// The Theorem 2 test itself.
    pub fn overlap_beneficial(&self, m_new: f64, m_old_remaining: f64) -> bool {
        if m_old_remaining <= 0.0 {
            return true;
        }
        m_new / m_old_remaining < self.adadual_threshold()
    }

    /// Network-efficiency loss at k-way contention relative to round-robin
    /// ideal sharing (a + k·b·M): the paper's Fig 2b gap.
    pub fn efficiency(&self, m: f64, k: usize) -> f64 {
        let ideal = self.a + (k as f64) * self.b * m;
        ideal / self.time_contended(m, k)
    }

    /// Numeric sanity: latency and the contention penalty must be finite
    /// and non-negative, the per-byte time finite and strictly positive
    /// (`b == 0` would mean an infinite-bandwidth link and divides by
    /// zero in [`rate`](Self::rate)). Run on every ingestion path so bad
    /// constants surface as typed errors instead of NaNs deep in the
    /// simulator's float chain.
    pub fn validate(&self) -> Result<(), String> {
        if !self.a.is_finite() || self.a < 0.0 {
            return Err(format!("latency a must be finite and >= 0, got {}", self.a));
        }
        if !self.b.is_finite() || self.b <= 0.0 {
            return Err(format!("per-byte time b must be finite and > 0, got {}", self.b));
        }
        if !self.eta.is_finite() || self.eta < 0.0 {
            return Err(format!(
                "contention penalty eta must be finite and >= 0, got {}",
                self.eta
            ));
        }
        Ok(())
    }

    /// Scenario-file serialization (see docs/SCENARIOS.md).
    pub fn to_json(&self) -> Json {
        Json::obj().set("a", self.a).set("b", self.b).set("eta", self.eta)
    }

    pub fn from_json(v: &Json) -> Result<CommModel, String> {
        let m = CommModel {
            a: v.req_f64("a")?,
            b: v.req_f64("b")?,
            eta: v.req_f64("eta")?,
        };
        m.validate().map_err(|e| format!("comm model: {e}"))?;
        Ok(m)
    }
}

/// Fit η from (k, measured mean time) samples at fixed message size `m`,
/// least-squares on Eq (5) residuals against the already-known a and b.
/// This regenerates the paper's Fig 2(b) calibration step.
pub fn fit_eta(a: f64, b: f64, m: f64, samples: &[(usize, f64)]) -> f64 {
    // T - a - k b M = (k-1) η M  =>  η = Σ x·y / Σ x²  with x = (k-1)·M.
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for &(k, t) in samples {
        let x = (k as f64 - 1.0) * m;
        let y = t - a - (k as f64) * b * m;
        sxy += x * y;
        sxx += x * x;
    }
    if sxx == 0.0 {
        0.0
    } else {
        (sxy / sxx).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm() -> CommModel {
        CommModel::paper_10gbe()
    }

    #[test]
    fn eq5_reduces_to_eq2_at_k1() {
        let m = 100e6;
        assert!((cm().time_contended(m, 1) - cm().time_free(m)).abs() < 1e-12);
    }

    #[test]
    fn contended_time_superlinear_in_k() {
        let m = 100e6;
        let t1 = cm().time_contended(m, 1);
        let t2 = cm().time_contended(m, 2);
        let t4 = cm().time_contended(m, 4);
        assert!(t2 > 2.0 * t1 - cm().a); // worse than perfect sharing
        assert!(t4 > 2.0 * t2 - cm().a);
    }

    #[test]
    fn per_byte_matches_total_time() {
        let m = 50e6;
        for k in 1..=8 {
            let from_rate = cm().a + m * cm().per_byte(k);
            assert!((from_rate - cm().time_contended(m, k)).abs() < 1e-9);
        }
    }

    #[test]
    fn threshold_in_unit_interval() {
        let th = cm().adadual_threshold();
        assert!(th > 0.0 && th < 0.5, "{th}"); // < 1/2 always since η >= 0
    }

    #[test]
    fn overlap_decision_matches_threshold() {
        let c = cm();
        let m_old = 100e6;
        let th = c.adadual_threshold();
        assert!(c.overlap_beneficial(m_old * (th - 1e-6), m_old));
        assert!(!c.overlap_beneficial(m_old * (th + 1e-6), m_old));
    }

    #[test]
    fn efficiency_degrades_with_k() {
        let m = 100e6;
        let e2 = cm().efficiency(m, 2);
        let e4 = cm().efficiency(m, 4);
        let e8 = cm().efficiency(m, 8);
        assert!(e2 > e4 && e4 > e8);
        assert!(e8 > 0.5, "penalty should not be catastrophic: {e8}");
    }

    #[test]
    fn fit_eta_recovers_truth() {
        let c = cm();
        let m = 100e6;
        let samples: Vec<(usize, f64)> =
            (1..=8).map(|k| (k, c.time_contended(m, k))).collect();
        let eta = fit_eta(c.a, c.b, m, &samples);
        assert!((eta - c.eta).abs() / c.eta < 1e-9);
    }

    #[test]
    fn from_json_rejects_bad_constants() {
        for (a, b, eta) in [
            (f64::NAN, 1e-9, 0.0),
            (-1.0, 1e-9, 0.0),
            (1e-4, 0.0, 0.0),
            (1e-4, -1e-9, 0.0),
            (1e-4, f64::INFINITY, 0.0),
            (1e-4, 1e-9, -0.1),
            (1e-4, 1e-9, f64::NAN),
        ] {
            let v = Json::obj().set("a", a).set("b", b).set("eta", eta);
            let e = CommModel::from_json(&v).unwrap_err();
            assert!(e.starts_with("comm model:"), "({a},{b},{eta}): {e}");
        }
        assert!(CommModel::paper_10gbe().validate().is_ok());
    }

    #[test]
    fn fit_eta_zero_for_ideal_sharing() {
        let c = CommModel { eta: 0.0, ..cm() };
        let m = 10e6;
        let samples: Vec<(usize, f64)> =
            (1..=4).map(|k| (k, c.time_contended(m, k))).collect();
        assert_eq!(fit_eta(c.a, c.b, m, &samples), 0.0);
    }
}
