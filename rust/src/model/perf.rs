//! GPU compute performance model — Eqs (3)–(4) of the paper.
//!
//! `t_f = λ_f · B / P` and `t_b = λ_b · B / P`, where λ are per-model
//! workload coefficients, B is the mini-batch size and P the GPU's peak
//! throughput. Table III provides measured (t_f, t_b) at a reference batch
//! on a V100, from which λ is recovered; the model then scales to other
//! batch sizes and GPU grades.

use super::zoo::DnnModel;

/// Theoretical f32 peak of a Tesla V100 (GFLOPS) — the reference GPU.
pub const V100_PEAK_GFLOPS: f64 = 15_700.0;

/// Per-(model, GPU) compute-time calculator.
#[derive(Clone, Copy, Debug)]
pub struct PerfModel {
    /// λ_f · 1e-9 · (flop-ish unit): stored directly as s·GFLOPS/sample.
    lambda_f: f64,
    lambda_b: f64,
}

impl PerfModel {
    /// Recover λ from the Table III measurement of `model`.
    pub fn for_model(model: DnnModel) -> PerfModel {
        let s = model.spec();
        let b = s.batch_size as f64;
        PerfModel {
            lambda_f: s.t_fwd * V100_PEAK_GFLOPS / b,
            lambda_b: s.t_bwd * V100_PEAK_GFLOPS / b,
        }
    }

    /// Eq (3): feed-forward seconds for `batch` samples on a `peak_gflops` GPU.
    pub fn t_fwd(&self, batch: u32, peak_gflops: f64) -> f64 {
        self.lambda_f * batch as f64 / peak_gflops
    }

    /// Eq (4): backpropagation seconds.
    pub fn t_bwd(&self, batch: u32, peak_gflops: f64) -> f64 {
        self.lambda_b * batch as f64 / peak_gflops
    }

    /// Whole-iteration compute time (fwd + bwd), Eq (7) per-iteration part.
    pub fn t_iter(&self, batch: u32, peak_gflops: f64) -> f64 {
        self.t_fwd(batch, peak_gflops) + self.t_bwd(batch, peak_gflops)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo::ALL_MODELS;

    #[test]
    fn recovers_table3_at_reference_point() {
        for m in ALL_MODELS {
            let s = m.spec();
            let p = PerfModel::for_model(m);
            let tf = p.t_fwd(s.batch_size, V100_PEAK_GFLOPS);
            let tb = p.t_bwd(s.batch_size, V100_PEAK_GFLOPS);
            assert!((tf - s.t_fwd).abs() < 1e-12, "{}", s.name);
            assert!((tb - s.t_bwd).abs() < 1e-12, "{}", s.name);
        }
    }

    #[test]
    fn linear_in_batch() {
        let p = PerfModel::for_model(DnnModel::ResNet50);
        let t16 = p.t_fwd(16, V100_PEAK_GFLOPS);
        let t32 = p.t_fwd(32, V100_PEAK_GFLOPS);
        assert!((t32 / t16 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn inverse_in_peak() {
        let p = PerfModel::for_model(DnnModel::Vgg16);
        let fast = p.t_iter(16, 2.0 * V100_PEAK_GFLOPS);
        let slow = p.t_iter(16, V100_PEAK_GFLOPS);
        assert!((slow / fast - 2.0).abs() < 1e-9);
    }
}
