//! All-reduce algorithm cost models — Table I of the paper.
//!
//! Each algorithm's cost is `a + b·M` (Eq 2) with algorithm-specific
//! coefficients in terms of the α-β-γ model: α per-message latency,
//! β per-byte transfer time, γ per-byte reduction compute time.

/// The four algorithms of Table I.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AllReduceAlgo {
    BinaryTree,
    RecursiveDoubling,
    RecursiveHalvingDoubling,
    Ring,
}

pub const ALL_ALGOS: [AllReduceAlgo; 4] = [
    AllReduceAlgo::BinaryTree,
    AllReduceAlgo::RecursiveDoubling,
    AllReduceAlgo::RecursiveHalvingDoubling,
    AllReduceAlgo::Ring,
];

/// α-β-γ network/compute primitive costs.
#[derive(Clone, Copy, Debug)]
pub struct AlphaBetaGamma {
    /// Per-message latency (s).
    pub alpha: f64,
    /// Per-byte transfer time (s/B).
    pub beta: f64,
    /// Per-byte reduction compute time (s/B).
    pub gamma: f64,
}

impl AlphaBetaGamma {
    /// 10 GbE-ish defaults: ~25 µs latency, 10 Gbps line rate, γ = β/10.
    pub fn ethernet_10g() -> AlphaBetaGamma {
        let beta = 8.0 / 10.0e9; // s per byte at 10 Gbps
        AlphaBetaGamma { alpha: 25e-6, beta, gamma: beta / 10.0 }
    }
}

impl AllReduceAlgo {
    pub fn name(self) -> &'static str {
        match self {
            AllReduceAlgo::BinaryTree => "binary tree",
            AllReduceAlgo::RecursiveDoubling => "recursive doubling",
            AllReduceAlgo::RecursiveHalvingDoubling => "recursive halving and doubling",
            AllReduceAlgo::Ring => "ring",
        }
    }

    /// Table I: the (a, b) pair for `n` participating nodes.
    /// `n` must be >= 2 (a power of two per the paper's assumption; the
    /// formulas extend to any n >= 2 and we accept that generalisation).
    pub fn cost_coeffs(self, n: usize, p: AlphaBetaGamma) -> (f64, f64) {
        assert!(n >= 2, "all-reduce needs at least two nodes");
        let nf = n as f64;
        let log_n = (n as f64).log2();
        match self {
            AllReduceAlgo::BinaryTree => {
                (2.0 * p.alpha * log_n, (2.0 * p.beta + p.gamma) * log_n)
            }
            AllReduceAlgo::RecursiveDoubling => {
                (p.alpha * log_n, (p.beta + p.gamma) * log_n)
            }
            AllReduceAlgo::RecursiveHalvingDoubling => (
                2.0 * p.alpha * log_n,
                2.0 * p.beta - (2.0 * p.beta + p.gamma) / nf + p.gamma,
            ),
            AllReduceAlgo::Ring => (
                2.0 * (nf - 1.0) * p.alpha,
                2.0 * (nf - 1.0) / nf * p.beta + (nf - 1.0) / nf * p.gamma,
            ),
        }
    }

    /// Eq (2): contention-free all-reduce time for message of `m` bytes.
    pub fn time(self, n: usize, m: f64, p: AlphaBetaGamma) -> f64 {
        let (a, b) = self.cost_coeffs(n, p);
        a + b * m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> AlphaBetaGamma {
        AlphaBetaGamma::ethernet_10g()
    }

    #[test]
    fn coeffs_positive_and_monotone_in_n() {
        for algo in ALL_ALGOS {
            let (a2, b2) = algo.cost_coeffs(2, p());
            let (a8, _b8) = algo.cost_coeffs(8, p());
            assert!(a2 > 0.0 && b2 > 0.0, "{:?}", algo);
            assert!(a8 > a2, "{:?} latency should grow with n", algo);
        }
    }

    #[test]
    fn ring_bandwidth_term_approaches_2beta() {
        // b_ring -> 2β + γ as n -> ∞ (bandwidth-optimal family).
        let (_a, b) = AllReduceAlgo::Ring.cost_coeffs(1024, p());
        let limit = 2.0 * p().beta + p().gamma;
        assert!((b - limit).abs() / limit < 0.01);
    }

    #[test]
    fn halving_doubling_beats_doubling_for_large_messages() {
        let m = 500e6;
        let rd = AllReduceAlgo::RecursiveDoubling.time(16, m, p());
        let rhd = AllReduceAlgo::RecursiveHalvingDoubling.time(16, m, p());
        assert!(rhd < rd);
    }

    #[test]
    fn doubling_beats_ring_for_small_messages() {
        let m = 1e3;
        let rd = AllReduceAlgo::RecursiveDoubling.time(16, m, p());
        let ring = AllReduceAlgo::Ring.time(16, m, p());
        assert!(rd < ring);
    }

    #[test]
    fn time_is_affine_in_message() {
        let algo = AllReduceAlgo::Ring;
        let t0 = algo.time(4, 0.0, p());
        let t1 = algo.time(4, 1e6, p());
        let t2 = algo.time(4, 2e6, p());
        assert!(((t2 - t1) - (t1 - t0)).abs() < 1e-12);
    }
}
