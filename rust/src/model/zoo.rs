//! The DNN model zoo — Table III of the paper: per-model parameter size,
//! GPU memory footprint, batch size and measured fwd/bwd times on a Tesla
//! V100-16GB. These constants parameterise the simulator's compute tasks;
//! they are the paper's own measurements.

/// Identifies one of the four benchmark DNNs from Table III.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DnnModel {
    Vgg16,
    ResNet50,
    InceptionV3,
    LstmPtb,
}

pub const ALL_MODELS: [DnnModel; 4] = [
    DnnModel::Vgg16,
    DnnModel::ResNet50,
    DnnModel::InceptionV3,
    DnnModel::LstmPtb,
];

/// Table III row: training parameters + measured per-iteration times.
#[derive(Clone, Copy, Debug)]
pub struct ModelSpec {
    pub name: &'static str,
    /// Model (gradient message) size in bytes.
    pub model_bytes: f64,
    /// Device memory footprint in bytes while training at `batch_size`.
    pub mem_bytes: f64,
    pub batch_size: u32,
    /// Measured feed-forward time per iteration (seconds, V100).
    pub t_fwd: f64,
    /// Measured backpropagation time per iteration (seconds, V100).
    pub t_bwd: f64,
}

const MB: f64 = 1024.0 * 1024.0;

impl DnnModel {
    /// Table III constants (sizes MB -> bytes, times ms -> s).
    pub fn spec(self) -> ModelSpec {
        match self {
            DnnModel::Vgg16 => ModelSpec {
                name: "VGG-16",
                model_bytes: 526.4 * MB,
                mem_bytes: 4527.0 * MB,
                batch_size: 16,
                t_fwd: 35.8e-3,
                t_bwd: 53.7e-3,
            },
            DnnModel::ResNet50 => ModelSpec {
                name: "ResNet-50",
                model_bytes: 99.2 * MB,
                mem_bytes: 3213.0 * MB,
                batch_size: 16,
                t_fwd: 25.0e-3,
                t_bwd: 37.4e-3,
            },
            DnnModel::InceptionV3 => ModelSpec {
                name: "Inception-V3",
                model_bytes: 103.0 * MB,
                mem_bytes: 3291.0 * MB,
                batch_size: 16,
                t_fwd: 34.9e-3,
                t_bwd: 52.4e-3,
            },
            DnnModel::LstmPtb => ModelSpec {
                name: "LSTM-PTB",
                model_bytes: 251.8 * MB,
                mem_bytes: 2751.0 * MB,
                batch_size: 64,
                t_fwd: 31.5e-3,
                t_bwd: 47.3e-3,
            },
        }
    }

    pub fn from_name(name: &str) -> Option<DnnModel> {
        ALL_MODELS.iter().copied().find(|m| m.spec().name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_specs_sane() {
        for m in ALL_MODELS {
            let s = m.spec();
            assert!(s.model_bytes > 0.0 && s.mem_bytes > s.model_bytes, "{}", s.name);
            assert!(s.t_fwd > 0.0 && s.t_bwd > s.t_fwd, "{}", s.name);
            assert!(s.batch_size >= 16);
        }
    }

    #[test]
    fn vgg_is_largest_message() {
        let vgg = DnnModel::Vgg16.spec().model_bytes;
        for m in [DnnModel::ResNet50, DnnModel::InceptionV3, DnnModel::LstmPtb] {
            assert!(vgg > m.spec().model_bytes);
        }
    }

    #[test]
    fn name_roundtrip() {
        for m in ALL_MODELS {
            assert_eq!(DnnModel::from_name(m.spec().name), Some(m));
        }
        assert_eq!(DnnModel::from_name("nope"), None);
    }

    #[test]
    fn memory_fits_v100_16gb() {
        // Every model must fit at least 3x on one V100-16GB (the workload
        // packs multiple jobs per GPU).
        for m in ALL_MODELS {
            assert!(m.spec().mem_bytes * 3.0 < 16.0 * 1024.0 * MB);
        }
    }
}
