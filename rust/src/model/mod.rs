//! Performance models: the DNN zoo (Table III), GPU compute model
//! (Eqs 3–4), all-reduce algorithm costs (Table I) and the communication
//! contention model (Eqs 2 and 5).

pub mod allreduce;
pub mod comm;
pub mod perf;
pub mod zoo;

pub use allreduce::{AllReduceAlgo, AlphaBetaGamma, ALL_ALGOS};
pub use comm::{fit_eta, CommModel};
pub use perf::{PerfModel, V100_PEAK_GFLOPS};
pub use zoo::{DnnModel, ModelSpec, ALL_MODELS};
