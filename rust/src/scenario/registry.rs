//! Unified algorithm registry: the single string → constructor mapping for
//! placement and communication-scheduling algorithms. Replaces the two
//! ad-hoc `by_name` factories that previously lived in `placement` and
//! `sched` with duplicated alias tables; every frontend (CLI, scenario
//! files, benches, the live coordinator gate) resolves names here.

use crate::model::CommModel;
use crate::placement::{
    FirstFitPlacer, HealthAwarePlacer, ListSchedulingPlacer, LwfPlacer, Placer, RackLwfPlacer,
    RandomPlacer,
};
use crate::sched::{AdaDual, CommPolicy, SrsfCap};
use crate::util::error::{Error, Result};

/// Canonical placer names: the paper's Table IV four, then our
/// rack-locality extension (which needs a racked `net` topology to
/// differ from LWF — on a flat fabric it degenerates to LWF exactly),
/// then the gray-failure-aware placer (ranks GPUs by live + EWMA device
/// health; degenerates to LS on a healthy fleet).
pub const PLACERS: [&str; 6] = ["rand", "ff", "ls", "lwf", "lwf-rack", "health"];

/// The paper's Table IV placer axis (what `Experiment::paper_grid` and
/// the committed `scenarios/paper_grid.json` sweep).
pub const PAPER_PLACERS: [&str; 4] = ["rand", "ff", "ls", "lwf"];

/// Canonical policy names, in paper presentation order (Table V).
pub const POLICIES: [&str; 4] = ["srsf1", "srsf2", "srsf3", "ada"];

/// Trace-source kinds a scenario's `trace.source` field accepts
/// (docs/SCENARIOS.md §Trace sources). `csv` streams a raw cluster-trace
/// dump; `ddl-sched ingest` converts one into a committed `file` trace.
pub const TRACE_SOURCES: [&str; 4] = ["file", "generated", "inline", "csv"];

/// Resolve a placer name or alias to its canonical form.
pub fn canonical_placer(name: &str) -> Option<&'static str> {
    match name {
        "rand" | "RAND" | "random" => Some("rand"),
        "ff" | "FF" | "first-fit" => Some("ff"),
        "ls" | "LS" | "list-scheduling" => Some("ls"),
        "lwf" | "LWF" | "LWF-k" => Some("lwf"),
        "lwf-rack" | "LWF-rack" | "lwf_rack" | "rack" => Some("lwf-rack"),
        "health" | "HEALTH" | "health-aware" => Some("health"),
        _ => None,
    }
}

/// Resolve a policy name or alias to its canonical form.
pub fn canonical_policy(name: &str) -> Option<&'static str> {
    match name {
        "srsf1" | "SRSF(1)" => Some("srsf1"),
        "srsf2" | "SRSF(2)" => Some("srsf2"),
        "srsf3" | "SRSF(3)" => Some("srsf3"),
        "ada" | "adadual" | "AdaDUAL" | "Ada-SRSF" => Some("ada"),
        _ => None,
    }
}

/// Construct a placer. `kappa` is LWF's consolidation threshold; `seed`
/// feeds the RAND baseline (ignored by the deterministic placers);
/// `rack_size` is the fabric's rack width for the rack-locality placer
/// (pass `TopologySpec::rack_size()` — `usize::MAX` on rackless fabrics,
/// where LWF-rack degenerates to LWF).
pub fn make_placer(
    name: &str,
    kappa: usize,
    seed: u64,
    rack_size: usize,
) -> Result<Box<dyn Placer + Send>> {
    match canonical_placer(name) {
        Some("rand") => Ok(Box::new(RandomPlacer::new(seed))),
        Some("ff") => Ok(Box::new(FirstFitPlacer)),
        Some("ls") => Ok(Box::new(ListSchedulingPlacer)),
        Some("lwf") => Ok(Box::new(LwfPlacer::new(kappa))),
        Some("lwf-rack") => Ok(Box::new(RackLwfPlacer::new(kappa, rack_size))),
        Some("health") => Ok(Box::new(HealthAwarePlacer::new())),
        _ => Err(unknown("placer", name, &PLACERS)),
    }
}

/// Construct a communication admission policy. The box is `Send + Sync` so
/// policies can be shared across experiment workers and live job threads.
pub fn make_policy(name: &str, comm: CommModel) -> Result<Box<dyn CommPolicy + Send + Sync>> {
    match canonical_policy(name) {
        Some("srsf1") => Ok(Box::new(SrsfCap { cap: 1 })),
        Some("srsf2") => Ok(Box::new(SrsfCap { cap: 2 })),
        Some("srsf3") => Ok(Box::new(SrsfCap { cap: 3 })),
        Some("ada") => Ok(Box::new(AdaDual { model: comm })),
        _ => Err(unknown("policy", name, &POLICIES)),
    }
}

/// Paper-style display label for a placer ("LWF-1", "RAND", ...).
pub fn placer_label(name: &str, kappa: usize) -> String {
    match canonical_placer(name) {
        Some("lwf") => format!("LWF-{kappa}"),
        Some("lwf-rack") => format!("LWF-rack-{kappa}"),
        Some(c) => c.to_uppercase(),
        None => name.to_string(),
    }
}

/// Paper-style display label for a policy ("SRSF(1)", "Ada-SRSF", ...).
pub fn policy_label(name: &str) -> String {
    match canonical_policy(name) {
        Some("ada") => "Ada-SRSF".to_string(),
        Some(c) => format!("SRSF({})", &c[4..]),
        None => name.to_string(),
    }
}

fn unknown(kind: &str, name: &str, known: &[&str]) -> Error {
    Error::msg(format!("unknown {kind} '{name}' (known: {})", known.join(", ")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_canonical_placer_resolves() {
        for name in PLACERS {
            assert_eq!(canonical_placer(name), Some(name));
            let p = make_placer(name, 1, 0, usize::MAX).unwrap();
            assert!(!p.name().is_empty());
        }
        // The paper axis is a strict prefix of the full list.
        assert_eq!(&PLACERS[..PAPER_PLACERS.len()], &PAPER_PLACERS[..]);
    }

    #[test]
    fn every_canonical_policy_resolves() {
        let cm = CommModel::paper_10gbe();
        for name in POLICIES {
            assert_eq!(canonical_policy(name), Some(name));
            let p = make_policy(name, cm).unwrap();
            assert!(!p.name().is_empty());
        }
    }

    #[test]
    fn every_trace_source_kind_parses() {
        use crate::scenario::TraceSource;
        use crate::util::json::Json;
        // The registry list and the TraceSource parser must agree: every
        // listed kind is recognized (even if its payload is then missing).
        for kind in TRACE_SOURCES {
            let v = Json::obj().set("source", kind);
            let err = match TraceSource::from_json(&v) {
                Ok(_) => continue,
                Err(e) => e.to_string(),
            };
            assert!(!err.contains("unknown trace source"), "'{kind}' not recognized: {err}");
        }
        let v = Json::obj().set("source", "parquet");
        let err = TraceSource::from_json(&v).unwrap_err().to_string();
        assert!(err.contains("file|generated|inline|csv"), "{err}");
    }

    #[test]
    fn aliases_resolve_to_canonical() {
        assert_eq!(canonical_placer("health-aware"), Some("health"));
        assert_eq!(canonical_placer("LWF-k"), Some("lwf"));
        assert_eq!(canonical_placer("RAND"), Some("rand"));
        assert_eq!(canonical_placer("rack"), Some("lwf-rack"));
        assert_eq!(canonical_placer("LWF-rack"), Some("lwf-rack"));
        assert_eq!(canonical_policy("Ada-SRSF"), Some("ada"));
        assert_eq!(canonical_policy("SRSF(2)"), Some("srsf2"));
    }

    #[test]
    fn unknown_names_error_and_list_known() {
        let e = make_placer("nope", 1, 0, usize::MAX).unwrap_err().to_string();
        assert!(e.contains("unknown placer 'nope'") && e.contains("lwf"), "{e}");
        let e = make_policy("bogus", CommModel::paper_10gbe()).unwrap_err().to_string();
        assert!(e.contains("unknown policy 'bogus'") && e.contains("ada"), "{e}");
    }

    #[test]
    fn labels_match_paper_spelling() {
        assert_eq!(placer_label("lwf", 4), "LWF-4");
        assert_eq!(placer_label("lwf-rack", 2), "LWF-rack-2");
        assert_eq!(placer_label("rand", 1), "RAND");
        assert_eq!(placer_label("ff", 1), "FF");
        assert_eq!(policy_label("ada"), "Ada-SRSF");
        assert_eq!(policy_label("srsf3"), "SRSF(3)");
    }

    #[test]
    fn lwf_kappa_threading() {
        let mut p = make_placer("lwf", 2, 0, usize::MAX).unwrap();
        let st = crate::cluster::ClusterState::new(crate::cluster::ClusterSpec::tiny(2, 2));
        let job = crate::trace::JobSpec {
            id: 0,
            arrival: 0.0,
            model: crate::model::DnnModel::ResNet50,
            n_gpus: 2,
            iterations: 10,
        };
        assert!(p.place(&job, &st).is_some());
    }
}
