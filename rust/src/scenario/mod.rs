//! Declarative run specifications — the public API of the simulator.
//!
//! A [`Scenario`] names everything one simulation run needs: cluster shape,
//! Eq (5) contention model, fabric topology (`net::TopologySpec`; the
//! default `flat` preset is elided from JSON so paper-era files and
//! records stay byte-stable), trace source (file | generated | inline |
//! csv),
//! placer + κ, communication policy, job priority, repricing mode, the
//! RNG seed, and optionally which observer sinks to attach
//! ([`OutputSpec`]: JSONL event stream, per-GPU timeline, per-link
//! contention profile — `sim::observe`). Scenarios serialize to JSON (`util::json`), so every
//! evaluation setup is a shareable data file instead of hand-wired code —
//! see docs/SCENARIOS.md for the schema.
//!
//! [`registry`] is the single string → algorithm mapping (placers and
//! policies, with their paper-style labels); [`experiment`] expands a
//! scenario across grid axes and executes the grid on `std::thread`
//! workers, collecting deterministic [`RunRecord`]s.
//!
//! ```no_run
//! use ddl_sched::prelude::*;
//!
//! let record = Scenario::paper().run().unwrap();
//! println!("avg JCT: {:.1}s", record.eval.jct.mean);
//! ```

pub mod experiment;
pub mod registry;

pub use experiment::{records_to_csv, records_to_json, Experiment, RunRecord};

use crate::cluster::ClusterSpec;
use crate::fault::{FaultPlan, FaultsSpec};
use crate::metrics::Evaluation;
use crate::model::CommModel;
use crate::net::TopologySpec;
use crate::placement::Placer;
use crate::sched::CommPolicy;
use crate::sim::{self, JobPriority, Repricing, SimConfig};
use crate::source::{self, CsvTraceSource, JobSource, VecSource};
use crate::trace::{self, JobSpec, TraceConfig};
use crate::util::error::{Context, Error, Result};
use crate::util::json::Json;

/// Where a scenario's jobs come from.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceSource {
    /// A trace JSON file (as written by `ddl-sched trace-gen`).
    File(String),
    /// Generate `jobs` jobs with the §V-A workload shape. `seed: None`
    /// inherits the scenario seed, which makes the experiment seed axis
    /// vary the workload and the RAND placer together.
    Generated { jobs: usize, seed: Option<u64> },
    /// Jobs spelled out inline in the scenario file.
    Inline(Vec<JobSpec>),
    /// A raw cluster-trace CSV (Alibaba/Philly-style column names; see
    /// docs/SCENARIOS.md for the column contract). Resolved through the
    /// streaming CSV reader and normalized — sorted by submit time,
    /// rebased to t = 0, re-id'd. `ddl-sched ingest` converts such a file
    /// into a committed trace JSON for `file` sources.
    Csv(String),
}

impl TraceSource {
    fn to_json(&self) -> Json {
        match self {
            TraceSource::File(path) => {
                Json::obj().set("source", "file").set("path", path.as_str())
            }
            TraceSource::Generated { jobs, seed } => {
                let v = Json::obj().set("source", "generated").set("jobs", *jobs);
                match seed {
                    Some(s) => v.set("seed", *s),
                    None => v,
                }
            }
            TraceSource::Inline(jobs) => Json::obj()
                .set("source", "inline")
                .set("jobs", Json::Arr(jobs.iter().map(JobSpec::to_json).collect())),
            TraceSource::Csv(path) => {
                Json::obj().set("source", "csv").set("path", path.as_str())
            }
        }
    }

    pub(crate) fn from_json(v: &Json) -> Result<TraceSource, String> {
        match v.req_str("source")? {
            "file" => Ok(TraceSource::File(v.req_str("path")?.to_string())),
            "generated" => Ok(TraceSource::Generated {
                jobs: v.req_usize("jobs")?,
                seed: v.get("seed").and_then(Json::as_u64),
            }),
            "inline" => {
                let arr = v
                    .get("jobs")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| "inline trace needs a 'jobs' array".to_string())?;
                Ok(TraceSource::Inline(
                    arr.iter().map(JobSpec::from_json).collect::<Result<_, _>>()?,
                ))
            }
            "csv" => Ok(TraceSource::Csv(v.req_str("path")?.to_string())),
            other => Err(format!("unknown trace source '{other}' (file|generated|inline|csv)")),
        }
    }
}

/// Optional per-run output sinks (`sim::observe`), elided from JSON when
/// empty so the pre-observer scenario corpus stays byte-stable. Paths
/// are created/truncated at run time; sinks are pure taps — attaching
/// them never changes the run's metrics or its method label.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct OutputSpec {
    /// Stream every typed `SimEvent` as JSON Lines (constant memory).
    pub events: Option<String>,
    /// Per-GPU Gantt rows, JSON (`sim::TimelineObserver`).
    pub timeline: Option<String>,
    /// Per-link time-at-contention-level histogram, JSON
    /// (`sim::ContentionProfiler`).
    pub contention: Option<String>,
}

impl OutputSpec {
    /// No sinks: the engine runs with the metrics observer alone.
    pub fn is_default(&self) -> bool {
        *self == OutputSpec::default()
    }

    fn to_json(&self) -> Json {
        let mut v = Json::obj();
        if let Some(p) = &self.events {
            v = v.set("events", p.as_str());
        }
        if let Some(p) = &self.timeline {
            v = v.set("timeline", p.as_str());
        }
        if let Some(p) = &self.contention {
            v = v.set("contention", p.as_str());
        }
        v
    }

    fn from_json(v: &Json) -> Result<OutputSpec, String> {
        let Json::Obj(entries) = v else {
            return Err("'outputs' must be an object".to_string());
        };
        for (key, val) in entries {
            if !matches!(key.as_str(), "events" | "timeline" | "contention") {
                return Err(format!(
                    "unknown outputs key '{key}' (events|timeline|contention)"
                ));
            }
            if val.as_str().is_none() {
                return Err(format!("outputs '{key}' must be a file path string"));
            }
        }
        let path = |key: &str| v.get(key).and_then(Json::as_str).map(str::to_string);
        Ok(OutputSpec {
            events: path("events"),
            timeline: path("timeline"),
            contention: path("contention"),
        })
    }
}

/// One fully-specified simulation run.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    /// Free-form scenario name (carried into records and file names).
    pub name: String,
    pub cluster: ClusterSpec,
    pub comm: CommModel,
    /// Fabric topology; `comm` is the base link model the presets derive
    /// per-link parameters from. `Flat` reproduces the paper testbed.
    pub topology: TopologySpec,
    pub trace: TraceSource,
    /// Registry placer name (see [`registry::PLACERS`]).
    pub placer: String,
    /// LWF-κ consolidation threshold.
    pub kappa: usize,
    /// Registry policy name (see [`registry::POLICIES`]).
    pub policy: String,
    pub priority: JobPriority,
    pub repricing: Repricing,
    /// Steady-state iteration fast-forwarding in the engine (default on).
    /// A pure speed knob: results are identical either way
    /// (property-tested), so it never appears in labels, and the default
    /// is elided from JSON to keep pre-existing files byte-stable.
    pub coalescing: bool,
    /// Optional observer sinks to attach to the run (elided-by-default;
    /// docs/SCENARIOS.md §Outputs).
    pub outputs: OutputSpec,
    /// Optional fault-injection section: explicit failure timeline and/or
    /// MTBF/MTTR generator plus checkpoint/restart knobs. `None` (the
    /// default, elided from JSON) runs the classic healthy-fabric engine
    /// bit-for-bit (docs/SCENARIOS.md §Faults).
    pub faults: Option<FaultsSpec>,
    /// Seeds the RAND placer, any `Generated` trace without its own seed,
    /// and any fault generator without its own seed.
    pub seed: u64,
}

impl Scenario {
    /// The paper's evaluation setup: 160-job §V-A workload on the 64-GPU
    /// 10 GbE testbed, LWF-1 placement, Ada-SRSF admission.
    pub fn paper() -> Scenario {
        Scenario {
            name: "paper".to_string(),
            cluster: ClusterSpec::paper_64gpu(),
            comm: CommModel::paper_10gbe(),
            topology: TopologySpec::Flat,
            trace: TraceSource::Generated { jobs: 160, seed: None },
            placer: "lwf".to_string(),
            kappa: 1,
            policy: "ada".to_string(),
            priority: JobPriority::Srsf,
            repricing: Repricing::AtAdmission,
            coalescing: true,
            outputs: OutputSpec::default(),
            faults: None,
            seed: 42,
        }
    }

    /// A scaled-down variant for tests and demos: `jobs` jobs on a
    /// `n_servers × gpus_per_server` cluster.
    pub fn small(name: &str, n_servers: usize, gpus_per_server: usize, jobs: usize) -> Scenario {
        Scenario {
            name: name.to_string(),
            cluster: ClusterSpec::tiny(n_servers, gpus_per_server),
            trace: TraceSource::Generated { jobs, seed: None },
            ..Scenario::paper()
        }
    }

    /// Paper-style method label, e.g. `LWF-1/Ada-SRSF` (plus `/fifo`,
    /// `/las` or `/dynamic` markers when those axes leave paper defaults).
    pub fn label(&self) -> String {
        let mut label = format!(
            "{}/{}",
            registry::placer_label(&self.placer, self.kappa),
            registry::policy_label(&self.policy)
        );
        if self.priority != JobPriority::Srsf {
            label.push('/');
            label.push_str(self.priority.name());
        }
        if self.repricing != Repricing::AtAdmission {
            label.push('/');
            label.push_str(self.repricing.name());
        }
        if let Some(topo) = self.topology.label() {
            label.push('/');
            label.push_str(&topo);
        }
        if self.faults.is_some() {
            label.push_str("/faults");
        }
        label
    }

    /// The engine configuration this scenario describes — minus the fault
    /// timeline, which needs fallible compilation: `faults` is left empty
    /// here and filled in by callers via [`Scenario::fault_plan`] (as
    /// [`Scenario::run`] does).
    pub fn sim_config(&self) -> SimConfig {
        SimConfig {
            cluster: self.cluster,
            comm: self.comm,
            topology: self.topology.clone(),
            repricing: self.repricing,
            priority: self.priority,
            coalescing: self.coalescing,
            log_events: false,
            workers: 1,
            faults: FaultPlan::default(),
        }
    }

    /// Compile the `faults` section (if any) into the primitive timeline
    /// the engine consumes. `None` compiles to the empty plan — the
    /// engine's bit-identical healthy-fabric mode.
    pub fn fault_plan(&self) -> Result<FaultPlan> {
        match &self.faults {
            None => Ok(FaultPlan::default()),
            Some(spec) => spec
                .compile(&self.cluster, self.topology.n_links(&self.cluster), self.seed)
                .with_context(|| format!("scenario '{}' faults section", self.name)),
        }
    }

    /// The complete engine configuration: [`Scenario::sim_config`] with
    /// the fault timeline compiled in — what `run` assembles internally,
    /// exposed for direct [`sim::SimState`] / env construction (the
    /// `rollout` CLI subcommand).
    pub fn engine_config(&self) -> Result<SimConfig> {
        let mut cfg = self.sim_config();
        cfg.faults = self.fault_plan()?;
        Ok(cfg)
    }

    /// Resolve the trace source into concrete jobs.
    pub fn jobs(&self) -> Result<Vec<JobSpec>> {
        match &self.trace {
            TraceSource::File(path) => {
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("reading trace file '{path}'"))?;
                trace::from_json(&text).map_err(Error::msg)
            }
            TraceSource::Generated { jobs, seed } => {
                let seed = seed.unwrap_or(self.seed);
                let cfg = if *jobs == 160 {
                    TraceConfig { seed, ..TraceConfig::paper_160() }
                } else {
                    TraceConfig::scaled(*jobs, seed)
                };
                let mut jobs = trace::generate(&cfg);
                // The scaled §V-A histogram can emit jobs wider than a small
                // scenario cluster; clamp so every generated workload is
                // placeable (the paper setup is never affected: 32 <= 64).
                let cap = self.cluster.n_gpus();
                for j in &mut jobs {
                    j.n_gpus = j.n_gpus.min(cap);
                }
                Ok(jobs)
            }
            TraceSource::Inline(jobs) => Ok(jobs.clone()),
            TraceSource::Csv(path) => source::read_csv_jobs(path),
        }
    }

    /// Resolve the trace section into a streaming [`JobSource`] for
    /// [`sim::simulate_stream`]. File / generated / inline sources
    /// materialize exactly the jobs [`Scenario::jobs`] returns, so a
    /// streamed run is bit-identical to the batch path (property-tested
    /// in `sim::tests`); a `csv` source streams the file line-by-line and
    /// never holds the full trace in memory.
    pub fn job_source(&self) -> Result<Box<dyn JobSource>> {
        if let TraceSource::Csv(path) = &self.trace {
            return Ok(Box::new(CsvTraceSource::open(path)?));
        }
        let jobs = self.jobs()?;
        if !jobs.windows(2).all(|w| w[0].arrival <= w[1].arrival) {
            return Err(Error::msg(format!(
                "scenario '{}': trace is not arrival-sorted; streaming runs need source \
                 order (sort the jobs, or convert with 'ddl-sched ingest')",
                self.name
            )));
        }
        Ok(Box::new(VecSource::new(jobs)))
    }

    /// The seed that actually drives a `Generated` trace; `None` for
    /// file/inline sources (their content is seed-independent).
    pub(crate) fn effective_trace_seed(&self) -> Option<u64> {
        match &self.trace {
            TraceSource::Generated { seed, .. } => Some(seed.unwrap_or(self.seed)),
            _ => None,
        }
    }

    /// Execute the scenario: resolve the trace, build the algorithms from
    /// the [`registry`], run the simulator and evaluate. Deterministic for
    /// a fixed scenario — this is what makes parallel experiment runs
    /// byte-identical to serial ones.
    pub fn run(&self) -> Result<RunRecord> {
        self.run_with_jobs(&self.jobs()?)
    }

    /// Core execution against an already-resolved workload.
    /// `Experiment::run` resolves each unique trace once and shares it
    /// across grid cells instead of re-reading/regenerating per cell.
    pub(crate) fn run_with_jobs(&self, jobs: &[JobSpec]) -> Result<RunRecord> {
        if jobs.is_empty() {
            return Err(Error::msg(format!(
                "scenario '{}' resolves to an empty workload",
                self.name
            )));
        }
        let mut cfg = self.sim_config();
        cfg.faults = self.fault_plan()?;
        let mut placer = registry::make_placer(
            &self.placer,
            self.kappa,
            self.seed,
            self.topology.rack_size(),
        )?;
        let policy = registry::make_policy(&self.policy, self.comm)?;
        let res = if self.outputs.is_default() {
            sim::simulate(&cfg, jobs, placer.as_mut(), policy.as_ref())
        } else {
            self.run_with_sinks(&cfg, jobs, placer.as_mut(), policy.as_ref())?
        };
        if !res.jct.iter().any(|t| t.is_finite()) {
            return Err(Error::msg(format!(
                "scenario '{}': no job finished (workload infeasible on this cluster?)",
                self.name
            )));
        }
        let eval = Evaluation::from_sim(&self.label(), &res);
        Ok(RunRecord {
            scenario: self.clone(),
            eval,
            n_events: res.n_events,
            max_contention: res.max_contention,
        })
    }

    /// Observer-instrumented execution: attach the sinks the `outputs`
    /// section asks for alongside the metrics observer, write the
    /// collected artifacts, and return the same facade `SimResult` a
    /// sink-less run produces (sinks are pure taps — bit-identical
    /// metrics either way).
    fn run_with_sinks(
        &self,
        cfg: &SimConfig,
        jobs: &[JobSpec],
        placer: &mut dyn Placer,
        policy: &dyn CommPolicy,
    ) -> Result<sim::SimResult> {
        let mut metrics = sim::MetricsObserver::new();
        let mut events = match &self.outputs.events {
            Some(path) => {
                let f = std::fs::File::create(path)
                    .with_context(|| format!("creating events sink '{path}'"))?;
                Some(sim::JsonlSink::new(std::io::BufWriter::new(f)))
            }
            None => None,
        };
        let mut timeline = self.outputs.timeline.as_ref().map(|_| sim::TimelineObserver::new());
        let mut contention =
            self.outputs.contention.as_ref().map(|_| sim::ContentionProfiler::new());
        {
            let mut obs: Vec<&mut dyn sim::SimObserver> = vec![&mut metrics];
            if let Some(s) = events.as_mut() {
                obs.push(s);
            }
            if let Some(t) = timeline.as_mut() {
                obs.push(t);
            }
            if let Some(c) = contention.as_mut() {
                obs.push(c);
            }
            sim::simulate_observed(cfg, jobs, placer, policy, &mut obs);
        }
        if let Some(sink) = events {
            let path = self.outputs.events.as_deref().unwrap_or_default();
            sink.finish().with_context(|| format!("writing events sink '{path}'"))?;
        }
        if let Some(tl) = &timeline {
            let path = self.outputs.timeline.as_deref().unwrap_or_default();
            std::fs::write(path, tl.to_json().to_string_pretty())
                .with_context(|| format!("writing timeline '{path}'"))?;
        }
        if let Some(cp) = &contention {
            let path = self.outputs.contention.as_deref().unwrap_or_default();
            std::fs::write(path, cp.to_json().to_string_pretty())
                .with_context(|| format!("writing contention profile '{path}'"))?;
        }
        Ok(metrics.into_result())
    }

    // ---- serialization -----------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut v = Json::obj()
            .set("name", self.name.as_str())
            .set("cluster", self.cluster.to_json())
            .set("comm", self.comm.to_json());
        // The default flat fabric is elided so flat scenarios — the whole
        // pre-topology corpus, paper grid included — serialize (and hence
        // hash/diff) byte-identically to the old schema.
        if !self.topology.is_flat() {
            v = v.set("topology", self.topology.to_json());
        }
        let mut v = v
            .set("trace", self.trace.to_json())
            .set("placer", self.placer.as_str())
            .set("kappa", self.kappa)
            .set("policy", self.policy.as_str())
            .set("priority", self.priority.name())
            .set("repricing", self.repricing.name());
        // Like the flat topology, the default (on) is elided: coalescing
        // is a pure engine-speed knob with identical results, and
        // pre-existing scenario files must stay byte-stable.
        if !self.coalescing {
            v = v.set("coalescing", false);
        }
        // Same elision rule for the observer sinks: empty means none.
        if !self.outputs.is_default() {
            v = v.set("outputs", self.outputs.to_json());
        }
        // And for faults: an absent section means the healthy fabric, so
        // the entire pre-fault scenario corpus stays byte-stable.
        if let Some(f) = &self.faults {
            v = v.set("faults", f.to_json());
        }
        v.set("seed", self.seed)
    }

    /// Pretty JSON text (the shareable artifact form).
    pub fn to_json_text(&self) -> String {
        self.to_json().to_string_pretty()
    }

    pub fn from_json(v: &Json) -> Result<Scenario> {
        let placer = v.req_str("placer").map_err(Error::msg)?.to_string();
        let policy = v.req_str("policy").map_err(Error::msg)?.to_string();
        // Validate algorithm names eagerly so a bad scenario file fails at
        // load time, not mid-experiment.
        registry::make_placer(&placer, 1, 0, usize::MAX)?;
        registry::make_policy(&policy, CommModel::paper_10gbe())?;
        let priority = v.req_str("priority").map_err(Error::msg)?;
        let repricing = v.req_str("repricing").map_err(Error::msg)?;
        let cluster = ClusterSpec::from_json(
            v.get("cluster").ok_or_else(|| Error::msg("missing 'cluster'"))?,
        )
        .map_err(Error::msg)?;
        // An absent topology section means the paper's flat switch, so
        // every pre-topology scenario file keeps loading unchanged.
        let topology = match v.get("topology") {
            None => TopologySpec::Flat,
            Some(t) => TopologySpec::from_json(t).map_err(Error::msg)?,
        };
        topology.validate(&cluster).map_err(Error::msg)?;
        // Absent means the default: fast-forwarding on.
        let coalescing = match v.get("coalescing") {
            None => true,
            Some(c) => c
                .as_bool()
                .ok_or_else(|| Error::msg("'coalescing' must be a boolean (true|false)"))?,
        };
        // Absent means the default: no sinks attached.
        let outputs = match v.get("outputs") {
            None => OutputSpec::default(),
            Some(o) => OutputSpec::from_json(o).map_err(Error::msg)?,
        };
        // Absent means the default: no faults, healthy fabric throughout.
        // Present sections are validated against the cluster and fabric
        // eagerly so bad ids fail at load time, not mid-experiment.
        let faults = match v.get("faults") {
            None => None,
            Some(f) => {
                let spec = FaultsSpec::from_json(f)?;
                spec.validate(&cluster, topology.n_links(&cluster))?;
                Some(spec)
            }
        };
        Ok(Scenario {
            name: v.req_str("name").map_err(Error::msg)?.to_string(),
            cluster,
            comm: CommModel::from_json(
                v.get("comm").ok_or_else(|| Error::msg("missing 'comm'"))?,
            )
            .map_err(Error::msg)?,
            topology,
            trace: TraceSource::from_json(
                v.get("trace").ok_or_else(|| Error::msg("missing 'trace'"))?,
            )
            .map_err(Error::msg)?,
            placer,
            kappa: v.req_usize("kappa").map_err(Error::msg)?,
            policy,
            priority: JobPriority::parse(priority).ok_or_else(|| {
                Error::msg(format!("unknown priority '{priority}' (srsf|fifo|las)"))
            })?,
            repricing: Repricing::parse(repricing).ok_or_else(|| {
                Error::msg(format!("unknown repricing '{repricing}' (at-admission|dynamic)"))
            })?,
            coalescing,
            outputs,
            faults,
            seed: v.req_u64("seed").map_err(Error::msg)?,
        })
    }

    /// Parse a scenario from JSON text.
    pub fn from_text(text: &str) -> Result<Scenario> {
        let v = Json::parse(text).context("parsing scenario JSON")?;
        Scenario::from_json(&v)
    }

    /// Load a scenario from a JSON file.
    pub fn from_file(path: &str) -> Result<Scenario> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading scenario file '{path}'"))?;
        Scenario::from_text(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::DnnModel;

    #[test]
    fn paper_scenario_json_roundtrip() {
        let s = Scenario::paper();
        let text = s.to_json_text();
        let back = Scenario::from_text(&text).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn nondefault_scenario_json_roundtrip() {
        let s = Scenario {
            name: "ablate".into(),
            cluster: ClusterSpec::tiny(3, 2),
            comm: CommModel { a: 1e-3, b: 9e-10, eta: 2.5e-10 },
            topology: TopologySpec::Flat,
            trace: TraceSource::Generated { jobs: 24, seed: Some(9) },
            placer: "rand".into(),
            kappa: 4,
            policy: "srsf2".into(),
            priority: JobPriority::Las,
            repricing: Repricing::Dynamic,
            coalescing: false,
            outputs: OutputSpec::default(),
            faults: None,
            seed: 7,
        };
        let back = Scenario::from_text(&s.to_json_text()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn inline_trace_roundtrip() {
        let jobs = vec![
            JobSpec { id: 0, arrival: 0.0, model: DnnModel::ResNet50, n_gpus: 2, iterations: 30 },
            JobSpec { id: 1, arrival: 5.5, model: DnnModel::Vgg16, n_gpus: 4, iterations: 10 },
        ];
        let s = Scenario {
            trace: TraceSource::Inline(jobs.clone()),
            ..Scenario::small("inline", 2, 2, 0)
        };
        let back = Scenario::from_text(&s.to_json_text()).unwrap();
        assert_eq!(back.jobs().unwrap(), jobs);
    }

    #[test]
    fn file_trace_source_roundtrip_and_load() {
        let jobs = trace::generate(&TraceConfig::scaled(8, 3));
        let path = std::env::temp_dir().join("ddl_sched_scenario_trace_test.json");
        std::fs::write(&path, trace::to_json(&jobs)).unwrap();
        let s = Scenario {
            trace: TraceSource::File(path.to_string_lossy().into_owned()),
            ..Scenario::small("file", 2, 2, 0)
        };
        let back = Scenario::from_text(&s.to_json_text()).unwrap();
        assert_eq!(s, back);
        assert_eq!(back.jobs().unwrap(), jobs);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn csv_trace_source_roundtrip_load_and_stream() {
        let path = std::env::temp_dir().join("ddl_sched_scenario_trace_test.csv");
        std::fs::write(
            &path,
            "# anonymized sample\n\
             job_id,submit_time,model,n_gpus,iterations\n\
             j1,100.0,resnet50,2,30\n\
             j2,103.5,vgg16,4,10\n",
        )
        .unwrap();
        let s = Scenario {
            trace: TraceSource::Csv(path.to_string_lossy().into_owned()),
            ..Scenario::small("csv", 2, 2, 0)
        };
        let back = Scenario::from_text(&s.to_json_text()).unwrap();
        assert_eq!(s, back);
        let jobs = s.jobs().unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].arrival, 0.0); // rebased to t = 0
        assert!((jobs[1].arrival - 3.5).abs() < 1e-12);
        assert_eq!(jobs[0].model, DnnModel::ResNet50);
        assert_eq!(jobs[1].n_gpus, 4);
        // The streaming source yields exactly the batch jobs.
        let streamed = crate::source::drain(s.job_source().unwrap().as_mut()).unwrap();
        assert_eq!(streamed, jobs);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn unknown_trace_source_lists_csv() {
        let text = Scenario::paper().to_json_text().replace("\"generated\"", "\"parquet\"");
        let e = Scenario::from_text(&text).unwrap_err().to_string();
        assert!(e.contains("file|generated|inline|csv"), "{e}");
    }

    #[test]
    fn job_source_matches_jobs_for_generated_and_inline() {
        let s = Scenario::small("src", 2, 2, 10);
        let streamed = crate::source::drain(s.job_source().unwrap().as_mut()).unwrap();
        assert_eq!(streamed, s.jobs().unwrap());
        // Unsorted inline traces are rejected with a pointer to ingest.
        let s = Scenario {
            trace: TraceSource::Inline(vec![
                JobSpec { id: 0, arrival: 9.0, model: DnnModel::Vgg16, n_gpus: 1, iterations: 5 },
                JobSpec { id: 1, arrival: 2.0, model: DnnModel::Vgg16, n_gpus: 1, iterations: 5 },
            ]),
            ..Scenario::small("unsorted", 2, 2, 0)
        };
        let e = s.job_source().unwrap_err().to_string();
        assert!(e.contains("arrival-sorted"), "{e}");
    }

    #[test]
    fn from_json_rejects_unknown_names() {
        let mut s = Scenario::paper();
        s.placer = "magic".into();
        assert!(Scenario::from_text(&s.to_json_text())
            .unwrap_err()
            .to_string()
            .contains("unknown placer"));
        let mut s = Scenario::paper();
        s.policy = "magic".into();
        assert!(Scenario::from_text(&s.to_json_text())
            .unwrap_err()
            .to_string()
            .contains("unknown policy"));
    }

    #[test]
    fn from_text_rejects_bad_enum_spellings() {
        let text = Scenario::paper().to_json_text().replace("\"srsf\"", "\"sjf\"");
        assert!(Scenario::from_text(&text).unwrap_err().to_string().contains("priority"));
        let text = Scenario::paper()
            .to_json_text()
            .replace("\"at-admission\"", "\"mid-flight\"");
        assert!(Scenario::from_text(&text).unwrap_err().to_string().contains("repricing"));
    }

    #[test]
    fn generated_jobs_clamped_to_cluster_width() {
        let s = Scenario::small("clamp", 2, 2, 10);
        let jobs = s.jobs().unwrap();
        assert_eq!(jobs.len(), 10);
        assert!(jobs.iter().all(|j| j.n_gpus <= s.cluster.n_gpus()));
    }

    #[test]
    fn generated_trace_inherits_scenario_seed() {
        let a = Scenario { seed: 1, ..Scenario::small("s", 2, 2, 12) };
        let b = Scenario { seed: 2, ..Scenario::small("s", 2, 2, 12) };
        assert_ne!(a.jobs().unwrap(), b.jobs().unwrap());
        let pinned = Scenario {
            trace: TraceSource::Generated { jobs: 12, seed: Some(5) },
            ..a.clone()
        };
        let pinned2 = Scenario { seed: 99, ..pinned.clone() };
        assert_eq!(pinned.jobs().unwrap(), pinned2.jobs().unwrap());
    }

    #[test]
    fn label_composition() {
        let s = Scenario::paper();
        assert_eq!(s.label(), "LWF-1/Ada-SRSF");
        let s = Scenario {
            placer: "rand".into(),
            policy: "srsf1".into(),
            priority: JobPriority::Fifo,
            repricing: Repricing::Dynamic,
            ..Scenario::paper()
        };
        assert_eq!(s.label(), "RAND/SRSF(1)/fifo/dynamic");
    }

    #[test]
    fn empty_workload_errors_instead_of_panicking() {
        let s = Scenario {
            trace: TraceSource::Generated { jobs: 0, seed: None },
            ..Scenario::small("empty", 2, 2, 0)
        };
        let e = s.run().unwrap_err().to_string();
        assert!(e.contains("empty workload"), "{e}");
        let s = Scenario {
            trace: TraceSource::Inline(Vec::new()),
            ..Scenario::small("empty-inline", 2, 2, 0)
        };
        assert!(s.run().is_err());
    }

    #[test]
    fn run_small_scenario_end_to_end() {
        let rec = Scenario::small("smoke", 2, 2, 10).run().unwrap();
        assert_eq!(rec.eval.jct.n, 10);
        assert!(rec.eval.jct.mean > 0.0 && rec.eval.jct.mean.is_finite());
        assert!(rec.n_events > 0);
        assert_eq!(rec.scenario.name, "smoke");
    }

    #[test]
    fn sim_config_maps_all_fields() {
        let s = Scenario {
            priority: JobPriority::Las,
            repricing: Repricing::Dynamic,
            topology: TopologySpec::TwoTier { rack_size: 4, oversubscription: 2.0 },
            ..Scenario::paper()
        };
        let cfg = s.sim_config();
        assert_eq!(cfg.priority, JobPriority::Las);
        assert_eq!(cfg.repricing, Repricing::Dynamic);
        assert_eq!(cfg.cluster, s.cluster);
        assert_eq!(cfg.comm, s.comm);
        assert_eq!(cfg.topology, s.topology);
        assert!(cfg.coalescing);
        let off = Scenario { coalescing: false, ..s };
        assert!(!off.sim_config().coalescing);
    }

    // ---- coalescing knob ---------------------------------------------------

    #[test]
    fn coalescing_default_elided_and_off_roundtrips() {
        // The default (on) never appears in JSON: paper-era files and
        // records stay byte-stable.
        let text = Scenario::paper().to_json_text();
        assert!(!text.contains("coalescing"), "default must be elided:\n{text}");
        // Off is serialized and survives the roundtrip.
        let s = Scenario { coalescing: false, ..Scenario::paper() };
        let text = s.to_json_text();
        assert!(text.contains("\"coalescing\": false"), "{text}");
        assert_eq!(Scenario::from_text(&text).unwrap(), s);
        // An explicit `true` loads as the default and re-serializes elided.
        let explicit = Scenario::paper()
            .to_json_text()
            .replace("\"seed\": 42", "\"coalescing\": true,\n  \"seed\": 42");
        let back = Scenario::from_text(&explicit).unwrap();
        assert_eq!(back, Scenario::paper());
        assert!(!back.to_json_text().contains("coalescing"));
    }

    #[test]
    fn coalescing_rejects_non_boolean() {
        let text = Scenario::paper()
            .to_json_text()
            .replace("\"seed\": 42", "\"coalescing\": \"off\",\n  \"seed\": 42");
        let e = Scenario::from_text(&text).unwrap_err().to_string();
        assert!(e.contains("coalescing"), "{e}");
    }

    #[test]
    fn coalescing_does_not_change_results_or_labels() {
        let on = Scenario::small("ff-equiv", 2, 2, 10);
        let off = Scenario { coalescing: false, ..on.clone() };
        // Identical results is the engine's contract — a speed knob must
        // not leak into the method label either.
        assert_eq!(on.label(), off.label());
        let a = on.run().unwrap();
        let b = off.run().unwrap();
        assert_eq!(a.eval.jct.mean.to_bits(), b.eval.jct.mean.to_bits());
        assert_eq!(a.eval.jct.p95.to_bits(), b.eval.jct.p95.to_bits());
        assert_eq!(a.eval.makespan.to_bits(), b.eval.makespan.to_bits());
        assert_eq!(a.eval.clean_admissions, b.eval.clean_admissions);
        assert_eq!(a.eval.contended_admissions, b.eval.contended_admissions);
        assert!(a.n_events <= b.n_events, "coalescing added events");
    }

    // ---- outputs (observer sinks) ------------------------------------------

    #[test]
    fn outputs_default_elided_and_roundtrips() {
        // The empty outputs section never appears in JSON: pre-observer
        // files and records stay byte-stable.
        let text = Scenario::paper().to_json_text();
        assert!(!text.contains("outputs"), "default must be elided:\n{text}");
        let s = Scenario {
            outputs: OutputSpec {
                events: Some("ev.jsonl".into()),
                timeline: None,
                contention: Some("cont.json".into()),
            },
            ..Scenario::paper()
        };
        let text = s.to_json_text();
        assert!(text.contains("\"outputs\""), "{text}");
        let back = Scenario::from_text(&text).unwrap();
        assert_eq!(back, s);
        // Sinks are a pure output knob: the method label is untouched.
        assert_eq!(s.label(), Scenario::paper().label());
    }

    #[test]
    fn outputs_rejects_unknown_keys_and_non_strings() {
        let text = Scenario::paper().to_json_text().replace(
            "\"seed\": 42",
            "\"outputs\": {\"event\": \"x.jsonl\"},\n  \"seed\": 42",
        );
        let e = Scenario::from_text(&text).unwrap_err().to_string();
        assert!(e.contains("unknown outputs key 'event'"), "{e}");
        let text = Scenario::paper()
            .to_json_text()
            .replace("\"seed\": 42", "\"outputs\": {\"events\": 7},\n  \"seed\": 42");
        let e = Scenario::from_text(&text).unwrap_err().to_string();
        assert!(e.contains("must be a file path"), "{e}");
    }

    #[test]
    fn outputs_write_sink_files_end_to_end() {
        let dir = std::env::temp_dir();
        let ev = dir.join("ddl_sched_outputs_events.jsonl");
        let tl = dir.join("ddl_sched_outputs_timeline.json");
        let cp = dir.join("ddl_sched_outputs_contention.json");
        let plain = Scenario::small("sinks", 2, 2, 8);
        let s = Scenario {
            outputs: OutputSpec {
                events: Some(ev.to_string_lossy().into_owned()),
                timeline: Some(tl.to_string_lossy().into_owned()),
                contention: Some(cp.to_string_lossy().into_owned()),
            },
            ..plain.clone()
        };
        let with_sinks = s.run().unwrap();
        let without = plain.run().unwrap();
        // Sinks are pure taps: metrics are bit-identical to a plain run.
        assert_eq!(with_sinks.eval.jct.mean.to_bits(), without.eval.jct.mean.to_bits());
        assert_eq!(with_sinks.eval.makespan.to_bits(), without.eval.makespan.to_bits());
        assert_eq!(with_sinks.n_events, without.n_events);
        // The JSONL stream exists and every line parses.
        let events = std::fs::read_to_string(&ev).unwrap();
        assert!(events.lines().count() > 0, "empty event stream");
        for line in events.lines() {
            crate::util::json::Json::parse(line).unwrap();
        }
        // Timeline and contention profile parse as JSON.
        let tl_text = std::fs::read_to_string(&tl).unwrap();
        let tl_json = crate::util::json::Json::parse(&tl_text).unwrap();
        assert!(!tl_json.as_arr().unwrap().is_empty(), "no timeline spans");
        let cp_text = std::fs::read_to_string(&cp).unwrap();
        crate::util::json::Json::parse(&cp_text).unwrap();
        for p in [&ev, &tl, &cp] {
            let _ = std::fs::remove_file(p);
        }
    }

    #[test]
    fn outputs_events_sink_error_surfaces() {
        // An unwritable sink path must fail the run with context, not
        // silently produce a record.
        let s = Scenario {
            outputs: OutputSpec {
                events: Some("/definitely/not/a/dir/ev.jsonl".into()),
                ..OutputSpec::default()
            },
            ..Scenario::small("bad-sink", 2, 2, 6)
        };
        let e = s.run().unwrap_err().to_string();
        assert!(e.contains("events sink"), "{e}");
    }

    // ---- topology schema ---------------------------------------------------

    fn two_tier(rack_size: usize, oversub: f64) -> Scenario {
        Scenario {
            topology: TopologySpec::TwoTier { rack_size, oversubscription: oversub },
            ..Scenario::paper()
        }
    }

    #[test]
    fn topology_json_roundtrip() {
        let s = two_tier(4, 8.0);
        let back = Scenario::from_text(&s.to_json_text()).unwrap();
        assert_eq!(s, back);
        let s = Scenario {
            cluster: ClusterSpec::tiny(2, 2),
            topology: TopologySpec::Heterogeneous {
                nics: vec![CommModel::paper_10gbe(), CommModel::paper_10gbe().scaled(0.25)],
            },
            trace: TraceSource::Generated { jobs: 6, seed: None },
            ..Scenario::paper()
        };
        let back = Scenario::from_text(&s.to_json_text()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn flat_topology_is_elided_and_explicit_flat_is_accepted() {
        // Flat is the default: not serialized...
        let text = Scenario::paper().to_json_text();
        assert!(!text.contains("topology"), "flat must be elided:\n{text}");
        // ...but an explicit {"preset": "flat"} section loads to the same
        // scenario and re-serializes byte-identically to the elided form.
        let explicit = text.replace(
            "\"comm\": {",
            "\"topology\": {\"preset\": \"flat\"},\n  \"comm\": {",
        );
        assert_ne!(explicit, text);
        let back = Scenario::from_text(&explicit).unwrap();
        assert_eq!(back, Scenario::paper());
        assert_eq!(back.to_json_text(), text);
    }

    #[test]
    fn topology_rejects_unknown_preset() {
        let text = two_tier(4, 2.0).to_json_text().replace("two-tier", "three-tier");
        let e = Scenario::from_text(&text).unwrap_err().to_string();
        assert!(e.contains("unknown topology preset 'three-tier'"), "{e}");
    }

    #[test]
    fn topology_rejects_invalid_oversubscription() {
        let text = two_tier(4, 4.0)
            .to_json_text()
            .replace("\"oversubscription\": 4", "\"oversubscription\": 0.25");
        let e = Scenario::from_text(&text).unwrap_err().to_string();
        assert!(e.contains("oversubscription"), "{e}");
    }

    #[test]
    fn topology_rejects_wrong_nic_count() {
        let s = Scenario {
            topology: TopologySpec::Heterogeneous { nics: vec![CommModel::paper_10gbe(); 3] },
            ..Scenario::paper() // 16 servers
        };
        let e = Scenario::from_text(&s.to_json_text()).unwrap_err().to_string();
        assert!(e.contains("one NIC model per server"), "{e}");
    }

    #[test]
    fn label_carries_topology() {
        assert_eq!(two_tier(4, 4.0).label(), "LWF-1/Ada-SRSF/2tier-4:1");
        assert_eq!(Scenario::paper().label(), "LWF-1/Ada-SRSF");
    }

    // ---- faults schema -----------------------------------------------------

    fn faulted(events: Vec<crate::fault::FaultEvent>) -> Scenario {
        Scenario {
            faults: Some(crate::fault::FaultsSpec {
                warmup_s: 1.0,
                events,
                ..crate::fault::FaultsSpec::default()
            }),
            ..Scenario::small("faulted", 2, 2, 8)
        }
    }

    fn gpu_pair(g: usize, t_fail: f64, t_recover: f64) -> Vec<crate::fault::FaultEvent> {
        use crate::fault::{FaultEvent, FaultKind};
        vec![
            FaultEvent { t: t_fail, kind: FaultKind::GpuFail(g) },
            FaultEvent { t: t_recover, kind: FaultKind::GpuRecover(g) },
        ]
    }

    #[test]
    fn faults_default_elided_and_roundtrips() {
        // No faults section in the default corpus: byte-stable files.
        let text = Scenario::paper().to_json_text();
        assert!(!text.contains("faults"), "default must be elided:\n{text}");
        let s = faulted(gpu_pair(1, 50.0, 80.0));
        let text = s.to_json_text();
        assert!(text.contains("\"faults\""), "{text}");
        let back = Scenario::from_text(&text).unwrap();
        assert_eq!(back, s);
        // A faulted scenario is a different experiment: the label says so.
        assert!(s.label().ends_with("/faults"), "{}", s.label());
        assert!(!Scenario::paper().label().contains("faults"));
    }

    #[test]
    fn faults_rejects_out_of_range_ids() {
        let s = faulted(gpu_pair(99, 10.0, 20.0)); // 2x2 cluster: gpus 0..4
        let e = Scenario::from_text(&s.to_json_text()).unwrap_err().to_string();
        assert!(e.contains("gpu"), "{e}");
    }

    #[test]
    fn empty_faults_section_compiles_to_empty_plan() {
        let s = Scenario {
            faults: Some(crate::fault::FaultsSpec::default()),
            ..Scenario::small("no-events", 2, 2, 6)
        };
        // `"faults": {}` — all knobs at defaults — survives the roundtrip
        // and compiles to the engine's bit-identical empty plan.
        let back = Scenario::from_text(&s.to_json_text()).unwrap();
        assert_eq!(back, s);
        assert!(s.fault_plan().unwrap().is_empty());
    }

    #[test]
    fn fault_scenario_runs_end_to_end() {
        let healthy = Scenario::small("faulted", 2, 2, 8);
        let rec = faulted(gpu_pair(1, 5.0, 40.0)).run().unwrap();
        // Every job still finishes once capacity recovers, and losing a
        // GPU mid-run can only delay the workload.
        assert_eq!(rec.eval.jct.n, 8);
        assert!(rec.eval.makespan >= healthy.run().unwrap().eval.makespan);
    }

    #[test]
    fn two_tier_scenario_runs_end_to_end() {
        let s = Scenario {
            topology: TopologySpec::TwoTier { rack_size: 2, oversubscription: 4.0 },
            placer: "lwf-rack".into(),
            ..Scenario::small("2tier", 4, 2, 12)
        };
        let rec = s.run().unwrap();
        assert_eq!(rec.eval.jct.n, 12);
        assert!(rec.eval.jct.mean.is_finite() && rec.eval.jct.mean > 0.0);
        assert!(rec.scenario.label().ends_with("2tier-4:1"));
    }
}
