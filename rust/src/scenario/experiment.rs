//! Experiment = a [`Scenario`] plus grid axes. Expanding the grid yields
//! one scenario per (placer × κ × policy × priority × oversubscription ×
//! seed) combination;
//! [`Experiment::run`] executes the grid across `std::thread` workers and
//! collects [`RunRecord`]s in grid order.
//!
//! Determinism contract: each scenario run is fully deterministic and the
//! results vector is indexed by grid position, so `run(1)` and `run(n)`
//! produce byte-identical `records_to_json` / `records_to_csv` output —
//! parallelism only buys wall-clock (see benches/grid_parallel.rs).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::metrics::Evaluation;
use crate::net::{TopologySpec, DEFAULT_RACK_SIZE};
use crate::scenario::{registry, Scenario, TraceSource};
use crate::sim::JobPriority;
use crate::trace::JobSpec;
use crate::util::error::{Context, Error, Result};
use crate::util::json::Json;

/// The outcome of one scenario run: the spec that produced it plus the
/// paper's evaluation metrics and engine counters. Serializes without any
/// wall-clock fields so records are reproducible artifacts.
#[derive(Clone, Debug)]
pub struct RunRecord {
    pub scenario: Scenario,
    pub eval: Evaluation,
    pub n_events: u64,
    pub max_contention: usize,
}

impl RunRecord {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("scenario", self.scenario.to_json())
            .set("label", self.scenario.label())
            .set("eval", self.eval.to_json())
            .set("n_finished", self.eval.jct.n)
            .set("n_events", self.n_events)
            .set("max_contention", self.max_contention)
    }

    /// Column names for [`RunRecord::csv_row`]. `n_finished` counts the
    /// jobs that completed (the metrics are computed over exactly those).
    pub fn csv_header() -> &'static [&'static str] {
        &[
            "name", "placer", "kappa", "policy", "priority", "repricing", "seed", "n_finished",
            "avg_util", "avg_alloc_util", "avg_jct_s", "median_jct_s", "p95_jct_s",
            "makespan_s", "n_events", "clean_admissions", "contended_admissions",
            "max_contention",
        ]
    }

    pub fn csv_row(&self) -> Vec<String> {
        let s = &self.scenario;
        vec![
            csv_field(&s.name),
            csv_field(&s.placer),
            s.kappa.to_string(),
            csv_field(&s.policy),
            s.priority.name().to_string(),
            s.repricing.name().to_string(),
            s.seed.to_string(),
            self.eval.jct.n.to_string(),
            format!("{}", self.eval.avg_gpu_util),
            format!("{}", self.eval.avg_alloc_util),
            format!("{}", self.eval.jct.mean),
            format!("{}", self.eval.jct.median),
            format!("{}", self.eval.jct.p95),
            format!("{}", self.eval.makespan),
            self.n_events.to_string(),
            self.eval.clean_admissions.to_string(),
            self.eval.contended_admissions.to_string(),
            self.max_contention.to_string(),
        ]
    }
}

/// RFC 4180-style escaping: quote fields containing separators or quotes
/// (scenario names are free-form; a comma must not shift the columns).
fn csv_field(s: &str) -> String {
    if s.contains(&[',', '"', '\n', '\r'][..]) {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Serialize records to pretty JSON (deterministic for a deterministic grid).
pub fn records_to_json(records: &[RunRecord]) -> String {
    Json::Arr(records.iter().map(RunRecord::to_json).collect()).to_string_pretty()
}

/// Serialize records to CSV with [`RunRecord::csv_header`] columns.
pub fn records_to_csv(records: &[RunRecord]) -> String {
    let mut out = RunRecord::csv_header().join(",");
    out.push('\n');
    for r in records {
        out.push_str(&r.csv_row().join(","));
        out.push('\n');
    }
    out
}

/// A scenario grid: the base scenario plus per-axis value lists. Empty
/// axes keep the base value, so `Experiment::single(s)` is one run.
#[derive(Clone, Debug, PartialEq)]
pub struct Experiment {
    pub base: Scenario,
    pub placers: Vec<String>,
    pub kappas: Vec<usize>,
    pub policies: Vec<String>,
    pub priorities: Vec<JobPriority>,
    /// Two-tier core oversubscription ratios. Each value replaces the
    /// base topology with `TwoTier` at that ratio (keeping the base's
    /// rack size, or `net::DEFAULT_RACK_SIZE` if the base is rackless).
    pub oversubs: Vec<f64>,
    /// Fault-injection MTBF values (seconds). Each value gives the cell a
    /// `faults` section whose generator runs at that MTBF — overriding the
    /// base generator's MTBF if one exists, otherwise a default generator
    /// ([`crate::fault::GenSpec::with_mtbf`]) on the base's checkpoint
    /// knobs. The base's explicit fault events are kept.
    pub mtbfs: Vec<f64>,
    /// Gray-failure severity values (health factors in (0, 1); smaller =
    /// more severe). Each value gives the cell a degradation generator
    /// whose drawn factor is pinned to exactly that severity
    /// ([`crate::fault::DegradeSpec::with_severity`]) — overriding the
    /// base degradation spec's factor range if one exists, otherwise a
    /// default generator. The base's other fault knobs are kept.
    pub degrades: Vec<f64>,
    pub seeds: Vec<u64>,
}

impl Experiment {
    /// Default worker count for local runs: every available core.
    pub fn default_threads() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    /// No axes: the grid is exactly the base scenario.
    pub fn single(base: Scenario) -> Experiment {
        Experiment {
            base,
            placers: Vec::new(),
            kappas: Vec::new(),
            policies: Vec::new(),
            priorities: Vec::new(),
            oversubs: Vec::new(),
            mtbfs: Vec::new(),
            degrades: Vec::new(),
            seeds: Vec::new(),
        }
    }

    /// The paper's full evaluation grid over `base`: placers
    /// {rand, ff, ls, lwf} × policies {srsf1, srsf2, srsf3, ada}
    /// (Tables IV–V in one experiment).
    pub fn paper_grid(base: Scenario) -> Experiment {
        Experiment {
            placers: registry::PAPER_PLACERS.iter().map(|s| s.to_string()).collect(),
            policies: registry::POLICIES.iter().map(|s| s.to_string()).collect(),
            ..Experiment::single(base)
        }
    }

    /// Rack width the `oversub` axis builds its two-tier topologies with.
    fn oversub_rack_size(&self) -> usize {
        match self.base.topology {
            TopologySpec::TwoTier { rack_size, .. } => rack_size,
            _ => DEFAULT_RACK_SIZE,
        }
    }

    /// Expand the grid in axis-nesting order placer → κ → policy →
    /// priority → oversubscription → seed, validating every algorithm
    /// name and topology up front.
    pub fn grid(&self) -> Result<Vec<Scenario>> {
        let one = |v: &[String], base: &str| -> Vec<String> {
            if v.is_empty() {
                vec![base.to_string()]
            } else {
                v.to_vec()
            }
        };
        let placers = one(&self.placers, &self.base.placer);
        let policies = one(&self.policies, &self.base.policy);
        let kappas =
            if self.kappas.is_empty() { vec![self.base.kappa] } else { self.kappas.clone() };
        let priorities = if self.priorities.is_empty() {
            vec![self.base.priority]
        } else {
            self.priorities.clone()
        };
        let seeds = if self.seeds.is_empty() { vec![self.base.seed] } else { self.seeds.clone() };
        // `None` = keep the base topology; `Some(r)` = two-tier at ratio r.
        let oversubs: Vec<Option<f64>> = if self.oversubs.is_empty() {
            vec![None]
        } else {
            self.oversubs.iter().map(|&r| Some(r)).collect()
        };
        // `None` = keep the base faults section; `Some(m)` = generator at
        // MTBF m seconds.
        let mtbfs: Vec<Option<f64>> = if self.mtbfs.is_empty() {
            vec![None]
        } else {
            self.mtbfs.iter().map(|&m| Some(m)).collect()
        };
        for &m in &self.mtbfs {
            if !m.is_finite() || m <= 0.0 {
                return Err(Error::msg(format!(
                    "mtbf axis entries must be finite and positive seconds, got {m}"
                )));
            }
        }
        // `None` = keep the base degradation spec; `Some(x)` = generator
        // pinned to severity x.
        let degrades: Vec<Option<f64>> = if self.degrades.is_empty() {
            vec![None]
        } else {
            self.degrades.iter().map(|&x| Some(x)).collect()
        };
        for &x in &self.degrades {
            if !x.is_finite() || x <= 0.0 || x >= 1.0 {
                return Err(Error::msg(format!(
                    "degrade axis entries are health factors and must lie in (0, 1), got {x}"
                )));
            }
        }
        for p in &placers {
            registry::make_placer(p, 1, 0, usize::MAX)?;
        }
        for p in &policies {
            registry::make_policy(p, self.base.comm)?;
        }
        let rack_size = self.oversub_rack_size();
        for &r in &self.oversubs {
            TopologySpec::TwoTier { rack_size, oversubscription: r }
                .validate(&self.base.cluster)
                .map_err(Error::msg)?;
        }
        let n_runs = placers.len()
            * kappas.len()
            * policies.len()
            * priorities.len()
            * oversubs.len()
            * mtbfs.len()
            * degrades.len()
            * seeds.len();
        // Observer sinks are per-run files; every grid cell would clobber
        // the same paths. A degenerate single-cell grid is fine.
        if n_runs > 1 && !self.base.outputs.is_default() {
            return Err(Error::msg(
                "scenario 'outputs' sinks are per-run files and do not compose with grid \
                 axes; run the scenario via 'simulate' or drop the axes",
            ));
        }
        let mut out = Vec::with_capacity(n_runs);
        for placer in &placers {
            for &kappa in &kappas {
                for policy in &policies {
                    for &priority in &priorities {
                        for &oversub in &oversubs {
                            for &mtbf in &mtbfs {
                              for &degrade in &degrades {
                                for &seed in &seeds {
                                    let mut s = Scenario {
                                        placer: placer.clone(),
                                        kappa,
                                        policy: policy.clone(),
                                        priority,
                                        seed,
                                        ..self.base.clone()
                                    };
                                    if let Some(r) = oversub {
                                        s.topology = TopologySpec::TwoTier {
                                            rack_size,
                                            oversubscription: r,
                                        };
                                        // The CSV record schema has no
                                        // topology column (kept byte-stable
                                        // for flat grids), so make the axis
                                        // recoverable from the free-form
                                        // name column.
                                        s.name = format!("{}@{r}:1", s.name);
                                    }
                                    if let Some(m) = mtbf {
                                        let mut f = s.faults.take().unwrap_or_default();
                                        f.gen = Some(match f.gen {
                                            Some(g) => crate::fault::GenSpec { mtbf_s: m, ..g },
                                            None => crate::fault::GenSpec::with_mtbf(m),
                                        });
                                        s.faults = Some(f);
                                        // Same name-tag convention as the
                                        // oversub axis.
                                        s.name = format!("{}@mtbf{m}", s.name);
                                    }
                                    if let Some(x) = degrade {
                                        let mut f = s.faults.take().unwrap_or_default();
                                        f.degraded = Some(match f.degraded {
                                            Some(d) => crate::fault::DegradeSpec {
                                                factor_min: x,
                                                factor_max: x,
                                                ..d
                                            },
                                            None => crate::fault::DegradeSpec::with_severity(x),
                                        });
                                        s.faults = Some(f);
                                        s.name = format!("{}@deg{x}", s.name);
                                    }
                                    out.push(s);
                                }
                              }
                            }
                        }
                    }
                }
            }
        }
        Ok(out)
    }

    /// Run the whole grid. `threads <= 1` runs serially; otherwise up to
    /// `threads` workers pull scenarios from a shared counter. Either way
    /// the returned records are in grid order and identical. Each unique
    /// trace (source + effective seed) is resolved once and shared across
    /// the grid cells that use it, not re-read/regenerated per cell — a
    /// `csv` source in particular is parsed and normalized exactly once
    /// per sweep, however many cells replay it.
    pub fn run(&self, threads: usize) -> Result<Vec<RunRecord>> {
        let scenarios = self.grid()?;
        let mut cache: Vec<((TraceSource, Option<u64>), Arc<Vec<JobSpec>>)> = Vec::new();
        let mut workloads: Vec<Arc<Vec<JobSpec>>> = Vec::with_capacity(scenarios.len());
        for s in &scenarios {
            let key = (s.trace.clone(), s.effective_trace_seed());
            let jobs = match cache.iter().find(|(k, _)| *k == key) {
                Some((_, jobs)) => Arc::clone(jobs),
                None => {
                    let jobs = Arc::new(s.jobs()?);
                    cache.push((key, Arc::clone(&jobs)));
                    jobs
                }
            };
            workloads.push(jobs);
        }
        let workers = threads.max(1).min(scenarios.len().max(1));
        if workers <= 1 {
            return scenarios
                .iter()
                .zip(&workloads)
                .map(|(s, jobs)| s.run_with_jobs(jobs))
                .collect();
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<Result<RunRecord>>>> =
            scenarios.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= scenarios.len() {
                        break;
                    }
                    let record = scenarios[i].run_with_jobs(&workloads[i]);
                    // A poisoned slot means another worker panicked while
                    // holding it; the recovered value is still the one we
                    // just computed, so write it through either way.
                    match slots[i].lock() {
                        Ok(mut slot) => *slot = Some(record),
                        Err(poisoned) => *poisoned.into_inner() = Some(record),
                    }
                });
            }
        });
        slots
            .into_iter()
            .map(|slot| {
                slot.into_inner()
                    .unwrap_or_else(std::sync::PoisonError::into_inner)
                    .unwrap_or_else(|| {
                        Err(Error::msg("experiment worker died before filling its slot"))
                    })
            })
            .collect()
    }

    // ---- serialization -----------------------------------------------------

    pub fn to_json(&self) -> Json {
        let strs = |v: &[String]| Json::Arr(v.iter().map(|s| Json::Str(s.clone())).collect());
        let mut axes = Json::obj()
            .set("placer", strs(&self.placers))
            .set("kappa", Json::Arr(self.kappas.iter().map(|&k| Json::from(k)).collect()))
            .set("policy", strs(&self.policies))
            .set(
                "priority",
                Json::Arr(self.priorities.iter().map(|p| Json::from(p.name())).collect()),
            );
        // Like Scenario's flat topology, the empty oversub axis is elided
        // so pre-topology experiment artifacts stay byte-stable.
        if !self.oversubs.is_empty() {
            axes = axes.set(
                "oversub",
                Json::Arr(self.oversubs.iter().map(|&r| Json::from(r)).collect()),
            );
        }
        // Elided when empty, like oversub: pre-fault artifacts stay stable.
        if !self.mtbfs.is_empty() {
            axes = axes
                .set("mtbf", Json::Arr(self.mtbfs.iter().map(|&m| Json::from(m)).collect()));
        }
        if !self.degrades.is_empty() {
            axes = axes.set(
                "degrade",
                Json::Arr(self.degrades.iter().map(|&x| Json::from(x)).collect()),
            );
        }
        axes = axes.set("seed", Json::Arr(self.seeds.iter().map(|&s| Json::from(s)).collect()));
        Json::obj().set("base", self.base.to_json()).set("axes", axes)
    }

    pub fn to_json_text(&self) -> String {
        self.to_json().to_string_pretty()
    }

    pub fn from_json(v: &Json) -> Result<Experiment> {
        let base = Scenario::from_json(
            v.get("base").ok_or_else(|| Error::msg("experiment JSON missing 'base'"))?,
        )?;
        let mut exp = Experiment::single(base);
        let Some(axes) = v.get("axes") else { return Ok(exp) };
        // Reject unknown axis keys: a typo like "placers" would otherwise
        // silently run only the base scenario.
        if let Json::Obj(entries) = axes {
            for (key, _) in entries {
                if !matches!(
                    key.as_str(),
                    "placer" | "kappa" | "policy" | "priority" | "oversub" | "mtbf" | "degrade"
                        | "seed"
                ) {
                    return Err(Error::msg(format!(
                        "unknown experiment axis '{key}' \
                         (placer|kappa|policy|priority|oversub|mtbf|degrade|seed)"
                    )));
                }
            }
        } else {
            return Err(Error::msg("'axes' must be an object"));
        }
        let str_axis = |key: &str| -> Result<Vec<String>> {
            match axes.get(key) {
                None => Ok(Vec::new()),
                Some(a) => a
                    .as_arr()
                    .ok_or_else(|| Error::msg(format!("axis '{key}' must be an array")))?
                    .iter()
                    .map(|x| {
                        x.as_str()
                            .map(str::to_string)
                            .ok_or_else(|| Error::msg(format!("axis '{key}' entries must be strings")))
                    })
                    .collect(),
            }
        };
        exp.placers = str_axis("placer")?;
        exp.policies = str_axis("policy")?;
        if let Some(a) = axes.get("kappa") {
            exp.kappas = a
                .as_arr()
                .ok_or_else(|| Error::msg("axis 'kappa' must be an array"))?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| Error::msg("kappa entries must be integers")))
                .collect::<Result<_>>()?;
        }
        if let Some(a) = axes.get("oversub") {
            exp.oversubs = a
                .as_arr()
                .ok_or_else(|| Error::msg("axis 'oversub' must be an array"))?
                .iter()
                .map(|x| {
                    x.as_f64().ok_or_else(|| Error::msg("oversub entries must be numbers"))
                })
                .collect::<Result<_>>()?;
        }
        if let Some(a) = axes.get("mtbf") {
            exp.mtbfs = a
                .as_arr()
                .ok_or_else(|| Error::msg("axis 'mtbf' must be an array"))?
                .iter()
                .map(|x| x.as_f64().ok_or_else(|| Error::msg("mtbf entries must be numbers")))
                .collect::<Result<_>>()?;
        }
        if let Some(a) = axes.get("degrade") {
            exp.degrades = a
                .as_arr()
                .ok_or_else(|| Error::msg("axis 'degrade' must be an array"))?
                .iter()
                .map(|x| x.as_f64().ok_or_else(|| Error::msg("degrade entries must be numbers")))
                .collect::<Result<_>>()?;
        }
        if let Some(a) = axes.get("seed") {
            exp.seeds = a
                .as_arr()
                .ok_or_else(|| Error::msg("axis 'seed' must be an array"))?
                .iter()
                .map(|x| x.as_u64().ok_or_else(|| Error::msg("seed entries must be integers")))
                .collect::<Result<_>>()?;
        }
        exp.priorities = str_axis("priority")?
            .iter()
            .map(|s| {
                JobPriority::parse(s)
                    .ok_or_else(|| Error::msg(format!("unknown priority '{s}' (srsf|fifo|las)")))
            })
            .collect::<Result<_>>()?;
        Ok(exp)
    }

    /// Parse from JSON text. Accepts either a full experiment object
    /// (`{"base": {...}, "axes": {...}}`) or a bare scenario object, which
    /// becomes a single-run experiment — so any scenario file is runnable
    /// as a (degenerate) grid.
    pub fn from_text(text: &str) -> Result<Experiment> {
        let v = Json::parse(text).context("parsing experiment JSON")?;
        if v.get("base").is_some() {
            Experiment::from_json(&v)
        } else {
            Ok(Experiment::single(Scenario::from_json(&v)?))
        }
    }

    /// Load from a JSON file (scenario or experiment form).
    pub fn from_file(path: &str) -> Result<Experiment> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading scenario file '{path}'"))?;
        Experiment::from_text(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{TopologySpec, DEFAULT_RACK_SIZE};

    fn small_grid() -> Experiment {
        Experiment {
            placers: vec!["lwf".into(), "rand".into()],
            policies: vec!["srsf1".into(), "ada".into()],
            ..Experiment::single(Scenario::small("grid", 2, 2, 12))
        }
    }

    #[test]
    fn grid_expansion_order_and_count() {
        let g = small_grid().grid().unwrap();
        assert_eq!(g.len(), 4);
        assert_eq!((g[0].placer.as_str(), g[0].policy.as_str()), ("lwf", "srsf1"));
        assert_eq!((g[1].placer.as_str(), g[1].policy.as_str()), ("lwf", "ada"));
        assert_eq!((g[2].placer.as_str(), g[2].policy.as_str()), ("rand", "srsf1"));
        assert_eq!((g[3].placer.as_str(), g[3].policy.as_str()), ("rand", "ada"));
    }

    #[test]
    fn empty_axes_use_base_values() {
        let base = Scenario::small("one", 2, 2, 6);
        let g = Experiment::single(base.clone()).grid().unwrap();
        assert_eq!(g, vec![base]);
    }

    #[test]
    fn grid_rejects_outputs_with_multiple_cells() {
        use crate::scenario::OutputSpec;
        let base = Scenario {
            outputs: OutputSpec { events: Some("ev.jsonl".into()), ..OutputSpec::default() },
            ..Scenario::small("sink-grid", 2, 2, 6)
        };
        // A single-cell grid keeps the sinks (simulate-equivalent)...
        assert_eq!(Experiment::single(base.clone()).grid().unwrap().len(), 1);
        // ...but real axes would clobber the same files per cell.
        let bad = Experiment {
            policies: vec!["srsf1".into(), "ada".into()],
            ..Experiment::single(base)
        };
        let e = bad.grid().unwrap_err().to_string();
        assert!(e.contains("outputs"), "{e}");
    }

    #[test]
    fn grid_rejects_unknown_axis_names() {
        let mut e = small_grid();
        e.placers.push("teleport".into());
        assert!(e.grid().unwrap_err().to_string().contains("unknown placer"));
    }

    #[test]
    fn parallel_run_matches_serial_byte_for_byte() {
        let e = small_grid();
        let serial = e.run(1).unwrap();
        let parallel = e.run(4).unwrap();
        assert_eq!(records_to_json(&serial), records_to_json(&parallel));
        assert_eq!(records_to_csv(&serial), records_to_csv(&parallel));
    }

    #[test]
    fn seed_axis_changes_generated_workload() {
        let e = Experiment {
            seeds: vec![1, 2],
            ..Experiment::single(Scenario::small("seeds", 2, 2, 12))
        };
        let recs = e.run(2).unwrap();
        assert_eq!(recs.len(), 2);
        assert_ne!(recs[0].eval.jct.mean, recs[1].eval.jct.mean);
    }

    #[test]
    fn experiment_json_roundtrip() {
        let e = Experiment {
            kappas: vec![1, 2],
            priorities: vec![JobPriority::Srsf, JobPriority::Fifo],
            seeds: vec![3, 4],
            ..small_grid()
        };
        let back = Experiment::from_text(&e.to_json_text()).unwrap();
        assert_eq!(e, back);
    }

    #[test]
    fn bare_scenario_text_parses_as_single_experiment() {
        let s = Scenario::small("bare", 2, 2, 6);
        let e = Experiment::from_text(&s.to_json_text()).unwrap();
        assert_eq!(e, Experiment::single(s));
    }

    #[test]
    fn unknown_axis_key_rejected() {
        let base = Scenario::small("axes", 2, 2, 6).to_json_text();
        let text = format!("{{\"base\": {base}, \"axes\": {{\"placers\": [\"lwf\"]}}}}");
        let e = Experiment::from_text(&text).unwrap_err().to_string();
        assert!(e.contains("unknown experiment axis 'placers'"), "{e}");
    }

    #[test]
    fn oversub_axis_expands_to_two_tier_topologies() {
        let e = Experiment {
            policies: vec!["srsf1".into(), "ada".into()],
            oversubs: vec![2.0, 4.0, 8.0],
            ..Experiment::single(Scenario::small("oversub", 4, 2, 8))
        };
        let g = e.grid().unwrap();
        assert_eq!(g.len(), 6);
        // The ratio is recoverable from the record name (the CSV schema
        // carries no topology column).
        assert_eq!(g[0].name, "oversub@2:1");
        assert_eq!(g[2].name, "oversub@8:1");
        for s in &g {
            match s.topology {
                TopologySpec::TwoTier { rack_size, oversubscription } => {
                    assert_eq!(rack_size, DEFAULT_RACK_SIZE);
                    assert!([2.0, 4.0, 8.0].contains(&oversubscription));
                }
                ref other => panic!("expected two-tier, got {other:?}"),
            }
        }
        // Nesting order: policy is outer, oversub inner.
        assert_eq!(g[0].policy, "srsf1");
        assert!(matches!(
            g[0].topology,
            TopologySpec::TwoTier { oversubscription, .. } if oversubscription == 2.0
        ));
        assert!(matches!(
            g[2].topology,
            TopologySpec::TwoTier { oversubscription, .. } if oversubscription == 8.0
        ));
    }

    #[test]
    fn oversub_axis_keeps_base_rack_size() {
        let base = Scenario {
            topology: TopologySpec::TwoTier { rack_size: 2, oversubscription: 1.0 },
            ..Scenario::small("racked", 4, 2, 8)
        };
        let e = Experiment { oversubs: vec![4.0], ..Experiment::single(base) };
        let g = e.grid().unwrap();
        assert_eq!(
            g[0].topology,
            TopologySpec::TwoTier { rack_size: 2, oversubscription: 4.0 }
        );
    }

    #[test]
    fn oversub_axis_rejects_invalid_ratio() {
        let e = Experiment {
            oversubs: vec![0.5],
            ..Experiment::single(Scenario::small("bad", 2, 2, 6))
        };
        let err = e.grid().unwrap_err().to_string();
        assert!(err.contains("oversubscription"), "{err}");
    }

    #[test]
    fn oversub_axis_json_roundtrip_and_elision() {
        let plain = small_grid();
        assert!(!plain.to_json_text().contains("oversub"), "empty axis must be elided");
        let e = Experiment { oversubs: vec![2.0, 8.0], ..small_grid() };
        let back = Experiment::from_text(&e.to_json_text()).unwrap();
        assert_eq!(e, back);
    }

    #[test]
    fn oversub_sweep_runs_end_to_end() {
        let e = Experiment {
            oversubs: vec![1.0, 8.0],
            ..Experiment::single(Scenario {
                placer: "lwf-rack".into(),
                topology: TopologySpec::TwoTier { rack_size: 2, oversubscription: 1.0 },
                ..Scenario::small("2tier-run", 4, 2, 10)
            })
        };
        let recs = e.run(2).unwrap();
        assert_eq!(recs.len(), 2);
        for r in &recs {
            assert_eq!(r.eval.jct.n, 10);
            assert!(r.eval.jct.mean.is_finite());
        }
    }

    #[test]
    fn mtbf_axis_expands_to_fault_generators() {
        let e = Experiment {
            policies: vec!["srsf1".into(), "ada".into()],
            mtbfs: vec![300.0, 600.0],
            ..Experiment::single(Scenario::small("chaos", 2, 2, 8))
        };
        let g = e.grid().unwrap();
        assert_eq!(g.len(), 4);
        // Nesting: policy outer, mtbf inner; the axis is recoverable from
        // the record name and the label marks the cells as faulted.
        assert_eq!(g[0].name, "chaos@mtbf300");
        assert_eq!(g[1].name, "chaos@mtbf600");
        for s in &g {
            let gen = s.faults.as_ref().unwrap().gen.unwrap();
            assert!([300.0, 600.0].contains(&gen.mtbf_s));
            assert!(s.label().ends_with("/faults"), "{}", s.label());
        }
    }

    #[test]
    fn mtbf_axis_overrides_base_generator_but_keeps_knobs() {
        use crate::fault::{FaultsSpec, GenSpec};
        let base = Scenario {
            faults: Some(FaultsSpec {
                checkpoint_iters: 7,
                warmup_s: 2.0,
                gen: Some(GenSpec { mttr_s: 30.0, ..GenSpec::with_mtbf(100.0) }),
                ..FaultsSpec::default()
            }),
            ..Scenario::small("keep", 2, 2, 8)
        };
        let e = Experiment { mtbfs: vec![500.0], ..Experiment::single(base) };
        let f = e.grid().unwrap()[0].faults.clone().unwrap();
        assert_eq!(f.checkpoint_iters, 7);
        assert_eq!(f.warmup_s, 2.0);
        let gen = f.gen.unwrap();
        assert_eq!(gen.mtbf_s, 500.0);
        assert_eq!(gen.mttr_s, 30.0);
    }

    #[test]
    fn mtbf_axis_rejects_invalid_values() {
        for bad in [0.0, -5.0, f64::NAN, f64::INFINITY] {
            let e = Experiment {
                mtbfs: vec![bad],
                ..Experiment::single(Scenario::small("bad-mtbf", 2, 2, 6))
            };
            let err = e.grid().unwrap_err().to_string();
            assert!(err.contains("mtbf axis"), "{err}");
        }
    }

    #[test]
    fn mtbf_axis_json_roundtrip_and_elision() {
        let plain = small_grid();
        assert!(!plain.to_json_text().contains("mtbf"), "empty axis must be elided");
        let e = Experiment { mtbfs: vec![300.0, 1200.0], ..small_grid() };
        let back = Experiment::from_text(&e.to_json_text()).unwrap();
        assert_eq!(e, back);
    }

    #[test]
    fn mtbf_sweep_runs_end_to_end() {
        let e = Experiment {
            mtbfs: vec![200.0],
            ..Experiment::single(Scenario::small("chaos-run", 2, 2, 8))
        };
        let recs = e.run(1).unwrap();
        assert_eq!(recs.len(), 1);
        // Every generated failure schedules its recovery, so the whole
        // workload still completes.
        assert_eq!(recs[0].eval.jct.n, 8);
        assert!(recs[0].eval.jct.mean.is_finite());
    }

    #[test]
    fn grid_carries_base_coalescing() {
        // The speed knob is not an axis — every cell inherits it from the
        // base scenario (and the record schema is unchanged by it).
        let e = Experiment {
            policies: vec!["srsf1".into(), "ada".into()],
            ..Experiment::single(Scenario {
                coalescing: false,
                ..Scenario::small("ff-base", 2, 2, 6)
            })
        };
        let g = e.grid().unwrap();
        assert_eq!(g.len(), 2);
        assert!(g.iter().all(|s| !s.coalescing));
        let back = Experiment::from_text(&e.to_json_text()).unwrap();
        assert_eq!(back, e);
    }

    #[test]
    fn csv_escapes_free_form_names() {
        let mut s = Scenario::small("paper, v2", 2, 2, 6);
        s.name = "has \"quotes\", commas".into();
        let recs = Experiment::single(s).run(1).unwrap();
        let csv = records_to_csv(&recs);
        let row = csv.lines().nth(1).unwrap();
        assert!(row.starts_with("\"has \"\"quotes\"\", commas\","), "{row}");
        // Quoted commas must not shift the column count (naive split on
        // quoted commas over-counts; strip the quoted field first).
        let rest = &row[row.rfind('"').unwrap() + 2..];
        assert_eq!(rest.split(',').count(), RunRecord::csv_header().len() - 1);
    }

    #[test]
    fn csv_shape_matches_header() {
        let recs = Experiment::single(Scenario::small("csv", 2, 2, 6)).run(1).unwrap();
        let csv = records_to_csv(&recs);
        let mut lines = csv.lines();
        let header = lines.next().unwrap();
        assert_eq!(header.split(',').count(), RunRecord::csv_header().len());
        let row = lines.next().unwrap();
        assert_eq!(row.split(',').count(), RunRecord::csv_header().len());
    }

    #[test]
    fn record_json_carries_scenario_and_metrics() {
        let rec = Scenario::small("rec", 2, 2, 6).run().unwrap();
        let v = Json::parse(&records_to_json(&[rec])).unwrap();
        let first = &v.as_arr().unwrap()[0];
        assert_eq!(first.get("scenario").unwrap().req_str("name").unwrap(), "rec");
        assert!(first.get("eval").unwrap().req_f64("avg_jct").unwrap() > 0.0);
    }
}
