//! Cross-module integration: full paper-scale simulations, comparative
//! shape checks (the paper's qualitative findings), trace round-trips.

use ddl_sched::metrics::Evaluation;
use ddl_sched::prelude::*;

fn eval(placer_name: &str, policy_name: &str, jobs: &[JobSpec]) -> Evaluation {
    let cfg = SimConfig::paper();
    let mut placer = registry::make_placer(placer_name, 1, 7, usize::MAX).unwrap();
    let policy = registry::make_policy(policy_name, cfg.comm).unwrap();
    let res = sim::simulate(&cfg, &jobs.to_vec(), placer.as_mut(), policy.as_ref());
    Evaluation::from_sim(&format!("{placer_name}/{policy_name}"), &res)
}

#[test]
fn paper_trace_all_combinations_finish() {
    let jobs = trace::generate(&TraceConfig::scaled(60, 2));
    for placer in ["rand", "ff", "ls", "lwf"] {
        for policy in ["srsf1", "srsf2", "srsf3", "ada"] {
            let e = eval(placer, policy, &jobs);
            assert_eq!(e.jct.n, jobs.len(), "{placer}/{policy} lost jobs");
            assert!(e.jct.mean > 0.0 && e.jct.mean.is_finite());
            assert!(e.avg_gpu_util > 0.0 && e.avg_gpu_util <= 1.0);
        }
    }
}

#[test]
fn finding_lwf_beats_baselines_on_paper_trace() {
    // Table IV's qualitative shape: LWF-1 has the lowest average JCT and
    // the highest utilisation of the four placement algorithms.
    let jobs = trace::generate(&TraceConfig::paper_160());
    let lwf = eval("lwf", "ada", &jobs);
    for baseline in ["rand", "ff", "ls"] {
        let b = eval(baseline, "ada", &jobs);
        assert!(
            lwf.jct.mean < b.jct.mean,
            "LWF-1 ({:.1}) not better than {baseline} ({:.1})",
            lwf.jct.mean,
            b.jct.mean
        );
        assert!(
            lwf.avg_gpu_util > b.avg_gpu_util,
            "LWF-1 util {:.3} not above {baseline} {:.3}",
            lwf.avg_gpu_util,
            b.avg_gpu_util
        );
    }
}

#[test]
fn finding_ada_beats_srsf_variants_on_paper_trace() {
    // Table V's robust qualitative shape: SRSF(1) beats blind acceptance
    // (SRSF(2)/(3)), and Ada-SRSF beats blind acceptance and tracks
    // SRSF(1) closely. The paper's strict Ada-SRSF > SRSF(1) win does NOT
    // reproduce under exact Eq (5) repricing — an analysed divergence, see
    // docs/EXPERIMENTS.md §TableV-discussion: the pairwise-optimal AdaDUAL admission is
    // myopic w.r.t. repeated elephant slowdowns at high contention, so at
    // the macro scale it lands within a few percent of SRSF(1) instead of
    // 20% ahead. The pairwise win itself is verified in
    // sim::tests::adadual_admits_small_against_large and the Theorem 1–2
    // property tests.
    let jobs = trace::generate(&TraceConfig::paper_160());
    let ada = eval("lwf", "ada", &jobs);
    let s1 = eval("lwf", "srsf1", &jobs);
    let s2 = eval("lwf", "srsf2", &jobs);
    let s3 = eval("lwf", "srsf3", &jobs);
    assert!(
        s1.jct.mean < s2.jct.mean,
        "SRSF(1) {:.1} vs SRSF(2) {:.1}",
        s1.jct.mean,
        s2.jct.mean
    );
    assert!(
        s1.jct.mean < s3.jct.mean,
        "SRSF(1) {:.1} vs SRSF(3) {:.1}",
        s1.jct.mean,
        s3.jct.mean
    );
    assert!(
        ada.jct.mean < s2.jct.mean && ada.jct.mean < s3.jct.mean,
        "Ada-SRSF {:.1} must beat blind acceptance ({:.1}, {:.1})",
        ada.jct.mean,
        s2.jct.mean,
        s3.jct.mean
    );
    assert!(
        ada.jct.mean < s1.jct.mean * 1.05,
        "Ada-SRSF {:.1} should track SRSF(1) {:.1} within 5%",
        ada.jct.mean,
        s1.jct.mean
    );
}

#[test]
fn trace_roundtrip_preserves_simulation() {
    // Serialising and re-parsing a trace must not change results.
    let jobs = trace::generate(&TraceConfig::scaled(30, 9));
    let reparsed = trace::from_json(&trace::to_json(&jobs)).unwrap();
    let a = eval("lwf", "ada", &jobs);
    let b = eval("lwf", "ada", &reparsed);
    assert_eq!(a.jct.mean, b.jct.mean);
    assert_eq!(a.avg_gpu_util, b.avg_gpu_util);
}

#[test]
fn simulation_is_deterministic() {
    let jobs = trace::generate(&TraceConfig::scaled(50, 4));
    let a = eval("lwf", "ada", &jobs);
    let b = eval("lwf", "ada", &jobs);
    assert_eq!(a.jct.mean, b.jct.mean);
    assert_eq!(a.jct.p95, b.jct.p95);
}

#[test]
fn lighter_load_means_lower_jct() {
    // Halving the workload (same arrival horizon shape) must not raise
    // average JCT under the same scheduler.
    let heavy = trace::generate(&TraceConfig::scaled(120, 5));
    let light = trace::generate(&TraceConfig::scaled(30, 5));
    let h = eval("lwf", "ada", &heavy);
    let l = eval("lwf", "ada", &light);
    assert!(
        l.jct.mean <= h.jct.mean * 1.1,
        "light {:.1} vs heavy {:.1}",
        l.jct.mean,
        h.jct.mean
    );
}

#[test]
fn motivation_contention_blowup() {
    // §I: four scattered jobs under blind 4-way-ish contention take much
    // longer than one job alone; the blow-up shrinks under Ada-SRSF.
    let cfg = SimConfig {
        cluster: ClusterSpec::tiny(4, 4),
        comm: CommModel::paper_10gbe(),
        topology: TopologySpec::Flat,
        repricing: sim::Repricing::Dynamic,
        priority: sim::JobPriority::Srsf,
        coalescing: true,
        log_events: false,
        workers: 1,
        faults: FaultPlan::default(),
    };
    let job = |id| JobSpec {
        id,
        arrival: 0.0,
        model: DnnModel::Vgg16,
        n_gpus: 4,
        iterations: 500,
    };
    let mut ff = FirstFitPlacer;
    let solo = sim::simulate(&cfg, &[job(0)], &mut ff, &SrsfCap { cap: 1 });
    let four: Vec<JobSpec> = (0..4).map(job).collect();
    let mut rand = RandomPlacer::new(3);
    let blind = sim::simulate(&cfg, &four, &mut rand, &SrsfCap { cap: 3 });
    let blind_avg = blind.jct.iter().sum::<f64>() / 4.0;
    let blowup = blind_avg / solo.jct[0];
    assert!(
        blowup > 1.3,
        "contention blow-up should be material: {blowup:.2}x"
    );
    let mut rand = RandomPlacer::new(3);
    let ada = sim::simulate(&cfg, &four, &mut rand, &AdaDual { model: cfg.comm });
    let ada_avg = ada.jct.iter().sum::<f64>() / 4.0;
    assert!(
        ada_avg <= blind_avg * 1.02,
        "Ada-SRSF should not be worse than blind acceptance: {ada_avg:.0} vs {blind_avg:.0}"
    );
}
