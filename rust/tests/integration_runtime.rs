//! Runtime + coordinator integration: load the real AOT artifacts, execute
//! train/grad steps through PJRT, and verify numerics end-to-end (the
//! rust-side counterpart of python/tests/test_model.py).
//!
//! These tests require `make artifacts`; they are skipped (not failed)
//! when artifacts/ is missing so `cargo test` works on a fresh checkout.

use ddl_sched::coordinator::{self, CoordinatorConfig, JobRequest, RtServer};
use ddl_sched::prelude::*;
use ddl_sched::runtime::Runtime;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = ddl_sched::runtime::default_artifacts_dir();
    if dir.join("meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn runtime_loads_and_reports_meta() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    assert!(rt.meta.n_params > 100_000);
    assert_eq!(rt.meta.tokens_shape.0, rt.meta.batch);
    assert!(rt.meta.vocab >= 4);
    assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    let params = rt.init_params().unwrap();
    assert_eq!(params.len(), rt.meta.n_params);
    assert!(params.iter().all(|x| x.is_finite()));
}

#[test]
fn train_step_learns_and_matches_ref() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let params0 = rt.init_params().unwrap();
    let (b, t) = rt.meta.tokens_shape;
    let mut stream = coordinator::data::TokenStream::new(7, rt.meta.vocab);
    let tokens = stream.batch(b, t);

    // Pallas and reference variants must agree (same math, different kernels).
    let (p_pal, l_pal) = rt.train_step(&params0, &tokens, true).unwrap();
    let (p_ref, l_ref) = rt.train_step(&params0, &tokens, false).unwrap();
    assert!((l_pal - l_ref).abs() < 1e-3, "loss mismatch {l_pal} vs {l_ref}");
    let max_dp = p_pal
        .iter()
        .zip(&p_ref)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_dp < 1e-3, "param divergence {max_dp}");

    // Loss at init is near log(vocab); a few steps reduce it.
    let expect = (rt.meta.vocab as f32).ln();
    assert!((l_pal - expect).abs() < 1.0, "init loss {l_pal} vs ln(V)={expect}");
    let mut params = p_pal;
    let mut last = l_pal;
    for _ in 0..5 {
        let toks = stream.batch(b, t);
        let (p, l) = rt.train_step(&params, &toks, true).unwrap();
        params = p;
        last = l;
    }
    assert!(last < l_pal, "no learning: {l_pal} -> {last}");
}

#[test]
fn grad_path_equals_fused_step() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let params = rt.init_params().unwrap();
    let (b, t) = rt.meta.tokens_shape;
    let tokens = coordinator::data::TokenStream::new(3, rt.meta.vocab).batch(b, t);
    let lr = rt.meta.lr as f32;

    let (p_fused, l_fused) = rt.train_step(&params, &tokens, true).unwrap();
    let (grads, l_grad) = rt.grad_step(&params, &tokens).unwrap();
    let p_manual = rt.apply_grads(&params, &grads, lr).unwrap();
    assert!((l_fused - l_grad).abs() < 1e-4);
    let max_d = p_fused
        .iter()
        .zip(&p_manual)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_d < 1e-4, "grad path diverges from fused step: {max_d}");
}

#[test]
fn allreduce_sum_is_elementwise_add() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let n = rt.meta.n_params;
    let x: Vec<f32> = (0..n).map(|i| (i % 13) as f32).collect();
    let y: Vec<f32> = (0..n).map(|i| -((i % 7) as f32)).collect();
    let s = rt.allreduce_sum(&x, &y).unwrap();
    for i in (0..n).step_by(n / 17 + 1) {
        assert_eq!(s[i], x[i] + y[i], "index {i}");
    }
}

#[test]
fn coordinator_end_to_end_small() {
    let Some(dir) = artifacts_dir() else { return };
    let server = RtServer::start(dir).unwrap();
    let cluster = ClusterSpec::tiny(2, 2);
    let cfg = CoordinatorConfig {
        time_scale: 0.0, // no pacing in tests; admission logic still runs
        ..CoordinatorConfig::default_ada(cluster)
    };
    let jobs = vec![
        JobRequest { id: 0, n_workers: 2, steps: 4, seed: 11 },
        JobRequest { id: 1, n_workers: 2, steps: 4, seed: 12 },
    ];
    let reports = coordinator::run_jobs(&cfg, &server, &jobs).unwrap();
    assert_eq!(reports.len(), 2);
    for r in &reports {
        assert_eq!(r.losses.len(), 4);
        assert!(r.losses.iter().all(|l| l.is_finite()));
        assert_eq!(r.gpus.len(), 2);
    }
    // 2 jobs x 2 workers on 2x2 cluster: LWF-1 consolidates each onto one
    // server, so no job needs inter-node communication.
    assert!(reports.iter().all(|r| !r.multi_server));
}

#[test]
fn coordinator_multi_server_takes_comm_path() {
    let Some(dir) = artifacts_dir() else { return };
    let server = RtServer::start(dir).unwrap();
    let cluster = ClusterSpec::tiny(4, 1); // 1 GPU per server forces spanning
    let cfg = CoordinatorConfig {
        time_scale: 0.0,
        ..CoordinatorConfig::default_ada(cluster)
    };
    let jobs = vec![
        JobRequest { id: 0, n_workers: 2, steps: 3, seed: 21 },
        JobRequest { id: 1, n_workers: 2, steps: 3, seed: 22 },
    ];
    let reports = coordinator::run_jobs(&cfg, &server, &jobs).unwrap();
    for r in &reports {
        assert!(r.multi_server, "1-GPU servers force multi-server placement");
        assert_eq!(r.comm_rounds, 3, "one gated all-reduce per step");
        assert!(r.losses.iter().all(|l| l.is_finite()));
    }
}
