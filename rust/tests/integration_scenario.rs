//! Scenario/Experiment API integration: the paper grid runs end-to-end
//! from a single JSON artifact, parallel execution is byte-identical to
//! serial, and scenario files round-trip through disk — the same path the
//! `ddl-sched sweep --scenario FILE --threads N` CLI takes.

use ddl_sched::prelude::*;

/// A fast stand-in for the paper grid: same 4 x 4 placer x policy axes on
/// a scaled-down workload so the whole test stays in the sub-second range.
fn small_paper_grid() -> Experiment {
    Experiment::paper_grid(Scenario::small("grid-test", 4, 4, 24))
}

#[test]
fn paper_grid_runs_from_one_json_artifact() {
    // Serialize the grid to its artifact form, re-load it, run it: exactly
    // what the CLI does with a scenario file.
    let artifact = small_paper_grid().to_json_text();
    let exp = Experiment::from_text(&artifact).unwrap();
    let records = exp.run(2).unwrap();
    assert_eq!(records.len(), registry::PAPER_PLACERS.len() * registry::POLICIES.len());
    for r in &records {
        assert_eq!(r.eval.jct.n, 24, "{} lost jobs", r.scenario.label());
        assert!(r.eval.jct.mean > 0.0 && r.eval.jct.mean.is_finite());
        assert!(r.eval.avg_gpu_util > 0.0 && r.eval.avg_gpu_util <= 1.0);
    }
    // Every placer x policy combination appears exactly once.
    for placer in registry::PAPER_PLACERS {
        for policy in registry::POLICIES {
            let n = records
                .iter()
                .filter(|r| r.scenario.placer == placer && r.scenario.policy == policy)
                .count();
            assert_eq!(n, 1, "{placer}/{policy}");
        }
    }
}

#[test]
fn parallel_grid_is_byte_identical_to_serial() {
    let exp = small_paper_grid();
    let serial = exp.run(1).unwrap();
    let parallel = exp.run(4).unwrap();
    assert_eq!(records_to_json(&serial), records_to_json(&parallel));
    assert_eq!(records_to_csv(&serial), records_to_csv(&parallel));
}

#[test]
fn scenario_file_roundtrip_through_disk() {
    let dir = std::env::temp_dir();
    let scenario_path = dir.join("ddl_sched_it_scenario.json");
    let s = Scenario::small("disk-roundtrip", 2, 2, 10);
    std::fs::write(&scenario_path, s.to_json_text()).unwrap();
    let loaded = Scenario::from_file(scenario_path.to_str().unwrap()).unwrap();
    assert_eq!(loaded, s);
    // A bare scenario file also loads as a single-run experiment.
    let exp = Experiment::from_file(scenario_path.to_str().unwrap()).unwrap();
    assert_eq!(exp, Experiment::single(s));
    let records = exp.run(1).unwrap();
    assert_eq!(records.len(), 1);
    let _ = std::fs::remove_file(&scenario_path);
}

#[test]
fn priority_axis_is_sweepable() {
    // The SRSF/FIFO/LAS axis (sweep --what priority) runs and produces
    // distinct schedules on a contended workload.
    let exp = Experiment {
        priorities: JobPriority::all().to_vec(),
        ..Experiment::single(Scenario::small("priority", 2, 2, 20))
    };
    let records = exp.run(3).unwrap();
    assert_eq!(records.len(), 3);
    let srsf = &records[0];
    let fifo = &records[1];
    assert_eq!(srsf.scenario.priority, JobPriority::Srsf);
    assert_eq!(fifo.scenario.priority, JobPriority::Fifo);
    assert!(
        (srsf.eval.jct.mean - fifo.eval.jct.mean).abs() > 1e-9,
        "SRSF and FIFO produced identical schedules on a contended workload"
    );
}

#[test]
fn run_record_json_parses_back() {
    let records = Experiment::single(Scenario::small("json", 2, 2, 8)).run(1).unwrap();
    let text = records_to_json(&records);
    let v = ddl_sched::util::json::Json::parse(&text).unwrap();
    let arr = v.as_arr().unwrap();
    assert_eq!(arr.len(), 1);
    let scenario = Scenario::from_json(arr[0].get("scenario").unwrap()).unwrap();
    assert_eq!(scenario, records[0].scenario);
}

#[test]
fn committed_paper_grid_artifact_parses_and_expands() {
    // The repo ships the paper grid as a scenario file; it must stay in
    // sync with the schema (cargo runs integration tests from the package
    // root, where scenarios/ lives).
    let exp = Experiment::from_file("scenarios/paper_grid.json").unwrap();
    assert_eq!(exp.base.name, "paper");
    assert_eq!(exp.base.cluster.n_gpus(), 64);
    let grid = exp.grid().unwrap();
    assert_eq!(grid.len(), 16);
}

#[test]
fn committed_oversub_sweep_artifact_parses_and_expands() {
    // The two-tier oversubscription family ships as a scenario file:
    // policy x {2:1, 4:1, 8:1} over the paper workload on racks of 4.
    let exp = Experiment::from_file("scenarios/oversub_sweep.json").unwrap();
    assert_eq!(exp.oversubs, vec![2.0, 4.0, 8.0]);
    let grid = exp.grid().unwrap();
    assert_eq!(grid.len(), registry::POLICIES.len() * 3);
    for s in &grid {
        match s.topology {
            TopologySpec::TwoTier { rack_size, oversubscription } => {
                assert_eq!(rack_size, 4);
                assert!([2.0, 4.0, 8.0].contains(&oversubscription));
            }
            ref other => panic!("expected two-tier, got {other:?}"),
        }
        assert_eq!(s.placer, "lwf-rack");
    }
}

#[test]
fn two_tier_grid_runs_end_to_end() {
    // A scaled-down oversubscription sweep through the whole
    // file -> grid -> threads -> records pipeline.
    let base = Scenario {
        placer: "lwf-rack".into(),
        topology: TopologySpec::TwoTier { rack_size: 2, oversubscription: 2.0 },
        ..Scenario::small("2tier-grid", 4, 2, 16)
    };
    let exp = Experiment {
        policies: vec!["srsf1".into(), "ada".into()],
        oversubs: vec![2.0, 8.0],
        ..Experiment::single(base)
    };
    let text = exp.to_json_text();
    let reloaded = Experiment::from_text(&text).unwrap();
    assert_eq!(reloaded, exp);
    let serial = reloaded.run(1).unwrap();
    let parallel = reloaded.run(4).unwrap();
    assert_eq!(records_to_json(&serial), records_to_json(&parallel));
    assert_eq!(serial.len(), 4);
    for r in &serial {
        assert_eq!(r.eval.jct.n, 16, "{} lost jobs", r.scenario.label());
        assert!(r.scenario.label().contains("2tier"), "{}", r.scenario.label());
    }
}

#[test]
fn flat_record_json_is_topology_free() {
    // Byte-stability contract: a flat scenario's RunRecord JSON carries no
    // topology section, exactly like the pre-net schema.
    let recs = Experiment::single(Scenario::small("flat-json", 2, 2, 8)).run(1).unwrap();
    let text = records_to_json(&recs);
    assert!(!text.contains("topology"), "flat record JSON grew a topology field");
    // And the CSV column set is unchanged.
    let csv = records_to_csv(&recs);
    assert!(!csv.lines().next().unwrap().contains("topology"));
}

#[test]
fn registry_matches_legacy_names_end_to_end() {
    // The names the old placement::by_name / sched::by_name accepted keep
    // resolving through the unified registry.
    for name in ["rand", "RAND", "ff", "FF", "ls", "LS", "lwf", "LWF", "lwf-rack"] {
        assert!(registry::make_placer(name, 1, 0, usize::MAX).is_ok(), "{name}");
    }
    let cm = CommModel::paper_10gbe();
    for name in ["srsf1", "SRSF(1)", "srsf2", "SRSF(2)", "srsf3", "SRSF(3)", "ada", "adadual"] {
        assert!(registry::make_policy(name, cm).is_ok(), "{name}");
    }
    assert!(registry::make_placer("nope", 1, 0, usize::MAX).is_err());
    assert!(registry::make_policy("nope", cm).is_err());
}
