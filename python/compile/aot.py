"""AOT compile path: lower the L2 entry points to HLO *text* artifacts.

Run once at build time (`make artifacts`); the rust runtime loads the text
via `HloModuleProto::from_text_file` and compiles it on the PJRT CPU
client. Python is never on the request path.

HLO TEXT, not `.serialize()`: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the image's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Artifacts written to --out-dir (default ../artifacts):
  train_step.hlo.txt       (params, tokens)        -> (params', loss)  [pallas fwd]
  train_step_ref.hlo.txt   same, pure-jnp kernels (L1 ablation baseline)
  grad_step.hlo.txt        (params, tokens)        -> (grads, loss)
  allreduce_sum.hlo.txt    (x, y)                  -> x + y
  apply_grads.hlo.txt      (params, grads, scale)  -> params'
  init_params.bin          raw little-endian f32 parameter vector
  meta.json                config, shapes, artifact index
"""

from __future__ import annotations

import argparse
import json
import hashlib
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifacts(preset: str, batch: int, lr: float, seed: int, out_dir: str) -> dict:
    cfg = M.Config.preset(preset, use_pallas=True)
    cfg_ref = M.Config.preset(preset, use_pallas=False)
    n_params = M.param_count(cfg)

    p_spec = jax.ShapeDtypeStruct((n_params,), jnp.float32)
    # tokens carry T+1 positions: model consumes [:, :-1], targets [:, 1:]
    tok_spec = jax.ShapeDtypeStruct((batch, cfg.seq_len + 1), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)

    os.makedirs(out_dir, exist_ok=True)
    artifacts = {}

    def emit(name: str, fn, *specs):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        artifacts[name] = {
            "file": f"{name}.hlo.txt",
            "bytes": len(text),
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        print(f"  {name}: {len(text)} chars")

    emit("train_step", M.make_train_step(cfg, lr=lr), p_spec, tok_spec)
    emit("train_step_ref", M.make_train_step(cfg_ref, lr=lr), p_spec, tok_spec)
    emit("grad_step", M.make_grad_step(cfg), p_spec, tok_spec)
    emit("allreduce_sum", M.allreduce_sum, p_spec, p_spec)
    emit("apply_grads", M.apply_grads, p_spec, p_spec, scalar)

    params = M.init_params(cfg, seed=seed)
    with open(os.path.join(out_dir, "init_params.bin"), "wb") as f:
        f.write(params.tobytes())

    meta = {
        "preset": preset,
        "config": cfg.as_dict(),
        "batch": batch,
        "lr": lr,
        "seed": seed,
        "n_params": n_params,
        "tokens_shape": [batch, cfg.seq_len + 1],
        "artifacts": artifacts,
        "param_layout": [
            {"name": n, "shape": list(s)} for n, s in M.param_shapes(cfg)
        ],
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--preset", default="small", choices=sorted(M.PRESETS))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print(f"lowering preset={args.preset} batch={args.batch} -> {args.out_dir}")
    meta = lower_artifacts(args.preset, args.batch, args.lr, args.seed, args.out_dir)
    print(f"n_params={meta['n_params']}  artifacts={len(meta['artifacts'])}")


if __name__ == "__main__":
    main()
