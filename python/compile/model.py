"""Layer-2: decoder-only transformer LM in JAX, calling the L1 Pallas kernels.

This is the DDL training workload executed by the rust coordinator in the
end-to-end prototype (`examples/e2e_train.rs`). The whole parameter set is
flattened into ONE f32 vector so the rust side only ever handles a single
parameter literal per job; (un)flattening happens inside the jitted
functions and costs nothing after XLA fusion.

Exported entry points (AOT-lowered to HLO text by aot.py):
  train_step(params, tokens)        -> (params', loss)      single-worker
  grad_step(params, tokens)         -> (grads, loss)        data-parallel worker
  apply_grads(params, grads, scale) -> params'               leader update
  allreduce_sum(x, y)               -> x + y                 reduction stage

The Pallas kernels sit on the forward path through jax.custom_vjp wrappers:
interpret-mode pallas_call is not differentiable, so the backward pass uses
the pure-jnp reference math (a rematerialising backward, the common choice
for flash attention anyway).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import flash_attention, fused_linear
from .kernels import ref as kref

# ---------------------------------------------------------------------------
# Config

PRESETS: Dict[str, Dict[str, int]] = {
    # ~0.46 M params; < 1 s/step on 1 CPU core. Default e2e workload.
    "small": dict(vocab=256, d_model=128, n_layers=2, n_heads=4, d_ff=512, seq_len=64),
    # ~3.7 M params; the "medium" ablation workload.
    "medium": dict(vocab=1024, d_model=256, n_layers=4, n_heads=8, d_ff=1024, seq_len=128),
    # ~33 M params; compile-only scale check (too slow to train on 1 CPU core).
    "base": dict(vocab=8192, d_model=512, n_layers=8, n_heads=8, d_ff=2048, seq_len=256),
}


class Config:
    """Transformer hyper-parameters plus kernel block sizes."""

    def __init__(
        self,
        vocab: int,
        d_model: int,
        n_layers: int,
        n_heads: int,
        d_ff: int,
        seq_len: int,
        use_pallas: bool = True,
    ) -> None:
        assert d_model % n_heads == 0
        self.vocab = vocab
        self.d_model = d_model
        self.n_layers = n_layers
        self.n_heads = n_heads
        self.d_ff = d_ff
        self.seq_len = seq_len
        self.d_head = d_model // n_heads
        self.use_pallas = use_pallas

    @classmethod
    def preset(cls, name: str, use_pallas: bool = True) -> "Config":
        return cls(**PRESETS[name], use_pallas=use_pallas)

    def as_dict(self) -> Dict[str, Any]:
        d = {k: getattr(self, k) for k in
             ("vocab", "d_model", "n_layers", "n_heads", "d_ff", "seq_len")}
        d["use_pallas"] = self.use_pallas
        return d


# ---------------------------------------------------------------------------
# Parameter pytree <-> flat f32 vector

def param_shapes(cfg: Config) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic (name, shape) list defining the flat layout."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    shapes: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed", (v, d)),
        ("pos_embed", (cfg.seq_len, d)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        shapes += [
            (p + "ln1.scale", (d,)), (p + "ln1.bias", (d,)),
            (p + "attn.wqkv", (d, 3 * d)), (p + "attn.bqkv", (3 * d,)),
            (p + "attn.wo", (d, d)), (p + "attn.bo", (d,)),
            (p + "ln2.scale", (d,)), (p + "ln2.bias", (d,)),
            (p + "mlp.w1", (d, f)), (p + "mlp.b1", (f,)),
            (p + "mlp.w2", (f, d)), (p + "mlp.b2", (d,)),
        ]
    shapes += [("ln_f.scale", (d,)), ("ln_f.bias", (d,)), ("unembed", (d, v))]
    return shapes


def param_count(cfg: Config) -> int:
    return sum(int(np.prod(s)) for _, s in param_shapes(cfg))


def unflatten(cfg: Config, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    params, off = {}, 0
    for name, shape in param_shapes(cfg):
        n = int(np.prod(shape))
        params[name] = flat[off:off + n].reshape(shape)
        off += n
    return params


def flatten(cfg: Config, params: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    return jnp.concatenate([params[n].reshape(-1) for n, _ in param_shapes(cfg)])


def init_params(cfg: Config, seed: int = 0) -> np.ndarray:
    """GPT-2-style init, returned as the flat f32 numpy vector."""
    rng = np.random.default_rng(seed)
    chunks = []
    for name, shape in param_shapes(cfg):
        if name.endswith((".bias", ".bo", ".b1", ".b2", ".bqkv")):
            a = np.zeros(shape, np.float32)
        elif name.endswith(".scale"):
            a = np.ones(shape, np.float32)
        elif name in ("embed", "pos_embed", "unembed"):
            a = rng.normal(0.0, 0.02, shape).astype(np.float32)
        else:  # projection matrices
            a = rng.normal(0.0, 0.02, shape).astype(np.float32)
            if name.endswith((".wo", ".w2")):  # residual-branch scaling
                a /= np.sqrt(2.0 * cfg.n_layers)
        chunks.append(a.reshape(-1))
    return np.concatenate(chunks)


# ---------------------------------------------------------------------------
# Differentiable wrappers: Pallas forward, reference backward

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _linear_gelu(x, w, b, use_pallas):
    if use_pallas:
        return fused_linear(x, w, b, activation="gelu")
    return kref.fused_linear_ref(x, w, b, activation="gelu")


def _linear_gelu_fwd(x, w, b, use_pallas):
    return _linear_gelu(x, w, b, use_pallas), (x, w, b)


def _linear_gelu_bwd(use_pallas, res, g):
    x, w, b = res
    _, vjp = jax.vjp(lambda x_, w_, b_: kref.fused_linear_ref(x_, w_, b_, "gelu"), x, w, b)
    return vjp(g)


_linear_gelu.defvjp(_linear_gelu_fwd, _linear_gelu_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _attention(q, k, v, causal, use_pallas):
    if use_pallas:
        return flash_attention(q, k, v, causal=causal)
    return kref.attention_ref(q, k, v, causal=causal)


def _attention_fwd(q, k, v, causal, use_pallas):
    return _attention(q, k, v, causal, use_pallas), (q, k, v)


def _attention_bwd(causal, use_pallas, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: kref.attention_ref(q_, k_, v_, causal=causal), q, k, v)
    return vjp(g)


_attention.defvjp(_attention_fwd, _attention_bwd)


# ---------------------------------------------------------------------------
# Model

def _layer_norm(x, scale, bias, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def forward(cfg: Config, flat_params: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens: (B, T) int32 -> logits (B, T, vocab). T <= cfg.seq_len."""
    p = unflatten(cfg, flat_params)
    bsz, t = tokens.shape
    x = p["embed"][tokens] + p["pos_embed"][:t][None]
    for i in range(cfg.n_layers):
        l = f"layer{i}."
        h = _layer_norm(x, p[l + "ln1.scale"], p[l + "ln1.bias"])
        qkv = h @ p[l + "attn.wqkv"] + p[l + "attn.bqkv"]  # (B,T,3D)
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(a):  # (B,T,D) -> (B*H, T, Dh)
            return (a.reshape(bsz, t, cfg.n_heads, cfg.d_head)
                     .transpose(0, 2, 1, 3)
                     .reshape(bsz * cfg.n_heads, t, cfg.d_head))

        att = _attention(heads(q), heads(k), heads(v), True, cfg.use_pallas)
        att = (att.reshape(bsz, cfg.n_heads, t, cfg.d_head)
                  .transpose(0, 2, 1, 3)
                  .reshape(bsz, t, cfg.d_model))
        x = x + att @ p[l + "attn.wo"] + p[l + "attn.bo"]

        h = _layer_norm(x, p[l + "ln2.scale"], p[l + "ln2.bias"])
        h2 = _linear_gelu(h.reshape(bsz * t, cfg.d_model), p[l + "mlp.w1"],
                          p[l + "mlp.b1"], cfg.use_pallas)
        x = x + (h2 @ p[l + "mlp.w2"] + p[l + "mlp.b2"]).reshape(bsz, t, cfg.d_model)
    x = _layer_norm(x, p["ln_f.scale"], p["ln_f.bias"])
    return x @ p["unembed"]


def loss_fn(cfg: Config, flat_params: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """Next-token cross-entropy over tokens (B, T+1 truncated internally)."""
    logits = forward(cfg, flat_params, tokens[:, :-1])
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt_logit = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return (logz - tgt_logit).mean()


# ---------------------------------------------------------------------------
# AOT entry points (each is jitted and lowered by aot.py)

def make_train_step(cfg: Config, lr: float = 0.05):
    """(params, tokens) -> (params', loss). Single-worker SGD step."""

    def train_step(flat_params, tokens):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens))(flat_params)
        return flat_params - lr * grads, loss

    return train_step


def make_grad_step(cfg: Config):
    """(params, tokens) -> (grads, loss). One data-parallel worker's step."""

    def grad_step(flat_params, tokens):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens))(flat_params)
        return grads, loss

    return grad_step


def apply_grads(flat_params, summed_grads, scale):
    """params - scale * grads; scale = lr / n_workers as f32 scalar array."""
    return flat_params - scale * summed_grads


def allreduce_sum(x, y):
    """One reduction stage of the coordinator-driven all-reduce tree."""
    return x + y
