"""Layer-1 Pallas kernel: tiled fused linear layer (matmul + bias + GELU).

This is the transformer MLP hot-spot. On a real TPU the kernel tiles the
operands into VMEM-resident blocks and drives the MXU with 128-aligned
matmul tiles; here we express exactly that schedule with ``BlockSpec`` and
run under ``interpret=True`` so the lowered HLO executes on any PJRT
backend (the rust CPU client included).

Hardware adaptation (the paper's workloads are CUDA models on V100s; see
DESIGN.md §Hardware-Adaptation): a CUDA kernel would assign one threadblock
per output tile and stage A/B panels through shared memory; the TPU-style
equivalent is the (i, j, k) grid below where each BlockSpec index_map
expresses the HBM->VMEM panel schedule and the MXU consumes
(bm, bk) x (bk, bn) tiles. The f32 accumulator is the output block itself,
which stays VMEM-resident across the innermost k loop.

VMEM budget at the default tiles (f32): A panel 128x512 (256 KiB) +
B panel 512x128 (256 KiB) + out 128x128 (64 KiB) + bias 128 (0.5 KiB)
= 0.57 MiB, far under the ~16 MiB/core budget — enough headroom for the
compiler to double-buffer both input streams.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly multiples of 128 in the matmul dims.
BM, BK, BN = 128, 512, 128


def gelu_tanh(x):
    """tanh-approximation GELU (matches jax.nn.gelu(approximate=True))."""
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x * x * x)))


def _fused_linear_kernel(x_ref, w_ref, b_ref, o_ref, *, nsteps, activation):
    """Grid = (m/bm, n/bn, k/bk); k innermost so the output block stays hot."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU tile: (bm, bk) @ (bk, bn) accumulated in f32.
    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(ki == nsteps - 1)
    def _epilogue():
        acc = o_ref[...] + b_ref[...][None, :]
        if activation == "gelu":
            acc = gelu_tanh(acc)
        o_ref[...] = acc


def fused_linear(x, w, b, *, bm=BM, bk=BK, bn=BN, activation="gelu"):
    """y = activation(x @ w + b) with a Pallas tiled kernel.

    x: (M, K), w: (K, N), b: (N,), all f32. Dims need not be tile
    multiples: operands are zero-padded up to tile multiples (out-of-bounds
    block reads are *undefined* on TPU and NaN-poisoned in interpret mode,
    so explicit padding is required for ragged edges) and the result is
    sliced back. Zero padding is exact for matmul + bias.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"
    bm_, bk_, bn_ = min(bm, m), min(bk, k), min(bn, n)

    def rnd(v, t):
        return (v + t - 1) // t * t

    mp, kp, np_ = rnd(m, bm_), rnd(k, bk_), rnd(n, bn_)
    if (mp, kp) != (m, k):
        x = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        w = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    if np_ != n:
        b = jnp.pad(b, (0, np_ - n))
    grid = (mp // bm_, np_ // bn_, kp // bk_)
    kernel = functools.partial(
        _fused_linear_kernel, nsteps=grid[2], activation=activation
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, ki: (i, ki)),
            pl.BlockSpec((bk_, bn_), lambda i, j, ki: (ki, j)),
            pl.BlockSpec((bn_,), lambda i, j, ki: (j,)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, ki: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(x, w, b)[:m, :n]
