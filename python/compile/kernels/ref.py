"""Pure-jnp oracles for the Pallas kernels (the build-time correctness bar).

Every kernel in this package has a reference here; pytest + hypothesis sweep
shapes and compare with assert_allclose. These are also the "unfused"
baselines used by the L2 ablation (model.py use_pallas=False).
"""

from __future__ import annotations

import jax.numpy as jnp


def gelu_tanh(x):
    """tanh-approximation GELU, the same polynomial as the kernel epilogue."""
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x * x * x)))


def fused_linear_ref(x, w, b, activation="gelu"):
    """y = activation(x @ w + b)."""
    y = x @ w + b
    if activation == "gelu":
        y = gelu_tanh(y)
    return y


def attention_ref(q, k, v, *, causal=True, scale=None):
    """Naive materialised-scores attention. q,k,v: (B, S, D)."""
    b, s, d = q.shape
    if scale is None:
        scale = 1.0 / (d**0.5)
    scores = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask[None], scores, -1e30)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, v)
