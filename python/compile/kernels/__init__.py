"""L1 Pallas kernels for the DDL training workload (interpret=True on CPU).

- fused_linear: tiled matmul + bias + GELU (transformer MLP hot-spot)
- flash_attention: blockwise-softmax fused attention
- ref: pure-jnp oracles used by pytest and the no-pallas ablation
"""

from .attention import flash_attention
from .fused_linear import fused_linear

__all__ = ["flash_attention", "fused_linear"]
