"""Layer-1 Pallas kernel: fused scaled-dot-product attention.

Blockwise (flash-style) attention adapted for the TPU memory hierarchy:
instead of CUDA warps cooperating through shared memory, each grid step
holds one (bq, d) query panel in VMEM and streams (bkv, d) key/value panels
from HBM, maintaining the running row-max / row-sum online-softmax state in
two small VMEM scratch columns. The MXU consumes the (bq, d) x (d, bkv)
score tile and the (bq, bkv) x (bkv, d) value tile.

Masking: padded key columns (cols >= kv_len) are always poisoned to -1e30;
the causal triangle is applied on top when requested. Fully masked rows
(can only be padded query rows) fall back to zero output.

VMEM at defaults (bq=128, bkv=128, d<=128, f32): q 64 KiB + k 64 KiB +
v 64 KiB + out 64 KiB + 2 state columns 1 KiB ~= 0.26 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ, BKV = 128, 128
NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, nkv, scale, causal, kv_len, bq, bkv
):
    """Grid = (batch*heads, q blocks, kv blocks); kv innermost."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]  # (bq, d)
    k = k_ref[0]  # (bkv, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bkv)

    cols = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = cols < kv_len
    if causal:
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        mask = mask & (rows >= cols)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # (bq,)
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])  # (bq, bkv)
    alpha = jnp.exp(m_prev - m_new)  # rescale factor for the old state
    l_ref[...] = alpha * l_prev + jnp.sum(p, axis=1)
    m_ref[...] = m_new
    o_ref[0] = alpha[:, None] * o_ref[0] + jnp.dot(
        p, v_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(ki == nkv - 1)
    def _final():
        l = l_ref[...]
        denom = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> zeros
        o_ref[0] = o_ref[0] / denom[:, None]


def _vmem_scratch(shape):
    """VMEM scratch shape; pltpu.VMEM also works under interpret mode."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)


def flash_attention(q, k, v, *, causal=True, bq=BQ, bkv=BKV, scale=None):
    """softmax(mask(q k^T * scale)) v with a blockwise-softmax Pallas kernel.

    q, k, v: (B, S, D) f32, where B folds batch*heads. S is zero-padded to
    the block size; padded key columns are masked inside the kernel and
    padded query rows are sliced away.
    """
    b, s, d = q.shape
    assert k.shape == v.shape == (b, s, d), (q.shape, k.shape, v.shape)
    if scale is None:
        scale = 1.0 / (d**0.5)
    bq_, bkv_ = min(bq, s), min(bkv, s)

    def rnd(v_, t):
        return (v_ + t - 1) // t * t

    sp = max(rnd(s, bq_), rnd(s, bkv_))
    if sp != s:
        pad = ((0, 0), (0, sp - s), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    grid = (b, sp // bq_, sp // bkv_)
    kernel = functools.partial(
        _attn_kernel,
        nkv=grid[2],
        scale=scale,
        causal=causal,
        kv_len=s,
        bq=bq_,
        bkv=bkv_,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bkv_, d), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bkv_, d), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sp, d), jnp.float32),
        scratch_shapes=[_vmem_scratch((bq_,)), _vmem_scratch((bq_,))],
        interpret=True,
    )(q, k, v)
    return out[:, :s, :]
