"""L1 kernel correctness: Pallas vs pure-jnp oracle, swept with hypothesis."""

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_attention, fused_linear
from compile.kernels import ref as kref

jax.config.update("jax_platform_name", "cpu")

RTOL, ATOL = 2e-5, 2e-5


def _rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape, jnp.float32) * scale


# ---------------------------------------------------------------------------
# fused_linear


@pytest.mark.parametrize(
    "m,k,n,bm,bk,bn",
    [
        (128, 512, 128, 128, 512, 128),  # exact default tiles
        (64, 64, 64, 32, 32, 32),        # multiple blocks each dim
        (1, 1, 1, 8, 8, 8),              # degenerate
        (100, 200, 72, 32, 64, 32),      # ragged everywhere
        (257, 129, 65, 128, 128, 64),    # prime-ish ragged
    ],
)
def test_fused_linear_shapes(m, k, n, bm, bk, bn):
    key = jax.random.key(m * 7 + k * 3 + n)
    x = _rand(jax.random.fold_in(key, 0), (m, k), 0.5)
    w = _rand(jax.random.fold_in(key, 1), (k, n), 0.1)
    b = _rand(jax.random.fold_in(key, 2), (n,))
    y = fused_linear(x, w, b, bm=bm, bk=bk, bn=bn)
    r = kref.fused_linear_ref(x, w, b)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=RTOL, atol=ATOL)


def test_fused_linear_no_activation():
    key = jax.random.key(0)
    x = _rand(jax.random.fold_in(key, 0), (48, 80))
    w = _rand(jax.random.fold_in(key, 1), (80, 24), 0.2)
    b = _rand(jax.random.fold_in(key, 2), (24,))
    y = fused_linear(x, w, b, bm=16, bk=32, bn=16, activation="none")
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(x @ w + b), rtol=RTOL, atol=ATOL
    )


def test_fused_linear_zero_inputs():
    y = fused_linear(jnp.zeros((16, 16)), jnp.zeros((16, 16)), jnp.zeros((16,)))
    assert not np.isnan(np.asarray(y)).any()
    np.testing.assert_allclose(np.asarray(y), 0.0, atol=1e-7)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    bm=st.sampled_from([8, 16, 32, 64]),
    bk=st.sampled_from([8, 16, 32, 64]),
    bn=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_hypothesis(m, k, n, bm, bk, bn, seed):
    key = jax.random.key(seed)
    x = _rand(jax.random.fold_in(key, 0), (m, k), 0.5)
    w = _rand(jax.random.fold_in(key, 1), (k, n), 0.2)
    b = _rand(jax.random.fold_in(key, 2), (n,))
    y = fused_linear(x, w, b, bm=bm, bk=bk, bn=bn)
    r = kref.fused_linear_ref(x, w, b)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=5e-5, atol=5e-5)


# ---------------------------------------------------------------------------
# flash_attention


@pytest.mark.parametrize(
    "b,s,d,causal,bq,bkv",
    [
        (1, 128, 64, True, 128, 128),   # exact default-ish tiles
        (2, 100, 32, True, 32, 32),     # ragged seq
        (1, 64, 16, False, 32, 16),     # non-causal
        (3, 33, 8, True, 16, 16),       # small ragged
        (4, 16, 4, False, 16, 16),      # single block
    ],
)
def test_attention_shapes(b, s, d, causal, bq, bkv):
    key = jax.random.key(b * 31 + s)
    q = _rand(jax.random.fold_in(key, 0), (b, s, d))
    k = _rand(jax.random.fold_in(key, 1), (b, s, d))
    v = _rand(jax.random.fold_in(key, 2), (b, s, d))
    y = flash_attention(q, k, v, causal=causal, bq=bq, bkv=bkv)
    r = kref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=RTOL, atol=ATOL)


def test_attention_causality():
    """Perturbing a future key must not change earlier outputs."""
    key = jax.random.key(7)
    q = _rand(jax.random.fold_in(key, 0), (1, 32, 8))
    k = _rand(jax.random.fold_in(key, 1), (1, 32, 8))
    v = _rand(jax.random.fold_in(key, 2), (1, 32, 8))
    y0 = flash_attention(q, k, v, causal=True, bq=16, bkv=16)
    k2 = k.at[0, 20].add(100.0)
    v2 = v.at[0, 20].add(-50.0)
    y1 = flash_attention(q, k2, v2, causal=True, bq=16, bkv=16)
    np.testing.assert_allclose(
        np.asarray(y0[0, :20]), np.asarray(y1[0, :20]), rtol=1e-6, atol=1e-6
    )
    assert np.abs(np.asarray(y0[0, 20:]) - np.asarray(y1[0, 20:])).max() > 1e-3


def test_attention_scale_override():
    key = jax.random.key(9)
    q = _rand(jax.random.fold_in(key, 0), (1, 24, 8))
    k = _rand(jax.random.fold_in(key, 1), (1, 24, 8))
    v = _rand(jax.random.fold_in(key, 2), (1, 24, 8))
    y = flash_attention(q, k, v, causal=False, scale=0.1, bq=8, bkv=8)
    r = kref.attention_ref(q, k, v, causal=False, scale=0.1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=RTOL, atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    s=st.integers(2, 80),
    d=st.sampled_from([4, 8, 16, 32]),
    causal=st.booleans(),
    bq=st.sampled_from([8, 16, 32]),
    bkv=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_hypothesis(b, s, d, causal, bq, bkv, seed):
    key = jax.random.key(seed)
    q = _rand(jax.random.fold_in(key, 0), (b, s, d))
    k = _rand(jax.random.fold_in(key, 1), (b, s, d))
    v = _rand(jax.random.fold_in(key, 2), (b, s, d))
    y = flash_attention(q, k, v, causal=causal, bq=bq, bkv=bkv)
    r = kref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=5e-5, atol=5e-5)
