"""L2 model correctness: pallas vs ref forward, gradients, training sanity."""

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def tiny_cfg():
    return M.Config(vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=64, seq_len=16)


@pytest.fixture(scope="module")
def tiny_cfg_ref():
    return M.Config(
        vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=64, seq_len=16,
        use_pallas=False,
    )


def _tokens(cfg, batch, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab, (batch, cfg.seq_len + 1)), jnp.int32)


def test_param_layout_roundtrip(tiny_cfg):
    flat = jnp.asarray(M.init_params(tiny_cfg, seed=1))
    assert flat.shape == (M.param_count(tiny_cfg),)
    tree = M.unflatten(tiny_cfg, flat)
    flat2 = M.flatten(tiny_cfg, tree)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(flat2))


def test_param_count_matches_shapes(tiny_cfg):
    n = sum(int(np.prod(s)) for _, s in M.param_shapes(tiny_cfg))
    assert n == M.param_count(tiny_cfg)


def test_init_params_deterministic(tiny_cfg):
    a = M.init_params(tiny_cfg, seed=3)
    b = M.init_params(tiny_cfg, seed=3)
    c = M.init_params(tiny_cfg, seed=4)
    np.testing.assert_array_equal(a, b)
    assert np.abs(a - c).max() > 0


def test_forward_pallas_matches_ref(tiny_cfg, tiny_cfg_ref):
    flat = jnp.asarray(M.init_params(tiny_cfg, seed=0))
    toks = _tokens(tiny_cfg, 2)[:, :-1]
    y_pallas = M.forward(tiny_cfg, flat, toks)
    y_ref = M.forward(tiny_cfg_ref, flat, toks)
    np.testing.assert_allclose(
        np.asarray(y_pallas), np.asarray(y_ref), rtol=2e-4, atol=2e-4
    )


def test_loss_finite_and_near_uniform_at_init(tiny_cfg):
    flat = jnp.asarray(M.init_params(tiny_cfg, seed=0))
    loss = M.loss_fn(tiny_cfg, flat, _tokens(tiny_cfg, 4))
    assert np.isfinite(float(loss))
    # 0.02-scale init => logits ~ 0 => loss ~ log(vocab)
    assert abs(float(loss) - np.log(tiny_cfg.vocab)) < 0.5


def test_grads_pallas_match_ref(tiny_cfg, tiny_cfg_ref):
    """custom_vjp (pallas fwd, ref bwd) must agree with the all-ref grads."""
    flat = jnp.asarray(M.init_params(tiny_cfg, seed=2))
    toks = _tokens(tiny_cfg, 2, seed=5)
    g_pallas = jax.grad(lambda p: M.loss_fn(tiny_cfg, p, toks))(flat)
    g_ref = jax.grad(lambda p: M.loss_fn(tiny_cfg_ref, p, toks))(flat)
    np.testing.assert_allclose(
        np.asarray(g_pallas), np.asarray(g_ref), rtol=5e-4, atol=5e-4
    )


def test_train_step_decreases_loss(tiny_cfg):
    step = jax.jit(M.make_train_step(tiny_cfg, lr=0.1))
    flat = jnp.asarray(M.init_params(tiny_cfg, seed=0))
    toks = _tokens(tiny_cfg, 8, seed=11)
    losses = []
    for _ in range(15):
        flat, loss = step(flat, toks)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.3, losses


def test_grad_step_matches_train_step(tiny_cfg):
    """apply_grads(grad_step(...)) == train_step(...) for one worker."""
    lr = 0.07
    flat = jnp.asarray(M.init_params(tiny_cfg, seed=6))
    toks = _tokens(tiny_cfg, 4, seed=7)
    p1, l1 = M.make_train_step(tiny_cfg, lr=lr)(flat, toks)
    g, l2 = M.make_grad_step(tiny_cfg)(flat, toks)
    p2 = M.apply_grads(flat, g, jnp.float32(lr))
    assert abs(float(l1) - float(l2)) < 1e-6
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-6, atol=1e-6)


def test_allreduce_sum_is_sum(tiny_cfg):
    x = jnp.arange(8, dtype=jnp.float32)
    y = jnp.ones(8, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(M.allreduce_sum(x, y)), np.arange(8) + 1.0)


def test_data_parallel_equivalence(tiny_cfg):
    """2-worker sum-then-scale == single step on the concatenated batch."""
    lr = 0.05
    flat = jnp.asarray(M.init_params(tiny_cfg, seed=8))
    t1 = _tokens(tiny_cfg, 4, seed=21)
    t2 = _tokens(tiny_cfg, 4, seed=22)
    g1, _ = M.make_grad_step(tiny_cfg)(flat, t1)
    g2, _ = M.make_grad_step(tiny_cfg)(flat, t2)
    summed = M.allreduce_sum(g1, g2)
    p_dp = M.apply_grads(flat, summed, jnp.float32(lr / 2))
    p_big, _ = M.make_train_step(tiny_cfg, lr=lr)(flat, jnp.concatenate([t1, t2]))
    np.testing.assert_allclose(np.asarray(p_dp), np.asarray(p_big), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("preset", sorted(M.PRESETS))
def test_presets_construct(preset):
    cfg = M.Config.preset(preset)
    assert M.param_count(cfg) > 0
    assert cfg.d_model % cfg.n_heads == 0
