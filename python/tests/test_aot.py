"""AOT artifact emission: HLO text is parseable, proto-id-safe, complete."""

import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest

from compile import aot, model as M

REPO = os.path.join(os.path.dirname(__file__), "..", "..")
ARTIFACTS = os.path.join(REPO, "artifacts")

EXPECTED = ["train_step", "train_step_ref", "grad_step", "allreduce_sum", "apply_grads"]


@pytest.fixture(scope="module")
def built_meta():
    """Use the checked-out artifacts if present, else lower a tiny set."""
    meta_path = os.path.join(ARTIFACTS, "meta.json")
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            return json.load(f), ARTIFACTS
    tmp = tempfile.mkdtemp(prefix="aot_test_")
    meta = aot.lower_artifacts("small", batch=2, lr=0.05, seed=0, out_dir=tmp)
    return meta, tmp


def test_all_artifacts_present(built_meta):
    meta, art_dir = built_meta
    for name in EXPECTED:
        assert name in meta["artifacts"], name
        path = os.path.join(art_dir, meta["artifacts"][name]["file"])
        assert os.path.exists(path), path
        assert os.path.getsize(path) > 0


def test_hlo_text_has_no_custom_calls(built_meta):
    """interpret=True pallas must lower to plain HLO the CPU client can run."""
    meta, art_dir = built_meta
    for name in EXPECTED:
        with open(os.path.join(art_dir, meta["artifacts"][name]["file"])) as f:
            text = f.read()
        assert "custom-call" not in text, f"{name} contains a custom-call"
        assert text.startswith("HloModule"), name


def test_entry_layout_matches_meta(built_meta):
    meta, art_dir = built_meta
    n = meta["n_params"]
    b, t = meta["tokens_shape"]
    with open(os.path.join(art_dir, "train_step.hlo.txt")) as f:
        head = f.readline()
    assert f"f32[{n}]" in head
    assert f"s32[{b},{t}]" in head


def test_init_params_bin_size(built_meta):
    meta, art_dir = built_meta
    path = os.path.join(art_dir, "init_params.bin")
    assert os.path.getsize(path) == meta["n_params"] * 4
    params = np.fromfile(path, dtype=np.float32)
    assert np.isfinite(params).all()
    assert params.std() > 0


def test_param_layout_covers_n_params(built_meta):
    meta, _ = built_meta
    total = sum(int(np.prod(e["shape"])) for e in meta["param_layout"])
    assert total == meta["n_params"]


def test_meta_config_reconstructs(built_meta):
    meta, _ = built_meta
    cfg = M.Config(**{k: v for k, v in meta["config"].items()})
    assert M.param_count(cfg) == meta["n_params"]
