//! Scheduling study (Fig 6, Table V): SRSF(1)/(2)/(3) vs Ada-SRSF under
//! LWF-1 placement. Prints Table V and writes the Fig 6 series to
//! `results/*.csv`.
//!
//! Run: `cargo run --release --example sched_study`

use ddl_sched::metrics::{saving, Evaluation};
use ddl_sched::prelude::*;

fn main() {
    let jobs = trace::generate(&TraceConfig::paper_160());
    let cfg = SimConfig::paper();

    let mut table = Table::new(
        "Table V — communication scheduling with LWF-1",
        &["method", "avg util", "avg JCT(s)", "median JCT(s)", "95th JCT(s)"],
    );
    let mut evals = Vec::new();
    for name in ["srsf1", "srsf2", "srsf3", "ada"] {
        let mut placer = LwfPlacer::new(1);
        let policy = sched::by_name(name, cfg.comm).unwrap();
        let res = sim::simulate(&cfg, &jobs, &mut placer, policy.as_ref());
        let label = match name {
            "ada" => "Ada-SRSF".to_string(),
            other => format!("SRSF({})", &other[4..]),
        };
        let eval = Evaluation::from_sim(&label, &res);
        table.row(&eval.table_row());
        let _ = write_csv(&format!("fig6a_cdf_{name}"), &["jct_s", "cdf"], &eval.cdf_rows());
        let utils: Vec<Vec<f64>> = eval.gpu_utils.iter().map(|&u| vec![u]).collect();
        let _ = write_csv(&format!("fig6b_util_{name}"), &["gpu_util"], &utils);
        println!(
            "{label}: admissions clean={} overlapped={} max_k={}",
            res.clean_admissions, res.contended_admissions, res.max_contention
        );
        evals.push(eval);
    }
    table.print();

    let srsf1 = &evals[0];
    let srsf2 = &evals[1];
    let ada = &evals[3];
    println!(
        "\nAda-SRSF saves {:.1}% avg JCT vs SRSF(1)  (paper: 20.1%)",
        saving(srsf1.jct.mean, ada.jct.mean) * 100.0
    );
    println!(
        "Ada-SRSF saves {:.1}% avg JCT vs SRSF(2)  (paper: 36.7%)",
        saving(srsf2.jct.mean, ada.jct.mean) * 100.0
    );
    println!(
        "Ada-SRSF util {:.1}% vs SRSF(1) {:.1}%     (paper: 42.78% vs 30.65%)",
        ada.avg_gpu_util * 100.0,
        srsf1.avg_gpu_util * 100.0
    );
}
