//! Scheduling study (Fig 6, Table V): SRSF(1)/(2)/(3) vs Ada-SRSF under
//! LWF-1 placement — one [`Experiment`] with a policy axis. Prints Table V
//! and writes the Fig 6 series to `results/*.csv`.
//!
//! Run: `cargo run --release --example sched_study`

use ddl_sched::metrics::saving;
use ddl_sched::prelude::*;

fn main() {
    let threads = Experiment::default_threads();
    let exp = Experiment {
        policies: registry::POLICIES.iter().map(|s| s.to_string()).collect(),
        ..Experiment::single(Scenario::paper())
    };
    let records = exp.run(threads).unwrap();

    let mut table = Table::new(
        "Table V — communication scheduling with LWF-1",
        &["method", "avg util", "avg JCT(s)", "median JCT(s)", "95th JCT(s)"],
    );
    for r in &records {
        table.row(&r.eval.table_row());
        let name = &r.scenario.policy;
        let _ = write_csv(&format!("fig6a_cdf_{name}"), &["jct_s", "cdf"], &r.eval.cdf_rows());
        let utils: Vec<Vec<f64>> = r.eval.gpu_utils.iter().map(|&u| vec![u]).collect();
        let _ = write_csv(&format!("fig6b_util_{name}"), &["gpu_util"], &utils);
        println!(
            "{}: admissions clean={} overlapped={} max_k={}",
            r.scenario.label(),
            r.eval.clean_admissions,
            r.eval.contended_admissions,
            r.max_contention
        );
    }
    table.print();

    let by = |policy: &str| {
        &records.iter().find(|r| r.scenario.policy == policy).unwrap().eval
    };
    let (srsf1, srsf2, ada) = (by("srsf1"), by("srsf2"), by("ada"));
    println!(
        "\nAda-SRSF saves {:.1}% avg JCT vs SRSF(1)  (paper: 20.1%)",
        saving(srsf1.jct.mean, ada.jct.mean) * 100.0
    );
    println!(
        "Ada-SRSF saves {:.1}% avg JCT vs SRSF(2)  (paper: 36.7%)",
        saving(srsf2.jct.mean, ada.jct.mean) * 100.0
    );
    println!(
        "Ada-SRSF util {:.1}% vs SRSF(1) {:.1}%     (paper: 42.78% vs 30.65%)",
        ada.avg_gpu_util * 100.0,
        srsf1.avg_gpu_util * 100.0
    );
}
