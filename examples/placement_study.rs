//! Placement study (Figs 4–5, Table IV): compare RAND / FF / LS / LWF-1
//! under Ada-SRSF, then sweep κ — two [`Experiment`]s over the paper
//! scenario, executed on worker threads. Writes the CDF/histogram series
//! to `results/*.csv` and prints the summary tables.
//!
//! Run: `cargo run --release --example placement_study`

use ddl_sched::prelude::*;

fn main() {
    let threads = Experiment::default_threads();

    // --- Fig 4 / Table IV: placement algorithms under Ada-SRSF ----------
    // Placer seed 7 on the pinned seed-42 paper trace, matching the
    // fig4_placement/table4_placement benches so regenerated Fig 4 CSVs
    // agree regardless of which binary wrote them.
    let base = Scenario {
        seed: 7,
        trace: TraceSource::Generated { jobs: 160, seed: Some(42) },
        ..Scenario::paper()
    };
    let exp = Experiment {
        placers: registry::PAPER_PLACERS.iter().map(|s| s.to_string()).collect(),
        ..Experiment::single(base)
    };
    let records = exp.run(threads).unwrap();
    let mut table = Table::new(
        "Table IV — placement solutions with Ada-SRSF",
        &["method", "avg util", "avg JCT(s)", "median JCT(s)", "95th JCT(s)"],
    );
    for r in &records {
        table.row(&r.eval.table_row());
        let name = &r.scenario.placer;
        bench_csv(&format!("fig4a_cdf_{name}"), &["jct_s", "cdf"], &r.eval.cdf_rows());
        let utils: Vec<Vec<f64>> = r.eval.gpu_utils.iter().map(|&u| vec![u]).collect();
        bench_csv(&format!("fig4b_util_{name}"), &["gpu_util"], &utils);
    }
    table.print();
    let lwf = &records.iter().find(|r| r.scenario.placer == "lwf").unwrap().eval;
    println!(
        "LWF-1 avg JCT {:.1}s — paper reports LWF-1 best on every metric\n",
        lwf.jct.mean
    );

    // --- Fig 5: the κ sweep ---------------------------------------------
    let exp = Experiment {
        kappas: vec![1, 2, 4, 8, 16, 32],
        ..Experiment::single(Scenario::paper())
    };
    let records = exp.run(threads).unwrap();
    let mut table = Table::new(
        "Fig 5 — LWF-kappa sweep (with Ada-SRSF)",
        &["method", "avg util", "avg JCT(s)", "median JCT(s)", "95th JCT(s)"],
    );
    for r in &records {
        table.row(&r.eval.table_row());
        bench_csv(
            &format!("fig5a_cdf_k{}", r.scenario.kappa),
            &["jct_s", "cdf"],
            &r.eval.cdf_rows(),
        );
    }
    table.print();
    println!("paper finding: kappa = 1 gives the best results overall");
}

fn bench_csv(name: &str, header: &[&str], rows: &[Vec<f64>]) {
    match write_csv(name, header, rows) {
        Ok(path) => println!("  wrote {path}"),
        Err(e) => eprintln!("  csv write failed: {e}"),
    }
}
