//! Placement study (Figs 4–5, Table IV): compare RAND / FF / LS / LWF-1
//! under Ada-SRSF, then sweep κ. Writes the CDF/histogram series to
//! `results/*.csv` and prints the summary tables.
//!
//! Run: `cargo run --release --example placement_study`

use ddl_sched::metrics::Evaluation;
use ddl_sched::prelude::*;

fn main() {
    let jobs = trace::generate(&TraceConfig::paper_160());
    let cfg = SimConfig::paper();

    // --- Fig 4 / Table IV: placement algorithms under Ada-SRSF ----------
    let mut table = Table::new(
        "Table IV — placement solutions with Ada-SRSF",
        &["method", "avg util", "avg JCT(s)", "median JCT(s)", "95th JCT(s)"],
    );
    let mut lwf_eval = None;
    for name in ["rand", "ff", "ls", "lwf"] {
        let mut placer = placement::by_name(name, 1, 7).unwrap();
        let policy = AdaDual { model: cfg.comm };
        let res = sim::simulate(&cfg, &jobs, placer.as_mut(), &policy);
        let label = if name == "lwf" { "LWF-1" } else { name };
        let eval = Evaluation::from_sim(label, &res);
        table.row(&eval.table_row());
        let cdf = eval.cdf_rows();
        bench_csv(&format!("fig4a_cdf_{name}"), &["jct_s", "cdf"], &cdf);
        let utils: Vec<Vec<f64>> = eval.gpu_utils.iter().map(|&u| vec![u]).collect();
        bench_csv(&format!("fig4b_util_{name}"), &["gpu_util"], &utils);
        if name == "lwf" {
            lwf_eval = Some(eval);
        }
    }
    table.print();
    let lwf = lwf_eval.unwrap();
    println!(
        "LWF-1 avg JCT {:.1}s — paper reports LWF-1 best on every metric\n",
        lwf.jct.mean
    );

    // --- Fig 5: the κ sweep ---------------------------------------------
    let mut table = Table::new(
        "Fig 5 — LWF-kappa sweep (with Ada-SRSF)",
        &["kappa", "avg util", "avg JCT(s)", "median JCT(s)", "95th JCT(s)"],
    );
    for kappa in [1usize, 2, 4, 8, 16, 32] {
        let mut placer = LwfPlacer::new(kappa);
        let policy = AdaDual { model: cfg.comm };
        let res = sim::simulate(&cfg, &jobs, &mut placer, &policy);
        let eval = Evaluation::from_sim(&format!("LWF-{kappa}"), &res);
        table.row(&eval.table_row());
        bench_csv(&format!("fig5a_cdf_k{kappa}"), &["jct_s", "cdf"], &eval.cdf_rows());
    }
    table.print();
    println!("paper finding: kappa = 1 gives the best results overall");
}

fn bench_csv(name: &str, header: &[&str], rows: &[Vec<f64>]) {
    match write_csv(name, header, rows) {
        Ok(path) => println!("  wrote {path}"),
        Err(e) => eprintln!("  csv write failed: {e}"),
    }
}
