//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Four transformer-LM training jobs (L2 JAX model whose MLP/attention
//! hot-spots are L1 Pallas kernels, AOT-compiled to HLO artifacts) run
//! concurrently under the L3 rust coordinator: LWF-1 places them on the
//! modelled cluster, and every inter-node gradient all-reduce passes the
//! live AdaDUAL admission gate with Eq (5) pacing. Loss curves are real
//! (PJRT CPU execution); Python is never on this path.
//!
//! Prereq: `make artifacts`. Run: `cargo run --release --example e2e_train`
//! Env: E2E_STEPS (default 120), E2E_JOBS (default 4), E2E_WORKERS (2).

use ddl_sched::coordinator::{self, CoordinatorConfig, JobRequest, RtServer};
use ddl_sched::prelude::*;
use ddl_sched::runtime::default_artifacts_dir;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> ddl_sched::util::error::Result<()> {
    let steps = env_usize("E2E_STEPS", 120);
    let n_jobs = env_usize("E2E_JOBS", 4);
    let workers = env_usize("E2E_WORKERS", 2);

    let server = RtServer::start(default_artifacts_dir())?;
    println!(
        "model: preset={} n_params={} tokens={:?} (L1 pallas kernels inside)",
        server.meta.preset, server.meta.n_params, server.meta.tokens_shape
    );

    // 3 servers x 2 GPUs with 4 two-worker jobs: LWF-1 consolidates the
    // first three onto whole servers; the fourth must span two servers —
    // so one run exercises both the free intra-node path and the gated
    // inter-node (AdaDUAL + Eq 5 pacing) path.
    let cluster = ClusterSpec::tiny(3, 2);
    let cfg = CoordinatorConfig {
        cluster,
        time_scale: 1.0,
        ..CoordinatorConfig::default_ada(cluster)
    };
    let jobs: Vec<JobRequest> = (0..n_jobs)
        .map(|id| JobRequest { id, n_workers: workers, steps, seed: 1000 + id as u64 })
        .collect();

    let t0 = std::time::Instant::now();
    let reports = coordinator::run_jobs(&cfg, &server, &jobs)?;
    let wall = t0.elapsed().as_secs_f64();

    let mut t = Table::new(
        "e2e multi-job training (real PJRT compute, AdaDUAL-gated comm)",
        &["job", "gpus", "multi-srv", "steps", "loss[0]", "loss[last]", "jct(s)", "comm", "contended"],
    );
    for r in &reports {
        t.row(&[
            format!("{}", r.id),
            format!("{:?}", r.gpus),
            format!("{}", r.multi_server),
            format!("{}", r.losses.len()),
            format!("{:.3}", r.losses.first().copied().unwrap_or(f32::NAN)),
            format!("{:.3}", r.losses.last().copied().unwrap_or(f32::NAN)),
            format!("{:.1}", r.jct),
            format!("{}", r.comm_rounds),
            format!("{}", r.contended_rounds),
        ]);
    }
    t.print();
    println!("wall time {wall:.1}s for {n_jobs} jobs x {steps} steps");

    // Dump loss curves for EXPERIMENTS.md.
    let rows: Vec<Vec<f64>> = reports
        .iter()
        .flat_map(|r| {
            r.losses
                .iter()
                .enumerate()
                .map(|(i, &l)| vec![r.id as f64, i as f64, l as f64])
                .collect::<Vec<_>>()
        })
        .collect();
    if let Ok(path) = write_csv("e2e_loss_curves", &["job", "step", "loss"], &rows) {
        println!("wrote {path}");
    }

    // Sanity: learning must actually happen on the predictable stream.
    for r in &reports {
        let first = r.losses.first().copied().unwrap_or(f32::NAN);
        let last = r.losses.last().copied().unwrap_or(f32::NAN);
        assert!(
            last < first,
            "job {} did not learn: {first} -> {last}",
            r.id
        );
    }
    println!("all jobs reduced their loss — three-layer stack verified");
    Ok(())
}
