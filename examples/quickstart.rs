//! Quickstart: generate the paper's 160-job workload, schedule it with
//! LWF-1 placement + Ada-SRSF communication scheduling on the 64-GPU
//! cluster, and print the headline metrics.
//!
//! Run: `cargo run --release --example quickstart`

use ddl_sched::metrics::Evaluation;
use ddl_sched::prelude::*;

fn main() {
    // 1. The workload: 160 DDL jobs shaped like the Microsoft trace (§V-A).
    let trace_cfg = TraceConfig::paper_160();
    let jobs = trace::generate(&trace_cfg);
    println!(
        "workload: {} jobs over {:.0}s ({} single-GPU, {} multi-GPU)",
        jobs.len(),
        trace_cfg.horizon,
        jobs.iter().filter(|j| j.n_gpus == 1).count(),
        jobs.iter().filter(|j| j.n_gpus > 1).count(),
    );

    // 2. The cluster: 16 servers x 4 V100, 10 GbE with the Eq (5)
    //    contention model fitted on real hardware.
    let cfg = SimConfig::paper();
    println!(
        "cluster: {} servers x {} GPUs, comm a={:.2e}s b={:.2e}s/B eta={:.2e}s/B",
        cfg.cluster.n_servers, cfg.cluster.gpus_per_server, cfg.comm.a, cfg.comm.b, cfg.comm.eta
    );

    // 3. Schedule with the paper's full solution: LWF-1 + Ada-SRSF.
    let mut placer = LwfPlacer::new(1);
    let policy = AdaDual { model: cfg.comm };
    let res = sim::simulate(&cfg, &jobs, &mut placer, &policy);
    let eval = Evaluation::from_sim("LWF-1 + Ada-SRSF", &res);

    let mut t = Table::new(
        "Ada-SRSF on the paper workload",
        &["method", "avg util", "avg JCT(s)", "median JCT(s)", "95th JCT(s)"],
    );
    t.row(&eval.table_row());
    t.print();
    println!(
        "\nsimulated {} events; makespan {:.0}s; comm admissions: {} clean, {} overlapped (max {}-way)",
        res.n_events, res.makespan, res.clean_admissions, res.contended_admissions, res.max_contention
    );

    // 4. Contrast with the contention-blind baselines in one line each.
    for name in ["srsf1", "srsf2"] {
        let mut p = LwfPlacer::new(1);
        let policy = sched::by_name(name, cfg.comm).unwrap();
        let r = sim::simulate(&cfg, &jobs, &mut p, policy.as_ref());
        let e = Evaluation::from_sim(name, &r);
        println!(
            "{:>8}: avg JCT {:.1}s (Ada-SRSF saves {:.1}%)",
            name,
            e.jct.mean,
            ddl_sched::metrics::saving(e.jct.mean, eval.jct.mean) * 100.0
        );
    }
}
