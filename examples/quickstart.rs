//! Quickstart: the paper's 160-job workload scheduled with LWF-1 placement
//! + Ada-SRSF communication scheduling on the 64-GPU cluster — expressed
//! as one declarative [`Scenario`] that also serializes to a shareable
//! JSON file (docs/SCENARIOS.md).
//!
//! Run: `cargo run --release --example quickstart`

use ddl_sched::prelude::*;

fn main() {
    // 1. The whole run is one declarative spec: workload shape (§V-A),
    //    cluster (16 servers x 4 V100, 10 GbE), Eq (5) contention model,
    //    LWF-1 + Ada-SRSF, SRSF job priority, paper repricing, seed.
    let scenario = Scenario::paper();
    println!(
        "cluster: {} servers x {} GPUs, comm a={:.2e}s b={:.2e}s/B eta={:.2e}s/B",
        scenario.cluster.n_servers,
        scenario.cluster.gpus_per_server,
        scenario.comm.a,
        scenario.comm.b,
        scenario.comm.eta
    );
    let jobs = scenario.jobs().unwrap();
    println!(
        "workload: {} jobs ({} single-GPU, {} multi-GPU)",
        jobs.len(),
        jobs.iter().filter(|j| j.n_gpus == 1).count(),
        jobs.iter().filter(|j| j.n_gpus > 1).count(),
    );

    // 2. Run it. The record bundles the scenario, the Table IV/V metrics
    //    and the engine counters.
    let record = scenario.run().unwrap();
    let mut t = Table::new(
        "Ada-SRSF on the paper workload",
        &["method", "avg util", "avg JCT(s)", "median JCT(s)", "95th JCT(s)"],
    );
    t.row(&record.eval.table_row());
    t.print();
    println!(
        "\nsimulated {} events; makespan {:.0}s; comm admissions: {} clean, {} overlapped (max {}-way)",
        record.n_events,
        record.eval.makespan,
        record.eval.clean_admissions,
        record.eval.contended_admissions,
        record.max_contention
    );

    // 3. Contrast with the contention-blind baselines: same scenario, only
    //    the policy name changes.
    for name in ["srsf1", "srsf2"] {
        let r = Scenario { policy: name.to_string(), ..scenario.clone() }.run().unwrap();
        println!(
            "{:>8}: avg JCT {:.1}s (Ada-SRSF saves {:.1}%)",
            registry::policy_label(name),
            r.eval.jct.mean,
            ddl_sched::metrics::saving(r.eval.jct.mean, record.eval.jct.mean) * 100.0
        );
    }

    // 4. The scenario is a data file: share it, re-run it anywhere.
    //    (`ddl-sched simulate --scenario quickstart.json` reproduces this.)
    println!("\nscenario as a shareable JSON artifact:\n{}", scenario.to_json_text());
}
