//! The paper's §I motivation experiment: one 4-GPU cross-server DDL job
//! finishes in T seconds; four identical jobs run concurrently (each
//! spanning servers) take far longer than T because their All-Reduces
//! contend for the 10 GbE links — the effect Eq (5) models and the whole
//! paper addresses.
//!
//! The paper measured 295 s -> 675 s (2.3x) on real hardware. This demo
//! reproduces the *shape* of that blow-up in the simulator, then shows how
//! much of it each scheduling policy claws back.
//!
//! Run: `cargo run --release --example contention_demo`

use ddl_sched::metrics::Evaluation;
use ddl_sched::prelude::*;

fn vgg_job(id: usize, n_gpus: usize, iters: u64) -> JobSpec {
    JobSpec { id, arrival: 0.0, model: DnnModel::Vgg16, n_gpus, iterations: iters }
}

/// The paper's exact §I layout: job k takes GPU slot k of *every* server,
/// so every job spans all four nodes and all four NICs are shared.
struct ScatterPlacer;

impl Placer for ScatterPlacer {
    fn name(&self) -> &'static str {
        "scatter"
    }

    fn place(
        &mut self,
        job: &JobSpec,
        state: &ddl_sched::cluster::ClusterState,
    ) -> Option<Vec<usize>> {
        let slot = job.id % state.spec.gpus_per_server;
        Some(
            (0..state.spec.n_servers)
                .map(|s| s * state.spec.gpus_per_server + slot)
                .take(job.n_gpus)
                .collect(),
        )
    }
}

fn main() {
    // 4 servers x 4 GPUs. Each job takes one GPU from each server — the
    // worst-case scatter the paper's experiment used.
    let cfg = SimConfig {
        cluster: ClusterSpec::tiny(4, 4),
        comm: CommModel::paper_10gbe(),
        topology: TopologySpec::Flat,
        repricing: sim::Repricing::Dynamic,
        priority: sim::JobPriority::Srsf,
        coalescing: true,
        log_events: false,
        workers: 1,
        faults: FaultPlan::default(),
    };
    let iters = 2000;

    // --- one job alone (one GPU per server, like the paper) --------------
    let solo = sim::simulate(
        &cfg,
        &[vgg_job(0, 4, iters)],
        &mut ScatterPlacer,
        &SrsfCap { cap: 1 },
    );
    let t_solo = solo.jct[0];
    println!("1 VGG-16 job on 4 GPUs (1 per server): {t_solo:.0}s");

    // --- four concurrent jobs, scattered like the paper -----------------
    let jobs: Vec<JobSpec> = (0..4).map(|i| vgg_job(i, 4, iters)).collect();
    let mut table = Table::new(
        "4 concurrent scattered jobs",
        &["policy", "avg JCT(s)", "blow-up vs solo", "overlapped", "max k"],
    );
    for name in registry::POLICIES {
        let policy = registry::make_policy(name, cfg.comm).unwrap();
        let res = sim::simulate(&cfg, &jobs, &mut ScatterPlacer, policy.as_ref());
        let eval = Evaluation::from_sim(name, &res);
        table.row(&[
            name.to_string(),
            format!("{:.0}", eval.jct.mean),
            format!("{:.2}x", eval.jct.mean / t_solo),
            format!("{}", res.contended_admissions),
            format!("{}", res.max_contention),
        ]);
    }
    table.print();
    println!(
        "\npaper's real-hardware reference: 295s solo -> 675s with 4 concurrent jobs (2.29x)\n\
         the simulated blow-up shape should fall in the same 1.5-3x band for the\n\
         contention-accepting policies and be smallest for Ada-SRSF/SRSF(1)."
    );

    // --- Fig 1 in miniature: two jobs, same link ------------------------
    // (b) start both transfers together vs (c) serialise the smaller first.
    let cm = cfg.comm;
    let m1 = DnnModel::ResNet50.spec().model_bytes;
    let m2 = DnnModel::Vgg16.spec().model_bytes;
    let together = ddl_sched::sched::two_tasks::mean_completion(&cm, m2, m1, 0.0);
    let serial = ddl_sched::sched::two_tasks::mean_completion(&cm, m2, m1, cm.b * m2);
    println!(
        "\nFig 1 micro-case (ResNet-50 vs VGG-16 messages): overlap {:.3}s vs serial {:.3}s -> {}",
        together,
        serial,
        if together < serial { "overlap wins (AdaDUAL admits)" } else { "serial wins (AdaDUAL waits)" }
    );
}
