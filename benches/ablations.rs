//! Design-choice ablations beyond the paper's headline tables:
//!
//! 1. Job-priority rule (SRSF vs FIFO vs LAS) — the paper adopts SRSF from
//!    Tiresias "since it performs well most of time"; this quantifies that
//!    choice on our workload. Driven by the Experiment priority axis.
//! 2. Contention repricing (AtAdmission vs Dynamic) — the Eq (5)
//!    mid-flight ambiguity analysed in DESIGN.md §5b.
//! 3. All-reduce algorithm (Table I) as the per-job message cost —
//!    replacing the fitted 2-node constants with α-β-γ ring/RHD costs
//!    scaled to each job's server span.

use ddl_sched::model::{AllReduceAlgo, AlphaBetaGamma};
use ddl_sched::prelude::*;
use ddl_sched::sim::{JobPriority, Repricing};

fn main() {
    let threads = Experiment::default_threads();

    // ---- 1. priority rules (the sweep --what priority axis) ---------------
    let exp = Experiment {
        priorities: JobPriority::all().to_vec(),
        ..Experiment::single(Scenario::paper())
    };
    let records = exp.run(threads).unwrap();
    let mut t = Table::new(
        "ablation: job priority rule (LWF-1 + Ada-SRSF)",
        &["priority", "avg util", "avg JCT(s)", "median JCT(s)", "95th JCT(s)"],
    );
    let mut means = Vec::new();
    for r in &records {
        let name = match r.scenario.priority {
            JobPriority::Srsf => "SRSF (paper)",
            JobPriority::Fifo => "FIFO",
            JobPriority::Las => "LAS",
        };
        let e = &r.eval;
        t.row(&[
            name.to_string(),
            format!("{:.2}%", e.avg_gpu_util * 100.0),
            format!("{:.1}", e.jct.mean),
            format!("{:.1}", e.jct.median),
            format!("{:.1}", e.jct.p95),
        ]);
        means.push((name, e.jct.mean));
    }
    t.print();
    let srsf = means[0].1;
    let fifo = means[1].1;
    println!(
        "SRSF vs FIFO avg-JCT saving: {:.1}% — size+length-aware priority {}\n",
        (fifo - srsf) / fifo * 100.0,
        if srsf <= fifo { "confirmed" } else { "DIVERGES" }
    );

    // ---- 2. repricing modes ------------------------------------------------
    let mut t = Table::new(
        "ablation: Eq(5) repricing mode (LWF-1)",
        &["mode", "policy", "avg JCT(s)", "avg util"],
    );
    for (mode_name, repricing) in [
        ("AtAdmission (paper)", Repricing::AtAdmission),
        ("Dynamic (exact)", Repricing::Dynamic),
    ] {
        for pol in ["srsf1", "ada"] {
            let scenario = Scenario {
                policy: pol.to_string(),
                repricing,
                ..Scenario::paper()
            };
            let e = scenario.run().unwrap().eval;
            t.row(&[
                mode_name.to_string(),
                pol.to_string(),
                format!("{:.1}", e.jct.mean),
                format!("{:.2}%", e.avg_gpu_util * 100.0),
            ]);
        }
    }
    t.print();
    println!("see DESIGN.md §5b: Ada-SRSF's macro gap vs SRSF(1) depends on this choice\n");

    // ---- 3. all-reduce algorithm span-scaling -------------------------------
    // The paper fits (a, b) on 2 nodes and holds them constant; Table I
    // says the coefficients grow with the span N. Here: what each job's
    // *contention-free* communication total would be under each algorithm,
    // aggregated over the trace (comm-cost perspective only).
    let jobs = Scenario::paper().jobs().unwrap();
    let p = AlphaBetaGamma::ethernet_10g();
    let mut t = Table::new(
        "ablation: per-algorithm total contention-free comm cost of the trace",
        &["algorithm", "total comm (GPU-free s)", "vs fitted-2node"],
    );
    let fitted: f64 = jobs
        .iter()
        .filter(|j| j.n_gpus > 4)
        .map(|j| CommModel::paper_10gbe().time_free(j.message_bytes()) * j.iterations as f64)
        .sum();
    for algo in [
        AllReduceAlgo::Ring,
        AllReduceAlgo::RecursiveDoubling,
        AllReduceAlgo::RecursiveHalvingDoubling,
        AllReduceAlgo::BinaryTree,
    ] {
        let total: f64 = jobs
            .iter()
            .filter(|j| j.n_gpus > 4)
            .map(|j| {
                let span = j.n_gpus.div_ceil(4); // servers at 4 GPUs each
                algo.time(span.max(2), j.message_bytes(), p) * j.iterations as f64
            })
            .sum();
        t.row(&[
            algo.name().to_string(),
            format!("{total:.0}"),
            format!("{:.2}x", total / fitted),
        ]);
    }
    t.print();
}
