//! Fig 4 regeneration: JCT CDF (a), GPU-utilisation distribution (b) and
//! average JCT (c) for the four placement algorithms (RAND / FF / LS /
//! LWF-1) under Ada-SRSF on the 160-job paper workload, with wall-clock
//! timing of each full scenario run.
//!
//! Driven by the Scenario API: one base scenario, placer axis varied.

use ddl_sched::prelude::*;
use ddl_sched::util::bench::bench;

fn main() {
    // Placer seed 7 on the canonical seed-42 paper trace (pinned so the
    // scenario seed only feeds the RAND placer, as the original bench did).
    let base = Scenario {
        seed: 7,
        trace: TraceSource::Generated { jobs: 160, seed: Some(42) },
        ..Scenario::paper()
    };

    let mut fig4c = Table::new(
        "Fig 4(c) — average JCT per placement algorithm (Ada-SRSF)",
        &["method", "avg JCT(s)", "avg util", "sim wall (ms)"],
    );
    let mut cdf_table = Table::new(
        "Fig 4(a) — JCT CDF checkpoints P(JCT <= x)",
        &["method", "x=500s", "x=1000s", "x=2500s", "x=5000s"],
    );
    let mut util_table = Table::new(
        "Fig 4(b) — GPU utilisation histogram (10 bins over [0,1])",
        &["method", "histogram"],
    );

    let mut avg_jcts = Vec::new();
    for name in registry::PAPER_PLACERS {
        let scenario = Scenario { placer: name.to_string(), ..base.clone() };
        // Time the full scenario run (the sim_hotpath bench dives deeper).
        let timing = bench(&format!("sim/{name}"), 1, 3, || {
            std::hint::black_box(scenario.run().unwrap());
        });
        let record = scenario.run().unwrap();
        let label = registry::placer_label(name, scenario.kappa);
        let eval = &record.eval;

        fig4c.row(&[
            label.clone(),
            format!("{:.1}", eval.jct.mean),
            format!("{:.2}%", eval.avg_gpu_util * 100.0),
            format!("{:.1}", timing.mean_s * 1e3),
        ]);
        let cdf_at = |x: f64| {
            eval.jct_cdf
                .iter()
                .take_while(|&&(v, _)| v <= x)
                .last()
                .map(|&(_, p)| p)
                .unwrap_or(0.0)
        };
        cdf_table.row(&[
            label.clone(),
            format!("{:.2}", cdf_at(500.0)),
            format!("{:.2}", cdf_at(1000.0)),
            format!("{:.2}", cdf_at(2500.0)),
            format!("{:.2}", cdf_at(5000.0)),
        ]);
        util_table.row(&[label.clone(), format!("{:?}", eval.util_histogram(10))]);
        let _ = write_csv(&format!("fig4a_cdf_{name}"), &["jct_s", "cdf"], &eval.cdf_rows());
        avg_jcts.push((label, eval.jct.mean, eval.avg_gpu_util));
    }
    cdf_table.print();
    util_table.print();
    fig4c.print();

    // Shape assertions (the paper's qualitative findings).
    let get = |n: &str| avg_jcts.iter().find(|(l, _, _)| l == n).unwrap();
    let (_, jct_lwf, util_lwf) = get("LWF-1");
    let (_, jct_rand, util_rand) = get("RAND");
    let (_, jct_ff, _) = get("FF");
    let (_, jct_ls, _) = get("LS");
    println!("\nshape checks vs paper:");
    println!(
        "  LWF-1 best avg JCT: {}",
        ok(jct_lwf <= jct_ff && jct_lwf <= jct_ls && jct_lwf <= jct_rand)
    );
    println!("  RAND worst or near-worst: {}", ok(*jct_rand >= *jct_ff));
    println!(
        "  LWF-1 util gain vs RAND {:.2}x (paper 2.19x): {}",
        util_lwf / util_rand,
        ok(util_lwf / util_rand > 1.2)
    );
}

fn ok(b: bool) -> &'static str {
    if b { "OK" } else { "DIVERGES" }
}
