//! Fig 6 regeneration: JCT CDF (a) and GPU-utilisation distribution (b)
//! for the communication scheduling policies SRSF(1)/(2)/(3) vs Ada-SRSF
//! under LWF-1. Paper findings: avoiding all contention (SRSF(1)) beats
//! blindly accepting it (SRSF(2)/(3)); Ada-SRSF beats both.
//!
//! Driven by the Experiment API: one base scenario, policy axis.

use ddl_sched::prelude::*;

fn main() {
    let exp = Experiment {
        policies: registry::POLICIES.iter().map(|s| s.to_string()).collect(),
        ..Experiment::single(Scenario::paper())
    };
    let threads = Experiment::default_threads();
    let records = exp.run(threads).unwrap();

    let mut cdf_table = Table::new(
        "Fig 6(a) — JCT CDF checkpoints P(JCT <= x)",
        &["method", "x=500s", "x=1000s", "x=2500s", "x=5000s"],
    );
    let mut util_table = Table::new(
        "Fig 6(b) — GPU utilisation histogram (10 bins over [0,1])",
        &["method", "histogram", "avg util"],
    );
    let mut means = Vec::new();
    for r in &records {
        let label = registry::policy_label(&r.scenario.policy);
        let eval = &r.eval;
        let cdf_at = |x: f64| {
            eval.jct_cdf
                .iter()
                .take_while(|&&(v, _)| v <= x)
                .last()
                .map(|&(_, p)| p)
                .unwrap_or(0.0)
        };
        cdf_table.row(&[
            label.clone(),
            format!("{:.2}", cdf_at(500.0)),
            format!("{:.2}", cdf_at(1000.0)),
            format!("{:.2}", cdf_at(2500.0)),
            format!("{:.2}", cdf_at(5000.0)),
        ]);
        util_table.row(&[
            label.clone(),
            format!("{:?}", eval.util_histogram(10)),
            format!("{:.2}%", eval.avg_gpu_util * 100.0),
        ]);
        let _ = write_csv(
            &format!("fig6a_cdf_{}", r.scenario.policy),
            &["jct_s", "cdf"],
            &eval.cdf_rows(),
        );
        means.push((label, eval.jct.mean, eval.avg_gpu_util));
    }
    cdf_table.print();
    util_table.print();

    let m = |n: &str| means.iter().find(|(l, _, _)| l == n).unwrap();
    let (_, ada, ada_util) = m("Ada-SRSF");
    let (_, s1, s1_util) = m("SRSF(1)");
    let (_, s2, _) = m("SRSF(2)");
    let (_, s3, _) = m("SRSF(3)");
    println!("\nshape checks vs paper:");
    println!("  SRSF(1) beats SRSF(2) and SRSF(3): {}", ok(s1 < s2 && s1 < s3));
    println!("  Ada-SRSF beats SRSF(1): {}", ok(ada < s1));
    println!("  Ada-SRSF util > SRSF(1) util: {}", ok(ada_util > s1_util));
}

fn ok(b: bool) -> &'static str {
    if b { "OK" } else { "DIVERGES" }
}
