//! L3 performance benchmark: simulator throughput (events/second) on the
//! paper workload and scaled variants (flat and two-tier fabrics), plus
//! micro-benchmarks of the hot helpers (placement, admission, two-task
//! oracle). This is the §Perf harness for docs/EXPERIMENTS.md — run
//! before/after each optimisation (CI smoke-runs it in release mode).

use ddl_sched::prelude::*;
use ddl_sched::util::bench::bench;

fn main() {
    let cfg = SimConfig::paper();

    let mut t = Table::new(
        "L3 hot path — full simulations",
        &["workload", "events", "wall (ms)", "events/s (M)"],
    );
    for (label, n_jobs) in [("40 jobs", 40), ("160 jobs (paper)", 160), ("320 jobs", 320)] {
        let jobs = if n_jobs == 160 {
            trace::generate(&TraceConfig::paper_160())
        } else {
            trace::generate(&TraceConfig::scaled(n_jobs, 11))
        };
        let mut events = 0u64;
        let timing = bench(label, 1, 3, || {
            let mut placer = LwfPlacer::new(1);
            let policy = AdaDual { model: cfg.comm };
            let res = sim::simulate(&cfg, &jobs, &mut placer, &policy);
            events = res.n_events;
        });
        t.row(&[
            label.to_string(),
            format!("{events}"),
            format!("{:.1}", timing.mean_s * 1e3),
            format!("{:.2}", events as f64 / timing.mean_s / 1e6),
        ]);
    }
    // The link-indexed fabric path: same paper workload on a 4:1
    // oversubscribed two-tier fabric with rack-locality placement.
    {
        let mut cfg2 = SimConfig::paper();
        cfg2.topology = TopologySpec::TwoTier { rack_size: 4, oversubscription: 4.0 };
        let jobs = trace::generate(&TraceConfig::paper_160());
        let mut events = 0u64;
        let label = "160 jobs (2-tier 4:1)";
        let timing = bench(label, 1, 3, || {
            let mut placer = RackLwfPlacer::new(1, 4);
            let policy = AdaDual { model: cfg2.comm };
            let res = sim::simulate(&cfg2, &jobs, &mut placer, &policy);
            events = res.n_events;
        });
        t.row(&[
            label.to_string(),
            format!("{events}"),
            format!("{:.1}", timing.mean_s * 1e3),
            format!("{:.2}", events as f64 / timing.mean_s / 1e6),
        ]);
    }
    t.print();

    // ---- micro benches -----------------------------------------------------
    let jobs = trace::generate(&TraceConfig::paper_160());
    let mut t = Table::new("micro benches", &["op", "mean"]);

    let state = ddl_sched::cluster::ClusterState::new(cfg.cluster);
    let job = &jobs[10];
    let timing = bench("LWF-1 placement decision", 10, 1000, || {
        let mut p = LwfPlacer::new(1);
        std::hint::black_box(p.place(job, &state));
    });
    t.row(&[timing.name.clone(), format!("{:.2} us", timing.mean_s * 1e6)]);

    let cm = cfg.comm;
    let timing = bench("two-task oracle (simulate_pair)", 10, 1000, || {
        std::hint::black_box(ddl_sched::sched::two_tasks::simulate_pair(
            &cm, 1.0e8, 5.3e8, 0.02,
        ));
    });
    t.row(&[timing.name.clone(), format!("{:.2} us", timing.mean_s * 1e6)]);

    let per_link: Vec<Vec<(usize, f64)>> = vec![vec![(1, 2.0e8)]; 16];
    let policy = AdaDual { model: cm };
    let timing = bench("AdaDUAL admission decision", 10, 10000, || {
        use ddl_sched::sched::{CommPolicy, NetView};
        std::hint::black_box(policy.admit(
            1.0e8,
            &[0, 3, 7, 12],
            &NetView { per_link: &per_link },
        ));
    });
    t.row(&[timing.name.clone(), format!("{:.3} us", timing.mean_s * 1e6)]);

    let timing = bench("trace generation (160 jobs)", 2, 100, || {
        std::hint::black_box(trace::generate(&TraceConfig::paper_160()));
    });
    t.row(&[timing.name.clone(), format!("{:.2} us", timing.mean_s * 1e6)]);
    t.print();
}
