//! L3 performance benchmark: simulator throughput on the paper workload
//! and scaled variants (flat and two-tier fabrics), with steady-state
//! fast-forwarding measured against the event-exact engine (`coalescing`
//! off → on: heap events before/after and the resulting events/s), plus
//! micro-benchmarks of the hot helpers. This is the §Perf harness for
//! docs/EXPERIMENTS.md — run before/after each optimisation (CI
//! smoke-runs it in release mode and uploads the machine-readable row
//! dump `results/BENCH_sim_hotpath.json` as an artifact so the perf
//! trajectory is tracked across PRs).

use ddl_sched::prelude::*;
use ddl_sched::util::bench::{bench, BenchReport};

/// Run one workload twice — event-exact, then coalescing — and report
/// the event-count reduction plus the coalesced run's throughput.
fn run_row(
    t: &mut Table,
    report: &mut BenchReport,
    label: &str,
    base: &SimConfig,
    jobs: &[JobSpec],
    rack_size: Option<usize>,
) {
    let mut events = [0u64; 2];
    let mut wall = [0f64; 2];
    for (i, coalescing) in [false, true].into_iter().enumerate() {
        let cfg = SimConfig { coalescing, ..base.clone() };
        let mode = if coalescing { "coalescing=on" } else { "coalescing=off" };
        let timing = bench(&format!("{label} {mode}"), 1, 3, || {
            let res = match rack_size {
                Some(r) => {
                    let mut placer = RackLwfPlacer::new(1, r);
                    sim::simulate(&cfg, jobs, &mut placer, &AdaDual { model: cfg.comm })
                }
                None => {
                    let mut placer = LwfPlacer::new(1);
                    sim::simulate(&cfg, jobs, &mut placer, &AdaDual { model: cfg.comm })
                }
            };
            events[i] = res.n_events;
        });
        wall[i] = timing.mean_s;
        report.record(&format!("{label} {mode}"), events[i], timing.mean_s);
    }
    t.row(&[
        label.to_string(),
        format!("{}", events[0]),
        format!("{}", events[1]),
        format!("{:.1}x", events[0] as f64 / events[1].max(1) as f64),
        format!("{:.1}", wall[1] * 1e3),
        format!("{:.2}", events[1] as f64 / wall[1] / 1e6),
    ]);
}

fn main() {
    let cfg = SimConfig::paper();
    let mut report = BenchReport::new("sim_hotpath");

    let mut t = Table::new(
        "L3 hot path — full simulations, event-exact vs fast-forwarded",
        &["workload", "events off", "events on", "reduction", "wall on (ms)", "events/s (M)"],
    );
    for (label, n_jobs) in [("40 jobs", 40), ("160 jobs (paper)", 160), ("320 jobs", 320)] {
        let jobs = if n_jobs == 160 {
            trace::generate(&TraceConfig::paper_160())
        } else {
            trace::generate(&TraceConfig::scaled(n_jobs, 11))
        };
        run_row(&mut t, &mut report, label, &cfg, &jobs, None);
    }
    // Saturation workload: the paper's job mix compressed into a quarter
    // of its arrival window (~4x the paper's arrival density), so the
    // placement queue stays deep and finish-triggered passes dominate —
    // the regime the release-generation/capacity placement gate and the
    // lazy admission view exist for.
    {
        let mut tc = TraceConfig::scaled(320, 17);
        tc.horizon = 600.0;
        let jobs = trace::generate(&tc);
        run_row(&mut t, &mut report, "320 jobs saturated (4x density)", &cfg, &jobs, None);
    }
    // The link-indexed fabric path: same paper workload on a 4:1
    // oversubscribed two-tier fabric with rack-locality placement.
    {
        let mut cfg2 = SimConfig::paper();
        cfg2.topology = TopologySpec::TwoTier { rack_size: 4, oversubscription: 4.0 };
        let jobs = trace::generate(&TraceConfig::paper_160());
        run_row(&mut t, &mut report, "160 jobs (2-tier 4:1)", &cfg2, &jobs, Some(4));
    }
    t.print();

    // ---- parallel advancement: worker-count sweep --------------------------
    // Same saturated workload, coalescing on, reconcile walks fanned out
    // over 1/2/4 workers. Results are bit-identical by construction
    // (property-tested in sim::tests); the wall-clock delta is the value
    // of the fan-out, and the allocs/event column (dhat-heap builds
    // only) is the §Perf steady-state allocation number.
    {
        let mut tc = TraceConfig::scaled(320, 17);
        tc.horizon = 600.0;
        let jobs = trace::generate(&tc);
        let mut t = Table::new(
            "parallel advancement — 320 jobs saturated, coalescing on",
            &["workers", "heap events", "wall (ms)", "events/s (M)", "allocs/event"],
        );
        for workers in [1usize, 2, 4] {
            let wcfg = SimConfig { workers, ..cfg.clone() };
            let label = format!("320 jobs saturated workers={workers}");
            let mut events = 0u64;
            let a0 = ddl_sched::util::heap::snapshot();
            let timing = bench(&label, 1, 3, || {
                let mut placer = LwfPlacer::new(1);
                let res = sim::simulate(&wcfg, &jobs, &mut placer, &AdaDual { model: wcfg.comm });
                events = res.n_events;
            });
            // 1 warmup + 3 timed runs share the snapshot window.
            let allocs = ddl_sched::util::heap::snapshot().since(&a0).allocs / 4;
            report.record_with_allocs(&label, events, timing.mean_s, allocs, events);
            t.row(&[
                format!("{workers}"),
                format!("{events}"),
                format!("{:.1}", timing.mean_s * 1e3),
                format!("{:.2}", events as f64 / timing.mean_s / 1e6),
                if ddl_sched::util::heap::ENABLED {
                    format!("{:.3}", allocs as f64 / events.max(1) as f64)
                } else {
                    "n/a".to_string()
                },
            ]);
        }
        t.print();
    }

    // ---- observer sinks: events/s with sinks off vs JSONL on ---------------
    // The output-layer cost question: what does streaming every typed
    // event as a JSON line cost versus the metrics-only facade? The sink
    // writes to io::sink() so serialization is isolated from disk.
    {
        let jobs = trace::generate(&TraceConfig::paper_160());
        let mut t = Table::new(
            "observer sinks — 160 jobs (paper), coalescing on",
            &["mode", "heap events", "stream events", "wall (ms)", "events/s (M)"],
        );
        let mut heap_events = 0u64;
        let timing = bench("160 jobs sinks-off", 1, 3, || {
            let mut placer = LwfPlacer::new(1);
            let res = sim::simulate(&cfg, &jobs, &mut placer, &AdaDual { model: cfg.comm });
            heap_events = res.n_events;
        });
        report.record("160 jobs (paper) sinks-off", heap_events, timing.mean_s);
        t.row(&[
            "sinks off".to_string(),
            format!("{heap_events}"),
            "-".to_string(),
            format!("{:.1}", timing.mean_s * 1e3),
            format!("{:.2}", heap_events as f64 / timing.mean_s / 1e6),
        ]);
        let mut stream_events = 0u64;
        let timing = bench("160 jobs jsonl-on", 1, 3, || {
            let mut placer = LwfPlacer::new(1);
            let mut metrics = MetricsObserver::new();
            let mut sink = JsonlSink::new(std::io::sink());
            {
                let mut obs: [&mut dyn SimObserver; 2] = [&mut metrics, &mut sink];
                sim::simulate_observed(
                    &cfg,
                    &jobs,
                    &mut placer,
                    &AdaDual { model: cfg.comm },
                    &mut obs,
                );
            }
            heap_events = metrics.n_events();
            stream_events = sink.written();
        });
        report.record("160 jobs (paper) jsonl-on", heap_events, timing.mean_s);
        t.row(&[
            "jsonl on".to_string(),
            format!("{heap_events}"),
            format!("{stream_events}"),
            format!("{:.1}", timing.mean_s * 1e3),
            format!("{:.2}", heap_events as f64 / timing.mean_s / 1e6),
        ]);
        t.print();
    }

    // ---- micro benches -----------------------------------------------------
    let jobs = trace::generate(&TraceConfig::paper_160());
    let mut t = Table::new("micro benches", &["op", "mean"]);

    let state = ddl_sched::cluster::ClusterState::new(cfg.cluster);
    let job = &jobs[10];
    let timing = bench("LWF-1 placement decision", 10, 1000, || {
        let mut p = LwfPlacer::new(1);
        std::hint::black_box(p.place(job, &state));
    });
    t.row(&[timing.name.clone(), format!("{:.2} us", timing.mean_s * 1e6)]);

    let cm = cfg.comm;
    let timing = bench("two-task oracle (simulate_pair)", 10, 1000, || {
        std::hint::black_box(ddl_sched::sched::two_tasks::simulate_pair(
            &cm, 1.0e8, 5.3e8, 0.02,
        ));
    });
    t.row(&[timing.name.clone(), format!("{:.2} us", timing.mean_s * 1e6)]);

    let per_link: Vec<Vec<(usize, f64)>> = vec![vec![(1, 2.0e8)]; 16];
    let net = ddl_sched::sched::MaterializedNet::from_tuples(&per_link);
    let policy = AdaDual { model: cm };
    let timing = bench("AdaDUAL admission decision", 10, 10000, || {
        use ddl_sched::sched::CommPolicy;
        std::hint::black_box(net.with_view(|view| policy.admit(1.0e8, &[0, 3, 7, 12], view)));
    });
    t.row(&[timing.name.clone(), format!("{:.3} us", timing.mean_s * 1e6)]);

    let timing = bench("trace generation (160 jobs)", 2, 100, || {
        std::hint::black_box(trace::generate(&TraceConfig::paper_160()));
    });
    t.row(&[timing.name.clone(), format!("{:.2} us", timing.mean_s * 1e6)]);
    t.print();

    // Trajectory visibility (non-fatal): events/s against whatever
    // baseline is committed, printed into the CI log before the file is
    // refreshed below.
    print!("{}", report.delta_vs_committed());
    match report.write() {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write bench report: {e}"),
    }
}
