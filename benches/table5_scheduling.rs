//! Table V regeneration: communication scheduling solutions with LWF-1 —
//! average GPU utilisation, average/median/95th-percentile JCT — plus the
//! paper's headline derived numbers (Ada-SRSF vs SRSF(1)/(2)).
//!
//! Driven by the Experiment API: policy axis on the paper scenario.

use ddl_sched::metrics::{improvement, saving};
use ddl_sched::prelude::*;

fn main() {
    let exp = Experiment {
        policies: registry::POLICIES.iter().map(|s| s.to_string()).collect(),
        ..Experiment::single(Scenario::paper())
    };
    let threads = Experiment::default_threads();
    let records = exp.run(threads).unwrap();

    let mut table = Table::new(
        "Table V — communication scheduling with LWF-1",
        &["method", "avg util", "avg JCT(s)", "median JCT(s)", "95th JCT(s)"],
    );
    for r in &records {
        table.row(&r.eval.table_row());
    }
    table.print();

    let by = |policy: &str| {
        &records.iter().find(|r| r.scenario.policy == policy).unwrap().eval
    };
    let (s1, s2, ada) = (by("srsf1"), by("srsf2"), by("ada"));
    let mut t = Table::new(
        "derived comparisons (paper values in parentheses)",
        &["comparison", "ours", "paper"],
    );
    t.row(&[
        "Ada-SRSF JCT saving vs SRSF(1)".into(),
        format!("{:.1}%", saving(s1.jct.mean, ada.jct.mean) * 100.0),
        "20.1%".into(),
    ]);
    t.row(&[
        "Ada-SRSF JCT saving vs SRSF(2)".into(),
        format!("{:.1}%", saving(s2.jct.mean, ada.jct.mean) * 100.0),
        "36.7%".into(),
    ]);
    t.row(&[
        "Ada-SRSF util gain vs SRSF(1)".into(),
        format!("{:.1}%", (improvement(s1.avg_gpu_util, ada.avg_gpu_util) - 1.0) * 100.0),
        "39.6%".into(),
    ]);
    t.row(&[
        "Ada-SRSF p95 JCT vs SRSF(1)".into(),
        format!("{:.2}x", s1.jct.p95 / ada.jct.p95),
        "1.56x".into(),
    ]);
    t.print();
}
