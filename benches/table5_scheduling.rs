//! Table V regeneration: communication scheduling solutions with LWF-1 —
//! average GPU utilisation, average/median/95th-percentile JCT — plus the
//! paper's headline derived numbers (Ada-SRSF vs SRSF(1)/(2)).

use ddl_sched::metrics::{improvement, saving, Evaluation};
use ddl_sched::prelude::*;

fn main() {
    let jobs = trace::generate(&TraceConfig::paper_160());
    let cfg = SimConfig::paper();

    let mut table = Table::new(
        "Table V — communication scheduling with LWF-1",
        &["method", "avg util", "avg JCT(s)", "median JCT(s)", "95th JCT(s)"],
    );
    let mut evals = Vec::new();
    for name in ["srsf1", "srsf2", "srsf3", "ada"] {
        let mut placer = LwfPlacer::new(1);
        let policy = sched::by_name(name, cfg.comm).unwrap();
        let res = sim::simulate(&cfg, &jobs, &mut placer, policy.as_ref());
        let label = match name {
            "ada" => "Ada-SRSF".to_string(),
            other => format!("SRSF({})", &other[4..]),
        };
        let eval = Evaluation::from_sim(&label, &res);
        table.row(&eval.table_row());
        evals.push(eval);
    }
    table.print();

    let by = |n: &str| evals.iter().find(|e| e.method == n).unwrap();
    let (s1, s2, ada) = (by("SRSF(1)"), by("SRSF(2)"), by("Ada-SRSF"));
    let mut t = Table::new(
        "derived comparisons (paper values in parentheses)",
        &["comparison", "ours", "paper"],
    );
    t.row(&[
        "Ada-SRSF JCT saving vs SRSF(1)".into(),
        format!("{:.1}%", saving(s1.jct.mean, ada.jct.mean) * 100.0),
        "20.1%".into(),
    ]);
    t.row(&[
        "Ada-SRSF JCT saving vs SRSF(2)".into(),
        format!("{:.1}%", saving(s2.jct.mean, ada.jct.mean) * 100.0),
        "36.7%".into(),
    ]);
    t.row(&[
        "Ada-SRSF util gain vs SRSF(1)".into(),
        format!("{:.1}%", (improvement(s1.avg_gpu_util, ada.avg_gpu_util) - 1.0) * 100.0),
        "39.6%".into(),
    ]);
    t.row(&[
        "Ada-SRSF p95 JCT vs SRSF(1)".into(),
        format!("{:.2}x", s1.jct.p95 / ada.jct.p95),
        "1.56x".into(),
    ]);
    t.print();
}
