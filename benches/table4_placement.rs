//! Table IV regeneration: placement solutions with Ada-SRSF — average GPU
//! utilisation, average/median/95th-percentile JCT — plus the paper's
//! derived improvement factors (LWF-1 vs RAND/FF/LS).

use ddl_sched::metrics::{improvement, saving, Evaluation};
use ddl_sched::prelude::*;

fn main() {
    let jobs = trace::generate(&TraceConfig::paper_160());
    let cfg = SimConfig::paper();

    let mut table = Table::new(
        "Table IV — placement solutions with Ada-SRSF",
        &["method", "avg util", "avg JCT(s)", "median JCT(s)", "95th JCT(s)"],
    );
    let mut evals = Vec::new();
    for name in ["rand", "ff", "ls", "lwf"] {
        let mut placer = placement::by_name(name, 1, 7).unwrap();
        let policy = AdaDual { model: cfg.comm };
        let res = sim::simulate(&cfg, &jobs, placer.as_mut(), &policy);
        let label = match name {
            "rand" => "RAND",
            "ff" => "FF",
            "ls" => "LS",
            _ => "LWF-1",
        };
        let eval = Evaluation::from_sim(label, &res);
        table.row(&eval.table_row());
        evals.push(eval);
    }
    table.print();

    let by = |n: &str| evals.iter().find(|e| e.method == n).unwrap();
    let (rand, ff, ls, lwf) = (by("RAND"), by("FF"), by("LS"), by("LWF-1"));
    let mut t = Table::new(
        "derived comparisons (paper values in parentheses)",
        &["comparison", "ours", "paper"],
    );
    t.row(&[
        "LWF-1 util vs RAND".into(),
        format!("{:.2}x", improvement(rand.avg_gpu_util, lwf.avg_gpu_util)),
        "2.19x".into(),
    ]);
    t.row(&[
        "LWF-1 util vs FF".into(),
        format!("{:.2}x", improvement(ff.avg_gpu_util, lwf.avg_gpu_util)),
        "1.59x".into(),
    ]);
    t.row(&[
        "LWF-1 util vs LS".into(),
        format!("{:.2}x", improvement(ls.avg_gpu_util, lwf.avg_gpu_util)),
        "1.70x".into(),
    ]);
    t.row(&[
        "JCT saving vs RAND".into(),
        format!("{:.1}%", saving(rand.jct.mean, lwf.jct.mean) * 100.0),
        "61.9%".into(),
    ]);
    t.row(&[
        "JCT saving vs FF".into(),
        format!("{:.1}%", saving(ff.jct.mean, lwf.jct.mean) * 100.0),
        "42.8%".into(),
    ]);
    t.row(&[
        "JCT saving vs LS".into(),
        format!("{:.1}%", saving(ls.jct.mean, lwf.jct.mean) * 100.0),
        "51.9%".into(),
    ]);
    t.print();
}
