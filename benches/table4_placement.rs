//! Table IV regeneration: placement solutions with Ada-SRSF — average GPU
//! utilisation, average/median/95th-percentile JCT — plus the paper's
//! derived improvement factors (LWF-1 vs RAND/FF/LS).
//!
//! Driven by the Experiment API: placer axis on the paper scenario.

use ddl_sched::metrics::{improvement, saving};
use ddl_sched::prelude::*;

fn main() {
    let base = Scenario {
        seed: 7,
        trace: TraceSource::Generated { jobs: 160, seed: Some(42) },
        ..Scenario::paper()
    };
    let exp = Experiment {
        placers: registry::PAPER_PLACERS.iter().map(|s| s.to_string()).collect(),
        ..Experiment::single(base)
    };
    let threads = Experiment::default_threads();
    let records = exp.run(threads).unwrap();

    let mut table = Table::new(
        "Table IV — placement solutions with Ada-SRSF",
        &["method", "avg util", "avg JCT(s)", "median JCT(s)", "95th JCT(s)"],
    );
    for r in &records {
        table.row(&r.eval.table_row());
    }
    table.print();

    let by = |placer: &str| {
        &records.iter().find(|r| r.scenario.placer == placer).unwrap().eval
    };
    let (rand, ff, ls, lwf) = (by("rand"), by("ff"), by("ls"), by("lwf"));
    let mut t = Table::new(
        "derived comparisons (paper values in parentheses)",
        &["comparison", "ours", "paper"],
    );
    t.row(&[
        "LWF-1 util vs RAND".into(),
        format!("{:.2}x", improvement(rand.avg_gpu_util, lwf.avg_gpu_util)),
        "2.19x".into(),
    ]);
    t.row(&[
        "LWF-1 util vs FF".into(),
        format!("{:.2}x", improvement(ff.avg_gpu_util, lwf.avg_gpu_util)),
        "1.59x".into(),
    ]);
    t.row(&[
        "LWF-1 util vs LS".into(),
        format!("{:.2}x", improvement(ls.avg_gpu_util, lwf.avg_gpu_util)),
        "1.70x".into(),
    ]);
    t.row(&[
        "JCT saving vs RAND".into(),
        format!("{:.1}%", saving(rand.jct.mean, lwf.jct.mean) * 100.0),
        "61.9%".into(),
    ]);
    t.row(&[
        "JCT saving vs FF".into(),
        format!("{:.1}%", saving(ff.jct.mean, lwf.jct.mean) * 100.0),
        "42.8%".into(),
    ]);
    t.row(&[
        "JCT saving vs LS".into(),
        format!("{:.1}%", saving(ls.jct.mean, lwf.jct.mean) * 100.0),
        "51.9%".into(),
    ]);
    t.print();
}
