//! Per-component microbench suite: saturate each hot subsystem *in
//! isolation* — event-heap churn, admission-view reads over the flat
//! per-link slab, priority-queue insertion (including the gap-buffer
//! counter-proposal the JobQueue docs reference), the free-GPU capacity
//! index, and per-link membership churn — plus one end-to-end
//! steady-state engine row that reports allocations/event when built
//! with `--features dhat-heap`, and the gym-style env decision-stepping
//! rows (`env_step`), whose random-agent row carries the SimEnv
//! throughput floor.
//!
//! Attribution convention (docs/EXPERIMENTS.md §Perf): the in-repo heap
//! profiler counts process-wide allocations, not call sites, so each
//! workload here exercises exactly one subsystem — a nonzero allocs/op
//! localizes to that subsystem by construction. Rows land in
//! `results/BENCH_micro.json` under the committed-baseline delta
//! convention (results/README.md); deltas are informational, never
//! build-failing.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use ddl_sched::net::LinkLists;
use ddl_sched::prelude::*;
use ddl_sched::sched::JobQueue;
use ddl_sched::util::bench::{bench, BenchReport};
use ddl_sched::util::heap as heap_prof;
use ddl_sched::util::rng::Pcg;

mod env_step;

/// Mirror of the engine's heap entry — (t, seq)-ordered min-heap via
/// reversed comparison — so heap churn is measured on the real ordering
/// logic without exposing engine internals.
struct Timed {
    t: f64,
    seq: u64,
}

impl PartialEq for Timed {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Timed {}
impl Ord for Timed {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .t
            .total_cmp(&self.t)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Timed {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// The gap-buffer alternative the JobQueue docs argue against: one
/// contiguous vec with a movable gap at the last insertion point, so
/// runs of nearby insertions avoid long memmoves. Implemented here (not
/// in the library) purely to bench the claim — the engine's
/// take_all/restore pattern closes the gap every placement pass, which
/// is exactly what the "JobQueue insert" vs "gap-buffer insert" rows
/// quantify.
struct GapBuffer {
    /// Entries below the gap (ascending order).
    lo: Vec<(f64, usize)>,
    /// Entries above the gap, *reversed* (top of `hi` is the smallest
    /// entry above the gap), so moving the gap is push/pop between vecs.
    hi: Vec<(f64, usize)>,
}

impl GapBuffer {
    fn new() -> GapBuffer {
        GapBuffer { lo: Vec::new(), hi: Vec::new() }
    }

    fn insert(&mut self, key: f64, job: usize) {
        let probe = (key, job);
        // Move the gap left/right until it sits at the insertion point.
        while self
            .lo
            .last()
            .is_some_and(|&(k, j)| (k, j) > probe)
        {
            self.hi.push(self.lo.pop().unwrap());
        }
        while self
            .hi
            .last()
            .is_some_and(|&(k, j)| (k, j) < probe)
        {
            self.lo.push(self.hi.pop().unwrap());
        }
        self.lo.push(probe);
    }

    /// The engine's per-placement-pass drain: one ordered walk consumes
    /// the whole queue — which forces the gap closed no matter where the
    /// insertions left it. This is the structural reason the gap buffer
    /// cannot win in the engine (see `sched::JobQueue` docs).
    fn take_all(&mut self) -> Vec<(f64, usize)> {
        let mut out = std::mem::take(&mut self.lo);
        while let Some(e) = self.hi.pop() {
            out.push(e);
        }
        out
    }

    fn restore(&mut self, entries: Vec<(f64, usize)>) {
        self.lo = entries;
    }
}

fn push_row(
    t: &mut Table,
    report: &mut BenchReport,
    label: &str,
    ops: u64,
    wall_s: f64,
    allocs: u64,
) {
    report.record_with_allocs(label, ops, wall_s, allocs, ops);
    t.row(&[
        label.to_string(),
        format!("{ops}"),
        format!("{:.2}", wall_s * 1e3),
        format!("{:.2}", ops as f64 / wall_s / 1e6),
        if heap_prof::ENABLED {
            format!("{:.3}", allocs as f64 / ops as f64)
        } else {
            "n/a".to_string()
        },
    ]);
}

fn main() {
    let mut report = BenchReport::new("micro");
    let mut t = Table::new(
        "micro — per-subsystem saturation",
        &["workload", "ops", "wall (ms)", "Mops/s", "allocs/op"],
    );

    // ---- event-heap churn --------------------------------------------------
    // Steady-state shape: a warm heap holding ~256 in-flight events, each
    // op popping the minimum and pushing a successor slightly later —
    // the engine's push/pop pattern with zero allocator traffic expected
    // once the heap's backing vec is warm.
    {
        const LIVE: usize = 256;
        const OPS: u64 = 1_000_000;
        let mut heap = BinaryHeap::with_capacity(LIVE + 1);
        let mut rng = Pcg::seed(7);
        let mut seq = 0u64;
        for _ in 0..LIVE {
            seq += 1;
            heap.push(Timed { t: rng.range_f64(0.0, 1.0), seq });
        }
        let a0 = heap_prof::snapshot();
        let timing = bench("heap churn (pop+push, 256 live)", 1, 3, || {
            for _ in 0..OPS {
                let top = heap.pop().unwrap();
                seq += 1;
                heap.push(Timed { t: top.t + rng.range_f64(0.0, 0.01), seq });
            }
        });
        let allocs = heap_prof::snapshot().since(&a0).allocs / 4; // 1 warmup + 3 timed
        push_row(
            &mut t,
            &mut report,
            "heap churn (pop+push, 256 live)",
            OPS,
            timing.mean_s,
            allocs,
        );
    }

    // ---- admission view over the flat per-link slab ------------------------
    // The policy-facing read path: `max_occupancy` probes (the whole
    // cost of an SRSF(n) decision) over LinkLists through NetView, at
    // paper-like contention (0–3 tasks per link).
    {
        const OPS: u64 = 1_000_000;
        let mut links = LinkLists::new(16);
        let mut rng = Pcg::seed(11);
        for l in 0..16 {
            for task in 0..rng.range_usize(0, 3) {
                links.push(l, l * 8 + task);
            }
        }
        let remaining = |_task: usize| 1.0e8;
        let probe: Vec<usize> = vec![0, 3, 7, 12];
        let a0 = heap_prof::snapshot();
        let timing = bench("NetView admission read (LinkLists, 16 links)", 1, 3, || {
            let view = ddl_sched::sched::NetView::new(&links, &remaining);
            let mut acc = 0usize;
            for _ in 0..OPS {
                acc = acc.wrapping_add(view.max_occupancy(&probe));
            }
            std::hint::black_box(acc);
        });
        let allocs = heap_prof::snapshot().since(&a0).allocs / 4;
        push_row(
            &mut t,
            &mut report,
            "NetView admission read (LinkLists, 16 links)",
            OPS,
            timing.mean_s,
            allocs,
        );
    }

    // ---- JobQueue insert vs gap buffer, at three depths --------------------
    // Each op inserts one random-key job into a warm queue and every
    // 8th op runs the engine's take_all/restore placement-pass drain.
    // The drain is what makes the memmove layout win: the gap buffer
    // pays the same O(n) walk to close its gap, then pays its gap moves
    // on top (see sched::JobQueue docs for the argument these rows prove).
    for depth in [16usize, 256, 4096] {
        const OPS: u64 = 100_000;
        let keys = |rng: &mut Pcg| rng.range_f64(0.0, 1.0e6);

        let mut q = JobQueue::new();
        let mut rng = Pcg::seed(13);
        for j in 0..depth {
            q.insert(keys(&mut rng), j);
        }
        let label = format!("JobQueue insert (depth {depth})");
        let a0 = heap_prof::snapshot();
        let timing = bench(&label, 1, 3, || {
            for op in 0..OPS {
                q.insert(keys(&mut rng), (op as usize) % depth);
                if op % 8 == 7 {
                    let mut entries = q.take_all();
                    entries.truncate(depth); // keep the depth bounded
                    q.restore(entries);
                }
            }
        });
        let allocs = heap_prof::snapshot().since(&a0).allocs / 4;
        push_row(&mut t, &mut report, &label, OPS, timing.mean_s, allocs);

        let mut gq = GapBuffer::new();
        let mut rng = Pcg::seed(13);
        for j in 0..depth {
            gq.insert(keys(&mut rng), j);
        }
        let label = format!("gap-buffer insert (depth {depth})");
        let a0 = heap_prof::snapshot();
        let timing = bench(&label, 1, 3, || {
            for op in 0..OPS {
                gq.insert(keys(&mut rng), (op as usize) % depth);
                if op % 8 == 7 {
                    let mut entries = gq.take_all();
                    entries.truncate(depth);
                    gq.restore(entries);
                }
            }
        });
        let allocs = heap_prof::snapshot().since(&a0).allocs / 4;
        push_row(&mut t, &mut report, &label, OPS, timing.mean_s, allocs);
    }

    // ---- free-GPU capacity index -------------------------------------------
    // The placement gate's O(Δ) maintenance: feasibility probes mixed
    // with allocate/release-style threshold-crossing records.
    {
        const OPS: u64 = 1_000_000;
        let spec = ClusterSpec::paper_64gpu();
        let state = ClusterState::new(spec);
        let thresholds: Vec<f64> =
            (1..=8).map(|i| i as f64 * 2.0 * 1024.0 * 1024.0 * 1024.0).collect();
        let mut idx = ddl_sched::cluster::FreeGpuIndex::new(thresholds.clone(), &state);
        let mut rng = Pcg::seed(17);
        let a0 = heap_prof::snapshot();
        let timing = bench("FreeGpuIndex probe+record", 1, 3, || {
            let mut acc = 0usize;
            for _ in 0..OPS {
                let m = thresholds[rng.range_usize(0, thresholds.len() - 1)];
                acc = acc.wrapping_add(idx.feasible(m));
                // A release/allocate pair crossing one threshold.
                idx.record(m - 1.0, m + 1.0);
                idx.record(m + 1.0, m - 1.0);
            }
            std::hint::black_box(acc);
        });
        let allocs = heap_prof::snapshot().since(&a0).allocs / 4;
        push_row(&mut t, &mut report, "FreeGpuIndex probe+record", OPS, timing.mean_s, allocs);
    }

    // ---- per-link membership churn: LinkLists vs nested vecs ---------------
    // The admit/complete write path: push a task onto 4 links, then
    // swap-remove it, forever. The flat slab should show zero allocs/op;
    // the nested layout allocates only on first growth but still pays
    // the pointer chase.
    {
        const OPS: u64 = 500_000;
        let probe: [usize; 4] = [0, 3, 7, 12];

        let mut slab = LinkLists::new(16);
        let a0 = heap_prof::snapshot();
        let timing = bench("per-link churn (LinkLists, 4 links/op)", 1, 3, || {
            for op in 0..OPS {
                let id = op as usize;
                for &l in &probe {
                    slab.push(l, id);
                }
                for &l in &probe {
                    let last = slab.len(l) - 1;
                    slab.swap_remove(l, last);
                }
            }
        });
        let allocs = heap_prof::snapshot().since(&a0).allocs / 4;
        push_row(
            &mut t,
            &mut report,
            "per-link churn (LinkLists, 4 links/op)",
            OPS,
            timing.mean_s,
            allocs,
        );

        let mut nested: Vec<Vec<usize>> = vec![Vec::new(); 16];
        let a0 = heap_prof::snapshot();
        let timing = bench("per-link churn (Vec<Vec>, 4 links/op)", 1, 3, || {
            for op in 0..OPS {
                let id = op as usize;
                for &l in &probe {
                    nested[l].push(id);
                }
                for &l in &probe {
                    let last = nested[l].len() - 1;
                    nested[l].swap_remove(last);
                }
            }
        });
        let allocs = heap_prof::snapshot().since(&a0).allocs / 4;
        push_row(
            &mut t,
            &mut report,
            "per-link churn (Vec<Vec>, 4 links/op)",
            OPS,
            timing.mean_s,
            allocs,
        );
    }

    // ---- end-to-end: engine steady-state allocations/event -----------------
    // The number the §Perf allocation-profile table quotes: a saturated
    // full simulation, allocations divided by heap events processed.
    // Run with `cargo bench --bench micro --features dhat-heap` for a
    // live count; without the feature the column prints n/a.
    {
        let cfg = SimConfig::paper();
        let mut tc = TraceConfig::scaled(320, 17);
        tc.horizon = 600.0;
        let jobs = trace::generate(&tc);
        let mut events = 0u64;
        let a0 = heap_prof::snapshot();
        let timing = bench("engine steady state (320 jobs saturated)", 1, 3, || {
            let mut placer = LwfPlacer::new(1);
            let res = sim::simulate(&cfg, &jobs, &mut placer, &AdaDual { model: cfg.comm });
            events = res.n_events;
        });
        let allocs = heap_prof::snapshot().since(&a0).allocs / 4;
        push_row(
            &mut t,
            &mut report,
            "engine steady state (320 jobs saturated)",
            events,
            timing.mean_s,
            allocs,
        );
    }

    // ---- gym-style env decision stepping -----------------------------------
    // Random-agent and builtin-agent decision-steps/sec over the same
    // saturated workload, with the SimEnv acceptance floor (module docs).
    env_step::run(&mut t, &mut report);

    t.print();
    print!("{}", report.delta_vs_committed());
    match report.write() {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write bench report: {e}"),
    }
}
