//! Decision-step throughput of the gym-style env: the saturated
//! sim_hotpath workload (320 jobs on the paper cluster) driven one
//! decision at a time through `SimEnv::step`. Two rows: a seeded
//! `RandomAgent` (pure env overhead plus whatever chaos random actions
//! cause) and the `BuiltinAgent` wrapping the paper's LWF-1 + AdaDUAL
//! (the env re-running the exact monolithic schedule, so the row is
//! directly comparable to the "engine steady state" row next to it).
//!
//! The random row carries an absolute floor (the SimEnv acceptance bar:
//! >100k decision steps/s; release builds clear it by an order of
//! magnitude), per the scale_smoke convention: only catastrophic
//! regressions — an O(jobs) observation capture, a debug-profile CI
//! misconfiguration — can fail the build, and finer tracking stays with
//! the non-fatal delta-vs-committed print.

use ddl_sched::prelude::*;
use ddl_sched::util::bench::{bench, BenchReport};
use ddl_sched::util::heap as heap_prof;

pub fn run(t: &mut Table, report: &mut BenchReport) {
    let cfg = SimConfig::paper();
    let mut tc = TraceConfig::scaled(320, 17);
    tc.horizon = 600.0;
    let jobs = trace::generate(&tc);

    // ---- random agent ------------------------------------------------------
    const CAP: u64 = 100_000;
    let mut steps = 0u64;
    let a0 = heap_prof::snapshot();
    let timing = bench("env decision steps (random agent)", 1, 3, || {
        let mut env = SimEnv::new(&cfg, &jobs);
        let mut agent = RandomAgent::new(23);
        let mut no_obs: [&mut dyn SimObserver; 0] = [];
        steps = env
            .run_agent(&mut agent, Some(CAP), &mut no_obs)
            .expect("batch rollout cannot fail");
    });
    let allocs = heap_prof::snapshot().since(&a0).allocs / 4;
    crate::push_row(t, report, "env decision steps (random agent)", steps, timing.mean_s, allocs);
    let rate = steps as f64 / timing.mean_s;
    assert!(
        rate > 100_000.0,
        "random agent fell to {:.0} env steps/s — decision loop catastrophically slower",
        rate
    );

    // ---- builtin agent -----------------------------------------------------
    let mut steps = 0u64;
    let a0 = heap_prof::snapshot();
    let timing = bench("env decision steps (builtin LWF-1/AdaDUAL)", 1, 3, || {
        let mut env = SimEnv::new(&cfg, &jobs);
        let mut agent = BuiltinAgent::new(
            Box::new(LwfPlacer::new(1)),
            Box::new(AdaDual { model: cfg.comm }),
        );
        let mut no_obs: [&mut dyn SimObserver; 0] = [];
        steps = env.run_agent(&mut agent, None, &mut no_obs).expect("batch rollout cannot fail");
    });
    let allocs = heap_prof::snapshot().since(&a0).allocs / 4;
    crate::push_row(
        t,
        report,
        "env decision steps (builtin LWF-1/AdaDUAL)",
        steps,
        timing.mean_s,
        allocs,
    );
}
