//! Fig 2 + Table I regeneration: (a) the single-All-Reduce cost model
//! `T = a + bM` fit over message sizes; (b) k-way contention times at
//! M = 100 MB vs the ideal round-robin share `a + k·b·M`; plus the
//! Table I algorithm coefficients under the α-β-γ model.
//!
//! The "measurement" substrate is the two-task/k-task continuous-time
//! contention dynamics (the same code path the simulator uses), seeded
//! with the paper's fitted constants — see DESIGN.md §Substitutions.

use ddl_sched::model::{fit_eta, AllReduceAlgo, AlphaBetaGamma, CommModel, ALL_ALGOS};
use ddl_sched::util::bench::{write_csv, Table};
use ddl_sched::util::stats::linear_fit;

fn main() {
    let cm = CommModel::paper_10gbe();

    // ---- Fig 2(a): single all-reduce, fit a + bM ------------------------
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut rows = Vec::new();
    let mut m = 1.0e6; // 1 MB .. 512 MB
    while m <= 512.0e6 {
        let t = cm.time_free(m);
        xs.push(m);
        ys.push(t);
        rows.push(vec![m, t]);
        m *= 2.0;
    }
    let (a_fit, b_fit, r2) = linear_fit(&xs, &ys);
    let mut t = Table::new(
        "Fig 2(a) — single All-Reduce cost model",
        &["quantity", "paper", "re-fit"],
    );
    t.row(&["a (s)".into(), format!("{:.3e}", 6.69e-4), format!("{a_fit:.3e}")]);
    t.row(&["b (s/B)".into(), format!("{:.3e}", 8.53e-10), format!("{b_fit:.3e}")]);
    t.row(&["r^2".into(), "-".into(), format!("{r2:.6}")]);
    t.print();
    let _ = write_csv("fig2a_single_allreduce", &["bytes", "seconds"], &rows);

    // ---- Fig 2(b): k-way contention at 100 MB ---------------------------
    let m100 = 100.0e6;
    let mut t = Table::new(
        "Fig 2(b) — k concurrent All-Reduces of 100 MB",
        &["k", "ideal a+kbM (s)", "measured (s)", "efficiency"],
    );
    let mut rows = Vec::new();
    let mut samples = Vec::new();
    for k in 1..=8usize {
        let ideal = cm.a + k as f64 * cm.b * m100;
        let measured = cm.time_contended(m100, k);
        samples.push((k, measured));
        t.row(&[
            format!("{k}"),
            format!("{ideal:.3}"),
            format!("{measured:.3}"),
            format!("{:.3}", cm.efficiency(m100, k)),
        ]);
        rows.push(vec![k as f64, ideal, measured]);
    }
    t.print();
    let _ = write_csv("fig2b_contention", &["k", "ideal_s", "measured_s"], &rows);

    // The calibration step: recover eta from the sweep (must match input).
    let eta = fit_eta(cm.a, cm.b, m100, &samples);
    println!(
        "eta re-fit from the k-sweep: {:.3e} s/B (configured {:.3e}) — {}",
        eta,
        cm.eta,
        if (eta - cm.eta).abs() / cm.eta < 1e-6 { "exact" } else { "MISMATCH" }
    );

    // ---- Table I: all-reduce algorithm coefficients ----------------------
    let p = AlphaBetaGamma::ethernet_10g();
    let mut t = Table::new(
        "Table I — All-Reduce algorithm costs (alpha-beta-gamma, N=16)",
        &["algorithm", "a (s)", "b (s/B)", "T(100MB) (s)"],
    );
    for algo in ALL_ALGOS {
        let (a, b) = algo.cost_coeffs(16, p);
        t.row(&[
            algo.name().to_string(),
            format!("{a:.3e}"),
            format!("{b:.3e}"),
            format!("{:.3}", algo.time(16, m100, p)),
        ]);
    }
    t.print();
    println!(
        "shape check: ring is bandwidth-optimal for large M; recursive doubling wins on latency"
    );
    let ring = AllReduceAlgo::Ring.time(16, 512e6, p);
    let rd = AllReduceAlgo::RecursiveDoubling.time(16, 512e6, p);
    assert!(ring < rd, "ring should win at 512MB: {ring} vs {rd}");
}
