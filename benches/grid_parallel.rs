//! The paper-grid experiment end-to-end from one JSON artifact: placers
//! {rand, ff, ls, lwf} × policies {srsf1, srsf2, srsf3, ada} — Tables IV
//! and V as a single 16-run grid — executed twice:
//!
//! * serially (`--threads 1` equivalent), and
//! * on all available cores,
//!
//! asserting the two produce **byte-identical** RunRecord JSON/CSV output
//! (the Experiment determinism contract) and reporting the wall-clock
//! speedup the worker pool buys. The same artifact drives the CLI:
//! `ddl-sched scenario-gen --grid --out grid.json &&
//!  ddl-sched sweep --scenario grid.json --threads 8`.

use std::time::Instant;

use ddl_sched::prelude::*;

fn main() {
    // Round-trip the grid through its JSON artifact form first: what runs
    // below is exactly what a shared scenario file would run.
    let artifact = Experiment::paper_grid(Scenario::paper()).to_json_text();
    let exp = Experiment::from_text(&artifact).unwrap();
    let n_runs = exp.grid().unwrap().len();
    println!(
        "paper grid: {n_runs} runs ({} placers x {} policies), {} bytes of scenario JSON\n",
        registry::PAPER_PLACERS.len(),
        registry::POLICIES.len(),
        artifact.len()
    );

    let t0 = Instant::now();
    let serial = exp.run(1).unwrap();
    let t_serial = t0.elapsed().as_secs_f64();

    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).max(2);
    let t0 = Instant::now();
    let parallel = exp.run(threads).unwrap();
    let t_parallel = t0.elapsed().as_secs_f64();

    let json_serial = records_to_json(&serial);
    let json_parallel = records_to_json(&parallel);
    assert_eq!(
        json_serial, json_parallel,
        "parallel run is not byte-identical to serial"
    );
    assert_eq!(
        records_to_csv(&serial),
        records_to_csv(&parallel),
        "parallel CSV is not byte-identical to serial"
    );

    let mut t = Table::new(
        "paper grid (Tables IV-V in one experiment)",
        &["method", "avg util", "avg JCT(s)", "median JCT(s)", "95th JCT(s)"],
    );
    for r in &serial {
        t.row(&r.eval.table_row());
    }
    t.print();

    println!(
        "\nserial: {t_serial:.2}s | {threads} threads: {t_parallel:.2}s | speedup {:.2}x {}",
        t_serial / t_parallel,
        if t_parallel < t_serial { "(OK)" } else { "(NO SPEEDUP — single-core machine?)" }
    );
    println!("records byte-identical across serial and parallel runs: OK");

    if std::fs::create_dir_all("results").is_ok() {
        let path = "results/grid_parallel_records.csv";
        if std::fs::write(path, records_to_csv(&serial)).is_ok() {
            println!("wrote {path}");
        }
    }
}
