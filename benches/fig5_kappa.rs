//! Fig 5 regeneration: the LWF-κ sweep under Ada-SRSF — JCT CDF (a),
//! GPU-utilisation distribution (b) and average JCT (c) for κ ∈
//! {1, 2, 4, 8, 16, 32}. Paper finding: κ = 1 is best overall.
//!
//! Driven by the Experiment API: one base scenario, κ axis, parallel
//! execution across worker threads.

use ddl_sched::prelude::*;

fn main() {
    let exp = Experiment {
        kappas: vec![1, 2, 4, 8, 16, 32],
        ..Experiment::single(Scenario::paper())
    };
    let threads = Experiment::default_threads();
    let t0 = std::time::Instant::now();
    let records = exp.run(threads).unwrap();
    let wall = t0.elapsed().as_secs_f64();

    let mut table = Table::new(
        "Fig 5 — LWF-kappa sweep (Ada-SRSF)",
        &["kappa", "avg util", "avg JCT(s)", "median JCT(s)", "95th JCT(s)"],
    );
    let mut results = Vec::new();
    for r in &records {
        let kappa = r.scenario.kappa;
        let eval = &r.eval;
        table.row(&[
            format!("{kappa}"),
            format!("{:.2}%", eval.avg_gpu_util * 100.0),
            format!("{:.1}", eval.jct.mean),
            format!("{:.1}", eval.jct.median),
            format!("{:.1}", eval.jct.p95),
        ]);
        let _ = write_csv(
            &format!("fig5a_cdf_k{kappa}"),
            &["jct_s", "cdf"],
            &eval.cdf_rows(),
        );
        let utils: Vec<Vec<f64>> = eval.gpu_utils.iter().map(|&u| vec![u]).collect();
        let _ = write_csv(&format!("fig5b_util_k{kappa}"), &["gpu_util"], &utils);
        results.push((kappa, eval.jct.mean));
    }
    table.print();
    println!("{} runs in {wall:.2}s on {threads} thread(s)", records.len());

    let best = results
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    println!(
        "\nbest kappa by avg JCT: {} ({:.1}s) — paper finds kappa=1 generally best: {}",
        best.0,
        best.1,
        if best.0 <= 2 { "OK" } else { "DIVERGES" }
    );
}
