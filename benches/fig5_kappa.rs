//! Fig 5 regeneration: the LWF-κ sweep under Ada-SRSF — JCT CDF (a),
//! GPU-utilisation distribution (b) and average JCT (c) for κ ∈
//! {1, 2, 4, 8, 16, 32}. Paper finding: κ = 1 is best overall.

use ddl_sched::metrics::Evaluation;
use ddl_sched::prelude::*;

fn main() {
    let jobs = trace::generate(&TraceConfig::paper_160());
    let cfg = SimConfig::paper();

    let mut table = Table::new(
        "Fig 5 — LWF-kappa sweep (Ada-SRSF)",
        &["kappa", "avg util", "avg JCT(s)", "median JCT(s)", "95th JCT(s)"],
    );
    let mut results = Vec::new();
    for kappa in [1usize, 2, 4, 8, 16, 32] {
        let mut placer = LwfPlacer::new(kappa);
        let policy = AdaDual { model: cfg.comm };
        let res = sim::simulate(&cfg, &jobs, &mut placer, &policy);
        let eval = Evaluation::from_sim(&format!("{kappa}"), &res);
        table.row(&eval.table_row());
        let _ = write_csv(
            &format!("fig5a_cdf_k{kappa}"),
            &["jct_s", "cdf"],
            &eval.cdf_rows(),
        );
        let utils: Vec<Vec<f64>> = eval.gpu_utils.iter().map(|&u| vec![u]).collect();
        let _ = write_csv(&format!("fig5b_util_k{kappa}"), &["gpu_util"], &utils);
        results.push((kappa, eval.jct.mean));
    }
    table.print();

    let best = results
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    println!(
        "\nbest kappa by avg JCT: {} ({:.1}s) — paper finds kappa=1 generally best: {}",
        best.0,
        best.1,
        if best.0 <= 2 { "OK" } else { "DIVERGES" }
    );
}
