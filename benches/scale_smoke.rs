//! CI bounded-memory + throughput smoke: a 100k-job generated trace
//! through the observer engine with sinks off. The observer redesign made
//! event cost independent of run memory (no event strings, no per-event
//! state), and the incremental scheduler state (lazy admission views,
//! release-generation/capacity-gated placement, position-mapped
//! completions) made per-event cost independent of how much is queued or
//! in flight — which is what lets this gate run a workload three orders
//! of magnitude past the paper's 160 jobs. The run must finish (every job
//! placed and completed) with an empty `events` vec; events/s lands in
//! `results/BENCH_scale_smoke.json` next to `BENCH_sim_hotpath.json`, and
//! a non-fatal delta against the committed baseline (including the
//! pre-gate 20k-job rows) is printed for the CI log.

use ddl_sched::prelude::*;
use ddl_sched::util::bench::BenchReport;

fn main() {
    let n_jobs = 100_000;
    // 256 servers x 4 GPUs; the horizon scales with the job count so the
    // per-GPU arrival density stays at roughly half the paper's — the
    // cluster keeps up and the queue stays bounded. (This is a
    // throughput/memory gate; the saturation study lives in
    // benches/sim_hotpath.rs.)
    let cluster = ClusterSpec { n_servers: 256, ..ClusterSpec::paper_64gpu() };
    let cfg = SimConfig { cluster, ..SimConfig::paper() };
    let mut trace_cfg = TraceConfig::scaled(n_jobs, 7);
    trace_cfg.horizon = 100_000.0;
    let jobs = trace::generate(&trace_cfg);
    assert_eq!(jobs.len(), n_jobs);

    let t0 = std::time::Instant::now();
    let mut placer = LwfPlacer::new(1);
    let res = sim::simulate(&cfg, &jobs, &mut placer, &AdaDual { model: cfg.comm });
    let wall = t0.elapsed().as_secs_f64();

    let finished = res.jct.iter().filter(|t| t.is_finite()).count();
    assert_eq!(finished, n_jobs, "jobs lost at scale");
    assert!(res.events.is_empty(), "sinks-off run accumulated event strings");

    let mut t = Table::new(
        "scale smoke — sinks off",
        &["workload", "events", "wall (s)", "events/s (M)", "makespan (s)"],
    );
    t.row(&[
        format!("{n_jobs} jobs / {} GPUs", cfg.cluster.n_gpus()),
        format!("{}", res.n_events),
        format!("{wall:.2}"),
        format!("{:.2}", res.n_events as f64 / wall / 1e6),
        format!("{:.0}", res.makespan),
    ]);
    t.print();

    let mut report = BenchReport::new("scale_smoke");
    report.record(&format!("{n_jobs} jobs sinks-off"), res.n_events, wall);
    // Stable-label twin row: comparable across job-count bumps (the
    // events/s-no-worse-than-baseline gate survives 20k -> 100k -> ...).
    report.record("scale gate sinks-off", res.n_events, wall);
    print!("{}", report.delta_vs_committed());
    match report.write() {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write bench report: {e}"),
    }
}
