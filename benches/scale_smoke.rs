//! CI bounded-memory smoke: a 20k-job generated trace through the
//! observer engine with sinks off. The point of the observer redesign is
//! that event cost no longer scales run memory — the engine accumulates
//! no event strings and no per-event state, so a workload two orders of
//! magnitude past the paper's completes with a flat footprint. The run
//! must finish (every job placed and completed) and must report an empty
//! `events` vec; events/s lands in `results/BENCH_scale_smoke.json` so
//! the trajectory is tracked next to `BENCH_sim_hotpath.json`.

use ddl_sched::prelude::*;
use ddl_sched::util::bench::BenchReport;

fn main() {
    let n_jobs = 20_000;
    // 256 servers x 4 GPUs: arrival density per GPU stays at roughly half
    // the paper's, so the cluster keeps up and the queue stays bounded —
    // this is a throughput/memory gate, not a saturation study.
    let cluster = ClusterSpec { n_servers: 256, ..ClusterSpec::paper_64gpu() };
    let cfg = SimConfig { cluster, ..SimConfig::paper() };
    let mut trace_cfg = TraceConfig::scaled(n_jobs, 7);
    trace_cfg.horizon = 20_000.0;
    let jobs = trace::generate(&trace_cfg);
    assert_eq!(jobs.len(), n_jobs);

    let t0 = std::time::Instant::now();
    let mut placer = LwfPlacer::new(1);
    let res = sim::simulate(&cfg, &jobs, &mut placer, &AdaDual { model: cfg.comm });
    let wall = t0.elapsed().as_secs_f64();

    let finished = res.jct.iter().filter(|t| t.is_finite()).count();
    assert_eq!(finished, n_jobs, "jobs lost at scale");
    assert!(res.events.is_empty(), "sinks-off run accumulated event strings");

    let mut t = Table::new(
        "scale smoke — sinks off",
        &["workload", "events", "wall (s)", "events/s (M)", "makespan (s)"],
    );
    t.row(&[
        format!("{n_jobs} jobs / {} GPUs", cfg.cluster.n_gpus()),
        format!("{}", res.n_events),
        format!("{wall:.2}"),
        format!("{:.2}", res.n_events as f64 / wall / 1e6),
        format!("{:.0}", res.makespan),
    ]);
    t.print();

    let mut report = BenchReport::new("scale_smoke");
    report.record(&format!("{n_jobs} jobs sinks-off"), res.n_events, wall);
    match report.write() {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write bench report: {e}"),
    }
}
