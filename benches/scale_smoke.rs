//! CI bounded-memory + throughput smoke, two gates:
//!
//! 1. **Batch**: a 100k-job generated trace through the observer engine
//!    with sinks off. The observer redesign made event cost independent of
//!    run memory (no event strings, no per-event state), and the
//!    incremental scheduler state (lazy admission views,
//!    release-generation/capacity-gated placement, position-mapped
//!    completions) made per-event cost independent of how much is queued
//!    or in flight — which is what lets this gate run a workload three
//!    orders of magnitude past the paper's 160 jobs. The run must finish
//!    (every job placed and completed) with an empty `events` vec.
//!
//! 2. **Streaming**: 1M jobs pulled lazily from a [`GeneratedSource`]
//!    (never materialized as a `Vec`) through `simulate_stream_observed`
//!    with a constant-memory [`PercentilesObserver`] — the open-ended
//!    service regime. The gate asserts every job completes and that peak
//!    RSS stays bounded; p50/p95/p99 JCT and queueing delay are printed
//!    alongside events/s and peak RSS.
//!
//! Rows land in `results/BENCH_scale_smoke.json` next to
//! `BENCH_sim_hotpath.json`, and a non-fatal delta against the committed
//! baseline is printed for the CI log.

use ddl_sched::prelude::*;
use ddl_sched::util::bench::{peak_rss_bytes, BenchReport};

fn main() {
    let n_jobs = 100_000;
    // 256 servers x 4 GPUs; the horizon scales with the job count so the
    // per-GPU arrival density stays at roughly half the paper's — the
    // cluster keeps up and the queue stays bounded. (This is a
    // throughput/memory gate; the saturation study lives in
    // benches/sim_hotpath.rs.)
    let cluster = ClusterSpec { n_servers: 256, ..ClusterSpec::paper_64gpu() };
    let cfg = SimConfig { cluster, ..SimConfig::paper() };
    let mut trace_cfg = TraceConfig::scaled(n_jobs, 7);
    trace_cfg.horizon = 100_000.0;
    let jobs = trace::generate(&trace_cfg);
    assert_eq!(jobs.len(), n_jobs);

    let t0 = std::time::Instant::now();
    let mut placer = LwfPlacer::new(1);
    let res = sim::simulate(&cfg, &jobs, &mut placer, &AdaDual { model: cfg.comm });
    let wall = t0.elapsed().as_secs_f64();

    let finished = res.jct.iter().filter(|t| t.is_finite()).count();
    assert_eq!(finished, n_jobs, "jobs lost at scale");
    assert!(res.events.is_empty(), "sinks-off run accumulated event strings");

    let mut t = Table::new(
        "scale smoke — sinks off",
        &["workload", "events", "wall (s)", "events/s (M)", "makespan (s)"],
    );
    t.row(&[
        format!("{n_jobs} jobs / {} GPUs", cfg.cluster.n_gpus()),
        format!("{}", res.n_events),
        format!("{wall:.2}"),
        format!("{:.2}", res.n_events as f64 / wall / 1e6),
        format!("{:.0}", res.makespan),
    ]);
    t.print();

    let mut report = BenchReport::new("scale_smoke");
    report.record(&format!("{n_jobs} jobs sinks-off"), res.n_events, wall);
    // Stable-label twin row: comparable across job-count bumps (the
    // events/s-no-worse-than-baseline gate survives 20k -> 100k -> ...).
    report.record("scale gate sinks-off", res.n_events, wall);

    // ---- streaming gate: 1M jobs, never materialized -------------------
    // Same cluster and the same per-GPU arrival density as the batch gate
    // (mean gap = horizon / n_jobs(cfg) = 1 s), but the jobs come from an
    // open lazy source capped at 1M — the trace Vec never exists, and the
    // only per-job state left at the end is the engine's flat runtime
    // records plus the observer's P^2 markers.
    let n_stream: usize = 1_000_000;
    let mut stream_cfg = TraceConfig::scaled(100_000, 11);
    stream_cfg.horizon = 100_000.0;
    let mut src = GeneratedSource::new(&stream_cfg, Some(n_stream));
    let mut pct = PercentilesObserver::new();
    let t0 = std::time::Instant::now();
    {
        let mut placer = LwfPlacer::new(1);
        let policy = AdaDual { model: cfg.comm };
        let mut obs: [&mut dyn SimObserver; 1] = [&mut pct];
        sim::simulate_stream_observed(&cfg, &mut src, &mut placer, &policy, &mut obs)
            .expect("streaming gate failed");
    }
    let wall_stream = t0.elapsed().as_secs_f64();
    assert_eq!(pct.arrived(), n_stream as u64, "source under-delivered");
    assert_eq!(pct.finished(), n_stream as u64, "jobs lost in the stream");
    assert_eq!(pct.in_flight(), 0);

    let rss = peak_rss_bytes();
    if let Some(bytes) = rss {
        // Bounded-RSS gate: generous (covers the batch run's 100k-job
        // trace too), but far below what accidentally materializing 1M
        // jobs' event strings or per-event observer state would cost.
        assert!(
            bytes < 4 * 1024 * 1024 * 1024,
            "streaming run peak RSS {bytes} B — memory no longer bounded"
        );
    }
    let rss_mb =
        rss.map_or("n/a".to_string(), |b| format!("{:.0}", b as f64 / (1024.0 * 1024.0)));
    let jct = pct.jct_stats();
    let q = pct.queue_delay_stats();
    let mut t = Table::new(
        "scale smoke — streamed 1M jobs",
        &["metric", "p50", "p95", "p99", "mean"],
    );
    t.row(&[
        "JCT (s)".to_string(),
        format!("{:.1}", jct.p50),
        format!("{:.1}", jct.p95),
        format!("{:.1}", jct.p99),
        format!("{:.1}", jct.mean),
    ]);
    t.row(&[
        "queue delay (s)".to_string(),
        format!("{:.1}", q.p50),
        format!("{:.1}", q.p95),
        format!("{:.1}", q.p99),
        format!("{:.1}", q.mean),
    ]);
    t.print();
    println!(
        "streamed {} jobs: {} events in {:.2} s ({:.2} Mev/s), makespan {:.0} s, peak RSS {} MB",
        n_stream,
        pct.n_events(),
        wall_stream,
        pct.n_events() as f64 / wall_stream / 1e6,
        pct.makespan(),
        rss_mb,
    );

    // Absolute throughput floor, deliberately an order of magnitude under
    // any plausible machine (release builds clear 1 Mev/s comfortably):
    // catches only catastrophic regressions — an accidental O(n) scan per
    // event, a debug-profile CI misconfiguration — while staying immune
    // to runner noise. Finer-grained tracking stays with the non-fatal
    // delta-vs-committed print below, per the bench convention.
    let stream_evs = pct.n_events() as f64 / wall_stream;
    assert!(
        stream_evs > 0.1e6,
        "streamed gate fell to {:.3} Mev/s — hot path catastrophically slower",
        stream_evs / 1e6
    );

    report.record_with_rss(&format!("{n_stream} jobs streamed"), pct.n_events(), wall_stream);
    // Stable-label twin, same convention as the batch gate's.
    report.record_with_rss("stream gate percentiles", pct.n_events(), wall_stream);
    print!("{}", report.delta_vs_committed());
    match report.write() {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write bench report: {e}"),
    }
}
